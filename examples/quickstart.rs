//! Quickstart — the smallest end-to-end FP8FedAvg-UQ run.
//!
//! Trains the `mlp_c10` variant on synthetic vision data with 20
//! clients for 20 rounds of FP8 QAT + unbiased 8-bit communication,
//! printing the accuracy curve and the communication saving vs what
//! FP32 payloads would have cost.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;

use fedfp8::config::ExperimentConfig;
use fedfp8::coordinator::Server;
use fedfp8::runtime::{default_dir, Engine, Manifest};

fn main() -> Result<()> {
    let dir = default_dir();
    let engine = Engine::new(&dir)?;
    let manifest = Manifest::load(&dir)?;
    println!("PJRT platform: {}", engine.platform());

    let mut cfg = ExperimentConfig::preset("mlp_c10:uq:iid")?;
    cfg.clients = 20;
    cfg.participation = 5;
    cfg.rounds = 20;
    cfg.n_train = 2000;
    cfg.eval_every = 2;

    let model = manifest.model(&cfg.model)?;
    println!(
        "model {}: {} params ({} quantized tensors), U={} local steps",
        model.name,
        model.dim,
        model.alpha_dim,
        model.u_steps
    );

    let mut server = Server::new(&engine, &manifest, cfg)?;
    server.set_verbose(true);
    let result = server.run()?;

    // What would the same traffic have cost in FP32?
    let quant = model.quant_params() as u64;
    let raw = model.raw_params() as u64;
    let fp8_msg = quant + 4 * (raw + model.alpha_dim as u64
        + model.n_act as u64);
    let fp32_msg = 4 * model.dim as u64
        + 4 * (model.alpha_dim + model.n_act) as u64;
    println!(
        "\nfinal accuracy: {:.3}   best: {:.3}",
        result.final_accuracy,
        result.best_accuracy()
    );
    println!(
        "total communicated: {:.2} MiB ({} msgs); same messages in \
         FP32: {:.2} MiB -> {:.2}x per-round saving",
        result.total_bytes as f64 / (1 << 20) as f64,
        result.records.len() * (server.cfg.participation * 2),
        (result.total_bytes as f64 / fp8_msg as f64) * fp32_msg as f64
            / (1 << 20) as f64,
        fp32_msg as f64 / fp8_msg as f64
    );
    Ok(())
}
