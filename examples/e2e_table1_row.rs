//! End-to-end driver — reproduces one full Table-1 row, all three
//! methods (FP32 FedAvg, FP8FedAvg-UQ, FP8FedAvg-UQ+), on a real
//! (synthetic-CIFAR10) federated workload, and reports the paper's
//! headline metric: final accuracy + communication gain.
//!
//! This is the repo's "proves all layers compose" example: the Rust
//! coordinator samples clients, packs physical 8-bit payloads, the
//! PJRT runtime executes the AOT-lowered JAX graphs whose QAT
//! quantizer is the Pallas L1 kernel, ServerOptimize alternates Eq.(4)
//! HLO gradient steps with the Eq.(5) codec grid search — for a few
//! hundred client-rounds end to end.
//!
//! ```sh
//! cargo run --release --example e2e_table1_row -- \
//!     --model lenet_c10 --split iid --rounds 40
//! ```

use anyhow::Result;

use fedfp8::bench_tables::run_one;
use fedfp8::config::ExperimentConfig;
use fedfp8::coordinator::comm_gain;
use fedfp8::runtime::{default_dir, Engine, Manifest};
use fedfp8::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_env();
    let model = args.get_or("model", "lenet_c10");
    let split = args.get_or("split", "iid");
    let rounds: usize = args.parse_or("rounds", 40)?;
    let seed: u64 = args.parse_or("seed", 1u64)?;

    let dir = default_dir();
    let engine = Engine::new(&dir)?;
    let manifest = Manifest::load(&dir)?;

    let mut results = Vec::new();
    for method in ["fp32", "uq", "uq+"] {
        let mut cfg = ExperimentConfig::base(&model)?
            .with_method(method)?
            .with_split(&split)?;
        cfg.rounds = rounds;
        cfg.seed = seed;
        eprintln!("=== {} ===", cfg.name);
        let r = run_one(&engine, &manifest, cfg, true)?;
        results.push(r);
    }

    println!(
        "\nTable-1 row: {model} / {split} (rounds={rounds}, seed={seed})"
    );
    println!(
        "{:<16} {:>10} {:>12} {:>12} {:>10}",
        "method", "best acc", "total MiB", "bytes/round", "gain"
    );
    for r in &results {
        let (_, gain) = comm_gain(&results[0], r);
        println!(
            "{:<16} {:>10.4} {:>12.2} {:>12.0} {:>9.1}x",
            r.name,
            r.best_accuracy(),
            r.total_bytes as f64 / (1 << 20) as f64,
            r.total_bytes as f64 / r.records.len() as f64,
            gain
        );
    }
    let st = engine.stats();
    println!(
        "\nengine: {} HLO executions, {:.1}s exec / {:.1}s marshal / \
         {:.1}s compile",
        st.executions,
        st.execute_ns as f64 * 1e-9,
        st.marshal_ns as f64 * 1e-9,
        st.compile_ns as f64 * 1e-9
    );
    Ok(())
}
