//! Speaker-partitioned keyword spotting — the paper's "realistic
//! heterogeneity" scenario: every client is one speaker, with their
//! own timbre, pitch and word preferences (SpeechCommands speaker-id
//! split, §4).
//!
//! Runs MatchboxNet-style FP8FedAvg-UQ with AdamW local training and a
//! cosine learning-rate schedule, and contrasts the speaker split with
//! the i.i.d. split to expose the heterogeneity gap.
//!
//! ```sh
//! cargo run --release --example speech_speaker_id -- --rounds 30
//! ```

use anyhow::Result;

use fedfp8::config::ExperimentConfig;
use fedfp8::coordinator::Server;
use fedfp8::data::partition;
use fedfp8::runtime::{default_dir, Engine, Manifest};
use fedfp8::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_env();
    let rounds: usize = args.parse_or("rounds", 30)?;
    let model = args.get_or("model", "matchbox");

    let dir = default_dir();
    let engine = Engine::new(&dir)?;
    let manifest = Manifest::load(&dir)?;

    // show the skew the speaker split induces
    {
        use fedfp8::data::speech::{generate, SpeechCfg};
        let (train, _) = generate(&SpeechCfg::new(12, 64), 3200, 64, 1);
        let shards = partition::by_group(&train);
        println!(
            "speaker split: {} clients, majority-label fraction {:.2} \
             (1/classes = {:.2})",
            shards.len(),
            partition::skew(&train, &shards),
            1.0 / 12.0
        );
    }

    let mut outcomes = Vec::new();
    for split in ["iid", "speaker"] {
        let mut cfg = ExperimentConfig::base(&model)?
            .with_method("uq")?
            .with_split(split)?;
        cfg.rounds = rounds;
        eprintln!("=== {} ===", cfg.name);
        let mut server = Server::new(&engine, &manifest, cfg)?;
        server.set_verbose(true);
        let r = server.run()?;
        outcomes.push((split, r));
    }

    println!("\n{:<10} {:>10} {:>12}", "split", "best acc", "total MiB");
    for (split, r) in &outcomes {
        println!(
            "{:<10} {:>10.4} {:>12.2}",
            split,
            r.best_accuracy(),
            r.total_bytes as f64 / (1 << 20) as f64
        );
    }
    println!(
        "\n(the i.i.d. > speaker gap mirrors the paper's Table 1 \
         SpeechCommands rows)"
    );
    Ok(())
}
