//! Quantizer ablation (Table-2 style) on one model: deterministic vs
//! stochastic rounding in QAT and in communication.
//!
//! Demonstrates the paper's two design rules (Remarks 3-5):
//!   * training quantization should be DETERMINISTIC (smaller error
//!     norm -> better QAT), and
//!   * communication quantization should be STOCHASTIC (unbiased ->
//!     FedAvg converges; biased resets can stall or diverge).
//!
//! ```sh
//! cargo run --release --example ablation_quantizers -- \
//!     --model lenet_c100 --rounds 40
//! ```

use anyhow::Result;

use fedfp8::config::ExperimentConfig;
use fedfp8::coordinator::Server;
use fedfp8::runtime::{default_dir, Engine, Manifest};
use fedfp8::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_env();
    let model = args.get_or("model", "lenet_c100");
    let rounds: usize = args.parse_or("rounds", 30)?;

    let dir = default_dir();
    let engine = Engine::new(&dir)?;
    let manifest = Manifest::load(&dir)?;

    let arms = [
        ("nocq_det", "det QAT, no CQ"),
        ("nocq_rand", "rand QAT, no CQ"),
        ("bq", "det QAT, det CQ (biased)"),
        ("uq", "det QAT, rand CQ (unbiased)"),
        // extension: error feedback rescuing the biased arm (Remark 3)
        ("bq_ef", "det QAT, det CQ + error feedback"),
    ];

    let mut rows = Vec::new();
    for (method, label) in arms {
        let mut cfg = ExperimentConfig::base(&model)?
            .with_method(method)?
            .with_split("iid")?;
        cfg.rounds = rounds;
        eprintln!("=== {label} ===");
        let mut server = Server::new(&engine, &manifest, cfg)?;
        let r = server.run()?;
        rows.push((label, r));
    }

    println!("\n{:<30} {:>10} {:>12}", "arm", "best acc", "total MiB");
    for (label, r) in &rows {
        println!(
            "{:<30} {:>10.4} {:>12.2}",
            label,
            r.best_accuracy(),
            r.total_bytes as f64 / (1 << 20) as f64
        );
    }
    let det_cq = rows[2].1.best_accuracy();
    let rand_cq = rows[3].1.best_accuracy();
    println!(
        "\nunbiased-vs-biased CQ delta: {:+.4} (paper: rand CQ wins \
         decisively, e.g. 44.8 vs 38.0 on LeNet/CIFAR100)",
        rand_cq - det_cq
    );
    Ok(())
}
