/* C mirror of rust/benches/fp8_kernels.rs — seeds BENCH_fp8_kernels.json
 * when no Rust toolchain is available.
 *
 * Replicates the Rust kernels op-for-op (same f64 scalar math, PCG32
 * streams, memory layouts, block sizes and thread fan-out) so the
 * before/after ratios transfer:
 *   - encode: scalar per-element RNG path vs batched block-filled
 *     draws, sequential and pooled
 *   - decode: 256-entry table rebuilt per call vs cached LUT,
 *     sequential and pooled
 *   - Eq. (5) alpha search: naive O(G*K*d) client rescan vs
 *     sufficient-statistics O(d*(K+G)), sequential and pooled
 *   - kernel arms: scalar-oracle inner loop vs the AVX2 lane kernel
 *     (`--fp8-kernel simd`, rust/src/fp8/simd.rs) on the encode and
 *     Eq. (5) paths (runtime-gated; bit-identical by the conformance
 *     contract — see tools/fp8_kernel_conformance.c)
 *
 * Build & run (repo root):
 *   gcc -O3 -o /tmp/fp8_mirror tools/bench_fp8_mirror.c -lm -lpthread
 *   /tmp/fp8_mirror            # writes BENCH_fp8_kernels.json
 *
 * `cargo bench --bench fp8_kernels` overwrites the JSON with native
 * Rust numbers whenever a Rust toolchain is present.
 */

#include <immintrin.h>
#include <math.h>
#include <pthread.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>
#include <unistd.h>

/* ---- FP8 format (twin of rust/src/fp8/format.rs) ------------------ */

#define M_BITS 3
#define E_MAX 15
#define LOG2_TOP 0.9068905956085185

typedef struct {
    float alpha;
    double bias, exp2_bias, sub_scale, scales[16];
} Fp8Params;

static Fp8Params params_new(float alpha) {
    Fp8Params p;
    p.alpha = alpha;
    p.bias = 16.0 - log2((double)alpha) + LOG2_TOP - 1.0;
    p.exp2_bias = exp2(p.bias);
    p.sub_scale = exp2(1.0 - p.bias - M_BITS);
    for (int c = 0; c < 16; c++)
        p.scales[c] = exp2((double)c - p.bias - M_BITS);
    return p;
}

static inline int64_t code_exponent(const Fp8Params *p, double absx) {
    double u = absx * p->exp2_bias;
    uint64_t bits;
    memcpy(&bits, &u, 8);
    return (int64_t)((bits >> 52) & 0x7FF) - 1023;
}

static inline double fp8_scale(const Fp8Params *p, double absx) {
    int64_t c = code_exponent(p, absx);
    return c > 1 ? p->scales[c < 15 ? c : 15] : p->sub_scale;
}

static inline float fp8_quantize(const Fp8Params *p, float x, double u) {
    if (x == 0.0f || isnan(x)) return 0.0f;
    double x64 = (double)x;
    double s = fp8_scale(p, fabs(x64));
    double z = x64 / s;
    double f = floor(z);
    double q = (f + ((z - f >= u) ? 1.0 : 0.0)) * s;
    double a = (double)p->alpha;
    if (q > a) q = a;
    if (q < -a) q = -a;
    return (float)q;
}

static inline uint8_t fp8_encode(const Fp8Params *p, float x, double u) {
    if (x == 0.0f || !isfinite(x)) {
        if (isnan(x)) return 0;
        if (isfinite(x)) return 0;
        return (uint8_t)(((x < 0.0f) ? 0x80 : 0) | 0x7F);
    }
    int neg = x < 0.0f;
    double absx = fabs((double)x);
    int64_t c = code_exponent(p, absx);
    int64_t n;
    if (c > 1) {
        if (c > E_MAX) return (uint8_t)((neg << 7) | 0x7F);
        double s = p->scales[c];
        double z = absx / s, f = floor(z);
        int up = neg ? (1.0 - (z - f) < u) : (z - f >= u);
        n = (int64_t)f + up;
        if (n >= (1 << (M_BITS + 1))) { c += 1; n = 1 << M_BITS; }
        if (n < (1 << M_BITS)) { c -= 1; n = (1 << (M_BITS + 1)) - 1; }
        if (c > E_MAX) return (uint8_t)((neg << 7) | 0x7F);
        return (uint8_t)((neg << 7) | ((int)c << M_BITS) | (n & 7));
    }
    double z = absx / p->sub_scale, f = floor(z);
    int up = neg ? (1.0 - (z - f) < u) : (z - f >= u);
    n = (int64_t)f + up;
    if (n > (1 << (M_BITS + 1))) n = 1 << (M_BITS + 1);
    return (uint8_t)((neg << 7) | ((n >> M_BITS) << M_BITS) | (n & 7));
}

/* ---- AVX2 lane kernel (twin of rust/src/fp8/simd.rs::Avx2Kernel;
 * validated bit-identical over all 2^32 f32 patterns by
 * tools/fp8_kernel_conformance.c). target attributes keep the
 * documented plain `gcc -O3` build line working; runtime gate is
 * __builtin_cpu_supports("avx2"). ------------------------------------ */

__attribute__((target("avx2"))) static inline __m128i
narrow64(__m256i v) {
    return _mm256_castsi256_si128(_mm256_permutevar8x32_epi32(
        v, _mm256_setr_epi32(0, 2, 4, 6, 0, 0, 0, 0)));
}

__attribute__((target("avx2"))) static inline __m256d
scale_lookup(const double *scales, __m128i idx) {
    return _mm256_setr_pd(scales[(uint32_t)_mm_extract_epi32(idx, 0)],
                          scales[(uint32_t)_mm_extract_epi32(idx, 1)],
                          scales[(uint32_t)_mm_extract_epi32(idx, 2)],
                          scales[(uint32_t)_mm_extract_epi32(idx, 3)]);
}

__attribute__((target("avx2"))) static void
encode4_avx2(const Fp8Params *p, const float *src, const double *us,
             uint8_t *dst) {
    __m128 xs = _mm_loadu_ps(src);
    __m256d x = _mm256_cvtps_pd(xs);
    __m256d absx = _mm256_andnot_pd(_mm256_set1_pd(-0.0), x);
    __m256d ub = _mm256_mul_pd(absx, _mm256_set1_pd(p->exp2_bias));
    __m256i ebits = _mm256_and_si256(
        _mm256_srli_epi64(_mm256_castpd_si256(ub), 52),
        _mm256_set1_epi64x(0x7FF));
    __m128i c32 = _mm_sub_epi32(narrow64(ebits), _mm_set1_epi32(1023));
    __m128i is_sub32 = _mm_cmpgt_epi32(_mm_set1_epi32(2), c32);
    __m128i idx = _mm_min_epi32(
        _mm_max_epi32(c32, _mm_setzero_si128()), _mm_set1_epi32(15));
    __m256d s = _mm256_blendv_pd(
        scale_lookup(p->scales, idx), _mm256_set1_pd(p->sub_scale),
        _mm256_castsi256_pd(_mm256_cvtepi32_epi64(is_sub32)));
    __m256d z = _mm256_div_pd(absx, s);
    __m256d f = _mm256_floor_pd(z);
    __m256d frac = _mm256_sub_pd(z, f);
    __m256d u = _mm256_loadu_pd(us);
    __m256d neg_pd =
        _mm256_cmp_pd(x, _mm256_setzero_pd(), _CMP_LT_OQ);
    __m256d up_pd = _mm256_blendv_pd(
        _mm256_cmp_pd(frac, u, _CMP_GE_OQ),
        _mm256_cmp_pd(_mm256_sub_pd(_mm256_set1_pd(1.0), frac), u,
                      _CMP_LT_OQ),
        neg_pd);
    __m128i fi = _mm256_cvttpd_epi32(
        _mm256_min_pd(f, _mm256_set1_pd(17.0)));
    __m128i n32 =
        _mm_sub_epi32(fi, narrow64(_mm256_castpd_si256(up_pd)));
    __m128i carry = _mm_cmpgt_epi32(n32, _mm_set1_epi32(15));
    __m128i jitter = _mm_cmpgt_epi32(_mm_set1_epi32(8), n32);
    __m128i c_adj = _mm_add_epi32(_mm_sub_epi32(c32, carry), jitter);
    __m128i n_adj = _mm_blendv_epi8(n32, _mm_set1_epi32(8), carry);
    n_adj = _mm_blendv_epi8(n_adj, _mm_set1_epi32(15), jitter);
    __m128i sat = _mm_cmpgt_epi32(c_adj, _mm_set1_epi32(15));
    __m128i code_norm = _mm_or_si128(
        _mm_slli_epi32(c_adj, M_BITS),
        _mm_and_si128(n_adj, _mm_set1_epi32(7)));
    code_norm = _mm_blendv_epi8(code_norm, _mm_set1_epi32(0x7F), sat);
    __m128i mag = _mm_blendv_epi8(
        code_norm, _mm_min_epi32(n32, _mm_set1_epi32(16)), is_sub32);
    __m128i code = _mm_or_si128(
        mag, _mm_and_si128(narrow64(_mm256_castpd_si256(neg_pd)),
                           _mm_set1_epi32(0x80)));
    __m256d kill_pd = _mm256_or_pd(
        _mm256_cmp_pd(x, _mm256_setzero_pd(), _CMP_EQ_OQ),
        _mm256_cmp_pd(x, x, _CMP_UNORD_Q));
    code = _mm_andnot_si128(narrow64(_mm256_castpd_si256(kill_pd)),
                            code);
    __m128i packed = _mm_shuffle_epi8(
        code, _mm_setr_epi8(0, 4, 8, 12, -1, -1, -1, -1, -1, -1, -1,
                            -1, -1, -1, -1, -1));
    uint32_t out4 = (uint32_t)_mm_cvtsi128_si32(packed);
    memcpy(dst, &out4, 4);
}

__attribute__((target("avx2"))) static void
quantize4_avx2(const Fp8Params *p, const float *src, const double *us,
               float *dst) {
    __m128 xs = _mm_loadu_ps(src);
    __m256d x = _mm256_cvtps_pd(xs);
    __m256d absx = _mm256_andnot_pd(_mm256_set1_pd(-0.0), x);
    __m256d ub = _mm256_mul_pd(absx, _mm256_set1_pd(p->exp2_bias));
    __m256i ebits = _mm256_and_si256(
        _mm256_srli_epi64(_mm256_castpd_si256(ub), 52),
        _mm256_set1_epi64x(0x7FF));
    __m128i c32 = _mm_sub_epi32(narrow64(ebits), _mm_set1_epi32(1023));
    __m128i is_sub32 = _mm_cmpgt_epi32(_mm_set1_epi32(2), c32);
    __m128i idx = _mm_min_epi32(
        _mm_max_epi32(c32, _mm_setzero_si128()), _mm_set1_epi32(15));
    __m256d s = _mm256_blendv_pd(
        scale_lookup(p->scales, idx), _mm256_set1_pd(p->sub_scale),
        _mm256_castsi256_pd(_mm256_cvtepi32_epi64(is_sub32)));
    __m256d z = _mm256_div_pd(x, s);
    __m256d f = _mm256_floor_pd(z);
    __m256d up = _mm256_and_pd(
        _mm256_cmp_pd(_mm256_sub_pd(z, f), _mm256_loadu_pd(us),
                      _CMP_GE_OQ),
        _mm256_set1_pd(1.0));
    __m256d q = _mm256_mul_pd(_mm256_add_pd(f, up), s);
    __m256d a = _mm256_set1_pd((double)p->alpha);
    q = _mm256_min_pd(
        _mm256_max_pd(q, _mm256_sub_pd(_mm256_setzero_pd(), a)), a);
    __m128 qf = _mm256_cvtpd_ps(q);
    __m256d kill_pd = _mm256_or_pd(
        _mm256_cmp_pd(x, _mm256_setzero_pd(), _CMP_EQ_OQ),
        _mm256_cmp_pd(x, x, _CMP_UNORD_Q));
    __m128 kill =
        _mm_castsi128_ps(narrow64(_mm256_castpd_si256(kill_pd)));
    _mm_storeu_ps(dst, _mm_andnot_ps(kill, qf));
}

static inline float fp8_decode(const Fp8Params *p, uint8_t code) {
    int neg = (code & 0x80) != 0;
    int64_t e = (code >> M_BITS) & 0x0F;
    double m = (double)(code & 7);
    double v = e == 0 ? p->sub_scale * m
                      : exp2((double)e - p->bias) * (1.0 + m / 8.0);
    float vf = (float)v;
    return neg ? -vf : vf;
}

static void decode_table(const Fp8Params *p, float t[256]) {
    for (int i = 0; i < 256; i++) t[i] = fp8_decode(p, (uint8_t)i);
}

/* ---- PCG32 (twin of rust/src/fp8/rng.rs) -------------------------- */

typedef struct { uint64_t state, inc; } Pcg32;

static uint64_t splitmix(uint64_t *s) {
    *s += 0x9E3779B97F4A7C15ULL;
    uint64_t z = *s;
    z = (z ^ (z >> 30)) * 0xBF58476D1CE4E5B9ULL;
    z = (z ^ (z >> 27)) * 0x94D049BB133111EBULL;
    return z ^ (z >> 31);
}

static inline uint32_t pcg_u32(Pcg32 *r) {
    uint64_t old = r->state;
    r->state = old * 6364136223846793005ULL + r->inc;
    uint32_t xs = (uint32_t)(((old >> 18) ^ old) >> 27);
    uint32_t rot = (uint32_t)(old >> 59);
    return (xs >> rot) | (xs << ((32 - rot) & 31));
}

static Pcg32 pcg_new(uint64_t seed, uint64_t stream) {
    uint64_t s = seed ^ ((stream << 17) | (stream >> 47));
    Pcg32 r;
    r.state = splitmix(&s);
    r.inc = splitmix(&s) | 1;
    pcg_u32(&r);
    return r;
}

static inline uint64_t pcg_u64(Pcg32 *r) {
    return ((uint64_t)pcg_u32(r) << 32) | pcg_u32(r);
}

static inline double pcg_f64(Pcg32 *r) {
    return (double)(pcg_u64(r) >> 11) * (1.0 / 9007199254740992.0);
}

static uint64_t mix(uint64_t h, uint64_t v) {
    uint64_t z = (h ^ v) + 0x9E3779B97F4A7C15ULL;
    z = (z ^ (z >> 30)) * 0xBF58476D1CE4E5B9ULL;
    z = (z ^ (z >> 27)) * 0x94D049BB133111EBULL;
    return z ^ (z >> 31);
}

static Pcg32 pcg_derive(uint64_t seed, uint64_t a, uint64_t b,
                        uint64_t domain) {
    uint64_t h = mix(mix(mix(seed, domain), a), b);
    uint64_t stream = domain ^ ((b << 32) | (b >> 32)) ^ a;
    return pcg_new(h, stream);
}

/* ---- bench harness (twin of rust/src/util/bench.rs) --------------- */

typedef struct {
    const char *name;
    long iters;
    double median_ns, p10_ns, p90_ns;
} BResult;

static double now_ns(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return ts.tv_sec * 1e9 + ts.tv_nsec;
}

static int cmp_d(const void *a, const void *b) {
    double x = *(const double *)a, y = *(const double *)b;
    return (x > y) - (x < y);
}

#define MAX_SAMPLES 100000
static double SAMPLES[MAX_SAMPLES];

static BResult bench_run(const char *name, void (*f)(void),
                         double budget_ms) {
    double warm_end = now_ns() + budget_ms * 1e6 / 5.0; /* ms/5 warmup */
    while (now_ns() < warm_end) f();
    long n = 0;
    double end = now_ns() + budget_ms * 1e6;
    while ((now_ns() < end || n < 5) && n < MAX_SAMPLES) {
        double t0 = now_ns();
        f();
        SAMPLES[n++] = now_ns() - t0;
    }
    qsort(SAMPLES, n, sizeof(double), cmp_d);
    BResult r;
    r.name = name;
    r.iters = n;
    r.median_ns = SAMPLES[(long)((n - 1) * 0.5)];
    r.p10_ns = SAMPLES[(long)((n - 1) * 0.1)];
    r.p90_ns = SAMPLES[(long)((n - 1) * 0.9)];
    printf("%-44s %12.0f %12.0f %12.0f  (ns, median/p10/p90)\n",
           r.name, r.median_ns, r.p10_ns, r.p90_ns);
    return r;
}

/* ---- workload (matches the Rust bench config) --------------------- */

#define DIM 100000
#define TENSORS 4
#define SEG (DIM / TENSORS)
#define K_CLIENTS 8
#define GRID 32
#define RNG_BLOCK 4096
#define WIRE_DOMAIN 0xF8B10C5EULL

static int POOL = 2;
static float W_VEC[DIM];
static float CLIENTS[K_CLIENTS][DIM];
static float KW[K_CLIENTS];
static double US[TENSORS][SEG];
static float ALPHAS[TENSORS];
static Fp8Params PARAMS[TENSORS];
static float TABLES[TENSORS][256];
static uint8_t CODES[DIM];
static float DEC_OUT[DIM];
static Pcg32 KEY_RNG;
static double SS_S[TENSORS][SEG], SS_T[TENSORS][SEG];
static volatile double SINK;

/* ---- encode arms -------------------------------------------------- */

static void enc_scalar(void) {
    uint64_t key = pcg_u64(&KEY_RNG);
    size_t ci = 0;
    for (int si = 0; si < TENSORS; si++) {
        const Fp8Params *p = &PARAMS[si];
        const float *vals = W_VEC + si * SEG;
        for (int b = 0; b * RNG_BLOCK < SEG; b++) {
            int lo = b * RNG_BLOCK;
            int hi = lo + RNG_BLOCK < SEG ? lo + RNG_BLOCK : SEG;
            Pcg32 r = pcg_derive(key, si, b, WIRE_DOMAIN);
            for (int i = lo; i < hi; i++)
                CODES[ci++] = fp8_encode(p, vals[i], pcg_f64(&r));
        }
    }
}

static void enc_batched_range(int seg_lo, int seg_hi, uint64_t key,
                              double *scratch) {
    for (int si = seg_lo; si < seg_hi; si++) {
        const Fp8Params *p = &PARAMS[si];
        const float *vals = W_VEC + si * SEG;
        uint8_t *dst = CODES + si * SEG;
        for (int b = 0; b * RNG_BLOCK < SEG; b++) {
            int lo = b * RNG_BLOCK;
            int hi = lo + RNG_BLOCK < SEG ? lo + RNG_BLOCK : SEG;
            Pcg32 r = pcg_derive(key, si, b, WIRE_DOMAIN);
            for (int i = 0; i < hi - lo; i++) scratch[i] = pcg_f64(&r);
            for (int i = lo; i < hi; i++)
                dst[i] = fp8_encode(p, vals[i], scratch[i - lo]);
        }
    }
}

static void enc_batched(void) {
    static double scratch[RNG_BLOCK];
    enc_batched_range(0, TENSORS, pcg_u64(&KEY_RNG), scratch);
}

/* AVX2-kernel encode arm: identical stream/block structure to
 * enc_batched, only the inner loop swaps to the 4-wide lanes (the
 * exact shape of `--fp8-kernel simd` in Rust). */
static void enc_avx2_range(int seg_lo, int seg_hi, uint64_t key,
                           double *scratch) {
    for (int si = seg_lo; si < seg_hi; si++) {
        const Fp8Params *p = &PARAMS[si];
        const float *vals = W_VEC + si * SEG;
        uint8_t *dst = CODES + si * SEG;
        for (int b = 0; b * RNG_BLOCK < SEG; b++) {
            int lo = b * RNG_BLOCK;
            int hi = lo + RNG_BLOCK < SEG ? lo + RNG_BLOCK : SEG;
            int len = hi - lo, l4 = len & ~3;
            Pcg32 r = pcg_derive(key, si, b, WIRE_DOMAIN);
            for (int i = 0; i < len; i++) scratch[i] = pcg_f64(&r);
            for (int i = 0; i < l4; i += 4)
                encode4_avx2(p, vals + lo + i, scratch + i,
                             dst + lo + i);
            for (int i = l4; i < len; i++)
                dst[lo + i] = fp8_encode(p, vals[lo + i], scratch[i]);
        }
    }
}

static void enc_avx2(void) {
    static double scratch[RNG_BLOCK];
    enc_avx2_range(0, TENSORS, pcg_u64(&KEY_RNG), scratch);
}

typedef struct { int lo, hi; uint64_t key; } EncJob;

static void *enc_worker(void *arg) {
    EncJob *j = (EncJob *)arg;
    double *scratch = malloc(RNG_BLOCK * sizeof(double));
    enc_batched_range(j->lo, j->hi, j->key, scratch);
    free(scratch);
    return NULL;
}

static void enc_pooled(void) {
    uint64_t key = pcg_u64(&KEY_RNG);
    pthread_t th[8];
    EncJob jobs[8];
    int per = (TENSORS + POOL - 1) / POOL;
    int n = 0;
    for (int lo = 0; lo < TENSORS; lo += per, n++) {
        jobs[n].lo = lo;
        jobs[n].hi = lo + per < TENSORS ? lo + per : TENSORS;
        jobs[n].key = key;
        pthread_create(&th[n], NULL, enc_worker, &jobs[n]);
    }
    for (int i = 0; i < n; i++) pthread_join(th[i], NULL);
}

/* ---- decode arms -------------------------------------------------- */

static void dec_rebuild(void) {
    size_t ci = 0;
    for (int si = 0; si < TENSORS; si++) {
        float t[256];
        decode_table(&PARAMS[si], t);
        float *dst = DEC_OUT + si * SEG;
        for (int i = 0; i < SEG; i++) dst[i] = t[CODES[ci++]];
    }
}

static void dec_cached_range(int seg_lo, int seg_hi) {
    for (int si = seg_lo; si < seg_hi; si++) {
        const float *t = TABLES[si];
        const uint8_t *src = CODES + si * SEG;
        float *dst = DEC_OUT + si * SEG;
        for (int i = 0; i < SEG; i++) dst[i] = t[src[i]];
    }
}

static void dec_cached(void) { dec_cached_range(0, TENSORS); }
/* No pooled decode arm: at ~1 ns/element the Rust decode_pooled only
 * fans out above 2^20 elements, and DIM here is below that gate. */

/* ---- Eq. (5) arms ------------------------------------------------- */

static float cand_alpha(int gi) { return 0.5f + (float)gi / GRID; }

static void eq5_naive(void) {
    double best = 1e300;
    for (int si = 0; si < TENSORS; si++) {
        int off = si * SEG;
        for (int gi = 0; gi < GRID; gi++) {
            Fp8Params p = params_new(cand_alpha(gi));
            double total = 0.0;
            for (int i = 0; i < SEG; i++) {
                double q = fp8_quantize(&p, W_VEC[off + i], US[si][i]);
                for (int k = 0; k < K_CLIENTS; k++) {
                    double d = q - (double)CLIENTS[k][off + i];
                    total += (double)KW[k] * d * d;
                }
            }
            if (total < best) best = total;
        }
    }
    SINK = best;
}

static double ss_wsum(void) {
    double w = 0;
    for (int k = 0; k < K_CLIENTS; k++) w += KW[k];
    return w;
}

static void ss_build(void) {
    for (int si = 0; si < TENSORS; si++) {
        int off = si * SEG;
        memset(SS_S[si], 0, sizeof(SS_S[si]));
        memset(SS_T[si], 0, sizeof(SS_T[si]));
        for (int k = 0; k < K_CLIENTS; k++) {
            double kw = KW[k];
            const float *c = CLIENTS[k] + off;
            for (int i = 0; i < SEG; i++) {
                double cv = c[i];
                SS_S[si][i] += kw * cv;
                SS_T[si][i] += kw * cv * cv;
            }
        }
    }
}

/* 4 independent accumulators, matching SegmentStats::mse in Rust */
static double ss_score(int si, int gi, double wsum) {
    Fp8Params p = params_new(cand_alpha(gi));
    int off = si * SEG;
    double a0 = 0, a1 = 0, a2 = 0, a3 = 0;
    int i = 0;
    for (; i + 4 <= SEG; i += 4) {
        double q0 = fp8_quantize(&p, W_VEC[off + i], US[si][i]);
        double q1 = fp8_quantize(&p, W_VEC[off + i + 1], US[si][i + 1]);
        double q2 = fp8_quantize(&p, W_VEC[off + i + 2], US[si][i + 2]);
        double q3 = fp8_quantize(&p, W_VEC[off + i + 3], US[si][i + 3]);
        a0 += q0 * q0 * wsum - 2.0 * q0 * SS_S[si][i] + SS_T[si][i];
        a1 += q1 * q1 * wsum - 2.0 * q1 * SS_S[si][i + 1]
              + SS_T[si][i + 1];
        a2 += q2 * q2 * wsum - 2.0 * q2 * SS_S[si][i + 2]
              + SS_T[si][i + 2];
        a3 += q3 * q3 * wsum - 2.0 * q3 * SS_S[si][i + 3]
              + SS_T[si][i + 3];
    }
    double tail = 0.0;
    for (; i < SEG; i++) {
        double q = fp8_quantize(&p, W_VEC[off + i], US[si][i]);
        tail += q * q * wsum - 2.0 * q * SS_S[si][i] + SS_T[si][i];
    }
    return (a0 + a1) + (a2 + a3) + tail;
}

static void eq5_suffstats(void) {
    ss_build();
    double wsum = ss_wsum(), best = 1e300;
    for (int si = 0; si < TENSORS; si++)
        for (int gi = 0; gi < GRID; gi++) {
            double m = ss_score(si, gi, wsum);
            if (m < best) best = m;
        }
    SINK = best;
}

/* AVX2-kernel candidate scorer (the SegmentStats::mse_with shape) */
static double ss_score_avx2(int si, int gi, double wsum) {
    Fp8Params p = params_new(cand_alpha(gi));
    int off = si * SEG;
    double a0 = 0, a1 = 0, a2 = 0, a3 = 0;
    float qb[4];
    int i = 0;
    for (; i + 4 <= SEG; i += 4) {
        quantize4_avx2(&p, &W_VEC[off + i], &US[si][i], qb);
        a0 += (double)qb[0] * qb[0] * wsum - 2.0 * qb[0] * SS_S[si][i]
              + SS_T[si][i];
        a1 += (double)qb[1] * qb[1] * wsum
              - 2.0 * qb[1] * SS_S[si][i + 1] + SS_T[si][i + 1];
        a2 += (double)qb[2] * qb[2] * wsum
              - 2.0 * qb[2] * SS_S[si][i + 2] + SS_T[si][i + 2];
        a3 += (double)qb[3] * qb[3] * wsum
              - 2.0 * qb[3] * SS_S[si][i + 3] + SS_T[si][i + 3];
    }
    double tail = 0.0;
    for (; i < SEG; i++) {
        double q = fp8_quantize(&p, W_VEC[off + i], US[si][i]);
        tail += q * q * wsum - 2.0 * q * SS_S[si][i] + SS_T[si][i];
    }
    return (a0 + a1) + (a2 + a3) + tail;
}

static void eq5_suffstats_avx2(void) {
    ss_build();
    double wsum = ss_wsum(), best = 1e300;
    for (int si = 0; si < TENSORS; si++)
        for (int gi = 0; gi < GRID; gi++) {
            double m = ss_score_avx2(si, gi, wsum);
            if (m < best) best = m;
        }
    SINK = best;
}

typedef struct { int task_lo, task_hi; double wsum, best; } Eq5Job;

static void *eq5_worker(void *arg) {
    Eq5Job *j = (Eq5Job *)arg;
    j->best = 1e300;
    for (int t = j->task_lo; t < j->task_hi; t++) {
        double m = ss_score(t / GRID, t % GRID, j->wsum);
        if (m < j->best) j->best = m;
    }
    return NULL;
}

static void eq5_suffstats_pooled(void) {
    ss_build();
    double wsum = ss_wsum();
    int total = TENSORS * GRID;
    int per = (total + POOL - 1) / POOL;
    pthread_t th[8];
    Eq5Job jobs[8];
    int n = 0;
    for (int lo = 0; lo < total; lo += per, n++) {
        jobs[n].task_lo = lo;
        jobs[n].task_hi = lo + per < total ? lo + per : total;
        jobs[n].wsum = wsum;
        pthread_create(&th[n], NULL, eq5_worker, &jobs[n]);
    }
    double best = 1e300;
    for (int i = 0; i < n; i++) {
        pthread_join(th[i], NULL);
        if (jobs[i].best < best) best = jobs[i].best;
    }
    SINK = best;
}

/* ---- JSON emit (schema of util::bench::BenchJson) ----------------- */

static void emit_result(FILE *f, const BResult *r, int items, int first) {
    fprintf(f, "%s\n    {\"name\": \"%s\", \"iters\": %ld, "
               "\"median_ns\": %.1f, \"p10_ns\": %.1f, \"p90_ns\": %.1f",
            first ? "" : ",", r->name, r->iters, r->median_ns, r->p10_ns,
            r->p90_ns);
    if (items)
        fprintf(f, ", \"throughput_per_s\": %.1f",
                (double)items / (r->median_ns * 1e-9));
    fprintf(f, "}");
}

int main(void) {
    long cores = sysconf(_SC_NPROCESSORS_ONLN);
    if (cores > 4) cores = 4;
    if (cores > 1) POOL = (int)cores;
    /* data */
    Pcg32 r = pcg_new(1, 0);
    for (int i = 0; i < DIM; i++)
        W_VEC[i] = (float)((pcg_f64(&r) - 0.5) * 2.0);
    for (int k = 0; k < K_CLIENTS; k++) {
        Pcg32 cr = pcg_new(100 + k, 0);
        for (int i = 0; i < DIM; i++)
            CLIENTS[k][i] = (float)((pcg_f64(&cr) - 0.5) * 2.0);
        KW[k] = 1.0f / K_CLIENTS;
    }
    for (int si = 0; si < TENSORS; si++) {
        ALPHAS[si] = 0.7f + si * 0.15f;
        PARAMS[si] = params_new(ALPHAS[si]);
        decode_table(&PARAMS[si], TABLES[si]);
        for (int i = 0; i < SEG; i++) US[si][i] = pcg_f64(&r);
    }
    KEY_RNG = pcg_new(2, 0);
    enc_scalar(); /* populate CODES for the decode arms */

    int have_avx2 = __builtin_cpu_supports("avx2");
    printf("pool=%d dim=%d K=%d G=%d avx2=%d\n\n", POOL, DIM,
           K_CLIENTS, GRID, have_avx2);
    BResult e1 = bench_run("encode/scalar_ref (before)", enc_scalar, 400);
    BResult e2 = bench_run("encode/batched pool=1", enc_batched, 400);
    BResult e3 = bench_run("encode/batched pooled", enc_pooled, 400);
    BResult es = {0};
    if (have_avx2)
        es = bench_run("encode/kernel=avx2 pool=1", enc_avx2, 400);
    BResult d1 = bench_run("decode/rebuild_tables (before)", dec_rebuild,
                           400);
    BResult d2 = bench_run("decode/lut_cached", dec_cached, 400);
    BResult q1 = bench_run("eq5/naive O(G*K*d) K=8 G=32", eq5_naive,
                           1500);
    BResult q2 = bench_run("eq5/suffstats pool=1", eq5_suffstats, 1500);
    BResult q3 = bench_run("eq5/suffstats pooled", eq5_suffstats_pooled,
                           1500);
    BResult qs = {0};
    if (have_avx2)
        qs = bench_run("eq5/suffstats kernel=avx2 pool=1",
                       eq5_suffstats_avx2, 1500);

    double sp_eq5 = q1.median_ns / q3.median_ns;
    double sp_eq5_seq = q1.median_ns / q2.median_ns;
    double sp_enc = e1.median_ns / e3.median_ns;
    double sp_dec = d1.median_ns / d2.median_ns;
    double sp_wire = (e1.median_ns + d1.median_ns)
                     / (e3.median_ns + d2.median_ns);
    /* p10 ratios approximate an uncontended machine: on this shared
     * 2-vCPU box the medians of the threaded arms are dominated by
     * noisy neighbors. */
    double sp_eq5_p10 = q1.p10_ns / q3.p10_ns;
    double sp_enc_p10 = e1.p10_ns / e3.p10_ns;
    double sp_wire_p10 =
        (e1.p10_ns + d1.p10_ns) / (e3.p10_ns + d2.p10_ns);
    double sp_enc_simd = have_avx2 ? e2.median_ns / es.median_ns : 0.0;
    double sp_eq5_simd = have_avx2 ? q2.median_ns / qs.median_ns : 0.0;
    double sp_enc_simd_p10 = have_avx2 ? e2.p10_ns / es.p10_ns : 0.0;
    double sp_eq5_simd_p10 = have_avx2 ? q2.p10_ns / qs.p10_ns : 0.0;
    printf("\nspeedups: eq5 %.2fx (seq %.2fx)  encode %.2fx  "
           "decode %.2fx  wire %.2fx\n",
           sp_eq5, sp_eq5_seq, sp_enc, sp_dec, sp_wire);
    if (have_avx2)
        printf("kernel speedups (scalar -> avx2, pool=1): encode "
               "%.2fx (p10 %.2fx)  eq5 %.2fx (p10 %.2fx)\n",
               sp_enc_simd, sp_enc_simd_p10, sp_eq5_simd,
               sp_eq5_simd_p10);

    FILE *f = fopen("BENCH_fp8_kernels.json", "w");
    if (!f) { perror("BENCH_fp8_kernels.json"); return 1; }
    fprintf(f, "{\n  \"bench\": \"fp8_kernels\",\n");
    fprintf(f,
            "  \"provenance\": \"tools/bench_fp8_mirror.c (gcc -O3 C "
            "mirror of the Rust kernels, op-for-op: same f64 scalar "
            "math, PCG32 streams, block sizes and thread fan-out; "
            "build container lacks a Rust toolchain). Measured on a "
            "throttled 2-vCPU shared container: the pooled arms are "
            "lower bounds (thread spawn ~100-300us here; on >=4 "
            "physical cores the candidate fan-out is near-linear, "
            "projecting the eq5 search to ~2x seq * ~3.5x pool). "
            "The C scalar_ref baseline also "
            "lacks the Rust pre-PR path's per-element Vec::push and "
            "slice bounds checks, further understating the gain. "
            "The kernel=avx2 arms mirror `--fp8-kernel simd` "
            "(rust/src/fp8/simd.rs, bit-identical to scalar by the "
            "exhaustive conformance contract); the p10 kernel ratios "
            "are the steady-state numbers on this noisy box. "
            "Regenerate natively with `cargo bench --bench "
            "fp8_kernels --features simd`.\",\n");
    fprintf(f,
            "  \"config\": {\n    \"dim\": \"%d\",\n    \"tensors\": "
            "\"%d\",\n    \"k_clients\": \"%d\",\n    \"grid_points\": "
            "\"%d\",\n    \"pool\": \"%d\"\n  },\n",
            DIM, TENSORS, K_CLIENTS, GRID, POOL);
    fprintf(f, "  \"results\": [");
    emit_result(f, &e1, DIM, 1);
    emit_result(f, &e2, DIM, 0);
    emit_result(f, &e3, DIM, 0);
    if (have_avx2) emit_result(f, &es, DIM, 0);
    emit_result(f, &d1, DIM, 0);
    emit_result(f, &d2, DIM, 0);
    emit_result(f, &q1, 0, 0);
    emit_result(f, &q2, 0, 0);
    emit_result(f, &q3, 0, 0);
    if (have_avx2) emit_result(f, &qs, 0, 0);
    fprintf(f, "\n  ],\n  \"speedups\": {\n");
    if (have_avx2) {
        fprintf(f, "    \"encode_scalar_kernel_over_simd_kernel\": "
                   "%.3f,\n", sp_enc_simd);
        fprintf(f, "    \"encode_scalar_kernel_over_simd_kernel_p10\": "
                   "%.3f,\n", sp_enc_simd_p10);
        fprintf(f, "    \"eq5_scalar_kernel_over_simd_kernel\": "
                   "%.3f,\n", sp_eq5_simd);
        fprintf(f, "    \"eq5_scalar_kernel_over_simd_kernel_p10\": "
                   "%.3f,\n", sp_eq5_simd_p10);
    }
    fprintf(f, "    \"eq5_alpha_search_naive_over_suffstats_pooled\": "
               "%.3f,\n", sp_eq5);
    fprintf(f, "    \"eq5_alpha_search_naive_over_suffstats_seq\": "
               "%.3f,\n", sp_eq5_seq);
    fprintf(f, "    \"encode_scalar_over_batched_pooled\": %.3f,\n",
            sp_enc);
    fprintf(f, "    \"decode_rebuild_over_lut_cached\": %.3f,\n",
            sp_dec);
    fprintf(f, "    \"encode_decode_combined\": %.3f,\n", sp_wire);
    fprintf(f, "    \"eq5_alpha_search_pooled_p10\": %.3f,\n",
            sp_eq5_p10);
    fprintf(f, "    \"encode_scalar_over_batched_pooled_p10\": %.3f,\n",
            sp_enc_p10);
    fprintf(f, "    \"encode_decode_combined_p10\": %.3f\n",
            sp_wire_p10);
    fprintf(f, "  }\n}\n");
    fclose(f);
    printf("wrote BENCH_fp8_kernels.json\n");
    return 0;
}
