/* C mirror of rust/benches/snapshot.rs — seeds BENCH_snapshot.json
 * when no Rust toolchain is available.
 *
 * Replicates the coordinator's durability path op-for-op on the same
 * state shape (dim=16384 params, 256 touched EF clients, ~17 MiB
 * framed snapshot):
 *   - encode: serialize the FP8S v1 layout (16-byte header with IEEE
 *     crc32 of the body; fingerprint/round/dims, raw-LE f32 model +
 *     residual vectors, sorted per-client EF entries, 6 comm totals)
 *     into one contiguous buffer, exactly the field order of
 *     rust/src/coordinator/snapshot.rs.
 *   - decode: header checks (magic/version/body_len) + full-body
 *     crc32 + bounds-checked field walk back into structs.
 *   - write_atomic: temp file in the target dir, fwrite + fsync,
 *     rename over the generation name, fsync the directory, prune to
 *     2 generations — the identical syscall sequence.
 *   - load_resume: directory scan for snap-*.fp8s, read newest, full
 *     decode + fingerprint gate.
 *
 * Build & run (repo root):
 *   gcc -O3 -o /tmp/snap_mirror tools/bench_snapshot_mirror.c
 *   /tmp/snap_mirror           # writes BENCH_snapshot.json
 *
 * `cargo bench --bench snapshot` overwrites the JSON with native
 * Rust numbers whenever a Rust toolchain is present.
 */

#include <dirent.h>
#include <fcntl.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>
#include <unistd.h>

/* ---- PCG32 (twin of rust/src/fp8/rng.rs) -------------------------- */

typedef struct { uint64_t state, inc; } Pcg32;

static uint64_t splitmix(uint64_t *s) {
    *s += 0x9E3779B97F4A7C15ULL;
    uint64_t z = *s;
    z = (z ^ (z >> 30)) * 0xBF58476D1CE4E5B9ULL;
    z = (z ^ (z >> 27)) * 0x94D049BB133111EBULL;
    return z ^ (z >> 31);
}

static inline uint32_t pcg_u32(Pcg32 *r) {
    uint64_t old = r->state;
    r->state = old * 6364136223846793005ULL + r->inc;
    uint32_t xs = (uint32_t)(((old >> 18) ^ old) >> 27);
    uint32_t rot = (uint32_t)(old >> 59);
    return (xs >> rot) | (xs << ((32 - rot) & 31));
}

static Pcg32 pcg_new(uint64_t seed, uint64_t stream) {
    uint64_t s = seed ^ ((stream << 17) | (stream >> 47));
    Pcg32 r;
    r.state = splitmix(&s);
    r.inc = splitmix(&s) | 1;
    pcg_u32(&r);
    return r;
}

static inline uint64_t pcg_u64(Pcg32 *r) {
    return ((uint64_t)pcg_u32(r) << 32) | pcg_u32(r);
}

static inline double pcg_f64(Pcg32 *r) {
    return (double)(pcg_u64(r) >> 11) * (1.0 / 9007199254740992.0);
}

/* ---- IEEE crc32 (twin of rust/src/net/frame.rs) ------------------- */

static uint32_t CRC_TAB[256];

static void crc_init(void) {
    for (uint32_t i = 0; i < 256; i++) {
        uint32_t c = i;
        for (int k = 0; k < 8; k++)
            c = (c & 1) ? 0xEDB88320u ^ (c >> 1) : c >> 1;
        CRC_TAB[i] = c;
    }
}

static uint32_t crc32_of(const uint8_t *buf, size_t len) {
    uint32_t c = 0xFFFFFFFFu;
    for (size_t i = 0; i < len; i++)
        c = CRC_TAB[(c ^ buf[i]) & 0xFF] ^ (c >> 8);
    return c ^ 0xFFFFFFFFu;
}

/* ---- bench harness (twin of rust/src/util/bench.rs) --------------- */

typedef struct {
    const char *name;
    long iters;
    double median_ns, p10_ns, p90_ns;
} BResult;

static double now_ns(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return ts.tv_sec * 1e9 + ts.tv_nsec;
}

static int cmp_d(const void *a, const void *b) {
    double x = *(const double *)a, y = *(const double *)b;
    return (x > y) - (x < y);
}

#define MAX_SAMPLES 100000
static double SAMPLES[MAX_SAMPLES];

static BResult bench_run(const char *name, void (*f)(void),
                         double budget_ms) {
    double warm_end = now_ns() + budget_ms * 1e6 / 5.0;
    while (now_ns() < warm_end) f();
    long n = 0;
    double end = now_ns() + budget_ms * 1e6;
    while ((now_ns() < end || n < 5) && n < MAX_SAMPLES) {
        double t0 = now_ns();
        f();
        SAMPLES[n++] = now_ns() - t0;
    }
    qsort(SAMPLES, n, sizeof(double), cmp_d);
    BResult r;
    r.name = name;
    r.iters = n;
    r.median_ns = SAMPLES[(long)((n - 1) * 0.5)];
    r.p10_ns = SAMPLES[(long)((n - 1) * 0.1)];
    r.p90_ns = SAMPLES[(long)((n - 1) * 0.9)];
    printf("%-44s %12.0f %12.0f %12.0f  (ns, median/p10/p90)\n",
           r.name, r.median_ns, r.p10_ns, r.p90_ns);
    return r;
}

/* ---- the state + FP8S v1 codec ------------------------------------ */

#define DIM 16384
#define SMALL 8
#define N_EF 256
#define HEADER 16
#define KEEP 2

static float W[DIM], ALPHA[SMALL], BETA[SMALL], EF_SERVER[DIM];
static uint64_t EF_ID[N_EF];
static float EF_RES[N_EF][DIM];
static const uint64_t FP = 0x5EEDF00D00000001ULL;
static const uint64_t NEXT_ROUND = 321;
static const uint64_t COMM[6] = {1ULL << 30, 1ULL << 31, 1ULL << 20,
                                 1ULL << 20, 1ULL << 24, 1ULL << 10};

static uint8_t *BUF; /* encode target / decode source */
static size_t BODY_LEN, TOTAL_LEN;
static char SNAP_DIR[256];

static void fill_state(void) {
    Pcg32 r = pcg_new(17, 3);
    for (int i = 0; i < DIM; i++)
        W[i] = (float)((pcg_f64(&r) - 0.5) * 2.0);
    for (int i = 0; i < SMALL; i++)
        ALPHA[i] = (float)((pcg_f64(&r) - 0.5) * 2.0);
    for (int i = 0; i < SMALL; i++)
        BETA[i] = (float)((pcg_f64(&r) - 0.5) * 2.0);
    for (int i = 0; i < DIM; i++)
        EF_SERVER[i] = (float)((pcg_f64(&r) - 0.5) * 2.0);
    for (int c = 0; c < N_EF; c++) {
        EF_ID[c] = (uint64_t)c * 4099;
        for (int i = 0; i < DIM; i++)
            EF_RES[c][i] = (float)((pcg_f64(&r) - 0.5) * 2.0);
    }
}

static inline uint8_t *put_u32(uint8_t *p, uint32_t v) {
    memcpy(p, &v, 4);
    return p + 4;
}

static inline uint8_t *put_u64p(uint8_t *p, uint64_t v) {
    memcpy(p, &v, 8);
    return p + 8;
}

static inline uint8_t *put_f32s(uint8_t *p, const float *v, size_t n) {
    memcpy(p, v, n * 4);
    return p + n * 4;
}

static void encode_snapshot(void) {
    uint8_t *p = BUF + HEADER;
    p = put_u64p(p, FP);
    p = put_u64p(p, NEXT_ROUND);
    p = put_u32(p, DIM);
    p = put_u32(p, SMALL);
    p = put_u32(p, SMALL);
    p = put_f32s(p, W, DIM);
    p = put_f32s(p, ALPHA, SMALL);
    p = put_f32s(p, BETA, SMALL);
    p = put_u32(p, DIM);
    p = put_f32s(p, EF_SERVER, DIM);
    p = put_u32(p, N_EF);
    for (int c = 0; c < N_EF; c++) { /* EF_ID ascending = BTreeMap */
        p = put_u64p(p, EF_ID[c]);
        p = put_u32(p, DIM);
        p = put_f32s(p, EF_RES[c], DIM);
    }
    for (int i = 0; i < 6; i++) p = put_u64p(p, COMM[i]);
    BODY_LEN = (size_t)(p - BUF) - HEADER;
    TOTAL_LEN = BODY_LEN + HEADER;
    memcpy(BUF, "FP8S", 4);
    uint16_t ver = 1, resv = 0;
    memcpy(BUF + 4, &ver, 2);
    memcpy(BUF + 6, &resv, 2);
    uint32_t bl = (uint32_t)BODY_LEN;
    memcpy(BUF + 8, &bl, 4);
    uint32_t crc = crc32_of(BUF + HEADER, BODY_LEN);
    memcpy(BUF + 12, &crc, 4);
}

static double SINK;

static int decode_snapshot(const uint8_t *buf, size_t len) {
    if (len < HEADER || memcmp(buf, "FP8S", 4) != 0) return -1;
    uint16_t ver;
    memcpy(&ver, buf + 4, 2);
    if (ver != 1) return -2;
    uint32_t bl, want;
    memcpy(&bl, buf + 8, 4);
    memcpy(&want, buf + 12, 4);
    if (len - HEADER != bl) return -3;
    if (crc32_of(buf + HEADER, bl) != want) return -4;
    const uint8_t *p = buf + HEADER, *endp = buf + len;
    uint64_t fp, round;
    memcpy(&fp, p, 8); p += 8;
    memcpy(&round, p, 8); p += 8;
    uint32_t dim, ad, bd;
    memcpy(&dim, p, 4); p += 4;
    memcpy(&ad, p, 4); p += 4;
    memcpy(&bd, p, 4); p += 4;
    double acc = 0;
    for (int blk = 0; blk < 3; blk++) {
        uint32_t n = blk == 0 ? dim : blk == 1 ? ad : bd;
        if ((size_t)(endp - p) < (size_t)n * 4) return -5;
        float v;
        memcpy(&v, p, 4); /* touch, then bulk-skip like Vec::from */
        acc += v;
        p += (size_t)n * 4;
    }
    uint32_t efl;
    memcpy(&efl, p, 4); p += 4;
    if ((size_t)(endp - p) < (size_t)efl * 4) return -5;
    p += (size_t)efl * 4;
    uint32_t nef;
    memcpy(&nef, p, 4); p += 4;
    for (uint32_t c = 0; c < nef; c++) {
        if ((size_t)(endp - p) < 12) return -5;
        uint64_t id;
        uint32_t n;
        memcpy(&id, p, 8); p += 8;
        memcpy(&n, p, 4); p += 4;
        if ((size_t)(endp - p) < (size_t)n * 4) return -5;
        acc += (double)id;
        p += (size_t)n * 4;
    }
    if ((size_t)(endp - p) != 48) return -6;
    uint64_t comm;
    memcpy(&comm, p, 8);
    SINK += acc + (double)comm + (double)fp + (double)round;
    return 0;
}

/* decode from a private copy so encode/decode arms don't alias */
static uint8_t *DEC_SRC;

static void arm_encode(void) { encode_snapshot(); }

static void arm_decode(void) {
    if (decode_snapshot(DEC_SRC, TOTAL_LEN) != 0) {
        fprintf(stderr, "decode failed\n");
        exit(1);
    }
}

static void write_atomic(void) {
    /* rust's snapshot::write_atomic takes the state, not bytes: the
     * measured cost includes the encode */
    encode_snapshot();
    char tmp[320], fin[320];
    snprintf(fin, sizeof fin, "%s/snap-%08llu.fp8s", SNAP_DIR,
             (unsigned long long)NEXT_ROUND);
    snprintf(tmp, sizeof tmp, "%s/.tmp-snap-%08llu.fp8s", SNAP_DIR,
             (unsigned long long)NEXT_ROUND);
    int fd = open(tmp, O_WRONLY | O_CREAT | O_TRUNC, 0644);
    if (fd < 0) { perror("open tmp"); exit(1); }
    size_t off = 0;
    while (off < TOTAL_LEN) {
        ssize_t k = write(fd, BUF + off, TOTAL_LEN - off);
        if (k <= 0) { perror("write"); exit(1); }
        off += (size_t)k;
    }
    if (fsync(fd) != 0) { perror("fsync"); exit(1); }
    close(fd);
    if (rename(tmp, fin) != 0) { perror("rename"); exit(1); }
    int dfd = open(SNAP_DIR, O_RDONLY);
    if (dfd >= 0) { fsync(dfd); close(dfd); }
    /* prune to KEEP generations (scan; nothing to remove here, but
     * the directory walk is part of the measured cost) */
    DIR *d = opendir(SNAP_DIR);
    if (d) {
        struct dirent *e;
        int n = 0;
        while ((e = readdir(d)) != NULL)
            if (strncmp(e->d_name, "snap-", 5) == 0) n++;
        closedir(d);
        SINK += n;
    }
}

static void load_resume(void) {
    /* newest generation: directory scan, then read + decode + gate */
    DIR *d = opendir(SNAP_DIR);
    if (!d) { perror("opendir"); exit(1); }
    struct dirent *e;
    char best[280] = "";
    while ((e = readdir(d)) != NULL)
        if (strncmp(e->d_name, "snap-", 5) == 0 &&
            strcmp(e->d_name, best) > 0)
            snprintf(best, sizeof best, "%s", e->d_name);
    closedir(d);
    char path[600];
    snprintf(path, sizeof path, "%s/%s", SNAP_DIR, best);
    FILE *f = fopen(path, "rb");
    if (!f) { perror("fopen"); exit(1); }
    static uint8_t *rd = NULL;
    if (!rd) rd = malloc(TOTAL_LEN);
    size_t n = fread(rd, 1, TOTAL_LEN, f);
    fclose(f);
    if (n != TOTAL_LEN || decode_snapshot(rd, n) != 0) {
        fprintf(stderr, "load failed\n");
        exit(1);
    }
    uint64_t fp;
    memcpy(&fp, rd + HEADER, 8);
    if (fp != FP) { fprintf(stderr, "fingerprint\n"); exit(1); }
}

static void emit_result(FILE *f, const BResult *r, double mib,
                        int first) {
    fprintf(f,
            "%s\n    {\"name\": \"%s\", \"iters\": %ld, "
            "\"median_ns\": %.1f, \"p10_ns\": %.1f, \"p90_ns\": %.1f, "
            "\"throughput_per_s\": %.1f}",
            first ? "" : ",", r->name, r->iters, r->median_ns,
            r->p10_ns, r->p90_ns, mib / (r->median_ns * 1e-9));
}

int main(void) {
    crc_init();
    fill_state();
    size_t cap = HEADER + 8 + 8 + 12 +
                 4ULL * (DIM + SMALL + SMALL) + 4 + 4ULL * DIM + 4 +
                 (size_t)N_EF * (12 + 4ULL * DIM) + 48;
    BUF = malloc(cap);
    DEC_SRC = malloc(cap);
    encode_snapshot();
    memcpy(DEC_SRC, BUF, TOTAL_LEN);
    double mib = (double)TOTAL_LEN / (1 << 20);
    printf("state: dim=%d ef_clients=%d -> %.1f MiB snapshot\n\n",
           DIM, N_EF, mib);

    snprintf(SNAP_DIR, sizeof SNAP_DIR,
             "/tmp/fedfp8_bench_snap_c_%d", (int)getpid());
    char cmd[640];
    snprintf(cmd, sizeof cmd, "rm -rf %s && mkdir -p %s", SNAP_DIR,
             SNAP_DIR);
    if (system(cmd) != 0) { fprintf(stderr, "mkdir\n"); return 1; }

    BResult enc = bench_run("snapshot/encode", arm_encode, 400);
    BResult dec = bench_run("snapshot/decode", arm_decode, 400);
    BResult wrt = bench_run("snapshot/write_atomic", write_atomic, 400);
    BResult load = bench_run("snapshot/load_resume", load_resume, 400);

    double durability_cost = wrt.median_ns / enc.median_ns;
    printf("\nthroughput at median: encode %.0f MiB/s  decode %.0f "
           "MiB/s  write_atomic %.0f MiB/s  load %.0f MiB/s\n",
           mib / (enc.median_ns * 1e-9), mib / (dec.median_ns * 1e-9),
           mib / (wrt.median_ns * 1e-9), mib / (load.median_ns * 1e-9));
    printf("durability overhead (write_atomic / encode): %.1fx\n",
           durability_cost);

    FILE *f = fopen("BENCH_snapshot.json", "w");
    if (!f) { perror("BENCH_snapshot.json"); return 1; }
    fprintf(f, "{\n  \"bench\": \"snapshot\",\n");
    fprintf(f,
            "  \"provenance\": \"tools/bench_snapshot_mirror.c (gcc "
            "-O3 C mirror of rust/benches/snapshot.rs, op-for-op: same "
            "FP8S v1 field walk, IEEE crc32 over the full body, and "
            "the identical temp-file + fsync + rename + dir-fsync + "
            "prune syscall sequence on the same-size state; build "
            "container lacks a Rust toolchain). Decode here bulk-skips "
            "vector bytes instead of materializing Vec<f32>s, so the "
            "decode/load arms understate allocation cost slightly "
            "while the write_atomic/encode durability ratio transfers. "
            "Regenerate natively with `cargo bench --bench "
            "snapshot`.\",\n");
    fprintf(f,
            "  \"config\": {\"dim\": \"%d\", \"ef_clients\": \"%d\", "
            "\"snapshot_mib\": \"%.2f\"},\n",
            DIM, N_EF, mib);
    fprintf(f, "  \"results\": [");
    emit_result(f, &enc, mib, 1);
    emit_result(f, &dec, mib, 0);
    emit_result(f, &wrt, mib, 0);
    emit_result(f, &load, mib, 0);
    fprintf(f, "\n  ],\n  \"speedups\": {\n");
    fprintf(f, "    \"encode_over_write_atomic\": %.3f,\n",
            durability_cost);
    fprintf(f, "    \"decode_over_load\": %.3f\n",
            load.median_ns / dec.median_ns);
    fprintf(f, "  }\n}\n");
    fclose(f);

    snprintf(cmd, sizeof cmd, "rm -rf %s", SNAP_DIR);
    if (system(cmd) != 0) return 1;
    printf("\nwrote BENCH_snapshot.json (SINK %.1f)\n", SINK);
    return 0;
}
