#!/usr/bin/env python3
"""Reference mirror of the fedfp8 wire format v2 + golden-fixture
generator (plus the frozen v1 mirror for the version-skew fixture).

The Rust implementation lives in ``rust/src/net/{frame,codec}.rs``;
this script is the *independent second implementation* of the same
byte-level spec, used to

  1. generate ``rust/tests/fixtures/wire_v2.bin`` (the golden frames
     that ``rust/tests/golden_wire.rs`` pins) and regenerate the
     byte-identical ``wire_v1.bin`` (kept committed so the typed
     version-mismatch behaviour stays pinned), and
  2. let ``python/tests/test_wire_fixture.py`` cross-check the
     committed fixtures against this mirror on every pytest run.

The build container for this repo has no Rust toolchain (see
``tools/bench_fp8_mirror.c`` for the same pattern on the kernel side),
so the golden bytes are produced here and *verified* by the Rust test
suite in CI. If the two implementations ever disagree, the Rust
golden test fails and prints the first divergent offset.

Wire format v2 — all integers little-endian
-------------------------------------------

Frame envelope (16 bytes), followed by ``body``::

    0   magic     4  = b"FP8W"
    4   version   u16 = 2
    6   kind      u8  (1=Hello 2=HelloAck 3=Job 4=Outcome 5=Shutdown
                       6=Heartbeat 7=HeartbeatAck 8=Partial 9=Shard
                       10=ShardDone)
    7   flags     u8  = 0 (reserved)
    8   body_len  u32
    12  crc32     u32 (IEEE CRC-32 of body)

Payload block (a packed ``WirePayload``)::

    codes_len u32, raw_len u32, alphas_len u32, betas_len u32,
    codes  [u8  x codes_len],
    raw    [f32 x raw_len],
    alphas [f32 x alphas_len],
    betas  [f32 x betas_len]

Job body (kind=3)::

    round u32, client u32, job_id u32, seed u64,
    qat u8 (0=det 1=rand 2=none),
    comm u8 (0=deterministic 1=stochastic 2=none),
    flip_aug u8, has_ef u8,
    lr f32, weight_decay f32, n_k u64,
    down: payload block,
    [ef_len u32, ef f32 x ef_len]   # iff has_ef

``job_id`` is the round-scoped multiplexing tag (cohort position):
one connection carries N in-flight jobs, outcomes return out of
order, and the worker's reconnect cache is keyed on it.

Outcome body (kind=4)::

    round u32, client u32, job_id u32, n_k u64, mean_loss f32,
    has_ef u8, payload block,
    [ef_len u32, ef f32 x ef_len]   # iff has_ef

Partial body (kind=8, the tree-aggregation backbone)::

    round u32, start u64, end u64, width u32, n_fragments u32,
    then per fragment:
      frag_start u64, frag_len u64, sums [f64 x width]

The f64 sums travel as raw little-endian bit patterns — a decoded
partial is bit-identical to the sender's accumulator state, the
property the tree-vs-flat contract rests on.

Hello body (kind=1)::

    fingerprint u64, dim u64, model_len u16, model utf-8 bytes,
    auth u64   # FNV-1a-64 digest of --net-token (0 = no token);
               # trailing field is optional on decode, so pre-auth
               # builds still parse (and then fail the digest check)

HelloAck body (kind=2): ``fingerprint u64, auth u64`` (auth echoed
for mutual verification; likewise optional on decode).  Shutdown
(kind=5): empty.  Heartbeat / HeartbeatAck bodies (kinds 6/7):
``nonce u64`` (the ack echoes the probe's nonce).

Snapshot file format v2 (``rust/src/coordinator/snapshot.rs``) is
mirrored at the bottom of this file and pinned by
``rust/tests/golden_snapshot.rs`` against
``rust/tests/fixtures/snapshot_v2.bin`` (plus the must-fail
``snapshot_v1.bin`` / ``snapshot_v0.bin`` version-skew fixtures,
both frozen byte-for-byte).

Accounting identities (mirrored by ``coordinator/comm.rs``)::

    job frame bytes     = payload.wire_bytes + 72   (no EF)
    outcome frame bytes = payload.wire_bytes + 57   (no EF)

where ``wire_bytes = codes + 4*(raw + alphas + betas)`` and
72 = 16 (envelope) + 40 (job meta) + 16 (payload section table),
57 = 16 (envelope) + 25 (outcome meta) + 16 (section table).
Heartbeat traffic is deliberately excluded from the CommStats
identity (liveness overhead, not communication cost).
"""

import json
import math
import os
import struct
import zlib

MAGIC = b"FP8W"
VERSION = 2
(
    KIND_HELLO,
    KIND_HELLO_ACK,
    KIND_JOB,
    KIND_OUTCOME,
    KIND_SHUTDOWN,
    KIND_HEARTBEAT,
    KIND_HEARTBEAT_ACK,
    KIND_PARTIAL,
    KIND_SHARD,
    KIND_SHARD_DONE,
) = 1, 2, 3, 4, 5, 6, 7, 8, 9, 10

FRAME_HEADER_BYTES = 16
PAYLOAD_TABLE_BYTES = 16
JOB_META_BYTES = 40
OUTCOME_META_BYTES = 25
PARTIAL_META_BYTES = 28
PARTIAL_RANGE_HEADER_BYTES = 16
JOB_FRAME_OVERHEAD = FRAME_HEADER_BYTES + JOB_META_BYTES + PAYLOAD_TABLE_BYTES
OUTCOME_FRAME_OVERHEAD = (
    FRAME_HEADER_BYTES + OUTCOME_META_BYTES + PAYLOAD_TABLE_BYTES
)
PARTIAL_FRAME_OVERHEAD = FRAME_HEADER_BYTES + PARTIAL_META_BYTES


def f32s(vals):
    return b"".join(struct.pack("<f", v) for v in vals)


def payload_block(codes, raw, alphas, betas):
    return (
        struct.pack("<IIII", len(codes), len(raw), len(alphas), len(betas))
        + bytes(codes)
        + f32s(raw)
        + f32s(alphas)
        + f32s(betas)
    )


def wire_bytes(codes, raw, alphas, betas):
    return len(codes) + 4 * (len(raw) + len(alphas) + len(betas))


def frame(kind, body, version=VERSION):
    hdr = MAGIC + struct.pack(
        "<HBBII", version, kind, 0, len(body),
        zlib.crc32(body) & 0xFFFFFFFF,
    )
    assert len(hdr) == FRAME_HEADER_BYTES
    return hdr + body


def job_body(round_, client, job_id, seed, qat, comm, flip_aug, lr, wd,
             n_k, down, ef=None):
    body = struct.pack(
        "<IIIQBBBBffQ",
        round_, client, job_id, seed, qat, comm,
        1 if flip_aug else 0, 0 if ef is None else 1, lr, wd, n_k,
    )
    assert len(body) == JOB_META_BYTES
    body += payload_block(*down)
    if ef is not None:
        body += struct.pack("<I", len(ef)) + f32s(ef)
    return body


def outcome_body(round_, client, job_id, n_k, mean_loss, payload,
                 ef=None):
    body = struct.pack(
        "<IIIQfB", round_, client, job_id, n_k, mean_loss,
        0 if ef is None else 1,
    )
    assert len(body) == OUTCOME_META_BYTES
    body += payload_block(*payload)
    if ef is not None:
        body += struct.pack("<I", len(ef)) + f32s(ef)
    return body


def heartbeat_body(nonce):
    return struct.pack("<Q", nonce)


def f64s(vals):
    return b"".join(struct.pack("<d", v) for v in vals)


def partial_body(round_, start, end, width, fragments):
    """``fragments`` is a list of (frag_start, frag_len, sums) with
    ``len(sums) == width``; sums are f64 bit patterns on the wire."""
    body = struct.pack(
        "<IQQII", round_, start, end, width, len(fragments)
    )
    assert len(body) == PARTIAL_META_BYTES
    for s, l, sums in fragments:
        assert len(sums) == width
        body += struct.pack("<QQ", s, l) + f64s(sums)
    return body


def partial_wire_bytes(width, n_fragments):
    return n_fragments * (PARTIAL_RANGE_HEADER_BYTES + 8 * width)


# ---- frozen v1 mirror (version-skew fixture) -------------------------
#
# wire_v1.bin stays committed byte-for-byte: a v2 build must fail to
# decode it with the *typed* VersionMismatch error (pinned by
# rust/tests/golden_wire.rs). These v1 builders exist only so the
# committed fixture can be regenerated / drift-checked; they must
# never change again.

V1_VERSION = 1
V1_JOB_META_BYTES = 36
V1_OUTCOME_META_BYTES = 21
V1_JOB_FRAME_OVERHEAD = (
    FRAME_HEADER_BYTES + V1_JOB_META_BYTES + PAYLOAD_TABLE_BYTES
)
V1_OUTCOME_FRAME_OVERHEAD = (
    FRAME_HEADER_BYTES + V1_OUTCOME_META_BYTES + PAYLOAD_TABLE_BYTES
)


def job_body_v1(round_, client, seed, qat, comm, flip_aug, lr, wd, n_k,
                down, ef=None):
    body = struct.pack(
        "<IIQBBBBffQ",
        round_, client, seed, qat, comm,
        1 if flip_aug else 0, 0 if ef is None else 1, lr, wd, n_k,
    )
    assert len(body) == V1_JOB_META_BYTES
    body += payload_block(*down)
    if ef is not None:
        body += struct.pack("<I", len(ef)) + f32s(ef)
    return body


def outcome_body_v1(round_, client, n_k, mean_loss, payload, ef=None):
    body = struct.pack(
        "<IIQfB", round_, client, n_k, mean_loss,
        0 if ef is None else 1,
    )
    assert len(body) == V1_OUTCOME_META_BYTES
    body += payload_block(*payload)
    if ef is not None:
        body += struct.pack("<I", len(ef)) + f32s(ef)
    return body


# ---- FP8 value-mapping mirror (twin of rust/src/fp8/format.rs) -------
#
# Independent second implementation of the flexible-bias FP8 encode,
# used to generate ``rust/tests/fixtures/fp8_edges_v1.json`` — golden
# *codes* (not just frames) for subnormal / saturation / NaN / ±0 /
# ±inf / grid-boundary inputs, so ``rust/tests/golden_fp8.rs`` can pin
# every kernel's byte output against a second implementation and
# ``python/tests/test_wire_fixture.py`` can detect fixture drift.
#
# All math is f64, like the Rust oracle. exp2/log2 go through libm via
# ctypes when available (the exact functions Rust's f64::exp2 lowers
# to on linux-gnu); ``2.0 ** x`` is a bit-identical fallback for the
# constants involved (verified against libm on the build host).

M_BITS = 3
E_MAX = 15
LOG2_TOP = 0.9068905956085185

try:
    import ctypes

    _libm = ctypes.CDLL("libm.so.6")
    _libm.exp2.restype = ctypes.c_double
    _libm.exp2.argtypes = [ctypes.c_double]

    def _exp2(x):
        return _libm.exp2(x)
except OSError:  # non-glibc host: pow is bit-identical for our inputs
    def _exp2(x):
        return 2.0 ** x


class Fp8Mirror:
    def __init__(self, alpha):
        self.alpha = alpha
        self.bias = 16.0 - math.log2(alpha) + LOG2_TOP - 1.0
        self.exp2_bias = _exp2(self.bias)
        self.sub_scale = _exp2(1.0 - self.bias - M_BITS)
        self.scales = [_exp2(c - self.bias - M_BITS) for c in range(16)]

    def code_exponent(self, absx):
        u = absx * self.exp2_bias
        bits = struct.unpack("<Q", struct.pack("<d", u))[0]
        return ((bits >> 52) & 0x7FF) - 1023

    def encode(self, x, u):
        """Twin of Fp8Params::encode — branch for branch."""
        if x == 0.0 or math.isnan(x):
            return 0
        if math.isinf(x):
            return (0x80 if x < 0.0 else 0) | 0x7F
        neg = x < 0.0
        absx = abs(x)
        c = self.code_exponent(absx)
        if c > 1:
            if c > E_MAX:
                return (0x80 if neg else 0) | 0x7F
            s = self.scales[c]
            z = absx / s
            f = math.floor(z)
            up = (1.0 - (z - f) < u) if neg else (z - f >= u)
            n = f + (1 if up else 0)
            if n >= 1 << (M_BITS + 1):
                c += 1
                n = 1 << M_BITS
            if n < 1 << M_BITS:
                c -= 1
                n = (1 << (M_BITS + 1)) - 1
            if c > E_MAX:
                return (0x80 if neg else 0) | 0x7F
            return (0x80 if neg else 0) | (c << M_BITS) | (n & 7)
        z = absx / self.sub_scale
        f = math.floor(z)
        up = (1.0 - (z - f) < u) if neg else (z - f >= u)
        n = min(f + (1 if up else 0), 1 << (M_BITS + 1))
        return (0x80 if neg else 0) | ((n >> M_BITS) << M_BITS) | (n & 7)

    def decode(self, code):
        neg = code & 0x80
        e = (code >> M_BITS) & 0x0F
        m = float(code & 7)
        if e == 0:
            v = self.sub_scale * m
        else:
            v = _exp2(float(e) - self.bias) * (1.0 + m / 8.0)
        v = f32(v)
        return -v if neg else v


def f32(x):
    """Round a python float (f64) to f32 precision."""
    return struct.unpack("<f", struct.pack("<f", x))[0]


def f32_from_bits(b):
    return struct.unpack("<f", struct.pack("<I", b))[0]


def f32_bits(x):
    return struct.unpack("<I", struct.pack("<f", x))[0]


# Alphas for the edge-code family; u draws are exactly-representable
# short decimals so the JSON round-trips bit-exactly in every parser.
EDGE_ALPHAS = [1.0, 0.0625, 3.7, 117.0]
EDGE_US = [0.5, 0.0078125, 0.99609375]


def edge_inputs(mirror):
    """Edge-case f32 bit patterns for one alpha: zeros, NaN payloads,
    infinities, f32 subnormals, saturation band, and ±2-ulp
    neighborhoods of every FP8 grid magnitude (subnormal band and
    mantissa-carry boundaries included)."""
    bits = [
        0x00000000, 0x80000000,              # ±0
        0x7FC00000, 0xFFC00000,              # quiet NaNs
        0x7F800001, 0xFF800001, 0x7FFFFFFF,  # signalling/max payloads
        0x7F800000, 0xFF800000,              # ±inf
        0x00000001, 0x80000001, 0x007FFFFF,  # f32 subnormals
        0x7F7FFFFF, 0xFF7FFFFF,              # ±f32::MAX
    ]
    for v in [
        mirror.alpha,
        -mirror.alpha,
        mirror.alpha * 0.9999999,
        mirror.alpha * 1.0000001,
        mirror.alpha * 2.0,
        -mirror.alpha * 2.0,
        mirror.alpha * 1.0e6,
    ]:
        bits.append(f32_bits(f32(v)))
    for code in range(0x80):
        v = mirror.decode(code)
        b = f32_bits(v)
        for d in (-2, -1, 0, 1, 2):
            nb = (b + d) & 0xFFFFFFFF
            bits.append(nb)
            bits.append(nb ^ 0x80000000)
    # dedupe, stable order
    seen, out = set(), []
    for b in bits:
        if b not in seen:
            seen.add(b)
            out.append(b)
    return out


def fp8_edge_fixture():
    cases = []
    for alpha in EDGE_ALPHAS:
        m = Fp8Mirror(alpha)
        x_bits = edge_inputs(m)
        for u in EDGE_US:
            codes = [m.encode(f32_from_bits(b), u) for b in x_bits]
            cases.append(
                {"alpha": alpha, "u": u, "x_bits": x_bits,
                 "codes": codes}
            )
    return {"m": M_BITS, "e": 4, "version": 1, "cases": cases}


# ---- snapshot format mirror (twin of coordinator/snapshot.rs) --------
#
# Durable round-state snapshot, all integers little-endian::
#
#     header (16 bytes):
#       magic      4  = b"FP8S"
#       version    u16 = 2
#       reserved   u16 = 0
#       body_len   u32
#       crc32      u32 (IEEE CRC-32 of body)
#     body:
#       fingerprint u64, next_round u64,
#       dim u32, alpha_dim u32, beta_dim u32,
#       w [f32 x dim], alpha [f32 x alpha_dim], beta [f32 x beta_dim],
#       ef_server_len u32, ef_server [f32 x len],
#       ef_clients_count u32, then per entry (ascending client id):
#         client u64, len u32, residual [f32 x len],
#       comm 6 x u64 (up_bytes, down_bytes, up_msgs, down_msgs,
#                     partial_bytes, partial_msgs),
#       wall_millis u64   # v2: cumulative wall clock across resumes
#
# v1 is v2 without the trailing wall_millis field.

SNAP_MAGIC = b"FP8S"
SNAP_VERSION = 2
SNAP_HEADER_BYTES = 16


def snapshot_frame(body, version=SNAP_VERSION):
    hdr = SNAP_MAGIC + struct.pack(
        "<HHII", version, 0, len(body), zlib.crc32(body) & 0xFFFFFFFF
    )
    assert len(hdr) == SNAP_HEADER_BYTES
    return hdr + body


def snapshot_body_v1(fingerprint, next_round, w, alpha, beta,
                     ef_server, ef_clients, comm):
    """Frozen v1 body (no wall_millis) — keep byte-stable forever."""
    body = struct.pack(
        "<QQIII", fingerprint, next_round, len(w), len(alpha), len(beta)
    )
    body += f32s(w) + f32s(alpha) + f32s(beta)
    body += struct.pack("<I", len(ef_server)) + f32s(ef_server)
    body += struct.pack("<I", len(ef_clients))
    for client in sorted(ef_clients):  # BTreeMap order: ascending id
        res = ef_clients[client]
        body += struct.pack("<QI", client, len(res)) + f32s(res)
    body += struct.pack("<QQQQQQ", *comm)
    return body


def snapshot_body(wall_millis=0, **kw):
    return snapshot_body_v1(**kw) + struct.pack("<Q", wall_millis)


# Mirrors canon() in rust/tests/golden_snapshot.rs: every f32 is an
# exactly-representable short binary fraction.
CANON_SNAP_V1 = dict(
    fingerprint=0xDEADBEEF01234567,
    next_round=42,
    w=[1.0, -2.0, 0.5],
    alpha=[3.0],
    beta=[0.125, 8.0],
    ef_server=[0.0625, -0.0625, 0.0],
    ef_clients={3: [0.5, -0.25], 11: [1.5, 2.5]},
    # (up_bytes, down_bytes, up_msgs, down_msgs,
    #  partial_bytes, partial_msgs)
    comm=(111, 222, 3, 4, 55, 6),
)
CANON_SNAP = dict(CANON_SNAP_V1, wall_millis=987654)


def golden_snapshot():
    return snapshot_frame(snapshot_body(**CANON_SNAP))


def golden_snapshot_v1():
    """Frozen v1 fixture (must reproduce the committed
    snapshot_v1.bin byte-for-byte, forever): a v2 reader must reject
    it with the typed VersionMismatch, never fall through to the body
    decoder."""
    return snapshot_frame(snapshot_body_v1(**CANON_SNAP_V1), version=1)


def golden_snapshot_v0():
    """Version-skew fixture: a v0 header over the frozen v1 body
    (valid, correctly crc'd) — likewise rejected with the typed
    VersionMismatch."""
    return snapshot_frame(snapshot_body_v1(**CANON_SNAP_V1), version=0)


# ---- canonical golden messages (mirrored in rust/tests/golden_wire.rs)

CANON_DOWN = (range(16), [1.0, -2.5, 0.375], [1.0, 0.5], [2.0])
CANON_UP = ([0xFF, 0x80, 0x07], [], [1.5], [])
CANON_JOB_ID = 2
CANON_NONCE = 0x0000BEA7_0000BEA7
# canonical mid-tier partial: cohort positions [2, 4), width-3
# accumulator, two fragments; every sum is an exactly-representable
# short binary fraction, so the f64 bit patterns are parser-stable
CANON_PARTIAL = dict(
    round_=3, start=2, end=4, width=3,
    fragments=[
        (0, 2, [1.5, -0.25, 8.0]),
        (2, 1, [0.0625, -2.0, 128.0]),
    ],
)


def golden_frames():
    """The v2 golden stream: Job, Outcome, Heartbeat, HeartbeatAck,
    Partial."""
    job = frame(
        KIND_JOB,
        job_body(
            round_=3, client=5, job_id=CANON_JOB_ID, seed=0x00C0FFEE,
            qat=0, comm=1, flip_aug=True, lr=0.125, wd=0.0009765625,
            n_k=100, down=CANON_DOWN, ef=None,
        ),
    )
    outcome = frame(
        KIND_OUTCOME,
        outcome_body(
            round_=3, client=5, job_id=CANON_JOB_ID, n_k=100,
            mean_loss=0.75, payload=CANON_UP, ef=[0.5, -0.25],
        ),
    )
    heartbeat = frame(KIND_HEARTBEAT, heartbeat_body(CANON_NONCE))
    heartbeat_ack = frame(
        KIND_HEARTBEAT_ACK, heartbeat_body(CANON_NONCE)
    )
    partial = frame(KIND_PARTIAL, partial_body(**CANON_PARTIAL))
    return job, outcome, heartbeat, heartbeat_ack, partial


def golden_frames_v1():
    """The frozen v1 stream (must reproduce the committed wire_v1.bin
    byte-for-byte, forever)."""
    job = frame(
        KIND_JOB,
        job_body_v1(
            round_=3, client=5, seed=0x00C0FFEE, qat=0, comm=1,
            flip_aug=True, lr=0.125, wd=0.0009765625, n_k=100,
            down=CANON_DOWN, ef=None,
        ),
        version=V1_VERSION,
    )
    outcome = frame(
        KIND_OUTCOME,
        outcome_body_v1(
            round_=3, client=5, n_k=100, mean_loss=0.75,
            payload=CANON_UP, ef=[0.5, -0.25],
        ),
        version=V1_VERSION,
    )
    return job, outcome


def main():
    fixtures = os.path.join(
        os.path.dirname(__file__), "..", "rust", "tests", "fixtures"
    )
    os.makedirs(fixtures, exist_ok=True)

    job, outcome, heartbeat, heartbeat_ack, partial = golden_frames()
    # overhead identities the Rust accounting constants rely on
    assert len(job) == wire_bytes(*CANON_DOWN) + JOB_FRAME_OVERHEAD
    assert (
        len(outcome)
        == wire_bytes(*CANON_UP) + OUTCOME_FRAME_OVERHEAD + 4 + 4 * 2
    )
    assert len(heartbeat) == FRAME_HEADER_BYTES + 8
    # the backbone identity CommStats::record_partial charges by
    assert len(partial) == (
        partial_wire_bytes(
            CANON_PARTIAL["width"], len(CANON_PARTIAL["fragments"])
        )
        + PARTIAL_FRAME_OVERHEAD
    )
    out = os.path.join(fixtures, "wire_v2.bin")
    stream = job + outcome + heartbeat + heartbeat_ack + partial
    with open(out, "wb") as f:
        f.write(stream)
    print(f"wrote {out}: job {len(job)} B + outcome {len(outcome)} B "
          f"+ 2 heartbeat frames + partial {len(partial)} B "
          f"= {len(stream)} B")
    print("job      :", job.hex())
    print("outcome  :", outcome.hex())
    print("heartbeat:", heartbeat.hex())
    print("partial  :", partial.hex())

    job1, outcome1 = golden_frames_v1()
    assert len(job1) == wire_bytes(*CANON_DOWN) + V1_JOB_FRAME_OVERHEAD
    out = os.path.join(fixtures, "wire_v1.bin")
    with open(out, "wb") as f:
        f.write(job1 + outcome1)
    print(f"wrote {out}: {len(job1) + len(outcome1)} B (frozen v1)")

    snap = golden_snapshot()
    out = os.path.join(fixtures, "snapshot_v2.bin")
    with open(out, "wb") as f:
        f.write(snap)
    print(f"wrote {out}: {len(snap)} B")
    print("snapshot :", snap.hex())

    snap1 = golden_snapshot_v1()
    out = os.path.join(fixtures, "snapshot_v1.bin")
    with open(out, "wb") as f:
        f.write(snap1)
    print(f"wrote {out}: {len(snap1)} B (frozen v1, must-fail skew)")

    snap0 = golden_snapshot_v0()
    out = os.path.join(fixtures, "snapshot_v0.bin")
    with open(out, "wb") as f:
        f.write(snap0)
    print(f"wrote {out}: {len(snap0)} B (must-fail version skew)")

    edges = fp8_edge_fixture()
    out = os.path.join(fixtures, "fp8_edges_v1.json")
    with open(out, "w") as f:
        json.dump(edges, f, separators=(",", ":"))
        f.write("\n")
    n = sum(len(c["codes"]) for c in edges["cases"])
    print(f"wrote {out}: {len(edges['cases'])} cases, {n} edge codes")


if __name__ == "__main__":
    main()
