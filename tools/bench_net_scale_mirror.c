/* C mirror of rust/benches/net_scale.rs — seeds BENCH_net_scale.json
 * when no Rust toolchain is available.
 *
 * Replicates the transport-scaling bench op-for-op: for each fleet
 * size N, stand up N loopback TCP connections each primed with 64
 * Outcome-sized frames (16-byte FP8W header — magic, version=2 LE,
 * kind=4, body len LE, IEEE crc32 of the body LE — plus a 64-byte
 * body), then drain every frame two ways:
 *
 *   - poll:    ONE thread, one epoll instance, N non-blocking
 *              sockets, a resumable per-connection frame parser
 *              (header -> body with magic/version/crc validation) —
 *              the server's event-driven poll-loop data path.
 *   - threads: N spawned pthreads, each blocking-reading its own
 *              socket through the same frame walk — the
 *              thread-per-connection architecture the poll loop
 *              replaces. Spawn/teardown is inside the timed region,
 *              exactly as the Rust arm times thread::scope.
 *
 * Both arms pay identical setup (connect + prime inside the timed
 * closure), mirroring the Rust bench, so the delta isolates reader
 * threads vs one readiness loop. Timing harness is a twin of
 * rust/src/util/bench.rs::bench (warmup max(budget/5, 10) ms, one
 * sample per call until the budget elapses with >= 5 samples,
 * median/p10/p90 at index (len-1)*p).
 *
 * Build & run (repo root):
 *   gcc -O3 -pthread -o /tmp/net_scale_mirror \
 *       tools/bench_net_scale_mirror.c
 *   /tmp/net_scale_mirror      # writes BENCH_net_scale.json
 *
 * `cargo bench --bench net_scale` overwrites the JSON with native
 * Rust numbers whenever a Rust toolchain is present.
 */

#include <arpa/inet.h>
#include <errno.h>
#include <fcntl.h>
#include <netinet/in.h>
#include <netinet/tcp.h>
#include <pthread.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <sys/epoll.h>
#include <sys/socket.h>
#include <time.h>
#include <unistd.h>

#define HDR_BYTES 16
#define BODY_BYTES 64
#define FRAME_BYTES (HDR_BYTES + BODY_BYTES)
#define KIND_OUTCOME 4
#define WIRE_VERSION 2
#define MAX_FLEET 128

static const uint8_t MAGIC[4] = {'F', 'P', '8', 'W'};

/* ---- IEEE crc32 (twin of rust/src/net/frame.rs) ------------------- */

static uint32_t CRC_TAB[256];

static void crc_init(void) {
    for (uint32_t i = 0; i < 256; i++) {
        uint32_t c = i;
        for (int k = 0; k < 8; k++)
            c = (c & 1) ? 0xEDB88320u ^ (c >> 1) : c >> 1;
        CRC_TAB[i] = c;
    }
}

static uint32_t crc32_of(const uint8_t *buf, size_t len) {
    uint32_t c = 0xFFFFFFFFu;
    for (size_t i = 0; i < len; i++)
        c = CRC_TAB[(c ^ buf[i]) & 0xFF] ^ (c >> 8);
    return ~c;
}

/* ---- frame priming ------------------------------------------------ */

static uint8_t FRAME[FRAME_BYTES]; /* one encoded Outcome frame */

static void frame_init(void) {
    uint8_t body[BODY_BYTES];
    memset(body, 7, sizeof body);
    memcpy(FRAME, MAGIC, 4);
    FRAME[4] = WIRE_VERSION & 0xFF;
    FRAME[5] = (WIRE_VERSION >> 8) & 0xFF;
    FRAME[6] = KIND_OUTCOME;
    FRAME[7] = 0;
    uint32_t len = BODY_BYTES;
    memcpy(FRAME + 8, &len, 4); /* x86_64: LE, same as to_le_bytes */
    uint32_t crc = crc32_of(body, BODY_BYTES);
    memcpy(FRAME + 12, &crc, 4);
    memcpy(FRAME + HDR_BYTES, body, BODY_BYTES);
}

static void die(const char *what) {
    perror(what);
    exit(1);
}

/* N primed loopback connections; write ends in wfd[], read ends in
 * rfd[]. Every read end already holds `frames` complete frames. */
static void primed_pairs(int n, int frames, int *wfd, int *rfd) {
    int lfd = socket(AF_INET, SOCK_STREAM, 0);
    if (lfd < 0) die("socket");
    struct sockaddr_in addr;
    memset(&addr, 0, sizeof addr);
    addr.sin_family = AF_INET;
    addr.sin_addr.s_addr = htonl(INADDR_LOOPBACK);
    addr.sin_port = 0;
    if (bind(lfd, (struct sockaddr *)&addr, sizeof addr) < 0) die("bind");
    if (listen(lfd, MAX_FLEET) < 0) die("listen");
    socklen_t alen = sizeof addr;
    if (getsockname(lfd, (struct sockaddr *)&addr, &alen) < 0)
        die("getsockname");
    for (int i = 0; i < n; i++) {
        int w = socket(AF_INET, SOCK_STREAM, 0);
        if (w < 0) die("socket");
        if (connect(w, (struct sockaddr *)&addr, sizeof addr) < 0)
            die("connect");
        int one = 1;
        setsockopt(w, IPPROTO_TCP, TCP_NODELAY, &one, sizeof one);
        int r = accept(lfd, NULL, NULL);
        if (r < 0) die("accept");
        for (int fidx = 0; fidx < frames; fidx++) {
            size_t off = 0;
            while (off < FRAME_BYTES) {
                ssize_t k = write(w, FRAME + off, FRAME_BYTES - off);
                if (k <= 0) die("prime write");
                off += (size_t)k;
            }
        }
        wfd[i] = w;
        rfd[i] = r;
    }
    close(lfd);
}

static void close_pairs(int n, const int *wfd, const int *rfd) {
    for (int i = 0; i < n; i++) {
        close(wfd[i]);
        close(rfd[i]);
    }
}

/* Resumable per-connection parser — twin of FrameReader::poll. */
typedef struct {
    uint8_t buf[FRAME_BYTES];
    size_t have;   /* bytes of the current frame accumulated */
    int got;       /* complete frames consumed */
} Parser;

static void check_frame(const uint8_t *f) {
    if (memcmp(f, MAGIC, 4) != 0) {
        fprintf(stderr, "bad magic\n");
        exit(1);
    }
    uint16_t ver;
    uint32_t len, crc;
    memcpy(&ver, f + 4, 2);
    memcpy(&len, f + 8, 4);
    memcpy(&crc, f + 12, 4);
    if (ver != WIRE_VERSION || f[6] != KIND_OUTCOME ||
        len != BODY_BYTES || crc != crc32_of(f + HDR_BYTES, len)) {
        fprintf(stderr, "bad frame\n");
        exit(1);
    }
}

/* ---- poll arm: one thread, one epoll, N parsers ------------------- */

static void drain_poll(int n, int frames) {
    int wfd[MAX_FLEET], rfd[MAX_FLEET];
    primed_pairs(n, frames, wfd, rfd);
    int ep = epoll_create1(EPOLL_CLOEXEC);
    if (ep < 0) die("epoll_create1");
    Parser ps[MAX_FLEET];
    memset(ps, 0, sizeof(Parser) * (size_t)n);
    for (int i = 0; i < n; i++) {
        int fl = fcntl(rfd[i], F_GETFL, 0);
        fcntl(rfd[i], F_SETFL, fl | O_NONBLOCK);
        struct epoll_event ev;
        ev.events = EPOLLIN | EPOLLRDHUP;
        ev.data.u64 = (uint64_t)i;
        if (epoll_ctl(ep, EPOLL_CTL_ADD, rfd[i], &ev) < 0)
            die("epoll_ctl");
    }
    int remaining = n * frames;
    struct epoll_event evs[64];
    while (remaining > 0) {
        int nr = epoll_wait(ep, evs, 64, 10);
        if (nr < 0) {
            if (errno == EINTR) continue;
            die("epoll_wait");
        }
        for (int e = 0; e < nr; e++) {
            int i = (int)evs[e].data.u64;
            Parser *p = &ps[i];
            while (p->got < frames) {
                ssize_t k = read(rfd[i], p->buf + p->have,
                                 FRAME_BYTES - p->have);
                if (k < 0) {
                    if (errno == EAGAIN || errno == EWOULDBLOCK)
                        break;
                    die("poll read");
                }
                if (k == 0) die("poll eof");
                p->have += (size_t)k;
                if (p->have == FRAME_BYTES) {
                    check_frame(p->buf);
                    p->have = 0;
                    p->got++;
                    remaining--;
                }
            }
        }
    }
    close(ep);
    close_pairs(n, wfd, rfd);
}

/* ---- thread arm: N blocking readers ------------------------------- */

typedef struct {
    int fd;
    int frames;
} ThreadJob;

static void *reader_main(void *arg) {
    ThreadJob *job = (ThreadJob *)arg;
    uint8_t buf[FRAME_BYTES];
    for (int fidx = 0; fidx < job->frames; fidx++) {
        size_t off = 0;
        while (off < FRAME_BYTES) {
            ssize_t k = read(job->fd, buf + off, FRAME_BYTES - off);
            if (k <= 0) die("thread read");
            off += (size_t)k;
        }
        check_frame(buf);
    }
    return NULL;
}

static void drain_threads(int n, int frames) {
    int wfd[MAX_FLEET], rfd[MAX_FLEET];
    primed_pairs(n, frames, wfd, rfd);
    pthread_t tids[MAX_FLEET];
    ThreadJob jobs[MAX_FLEET];
    for (int i = 0; i < n; i++) {
        jobs[i].fd = rfd[i];
        jobs[i].frames = frames;
        if (pthread_create(&tids[i], NULL, reader_main, &jobs[i]) != 0)
            die("pthread_create");
    }
    for (int i = 0; i < n; i++)
        pthread_join(tids[i], NULL);
    close_pairs(n, wfd, rfd);
}

/* ---- timing harness (twin of rust/src/util/bench.rs) -------------- */

typedef struct {
    const char *name;
    uint64_t iters;
    double median_ns, p10_ns, p90_ns;
} BenchResult;

static double now_ns(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return (double)ts.tv_sec * 1e9 + (double)ts.tv_nsec;
}

static int cmp_f64(const void *a, const void *b) {
    double x = *(const double *)a, y = *(const double *)b;
    return (x > y) - (x < y);
}

static BenchResult run_bench(const char *name, uint64_t budget_ms,
                             void (*f)(int, int), int n, int frames) {
    double warm_until =
        now_ns() + (double)(budget_ms / 5 > 10 ? budget_ms / 5 : 10) * 1e6;
    while (now_ns() < warm_until)
        f(n, frames);
    static double samples[100000];
    size_t cnt = 0;
    double run_until = now_ns() + (double)budget_ms * 1e6;
    while (now_ns() < run_until || cnt < 5) {
        double t = now_ns();
        f(n, frames);
        samples[cnt++] = now_ns() - t;
        if (cnt >= 100000) break;
    }
    qsort(samples, cnt, sizeof(double), cmp_f64);
    BenchResult r;
    r.name = name;
    r.iters = cnt;
    r.median_ns = samples[(size_t)((double)(cnt - 1) * 0.5)];
    r.p10_ns = samples[(size_t)((double)(cnt - 1) * 0.1)];
    r.p90_ns = samples[(size_t)((double)(cnt - 1) * 0.9)];
    printf("%-44s %10.0f ns %10.0f ns %10.0f ns  (%llu iters)\n",
           name, r.median_ns, r.p10_ns, r.p90_ns,
           (unsigned long long)r.iters);
    return r;
}

static void emit_result(FILE *f, const BenchResult *r, double items,
                        int first) {
    fprintf(f,
            "%s\n    {\"name\": \"%s\", \"iters\": %llu, "
            "\"median_ns\": %.1f, \"p10_ns\": %.1f, \"p90_ns\": %.1f, "
            "\"throughput_per_s\": %.1f}",
            first ? "" : ",", r->name, (unsigned long long)r->iters,
            r->median_ns, r->p10_ns, r->p90_ns,
            items / (r->median_ns * 1e-9));
}

int main(void) {
    crc_init();
    frame_init();
    const int fleet[] = {8, 32, 128};
    const int n_fleet = 3;
    const int frames = 64;
    const uint64_t budget_ms = 400;
    char poll_names[3][48], thr_names[3][48];
    BenchResult poll_r[3], thr_r[3];
    printf("readiness backend: epoll; %d frames x %d B bodies per "
           "connection\n\n",
           frames, BODY_BYTES);
    for (int i = 0; i < n_fleet; i++) {
        int n = fleet[i];
        snprintf(poll_names[i], sizeof poll_names[i],
                 "net_scale/poll_1thread_n%d", n);
        snprintf(thr_names[i], sizeof thr_names[i],
                 "net_scale/threads_n%d", n);
        poll_r[i] =
            run_bench(poll_names[i], budget_ms, drain_poll, n, frames);
        thr_r[i] = run_bench(thr_names[i], budget_ms, drain_threads, n,
                             frames);
    }

    FILE *f = fopen("BENCH_net_scale.json", "w");
    if (!f) die("BENCH_net_scale.json");
    fprintf(f, "{\n  \"bench\": \"net_scale\",\n");
    fprintf(f,
            "  \"provenance\": \"tools/bench_net_scale_mirror.c (gcc "
            "-O3 -pthread C mirror of rust/benches/net_scale.rs, "
            "op-for-op: same FP8W frame walk — 16-byte header with "
            "IEEE crc32 of each 64-byte body — over N primed loopback "
            "TCP connections, drained by one epoll readiness loop vs "
            "one blocking reader thread per connection, with "
            "connection setup and thread spawn inside the timed "
            "region on both arms exactly as the Rust bench times "
            "them; build container lacks a Rust toolchain). The C "
            "parser resumes partial frames like FrameReader but skips "
            "Rust's enum/Vec materialization, so absolute latencies "
            "understate both arms equally while the poll-vs-threads "
            "scaling ratio transfers. Regenerate natively with `cargo "
            "bench --bench net_scale`.\",\n");
    fprintf(f,
            "  \"config\": {\"backend\": \"epoll\", "
            "\"frames_per_conn\": \"%d\", \"body_bytes\": \"%d\", "
            "\"fleet_sizes\": \"[%d, %d, %d]\"},\n",
            frames, BODY_BYTES, fleet[0], fleet[1], fleet[2]);
    fprintf(f, "  \"results\": [");
    for (int i = 0; i < n_fleet; i++) {
        double items = (double)fleet[i] * frames;
        emit_result(f, &poll_r[i], items, i == 0);
        emit_result(f, &thr_r[i], items, 0);
    }
    fprintf(f, "\n  ],\n  \"speedups\": {\n");
    for (int i = 0; i < n_fleet; i++) {
        fprintf(f, "    \"poll_over_threads_n%d\": %.3f%s\n", fleet[i],
                thr_r[i].median_ns / poll_r[i].median_ns,
                i + 1 < n_fleet ? "," : "");
    }
    fprintf(f, "  }\n}\n");
    fclose(f);
    printf("\nwrote BENCH_net_scale.json\n");
    return 0;
}
