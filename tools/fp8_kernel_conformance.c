/* Differential conformance harness for the FP8 SIMD kernel layer
 * (`rust/src/fp8/simd.rs`) — C twin, exhaustively runnable.
 *
 * The Rust tree carries three implementations of the FP8 value
 * mapping: the branchy scalar oracle (`format.rs::Fp8Params::
 * {quantize,encode}`), the portable branch-free kernel and the AVX2
 * lane kernel (both in `simd.rs`). The contract is *bit-equality for
 * every f32 input*: FP8 Formats for Deep Learning (Micikevicius et
 * al., 2022) and 8-bit Numerical Formats for DNNs (Noune et al.,
 * 2022) both document that bias/subnormal/saturation handling is
 * where FP8 implementations silently diverge, so the speedup ships
 * welded to this sweep.
 *
 * This file mirrors all three implementations op-for-op (IEEE f64
 * math is deterministic, so the equivalence argument transfers) and
 * was used to validate the algorithms over the FULL 2^32 f32 bit
 * pattern space before the Rust transcription; the in-tree twin is
 * `rust/tests/exhaustive_fp8.rs` (stratified subset in tier-1, full
 * sweep in nightly CI via FEDFP8_EXHAUSTIVE_CHUNKS).
 *
 * Build & run (repo root):
 *   gcc -O3 -mavx2 -o /tmp/fp8_conf tools/fp8_kernel_conformance.c \
 *       -lm -lpthread
 *   /tmp/fp8_conf stratified          # fast edge-pattern subset
 *   /tmp/fp8_conf exhaustive          # all 2^32 patterns (minutes)
 *   /tmp/fp8_conf exhaustive 3 8      # chunk 3 of 8
 *   /tmp/fp8_conf bench               # scalar vs bf vs avx2 encode
 */

#include <immintrin.h>
#include <math.h>
#include <pthread.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>
#include <unistd.h>

/* ---- FP8 format (twin of rust/src/fp8/format.rs) ------------------ */

#define M_BITS 3
#define E_MAX 15
#define LOG2_TOP 0.9068905956085185

typedef struct {
    float alpha;
    double bias, exp2_bias, sub_scale, scales[16];
} Fp8Params;

static Fp8Params params_new(float alpha) {
    Fp8Params p;
    p.alpha = alpha;
    p.bias = 16.0 - log2((double)alpha) + LOG2_TOP - 1.0;
    p.exp2_bias = exp2(p.bias);
    p.sub_scale = exp2(1.0 - p.bias - M_BITS);
    for (int c = 0; c < 16; c++)
        p.scales[c] = exp2((double)c - p.bias - M_BITS);
    return p;
}

static inline int64_t code_exponent(const Fp8Params *p, double absx) {
    double u = absx * p->exp2_bias;
    uint64_t bits;
    memcpy(&bits, &u, 8);
    return (int64_t)((bits >> 52) & 0x7FF) - 1023;
}

/* scalar oracle — branch-for-branch copy of Fp8Params::quantize */
static inline float quantize_scalar(const Fp8Params *p, float x, double u) {
    if (x == 0.0f) return 0.0f;
    if (isnan(x)) return 0.0f;
    double x64 = (double)x;
    int64_t c = code_exponent(p, fabs(x64));
    double s = c > 1 ? p->scales[c < 15 ? c : 15] : p->sub_scale;
    double z = x64 / s;
    double f = floor(z);
    double up = (z - f >= u) ? 1.0 : 0.0;
    double q = (f + up) * s;
    double a = (double)p->alpha;
    if (q < -a) q = -a;
    if (q > a) q = a;
    return (float)q;
}

/* scalar oracle — branch-for-branch copy of Fp8Params::encode */
static inline uint8_t encode_scalar(const Fp8Params *p, float x, double u) {
    if (x == 0.0f || isnan(x)) return 0;
    if (isinf(x))
        return (uint8_t)(((x < 0.0f) ? 0x80 : 0) | 0x7F);
    int neg = x < 0.0f;
    double absx = fabs((double)x);
    int64_t c = code_exponent(p, absx);
    int64_t n;
    if (c > 1) {
        if (c > E_MAX) return (uint8_t)((neg << 7) | 0x7F);
        double s = p->scales[c];
        double z = absx / s, f = floor(z);
        int up = neg ? (1.0 - (z - f) < u) : (z - f >= u);
        n = (int64_t)f + up;
        if (n >= (1 << (M_BITS + 1))) { c += 1; n = 1 << M_BITS; }
        if (n < (1 << M_BITS)) { c -= 1; n = (1 << (M_BITS + 1)) - 1; }
        if (c > E_MAX) return (uint8_t)((neg << 7) | 0x7F);
        return (uint8_t)((neg << 7) | ((int)c << M_BITS) | (n & 7));
    }
    double z = absx / p->sub_scale, f = floor(z);
    int up = neg ? (1.0 - (z - f) < u) : (z - f >= u);
    n = (int64_t)f + up;
    if (n > (1 << (M_BITS + 1))) n = 1 << (M_BITS + 1);
    return (uint8_t)((neg << 7) | ((n >> M_BITS) << M_BITS) | (n & 7));
}

/* ---- branch-free portable kernel (twin of simd.rs quantize_bf) ---- */

static inline float quantize_bf(const Fp8Params *p, float x, double u) {
    double x64 = (double)x;
    double absx = fabs(x64);
    double ub = absx * p->exp2_bias;
    uint64_t bits;
    memcpy(&bits, &ub, 8);
    int64_t c = (int64_t)((bits >> 52) & 0x7FF) - 1023;
    int is_sub = c <= 1;
    int64_t idx = c < 0 ? 0 : (c > 15 ? 15 : c);
    double s = is_sub ? p->sub_scale : p->scales[idx];
    double z = x64 / s;
    double f = floor(z);
    double up = (z - f >= u) ? 1.0 : 0.0;
    double a = (double)p->alpha;
    double q = fmin(fmax((f + up) * s, -a), a);
    float out = (float)q;
    return (x == 0.0f || isnan(x)) ? 0.0f : out;
}

/* twin of simd.rs encode_bf */
static inline uint8_t encode_bf(const Fp8Params *p, float x, double u) {
    double x64 = (double)x;
    double absx = fabs(x64);
    double ub = absx * p->exp2_bias;
    uint64_t bits;
    memcpy(&bits, &ub, 8);
    int64_t c = (int64_t)((bits >> 52) & 0x7FF) - 1023;
    int is_sub = c <= 1;
    int64_t idx = c < 0 ? 0 : (c > 15 ? 15 : c);
    double s = is_sub ? p->sub_scale : p->scales[idx];
    double z = absx / s;
    double f = floor(z);
    double frac = z - f;
    int neg = x64 < 0.0;
    int up = neg ? (1.0 - frac < u) : (frac >= u);
    /* clamp before int conversion: saturated lanes can carry huge or
     * NaN f (fmin(NaN, 17) = 17); non-saturated lanes never exceed 16
     * so the clamp is a no-op exactly where the result is used */
    int64_t n = (int64_t)fmin(f, 17.0) + up;
    int64_t c_adj = c + (n > 15) - (n < 8);
    int64_t n_adj = n > 15 ? 8 : (n < 8 ? 15 : n);
    int sat = c_adj > 15;
    uint8_t code_norm =
        sat ? 0x7F : (uint8_t)((c_adj << M_BITS) | (n_adj & 7));
    uint8_t code_sub = (uint8_t)(n > 16 ? 16 : n);
    uint8_t mag = is_sub ? code_sub : code_norm;
    uint8_t code = (uint8_t)((neg ? 0x80 : 0) | mag);
    return (x == 0.0f || isnan(x)) ? 0 : code;
}

/* ---- AVX2 lane kernel (twin of simd.rs Avx2Kernel) ---------------- */

static inline __m128i narrow64(__m256i v) {
    return _mm256_castsi256_si128(_mm256_permutevar8x32_epi32(
        v, _mm256_setr_epi32(0, 2, 4, 6, 0, 0, 0, 0)));
}

/* Per-exponent scale lookup via four indexed loads: measurably faster
 * than vgatherdpd on this (virtualized) host and on pre-Skylake parts,
 * and bit-identical — the loads read the same scales[] the scalar
 * oracle uses. */
static inline __m256d scale_lookup(const double *scales, __m128i idx) {
    return _mm256_setr_pd(scales[(uint32_t)_mm_extract_epi32(idx, 0)],
                          scales[(uint32_t)_mm_extract_epi32(idx, 1)],
                          scales[(uint32_t)_mm_extract_epi32(idx, 2)],
                          scales[(uint32_t)_mm_extract_epi32(idx, 3)]);
}

/* 4 lanes of quantize; in-place on data[0..4] */
static void quantize4_avx2(const Fp8Params *p, float *data,
                           const double *us) {
    __m128 xs = _mm_loadu_ps(data);
    __m256d x = _mm256_cvtps_pd(xs);
    __m256d absx =
        _mm256_andnot_pd(_mm256_set1_pd(-0.0), x);
    __m256d ub = _mm256_mul_pd(absx, _mm256_set1_pd(p->exp2_bias));
    __m256i ebits = _mm256_and_si256(
        _mm256_srli_epi64(_mm256_castpd_si256(ub), 52),
        _mm256_set1_epi64x(0x7FF));
    __m128i c32 = _mm_sub_epi32(
        narrow64(ebits), _mm_set1_epi32(1023));
    __m128i is_sub32 = _mm_cmpgt_epi32(_mm_set1_epi32(2), c32);
    __m128i idx = _mm_min_epi32(
        _mm_max_epi32(c32, _mm_setzero_si128()), _mm_set1_epi32(15));
    __m256d sg = scale_lookup(p->scales, idx);
    __m256d is_sub_pd =
        _mm256_castsi256_pd(_mm256_cvtepi32_epi64(is_sub32));
    __m256d s = _mm256_blendv_pd(
        sg, _mm256_set1_pd(p->sub_scale), is_sub_pd);
    __m256d z = _mm256_div_pd(x, s);
    __m256d f = _mm256_floor_pd(z);
    __m256d u = _mm256_loadu_pd(us);
    __m256d up_mask =
        _mm256_cmp_pd(_mm256_sub_pd(z, f), u, _CMP_GE_OQ);
    __m256d up =
        _mm256_and_pd(up_mask, _mm256_set1_pd(1.0));
    __m256d q = _mm256_mul_pd(_mm256_add_pd(f, up), s);
    __m256d a = _mm256_set1_pd((double)p->alpha);
    q = _mm256_min_pd(
        _mm256_max_pd(q, _mm256_sub_pd(_mm256_setzero_pd(), a)), a);
    __m128 qf = _mm256_cvtpd_ps(q);
    __m256d kill_pd = _mm256_or_pd(
        _mm256_cmp_pd(x, _mm256_setzero_pd(), _CMP_EQ_OQ),
        _mm256_cmp_pd(x, x, _CMP_UNORD_Q));
    __m128 kill = _mm_castsi128_ps(narrow64(_mm256_castpd_si256(kill_pd)));
    _mm_storeu_ps(data, _mm_andnot_ps(kill, qf));
}

/* 4 lanes of encode; dst[0..4] */
static void encode4_avx2(const Fp8Params *p, const float *src,
                         const double *us, uint8_t *dst) {
    __m128 xs = _mm_loadu_ps(src);
    __m256d x = _mm256_cvtps_pd(xs);
    __m256d absx = _mm256_andnot_pd(_mm256_set1_pd(-0.0), x);
    __m256d ub = _mm256_mul_pd(absx, _mm256_set1_pd(p->exp2_bias));
    __m256i ebits = _mm256_and_si256(
        _mm256_srli_epi64(_mm256_castpd_si256(ub), 52),
        _mm256_set1_epi64x(0x7FF));
    __m128i c32 = _mm_sub_epi32(narrow64(ebits), _mm_set1_epi32(1023));
    __m128i is_sub32 = _mm_cmpgt_epi32(_mm_set1_epi32(2), c32);
    __m128i idx = _mm_min_epi32(
        _mm_max_epi32(c32, _mm_setzero_si128()), _mm_set1_epi32(15));
    __m256d sg = scale_lookup(p->scales, idx);
    __m256d is_sub_pd =
        _mm256_castsi256_pd(_mm256_cvtepi32_epi64(is_sub32));
    __m256d s = _mm256_blendv_pd(
        sg, _mm256_set1_pd(p->sub_scale), is_sub_pd);
    __m256d z = _mm256_div_pd(absx, s);
    __m256d f = _mm256_floor_pd(z);
    __m256d frac = _mm256_sub_pd(z, f);
    __m256d u = _mm256_loadu_pd(us);
    __m256d neg_pd = _mm256_cmp_pd(x, _mm256_setzero_pd(), _CMP_LT_OQ);
    __m256d up_pos = _mm256_cmp_pd(frac, u, _CMP_GE_OQ);
    __m256d up_neg = _mm256_cmp_pd(
        _mm256_sub_pd(_mm256_set1_pd(1.0), frac), u, _CMP_LT_OQ);
    __m256d up_pd = _mm256_blendv_pd(up_pos, up_neg, neg_pd);
    __m256d fcl = _mm256_min_pd(f, _mm256_set1_pd(17.0));
    __m128i fi = _mm256_cvttpd_epi32(fcl);
    __m128i up32 = narrow64(_mm256_castpd_si256(up_pd));
    /* up32 lanes are 0 or -1; subtract to add the rounding increment */
    __m128i n32 = _mm_sub_epi32(fi, up32);
    __m128i carry = _mm_cmpgt_epi32(n32, _mm_set1_epi32(15));
    __m128i jitter = _mm_cmpgt_epi32(_mm_set1_epi32(8), n32);
    __m128i c_adj = _mm_add_epi32(_mm_sub_epi32(c32, carry), jitter);
    __m128i n_adj = _mm_blendv_epi8(n32, _mm_set1_epi32(8), carry);
    n_adj = _mm_blendv_epi8(n_adj, _mm_set1_epi32(15), jitter);
    __m128i sat = _mm_cmpgt_epi32(c_adj, _mm_set1_epi32(15));
    __m128i code_norm = _mm_or_si128(
        _mm_slli_epi32(c_adj, M_BITS),
        _mm_and_si128(n_adj, _mm_set1_epi32(7)));
    code_norm = _mm_blendv_epi8(code_norm, _mm_set1_epi32(0x7F), sat);
    __m128i code_sub = _mm_min_epi32(n32, _mm_set1_epi32(16));
    __m128i mag = _mm_blendv_epi8(code_norm, code_sub, is_sub32);
    __m128i neg32 = narrow64(_mm256_castpd_si256(neg_pd));
    __m128i code = _mm_or_si128(
        mag, _mm_and_si128(neg32, _mm_set1_epi32(0x80)));
    __m256d kill_pd = _mm256_or_pd(
        _mm256_cmp_pd(x, _mm256_setzero_pd(), _CMP_EQ_OQ),
        _mm256_cmp_pd(x, x, _CMP_UNORD_Q));
    code = _mm_andnot_si128(narrow64(_mm256_castpd_si256(kill_pd)), code);
    __m128i packed = _mm_shuffle_epi8(
        code, _mm_setr_epi8(0, 4, 8, 12, -1, -1, -1, -1, -1, -1, -1,
                            -1, -1, -1, -1, -1));
    uint32_t out4 = (uint32_t)_mm_cvtsi128_si32(packed);
    memcpy(dst, &out4, 4);
}

/* ---- differential sweep ------------------------------------------- */

static uint64_t splitmix(uint64_t z) {
    z += 0x9E3779B97F4A7C15ULL;
    z = (z ^ (z >> 30)) * 0xBF58476D1CE4E5B9ULL;
    z = (z ^ (z >> 27)) * 0x94D049BB133111EBULL;
    return z ^ (z >> 31);
}

static const float SWEEP_ALPHAS[] = {1.0f, 0.0625f, 3.7f, 117.0f};
#define N_ALPHAS (sizeof(SWEEP_ALPHAS) / sizeof(SWEEP_ALPHAS[0]))

typedef struct {
    uint64_t lo, hi;
    uint64_t checked, failures;
} SweepJob;

/* Check patterns [lo, hi); u draws: 0.5 (deterministic) and one
 * pattern-derived pseudo-random draw per element. */
static void sweep_range(SweepJob *j) {
    Fp8Params ps[N_ALPHAS];
    for (size_t a = 0; a < N_ALPHAS; a++)
        ps[a] = params_new(SWEEP_ALPHAS[a]);
    float xs[4];
    double us[4];
    uint8_t enc_v[4];
    float q_v[4];
    for (uint64_t base = j->lo; base < j->hi; base += 4) {
        for (int l = 0; l < 4; l++) {
            uint32_t b = (uint32_t)(base + l);
            memcpy(&xs[l], &b, 4);
        }
        for (int pass = 0; pass < 2; pass++) {
            for (int l = 0; l < 4; l++)
                us[l] = pass == 0
                    ? 0.5
                    : (double)(splitmix(base + l) >> 11)
                          * (1.0 / 9007199254740992.0);
            for (size_t a = 0; a < N_ALPHAS; a++) {
                const Fp8Params *p = &ps[a];
                encode4_avx2(p, xs, us, enc_v);
                memcpy(q_v, xs, sizeof(q_v));
                quantize4_avx2(p, q_v, us);
                for (int l = 0; l < 4; l++) {
                    uint8_t e0 = encode_scalar(p, xs[l], us[l]);
                    uint8_t e1 = encode_bf(p, xs[l], us[l]);
                    float q0 = quantize_scalar(p, xs[l], us[l]);
                    float q1 = quantize_bf(p, xs[l], us[l]);
                    uint32_t q0b, q1b, qvb;
                    memcpy(&q0b, &q0, 4);
                    memcpy(&q1b, &q1, 4);
                    memcpy(&qvb, &q_v[l], 4);
                    if (e0 != e1 || e0 != enc_v[l] || q0b != q1b
                        || q0b != qvb) {
                        if (j->failures < 16)
                            fprintf(stderr,
                                    "MISMATCH x=%08x alpha=%g u=%.17g "
                                    "enc: s=%02x bf=%02x v=%02x  "
                                    "quant: s=%08x bf=%08x v=%08x\n",
                                    (uint32_t)(base + l),
                                    (double)p->alpha, us[l], e0, e1,
                                    enc_v[l], q0b, q1b, qvb);
                        j->failures++;
                    }
                    j->checked++;
                }
            }
        }
    }
}

static void *sweep_thread(void *arg) {
    sweep_range((SweepJob *)arg);
    return NULL;
}

static int run_sweep(uint64_t lo, uint64_t hi) {
    long cores = sysconf(_SC_NPROCESSORS_ONLN);
    if (cores < 1) cores = 1;
    if (cores > 16) cores = 16;
    pthread_t th[16];
    SweepJob jobs[16];
    uint64_t span = (hi - lo + cores - 1) / cores;
    span = (span + 3) & ~3ULL; /* keep 4-lane alignment */
    int n = 0;
    for (uint64_t s = lo; s < hi; s += span, n++) {
        jobs[n].lo = s;
        jobs[n].hi = s + span < hi ? s + span : hi;
        jobs[n].checked = jobs[n].failures = 0;
        pthread_create(&th[n], NULL, sweep_thread, &jobs[n]);
    }
    uint64_t checked = 0, failures = 0;
    for (int i = 0; i < n; i++) {
        pthread_join(th[i], NULL);
        checked += jobs[i].checked;
        failures += jobs[i].failures;
    }
    printf("checked %llu (pattern, alpha, u) triples: %llu failures\n",
           (unsigned long long)checked, (unsigned long long)failures);
    return failures ? 1 : 0;
}

/* stratified: all exponents x a few mantissas x both signs (covers
 * ±0, ±inf, f32 subnormals and NaN payloads structurally), plus
 * ±4-ulp neighborhoods of every FP8 grid magnitude per sweep alpha
 * (subnormal band, mantissa-carry and saturation boundaries) — the
 * same strata as the Rust tier-1 subset in tests/exhaustive_fp8.rs */
static int run_stratified(void) {
    uint64_t checked = 0, failures = 0;
    for (uint32_t exp = 0; exp < 256; exp++) {
        for (int s = 0; s < 2; s++) {
            for (int m = 0; m < 64; m++) {
                uint32_t mant =
                    m < 32 ? (uint32_t)m * 0x3FFFF
                           : (uint32_t)splitmix(exp * 64 + m) & 0x7FFFFF;
                uint32_t b = ((uint32_t)s << 31) | (exp << 23) | mant;
                SweepJob j = {b & ~3u, (b & ~3u) + 4, 0, 0};
                sweep_range(&j);
                checked += j.checked;
                failures += j.failures;
            }
        }
    }
    for (size_t a = 0; a < N_ALPHAS; a++) {
        Fp8Params p = params_new(SWEEP_ALPHAS[a]);
        for (int code = 0; code < 0x80; code++) {
            /* decode the (non-negative) grid magnitude, as format.rs */
            int64_t e = (code >> 3) & 0x0F;
            double m = (double)(code & 7);
            float v = (float)(e == 0
                                  ? p.sub_scale * m
                                  : exp2((double)e - p.bias)
                                        * (1.0 + m / 8.0));
            uint32_t b;
            memcpy(&b, &v, 4);
            for (int sign = 0; sign < 2; sign++) {
                uint32_t c = (b - 4u) ^ ((uint32_t)sign << 31);
                uint32_t lo = c & ~3u;
                /* 4-aligned range covering bits-4 .. bits+4 */
                SweepJob j = {lo, lo + 12, 0, 0};
                sweep_range(&j);
                checked += j.checked;
                failures += j.failures;
            }
        }
    }
    printf("stratified: %llu triples, %llu failures\n",
           (unsigned long long)checked, (unsigned long long)failures);
    return failures ? 1 : 0;
}

/* ---- micro bench: encode throughput scalar vs bf vs avx2 ---------- */

#define BN (1 << 20)
static float BDATA[BN];
static uint8_t BOUT[BN];
static double BUS[BN];
static volatile uint64_t BSINK;

static double now_ns(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return ts.tv_sec * 1e9 + ts.tv_nsec;
}

static double bench_one(const char *name,
                        void (*enc)(const Fp8Params *, const float *,
                                    const double *, uint8_t *, size_t),
                        const Fp8Params *p) {
    double best = 1e300;
    for (int rep = 0; rep < 7; rep++) {
        double t0 = now_ns();
        enc(p, BDATA, BUS, BOUT, BN);
        double dt = now_ns() - t0;
        uint64_t acc = 0;
        for (int i = 0; i < BN; i += 4096) acc += BOUT[i];
        BSINK += acc;
        if (dt < best) best = dt;
    }
    printf("%-28s %8.2f ns/elem  %8.1f M/s\n", name, best / BN,
           BN / best * 1e3);
    return best;
}

static void enc_arm_scalar(const Fp8Params *p, const float *src,
                           const double *us, uint8_t *dst, size_t n) {
    for (size_t i = 0; i < n; i++) dst[i] = encode_scalar(p, src[i], us[i]);
}

static void enc_arm_bf(const Fp8Params *p, const float *src,
                       const double *us, uint8_t *dst, size_t n) {
    for (size_t i = 0; i < n; i++) dst[i] = encode_bf(p, src[i], us[i]);
}

static void enc_arm_avx2(const Fp8Params *p, const float *src,
                         const double *us, uint8_t *dst, size_t n) {
    size_t n4 = n & ~3ULL;
    for (size_t i = 0; i < n4; i += 4)
        encode4_avx2(p, src + i, us + i, dst + i);
    for (size_t i = n4; i < n; i++) dst[i] = encode_bf(p, src[i], us[i]);
}

static int run_bench(void) {
    /* realistic wire distribution: weights uniform in (-alpha, alpha)
     * — on the real uplink, alpha IS the clipping point, so saturated
     * early-outs are rare and every element pays the grid divide */
    Fp8Params p = params_new(1.0f);
    uint64_t seed = 7;
    for (int i = 0; i < BN; i++) {
        uint32_t b = (uint32_t)splitmix(seed + i);
        BDATA[i] = (float)((double)b * (1.0 / 2147483648.0) - 1.0);
        BUS[i] = (double)(splitmix(b) >> 11) * (1.0 / 9007199254740992.0);
    }
    double s = bench_one("encode/scalar", enc_arm_scalar, &p);
    double b = bench_one("encode/branchfree", enc_arm_bf, &p);
    double v = bench_one("encode/avx2", enc_arm_avx2, &p);
    printf("speedups: bf %.2fx  avx2 %.2fx\n", s / b, s / v);
    return 0;
}

int main(int argc, char **argv) {
    const char *mode = argc > 1 ? argv[1] : "stratified";
    if (!strcmp(mode, "bench")) return run_bench();
    if (!strcmp(mode, "stratified")) return run_stratified();
    if (!strcmp(mode, "exhaustive")) {
        uint64_t chunk = argc > 3 ? strtoull(argv[2], NULL, 10) : 0;
        uint64_t total = argc > 3 ? strtoull(argv[3], NULL, 10) : 1;
        uint64_t span = (1ULL << 32) / total;
        uint64_t lo = chunk * span;
        uint64_t hi = chunk + 1 == total ? (1ULL << 32) : lo + span;
        printf("exhaustive sweep patterns [%llu, %llu)\n",
               (unsigned long long)lo, (unsigned long long)hi);
        return run_sweep(lo, hi);
    }
    fprintf(stderr, "usage: %s stratified|exhaustive [chunk total]|bench\n",
            argv[0]);
    return 2;
}
