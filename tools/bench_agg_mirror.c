/* C mirror of rust/benches/round_agg.rs — seeds BENCH_agg_tree.json
 * when no Rust toolchain is available.
 *
 * Replicates the round-aggregation scale paths op-for-op:
 *   - agg: the canonical pairwise f64 accumulator
 *     (rust/src/coordinator/aggregate.rs — leaf per uplink, adjacent
 *     fragments merge iff equal length on a 2l boundary, right-fold
 *     at finish), flat vs a depth-2 tree with 16 mid-tier nodes whose
 *     partials are serialized/deserialized through a byte buffer the
 *     way forward_partial drives the wire codec. The per-uplink
 *     "decode" is a 256-entry LUT pass over 1-byte codes — the same
 *     table-lookup inner loop as decode_pooled; the full FP8 format
 *     math is benchmarked separately (BENCH_fp8_kernels.json).
 *   - sample: dense partial Fisher-Yates (O(K) index vector per
 *     draw) vs the sparse sampler (O(P) displacement map), same
 *     PCG32 `below` draw sequence (rust/src/fp8/rng.rs).
 *   - world: dense round-robin iid sharding at K=10^6 (a million
 *     resident shard structs) vs the virtualized order-only map plus
 *     a full cohort's on-demand shard materialization
 *     (rust/src/coordinator/cohort.rs).
 *
 * Build & run (repo root):
 *   gcc -O3 -o /tmp/agg_mirror tools/bench_agg_mirror.c -lm
 *   /tmp/agg_mirror            # writes BENCH_agg_tree.json
 *
 * `cargo bench --bench round_agg` overwrites the JSON with native
 * Rust numbers whenever a Rust toolchain is present.
 */

#include <math.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

/* ---- PCG32 (twin of rust/src/fp8/rng.rs) -------------------------- */

typedef struct { uint64_t state, inc; } Pcg32;

static uint64_t splitmix(uint64_t *s) {
    *s += 0x9E3779B97F4A7C15ULL;
    uint64_t z = *s;
    z = (z ^ (z >> 30)) * 0xBF58476D1CE4E5B9ULL;
    z = (z ^ (z >> 27)) * 0x94D049BB133111EBULL;
    return z ^ (z >> 31);
}

static inline uint32_t pcg_u32(Pcg32 *r) {
    uint64_t old = r->state;
    r->state = old * 6364136223846793005ULL + r->inc;
    uint32_t xs = (uint32_t)(((old >> 18) ^ old) >> 27);
    uint32_t rot = (uint32_t)(old >> 59);
    return (xs >> rot) | (xs << ((32 - rot) & 31));
}

static Pcg32 pcg_new(uint64_t seed, uint64_t stream) {
    uint64_t s = seed ^ ((stream << 17) | (stream >> 47));
    Pcg32 r;
    r.state = splitmix(&s);
    r.inc = splitmix(&s) | 1;
    pcg_u32(&r);
    return r;
}

static inline uint64_t pcg_u64(Pcg32 *r) {
    return ((uint64_t)pcg_u32(r) << 32) | pcg_u32(r);
}

static inline double pcg_f64(Pcg32 *r) {
    return (double)(pcg_u64(r) >> 11) * (1.0 / 9007199254740992.0);
}

static inline size_t pcg_below(Pcg32 *r, size_t bound) {
    return (size_t)(pcg_u64(r) % (uint64_t)bound);
}

/* ---- bench harness (twin of rust/src/util/bench.rs) --------------- */

typedef struct {
    const char *name;
    long iters;
    double median_ns, p10_ns, p90_ns;
} BResult;

static double now_ns(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return ts.tv_sec * 1e9 + ts.tv_nsec;
}

static int cmp_d(const void *a, const void *b) {
    double x = *(const double *)a, y = *(const double *)b;
    return (x > y) - (x < y);
}

#define MAX_SAMPLES 100000
static double SAMPLES[MAX_SAMPLES];

static BResult bench_run(const char *name, void (*f)(void),
                         double budget_ms) {
    double warm_end = now_ns() + budget_ms * 1e6 / 5.0;
    while (now_ns() < warm_end) f();
    long n = 0;
    double end = now_ns() + budget_ms * 1e6;
    while ((now_ns() < end || n < 5) && n < MAX_SAMPLES) {
        double t0 = now_ns();
        f();
        SAMPLES[n++] = now_ns() - t0;
    }
    qsort(SAMPLES, n, sizeof(double), cmp_d);
    BResult r;
    r.name = name;
    r.iters = n;
    r.median_ns = SAMPLES[(long)((n - 1) * 0.5)];
    r.p10_ns = SAMPLES[(long)((n - 1) * 0.1)];
    r.p90_ns = SAMPLES[(long)((n - 1) * 0.9)];
    printf("%-44s %12.0f %12.0f %12.0f  (ns, median/p10/p90)\n",
           r.name, r.median_ns, r.p10_ns, r.p90_ns);
    return r;
}

/* ---- canonical pairwise accumulator ------------------------------- */

#define DIM 64
#define WIDTH (DIM + 3) /* w | alpha | beta | loss */
#define NODES 16
#define MAXFRAG 64 /* O(log P) pending fragments; 64 covers 2^64 */

typedef struct {
    uint64_t next_pos;
    int n, nspare;
    uint64_t starts[MAXFRAG], lens[MAXFRAG];
    double *sums[MAXFRAG];
    double *spare[MAXFRAG];
} Acc;

static void acc_init(Acc *a, uint64_t start) {
    a->next_pos = start;
    a->n = 0;
    /* nspare persists across rounds: buffers recycle like Rust's */
}

static double *acc_leaf_buf(Acc *a) {
    if (a->nspare > 0) {
        double *v = a->spare[--a->nspare];
        memset(v, 0, WIDTH * sizeof(double));
        return v;
    }
    return calloc(WIDTH, sizeof(double));
}

static void acc_settle(Acc *a) {
    while (a->n >= 2) {
        uint64_t l1 = a->lens[a->n - 1], l0 = a->lens[a->n - 2];
        uint64_t s0 = a->starts[a->n - 2];
        if (l0 != l1 || s0 % (2 * l0) != 0) break;
        double *top = a->sums[a->n - 1], *into = a->sums[a->n - 2];
        for (int i = 0; i < WIDTH; i++) into[i] += top[i];
        a->spare[a->nspare++] = top;
        a->n--;
        a->lens[a->n - 1] = 2 * l0;
    }
}

static void acc_push_leaf(Acc *a, double *leaf) {
    a->starts[a->n] = a->next_pos;
    a->lens[a->n] = 1;
    a->sums[a->n++] = leaf;
    a->next_pos++;
    acc_settle(a);
}

static void acc_append_range(Acc *a, uint64_t start, uint64_t len,
                             double *sum) {
    a->starts[a->n] = start;
    a->lens[a->n] = len;
    a->sums[a->n++] = sum;
    a->next_pos = start + len;
    acc_settle(a);
}

static double acc_finish(Acc *a) {
    while (a->n > 1) {
        double *top = a->sums[a->n - 1], *into = a->sums[a->n - 2];
        for (int i = 0; i < WIDTH; i++) into[i] += top[i];
        a->spare[a->nspare++] = top;
        a->n--;
    }
    double out = 0.0;
    if (a->n == 1) {
        out = a->sums[0][WIDTH - 1]; /* the loss slot */
        a->spare[a->nspare++] = a->sums[0];
        a->n = 0;
    }
    return out;
}

/* ---- uplink pool: pre-"encoded" codes + decode LUT ----------------- */

#define POOL_N 8
static uint8_t POOL_CODES[POOL_N][DIM];
static float POOL_ALPHA[POOL_N], POOL_BETA[POOL_N], POOL_LOSS[POOL_N];
static float LUT[256];
static Acc FLAT_ACC, MID_ACC, ROOT_ACC;
static size_t BENCH_P;
static double SINK;

static void fold_one(Acc *a, int pi, double kw) {
    /* decode (LUT pass, as decode_pooled's inner loop) + weighted leaf */
    double *leaf = acc_leaf_buf(a);
    const uint8_t *codes = POOL_CODES[pi];
    for (int i = 0; i < DIM; i++)
        leaf[i] = kw * (double)LUT[codes[i]];
    leaf[DIM] = kw * (double)POOL_ALPHA[pi];
    leaf[DIM + 1] = kw * (double)POOL_BETA[pi];
    leaf[DIM + 2] = kw * (double)POOL_LOSS[pi];
    acc_push_leaf(a, leaf);
}

static void flat_round(void) {
    size_t p = BENCH_P;
    double kw = 1.0 / (double)p; /* n_k = 1 each, m_t = P */
    acc_init(&FLAT_ACC, 0);
    for (size_t i = 0; i < p; i++)
        fold_one(&FLAT_ACC, (int)(i % POOL_N), kw);
    SINK += acc_finish(&FLAT_ACC);
}

/* serialize a mid accumulator's fragments (f64 bit patterns through a
 * byte buffer, 16 B range header + 28 B meta, as encode_partial) and
 * absorb them into the root */
static uint8_t WIREBUF[28 + MAXFRAG * (16 + WIDTH * 8)];

static void forward_into_root(Acc *mid) {
    uint8_t *w = WIREBUF;
    memcpy(w, &mid->next_pos, 8); /* stand-in meta */
    w += 28;
    for (int i = 0; i < mid->n; i++) {
        memcpy(w, &mid->starts[i], 8);
        memcpy(w + 8, &mid->lens[i], 8);
        memcpy(w + 16, mid->sums[i], WIDTH * 8);
        w += 16 + WIDTH * 8;
    }
    /* decode side */
    const uint8_t *rd = WIREBUF + 28;
    int nfrag = mid->n;
    for (int i = 0; i < nfrag; i++) {
        uint64_t s, l;
        memcpy(&s, rd, 8);
        memcpy(&l, rd + 8, 8);
        double *sum = acc_leaf_buf(&ROOT_ACC);
        memcpy(sum, rd + 16, WIDTH * 8);
        rd += 16 + WIDTH * 8;
        acc_append_range(&ROOT_ACC, s, l, sum);
    }
    /* retire the mid's buffers */
    for (int i = 0; i < mid->n; i++)
        mid->spare[mid->nspare++] = mid->sums[i];
    mid->n = 0;
}

static void tree_round(void) {
    size_t p = BENCH_P;
    double kw = 1.0 / (double)p;
    acc_init(&ROOT_ACC, 0);
    size_t g = NODES < p ? NODES : p;
    size_t base = p / g, extra = p % g, lo = 0;
    for (size_t ni = 0; ni < g; ni++) {
        size_t len = base + (ni < extra ? 1 : 0);
        acc_init(&MID_ACC, lo);
        for (size_t i = lo; i < lo + len; i++)
            fold_one(&MID_ACC, (int)(i % POOL_N), kw);
        forward_into_root(&MID_ACC);
        lo += len;
    }
    SINK += acc_finish(&ROOT_ACC);
}

static void flat_100(void) { BENCH_P = 100; flat_round(); }
static void tree_100(void) { BENCH_P = 100; tree_round(); }
static void flat_10k(void) { BENCH_P = 10000; flat_round(); }
static void tree_10k(void) { BENCH_P = 10000; tree_round(); }
static void flat_1m(void) { BENCH_P = 1000000; flat_round(); }
static void tree_1m(void) { BENCH_P = 1000000; tree_round(); }

/* ---- cohort sampling: dense vs sparse Fisher-Yates ----------------- */

#define K_POP 1000000
#define COHORT 256
static size_t DENSE_IDX[K_POP];
static size_t OUT_IDS[COHORT];

static void sample_dense(void) {
    Pcg32 r = pcg_new(9, 1);
    for (size_t i = 0; i < K_POP; i++) DENSE_IDX[i] = i;
    for (size_t i = 0; i < COHORT; i++) {
        size_t j = i + pcg_below(&r, K_POP - i);
        size_t t = DENSE_IDX[i];
        DENSE_IDX[i] = DENSE_IDX[j];
        DENSE_IDX[j] = t;
        OUT_IDS[i] = DENSE_IDX[i];
    }
    SINK += (double)OUT_IDS[COHORT - 1];
}

/* open-addressing map, 2*k slots rounded up to a power of two — the
 * displacement map of sample_distinct_sparse */
#define MAP_CAP 1024 /* >= 2 * COHORT, power of two */
static uint64_t MAP_KEY[MAP_CAP];
static size_t MAP_VAL[MAP_CAP];
static uint8_t MAP_USED[MAP_CAP];

static size_t map_get(uint64_t key, size_t dflt) {
    size_t h = (size_t)(key * 0x9E3779B97F4A7C15ULL) & (MAP_CAP - 1);
    while (MAP_USED[h]) {
        if (MAP_KEY[h] == key) return MAP_VAL[h];
        h = (h + 1) & (MAP_CAP - 1);
    }
    return dflt;
}

static void map_put(uint64_t key, size_t val) {
    size_t h = (size_t)(key * 0x9E3779B97F4A7C15ULL) & (MAP_CAP - 1);
    while (MAP_USED[h] && MAP_KEY[h] != key)
        h = (h + 1) & (MAP_CAP - 1);
    MAP_USED[h] = 1;
    MAP_KEY[h] = key;
    MAP_VAL[h] = val;
}

static void sample_sparse(void) {
    Pcg32 r = pcg_new(9, 1);
    memset(MAP_USED, 0, sizeof(MAP_USED));
    for (size_t i = 0; i < COHORT; i++) {
        size_t j = i + pcg_below(&r, K_POP - i);
        size_t vj = map_get(j, j);
        size_t vi = map_get(i, i);
        map_put(j, vi);
        OUT_IDS[i] = vj;
    }
    SINK += (double)OUT_IDS[COHORT - 1];
}

/* ---- world build: dense shard vecs vs virtual order map ------------ */

#define N_TRAIN 50000
typedef struct { size_t len, cap; size_t *v; } Shard;
static Shard *SHARDS; /* K_POP headers */
static size_t ORDER[N_TRAIN];

static void iid_order(Pcg32 *r) {
    for (size_t i = 0; i < N_TRAIN; i++) ORDER[i] = i;
    for (size_t i = N_TRAIN - 1; i >= 1; i--) {
        size_t j = pcg_below(r, i + 1);
        size_t t = ORDER[i];
        ORDER[i] = ORDER[j];
        ORDER[j] = t;
    }
}

static void world_dense(void) {
    Pcg32 r = pcg_new(5, 2);
    iid_order(&r);
    memset(SHARDS, 0, K_POP * sizeof(Shard));
    for (size_t i = 0; i < N_TRAIN; i++) {
        Shard *s = &SHARDS[i % K_POP];
        if (s->len == s->cap) {
            s->cap = s->cap ? s->cap * 2 : 4;
            s->v = realloc(s->v, s->cap * sizeof(size_t));
        }
        s->v[s->len++] = ORDER[i];
    }
    SINK += (double)SHARDS[0].len;
    for (size_t i = 0; i < K_POP; i++) {
        free(SHARDS[i].v);
        SHARDS[i].v = NULL;
    }
}

static size_t COHORT_SHARD[N_TRAIN];

static void world_virtual(void) {
    Pcg32 r = pcg_new(5, 2);
    iid_order(&r); /* the only O(n) state the virtual map holds */
    /* plus the whole per-round cost it must cover: sample a cohort
     * and materialize exactly its shards */
    Pcg32 sr = pcg_new(6, 3);
    memset(MAP_USED, 0, sizeof(MAP_USED));
    for (size_t i = 0; i < COHORT; i++) {
        size_t j = i + pcg_below(&sr, K_POP - i);
        size_t vj = map_get(j, j);
        size_t vi = map_get(i, i);
        map_put(j, vi);
        OUT_IDS[i] = vj;
    }
    size_t touched = 0;
    for (size_t i = 0; i < COHORT; i++) {
        for (size_t s = OUT_IDS[i]; s < N_TRAIN; s += K_POP)
            COHORT_SHARD[touched++] = ORDER[s];
    }
    SINK += (double)touched;
}

/* ---- JSON emit (schema of util::bench::BenchJson) ----------------- */

static void emit_result(FILE *f, const BResult *r, int items, int first) {
    fprintf(f, "%s\n    {\"name\": \"%s\", \"iters\": %ld, "
               "\"median_ns\": %.1f, \"p10_ns\": %.1f, \"p90_ns\": %.1f",
            first ? "" : ",", r->name, r->iters, r->median_ns, r->p10_ns,
            r->p90_ns);
    if (items)
        fprintf(f, ", \"throughput_per_s\": %.1f",
                (double)items / (r->median_ns * 1e-9));
    fprintf(f, "}");
}

int main(void) {
    Pcg32 r = pcg_new(42, 7);
    for (int c = 0; c < POOL_N; c++) {
        for (int i = 0; i < DIM; i++)
            POOL_CODES[c][i] = (uint8_t)(pcg_u32(&r) & 0xFF);
        POOL_ALPHA[c] = 0.9f + 0.05f * (float)c;
        POOL_BETA[c] = 2.0f;
        POOL_LOSS[c] = 0.5f + 0.1f * (float)c;
    }
    for (int i = 0; i < 256; i++)
        LUT[i] = (float)i * (1.0f / 128.0f) - 1.0f;
    FLAT_ACC.nspare = MID_ACC.nspare = ROOT_ACC.nspare = 0;
    FLAT_ACC.n = MID_ACC.n = ROOT_ACC.n = 0;
    SHARDS = calloc(K_POP, sizeof(Shard));

    printf("dim=%d nodes=%d K=%d cohort=%d n_train=%d\n\n", DIM, NODES,
           K_POP, COHORT, N_TRAIN);
    BResult f100 = bench_run("agg/flat P=100", flat_100, 120);
    BResult t100 = bench_run("agg/tree:16 P=100", tree_100, 120);
    BResult f10k = bench_run("agg/flat P=10000", flat_10k, 400);
    BResult t10k = bench_run("agg/tree:16 P=10000", tree_10k, 400);
    BResult f1m = bench_run("agg/flat P=1000000", flat_1m, 3000);
    BResult t1m = bench_run("agg/tree:16 P=1000000", tree_1m, 3000);
    BResult sd =
        bench_run("sample/dense K=1000000 P=256", sample_dense, 200);
    BResult ss =
        bench_run("sample/sparse K=1000000 P=256", sample_sparse, 200);
    BResult wd =
        bench_run("world/dense_iid K=1000000", world_dense, 2000);
    BResult wv = bench_run("world/virtual_iid+cohort K=1000000",
                           world_virtual, 400);

    double sp_sample = sd.median_ns / ss.median_ns;
    double sp_world = wd.median_ns / wv.median_ns;
    printf("\nper-uplink fold: P=100 flat %.0f/tree %.0f ns; "
           "P=10k flat %.0f/tree %.0f ns; P=1M flat %.0f/tree %.0f ns\n",
           f100.median_ns / 100, t100.median_ns / 100,
           f10k.median_ns / 1e4, t10k.median_ns / 1e4,
           f1m.median_ns / 1e6, t1m.median_ns / 1e6);
    printf("speedups: sampling dense->sparse %.1fx  world "
           "dense->virtual %.1fx\n",
           sp_sample, sp_world);

    FILE *f = fopen("BENCH_agg_tree.json", "w");
    if (!f) { perror("BENCH_agg_tree.json"); return 1; }
    fprintf(f, "{\n  \"bench\": \"agg_tree\",\n");
    fprintf(f,
            "  \"provenance\": \"tools/bench_agg_mirror.c (gcc -O3 C "
            "mirror of rust/benches/round_agg.rs, op-for-op: same "
            "canonical pairwise f64 accumulator, PCG32 draw sequences, "
            "fragment serialization and shard layouts; build container "
            "lacks a Rust toolchain). The per-uplink decode here is the "
            "256-entry LUT inner loop only — the full FP8 format math "
            "is measured in BENCH_fp8_kernels.json — so absolute "
            "latencies understate a full round slightly while the "
            "flat-vs-tree and dense-vs-sparse ratios transfer. "
            "Regenerate natively with `cargo bench --bench "
            "round_agg`.\",\n");
    fprintf(f, "  \"config\": {\"dim\": \"%d\", \"tree_nodes\": \"%d\", "
               "\"k_population\": \"%d\", \"cohort\": \"%d\", "
               "\"n_train\": \"%d\"},\n",
            DIM, NODES, K_POP, COHORT, N_TRAIN);
    fprintf(f, "  \"results\": [");
    emit_result(f, &f100, DIM, 1);
    emit_result(f, &t100, DIM, 0);
    emit_result(f, &f10k, DIM, 0);
    emit_result(f, &t10k, DIM, 0);
    emit_result(f, &f1m, DIM, 0);
    emit_result(f, &t1m, DIM, 0);
    emit_result(f, &sd, 0, 0);
    emit_result(f, &ss, 0, 0);
    emit_result(f, &wd, 0, 0);
    emit_result(f, &wv, 0, 0);
    fprintf(f, "\n  ],\n  \"speedups\": {\n");
    fprintf(f, "    \"agg_flat_over_tree_p100\": %.3f,\n",
            f100.median_ns / t100.median_ns);
    fprintf(f, "    \"agg_flat_over_tree_p10000\": %.3f,\n",
            f10k.median_ns / t10k.median_ns);
    fprintf(f, "    \"agg_flat_over_tree_p1000000\": %.3f,\n",
            f1m.median_ns / t1m.median_ns);
    fprintf(f, "    \"sample_dense_over_sparse\": %.3f,\n", sp_sample);
    fprintf(f, "    \"world_dense_over_virtual\": %.3f\n", sp_world);
    fprintf(f, "  }\n}\n");
    fclose(f);
    printf("\nwrote BENCH_agg_tree.json (SINK %.1f)\n", SINK);
    return 0;
}
