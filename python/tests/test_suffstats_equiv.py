"""Sufficient-statistics Eq. (5) scorer == naive rescan, against the
Python quantizer oracle.

The Rust ServerOptimize alpha search precomputes per-element client
statistics (W = sum_k kw_k, S_i = sum_k kw_k*c_ki, T_i = sum_k
kw_k*c_ki^2) so each alpha candidate costs O(d) instead of O(K*d):

    sum_i sum_k kw_k (q_i - c_ki)^2
  = sum_i q_i^2 W - 2 q_i S_i + T_i

This test pins the algebraic identity on `ref.quantize_np` (the same
oracle the Rust codec is golden-tested against), mirroring the Rust
property test `prop_suffstats_mse_matches_naive`.
"""

import numpy as np
import pytest

from compile.kernels import ref


RNG = np.random.default_rng(7)


@pytest.mark.parametrize("k_clients", [1, 3, 8])
@pytest.mark.parametrize("alpha", [0.3, 1.0, 4.7])
def test_suffstats_equals_naive(k_clients, alpha):
    d = 257
    w = (RNG.random(d) - 0.5) * 2.0
    clients = (RNG.random((k_clients, d)) - 0.5) * 2.0
    kw = RNG.random(k_clients)
    us = RNG.random(d)
    q = ref.quantize_np(w.astype(np.float32), alpha, us).astype(
        np.float64
    )
    naive = float((kw[:, None] * (q[None, :] - clients) ** 2).sum())
    W = kw.sum()
    S = (kw[:, None] * clients).sum(axis=0)
    T = (kw[:, None] * clients**2).sum(axis=0)
    fast = float((q * q * W - 2.0 * q * S + T).sum())
    assert abs(naive - fast) <= 1e-9 * (1.0 + abs(naive))


def test_suffstats_grid_search_picks_same_alpha():
    d = 400
    w = (RNG.random(d) - 0.5) * 2.0
    clients = (RNG.random((4, d)) - 0.5) * 2.0
    kw = np.full(4, 0.25)
    us = RNG.random(d)
    cands = np.linspace(0.4, 1.6, 25)
    W = kw.sum()
    S = (kw[:, None] * clients).sum(axis=0)
    T = (kw[:, None] * clients**2).sum(axis=0)
    naive_scores, fast_scores = [], []
    for a in cands:
        q = ref.quantize_np(w.astype(np.float32), float(a), us).astype(
            np.float64
        )
        naive_scores.append(
            float((kw[:, None] * (q[None, :] - clients) ** 2).sum())
        )
        fast_scores.append(float((q * q * W - 2.0 * q * S + T).sum()))
    assert int(np.argmin(naive_scores)) == int(np.argmin(fast_scores))
