"""Number-format properties — the empirically checkable content of the
paper's Appendix A-C lemmas.

  * outputs lie on the FP8(alpha) grid
  * the grid is symmetric and its bin size grows monotonically away from
    zero (precondition of Lemma 5)
  * Q_rand is unbiased (Lemma 3); Q_det is biased but smaller-error
    (Remark 4/5)
  * variance bound E|r|^2 <= S|x| (Lemma 4)
  * max code decodes to alpha exactly
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


RNG = np.random.default_rng(42)


def _grid(alpha):
    return ref.grid_points(alpha)


class TestGrid:
    @pytest.mark.parametrize("alpha", [0.1, 1.0, 2.5, 33.0])
    def test_top_code_is_alpha(self, alpha):
        g = _grid(alpha)
        assert np.isclose(g[-1], alpha, rtol=1e-12)

    @pytest.mark.parametrize("alpha", [0.5, 1.0, 7.3])
    def test_bin_size_monotone(self, alpha):
        """Bin size must increase monotonically from zero — the condition
        under which the paper's Lemma 5 decomposition holds for FP8."""
        g = _grid(alpha)
        d = np.diff(g)
        assert np.all(np.diff(d) >= -1e-15 * alpha)

    @pytest.mark.parametrize("alpha", [1.0, 3.0])
    def test_grid_membership(self, alpha):
        g = _grid(alpha).astype(np.float32)
        x = (RNG.normal(size=2000) * alpha * 0.6).astype(np.float32)
        q = ref.quantize_np(x, np.float32(alpha), np.full(x.shape, 0.5))
        for v in np.abs(q):
            assert np.any(np.isclose(v, g, rtol=2e-6, atol=1e-30)), v

    def test_grid_has_128_nonneg_points(self):
        assert len(_grid(1.0)) == 128  # 16 exponents x 8 mantissas

    def test_det_idempotent(self):
        """Q(Q(x)) == Q(x): grid points are fixed points."""
        x = (RNG.normal(size=500) * 2.0).astype(np.float32)
        u = np.full(x.shape, 0.5)
        q1 = ref.quantize_np(x, np.float32(2.0), u)
        q2 = ref.quantize_np(q1, np.float32(2.0), u)
        np.testing.assert_allclose(q1, q2, rtol=1e-6)


class TestLemmas:
    def test_lemma3_unbiased(self):
        """E[Q_rand(x)] == x for in-range x (stochastic rounding)."""
        n_draw = 4000
        x = (RNG.normal(size=32) * 0.3).astype(np.float32)
        alpha = np.float32(1.0)
        xs = np.broadcast_to(x, (n_draw, 32))
        us = RNG.random(size=(n_draw, 32))
        qs = ref.quantize_np(xs, alpha, us).astype(np.float64)
        err = qs.mean(axis=0) - x
        # std of the mean ~ binsize/sqrt(n); binsize <= 2^-3 * |x| * 2
        tol = 4 * (np.abs(x) * 2 ** -3 + 2.0 ** -10) / np.sqrt(n_draw)
        assert np.all(np.abs(err) < tol + 1e-6)

    def test_det_is_biased(self):
        """Q_det has nonzero mean error on a generic point cloud."""
        x = np.full(1000, 0.3711, np.float32)
        q = ref.quantize_np(x, np.float32(1.0), np.full(1000, 0.5))
        assert abs(float(q.mean()) - 0.3711) > 1e-4

    def test_remark4_det_smaller_error(self):
        """deterministic per-sample |error| <= stochastic expected
        |error| (motivates det QAT during training)."""
        x = (RNG.normal(size=5000) * 0.5).astype(np.float32)
        alpha = np.float32(1.5)
        qd = ref.quantize_np(x, alpha, np.full(x.shape, 0.5))
        ed = np.abs(qd.astype(np.float64) - x).mean()
        us = RNG.random(size=(50,) + x.shape)
        qr = ref.quantize_np(np.broadcast_to(x, us.shape), alpha, us)
        er = np.abs(qr.astype(np.float64) - x).mean()
        assert ed <= er + 1e-9

    def test_lemma4_variance_bound(self):
        """E|r_Qrand(x)|^2 <= S |x| element-wise, S = max scale."""
        alpha = 1.0
        g = _grid(alpha)
        s_max = np.max(np.diff(g))  # largest bin == largest scale
        x = (RNG.normal(size=200) * 0.5).astype(np.float32)
        x = np.clip(x, -alpha, alpha)
        us = RNG.random(size=(3000, 200))
        qs = ref.quantize_np(np.broadcast_to(x, us.shape),
                             np.float32(alpha), us).astype(np.float64)
        var = ((qs - x) ** 2).mean(axis=0)
        assert np.all(var <= s_max * np.abs(x) * 1.15 + 1e-9)

    def test_scale_bounded_by_alpha_fraction(self):
        """Assumption 3: scales uniformly bounded; for FP8(alpha) the
        largest scale is alpha * 2^-m / (2 - 2^-m)."""
        alpha = 2.0
        g = _grid(alpha)
        s_theory = alpha * 2.0 ** -3 / (2 - 2.0 ** -3)
        assert np.isclose(np.max(np.diff(g)), s_theory, rtol=1e-9)


@settings(max_examples=30, deadline=None)
@given(alpha=st.floats(min_value=0.05, max_value=50.0),
       seed=st.integers(0, 2**31 - 1))
def test_quantize_error_below_one_bin(alpha, seed):
    """|Q(x) - x| < bin(x) for unclipped x, any rounding draw."""
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=64) * alpha * 0.5).astype(np.float32)
    x = np.clip(x, -alpha * 0.99, alpha * 0.99)
    u = rng.random(size=64)
    q = ref.quantize_np(x, np.float32(alpha), u).astype(np.float64)
    b = 2.0**ref.E_BITS - np.log2(alpha) + ref.LOG2_TOP - 1.0
    absx = np.maximum(np.abs(x.astype(np.float64)), 1e-300)
    c = np.floor(np.log2(absx) + b)
    s = np.exp2(np.where(c > 1, c, 1.0) - b - ref.M_BITS)
    assert np.all(np.abs(q - x) <= s * (1 + 1e-9))
