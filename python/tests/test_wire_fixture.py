"""Cross-checks of the committed golden wire fixture against the
Python mirror of the v1 frame layout (``tools/gen_wire_fixture.py``).

The authoritative implementation is ``rust/src/net/{frame,codec}.rs``,
pinned by ``rust/tests/golden_wire.rs``; these tests make sure the
committed fixture file stays byte-identical to the documented spec, so
a regeneration with a drifted mirror cannot slip through unnoticed.
"""

import importlib.util
import os
import struct
import zlib

import pytest

HERE = os.path.dirname(__file__)
REPO = os.path.join(HERE, "..", "..")
FIXTURE = os.path.join(REPO, "rust", "tests", "fixtures", "wire_v1.bin")


def _mirror():
    spec = importlib.util.spec_from_file_location(
        "gen_wire_fixture",
        os.path.join(REPO, "tools", "gen_wire_fixture.py"),
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def mirror():
    return _mirror()


@pytest.fixture(scope="module")
def fixture_bytes():
    with open(FIXTURE, "rb") as f:
        return f.read()


def test_fixture_matches_mirror(mirror, fixture_bytes):
    job, outcome = mirror.golden_frames()
    assert fixture_bytes == job + outcome, (
        "wire_v1.bin no longer matches the spec mirror — regenerate with "
        "tools/gen_wire_fixture.py ONLY alongside a WIRE_VERSION bump"
    )


def test_frame_envelopes_are_well_formed(mirror, fixture_bytes):
    buf = fixture_bytes
    kinds = []
    while buf:
        magic, version, kind, flags, body_len, crc = struct.unpack_from(
            "<4sHBBII", buf
        )
        assert magic == mirror.MAGIC
        assert version == mirror.VERSION
        assert flags == 0
        body = buf[16:16 + body_len]
        assert len(body) == body_len
        assert zlib.crc32(body) & 0xFFFFFFFF == crc
        kinds.append(kind)
        buf = buf[16 + body_len:]
    assert kinds == [mirror.KIND_JOB, mirror.KIND_OUTCOME]


def test_overhead_constants(mirror):
    """The CommStats framing constants in coordinator/comm.rs charge
    exactly these overheads; if the layout grows, both must move."""
    assert mirror.JOB_FRAME_OVERHEAD == 68
    assert mirror.OUTCOME_FRAME_OVERHEAD == 53
    job, outcome = mirror.golden_frames()
    assert len(job) == mirror.wire_bytes(*mirror.CANON_DOWN) + 68
    # the outcome golden carries a 2-element EF block: 4 (len) + 8 (f32s)
    assert len(outcome) == mirror.wire_bytes(*mirror.CANON_UP) + 53 + 12
