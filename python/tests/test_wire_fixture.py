"""Cross-checks of the committed golden wire fixture against the
Python mirror of the v1 frame layout (``tools/gen_wire_fixture.py``).

The authoritative implementation is ``rust/src/net/{frame,codec}.rs``,
pinned by ``rust/tests/golden_wire.rs``; these tests make sure the
committed fixture file stays byte-identical to the documented spec, so
a regeneration with a drifted mirror cannot slip through unnoticed.
"""

import importlib.util
import json
import math
import os
import struct
import zlib

import pytest

HERE = os.path.dirname(__file__)
REPO = os.path.join(HERE, "..", "..")
FIXTURE = os.path.join(REPO, "rust", "tests", "fixtures", "wire_v1.bin")
EDGE_FIXTURE = os.path.join(
    REPO, "rust", "tests", "fixtures", "fp8_edges_v1.json"
)


def _mirror():
    spec = importlib.util.spec_from_file_location(
        "gen_wire_fixture",
        os.path.join(REPO, "tools", "gen_wire_fixture.py"),
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def mirror():
    return _mirror()


@pytest.fixture(scope="module")
def fixture_bytes():
    with open(FIXTURE, "rb") as f:
        return f.read()


def test_fixture_matches_mirror(mirror, fixture_bytes):
    job, outcome = mirror.golden_frames()
    assert fixture_bytes == job + outcome, (
        "wire_v1.bin no longer matches the spec mirror — regenerate with "
        "tools/gen_wire_fixture.py ONLY alongside a WIRE_VERSION bump"
    )


def test_frame_envelopes_are_well_formed(mirror, fixture_bytes):
    buf = fixture_bytes
    kinds = []
    while buf:
        magic, version, kind, flags, body_len, crc = struct.unpack_from(
            "<4sHBBII", buf
        )
        assert magic == mirror.MAGIC
        assert version == mirror.VERSION
        assert flags == 0
        body = buf[16:16 + body_len]
        assert len(body) == body_len
        assert zlib.crc32(body) & 0xFFFFFFFF == crc
        kinds.append(kind)
        buf = buf[16 + body_len:]
    assert kinds == [mirror.KIND_JOB, mirror.KIND_OUTCOME]


def test_overhead_constants(mirror):
    """The CommStats framing constants in coordinator/comm.rs charge
    exactly these overheads; if the layout grows, both must move."""
    assert mirror.JOB_FRAME_OVERHEAD == 68
    assert mirror.OUTCOME_FRAME_OVERHEAD == 53
    job, outcome = mirror.golden_frames()
    assert len(job) == mirror.wire_bytes(*mirror.CANON_DOWN) + 68
    # the outcome golden carries a 2-element EF block: 4 (len) + 8 (f32s)
    assert len(outcome) == mirror.wire_bytes(*mirror.CANON_UP) + 53 + 12


# ---- FP8 edge-code fixture (kernel byte output, not just framing) ----


@pytest.fixture(scope="module")
def edge_fixture():
    with open(EDGE_FIXTURE) as f:
        return json.load(f)


def test_edge_fixture_matches_mirror(mirror, edge_fixture):
    """The committed edge codes must equal a fresh mirror run, so a
    regeneration with a drifted value-mapping mirror cannot slip
    through unnoticed (the Rust side pins the same bytes against its
    oracle and every kernel in rust/tests/golden_fp8.rs)."""
    assert edge_fixture == mirror.fp8_edge_fixture()


def test_edge_fixture_covers_the_hostile_classes(edge_fixture):
    """Structural coverage floor: each case must include NaN payloads,
    both infinities, both zeros, f32 subnormals and saturating inputs,
    and every case's codes must be valid bytes."""
    assert edge_fixture["m"] == 3 and edge_fixture["e"] == 4
    alphas = {c["alpha"] for c in edge_fixture["cases"]}
    assert len(alphas) >= 4
    for case in edge_fixture["cases"]:
        bits = case["x_bits"]
        codes = case["codes"]
        assert len(bits) == len(codes)
        assert all(0 <= c <= 0xFF for c in codes)
        xs = [struct.unpack("<f", struct.pack("<I", b))[0] for b in bits]
        assert any(math.isnan(x) for x in xs)
        assert any(math.isinf(x) and x > 0 for x in xs)
        assert any(math.isinf(x) and x < 0 for x in xs)
        assert 0x00000000 in bits and 0x80000000 in bits
        assert any(0 < b < 0x00800000 for b in bits)  # f32 subnormal
        assert any(
            math.isfinite(x) and abs(x) >= 2.0 * case["alpha"]
            for x in xs
        )
        # NaN encodes to 0, infinities saturate to +-alpha top code
        for b, c in zip(bits, codes):
            x = struct.unpack("<f", struct.pack("<I", b))[0]
            if math.isnan(x):
                assert c == 0
            elif math.isinf(x):
                assert c == (0xFF if x < 0 else 0x7F)


def test_edge_fixture_mirror_math_is_f64_exact(mirror):
    """Spot-check the mirror against hand-derived facts: the top code
    decodes to ~alpha, code 0 to 0, and deterministic encode of alpha
    saturates to the top code."""
    for alpha in [1.0, 0.0625, 3.7, 117.0]:
        m = mirror.Fp8Mirror(alpha)
        assert m.decode(0) == 0.0
        assert abs(m.decode(0x7F) - alpha) <= 1e-6 * alpha
        assert m.encode(alpha, 0.5) == 0x7F
        assert m.encode(-alpha, 0.5) == 0xFF
        assert m.encode(float("nan"), 0.5) == 0
