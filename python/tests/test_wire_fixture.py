"""Cross-checks of the committed golden wire fixtures against the
Python mirror of the v2 frame layout (``tools/gen_wire_fixture.py``)
— and of the frozen v1 fixture against the frozen v1 mirror.

The authoritative implementation is ``rust/src/net/{frame,codec}.rs``,
pinned by ``rust/tests/golden_wire.rs``; these tests make sure the
committed fixture files stay byte-identical to the documented spec, so
a regeneration with a drifted mirror cannot slip through unnoticed.
"""

import importlib.util
import json
import math
import os
import struct
import zlib

import pytest

HERE = os.path.dirname(__file__)
REPO = os.path.join(HERE, "..", "..")
FIXTURE_V2 = os.path.join(
    REPO, "rust", "tests", "fixtures", "wire_v2.bin"
)
FIXTURE_V1 = os.path.join(
    REPO, "rust", "tests", "fixtures", "wire_v1.bin"
)
EDGE_FIXTURE = os.path.join(
    REPO, "rust", "tests", "fixtures", "fp8_edges_v1.json"
)
SNAP_FIXTURE_V2 = os.path.join(
    REPO, "rust", "tests", "fixtures", "snapshot_v2.bin"
)
SNAP_FIXTURE_V1 = os.path.join(
    REPO, "rust", "tests", "fixtures", "snapshot_v1.bin"
)
SNAP_FIXTURE_V0 = os.path.join(
    REPO, "rust", "tests", "fixtures", "snapshot_v0.bin"
)


def _mirror():
    spec = importlib.util.spec_from_file_location(
        "gen_wire_fixture",
        os.path.join(REPO, "tools", "gen_wire_fixture.py"),
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def mirror():
    return _mirror()


@pytest.fixture(scope="module")
def fixture_bytes():
    with open(FIXTURE_V2, "rb") as f:
        return f.read()


@pytest.fixture(scope="module")
def fixture_v1_bytes():
    with open(FIXTURE_V1, "rb") as f:
        return f.read()


def test_fixture_matches_mirror(mirror, fixture_bytes):
    job, outcome, heartbeat, heartbeat_ack, partial = (
        mirror.golden_frames()
    )
    stream = job + outcome + heartbeat + heartbeat_ack + partial
    assert fixture_bytes == stream, (
        "wire_v2.bin no longer matches the spec mirror — regenerate "
        "with tools/gen_wire_fixture.py ONLY alongside a WIRE_VERSION "
        "bump"
    )


def test_frozen_v1_fixture_matches_frozen_mirror(
    mirror, fixture_v1_bytes
):
    """wire_v1.bin is the version-skew probe: a v2 build must reject
    it with the typed VersionMismatch (pinned on the Rust side), so
    its bytes must never drift."""
    job, outcome = mirror.golden_frames_v1()
    assert fixture_v1_bytes == job + outcome, (
        "wire_v1.bin drifted — the frozen v1 fixture must stay "
        "byte-identical forever"
    )
    # and it really is a v1 stream
    assert struct.unpack_from("<H", fixture_v1_bytes, 4)[0] == 1
    assert mirror.VERSION == 2


def test_frame_envelopes_are_well_formed(mirror, fixture_bytes):
    buf = fixture_bytes
    kinds = []
    while buf:
        magic, version, kind, flags, body_len, crc = struct.unpack_from(
            "<4sHBBII", buf
        )
        assert magic == mirror.MAGIC
        assert version == mirror.VERSION
        assert flags == 0
        body = buf[16:16 + body_len]
        assert len(body) == body_len
        assert zlib.crc32(body) & 0xFFFFFFFF == crc
        kinds.append(kind)
        buf = buf[16 + body_len:]
    assert kinds == [
        mirror.KIND_JOB,
        mirror.KIND_OUTCOME,
        mirror.KIND_HEARTBEAT,
        mirror.KIND_HEARTBEAT_ACK,
        mirror.KIND_PARTIAL,
    ]


def test_job_and_outcome_open_with_the_multiplexing_ids(
    mirror, fixture_bytes
):
    """v2 contract: both bodies start with (round, client, job_id) —
    the demultiplexing key of the in-flight window and the worker
    cache."""
    buf = fixture_bytes
    seen = {}
    while buf:
        _, _, kind, _, body_len, _ = struct.unpack_from(
            "<4sHBBII", buf
        )
        body = buf[16:16 + body_len]
        if kind in (mirror.KIND_JOB, mirror.KIND_OUTCOME):
            seen[kind] = struct.unpack_from("<III", body)
        buf = buf[16 + body_len:]
    job_ids = seen[mirror.KIND_JOB]
    out_ids = seen[mirror.KIND_OUTCOME]
    assert job_ids == out_ids == (3, 5, mirror.CANON_JOB_ID)


def test_heartbeat_ack_echoes_the_nonce(mirror, fixture_bytes):
    frames = []
    buf = fixture_bytes
    while buf:
        _, _, kind, _, body_len, _ = struct.unpack_from("<4sHBBII", buf)
        frames.append((kind, buf[16:16 + body_len]))
        buf = buf[16 + body_len:]
    hb = dict(frames[2:])
    nonce = struct.unpack("<Q", hb[mirror.KIND_HEARTBEAT])[0]
    assert nonce == mirror.CANON_NONCE
    assert hb[mirror.KIND_HEARTBEAT_ACK] == hb[mirror.KIND_HEARTBEAT]


def test_overhead_constants(mirror):
    """The CommStats framing constants in coordinator/comm.rs charge
    exactly these overheads; if the layout grows, both must move."""
    assert mirror.JOB_FRAME_OVERHEAD == 72
    assert mirror.OUTCOME_FRAME_OVERHEAD == 57
    assert mirror.PARTIAL_FRAME_OVERHEAD == 44
    job, outcome, _, _, partial = mirror.golden_frames()
    assert len(job) == mirror.wire_bytes(*mirror.CANON_DOWN) + 72
    # the outcome golden carries a 2-element EF block: 4 (len) + 8 (f32s)
    assert len(outcome) == mirror.wire_bytes(*mirror.CANON_UP) + 57 + 12
    # the backbone identity record_partial charges by
    p = mirror.CANON_PARTIAL
    assert len(partial) == (
        mirror.partial_wire_bytes(p["width"], len(p["fragments"])) + 44
    )
    # v1 constants are frozen alongside the v1 fixture
    assert mirror.V1_JOB_FRAME_OVERHEAD == 68
    assert mirror.V1_OUTCOME_FRAME_OVERHEAD == 53


def test_partial_frame_pins_the_backbone_layout(mirror, fixture_bytes):
    """Regression (PR 6 gap): FrameKind::Partial was absent from the
    golden fixture, so a silent partial-frame layout drift would have
    passed the golden suite. The last fixture frame must be a Partial
    whose body decodes field-for-field to CANON_PARTIAL, f64 sum bit
    patterns included."""
    buf = fixture_bytes
    frames = []
    while buf:
        _, _, kind, _, body_len, _ = struct.unpack_from("<4sHBBII", buf)
        frames.append((kind, buf[16:16 + body_len]))
        buf = buf[16 + body_len:]
    kind, body = frames[-1]
    assert kind == mirror.KIND_PARTIAL == 8
    p = mirror.CANON_PARTIAL
    round_, start, end, width, n_frag = struct.unpack_from(
        "<IQQII", body
    )
    assert (round_, start, end, width) == (
        p["round_"], p["start"], p["end"], p["width"],
    )
    assert n_frag == len(p["fragments"])
    off = mirror.PARTIAL_META_BYTES
    for fs, fl, sums in p["fragments"]:
        got_s, got_l = struct.unpack_from("<QQ", body, off)
        assert (got_s, got_l) == (fs, fl)
        off += 16
        got_sums = struct.unpack_from(f"<{width}d", body, off)
        # bit-exact, not approx: the tree contract ships raw f64 bits
        for a, b in zip(got_sums, sums):
            assert struct.pack("<d", a) == struct.pack("<d", b)
        off += 8 * width
    assert off == len(body)


# ---- snapshot fixture (coordinator durable state, not the wire) ------


@pytest.fixture(scope="module")
def snap_bytes():
    with open(SNAP_FIXTURE_V2, "rb") as f:
        return f.read()


def test_snapshot_fixture_matches_mirror(mirror, snap_bytes):
    """snapshot_v2.bin must equal a fresh mirror encode of the
    canonical state (the Rust side pins the same bytes against its
    encoder/decoder in rust/tests/golden_snapshot.rs)."""
    assert snap_bytes == mirror.golden_snapshot(), (
        "snapshot_v2.bin no longer matches the spec mirror — "
        "regenerate with tools/gen_wire_fixture.py ONLY alongside a "
        "SNAPSHOT_VERSION bump (as snapshot_v<N>.bin, keeping older "
        "fixtures committed)"
    )


def test_snapshot_fixture_envelope_is_well_formed(mirror, snap_bytes):
    magic, version, reserved, body_len, crc = struct.unpack_from(
        "<4sHHII", snap_bytes
    )
    assert magic == mirror.SNAP_MAGIC == b"FP8S"
    assert version == mirror.SNAP_VERSION == 2
    assert reserved == 0
    body = snap_bytes[mirror.SNAP_HEADER_BYTES:]
    assert len(body) == body_len
    assert zlib.crc32(body) & 0xFFFFFFFF == crc
    # body opens with the fingerprint gate and the resume round, and
    # (since v2) closes with the cumulative wall clock
    fp, next_round = struct.unpack_from("<QQ", body)
    assert fp == mirror.CANON_SNAP["fingerprint"]
    assert next_round == mirror.CANON_SNAP["next_round"]
    wall = struct.unpack("<Q", body[-8:])[0]
    assert wall == mirror.CANON_SNAP["wall_millis"]


def test_snapshot_frozen_v1_fixture_matches_frozen_mirror(mirror):
    """snapshot_v1.bin is a version-skew probe now: a v2 build must
    reject it with the typed VersionMismatch (pinned on the Rust
    side), so its bytes must never drift."""
    with open(SNAP_FIXTURE_V1, "rb") as f:
        v1 = f.read()
    assert v1 == mirror.golden_snapshot_v1(), (
        "snapshot_v1.bin drifted — the frozen v1 fixture must stay "
        "byte-identical forever"
    )
    assert struct.unpack_from("<H", v1, 4)[0] == 1
    # the v2 body is the v1 body plus a trailing wall_millis u64
    assert len(mirror.golden_snapshot()) == len(v1) + 8


def test_snapshot_v0_fixture_is_the_must_fail_version_skew(
    mirror,
):
    """snapshot_v0.bin differs from the frozen v1 ONLY in the version
    field (the body and its crc are valid), so the only way a reader
    can reject it is the version gate itself."""
    with open(SNAP_FIXTURE_V0, "rb") as f:
        v0 = f.read()
    assert v0 == mirror.golden_snapshot_v0()
    assert struct.unpack_from("<H", v0, 4)[0] == 0
    v1 = mirror.golden_snapshot_v1()
    assert v0[:4] == v1[:4] and v0[6:] == v1[6:]


# ---- FP8 edge-code fixture (kernel byte output, not just framing) ----


@pytest.fixture(scope="module")
def edge_fixture():
    with open(EDGE_FIXTURE) as f:
        return json.load(f)


def test_edge_fixture_matches_mirror(mirror, edge_fixture):
    """The committed edge codes must equal a fresh mirror run, so a
    regeneration with a drifted value-mapping mirror cannot slip
    through unnoticed (the Rust side pins the same bytes against its
    oracle and every kernel in rust/tests/golden_fp8.rs)."""
    assert edge_fixture == mirror.fp8_edge_fixture()


def test_edge_fixture_covers_the_hostile_classes(edge_fixture):
    """Structural coverage floor: each case must include NaN payloads,
    both infinities, both zeros, f32 subnormals and saturating inputs,
    and every case's codes must be valid bytes."""
    assert edge_fixture["m"] == 3 and edge_fixture["e"] == 4
    alphas = {c["alpha"] for c in edge_fixture["cases"]}
    assert len(alphas) >= 4
    for case in edge_fixture["cases"]:
        bits = case["x_bits"]
        codes = case["codes"]
        assert len(bits) == len(codes)
        assert all(0 <= c <= 0xFF for c in codes)
        xs = [struct.unpack("<f", struct.pack("<I", b))[0] for b in bits]
        assert any(math.isnan(x) for x in xs)
        assert any(math.isinf(x) and x > 0 for x in xs)
        assert any(math.isinf(x) and x < 0 for x in xs)
        assert 0x00000000 in bits and 0x80000000 in bits
        assert any(0 < b < 0x00800000 for b in bits)  # f32 subnormal
        assert any(
            math.isfinite(x) and abs(x) >= 2.0 * case["alpha"]
            for x in xs
        )
        # NaN encodes to 0, infinities saturate to +-alpha top code
        for b, c in zip(bits, codes):
            x = struct.unpack("<f", struct.pack("<I", b))[0]
            if math.isnan(x):
                assert c == 0
            elif math.isinf(x):
                assert c == (0xFF if x < 0 else 0x7F)


def test_edge_fixture_mirror_math_is_f64_exact(mirror):
    """Spot-check the mirror against hand-derived facts: the top code
    decodes to ~alpha, code 0 to 0, and deterministic encode of alpha
    saturates to the top code."""
    for alpha in [1.0, 0.0625, 3.7, 117.0]:
        m = mirror.Fp8Mirror(alpha)
        assert m.decode(0) == 0.0
        assert abs(m.decode(0x7F) - alpha) <= 1e-6 * alpha
        assert m.encode(alpha, 0.5) == 0x7F
        assert m.encode(-alpha, 0.5) == 0xFF
        assert m.encode(float("nan"), 0.5) == 0
