"""L1 correctness: Pallas kernel vs the pure-jnp oracle.

The kernel and oracle must agree bit-for-bit (both are f32 math on the
same op sequence); hypothesis sweeps shapes, dtypes are fixed to f32
(the wire format's de-quantized domain).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fp8_quant, ref


RNG = np.random.default_rng(0)


def _check(x, alpha, u):
    """Kernel and oracle compute the same f32 formula, but XLA may fuse
    log2/exp2 differently between the two graphs — allow 1-2 ulp."""
    xq = fp8_quant.fp8_quantize(jnp.asarray(x), jnp.asarray(alpha),
                                jnp.asarray(u))
    xr = ref.quantize(jnp.asarray(x), jnp.asarray(alpha), jnp.asarray(u))
    np.testing.assert_allclose(np.asarray(xq), np.asarray(xr),
                               rtol=3e-6, atol=1e-30)


class TestKernelVsRef:
    @pytest.mark.parametrize("n", [1, 7, 127, 128, 129, 4096, 5000])
    def test_sizes_det(self, n):
        x = RNG.normal(size=n).astype(np.float32)
        _check(x, np.float32(1.3), np.full(n, 0.5, np.float32))

    @pytest.mark.parametrize("n", [63, 1024])
    def test_sizes_rand(self, n):
        x = RNG.normal(size=n).astype(np.float32)
        u = RNG.random(size=n).astype(np.float32)
        _check(x, np.float32(0.77), u)

    @pytest.mark.parametrize("alpha", [0.01, 0.25, 1.0, 3.7, 64.0])
    def test_alphas(self, alpha):
        x = (RNG.normal(size=512) * alpha).astype(np.float32)
        _check(x, np.float32(alpha), np.full(512, 0.5, np.float32))

    def test_per_element_alpha(self):
        x = RNG.normal(size=256).astype(np.float32)
        alpha = RNG.uniform(0.1, 4.0, size=256).astype(np.float32)
        _check(x, alpha, np.full(256, 0.5, np.float32))

    def test_2d_shape_roundtrips(self):
        x = RNG.normal(size=(17, 31)).astype(np.float32)
        q = fp8_quant.fp8_quantize(jnp.asarray(x), 2.0, 0.5)
        assert q.shape == x.shape

    def test_zero_maps_to_zero(self):
        x = np.zeros(130, np.float32)
        q = fp8_quant.fp8_quantize(jnp.asarray(x), 1.0, 0.5)
        assert np.all(np.asarray(q) == 0.0)

    def test_clipping(self):
        x = np.array([10.0, -10.0, 1e9, -1e9], np.float32)
        q = np.asarray(fp8_quant.fp8_quantize(jnp.asarray(x), 1.5, 0.5))
        np.testing.assert_allclose(q, [1.5, -1.5, 1.5, -1.5], rtol=1e-6)

    def test_whole_block_variant_matches(self):
        x = RNG.normal(size=777).astype(np.float32)
        u = np.full(777, 0.5, np.float32)
        a = np.full(777, 1.9, np.float32)
        q1 = fp8_quant.fp8_quantize(jnp.asarray(x), jnp.asarray(a),
                                    jnp.asarray(u))
        q2 = fp8_quant.fp8_quantize_whole(jnp.asarray(x), jnp.asarray(a),
                                          jnp.asarray(u))
        np.testing.assert_allclose(np.asarray(q1), np.asarray(q2),
                                   rtol=3e-6, atol=1e-30)

    @pytest.mark.parametrize("block_rows", [8, 64, 256])
    def test_block_size_invariance(self, block_rows):
        """Tiling is a schedule, not semantics: results must not depend
        on the BlockSpec."""
        x = RNG.normal(size=3000).astype(np.float32)
        u = np.full(3000, 0.5, np.float32)
        q = fp8_quant.fp8_quantize(jnp.asarray(x), 1.0, jnp.asarray(u),
                                   block_rows=block_rows)
        qr = ref.quantize(jnp.asarray(x), 1.0, jnp.asarray(u))
        np.testing.assert_allclose(np.asarray(q), np.asarray(qr),
                                   rtol=3e-6, atol=1e-30)


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=2048),
    alpha=st.floats(min_value=1e-2, max_value=100.0),
    scale=st.floats(min_value=1e-3, max_value=10.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    det=st.booleans(),
)
def test_kernel_hypothesis_sweep(n, alpha, scale, seed, det):
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=n) * scale).astype(np.float32)
    u = (np.full(n, 0.5) if det else rng.random(size=n)).astype(np.float32)
    _check(x, np.float32(alpha), u)
