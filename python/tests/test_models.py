"""L2 model-zoo tests: shapes, segment tables, init, gradient flow."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.models import BUILDERS


ALL = [("mlp", 10), ("lenet", 10), ("lenet", 100), ("resnet8", 10),
       ("matchbox", 12), ("kwt", 12)]


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


class TestSpec:
    @pytest.mark.parametrize("name,classes", ALL)
    def test_segments_tile_the_vector(self, name, classes):
        spec = BUILDERS[name](classes)["spec"]
        off = 0
        for s in spec.segs:
            assert s.offset == off
            off += s.size
        assert off == spec.dim

    @pytest.mark.parametrize("name,classes", ALL)
    def test_alpha_indices_dense(self, name, classes):
        spec = BUILDERS[name](classes)["spec"]
        idx = [s.alpha_idx for s in spec.segs if s.quant]
        assert idx == list(range(spec.alpha_dim))

    @pytest.mark.parametrize("name,classes", ALL)
    def test_unquantized_fraction_small(self, name, classes):
        """Paper §4: non-quantized params (biases, norm) are < ~2-6% of
        the total at full scale; at our reduced widths allow 12%."""
        spec = BUILDERS[name](classes)["spec"]
        unq = sum(s.size for s in spec.segs if not s.quant)
        assert unq / spec.dim < 0.12

    @pytest.mark.parametrize("name,classes", ALL)
    def test_init_alpha_covers_weights(self, name, classes, rng):
        spec = BUILDERS[name](classes)["spec"]
        w, alpha = spec.init_flat(rng)
        for s in spec.segs:
            if s.quant:
                seg = w[s.offset:s.offset + s.size]
                assert alpha[s.alpha_idx] >= np.abs(seg).max() - 1e-7

    def test_alpha_elem_expansion(self, rng):
        spec = BUILDERS["mlp"](10)["spec"]
        alpha = jnp.asarray(np.array([2.0, 3.0], np.float32))
        ae = np.asarray(spec.alpha_elem(alpha))
        s0, s1 = spec.segs[0], spec.segs[2]
        assert np.all(ae[s0.offset:s0.offset + s0.size] == 2.0)
        assert np.all(ae[s1.offset:s1.offset + s1.size] == 3.0)
        b = spec.segs[1]
        assert np.all(ae[b.offset:b.offset + b.size] == 1.0)


class TestForward:
    @pytest.mark.parametrize("name,classes", ALL)
    @pytest.mark.parametrize("mode", ["det", "none"])
    def test_logit_shapes(self, name, classes, mode, rng):
        mdl = M.build_model(name, classes)
        g = M.Graphs(mdl, mode)
        spec = mdl["spec"]
        w, alpha = spec.init_flat(rng)
        beta = np.full(mdl["n_act"], 4.0, np.float32)
        x = rng.normal(size=(3,) + tuple(mdl["input_shape"])).astype(
            np.float32)
        logits = g.forward(jnp.asarray(w), jnp.asarray(alpha),
                           jnp.asarray(beta), jnp.asarray(x),
                           jax.random.PRNGKey(0))
        assert logits.shape == (3, classes)
        assert np.all(np.isfinite(np.asarray(logits)))

    def test_rand_mode_stochastic(self, rng):
        mdl = M.build_model("mlp", 10)
        g = M.Graphs(mdl, "rand")
        spec = mdl["spec"]
        w, alpha = spec.init_flat(rng)
        beta = np.full(mdl["n_act"], 4.0, np.float32)
        x = rng.normal(size=(2, 8, 8, 3)).astype(np.float32)
        l1 = g.forward(w, alpha, beta, x, jax.random.PRNGKey(1))
        l2 = g.forward(w, alpha, beta, x, jax.random.PRNGKey(2))
        assert not np.allclose(np.asarray(l1), np.asarray(l2))

    def test_det_mode_deterministic(self, rng):
        mdl = M.build_model("mlp", 10)
        g = M.Graphs(mdl, "det")
        spec = mdl["spec"]
        w, alpha = spec.init_flat(rng)
        beta = np.full(mdl["n_act"], 4.0, np.float32)
        x = rng.normal(size=(2, 8, 8, 3)).astype(np.float32)
        l1 = g.forward(w, alpha, beta, x, jax.random.PRNGKey(1))
        l2 = g.forward(w, alpha, beta, x, jax.random.PRNGKey(2))
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


class TestGradients:
    @pytest.mark.parametrize("name,classes", [("mlp", 10), ("lenet", 10)])
    def test_alpha_beta_receive_gradients(self, name, classes, rng):
        mdl = M.build_model(name, classes)
        g = M.Graphs(mdl, "det")
        spec = mdl["spec"]
        w, alpha = spec.init_flat(rng)
        beta = np.full(mdl["n_act"], 0.5, np.float32)  # force clipping
        x = rng.normal(size=(8,) + tuple(mdl["input_shape"])).astype(
            np.float32)
        y = rng.integers(0, classes, 8).astype(np.int32)
        grads = jax.grad(
            lambda w, a, b: g.loss(w, a, b, x, y, jax.random.PRNGKey(0)),
            argnums=(0, 1, 2))(jnp.asarray(w), jnp.asarray(alpha),
                               jnp.asarray(beta))
        gw, ga, gb = (np.asarray(v) for v in grads)
        assert np.any(gw != 0)
        assert np.any(ga != 0)
        assert np.any(gb != 0)
        assert all(np.all(np.isfinite(v)) for v in (gw, ga, gb))

    def test_ste_masks_clipped_weights(self, rng):
        """dL/dw must be zero where |w| > alpha (STE clip mask)."""
        from compile import fp8
        x = jnp.asarray(np.array([0.3, 2.0, -3.0, 0.9], np.float32))
        a = jnp.full((4,), 1.0, jnp.float32)
        u = jnp.full((4,), 0.5, jnp.float32)
        gx = jax.grad(lambda x: fp8.quantize_ste(x, a, u).sum())(x)
        np.testing.assert_array_equal(np.asarray(gx), [1.0, 0.0, 0.0, 1.0])

    def test_alpha_gradient_sign_for_clipped(self):
        """Clipped elements push alpha up when loss wants larger values
        (dQ/dalpha = sign(x) on the clipped set)."""
        from compile import fp8
        x = jnp.asarray(np.array([5.0, -5.0], np.float32))
        a = jnp.full((2,), 1.0, jnp.float32)
        u = jnp.full((2,), 0.5, jnp.float32)
        ga = jax.grad(lambda a: fp8.quantize_ste(x, a, u).sum())(a)
        np.testing.assert_allclose(np.asarray(ga), [1.0, -1.0])
