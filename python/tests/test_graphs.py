"""End-to-end L2 graph tests: local updates learn, ServerOptimize
reduces the Eq. (4) MSE, artifacts in the manifest are consistent."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


RNG = np.random.default_rng(3)
ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _setup(name="mlp", classes=10, mode="det"):
    mdl = M.build_model(name, classes)
    g = M.Graphs(mdl, mode)
    spec = mdl["spec"]
    w, alpha = spec.init_flat(RNG)
    beta = np.full(mdl["n_act"], 4.0, np.float32)
    return mdl, g, w, alpha, beta


def _batches(mdl, classes, u, b):
    protos = RNG.normal(size=(classes,) + tuple(mdl["input_shape"]))
    ys = RNG.integers(0, classes, size=(u, b)).astype(np.int32)
    xs = (protos[ys] + 0.5 * RNG.normal(size=(u, b) + tuple(
        mdl["input_shape"]))).astype(np.float32)
    return xs, ys


class TestLocalUpdate:
    @pytest.mark.parametrize("mode", ["det", "rand", "none"])
    def test_sgd_reduces_loss(self, mode):
        mdl, g, w, alpha, beta = _setup(mode=mode)
        xs, ys = _batches(mdl, 10, 10, 32)
        f = jax.jit(g.local_update_sgd)
        _, _, _, l0 = f(w, alpha, beta, xs, ys, jnp.float32(0.1),
                        jnp.float32(1e-3), jnp.int32(0))
        w1, a1, b1 = w, alpha, beta
        for i in range(6):
            w1, a1, b1, l = f(w1, a1, b1, xs, ys, jnp.float32(0.1),
                              jnp.float32(1e-3), jnp.int32(i))
        assert float(l) < float(l0)

    def test_adamw_reduces_loss(self):
        mdl, g, w, alpha, beta = _setup("matchbox", 12)
        xs, ys = _batches(mdl, 12, 10, 16)
        f = jax.jit(g.local_update_adamw)
        w1, a1, b1, l0 = f(w, alpha, beta, xs, ys, jnp.float32(1e-3),
                           jnp.float32(0.1), jnp.int32(0))
        for i in range(5):
            w1, a1, b1, l = f(w1, a1, b1, xs, ys, jnp.float32(1e-3),
                              jnp.float32(0.1), jnp.int32(i))
        assert float(l) < float(l0)

    def test_alpha_stays_positive(self):
        mdl, g, w, alpha, beta = _setup()
        xs, ys = _batches(mdl, 10, 10, 32)
        f = jax.jit(g.local_update_sgd)
        a1 = alpha
        w1, b1 = w, beta
        for i in range(8):
            w1, a1, b1, _ = f(w1, a1, b1, xs, ys, jnp.float32(0.5),
                              jnp.float32(0.0), jnp.int32(i))
        assert np.all(np.asarray(a1) >= M.ALPHA_MIN - 1e-9)
        assert np.all(np.asarray(b1) >= M.ALPHA_MIN - 1e-9)

    def test_losses_averaged_over_steps(self):
        mdl, g, w, alpha, beta = _setup()
        xs, ys = _batches(mdl, 10, 1, 32)
        xs = np.repeat(xs, 4, axis=0)
        ys = np.repeat(ys, 4, axis=0)
        _, _, _, l = jax.jit(g.local_update_sgd)(
            w, alpha, beta, xs, ys, jnp.float32(0.0), jnp.float32(0.0),
            jnp.int32(0))
        # lr=0 -> every step sees the same params; mean loss == per-step
        l1 = g.loss(jnp.asarray(w), jnp.asarray(alpha), jnp.asarray(beta),
                    xs[0], ys[0], jax.random.PRNGKey(0))
        assert np.isclose(float(l), float(l1), rtol=1e-5)


class TestServerOpt:
    def test_gd_reduces_eq4_mse(self):
        mdl, g, w, alpha, beta = _setup()
        spec = mdl["spec"]
        p = 5
        clients = (w[None, :] + 0.05 * RNG.normal(
            size=(p, spec.dim))).astype(np.float32)
        kw = np.full(p, 1.0 / p, np.float32)
        u = RNG.random(size=spec.dim).astype(np.float32)
        f = jax.jit(g.server_opt_step)
        w1, mse0 = f(w, alpha, clients, kw, u, jnp.float32(0.1))
        w2, mse1 = f(np.asarray(w1), alpha, clients, kw, u,
                     jnp.float32(0.1))
        assert float(mse1) < float(mse0)

    def test_no_quant_fixed_point_is_fedavg(self):
        """With Q == identity the Eq. (4) minimizer is the weighted
        average; GD from the average must (almost) not move."""
        mdl, g, w, alpha, beta = _setup(mode="none")
        g.mode = "det"  # quantizer active; use tiny weights scale to
        # keep quantization error negligible relative to movement
        spec = mdl["spec"]
        p = 4
        clients = RNG.normal(size=(p, spec.dim)).astype(np.float32)
        kw = np.asarray([0.1, 0.2, 0.3, 0.4], np.float32)
        wavg = (kw[:, None] * clients).sum(0)
        grad = 2 * (kw[:, None] * (wavg[None] - clients)).sum(0)
        assert np.abs(grad).max() < 1e-5


class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        path = os.path.join(ART, "manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built")
        with open(path) as f:
            return json.load(f)

    def test_all_variants_present(self, manifest):
        for v in ("mlp_c10", "lenet_c10", "lenet_c100", "resnet8_c10",
                  "resnet8_c100", "matchbox", "kwt"):
            assert v in manifest["models"]

    def test_artifact_files_exist(self, manifest):
        for v, m in manifest["models"].items():
            for f in m["artifacts"].values():
                assert os.path.exists(os.path.join(ART, f)), f
            for f in m["init"].values():
                assert os.path.exists(os.path.join(ART, f)), f

    def test_init_sizes_match_dims(self, manifest):
        for v, m in manifest["models"].items():
            w = np.fromfile(os.path.join(ART, m["init"]["w"]), "<f4")
            a = np.fromfile(os.path.join(ART, m["init"]["alpha"]), "<f4")
            b = np.fromfile(os.path.join(ART, m["init"]["beta"]), "<f4")
            assert len(w) == m["dim"]
            assert len(a) == m["alpha_dim"]
            assert len(b) == m["n_act"]

    def test_segments_cover_dim(self, manifest):
        for v, m in manifest["models"].items():
            total = sum(s["size"] for s in m["segments"])
            assert total == m["dim"]

    def test_goldens_selfconsistent(self):
        from compile.kernels import ref
        path = os.path.join(ART, "golden_fp8.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built")
        with open(path) as f:
            g = json.load(f)
        for case in g["cases"]:
            x = np.array(case["x"], np.float32)
            q = ref.quantize_np(x, np.float32(case["alpha"]),
                                np.full(x.shape, 0.5))
            np.testing.assert_array_equal(
                q, np.array(case["q_det"], np.float32))
