"""2-layer MLP — the quickstart model (LeNet-scale dense stand-in)."""

from __future__ import annotations

import jax.numpy as jnp

from . import common


def build(classes: int, h: int = 8, w: int = 8, c: int = 3, hidden: int = 64):
    d_in = h * w * c
    sb = common.SpecBuilder()
    sb.add("fc1.w", (d_in, hidden))
    sb.add("fc1.b", (hidden,), quant=False, init="zeros")
    sb.add("fc2.w", (hidden, classes))
    sb.add("fc2.b", (classes,), quant=False, init="zeros")
    spec = sb.build()

    def apply(p, x, qact):
        z = x.reshape(x.shape[0], -1)
        a = jnp.maximum(z @ p["fc1.w"] + p["fc1.b"], 0.0)
        a = qact(0, a)
        return a @ p["fc2.w"] + p["fc2.b"]

    return dict(spec=spec, apply=apply, n_act=1,
                input_shape=(h, w, c), kind="vision", classes=classes)
