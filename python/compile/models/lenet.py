"""LeNet-style conv net with GroupNorm (paper's small vision model).

conv3x3(3->8) GN relu Q pool | conv3x3(8->16) GN relu Q pool |
fc(16*h/4*w/4 -> 32) relu Q | fc(32 -> classes)
"""

from __future__ import annotations

import jax.numpy as jnp

from . import common


def build(classes: int, h: int = 8, w: int = 8, c: int = 3,
          c1: int = 8, c2: int = 16, fc: int = 32):
    flat = (h // 4) * (w // 4) * c2
    sb = common.SpecBuilder()
    sb.add("conv1.w", (3, 3, c, c1))
    sb.add("gn1.g", (c1,), quant=False, init="ones")
    sb.add("gn1.b", (c1,), quant=False, init="zeros")
    sb.add("conv2.w", (3, 3, c1, c2))
    sb.add("gn2.g", (c2,), quant=False, init="ones")
    sb.add("gn2.b", (c2,), quant=False, init="zeros")
    sb.add("fc1.w", (flat, fc))
    sb.add("fc1.b", (fc,), quant=False, init="zeros")
    sb.add("fc2.w", (fc, classes))
    sb.add("fc2.b", (classes,), quant=False, init="zeros")
    spec = sb.build()

    def apply(p, x, qact):
        a = common.conv2d(x, p["conv1.w"])
        a = common.group_norm(a, p["gn1.g"], p["gn1.b"], 2)
        a = qact(0, jnp.maximum(a, 0.0))
        a = common.avg_pool2(a)
        a = common.conv2d(a, p["conv2.w"])
        a = common.group_norm(a, p["gn2.g"], p["gn2.b"], 4)
        a = qact(1, jnp.maximum(a, 0.0))
        a = common.avg_pool2(a)
        a = a.reshape(a.shape[0], -1)
        a = qact(2, jnp.maximum(a @ p["fc1.w"] + p["fc1.b"], 0.0))
        return a @ p["fc2.w"] + p["fc2.b"]

    return dict(spec=spec, apply=apply, n_act=3,
                input_shape=(h, w, c), kind="vision", classes=classes)
