"""Model zoo: reduced-scale stand-ins for the paper's architectures.

| paper model        | here       |
|--------------------|------------|
| LeNet              | `lenet`    |
| ResNet18 (GN)      | `resnet8`  |
| MatchboxNet 3x1x64 | `matchbox` |
| KWT-1              | `kwt`      |
| (quickstart)       | `mlp`      |
"""

from . import kwt, lenet, matchbox, mlp, resnet8  # noqa: F401

BUILDERS = {
    "mlp": mlp.build,
    "lenet": lenet.build,
    "resnet8": resnet8.build,
    "matchbox": matchbox.build,
    "kwt": kwt.build,
}
