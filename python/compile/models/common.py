"""Flat-parameter-vector model framework shared by the model zoo.

The L3 <-> L2 ABI is a single flat f32 vector `w[D]` plus a per-tensor
clipping vector `alpha[A]` (weights) and `beta[n_act]` (activations).
Each model declares an ordered list of named parameter segments; the
builder derives

  * `qmask[D]`      — static bool, True where the element is quantized
                      (biases and normalization parameters are excluded,
                      paper §4),
  * `alpha_index[D]`— static int32 mapping each element to its tensor's
                      alpha entry (A == dummy for unquantized elements),
  * `sizes[A]`      — quantized-segment sizes (for LSQ-style alpha
                      gradient scaling),

and init routines (He/Glorot for weights, alpha_0 = max|w_seg| as in the
paper, "alpha is first initialized using the maximum absolute value of
each weight range").

Everything here is build-time Python; the segment table is serialized to
`manifest.json` so the Rust coordinator can drive its wire codec
per-tensor without any pytree logic.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Segment:
    name: str
    shape: tuple
    quant: bool
    init: str
    offset: int = 0
    size: int = 0
    alpha_idx: Optional[int] = None
    fan_in: int = 1


class SpecBuilder:
    def __init__(self):
        self.segs: list[Segment] = []

    def add(self, name: str, shape, *, quant: bool = True,
            init: str = "he", fan_in: int = 0) -> str:
        shape = tuple(int(s) for s in shape)
        if fan_in == 0:
            # conv HWIO / dense IO: everything but the last dim feeds in
            fan_in = int(np.prod(shape[:-1])) if len(shape) > 1 else 1
        self.segs.append(Segment(name, shape, quant, init, fan_in=fan_in))
        return name

    def build(self) -> "ParamSpec":
        off, aidx = 0, 0
        for s in self.segs:
            s.offset = off
            s.size = int(np.prod(s.shape))
            off += s.size
            if s.quant:
                s.alpha_idx = aidx
                aidx += 1
        return ParamSpec(self.segs, off, aidx)


class ParamSpec:
    def __init__(self, segs, dim, alpha_dim):
        self.segs = segs
        self.dim = dim
        self.alpha_dim = alpha_dim
        qmask = np.zeros(dim, dtype=bool)
        aindex = np.full(dim, alpha_dim, dtype=np.int32)
        sizes = np.ones(alpha_dim, dtype=np.float32)
        for s in segs:
            if s.quant:
                qmask[s.offset:s.offset + s.size] = True
                aindex[s.offset:s.offset + s.size] = s.alpha_idx
                sizes[s.alpha_idx] = s.size
        self.qmask = qmask
        self.alpha_index = aindex
        self.alpha_sizes = sizes

    # ---- init ------------------------------------------------------
    def init_flat(self, rng: np.random.Generator):
        w = np.zeros(self.dim, dtype=np.float32)
        for s in self.segs:
            if s.init == "zeros":
                part = np.zeros(s.shape, np.float32)
            elif s.init == "ones":
                part = np.ones(s.shape, np.float32)
            elif s.init == "normal02":
                part = rng.normal(0, 0.02, s.shape).astype(np.float32)
            else:  # he
                std = float(np.sqrt(2.0 / max(s.fan_in, 1)))
                part = rng.normal(0, std, s.shape).astype(np.float32)
            w[s.offset:s.offset + s.size] = part.ravel()
        alpha = np.ones(self.alpha_dim, dtype=np.float32)
        for s in self.segs:
            if s.quant:
                seg = w[s.offset:s.offset + s.size]
                alpha[s.alpha_idx] = max(float(np.abs(seg).max()), 1e-3)
        return w, alpha

    # ---- traced helpers --------------------------------------------
    def unflatten(self, w_flat) -> dict:
        return {s.name: jax.lax.dynamic_slice_in_dim(
                    w_flat, s.offset, s.size).reshape(s.shape)
                for s in self.segs}

    def alpha_elem(self, alpha_vec):
        """Expand per-tensor alphas to per-element values.

        Built from static slices + broadcasts + one concatenate — NOT
        `jnp.take`: xla_extension 0.5.1 (the AOT runtime) mis-executes
        the gather-with-NaN-fill pattern modern jax emits for take,
        poisoning the whole graph (see DESIGN.md §Gotchas).
        Unquantized segments get the dummy clip 1.0.
        """
        parts = []
        for s in self.segs:
            if s.quant:
                a = jax.lax.slice(alpha_vec, (s.alpha_idx,),
                                  (s.alpha_idx + 1,))
                parts.append(jnp.broadcast_to(a, (s.size,)))
            else:
                parts.append(jnp.ones((s.size,), alpha_vec.dtype))
        return jnp.concatenate(parts)

    def to_manifest(self) -> dict:
        return {
            "dim": self.dim,
            "alpha_dim": self.alpha_dim,
            "segments": [
                {"name": s.name, "shape": list(s.shape), "offset": s.offset,
                 "size": s.size, "quantized": s.quant,
                 "alpha_idx": s.alpha_idx}
                for s in self.segs
            ],
        }


# ---- shared layer helpers (traced) ---------------------------------

def conv2d(x, w, stride=1):
    """NHWC x HWIO 'SAME' conv."""
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def conv1d(x, w, stride=1, groups=1):
    """NTC x TIO 'SAME' 1-D conv."""
    return jax.lax.conv_general_dilated(
        x, w, (stride,), "SAME",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=groups)


def group_norm(x, gamma, bias, groups, eps=1e-5):
    """GroupNorm over the channel (last) axis of NHWC / NTC tensors.

    The paper replaces BatchNorm with GroupNorm (Hsieh et al.: BN breaks
    under skewed federated splits); gamma/bias are NOT quantized.
    """
    orig = x.shape
    c = orig[-1]
    g = min(groups, c)
    xg = x.reshape(orig[:-1] + (g, c // g))
    red = tuple(range(1, len(orig) - 1)) + (len(orig),)
    mean = xg.mean(axis=red, keepdims=True)
    var = xg.var(axis=red, keepdims=True)
    xn = ((xg - mean) / jnp.sqrt(var + eps)).reshape(orig)
    return xn * gamma + bias


def layer_norm(x, gamma, bias, eps=1e-5):
    mean = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * gamma + bias


def avg_pool2(x):
    """2x2 average pool, NHWC."""
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID") / 4.0


def cross_entropy(logits, labels):
    """Mean CE via one-hot mask (no take_along_axis: its gather form
    breaks on the xla_extension 0.5.1 runtime — see `alpha_elem`)."""
    logz = jax.nn.log_softmax(logits)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logz.dtype)
    return -(logz * onehot).sum(axis=-1).mean()
