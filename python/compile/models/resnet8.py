"""ResNet-8 with GroupNorm — the reduced-width ResNet18 stand-in.

stem conv3x3(3->w) GN relu Q, then three residual stages of one basic
block each (widths w, 2w, 2w; strides 1, 2, 2), global average pool, fc.
Projection shortcuts (1x1 conv) where shape changes; all conv/fc weights
quantized, GN parameters not (paper §4).
"""

from __future__ import annotations

import jax.numpy as jnp

from . import common


def build(classes: int, h: int = 8, w: int = 8, c: int = 3, width: int = 16):
    widths = [width, 2 * width, 2 * width]
    strides = [1, 2, 2]
    sb = common.SpecBuilder()
    sb.add("stem.w", (3, 3, c, width))
    sb.add("stem.gn.g", (width,), quant=False, init="ones")
    sb.add("stem.gn.b", (width,), quant=False, init="zeros")
    c_in = width
    for i, (c_out, st) in enumerate(zip(widths, strides)):
        pre = f"b{i}."
        sb.add(pre + "c1.w", (3, 3, c_in, c_out))
        sb.add(pre + "gn1.g", (c_out,), quant=False, init="ones")
        sb.add(pre + "gn1.b", (c_out,), quant=False, init="zeros")
        sb.add(pre + "c2.w", (3, 3, c_out, c_out))
        sb.add(pre + "gn2.g", (c_out,), quant=False, init="ones")
        sb.add(pre + "gn2.b", (c_out,), quant=False, init="zeros")
        if st != 1 or c_in != c_out:
            sb.add(pre + "proj.w", (1, 1, c_in, c_out))
        c_in = c_out
    sb.add("fc.w", (c_in, classes))
    sb.add("fc.b", (classes,), quant=False, init="zeros")
    spec = sb.build()

    def apply(p, x, qact):
        site = 0
        a = common.conv2d(x, p["stem.w"])
        a = common.group_norm(a, p["stem.gn.g"], p["stem.gn.b"], 4)
        a = qact(site, jnp.maximum(a, 0.0)); site += 1
        cin = width
        for i, (c_out, st) in enumerate(zip(widths, strides)):
            pre = f"b{i}."
            r = common.conv2d(a, p[pre + "c1.w"], stride=st)
            r = common.group_norm(r, p[pre + "gn1.g"], p[pre + "gn1.b"], 4)
            r = qact(site, jnp.maximum(r, 0.0)); site += 1
            r = common.conv2d(r, p[pre + "c2.w"])
            r = common.group_norm(r, p[pre + "gn2.g"], p[pre + "gn2.b"], 4)
            if (pre + "proj.w") in p:
                skip = common.conv2d(a, p[pre + "proj.w"], stride=st)
            else:
                skip = a
            a = qact(site, jnp.maximum(r + skip, 0.0)); site += 1
            cin = c_out
        a = a.mean(axis=(1, 2))
        return a @ p["fc.w"] + p["fc.b"]

    return dict(spec=spec, apply=apply, n_act=7,
                input_shape=(h, w, c), kind="vision", classes=classes)
