"""MatchboxNet-style 1-D time-channel-separable CNN (keyword spotting).

pointwise(F->ch) GN relu Q, then `blocks` x [depthwise k=5 + pointwise +
GN + relu + Q], global average pool over time, fc. Input is an
MFCC-like (T, F) sequence.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import common


def build(classes: int, t: int = 32, f: int = 16, ch: int = 32,
          blocks: int = 2, k: int = 5):
    sb = common.SpecBuilder()
    sb.add("pw0.w", (1, f, ch))
    sb.add("gn0.g", (ch,), quant=False, init="ones")
    sb.add("gn0.b", (ch,), quant=False, init="zeros")
    for i in range(blocks):
        pre = f"b{i}."
        sb.add(pre + "dw.w", (k, 1, ch), fan_in=k)
        sb.add(pre + "pw.w", (1, ch, ch))
        sb.add(pre + "gn.g", (ch,), quant=False, init="ones")
        sb.add(pre + "gn.b", (ch,), quant=False, init="zeros")
    sb.add("fc.w", (ch, classes))
    sb.add("fc.b", (classes,), quant=False, init="zeros")
    spec = sb.build()

    def apply(p, x, qact):
        site = 0
        a = common.conv1d(x, p["pw0.w"])
        a = common.group_norm(a, p["gn0.g"], p["gn0.b"], 4)
        a = qact(site, jnp.maximum(a, 0.0)); site += 1
        for i in range(blocks):
            pre = f"b{i}."
            a = common.conv1d(a, p[pre + "dw.w"], groups=ch)
            a = common.conv1d(a, p[pre + "pw.w"])
            a = common.group_norm(a, p[pre + "gn.g"], p[pre + "gn.b"], 4)
            a = qact(site, jnp.maximum(a, 0.0)); site += 1
        a = a.mean(axis=1)
        return a @ p["fc.w"] + p["fc.b"]

    return dict(spec=spec, apply=apply, n_act=1 + blocks,
                input_shape=(t, f), kind="speech", classes=classes)
