"""KWT-style tiny keyword-spotting transformer.

Patchify the (T, F) spectrogram along time (patch = `patch_t` frames),
linear-embed to `dim`, prepend a CLS token, add learned positional
embeddings, run `depth` pre-LN transformer blocks (MHA + MLP), classify
from the CLS token. LayerNorm parameters are NOT quantized (paper §4);
all linear weights are.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common


def build(classes: int, t: int = 32, f: int = 16, patch_t: int = 4,
          dim: int = 32, depth: int = 2, heads: int = 2):
    n_tok = t // patch_t
    d_patch = patch_t * f
    sb = common.SpecBuilder()
    sb.add("embed.w", (d_patch, dim))
    sb.add("embed.b", (dim,), quant=False, init="zeros")
    sb.add("cls", (1, dim), quant=False, init="normal02")
    sb.add("pos", (n_tok + 1, dim), quant=False, init="normal02")
    for i in range(depth):
        pre = f"l{i}."
        sb.add(pre + "ln1.g", (dim,), quant=False, init="ones")
        sb.add(pre + "ln1.b", (dim,), quant=False, init="zeros")
        sb.add(pre + "qkv.w", (dim, 3 * dim))
        sb.add(pre + "qkv.b", (3 * dim,), quant=False, init="zeros")
        sb.add(pre + "proj.w", (dim, dim))
        sb.add(pre + "proj.b", (dim,), quant=False, init="zeros")
        sb.add(pre + "ln2.g", (dim,), quant=False, init="ones")
        sb.add(pre + "ln2.b", (dim,), quant=False, init="zeros")
        sb.add(pre + "mlp1.w", (dim, 2 * dim))
        sb.add(pre + "mlp1.b", (2 * dim,), quant=False, init="zeros")
        sb.add(pre + "mlp2.w", (2 * dim, dim))
        sb.add(pre + "mlp2.b", (dim,), quant=False, init="zeros")
    sb.add("head.ln.g", (dim,), quant=False, init="ones")
    sb.add("head.ln.b", (dim,), quant=False, init="zeros")
    sb.add("head.w", (dim, classes))
    sb.add("head.b", (classes,), quant=False, init="zeros")
    spec = sb.build()
    dh = dim // heads

    def apply(p, x, qact):
        site = 0
        bsz = x.shape[0]
        tok = x.reshape(bsz, n_tok, d_patch) @ p["embed.w"] + p["embed.b"]
        cls = jnp.broadcast_to(p["cls"], (bsz, 1, dim))
        a = jnp.concatenate([cls, tok], axis=1) + p["pos"]
        a = qact(site, a); site += 1
        n = n_tok + 1
        for i in range(depth):
            pre = f"l{i}."
            h = common.layer_norm(a, p[pre + "ln1.g"], p[pre + "ln1.b"])
            qkv = h @ p[pre + "qkv.w"] + p[pre + "qkv.b"]
            qkv = qkv.reshape(bsz, n, 3, heads, dh)
            q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
            att = jnp.einsum("bnhd,bmhd->bhnm", q, k) / jnp.sqrt(float(dh))
            att = jax.nn.softmax(att, axis=-1)
            o = jnp.einsum("bhnm,bmhd->bnhd", att, v).reshape(bsz, n, dim)
            a = a + qact(site, o @ p[pre + "proj.w"] + p[pre + "proj.b"])
            site += 1
            h = common.layer_norm(a, p[pre + "ln2.g"], p[pre + "ln2.b"])
            h = jax.nn.gelu(h @ p[pre + "mlp1.w"] + p[pre + "mlp1.b"])
            a = a + qact(site, h @ p[pre + "mlp2.w"] + p[pre + "mlp2.b"])
            site += 1
        h = common.layer_norm(a[:, 0], p["head.ln.g"], p["head.ln.b"])
        return h @ p["head.w"] + p["head.b"]

    return dict(spec=spec, apply=apply, n_act=1 + 2 * depth,
                input_shape=(t, f), kind="speech", classes=classes)
