"""AOT exporter — lowers every L2 graph to HLO **text** artifacts.

Run once via `make artifacts`; Python never appears on the request path.

Interchange format is HLO text, NOT `.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (in --out-dir, default ../artifacts):
  <variant>_<graph>_<mode>.hlo.txt   lowered computations
  <variant>_init_{w,alpha,beta}.bin  raw little-endian f32 init vectors
  manifest.json                      segment tables + artifact registry
  golden_fp8.json                    quantizer golden vectors for the
                                     Rust codec parity tests
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np


# ---- model-variant registry -----------------------------------------
# kind-level defaults chosen so one artifact set serves the whole bench
# suite; server_p / u_steps / batch are baked into artifact shapes and
# recorded in the manifest (the Rust config validates against them).
VISION = dict(u_steps=10, batch=32, eval_batch=256, server_p=10,
              optimizer="sgd")
SPEECH = dict(u_steps=10, batch=16, eval_batch=256, server_p=8,
              optimizer="adamw")

VARIANTS = {
    "mlp_c10": dict(model="mlp", classes=10, **VISION),
    "lenet_c10": dict(model="lenet", classes=10, **VISION),
    "lenet_c100": dict(model="lenet", classes=100, **VISION),
    "resnet8_c10": dict(model="resnet8", classes=10, **VISION),
    "resnet8_c100": dict(model="resnet8", classes=100, **VISION),
    "matchbox": dict(model="matchbox", classes=12, **SPEECH),
    "kwt": dict(model="kwt", classes=12, **SPEECH),
}

# QAT modes per variant: det + none everywhere (Table 1 / Fig 2);
# rand additionally for the Table 2 ablation variants.
RAND_QAT_VARIANTS = ("lenet_c100", "resnet8_c100", "lenet_c10")

BETA_INIT = 4.0


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def export_variant(vname: str, cfg: dict, out_dir: str) -> dict:
    from . import model as M

    entry = {}
    files = {}
    for mode in ("det", "none") + (("rand",) if vname in RAND_QAT_VARIANTS
                                   else ()):
        mdl, _, lows = M.lowered_graphs(
            cfg["model"], cfg["classes"], mode,
            u_steps=cfg["u_steps"], batch=cfg["batch"],
            eval_batch=cfg["eval_batch"], server_p=cfg["server_p"],
            optimizer=cfg["optimizer"])
        for gname, low in lows.items():
            fname = f"{vname}_{gname}_{mode}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(to_hlo_text(low))
            files[f"{gname}_{mode}"] = fname
        entry["mdl"] = mdl

    mdl = entry["mdl"]
    spec = mdl["spec"]
    # deterministic across processes (python's hash() is salted)
    import zlib
    rng = np.random.default_rng(zlib.crc32(vname.encode()))
    w0, alpha0 = spec.init_flat(rng)
    beta0 = np.full(mdl["n_act"], BETA_INIT, np.float32)
    init = {}
    for tag, arr in (("w", w0), ("alpha", alpha0), ("beta", beta0)):
        fname = f"{vname}_init_{tag}.bin"
        arr.astype("<f4").tofile(os.path.join(out_dir, fname))
        init[tag] = fname

    man = spec.to_manifest()
    man.update(
        n_act=mdl["n_act"], classes=cfg["classes"], kind=mdl["kind"],
        input_shape=list(mdl["input_shape"]), u_steps=cfg["u_steps"],
        batch=cfg["batch"], eval_batch=cfg["eval_batch"],
        server_p=cfg["server_p"], optimizer=cfg["optimizer"],
        artifacts=files, init=init)
    return man


def export_quant_demo(out_dir: str) -> dict:
    """Standalone L1-kernel artifact: lets Rust integration tests run the
    Pallas quantizer directly and compare it against the wire codec."""
    import jax
    import jax.numpy as jnp

    from .kernels import fp8_quant

    n = 1024
    s = jax.ShapeDtypeStruct((n,), jnp.float32)

    def f(x, alpha, u):
        return fp8_quant.fp8_quantize(x, alpha, u)

    low = jax.jit(f).lower(s, s, s)
    fname = "quant_demo.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as fh:
        fh.write(to_hlo_text(low))
    return {"file": fname, "n": n}


def export_goldens(out_dir: str) -> None:
    """Golden vectors: Rust codec must reproduce quantize_np (f64 math,
    f32 result) and the 256-entry decode tables."""
    from .kernels import ref

    rng = np.random.default_rng(7)
    cases = []
    for alpha in (1.0, 0.6455, 3.7, 17.0, 0.015625):
        x = (rng.normal(size=256) * alpha * 0.7).astype(np.float32)
        # include exact edge cases
        x[:8] = [0.0, alpha, -alpha, alpha * 2, -alpha * 2,
                 alpha * 1e-6, np.float32(alpha) / 2, -np.float32(alpha) / 3]
        u_det = np.full(x.shape, 0.5)
        u_rnd = rng.random(size=x.shape)
        q_det = ref.quantize_np(x, np.float32(alpha), u_det)
        q_rnd = ref.quantize_np(x, np.float32(alpha), u_rnd)
        cases.append({
            "alpha": float(alpha),
            "x": [float(v) for v in x],
            "u": [float(v) for v in u_rnd],
            "q_det": [float(v) for v in q_det],
            "q_rand": [float(v) for v in q_rnd],
        })
    # decode tables: non-negative grid, 128 points per alpha
    tables = {}
    for alpha in (1.0, 3.7):
        tables[str(alpha)] = [float(v) for v in
                              ref.grid_points(alpha).astype(np.float32)]
    with open(os.path.join(out_dir, "golden_fp8.json"), "w") as f:
        json.dump({"m": ref.M_BITS, "e": ref.E_BITS, "cases": cases,
                   "grids": tables}, f)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--variants", default="all",
                    help="comma-separated variant names or 'all'")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    names = (list(VARIANTS) if args.variants == "all"
             else args.variants.split(","))
    manifest = {"format": {"m": 3, "e": 4}, "models": {}}
    for vname in names:
        print(f"[aot] exporting {vname} ...", flush=True)
        manifest["models"][vname] = export_variant(
            vname, VARIANTS[vname], args.out_dir)
    manifest["quant_demo"] = export_quant_demo(args.out_dir)
    export_goldens(args.out_dir)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote manifest with {len(manifest['models'])} variants "
          f"to {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
