"""L2 — the jax compute graphs exported as AOT artifacts.

For every model variant four graph families are built (per QAT mode
det / rand / none):

  local_update   one full client round: `lax.scan` over U local
                 SGD/AdamW steps of quantization-aware training. Scanning
                 inside the artifact (instead of one-step dispatch from
                 Rust) amortizes dispatch overhead U-fold and lets XLA
                 fuse the optimizer update into the backward pass — this
                 is the L2 perf deliverable (see EXPERIMENTS.md §Perf).
  evaluate       test loss-sum + correct-count on one batch (quantized
                 weights for FP8 modes — the paper evaluates the
                 quantized server model).
  server_opt     one gradient-descent step of ServerOptimize Eq. (4):
                 min_w sum_k (n_k/m_t) ||Q_rand(w; abar) - w_hat_k||^2
                 with STE gradients through Q_rand; the Eq. (5) alpha
                 grid search runs in Rust on the wire codec.
  forward        logits only (debug / example use).

ABI (all f32 unless noted): flat weights w[D], per-tensor clips
alpha[A], activation clips beta[n_act]; batches xs[U,B,...]/ys[U,B] i32;
scalars lr, wd; seed i32 (only read by `rand` variants).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import fp8
from .models import BUILDERS, common

ALPHA_MIN = 1e-3
ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def build_model(name: str, classes: int, **kw):
    return BUILDERS[name](classes, **kw)


def _act_sizes(model) -> list:
    """Per-site activation element counts per example (for LSQ-style
    gradient normalization of beta), recorded via an abstract dry run."""
    sizes = {}

    def qact(site, a):
        sizes[site] = int(np.prod(a.shape[1:]))
        return a

    spec = model["spec"]
    x = jnp.zeros((2,) + tuple(model["input_shape"]), jnp.float32)
    w = jnp.zeros((spec.dim,), jnp.float32)
    jax.eval_shape(lambda w, x: model["apply"](spec.unflatten(w), x, qact),
                   w, x)
    return [sizes.get(i, 1) for i in range(model["n_act"])]


class Graphs:
    """Traced-graph factory for one (model, qat_mode) pair."""

    def __init__(self, model: dict, qat_mode: str):
        assert qat_mode in ("det", "rand", "none")
        self.model = model
        self.spec = model["spec"]
        self.mode = qat_mode
        self.qmask = jnp.asarray(self.spec.qmask)
        self.alpha_gscale = jnp.sqrt(
            jnp.asarray(self.spec.alpha_sizes, jnp.float32))
        sizes = _act_sizes(model)
        self.beta_gscale = jnp.sqrt(jnp.asarray(sizes, jnp.float32))

    # ---- forward / loss -------------------------------------------
    def forward(self, w, alpha, beta, x, key):
        spec, mode = self.spec, self.mode
        if mode == "none":
            params = spec.unflatten(w)
            return self.model["apply"](params, x, lambda s, a: a)
        alpha_el = spec.alpha_elem(alpha)
        if mode == "det":
            u_w = jnp.full(w.shape, 0.5, w.dtype)
        else:
            u_w = jax.random.uniform(jax.random.fold_in(key, 0xFFFF),
                                     w.shape, w.dtype)
        wq = fp8.quantize_weights(w, alpha_el, self.qmask, u_w)
        params = spec.unflatten(wq)

        def qact(site, a):
            if mode == "det":
                u = jnp.full(a.shape, 0.5, a.dtype)
            else:
                u = jax.random.uniform(jax.random.fold_in(key, site),
                                       a.shape, a.dtype)
            return fp8.quantize_act(a, beta[site], u)

        return self.model["apply"](params, x, qact)

    def loss(self, w, alpha, beta, x, y, key):
        logits = self.forward(w, alpha, beta, x, key)
        return common.cross_entropy(logits, y)

    # ---- local updates ---------------------------------------------
    def local_update_sgd(self, w, alpha, beta, xs, ys, lr, wd, seed):
        """U steps of local SGD with weight decay (image tasks)."""
        u_steps = xs.shape[0]
        base = jax.random.PRNGKey(seed)
        keys = jax.random.split(base, u_steps)

        def step(carry, inp):
            w, alpha, beta = carry
            x, y, key = inp
            l, grads = jax.value_and_grad(
                lambda w, a, b: self.loss(w, a, b, x, y, key),
                argnums=(0, 1, 2))(w, alpha, beta)
            gw, ga, gb = grads
            w = w - lr * (gw + wd * w)
            alpha = jnp.maximum(alpha - lr * ga / self.alpha_gscale,
                                ALPHA_MIN)
            beta = jnp.maximum(beta - lr * gb / self.beta_gscale,
                               ALPHA_MIN)
            return (w, alpha, beta), l

        (w, alpha, beta), losses = jax.lax.scan(
            step, (w, alpha, beta), (xs, ys, keys))
        return w, alpha, beta, losses.mean()

    def local_update_adamw(self, w, alpha, beta, xs, ys, lr, wd, seed):
        """U steps of local AdamW (speech tasks); optimizer state is
        reset at round start (standard FL practice)."""
        u_steps = xs.shape[0]
        base = jax.random.PRNGKey(seed)
        keys = jax.random.split(base, u_steps)
        zeros = lambda v: jnp.zeros_like(v)
        state0 = ((w, alpha, beta),
                  (zeros(w), zeros(alpha), zeros(beta)),
                  (zeros(w), zeros(alpha), zeros(beta)),
                  jnp.zeros((), jnp.float32))

        def step(carry, inp):
            (w, alpha, beta), ms, vs, t = carry
            x, y, key = inp
            l, grads = jax.value_and_grad(
                lambda w, a, b: self.loss(w, a, b, x, y, key),
                argnums=(0, 1, 2))(w, alpha, beta)
            gw, ga, gb = grads
            ga = ga / self.alpha_gscale
            gb = gb / self.beta_gscale
            t = t + 1.0
            c1 = 1.0 - ADAM_B1 ** t
            c2 = 1.0 - ADAM_B2 ** t

            def upd(p, m, v, g, decay):
                m = ADAM_B1 * m + (1 - ADAM_B1) * g
                v = ADAM_B2 * v + (1 - ADAM_B2) * g * g
                p = p - lr * ((m / c1) / (jnp.sqrt(v / c2) + ADAM_EPS)
                              + decay * p)
                return p, m, v

            w, mw, vw = upd(w, ms[0], vs[0], gw, wd)
            alpha, ma, va = upd(alpha, ms[1], vs[1], ga, 0.0)
            beta, mb, vb = upd(beta, ms[2], vs[2], gb, 0.0)
            alpha = jnp.maximum(alpha, ALPHA_MIN)
            beta = jnp.maximum(beta, ALPHA_MIN)
            return (((w, alpha, beta), (mw, ma, mb), (vw, va, vb), t), l)

        (params, _, _, _), losses = jax.lax.scan(
            step, state0, (xs, ys, keys))
        w, alpha, beta = params
        return w, alpha, beta, losses.mean()

    # ---- evaluation -------------------------------------------------
    def evaluate(self, w, alpha, beta, x, y):
        """Deterministic (u=0.5) quantized eval for FP8 modes."""
        key = jax.random.PRNGKey(0)
        mode = self.mode
        if mode == "rand":
            # evaluation is always deterministic
            g = Graphs.__new__(Graphs)
            g.__dict__.update(self.__dict__)
            g.mode = "det"
            logits = g.forward(w, alpha, beta, x, key)
        else:
            logits = self.forward(w, alpha, beta, x, key)
        logz = jax.nn.log_softmax(logits)
        onehot = jax.nn.one_hot(y, logits.shape[-1], dtype=logz.dtype)
        nll = -(logz * onehot).sum()
        correct = (jnp.argmax(logits, axis=1) == y).sum().astype(jnp.int32)
        return nll, correct

    # ---- ServerOptimize Eq. (4) -------------------------------------
    def server_opt_step(self, w, alpha, clients, kweights, u, lr):
        """One GD step on sum_k kw_k ||Q_rand(w; alpha) - what_k||^2.

        clients: dequantized client uplinks [P, D] (already on their own
        grids); u: the round's stochastic-rounding draw for Q_rand(w),
        supplied by the Rust coordinator's RNG.
        """
        alpha_el = self.spec.alpha_elem(alpha)

        def mse(w):
            qw = fp8.quantize_weights(w, alpha_el, self.qmask, u)
            d = qw[None, :] - clients
            return jnp.sum(kweights * jnp.sum(d * d, axis=1))

        val, gw = jax.value_and_grad(mse)(w)
        return w - lr * gw, val


# ---- export-ready jitted signatures --------------------------------

def lowered_graphs(name: str, classes: int, qat_mode: str, *,
                   u_steps: int, batch: int, eval_batch: int,
                   server_p: int, optimizer: str, model_kw=None):
    """Build all lowered (not yet serialized) computations for a model
    variant; returns (model, {artifact_name: lowered})."""
    model = build_model(name, classes, **(model_kw or {}))
    g = Graphs(model, qat_mode)
    spec = model["spec"]
    ishape = tuple(model["input_shape"])
    f32 = jnp.float32
    s_w = jax.ShapeDtypeStruct((spec.dim,), f32)
    s_a = jax.ShapeDtypeStruct((spec.alpha_dim,), f32)
    s_b = jax.ShapeDtypeStruct((model["n_act"],), f32)
    s_xs = jax.ShapeDtypeStruct((u_steps, batch) + ishape, f32)
    s_ys = jax.ShapeDtypeStruct((u_steps, batch), jnp.int32)
    s_x = jax.ShapeDtypeStruct((eval_batch,) + ishape, f32)
    s_y = jax.ShapeDtypeStruct((eval_batch,), jnp.int32)
    s_s = jax.ShapeDtypeStruct((), f32)
    s_seed = jax.ShapeDtypeStruct((), jnp.int32)
    s_cl = jax.ShapeDtypeStruct((server_p, spec.dim), f32)
    s_kw = jax.ShapeDtypeStruct((server_p,), f32)

    upd = (g.local_update_adamw if optimizer == "adamw"
           else g.local_update_sgd)

    def local_update(w, alpha, beta, xs, ys, lr, wd, seed):
        return upd(w, alpha, beta, xs, ys, lr, wd, seed)

    def evaluate(w, alpha, beta, x, y):
        return g.evaluate(w, alpha, beta, x, y)

    def server_opt(w, alpha, clients, kweights, u, lr):
        return g.server_opt_step(w, alpha, clients, kweights, u, lr)

    out = {
        "local_update": jax.jit(local_update, keep_unused=True).lower(
            s_w, s_a, s_b, s_xs, s_ys, s_s, s_s, s_seed),
        "evaluate": jax.jit(evaluate, keep_unused=True).lower(s_w, s_a, s_b, s_x, s_y),
    }
    if qat_mode != "none":
        out["server_opt"] = jax.jit(server_opt, keep_unused=True).lower(
            s_w, s_a, s_cl, s_kw, s_w, s_s)
    return model, g, out
