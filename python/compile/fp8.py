"""L2 glue — differentiable FP8 quantization-aware-training ops.

Wraps the L1 Pallas kernel in `jax.custom_vjp` rules implementing the
paper's gradient conventions (§2, "On-Device Quantization-Aware
Training"):

  * straight-through estimator through the rounding:   d round(z)/dz = 1
  * `floor(log2|x| + b)` treated as a CONSTANT (Kuzmin et al.), so the
    scale s does not contribute to dQ/dx;
  * learnable clipping value alpha with the LSQ-style gradient that the
    constant-c convention induces (s is proportional to alpha with c
    frozen, hence Q(x) - x scales linearly in alpha locally):

        dQ/dalpha = (Q(x) - x) / alpha      for |x| <= alpha
                  =  sign(x)                for |x| >  alpha  (clipped)

        dQ/dx     =  1                      for |x| <= alpha   (STE)
                  =  0                      for |x| >  alpha

The rounding threshold u is a non-differentiable input (0.5 for Q_det,
uniform random for Q_rand), so one pair of fns serves both quantizers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import fp8_quant


@jax.custom_vjp
def quantize_ste(x, alpha, u):
    """FP8-quantize x with per-element clipping alpha; STE gradients."""
    return fp8_quant.fp8_quantize_whole(x, alpha, u)


def _quantize_fwd(x, alpha, u):
    alpha_b = jnp.broadcast_to(jnp.asarray(alpha, x.dtype), x.shape)
    q = fp8_quant.fp8_quantize_whole(x, alpha_b, u)
    return q, (x, alpha_b, q)


def _quantize_bwd(res, g):
    x, alpha_b, q = res
    inside = jnp.abs(x) <= alpha_b
    dx = jnp.where(inside, g, jnp.zeros_like(g))
    dalpha_elem = jnp.where(inside, (q - x) / alpha_b, jnp.sign(x)) * g
    # alpha may have been broadcast from a scalar/smaller shape; jax sums
    # the cotangent back automatically only if we return the broadcast
    # shape and the caller used jnp.broadcast_to explicitly. We return the
    # per-element cotangent; callers pass alpha already expanded.
    return dx, dalpha_elem, None


quantize_ste.defvjp(_quantize_fwd, _quantize_bwd)


def quantize_weights(w_flat, alpha_elem, qmask, u):
    """Quantize the full flat weight vector in one kernel launch.

    alpha_elem: per-element clipping values (per-tensor alphas expanded
    by the model's segment table). qmask: static bool vector — biases and
    normalization parameters are NOT quantized (paper §4: <2% of params,
    sent in FP32). Gradients flow to alpha_elem only through quantized
    positions.
    """
    q = quantize_ste(w_flat, alpha_elem, u)
    return jnp.where(qmask, q, w_flat)


def quantize_act(a, beta, u_scalar):
    """Activation fake-quant with scalar learnable clip beta.

    beta enters via broadcast; its cotangent is the sum over the tensor
    (handled by broadcast_to's transpose).
    """
    beta_b = jnp.broadcast_to(beta, a.shape)
    u = jnp.full(a.shape, u_scalar, a.dtype)
    return quantize_ste(a, beta_b, u)
