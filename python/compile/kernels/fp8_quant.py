"""L1 — Pallas kernel for flexible-bias FP8 quantization.

The compute hot-spot of FP8FedAvg-UQ: every local training step quantizes
the full (flat) weight vector and every activation tensor onto the FP8
grid. The kernel is element-wise over (x, alpha, u):

    x      values to quantize
    alpha  per-element clipping value (per-tensor alphas are expanded to
           per-element by the caller, so ONE kernel launch covers all
           weight tensors of the network)
    u      rounding threshold in [0,1): 0.5 = deterministic round-half-up,
           uniform random = unbiased stochastic rounding

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper simulates
FP8 on CUDA GPUs; on TPU this op is VPU-bound element-wise work. We tile
the flat vector into (BLOCK_ROWS, 128)-shaped VMEM blocks — 128 is the TPU
lane width — and sweep the row dimension with the grid so HBM<->VMEM
transfers are double-buffered by the Mosaic pipeline. On CPU (this repo's
execution substrate) the kernel MUST run with interpret=True: real TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
Under jit, interpret mode inlines into plain HLO, so the exported artifact
is self-contained.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

LANES = 128  # TPU vector lane width; last-dim tile size.
DEFAULT_BLOCK_ROWS = 256  # (256, 128) f32 tile = 128 KiB VMEM per operand.


def _quant_kernel(x_ref, a_ref, u_ref, o_ref):
    """Element-wise FP8 quantization of one VMEM block."""
    x = x_ref[...]
    alpha = a_ref[...]
    u = u_ref[...]
    b = 2.0**ref.E_BITS - jnp.log2(alpha) + ref.LOG2_TOP - 1.0
    absx = jnp.abs(x)
    safe = jnp.where(absx > 0, absx, jnp.ones_like(absx))
    c = jnp.floor(jnp.log2(safe) + b)
    log2s = jnp.where(c > 1.0, c, jnp.ones_like(c)) - b - ref.M_BITS
    s = jnp.exp2(log2s)
    z = x / s
    f = jnp.floor(z)
    q = (f + (z - f >= u).astype(x.dtype)) * s
    q = jnp.clip(q, -alpha, alpha)
    o_ref[...] = jnp.where(absx > 0, q, jnp.zeros_like(q))


def fp8_quantize(x, alpha, u, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                 interpret: bool = True):
    """Quantize an arbitrary-shape array onto the FP8(alpha) grid.

    alpha and u are broadcast to x's shape. The array is flattened,
    padded to a whole number of (block_rows, LANES) tiles and swept by a
    1-D grid; per-block VMEM footprint is 4 tiles (x, alpha, u, out).
    """
    orig_shape = x.shape
    dtype = x.dtype
    xf = jnp.ravel(x)
    af = jnp.broadcast_to(jnp.asarray(alpha, dtype), x.shape).ravel()
    uf = jnp.broadcast_to(jnp.asarray(u, dtype), x.shape).ravel()

    n = xf.shape[0]
    tile = block_rows * LANES
    rows = -(-n // LANES)  # ceil-div: rows of 128 lanes
    grid_rows = -(-rows // block_rows) * block_rows
    pad = grid_rows * LANES - n
    # Pad with ones: log2(1) is finite, keeps the kernel free of special
    # cases for the padding tail.
    xf = jnp.concatenate([xf, jnp.ones((pad,), dtype)])
    af = jnp.concatenate([af, jnp.ones((pad,), dtype)])
    uf = jnp.concatenate([uf, jnp.full((pad,), 0.5, dtype)])

    x2 = xf.reshape(grid_rows, LANES)
    a2 = af.reshape(grid_rows, LANES)
    u2 = uf.reshape(grid_rows, LANES)

    grid = (grid_rows // block_rows,)
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    out = pl.pallas_call(
        _quant_kernel,
        out_shape=jax.ShapeDtypeStruct((grid_rows, LANES), dtype),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        interpret=interpret,
    )(x2, a2, u2)
    return out.ravel()[:n].reshape(orig_shape)


def fp8_quantize_whole(x, alpha, u, *, interpret: bool = True):
    """Single-block variant (no grid): whole array as one VMEM block.

    Used for small tensors (activations) where tiling overhead dominates;
    also the fallback exercised by the shape-sweep hypothesis tests.
    """
    orig_shape = x.shape
    dtype = x.dtype
    xf = jnp.ravel(x)
    af = jnp.broadcast_to(jnp.asarray(alpha, dtype), x.shape).ravel()
    uf = jnp.broadcast_to(jnp.asarray(u, dtype), x.shape).ravel()
    n = xf.shape[0]
    pad = (-n) % LANES
    xf = jnp.concatenate([xf, jnp.ones((pad,), dtype)])
    af = jnp.concatenate([af, jnp.ones((pad,), dtype)])
    uf = jnp.concatenate([uf, jnp.full((pad,), 0.5, dtype)])
    rows = (n + pad) // LANES
    out = pl.pallas_call(
        _quant_kernel,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), dtype),
        interpret=interpret,
    )(xf.reshape(rows, LANES), af.reshape(rows, LANES),
      uf.reshape(rows, LANES))
    return out.ravel()[:n].reshape(orig_shape)
