"""Pure-jnp / numpy oracle for the flexible-bias FP8 quantizer.

This is the single written-out source of truth for the number format used
by all three layers (Pallas kernel, JAX QAT graphs, Rust wire codec):

    1 sign bit, e=4 exponent bits, m=3 mantissa bits, *real-valued*
    exponent bias derived from the per-tensor clipping value alpha
    (Kuzmin et al., "FP8 quantization: the power of the exponent"):

        b = 2^e - log2(alpha) + log2(2 - 2^-m) - 1

    so that the largest finite code (E=15, M=7) decodes exactly to alpha.

Quantization of x (paper Eq. 2):

        c      = floor(log2|x| + b)
        log2 s = c - b - m         if c > 1      (normal range)
               = 1 - b - m         otherwise     (subnormal range)
        q      = s * rnd(x / s),   clipped to [-alpha, alpha]

`rnd` is parameterised by a uniform sample u in [0, 1):

        rnd(z) = floor(z) + [frac(z) >= u]

    u = 0.5        -> deterministic round-half-up        (Q_det)
    u ~ U[0, 1)    -> unbiased stochastic rounding       (Q_rand)
                      (P[round up] = frac(z), Lemma 3 of the paper)

Two implementations live here:
  * `quantize` — jnp, float32, traceable; the oracle the Pallas kernel is
    tested against.
  * `quantize_np` — numpy, float64 internal math, float32 in/out; the
    oracle the Rust codec is tested against (the Rust codec also computes
    in f64 and casts the dequantized result to f32).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

M_BITS = 3
E_BITS = 4
# log2(2 - 2^-m): offset making the top code land exactly on alpha.
LOG2_TOP = float(np.log2(2.0 - 2.0 ** (-M_BITS)))


def bias_from_alpha(alpha):
    """Real-valued exponent bias b for clipping value alpha (jnp)."""
    return 2.0**E_BITS - jnp.log2(alpha) + LOG2_TOP - 1.0


def scale(x, alpha):
    """Element-wise quantization scale s_i (paper Eq. 2), jnp."""
    b = bias_from_alpha(alpha)
    absx = jnp.abs(x)
    safe = jnp.where(absx > 0, absx, jnp.ones_like(absx))
    c = jnp.floor(jnp.log2(safe) + b)
    log2s = jnp.where(c > 1.0, c, jnp.ones_like(c)) - b - M_BITS
    return jnp.exp2(log2s)


def quantize(x, alpha, u):
    """Quantize x onto the FP8(alpha) grid; u parameterises the rounding.

    x, u: same-shape arrays. alpha: scalar or broadcastable array of
    per-element clipping values. u = 0.5 gives Q_det; u ~ U[0,1) gives
    Q_rand. Output is float32 values lying exactly on the grid.
    """
    s = scale(x, alpha)
    z = x / s
    f = jnp.floor(z)
    up = (z - f >= u).astype(x.dtype)
    q = (f + up) * s
    q = jnp.clip(q, -alpha, alpha)
    return jnp.where(jnp.abs(x) > 0, q, jnp.zeros_like(q))


def quantize_np(x, alpha, u):
    """float64-internal numpy twin of `quantize` (Rust-codec oracle)."""
    x64 = np.asarray(x, dtype=np.float64)
    a64 = np.asarray(alpha, dtype=np.float64)
    u64 = np.asarray(u, dtype=np.float64)
    b = 2.0**E_BITS - np.log2(a64) + LOG2_TOP - 1.0
    absx = np.abs(x64)
    safe = np.where(absx > 0, absx, 1.0)
    c = np.floor(np.log2(safe) + b)
    log2s = np.where(c > 1.0, c, 1.0) - b - M_BITS
    s = np.exp2(log2s)
    z = x64 / s
    f = np.floor(z)
    q = (f + (z - f >= u64)) * s
    q = np.clip(q, -a64, a64)
    q = np.where(absx > 0, q, 0.0)
    return q.astype(np.float32)


def grid_points(alpha: float) -> np.ndarray:
    """All non-negative representable values for a given alpha (float64).

    Used by property tests: every quantizer output must be a grid member;
    grid spacing must be monotone non-decreasing away from zero (the
    condition under which the paper's Lemma 5 holds).
    """
    b = 2.0**E_BITS - np.log2(float(alpha)) + LOG2_TOP - 1.0
    pts = []
    for enc in range(2**E_BITS):
        for man in range(2**M_BITS):
            if enc == 0:
                v = 2.0 ** (1.0 - b) * man / 2.0**M_BITS
            else:
                v = 2.0 ** (enc - b) * (1.0 + man / 2.0**M_BITS)
            pts.append(v)
    return np.array(sorted(set(pts)))
