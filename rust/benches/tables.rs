//! Smoke-scale regeneration of every paper table/figure — `cargo bench
//! --bench tables` runs Table 1 (subset), Table 2 and Figure 2 at a
//! reduced round budget so the full evaluation pipeline is exercised
//! in minutes. For the real (longer) runs use the `fedfp8` binary:
//!
//! ```sh
//! cargo run --release -- table1 --rounds 60 --seeds 3
//! cargo run --release -- table2 --rounds 60 --seeds 3
//! cargo run --release -- fig2   --rounds 60 --model lenet_c10
//! ```

use fedfp8::bench_tables::{fig2, table1, table2};
use fedfp8::runtime::default_dir;
use fedfp8::util::cli::Args;

fn main() -> anyhow::Result<()> {
    if !default_dir().join("manifest.json").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        return Ok(());
    }
    let smoke = |extra: &str| {
        Args::parse(
            format!(
                "--rounds 12 --seeds 1 --n-train 1200 --eval-every 2 \
                 {extra}"
            )
            .split_whitespace()
            .map(String::from),
        )
    };
    println!("=== Table 1 (smoke subset: lenet_c10 + matchbox) ===");
    table1::run(&smoke("--models lenet_c10,matchbox"))?;
    println!("\n=== Table 2 (smoke: lenet_c100) ===");
    table2::run(&smoke("--models lenet_c100"))?;
    println!("\n=== Figure 2 (smoke: mlp_c10) ===");
    fig2::run(&smoke("--model mlp_c10"))?;
    Ok(())
}
