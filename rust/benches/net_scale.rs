//! Connections-vs-threads scaling bench, seeding
//! `BENCH_net_scale.json` — the curve proving the transport's
//! structural wall moved.
//!
//! Run: `cargo bench --bench net_scale`. For each fleet size N it
//! stands up N loopback connections pre-loaded with a burst of
//! Outcome-sized frames, then drains every frame two ways:
//!
//! * **poll** — the event-driven shape: ONE thread, one
//!   [`Poller`], N non-blocking sockets each drained through its own
//!   resumable `FrameReader` (exactly the server's poll-loop data
//!   path).
//! * **threads** — the pre-refactor shape: N spawned threads, each
//!   blocking-reading its own socket (the server's old
//!   thread-per-connection reader architecture).
//!
//! Both arms pay identical setup (socket creation + frame priming
//! inside the timed closure), so the delta isolates what N reader
//! threads cost over one readiness loop: spawn/teardown, stacks, and
//! scheduler churn — the terms that scaled with fleet size. CI smoke:
//! `cargo bench --bench net_scale -- --quick` shrinks the matrix and
//! skips the JSON write.

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::thread;

use fedfp8::net::frame::{self, FrameKind, FrameReader};
use fedfp8::net::poll::{Poller, BACKEND};
use fedfp8::util::bench::{bench, header, BenchJson};

const BODY_BYTES: usize = 64;

/// N primed loopback connections: every read end already holds
/// `frames` complete Outcome-sized frames in its socket buffer.
fn primed_pairs(n: usize, frames: usize) -> Vec<(TcpStream, TcpStream)> {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let body = [7u8; BODY_BYTES];
    (0..n)
        .map(|_| {
            let mut w = TcpStream::connect(addr).unwrap();
            let (r, _) = listener.accept().unwrap();
            w.set_nodelay(true).unwrap();
            for _ in 0..frames {
                frame::write_frame(&mut w, FrameKind::Outcome, &body)
                    .unwrap();
            }
            w.flush().unwrap();
            (w, r)
        })
        .collect()
}

/// One thread, one poller, N FrameReaders — the poll-loop data path.
fn drain_poll(n: usize, frames: usize) {
    let pairs = primed_pairs(n, frames);
    let mut poller = Poller::new().unwrap();
    let mut conns: Vec<(TcpStream, FrameReader, usize)> = Vec::new();
    for (i, (_w, r)) in pairs.iter().enumerate() {
        r.set_nonblocking(true).unwrap();
        poller.register_stream(r, i as u64).unwrap();
        conns.push((r.try_clone().unwrap(), FrameReader::new(), 0));
    }
    let mut remaining = n * frames;
    let mut ready = Vec::new();
    while remaining > 0 {
        poller
            .wait(std::time::Duration::from_millis(10), &mut ready)
            .unwrap();
        for &t in &ready {
            let (stream, fr, got) = &mut conns[t as usize];
            while *got < frames {
                match fr.poll(stream) {
                    Ok(Some(f)) => {
                        assert_eq!(f.body.len(), BODY_BYTES);
                        *got += 1;
                        remaining -= 1;
                    }
                    Ok(None) => break,
                    Err(e) => panic!("poll drain failed: {e}"),
                }
            }
        }
    }
}

/// N spawned threads, each blocking on its own socket — the
/// thread-per-connection data path this PR retires.
fn drain_threads(n: usize, frames: usize) {
    let pairs = primed_pairs(n, frames);
    thread::scope(|s| {
        for (_w, r) in pairs.iter() {
            let mut r = r.try_clone().unwrap();
            s.spawn(move || {
                for _ in 0..frames {
                    let f = frame::read_frame(&mut r)
                        .expect("thread drain failed");
                    assert_eq!(f.body.len(), BODY_BYTES);
                }
            });
        }
    });
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (fleet, frames, budget_ms): (&[usize], usize, u64) = if quick {
        (&[4, 16], 16, 60)
    } else {
        (&[8, 32, 128], 64, 400)
    };
    println!(
        "readiness backend: {BACKEND}; {frames} frames x {BODY_BYTES} B \
         bodies per connection\n"
    );
    header();
    let mut j = BenchJson::new(
        "net_scale",
        "cargo bench --bench net_scale (rust/benches/net_scale.rs)",
    );
    j.config("backend", BACKEND);
    j.config("frames_per_conn", frames);
    j.config("body_bytes", BODY_BYTES);
    j.config("fleet_sizes", format!("{fleet:?}"));
    for &n in fleet {
        let items = (n * frames) as f64;
        let poll = bench(
            &format!("net_scale/poll_1thread_n{n}"),
            budget_ms,
            || drain_poll(n, frames),
        );
        let thr = bench(
            &format!("net_scale/threads_n{n}"),
            budget_ms,
            || drain_threads(n, frames),
        );
        j.push(&poll, Some(items));
        j.push(&thr, Some(items));
        // >1 = the single poll loop beats N reader threads
        j.speedup(
            &format!("poll_over_threads_n{n}"),
            thr.median_ns / poll.median_ns,
        );
    }
    if quick {
        println!("\n--quick: JSON trajectory write skipped");
        return;
    }
    let path = std::path::Path::new("../BENCH_net_scale.json");
    match j.write(path) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", path.display()),
    }
}
