//! Round latency vs. `parallelism` — measures the win of the parallel
//! client pipeline on a synthetic 8-client cohort.
//!
//! Two sections:
//!  * mock transport (always runs): each "client" burns a fixed chunk
//!    of real FP8-quantization CPU work, so the scaling reflects
//!    genuine parallel compute, not sleeps;
//!  * real engine (artifact-gated): the same sweep through the PJRT
//!    in-process transport when `make artifacts` has been run.
//!
//! Run: `cargo bench --bench round_parallel`

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::Result;
use fedfp8::config::ExperimentConfig;
use fedfp8::coordinator::client::LocalUpdate;
use fedfp8::coordinator::transport::{
    finish_uplink, ClientJob, ClientOutcome, Transport, WorkBuffers,
};
use fedfp8::coordinator::Server;
use fedfp8::fp8::codec::Segment;
use fedfp8::fp8::format::Fp8Params;
use fedfp8::runtime::{
    artifacts_available, default_dir, Engine, Manifest, ModelInfo,
};
use fedfp8::util::bench::{bench, header};

const DIM: usize = 4096;

fn write_f32(path: &Path, vals: &[f32]) {
    let bytes: Vec<u8> =
        vals.iter().flat_map(|v| v.to_le_bytes()).collect();
    std::fs::write(path, bytes).unwrap();
}

fn mock_manifest() -> (PathBuf, Manifest) {
    let dir = std::env::temp_dir()
        .join(format!("fedfp8_bench_par_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let w: Vec<f32> =
        (0..DIM).map(|i| (i as f32 * 0.37).sin() * 0.5).collect();
    write_f32(&dir.join("w.bin"), &w);
    write_f32(&dir.join("alpha.bin"), &[1.0]);
    write_f32(&dir.join("beta.bin"), &[2.0]);
    let segments = vec![
        Segment {
            name: "w".into(),
            offset: 0,
            size: DIM - 32,
            quantized: true,
            alpha_idx: Some(0),
        },
        Segment {
            name: "bias".into(),
            offset: DIM - 32,
            size: 32,
            quantized: false,
            alpha_idx: None,
        },
    ];
    let mut init = BTreeMap::new();
    init.insert("w".to_string(), "w.bin".to_string());
    init.insert("alpha".to_string(), "alpha.bin".to_string());
    init.insert("beta".to_string(), "beta.bin".to_string());
    let info = ModelInfo {
        name: "mock".into(),
        dim: DIM,
        alpha_dim: 1,
        n_act: 1,
        classes: 4,
        kind: "vision".into(),
        input_shape: vec![8, 8, 3],
        u_steps: 2,
        batch: 4,
        eval_batch: 8,
        server_p: 0,
        optimizer: "sgd".into(),
        segments,
        artifacts: BTreeMap::new(),
        init,
    };
    let mut models = BTreeMap::new();
    models.insert("mock".to_string(), info);
    (dir.clone(), Manifest { dir, models, quant_demo: None })
}

/// Burns ~`STEPS` passes of scalar FP8 quantization over the model —
/// a deterministic stand-in for U local QAT steps.
struct ComputeTransport;

const STEPS: usize = 20;

impl Transport for ComputeTransport {
    fn run_client(
        &self,
        job: ClientJob<'_>,
        buffers: &mut WorkBuffers,
    ) -> Result<ClientOutcome> {
        let p = Fp8Params::new(job.alpha_start[0]);
        let mut w: Vec<f32> = job.w_start.to_vec();
        for s in 0..STEPS {
            let u = 0.5 + (s as f64) * 1e-3;
            for v in w.iter_mut() {
                *v = 0.999 * p.quantize(*v, u);
            }
        }
        let upd = LocalUpdate {
            w,
            alpha: job.alpha_start.to_vec(),
            beta: job.beta_start.to_vec(),
            mean_loss: 1.0,
        };
        Ok(finish_uplink(job, upd, buffers))
    }
}

fn mock_sweep() -> Result<()> {
    println!("mock transport, 8-client cohort, {DIM}-dim model:");
    for par in [1usize, 2, 4, 8] {
        let (dir, manifest) = mock_manifest();
        let engine = Engine::new(&dir)?;
        let mut cfg = ExperimentConfig::base("mlp_c10")?
            .with_method("uq")?;
        cfg.model = "mock".into();
        cfg.name = format!("bench_par{par}");
        cfg.clients = 8;
        cfg.participation = 8;
        cfg.n_train = 256;
        cfg.n_test = 32;
        cfg.parallelism = par;
        let mut server = Server::with_transport(
            &engine,
            &manifest,
            cfg,
            Box::new(ComputeTransport),
        )?;
        let mut t = 0usize;
        bench(&format!("round/mock cohort=8 par={par}"), 1200, || {
            server.round(t).unwrap();
            t += 1;
        });
    }
    Ok(())
}

fn engine_sweep() -> Result<()> {
    if !artifacts_available() {
        println!(
            "(real-engine sweep skipped: artifacts not built — run \
             `make artifacts`)"
        );
        return Ok(());
    }
    let dir = default_dir();
    let engine = Engine::new(&dir)?;
    let manifest = Manifest::load(&dir)?;
    println!("\nreal engine (PJRT), mlp_c10 K=16 P=8:");
    for par in [1usize, 2, 4, 8] {
        let mut cfg = ExperimentConfig::preset("mlp_c10:uq:iid")?;
        cfg.clients = 16;
        cfg.participation = 8;
        cfg.n_train = 1000;
        cfg.n_test = 256;
        cfg.parallelism = par;
        let mut server = Server::new(&engine, &manifest, cfg)?;
        server.round(0)?; // warm the executable cache before timing
        let mut t = 1usize;
        bench(&format!("round/pjrt cohort=8 par={par}"), 3000, || {
            server.round(t).unwrap();
            t += 1;
        });
    }
    Ok(())
}

fn main() -> Result<()> {
    header();
    mock_sweep()?;
    engine_sweep()
}
