//! End-to-end round benchmark: one full FP8FedAvg-UQ(+) communication
//! round per iteration (client sampling + downlink + P local updates
//! via HLO + uplinks + aggregation [+ ServerOptimize]).
//!
//! This is the paper-system equivalent of a serving framework's
//! request benchmark; it splits coordinator overhead from HLO compute
//! using the engine's internal timers.
//!
//! Run: `cargo bench --bench round` (requires `make artifacts`).

use fedfp8::config::ExperimentConfig;
use fedfp8::coordinator::Server;
use fedfp8::runtime::{default_dir, Engine, Manifest};
use fedfp8::util::bench::{bench, header};

fn main() -> anyhow::Result<()> {
    let dir = default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        return Ok(());
    }
    let engine = Engine::new(&dir)?;
    let manifest = Manifest::load(&dir)?;

    header();
    for (preset, budget_ms) in [
        ("mlp_c10:uq:iid", 4000),
        ("lenet_c10:uq:iid", 4000),
        ("lenet_c10:uq+:iid", 4000),
        ("lenet_c10:fp32:iid", 4000),
        ("resnet8_c10:uq:iid", 6000),
        ("matchbox:uq:speaker", 6000),
    ] {
        let mut cfg = ExperimentConfig::preset(preset)?;
        cfg.n_train = 2000;
        cfg.n_test = 256;
        let mut server = Server::new(&engine, &manifest, cfg)?;
        // warm the executable cache before timing
        server.round(0)?;
        let mut t = 1usize;
        bench(&format!("round/{preset}"), budget_ms, || {
            server.round(t).unwrap();
            t += 1;
        });
    }

    let st = engine.stats();
    let total = st.execute_ns + st.marshal_ns;
    println!(
        "\nengine totals: {} execs, exec {:.2}s, marshal {:.2}s \
         ({:.1}% marshal), compile {:.2}s ({} modules)",
        st.executions,
        st.execute_ns as f64 * 1e-9,
        st.marshal_ns as f64 * 1e-9,
        100.0 * st.marshal_ns as f64 / total.max(1) as f64,
        st.compile_ns as f64 * 1e-9,
        st.compilations
    );
    Ok(())
}
