//! L3 hot-path microbenchmarks: FP8 wire codec, aggregation, and the
//! ServerOptimize Eq.(5) grid-search kernel.
//!
//! Run: `cargo bench --bench codec`
//! Targets (DESIGN.md §Perf): encode >= 200 MB/s on one core; decode
//! (LUT) faster than encode; coordinator overhead << HLO exec time.

use fedfp8::coordinator::aggregate;
use fedfp8::coordinator::comm::Uplink;
use fedfp8::fp8::codec::{self, Rounding, Segment};
use fedfp8::fp8::format::Fp8Params;
use fedfp8::fp8::rng::Pcg32;
use fedfp8::util::bench::{bench, header};

fn segments(dim: usize, tensors: usize) -> Vec<Segment> {
    let per = dim / tensors;
    (0..tensors)
        .map(|i| Segment {
            name: format!("t{i}"),
            offset: i * per,
            size: per,
            quantized: true,
            alpha_idx: Some(i),
        })
        .collect()
}

fn main() {
    const DIM: usize = 39_514; // resnet8 variant size
    let segs = segments(DIM, 10);
    let alphas: Vec<f32> = (0..10).map(|i| 0.5 + i as f32 * 0.1).collect();
    let mut rng = Pcg32::new(1, 0);
    let w: Vec<f32> = (0..DIM).map(|_| (rng.uniform() - 0.5) * 2.0).collect();

    header();

    let mut r = Pcg32::new(2, 0);
    let enc_det = bench("codec/encode_det 39.5k params", 400, || {
        std::hint::black_box(codec::encode(
            &w, &alphas, &[], &segs, Rounding::Deterministic, &mut r,
        ));
    });
    let enc_rand = bench("codec/encode_stochastic 39.5k params", 400, || {
        std::hint::black_box(codec::encode(
            &w, &alphas, &[], &segs, Rounding::Stochastic, &mut r,
        ));
    });

    let payload = codec::encode(
        &w, &alphas, &[], &segs, Rounding::Stochastic, &mut r,
    );
    let mut out = vec![0.0f32; DIM];
    let dec = bench("codec/decode_lut 39.5k params", 400, || {
        codec::decode(&payload, &segs, &mut out);
        std::hint::black_box(&out);
    });

    let mut qout = vec![0.0f32; DIM];
    bench("codec/quantize_vec_det (eq5 inner)", 400, || {
        codec::quantize_vec(
            &w, &alphas, &segs, Rounding::Deterministic, &mut r, &mut qout,
        );
        std::hint::black_box(&qout);
    });

    // scalar-level primitives
    let p = Fp8Params::new(1.3);
    bench("format/encode scalar x1000", 200, || {
        let mut acc = 0u32;
        for i in 0..1000 {
            acc = acc.wrapping_add(p.encode(w[i], 0.5) as u32);
        }
        std::hint::black_box(acc);
    });

    // §Perf before/after: per-element exp2 (baseline) vs exponent LUT
    bench("format/scale exp2 baseline x4096", 200, || {
        let mut acc = 0f64;
        for &v in w.iter().take(4096) {
            acc += p.scale_exp2((v as f64).abs() + 1e-9);
        }
        std::hint::black_box(acc);
    });
    bench("format/scale LUT optimized x4096", 200, || {
        let mut acc = 0f64;
        for &v in w.iter().take(4096) {
            acc += p.scale((v as f64).abs() + 1e-9);
        }
        std::hint::black_box(acc);
    });

    // aggregation of P=10 uplinks
    let uplinks: Vec<Uplink> = (0..10)
        .map(|c| Uplink {
            payload: codec::encode(
                &w, &alphas, &[4.0; 7], &segs, Rounding::Stochastic,
                &mut r,
            ),
            client: c,
            n_k: 100,
            mean_loss: 1.0,
        })
        .collect();
    let agg = bench("aggregate/fedavg P=10 x 39.5k", 400, || {
        std::hint::black_box(
            aggregate::fedavg(&uplinks, &segs, DIM, 10, 7).unwrap(),
        );
    });

    // Eq. (5) grid-search scoring: one segment, 50 candidates
    let seg = &segs[0];
    let clients: Vec<&[f32]> = vec![&w; 10];
    let kw = [0.1f32; 10];
    let us: Vec<f64> = (0..seg.size).map(|_| 0.37).collect();
    bench("server_opt/eq5_mse 1 seg x 50 cands", 400, || {
        let mut best = f64::MAX;
        for gi in 0..50 {
            let cand = 0.5 + gi as f32 * 0.01;
            best = best.min(codec::segment_quant_mse(
                &w, seg, cand, &clients, &kw, &us,
            ));
        }
        std::hint::black_box(best);
    });

    println!("\nthroughput:");
    println!(
        "  encode det    {:>8.1} M params/s ({:.0} MB/s in)",
        enc_det.throughput(DIM as f64) / 1e6,
        enc_det.throughput(DIM as f64 * 4.0) / 1e6
    );
    println!(
        "  encode rand   {:>8.1} M params/s",
        enc_rand.throughput(DIM as f64) / 1e6
    );
    println!(
        "  decode        {:>8.1} M params/s",
        dec.throughput(DIM as f64) / 1e6
    );
    println!(
        "  fedavg P=10   {:>8.1} M param-accums/s",
        agg.throughput(10.0 * DIM as f64) / 1e6
    );
}
