//! Snapshot durability benchmarks, seeding `BENCH_snapshot.json`.
//!
//! Run: `cargo bench --bench snapshot` — measures the four stages of
//! the coordinator's durability path on a realistic round state (a
//! 16k-param model with a 256-client touched-EF set): pure encode,
//! pure decode, the full atomic write (temp file + fsync + rename +
//! dir fsync + generation prune) and the resume load, then writes
//! `../BENCH_snapshot.json` (repo root). CI smoke: `cargo bench
//! --bench snapshot -- --quick` shrinks the state and skips the JSON
//! write.
//!
//! The interesting ratio is write_atomic / encode: everything above
//! 1x is what *durability* costs (fsync dominates), which is the
//! number an operator trades off when picking `--snapshot-every`.

use std::collections::BTreeMap;
use std::path::Path;

use fedfp8::coordinator::comm::CommStats;
use fedfp8::coordinator::snapshot::{self, SnapshotState};
use fedfp8::fp8::rng::Pcg32;
use fedfp8::util::bench::{bench, header, BenchJson};

/// A deterministic pseudo-random round state: `dim` params, the full
/// EF residual pair (server + `clients` touched uplinks), non-trivial
/// comm totals.
fn state(dim: usize, clients: usize) -> SnapshotState {
    let mut rng = Pcg32::new(17, 3);
    let mut vec = |n: usize| -> Vec<f32> {
        (0..n).map(|_| (rng.uniform() - 0.5) * 2.0).collect()
    };
    let w = vec(dim);
    let alpha = vec(8);
    let beta = vec(8);
    let ef_server = vec(dim);
    let ef_clients: BTreeMap<u64, Vec<f32>> = (0..clients)
        .map(|c| (c as u64 * 4099, vec(dim)))
        .collect();
    SnapshotState {
        fingerprint: 0x5EED_F00D_0000_0001,
        next_round: 321,
        w,
        alpha,
        beta,
        ef_server,
        ef_clients,
        comm: CommStats {
            up_bytes: 1 << 30,
            down_bytes: 1 << 31,
            up_msgs: 1 << 20,
            down_msgs: 1 << 20,
            partial_bytes: 1 << 24,
            partial_msgs: 1 << 10,
        },
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (dim, clients, budget_ms) =
        if quick { (4_096, 32, 60) } else { (16_384, 256, 400) };
    let s = state(dim, clients);
    let bytes = snapshot::encode(&s);
    let mib = bytes.len() as f64 / (1 << 20) as f64;
    println!(
        "state: dim={dim} ef_clients={clients} -> {:.1} MiB snapshot\n",
        mib
    );

    let dir = std::env::temp_dir()
        .join(format!("fedfp8_bench_snap_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    header();
    let enc = bench("snapshot/encode", budget_ms, || {
        std::hint::black_box(snapshot::encode(&s));
    });
    let dec = bench("snapshot/decode", budget_ms, || {
        std::hint::black_box(
            snapshot::decode(&bytes, Path::new("bench")).unwrap(),
        );
    });
    let wrt = bench("snapshot/write_atomic", budget_ms, || {
        std::hint::black_box(snapshot::write_atomic(&dir, &s).unwrap());
    });
    let load = bench("snapshot/load_resume", budget_ms, || {
        std::hint::black_box(
            snapshot::load_resume(&dir, s.fingerprint)
                .unwrap()
                .unwrap(),
        );
    });
    let _ = std::fs::remove_dir_all(&dir);

    let durability_cost = wrt.median_ns / enc.median_ns;
    println!("\nthroughput at median:");
    println!(
        "  encode {:.0} MiB/s   decode {:.0} MiB/s   write_atomic \
         {:.0} MiB/s   load {:.0} MiB/s",
        enc.throughput(mib),
        dec.throughput(mib),
        wrt.throughput(mib),
        load.throughput(mib),
    );
    println!(
        "  durability overhead (write_atomic / encode): \
         {durability_cost:.1}x — the fsync+rename price per snapshot"
    );

    if quick {
        println!("\n--quick: JSON trajectory write skipped");
        return;
    }
    let mut j = BenchJson::new(
        "snapshot",
        "cargo bench --bench snapshot (rust/benches/snapshot.rs)",
    );
    j.config("dim", dim);
    j.config("ef_clients", clients);
    j.config("snapshot_mib", format!("{mib:.2}"));
    for r in [&enc, &dec, &wrt, &load] {
        j.push(r, Some(mib));
    }
    j.speedup("encode_over_write_atomic", durability_cost);
    j.speedup("decode_over_load", load.median_ns / dec.median_ns);
    let path = std::path::Path::new("../BENCH_snapshot.json");
    match j.write(path) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", path.display()),
    }
}
