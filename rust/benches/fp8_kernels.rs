//! FP8 hot-path kernel benchmarks with explicit before/after arms,
//! seeding the repo's perf trajectory (`BENCH_fp8_kernels.json`).
//!
//! Run: `cargo bench --bench fp8_kernels` — measures the acceptance
//! configuration (K=8 clients, d=100k params, G=32 alpha candidates)
//! and writes `../BENCH_fp8_kernels.json` (repo root).
//! CI smoke: `cargo bench --bench fp8_kernels -- --quick` runs reduced
//! sizes/budgets (still above the encode pool threshold, so the
//! fan-out path is exercised) and skips the JSON write.
//!
//! Arms:
//! * encode: scalar per-element reference (`encode_into_scalar`, the
//!   pre-overhaul path shape) vs batched-RNG chunked encode at pool 1
//!   and pool N.
//! * decode: per-call table rebuild (pre-overhaul `decode` shape) vs
//!   `DecodeLutCache`-backed decode at d=100k (sequential — the
//!   parallel path only engages above 2^20 elements), plus a
//!   dedicated 2^20+-element pair that really takes `decode_parallel`
//!   (full mode only).
//! * Eq. (5) alpha search: naive O(G·K·d) rescan (`segment_quant_mse`)
//!   vs sufficient-statistics O(d·(K+G)) search (`SegmentStats`),
//!   sequential and pooled — the exact shape `server_opt` runs.
//! * kernel arms: the scalar-oracle inner loop vs the `--fp8-kernel
//!   simd` kernel (AVX2 lanes under `--features simd`, the portable
//!   branch-free fallback otherwise) on the encode and Eq. (5) paths.

use std::thread;

use fedfp8::fp8::codec::{self, DecodeLutCache, Rounding, Segment,
                         SegmentStats, WirePayload};
use fedfp8::fp8::format::Fp8Params;
use fedfp8::fp8::rng::Pcg32;
use fedfp8::fp8::simd::KernelKind;
use fedfp8::util::bench::{bench, header, BenchJson};

fn segments(dim: usize, tensors: usize) -> Vec<Segment> {
    let per = dim / tensors;
    (0..tensors)
        .map(|i| Segment {
            name: format!("t{i}"),
            offset: i * per,
            size: per,
            quantized: true,
            alpha_idx: Some(i),
        })
        .collect()
}

/// Pre-overhaul decode shape: rebuild the 256-entry table inside every
/// call, once per segment (the "before" arm the LUT cache replaces).
fn decode_rebuild_tables(
    payload: &WirePayload,
    segments: &[Segment],
    out: &mut [f32],
) {
    let mut ci = 0usize;
    for seg in segments {
        let table = Fp8Params::new(payload.alphas[seg.alpha_idx.unwrap()])
            .decode_table();
        let dst = &mut out[seg.offset..seg.offset + seg.size];
        for d in dst.iter_mut() {
            *d = table[payload.codes[ci] as usize];
            ci += 1;
        }
    }
}

/// The Eq. (5) search exactly as `server_opt` runs it: stats once per
/// segment, then G candidates scored in O(d) each, optionally fanned
/// over `pool` threads via the same `scatter_zip` skeleton.
fn alpha_search_suffstats(
    w: &[f32],
    segs: &[Segment],
    clients: &[&[f32]],
    kw: &[f32],
    us: &[Vec<f64>],
    grid: usize,
    pool: usize,
    kernel: KernelKind,
) -> f64 {
    let searches: Vec<SegmentStats> = segs
        .iter()
        .map(|seg| SegmentStats::build(seg, clients, kw))
        .collect();
    let mut tasks: Vec<(usize, f32)> = Vec::new();
    for si in 0..segs.len() {
        for gi in 0..grid {
            let cand = 0.5 + gi as f32 / grid as f32;
            tasks.push((si, cand));
        }
    }
    let mut mses = vec![0.0f64; tasks.len()];
    let score = |&(si, cand): &(usize, f32)| -> f64 {
        searches[si].mse_with(kernel, w, &segs[si], cand, &us[si])
    };
    if pool <= 1 {
        for (slot, t) in mses.iter_mut().zip(tasks.iter()) {
            *slot = score(t);
        }
    } else {
        codec::scatter_zip(&tasks, &mut mses, pool, score);
    }
    mses.into_iter().fold(f64::MAX, f64::min)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    // acceptance configuration: K=8 clients, d=100k params across 4
    // tensors, G=32 alpha candidates per tensor. Quick mode stays
    // above the encode pool threshold (2^15) so CI exercises the
    // fan-out path.
    let (dim, tensors, k_clients, grid, heavy_ms, light_ms) = if quick {
        (40_960usize, 4usize, 4usize, 8usize, 80u64, 40u64)
    } else {
        (100_000, 4, 8, 32, 1_500, 400)
    };
    let pool = thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(4);
    let segs = segments(dim, tensors);
    let alphas: Vec<f32> =
        (0..tensors).map(|i| 0.7 + i as f32 * 0.15).collect();
    let mut rng = Pcg32::new(1, 0);
    let w: Vec<f32> =
        (0..dim).map(|_| (rng.uniform() - 0.5) * 2.0).collect();

    header();

    // ---- encode: scalar reference vs batched (pool 1 / pool N) ------
    let mut r = Pcg32::new(2, 0);
    let mut payload = WirePayload::default();
    let enc_scalar = bench("encode/scalar_ref (before)", light_ms, || {
        codec::encode_into_scalar(
            &w, &alphas, &[], &segs, Rounding::Stochastic, &mut r,
            &mut payload,
        );
        std::hint::black_box(&payload);
    });
    let mut scratch = Vec::new();
    let enc_b1 = bench("encode/batched pool=1", light_ms, || {
        codec::encode_into_pooled(
            &w, &alphas, &[], &segs, Rounding::Stochastic,
            KernelKind::Scalar, &mut r, &mut scratch, 1, &mut payload,
        );
        std::hint::black_box(&payload);
    });
    let enc_bn = bench(&format!("encode/batched pool={pool}"), light_ms, || {
        codec::encode_into_pooled(
            &w, &alphas, &[], &segs, Rounding::Stochastic,
            KernelKind::Scalar, &mut r, &mut scratch, pool,
            &mut payload,
        );
        std::hint::black_box(&payload);
    });

    // ---- encode: scalar kernel vs the simd kernel -------------------
    let simd_name = KernelKind::Simd.resolve().name();
    let enc_simd1 = bench(
        &format!("encode/kernel={simd_name} pool=1"),
        light_ms,
        || {
            codec::encode_into_pooled(
                &w, &alphas, &[], &segs, Rounding::Stochastic,
                KernelKind::Simd, &mut r, &mut scratch, 1,
                &mut payload,
            );
            std::hint::black_box(&payload);
        },
    );
    let enc_simdn = bench(
        &format!("encode/kernel={simd_name} pool={pool}"),
        light_ms,
        || {
            codec::encode_into_pooled(
                &w, &alphas, &[], &segs, Rounding::Stochastic,
                KernelKind::Simd, &mut r, &mut scratch, pool,
                &mut payload,
            );
            std::hint::black_box(&payload);
        },
    );

    // ---- decode: per-call table rebuild vs cached LUT ---------------
    // (sequential at this size: the parallel decode path only engages
    // above 2^20 elements — measured separately below)
    let wire = codec::encode(
        &w, &alphas, &[], &segs, Rounding::Stochastic, &mut r,
    );
    let mut out = vec![0.0f32; dim];
    let dec_rebuild = bench("decode/rebuild_tables (before)", light_ms, || {
        decode_rebuild_tables(&wire, &segs, &mut out);
        std::hint::black_box(&out);
    });
    let mut cache = DecodeLutCache::default();
    let dec_cached = bench("decode/lut_cached", light_ms, || {
        codec::decode_pooled(&wire, &segs, &mut cache, 1, &mut out);
        std::hint::black_box(&out);
    });

    // ---- decode parallel path: a payload big enough to cross the
    // 2^20-element gate (full mode only; quick keeps CI fast) --------
    let dec_large = if quick {
        None
    } else {
        let big = (1usize << 20) + 4096;
        let bsegs = segments(big, tensors);
        let bw: Vec<f32> =
            (0..big).map(|_| (rng.uniform() - 0.5) * 2.0).collect();
        let bwire = codec::encode(
            &bw, &alphas, &[], &bsegs, Rounding::Stochastic, &mut r,
        );
        let mut bout = vec![0.0f32; big];
        let s1 = bench("decode/large 2^20+ pool=1", light_ms, || {
            codec::decode_pooled(&bwire, &bsegs, &mut cache, 1, &mut bout);
            std::hint::black_box(&bout);
        });
        let sn = bench(
            &format!("decode/large 2^20+ pool={pool}"),
            light_ms,
            || {
                codec::decode_pooled(
                    &bwire, &bsegs, &mut cache, pool, &mut bout,
                );
                std::hint::black_box(&bout);
            },
        );
        Some((s1, sn))
    };

    // ---- Eq. (5) alpha search: naive vs sufficient statistics -------
    let clients_data: Vec<Vec<f32>> = (0..k_clients)
        .map(|c| {
            let mut cr = Pcg32::new(100 + c as u64, 0);
            (0..dim).map(|_| (cr.uniform() - 0.5) * 2.0).collect()
        })
        .collect();
    let clients: Vec<&[f32]> =
        clients_data.iter().map(|v| v.as_slice()).collect();
    let kw = vec![1.0f32 / k_clients as f32; k_clients];
    let us: Vec<Vec<f64>> = segs
        .iter()
        .map(|s| (0..s.size).map(|_| rng.uniform_f64()).collect())
        .collect();

    let eq5_naive = bench(
        &format!("eq5/naive O(G*K*d) K={k_clients} G={grid}"),
        heavy_ms,
        || {
            let mut best = f64::MAX;
            for (si, seg) in segs.iter().enumerate() {
                for gi in 0..grid {
                    let cand = 0.5 + gi as f32 / grid as f32;
                    best = best.min(codec::segment_quant_mse(
                        &w, seg, cand, &clients, &kw, &us[si],
                    ));
                }
            }
            std::hint::black_box(best);
        },
    );
    let eq5_s1 = bench("eq5/suffstats pool=1", heavy_ms, || {
        std::hint::black_box(alpha_search_suffstats(
            &w, &segs, &clients, &kw, &us, grid, 1,
            KernelKind::Scalar,
        ));
    });
    let eq5_sn = bench(
        &format!("eq5/suffstats pool={pool}"),
        heavy_ms,
        || {
            std::hint::black_box(alpha_search_suffstats(
                &w, &segs, &clients, &kw, &us, grid, pool,
                KernelKind::Scalar,
            ));
        },
    );
    let eq5_simd1 = bench(
        &format!("eq5/suffstats kernel={simd_name} pool=1"),
        heavy_ms,
        || {
            std::hint::black_box(alpha_search_suffstats(
                &w, &segs, &clients, &kw, &us, grid, 1,
                KernelKind::Simd,
            ));
        },
    );

    // ---- report -----------------------------------------------------
    let d = dim as f64;
    println!("\nthroughput:");
    println!(
        "  encode scalar_ref  {:>8.1} M params/s",
        enc_scalar.throughput(d) / 1e6
    );
    println!(
        "  encode batched p{pool} {:>8.1} M params/s",
        enc_bn.throughput(d) / 1e6
    );
    println!(
        "  decode cached      {:>8.1} M params/s",
        dec_cached.throughput(d) / 1e6
    );
    let sp_eq5 = eq5_naive.median_ns / eq5_sn.median_ns;
    let sp_eq5_seq = eq5_naive.median_ns / eq5_s1.median_ns;
    let sp_enc = enc_scalar.median_ns / enc_bn.median_ns;
    let sp_dec = dec_rebuild.median_ns / dec_cached.median_ns;
    let sp_wire = (enc_scalar.median_ns + dec_rebuild.median_ns)
        / (enc_bn.median_ns + dec_cached.median_ns);
    let sp_enc_simd = enc_b1.median_ns / enc_simd1.median_ns;
    let sp_eq5_simd = eq5_s1.median_ns / eq5_simd1.median_ns;
    println!("\nspeedups (before / after):");
    println!("  eq5 alpha search   {sp_eq5:.2}x (seq {sp_eq5_seq:.2}x)");
    println!("  encode             {sp_enc:.2}x");
    println!("  decode             {sp_dec:.2}x");
    println!("  encode+decode      {sp_wire:.2}x");
    println!(
        "  encode scalar->{simd_name} kernel  {sp_enc_simd:.2}x \
         (eq5 {sp_eq5_simd:.2}x)"
    );
    if let Some((s1, sn)) = &dec_large {
        println!(
            "  decode 2^20+ pool  {:.2}x",
            s1.median_ns / sn.median_ns
        );
    }

    if quick {
        println!("\n--quick: JSON trajectory write skipped");
        return;
    }
    let mut j = BenchJson::new(
        "fp8_kernels",
        "cargo bench --bench fp8_kernels (rust/benches/fp8_kernels.rs)",
    );
    j.config("dim", dim);
    j.config("tensors", tensors);
    j.config("k_clients", k_clients);
    j.config("grid_points", grid);
    j.config("pool", pool);
    j.config("simd_kernel", simd_name);
    for res in [
        &enc_scalar, &enc_b1, &enc_bn, &enc_simd1, &enc_simdn,
        &dec_rebuild, &dec_cached, &eq5_naive, &eq5_s1, &eq5_sn,
        &eq5_simd1,
    ] {
        let items =
            if res.name.starts_with("eq5") { None } else { Some(d) };
        j.push(res, items);
    }
    j.speedup("eq5_alpha_search_naive_over_suffstats_pooled", sp_eq5);
    j.speedup("eq5_alpha_search_naive_over_suffstats_seq", sp_eq5_seq);
    j.speedup("encode_scalar_over_batched_pooled", sp_enc);
    j.speedup("decode_rebuild_over_lut_cached", sp_dec);
    j.speedup("encode_decode_combined", sp_wire);
    j.speedup("encode_scalar_kernel_over_simd_kernel", sp_enc_simd);
    j.speedup("eq5_scalar_kernel_over_simd_kernel", sp_eq5_simd);
    if let Some((s1, sn)) = &dec_large {
        let big = (1usize << 20) + 4096;
        j.push(s1, Some(big as f64));
        j.push(sn, Some(big as f64));
        j.speedup(
            "decode_large_seq_over_pooled",
            s1.median_ns / sn.median_ns,
        );
    }
    let path = std::path::Path::new("../BENCH_fp8_kernels.json");
    match j.write(path) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", path.display()),
    }
}
