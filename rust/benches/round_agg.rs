//! Round-aggregation scale benchmarks: flat vs tree fan-in, and the
//! million-client round machinery (sparse cohort sampling, virtualized
//! shard maps), seeding `BENCH_agg_tree.json`.
//!
//! Run: `cargo bench --bench round_agg` — measures flat-vs-tree
//! aggregation at cohort sizes {10^2, 10^4, 10^6} and the K=10^6
//! population paths, then writes `../BENCH_agg_tree.json` (repo
//! root). CI smoke: `cargo bench --bench round_agg -- --quick` drops
//! the 10^6 cohort arm and skips the JSON write.
//!
//! Arms:
//! * agg: flat `FedAvgStream` over P uplinks vs a depth-2 tree
//!   (`tree:16` shape) whose mid-tier partials travel through the
//!   real wire codec — the tree's overhead is O(nodes) partial
//!   frames, amortized to nothing as P grows.
//! * sample: dense Fisher-Yates (O(K) scratch per draw) vs the sparse
//!   sampler (O(P) scratch) drawing a 256-cohort from K=10^6.
//! * world: dense `partition::iid` at K=10^6 (a million resident
//!   Vecs) vs the virtualized shard map plus a full cohort's on-demand
//!   shard materialization.

use fedfp8::coordinator::aggregate::{FedAvgStream, Weighting};
use fedfp8::coordinator::cohort::ClientShards;
use fedfp8::coordinator::comm::{CommStats, Uplink};
use fedfp8::coordinator::tree::{forward_partial, shard_bounds};
use fedfp8::data::partition;
use fedfp8::fp8::codec::{self, Rounding, Segment};
use fedfp8::fp8::rng::Pcg32;
use fedfp8::util::bench::{bench, header, BenchJson, BenchResult};

const DIM: usize = 64;
const NODES: usize = 16;

fn segs() -> Vec<Segment> {
    vec![Segment {
        name: "w".into(),
        offset: 0,
        size: DIM,
        quantized: true,
        alpha_idx: Some(0),
    }]
}

/// A small pool of distinct pre-encoded uplinks, cycled to form
/// arbitrarily large cohorts without P-sized buffers (n_k = 1 each,
/// so m_t = P).
fn uplink_pool(segs: &[Segment], n: usize) -> Vec<Uplink> {
    let mut rng = Pcg32::new(42, 7);
    (0..n)
        .map(|c| {
            let w: Vec<f32> =
                (0..DIM).map(|_| (rng.uniform() - 0.5) * 2.0).collect();
            Uplink {
                payload: codec::encode(
                    &w,
                    &[0.9 + 0.05 * c as f32],
                    &[2.0],
                    segs,
                    Rounding::Stochastic,
                    &mut rng,
                ),
                client: c,
                n_k: 1,
                mean_loss: 0.5 + 0.1 * c as f32,
            }
        })
        .collect()
}

fn flat_round(
    segs: &[Segment],
    pool: &[Uplink],
    p: usize,
) -> f32 {
    let w = Weighting::BySamples { m_t: p as u64 };
    let mut s =
        FedAvgStream::with_weighting(segs, DIM, 1, 1, w, false, 0)
            .unwrap();
    for i in 0..p {
        s.push(&pool[i % pool.len()]);
    }
    s.finish().unwrap().mean_loss
}

fn tree_round(
    segs: &[Segment],
    pool: &[Uplink],
    p: usize,
    comm: &mut CommStats,
) -> f32 {
    let w = Weighting::BySamples { m_t: p as u64 };
    let mut root =
        FedAvgStream::with_weighting(segs, DIM, 1, 1, w, false, 0)
            .unwrap();
    for (lo, hi) in shard_bounds(p, NODES) {
        let mut mid = FedAvgStream::with_weighting(
            segs,
            DIM,
            1,
            1,
            w,
            false,
            lo as u64,
        )
        .unwrap();
        for i in lo..hi {
            mid.push(&pool[i % pool.len()]);
        }
        let partial =
            forward_partial(0, &mid.into_partial().unwrap(), comm)
                .unwrap();
        root.absorb(&partial).unwrap();
    }
    root.finish().unwrap().mean_loss
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let segs = segs();
    let pool = uplink_pool(&segs, 8);
    let k_pop = 1_000_000usize;
    let cohort = 256usize;

    header();

    // ---- flat vs tree fan-in across cohort scales -------------------
    // the invariant suite proves the results bit-identical; this
    // measures what the topology lever costs/buys in wall clock
    let mut arms: Vec<(usize, BenchResult, BenchResult)> = Vec::new();
    let cohorts: &[(usize, u64)] = if quick {
        &[(100, 60), (10_000, 120)]
    } else {
        &[(100, 120), (10_000, 400), (1_000_000, 3_000)]
    };
    for &(p, budget_ms) in cohorts {
        let flat = bench(&format!("agg/flat P={p}"), budget_ms, || {
            std::hint::black_box(flat_round(&segs, &pool, p));
        });
        let mut comm = CommStats::default();
        let tree = bench(
            &format!("agg/tree:{NODES} P={p}"),
            budget_ms,
            || {
                std::hint::black_box(tree_round(
                    &segs, &pool, p, &mut comm,
                ));
            },
        );
        arms.push((p, flat, tree));
    }

    // ---- cohort sampling: dense vs sparse Fisher-Yates --------------
    let samp_dense = bench(
        &format!("sample/dense K={k_pop} P={cohort}"),
        200,
        || {
            let mut rng = Pcg32::new(9, 1);
            std::hint::black_box(
                rng.sample_distinct(k_pop, cohort),
            );
        },
    );
    let samp_sparse = bench(
        &format!("sample/sparse K={k_pop} P={cohort}"),
        200,
        || {
            let mut rng = Pcg32::new(9, 1);
            std::hint::black_box(
                rng.sample_distinct_sparse(k_pop, cohort),
            );
        },
    );

    // ---- world build: dense shard vecs vs virtualized map -----------
    let n_train = 50_000usize;
    let world_dense = if quick {
        None
    } else {
        Some(bench(
            &format!("world/dense_iid K={k_pop}"),
            2_000,
            || {
                let mut rng = Pcg32::new(5, 2);
                std::hint::black_box(partition::iid(
                    n_train, k_pop, &mut rng,
                ));
            },
        ))
    };
    let world_virtual = bench(
        &format!("world/virtual_iid+cohort K={k_pop}"),
        400,
        || {
            let mut rng = Pcg32::new(5, 2);
            let shards =
                ClientShards::virtual_iid(n_train, k_pop, &mut rng);
            // plus the whole per-round cost it must cover: sample a
            // cohort and materialize exactly its shards
            let ids = Pcg32::new(6, 3)
                .sample_distinct_sparse(k_pop, cohort);
            let total: u64 =
                ids.iter().map(|&c| shards.n_k(c)).sum();
            let touched: usize =
                ids.iter().map(|&c| shards.shard(c).len()).sum();
            std::hint::black_box((total, touched));
        },
    );

    // ---- report -----------------------------------------------------
    println!("\nper-uplink fold latency:");
    for (p, flat, tree) in &arms {
        println!(
            "  P={p:<9} flat {:>9.0} ns/uplink   tree {:>9.0} ns/uplink",
            flat.median_ns / *p as f64,
            tree.median_ns / *p as f64,
        );
    }
    let sp_sample = samp_dense.median_ns / samp_sparse.median_ns;
    println!("\nspeedups (before / after):");
    println!("  cohort sampling dense->sparse  {sp_sample:.2}x");
    if let Some(wd) = &world_dense {
        println!(
            "  world build dense->virtual     {:.2}x",
            wd.median_ns / world_virtual.median_ns
        );
    }

    if quick {
        println!("\n--quick: JSON trajectory write skipped");
        return;
    }
    let mut j = BenchJson::new(
        "agg_tree",
        "cargo bench --bench round_agg (rust/benches/round_agg.rs)",
    );
    j.config("dim", DIM);
    j.config("tree_nodes", NODES);
    j.config("k_population", k_pop);
    j.config("cohort", cohort);
    j.config("n_train", n_train);
    for (_, flat, tree) in &arms {
        j.push(flat, Some(DIM as f64));
        j.push(tree, Some(DIM as f64));
    }
    for (p, flat, tree) in &arms {
        j.speedup(
            &format!("agg_flat_over_tree_p{p}"),
            flat.median_ns / tree.median_ns,
        );
    }
    j.push(&samp_dense, None);
    j.push(&samp_sparse, None);
    j.speedup("sample_dense_over_sparse", sp_sample);
    if let Some(wd) = &world_dense {
        j.push(wd, None);
        j.speedup(
            "world_dense_over_virtual",
            wd.median_ns / world_virtual.median_ns,
        );
    }
    j.push(&world_virtual, None);
    let path = std::path::Path::new("../BENCH_agg_tree.json");
    match j.write(path) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", path.display()),
    }
}
