//! Cross-layer parity: the Rust wire codec must reproduce the Python
//! oracle (`ref.quantize_np`, f64 math / f32 result) on the golden
//! vectors exported by `aot.py`, and the 256-entry decode tables.
//!
//! Requires `make artifacts`. Bit-exactness is expected because both
//! sides run the identical f64 op sequence; a tiny tolerance only
//! covers libm log2 differences at exact bin boundaries.

use fedfp8::fp8::format::Fp8Params;
use fedfp8::runtime::artifact_file_or_skip;
use fedfp8::util::json::Json;

fn goldens() -> Option<Json> {
    let p = artifact_file_or_skip(
        "golden_fp8.json",
        "golden-vector parity tests",
    )?;
    let text =
        std::fs::read_to_string(p).expect("golden json readable");
    Some(Json::parse(&text).expect("golden json parses"))
}

#[test]
fn format_constants_match() {
    let Some(g) = goldens() else {
        eprintln!("skip: artifacts not built");
        return;
    };
    assert_eq!(g.get("m").unwrap().as_usize().unwrap(), 3);
    assert_eq!(g.get("e").unwrap().as_usize().unwrap(), 4);
}

#[test]
fn quantize_matches_python_oracle() {
    let Some(g) = goldens() else {
        eprintln!("skip: artifacts not built");
        return;
    };
    let mut total = 0usize;
    let mut exact = 0usize;
    for case in g.get("cases").unwrap().as_arr().unwrap() {
        let alpha = case.get("alpha").unwrap().as_f64().unwrap() as f32;
        let x = case.get("x").unwrap().f32_vec().unwrap();
        let u = case.get("u").unwrap().f64_vec().unwrap();
        let q_det = case.get("q_det").unwrap().f32_vec().unwrap();
        let q_rand = case.get("q_rand").unwrap().f32_vec().unwrap();
        let p = Fp8Params::new(alpha);
        for i in 0..x.len() {
            total += 2;
            let rd = p.quantize(x[i], 0.5);
            let rr = p.quantize(x[i], u[i]);
            if rd == q_det[i] {
                exact += 1;
            } else {
                // boundary jitter must stay within one grid bin
                let bin = p.scale((x[i] as f64).abs()) as f32;
                assert!(
                    (rd - q_det[i]).abs() <= bin * 1.0001,
                    "det mismatch beyond one bin: x={} alpha={alpha} \
                     rust={rd} py={}",
                    x[i],
                    q_det[i]
                );
            }
            if rr == q_rand[i] {
                exact += 1;
            } else {
                let bin = p.scale((x[i] as f64).abs()) as f32;
                assert!(
                    (rr - q_rand[i]).abs() <= bin * 1.0001,
                    "rand mismatch beyond one bin: x={} alpha={alpha}",
                    x[i]
                );
            }
        }
    }
    let frac = exact as f64 / total as f64;
    assert!(
        frac > 0.999,
        "only {frac:.5} of golden cases bit-exact ({exact}/{total})"
    );
}

#[test]
fn encode_matches_python_oracle_via_wire() {
    let Some(g) = goldens() else {
        eprintln!("skip: artifacts not built");
        return;
    };
    // decode(encode(x, u)) must equal quantize(x, u) AND the golden
    for case in g.get("cases").unwrap().as_arr().unwrap() {
        let alpha = case.get("alpha").unwrap().as_f64().unwrap() as f32;
        let x = case.get("x").unwrap().f32_vec().unwrap();
        let u = case.get("u").unwrap().f64_vec().unwrap();
        let p = Fp8Params::new(alpha);
        for i in 0..x.len() {
            let direct = p.quantize(x[i], u[i]);
            let wire = p.decode(p.encode(x[i], u[i]));
            assert_eq!(direct, wire, "x={} alpha={alpha}", x[i]);
        }
    }
}

#[test]
fn decode_tables_match_python_grids() {
    let Some(g) = goldens() else {
        eprintln!("skip: artifacts not built");
        return;
    };
    for (alpha_s, grid) in g.get("grids").unwrap().as_obj().unwrap() {
        let alpha: f32 = alpha_s.parse().unwrap();
        let expect = grid.f32_vec().unwrap();
        let p = Fp8Params::new(alpha);
        let table = p.decode_table();
        // collect non-negative codes, sorted
        let mut mine: Vec<f32> = (0..128u16)
            .map(|c| table[c as usize])
            .collect();
        mine.sort_by(|a, b| a.partial_cmp(b).unwrap());
        mine.dedup();
        assert_eq!(mine.len(), expect.len(), "alpha={alpha}");
        for (m, e) in mine.iter().zip(&expect) {
            assert!(
                (m - e).abs() <= e.abs() * 2e-7 + f32::MIN_POSITIVE,
                "alpha={alpha}: {m} vs {e}"
            );
        }
    }
}
