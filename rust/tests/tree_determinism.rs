//! The tree-vs-flat invariant, end to end: running a round's cohort
//! through a depth-2 aggregation tree (`--agg tree:G`) must produce a
//! **bit-identical** model trajectory to the flat stream, for every
//! fan-out, every `--parallelism`, and with error feedback on or off.
//!
//! This is the pinned contract that makes the tree a pure perf/scale
//! lever: mid-tier partials travel through the real wire codec
//! (encode → account → decode), and the root's canonical pairwise
//! accumulator replays exactly the f64 adds the flat stream would
//! have performed (see `coordinator::aggregate`). Client-edge
//! communication accounting is also topology-independent; only the
//! backbone partial counters may differ.

mod common;

use common::{mock_cfg, mock_manifest, run_mock_agg, MockTransport};
use fedfp8::config::AggMode;
use fedfp8::coordinator::Server;
use fedfp8::runtime::Engine;

/// Flat-vs-tree comparison ignoring the backbone counters (which are
/// *supposed* to differ: partials exist only under tree aggregation).
fn assert_same_trajectory(
    flat: &common::Trace,
    tree: &common::Trace,
    what: &str,
) {
    assert_eq!(flat.w, tree.w, "w diverged: {what}");
    assert_eq!(flat.alpha, tree.alpha, "alpha diverged: {what}");
    assert_eq!(flat.beta, tree.beta, "beta diverged: {what}");
    assert_eq!(flat.losses, tree.losses, "losses diverged: {what}");
    // client-edge traffic is identical byte-for-byte — a tree moves
    // the same uplinks/downlinks, just through mid-tier nodes
    assert_eq!(flat.comm.up_bytes, tree.comm.up_bytes, "{what}");
    assert_eq!(flat.comm.down_bytes, tree.comm.down_bytes, "{what}");
    assert_eq!(flat.comm.up_msgs, tree.comm.up_msgs, "{what}");
    assert_eq!(flat.comm.down_msgs, tree.comm.down_msgs, "{what}");
}

#[test]
fn tree_matches_flat_bitwise_for_every_fanout() {
    // sequential baseline; EF off. Mock cohort is P=4 over 4 rounds.
    let flat = run_mock_agg(1, false, AggMode::Flat);
    assert_eq!(flat.comm.partial_msgs, 0, "flat must not emit partials");
    for nodes in [1usize, 2, 3, 4, 7] {
        let tree = run_mock_agg(1, false, AggMode::Tree { nodes });
        assert_same_trajectory(&flat, &tree, &format!("tree:{nodes}"));
        // one partial per materialized mid-tier node per round
        let per_round = nodes.min(4) as u64;
        assert_eq!(tree.comm.partial_msgs, 4 * per_round);
        assert!(tree.comm.partial_bytes > 0);
        assert!(
            tree.comm.grand_total_bytes()
                > tree.comm.total_bytes()
        );
    }
}

#[test]
fn tree_matches_flat_under_parallelism_and_ef() {
    // the acceptance grid: parallelism {1, 4} x EF {off, on}, fan-out
    // 2 — tree composes with the reorder buffer and with per-client
    // EF residual state (which flows through the sink, not the tree)
    for ef in [false, true] {
        let flat = run_mock_agg(1, ef, AggMode::Flat);
        for par in [1usize, 4] {
            let tree =
                run_mock_agg(par, ef, AggMode::Tree { nodes: 2 });
            assert_same_trajectory(
                &flat,
                &tree,
                &format!("par={par} ef={ef}"),
            );
        }
    }
}

#[test]
fn tree_rejects_server_optimize_at_construction() {
    // per-client retention cannot cross a tree link; the config layer
    // rejects the combination before any round runs
    let (dir, manifest) = mock_manifest("tree_so");
    let engine = Engine::new(&dir).unwrap();
    let transport = MockTransport::new(false);
    let mut cfg = mock_cfg(1, false);
    cfg.agg = AggMode::Tree { nodes: 2 };
    cfg.server_opt =
        Some(fedfp8::config::ServerOptCfg::default());
    let err = match Server::with_transport(
        &engine,
        &manifest,
        cfg,
        Box::new(&transport),
    ) {
        Ok(_) => panic!("tree + ServerOptimize must be rejected"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(
        msg.contains("tree") || msg.contains("ServerOptimize"),
        "unhelpful error: {msg}"
    );
}
