//! Crash-durability proof for the coordinator snapshot layer
//! (`coordinator::snapshot`): a run interrupted at any round boundary
//! and resumed from disk is **bit-identical** to an uninterrupted
//! run — at any `--parallelism`, with error feedback on, flat or
//! tree aggregation — and a torn or corrupted newest generation
//! falls back one generation (still bit-identical), while a foreign
//! config fingerprint is a typed hard reject.
//!
//! The crash model: drop the `Server` after a round boundary (the
//! snapshot is written *after* the round completes, so state on disk
//! always says "rounds `0..next_round` are complete"), then build a
//! fresh server from scratch — new process state, nothing carried
//! over but the snapshot directory — and `resume_from` it.

mod common;

use std::fs;
use std::path::{Path, PathBuf};

use common::{mock_cfg, mock_manifest, MockTransport, Trace};
use fedfp8::config::{AggMode, ExperimentConfig};
use fedfp8::coordinator::snapshot::{
    decode, write_atomic, SnapshotError,
};
use fedfp8::coordinator::Server;
use fedfp8::runtime::Engine;

/// Fresh (pre-cleaned) snapshot directory for one test arm.
fn snap_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "fedfp8_durab_{}_{tag}",
        std::process::id()
    ));
    let _ = fs::remove_dir_all(&dir);
    dir
}

/// Uninterrupted reference run: every round, no snapshots.
fn run_full(tag: &str, cfg: ExperimentConfig) -> Trace {
    let (dir, manifest) = mock_manifest(tag);
    let engine = Engine::new(&dir).unwrap();
    let transport = MockTransport::new(false);
    let rounds = cfg.rounds;
    let mut server = Server::with_transport(
        &engine,
        &manifest,
        cfg,
        Box::new(&transport),
    )
    .unwrap();
    let mut losses = Vec::new();
    for t in 0..rounds {
        losses.push(server.round(t).unwrap().to_bits());
    }
    Trace::capture(&server, losses)
}

/// Run rounds `0..cut` with a snapshot at every boundary
/// (`--snapshot-every 1`), then "crash": the server is dropped and
/// only the snapshot directory survives. Returns the pre-crash
/// per-round losses.
fn run_until_crash(
    tag: &str,
    cfg: ExperimentConfig,
    cut: usize,
    snaps: &Path,
) -> Vec<u32> {
    let (dir, manifest) = mock_manifest(tag);
    let engine = Engine::new(&dir).unwrap();
    let transport = MockTransport::new(false);
    let mut server = Server::with_transport(
        &engine,
        &manifest,
        cfg,
        Box::new(&transport),
    )
    .unwrap();
    let mut losses = Vec::new();
    for t in 0..cut {
        losses.push(server.round(t).unwrap().to_bits());
        server.save_snapshot(snaps, t + 1).unwrap();
    }
    losses
}

/// Fresh-process resume: build a brand-new server, `resume_from` the
/// snapshot directory, finish the run. Returns the resumed start
/// round and the post-resume trace (losses cover resumed rounds
/// only; the caller stitches).
fn resume_and_finish(
    tag: &str,
    cfg: ExperimentConfig,
    snaps: &Path,
) -> (usize, Trace) {
    let (dir, manifest) = mock_manifest(tag);
    let engine = Engine::new(&dir).unwrap();
    let transport = MockTransport::new(false);
    let rounds = cfg.rounds;
    let mut server = Server::with_transport(
        &engine,
        &manifest,
        cfg,
        Box::new(&transport),
    )
    .unwrap();
    let start = server.resume_from(snaps).unwrap();
    let mut losses = Vec::new();
    for t in start..rounds {
        losses.push(server.round(t).unwrap().to_bits());
    }
    (start, Trace::capture(&server, losses))
}

/// The core property: interrupt at round boundary `cut`, resume in a
/// fresh server, and the stitched trajectory (state, comm totals and
/// every per-round loss) is bitwise identical to never crashing.
fn prove_resume_identical(
    tag: &str,
    parallelism: usize,
    agg: AggMode,
    cut: usize,
) {
    let mut cfg = mock_cfg(parallelism, true);
    cfg.agg = agg;
    assert!(cfg.error_feedback, "durability arms must exercise EF");
    let base = run_full(&format!("{tag}_base"), cfg.clone());

    let snaps = snap_dir(tag);
    let first =
        run_until_crash(&format!("{tag}_a"), cfg.clone(), cut, &snaps);
    let (start, resumed) =
        resume_and_finish(&format!("{tag}_b"), cfg, &snaps);
    assert_eq!(start, cut, "{tag}: resumed at the wrong round");

    let mut losses = first;
    losses.extend_from_slice(&resumed.losses);
    let stitched = Trace { losses, ..resumed };
    assert_eq!(
        stitched, base,
        "{tag}: resumed trajectory diverged from uninterrupted run"
    );
    let _ = fs::remove_dir_all(&snaps);
}

// ---- acceptance (a): bit-identical resume across the lever matrix --

#[test]
fn resume_is_bit_identical_flat_p1() {
    prove_resume_identical("flat_p1", 1, AggMode::Flat, 2);
}

#[test]
fn resume_is_bit_identical_flat_p4() {
    prove_resume_identical("flat_p4", 4, AggMode::Flat, 2);
}

#[test]
fn resume_is_bit_identical_tree_p1() {
    prove_resume_identical("tree_p1", 1, AggMode::Tree { nodes: 4 }, 2);
}

#[test]
fn resume_is_bit_identical_tree_p4() {
    prove_resume_identical("tree_p4", 4, AggMode::Tree { nodes: 4 }, 3);
}

#[test]
fn resume_with_empty_dir_is_a_cold_start() {
    // `--resume` on the very first launch of a kill/resume loop: no
    // snapshot yet, so the run starts at round 0 and must match a
    // run that never had snapshots armed.
    let cfg = mock_cfg(1, true);
    let base = run_full("cold_base", cfg.clone());
    let snaps = snap_dir("cold");
    let (start, resumed) = resume_and_finish("cold_b", cfg, &snaps);
    assert_eq!(start, 0);
    assert_eq!(resumed, base);
}

// ---- acceptance (b): corrupt newest generation falls back one ------

/// Corrupt the newest generation with `mangle`, then prove resume
/// falls back to the previous generation and the finished run is
/// STILL bit-identical to the uninterrupted baseline.
fn prove_fallback(tag: &str, mangle: impl Fn(&Path)) {
    let cfg = mock_cfg(1, true);
    let base = run_full(&format!("{tag}_base"), cfg.clone());

    let snaps = snap_dir(tag);
    let cut = 2; // leaves generations snap-00000001 + snap-00000002
    let first =
        run_until_crash(&format!("{tag}_a"), cfg.clone(), cut, &snaps);

    let newest = snaps.join("snap-00000002.fp8s");
    assert!(newest.exists(), "{tag}: expected newest generation");
    mangle(&newest);

    // fallback target is the round-1 snapshot: resume re-runs round 1
    let (start, resumed) =
        resume_and_finish(&format!("{tag}_b"), cfg, &snaps);
    assert_eq!(
        start, 1,
        "{tag}: corrupt newest should fall back one generation"
    );
    let mut losses = vec![first[0]];
    losses.extend_from_slice(&resumed.losses);
    let stitched = Trace { losses, ..resumed };
    assert_eq!(
        stitched, base,
        "{tag}: fallback resume diverged from uninterrupted run"
    );
    let _ = fs::remove_dir_all(&snaps);
}

#[test]
fn truncated_newest_falls_back_one_generation() {
    // torn write: the file ends mid-body
    prove_fallback("trunc", |p| {
        let bytes = fs::read(p).unwrap();
        fs::write(p, &bytes[..bytes.len() / 2]).unwrap();
    });
}

#[test]
fn byte_flipped_newest_falls_back_one_generation() {
    // bit rot: same length, one flipped body byte → crc catches it
    prove_fallback("flip", |p| {
        let mut bytes = fs::read(p).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        fs::write(p, bytes).unwrap();
    });
}

#[test]
fn all_generations_corrupt_is_a_typed_error_naming_each_file() {
    let cfg = mock_cfg(1, true);
    let snaps = snap_dir("allbad");
    run_until_crash("allbad_a", cfg.clone(), 2, &snaps);
    for gen in ["snap-00000001.fp8s", "snap-00000002.fp8s"] {
        let p = snaps.join(gen);
        let bytes = fs::read(&p).unwrap();
        fs::write(&p, &bytes[..8]).unwrap();
    }
    let (dir, manifest) = mock_manifest("allbad_b");
    let engine = Engine::new(&dir).unwrap();
    let transport = MockTransport::new(false);
    let mut server = Server::with_transport(
        &engine,
        &manifest,
        cfg,
        Box::new(&transport),
    )
    .unwrap();
    let err = server.resume_from(&snaps).unwrap_err();
    match err.downcast_ref::<SnapshotError>() {
        Some(SnapshotError::NoValidSnapshot { tried, .. }) => {
            assert_eq!(tried.len(), 2, "both generations tried");
            for gen in ["snap-00000001.fp8s", "snap-00000002.fp8s"] {
                assert!(
                    tried.iter().any(|t| t.contains(gen)),
                    "error does not name {gen}: {tried:?}"
                );
            }
        }
        other => panic!("expected NoValidSnapshot, got {other:?}"),
    }
    let _ = fs::remove_dir_all(&snaps);
}

// ---- regression: a crashed write_atomic cannot strand tmp files ----

#[test]
fn stale_tmp_from_crashed_write_is_pruned_on_resume() {
    // crash model: the process died between creating the temp file
    // and the rename commit point — exactly the state a resume
    // starts from. The orphan must be swept, the kept generations
    // must survive, and the resumed run must stay bit-identical.
    let cfg = mock_cfg(1, true);
    let base = run_full("tmp_base", cfg.clone());

    let snaps = snap_dir("tmp");
    let cut = 2;
    let first = run_until_crash("tmp_a", cfg.clone(), cut, &snaps);
    let orphan = snaps.join(".tmp-snap-00000003.fp8s");
    fs::write(&orphan, b"torn half-written garbage").unwrap();
    // a dotfile that does NOT match the temp pattern is not ours to
    // delete
    let foreign = snaps.join(".tmp-notes.txt");
    fs::write(&foreign, b"operator scratch").unwrap();

    let (start, resumed) = resume_and_finish("tmp_b", cfg, &snaps);
    assert_eq!(start, cut);
    assert!(
        !orphan.exists(),
        "stale .tmp-snap-* orphan survived resume"
    );
    assert!(
        foreign.exists(),
        "resume deleted a foreign dotfile it does not own"
    );
    for gen in ["snap-00000001.fp8s", "snap-00000002.fp8s"] {
        assert!(snaps.join(gen).exists(), "pruned a kept generation");
    }

    let mut losses = first;
    losses.extend_from_slice(&resumed.losses);
    let stitched = Trace { losses, ..resumed };
    assert_eq!(
        stitched, base,
        "tmp-prune changed the resumed trajectory"
    );
    let _ = fs::remove_dir_all(&snaps);
}

// ---- regression: wall clock is cumulative across resumes -----------

#[test]
fn wall_clock_is_cumulative_across_resume() {
    // pre-v2 snapshots had no wall_millis, so every resume restarted
    // the clock at zero while cum_bytes kept counting — skewing
    // bytes-vs-time comparisons. The counter must now ride the
    // snapshot: restore it on resume, persist it back out, and never
    // perturb the model trajectory.
    let cfg = mock_cfg(1, true);
    let base = run_full("wall_base", cfg.clone());

    let snaps = snap_dir("wall");
    let cut = 2;
    let first = run_until_crash("wall_a", cfg.clone(), cut, &snaps);

    // stamp the newest generation with 5s of pre-crash wall clock
    // (the manual-round harness never advances it, so plant a known
    // value the way a real `Server::run` segment would have)
    let newest = snaps.join("snap-00000002.fp8s");
    let mut s =
        decode(&fs::read(&newest).unwrap(), &newest).unwrap();
    assert_eq!(s.next_round, 2);
    s.wall_millis = 5_000;
    write_atomic(&snaps, &s).unwrap();

    let (dir, manifest) = mock_manifest("wall_b");
    let engine = Engine::new(&dir).unwrap();
    let transport = MockTransport::new(false);
    let rounds = cfg.rounds;
    let mut server = Server::with_transport(
        &engine,
        &manifest,
        cfg,
        Box::new(&transport),
    )
    .unwrap();
    let start = server.resume_from(&snaps).unwrap();
    assert_eq!(start, cut);
    assert_eq!(
        server.wall_millis(),
        5_000,
        "resume did not restore the cumulative wall clock"
    );

    let mut losses = first;
    for t in start..rounds {
        losses.push(server.round(t).unwrap().to_bits());
    }
    // the restored base must flow back out through save_snapshot —
    // a later resume of THIS segment starts from >= 5s, not zero
    server.save_snapshot(&snaps, rounds).unwrap();
    let last = snaps.join(format!("snap-{rounds:08}.fp8s"));
    let persisted =
        decode(&fs::read(&last).unwrap(), &last).unwrap();
    assert!(
        persisted.wall_millis >= 5_000,
        "cumulative wall clock reset at the resume boundary: {}",
        persisted.wall_millis
    );

    // and the clock is bookkeeping only: trajectory still identical
    let stitched =
        Trace { losses, ..Trace::capture(&server, Vec::new()) };
    assert_eq!(
        stitched, base,
        "wall-clock persistence changed the trajectory"
    );
    let _ = fs::remove_dir_all(&snaps);
}

// ---- acceptance (c): fingerprint mismatch is a hard reject ---------

#[test]
fn foreign_fingerprint_is_hard_rejected_naming_both() {
    // two configs that differ only in seed — different fingerprints,
    // same shapes, so only the gate (not a dim check) can catch it
    let cfg_a = mock_cfg(1, true);
    let mut cfg_b = mock_cfg(1, true);
    cfg_b.seed = 12;
    let fp_a = cfg_a.fingerprint();
    let fp_b = cfg_b.fingerprint();
    assert_ne!(fp_a, fp_b);

    let snaps = snap_dir("foreign");
    run_until_crash("foreign_a", cfg_a, 2, &snaps);

    let (dir, manifest) = mock_manifest("foreign_b");
    let engine = Engine::new(&dir).unwrap();
    let transport = MockTransport::new(false);
    let mut server = Server::with_transport(
        &engine,
        &manifest,
        cfg_b,
        Box::new(&transport),
    )
    .unwrap();
    let err = server.resume_from(&snaps).unwrap_err();
    match err.downcast_ref::<SnapshotError>() {
        Some(SnapshotError::FingerprintMismatch {
            snapshot,
            config,
            ..
        }) => {
            assert_eq!(*snapshot, fp_a);
            assert_eq!(*config, fp_b);
        }
        other => panic!("expected FingerprintMismatch, got {other:?}"),
    }
    // the Display names BOTH fingerprints so the operator can tell
    // which side is stale
    let msg = format!("{err:#}");
    assert!(
        msg.contains(&format!("{fp_a:#018x}"))
            && msg.contains(&format!("{fp_b:#018x}")),
        "error must name both fingerprints: {msg}"
    );
    let _ = fs::remove_dir_all(&snaps);
}

// ---- nightly soak: every boundary, every lever combination ---------

/// Kill/resume soak for the nightly workflow: interrupt at EVERY
/// round boundary, for flat and tree aggregation at parallelism 1
/// and 4 — 3 boundaries x 4 lever combinations, each proven
/// bit-identical against its uninterrupted baseline.
#[test]
#[ignore]
fn kill_resume_soak_every_boundary() {
    for (pi, parallelism) in [1usize, 4].into_iter().enumerate() {
        for (ai, agg) in
            [AggMode::Flat, AggMode::Tree { nodes: 4 }]
                .into_iter()
                .enumerate()
        {
            for cut in 1..mock_cfg(1, true).rounds {
                prove_resume_identical(
                    &format!("soak_p{pi}_a{ai}_c{cut}"),
                    parallelism,
                    agg,
                    cut,
                );
            }
        }
    }
}
