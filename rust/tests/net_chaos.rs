//! Chaos suite for the v2 networked transport: adversarial fault
//! schedules injected by a frame-aware TCP proxy, plus raw-socket
//! stall/partition actors.
//!
//! The invariant every scenario enforces is the acceptance contract
//! of the protocol upgrade: **any fault schedule that leaves the
//! round completable must end with results bit-identical to the
//! in-process transport** (weights, alphas, betas, losses, CommStats)
//! — and any schedule that doesn't must end in a *typed* error naming
//! the offending client, never a hang.
//!
//! Injected faults:
//!
//! * **mid-round disconnect** — the proxy swallows a Job frame and
//!   kills both legs; the server must detect the dead connection,
//!   re-dispatch the un-acked job to a surviving worker, and finish
//!   the round bit-exactly (the killed worker then rejoins directly,
//!   exercising the replacement acceptor).
//! * **delayed frames** — every proxied frame is forwarded late; the
//!   round completes bit-exactly (heartbeat probes must not
//!   misclassify a slow link as a dead one).
//! * **duplicated outcomes** — every Outcome frame is forwarded
//!   twice; the server must ignore the duplicates (at-least-once
//!   delivery) and count them.
//! * **stalled (heartbeat-less) worker** — a raw socket that
//!   handshakes, swallows its job, and never answers anything: the
//!   heartbeat state machine must declare it dead and re-dispatch
//!   (or, with no survivors, fail with the typed `HeartbeatLost`
//!   naming the client).
//! * **reconnect cache** — a worker whose connection drops after one
//!   outcome must answer the re-sent job on a fresh connection with
//!   byte-identical cached bytes and *zero* recomputation.
//! * **killed mid-tier aggregator** — under `--agg tree:G` over
//!   networked aggregators, a peer that swallows its shard and dies
//!   mid-round: the shard re-dispatches to a survivor (configured
//!   geometry, so the canonical accumulation — and the whole run —
//!   stays bit-identical).
//! * **corrupt Partial frame** — an aggregator answering with a
//!   checksum-corrupted Partial on a held-open socket must produce
//!   the typed checksum fault naming the aggregator, never a hang.
//!
//! The `soak_` test (ignored by default; nightly CI runs it with
//! `--ignored`) loops kill/rejoin schedules for
//! `FEDFP8_SOAK_SECS` (default 60) seconds of wall clock.

mod common;

use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::thread;
use std::time::{Duration, Instant};

use common::{
    mock_cfg, mock_manifest, run_mock, run_mock_agg, MockTransport,
    Trace,
};
use fedfp8::config::{AggMode, ExperimentConfig};
use fedfp8::coordinator::transport::{
    ClientJob, ClientOutcome, Transport, WorkBuffers,
};
use fedfp8::coordinator::{build_world, Server};
use fedfp8::fp8::codec as fp8codec;
use fedfp8::fp8::rng::Pcg32;
use fedfp8::net::frame::FrameKind;
use fedfp8::net::worker::WorkerCtx;
use fedfp8::net::{
    self, codec, frame, Hello, Inflight, OutcomeCache, ServeOpts,
    SocketCfg, WireJob,
};
use fedfp8::runtime::Engine;

fn hello_for(cfg: &ExperimentConfig) -> Hello {
    Hello {
        fingerprint: cfg.fingerprint(),
        dim: common::DIM as u64,
        model: "mock".into(),
        auth: 0,
        role: net::PeerRole::Worker,
        shard: None,
    }
}

/// One worker's link personality.
#[derive(Clone, Copy, Debug)]
enum Fault {
    /// Plain connection, no proxy.
    Direct,
    /// Forward every frame `ms` late (both directions).
    Delay(u64),
    /// Forward every Outcome frame twice.
    DuplicateOutcomes,
    /// Swallow the `n`-th Job frame and kill both legs — a mid-round
    /// disconnect with a job un-acked on the wire.
    CutAtJob(usize),
    /// Swallow the `n`-th Shard frame and kill both legs — a
    /// mid-round kill on the root -> aggregator backbone with a whole
    /// shard un-acked.
    CutAtShard(usize),
}

/// Frame-aware one-connection proxy. Listens on an ephemeral port;
/// the first (only) inbound connection is bridged to `upstream` with
/// `fault` applied. Pumps exit when either leg dies.
fn spawn_proxy<'s>(
    s: &'s thread::Scope<'s, '_>,
    upstream: String,
    fault: Fault,
) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    s.spawn(move || {
        let Ok((down_in, _)) = listener.accept() else { return };
        let Ok(up_out) = TcpStream::connect(&upstream) else { return };
        // clones so each pump can kill BOTH legs on a cut
        let w2s = (
            down_in.try_clone().unwrap(),
            up_out.try_clone().unwrap(),
        );
        let s2w = (up_out, down_in);
        let jobs_seen = AtomicUsize::new(0);
        thread::scope(|ps| {
            let jobs = &jobs_seen;
            // worker -> server leg
            ps.spawn(move || {
                let (mut from, mut to) = w2s;
                loop {
                    let f = match frame::read_frame(&mut from) {
                        Ok(f) => f,
                        Err(_) => break,
                    };
                    if let Fault::Delay(ms) = fault {
                        thread::sleep(Duration::from_millis(ms));
                    }
                    if frame::write_frame(&mut to, f.kind, &f.body)
                        .is_err()
                    {
                        break;
                    }
                    if matches!(fault, Fault::DuplicateOutcomes)
                        && f.kind == FrameKind::Outcome
                        && frame::write_frame(&mut to, f.kind, &f.body)
                            .is_err()
                    {
                        break;
                    }
                }
                let _ = from.shutdown(Shutdown::Both);
                let _ = to.shutdown(Shutdown::Both);
            });
            // server -> worker leg
            ps.spawn(move || {
                let (mut from, mut to) = s2w;
                loop {
                    let f = match frame::read_frame(&mut from) {
                        Ok(f) => f,
                        Err(_) => break,
                    };
                    if f.kind == FrameKind::Job {
                        let n =
                            jobs.fetch_add(1, Ordering::SeqCst) + 1;
                        if matches!(fault, Fault::CutAtJob(cut)
                                    if cut == n)
                        {
                            // swallow the job and drop the link:
                            // the server holds an un-acked dispatch
                            break;
                        }
                    }
                    if f.kind == FrameKind::Shard {
                        let n =
                            jobs.fetch_add(1, Ordering::SeqCst) + 1;
                        if matches!(fault, Fault::CutAtShard(cut)
                                    if cut == n)
                        {
                            // swallow the whole shard work order and
                            // drop the backbone link
                            break;
                        }
                    }
                    if let Fault::Delay(ms) = fault {
                        thread::sleep(Duration::from_millis(ms));
                    }
                    if frame::write_frame(&mut to, f.kind, &f.body)
                        .is_err()
                    {
                        break;
                    }
                }
                let _ = from.shutdown(Shutdown::Both);
                let _ = to.shutdown(Shutdown::Both);
            });
        });
    });
    addr
}

struct ChaosStats {
    requeues: u64,
    duplicates: u64,
    duplicate_bytes: u64,
    hedges: u64,
    bytes_received: u64,
    live_at_end: usize,
}

/// Run the full mock experiment over sockets with one personality per
/// worker; workers whose connection dies reconnect DIRECTLY to the
/// server (the replacement-acceptor path) with their outcome cache
/// intact.
fn run_chaos(
    tag: &str,
    parallelism: usize,
    inflight: usize,
    faults: &[Fault],
    hb_ms: u64,
    io_ms: u64,
) -> (Trace, ChaosStats) {
    run_chaos_hedged(
        tag, parallelism, inflight, faults, hb_ms, io_ms, 0, 0,
    )
}

/// `run_chaos` with the server's hedge timer armed (`hedge_ms > 0`
/// duplicates a straggler's job onto a second worker after that long
/// unanswered). `stagger_ms > 0` delays worker `w`'s first connect by
/// `w * stagger_ms`, making the server's connection-pool order — and
/// therefore the least-loaded tie-break — deterministic, so a test
/// can pin WHICH worker a primary or hedge dispatch lands on.
#[allow(clippy::too_many_arguments)]
fn run_chaos_hedged(
    tag: &str,
    parallelism: usize,
    inflight: usize,
    faults: &[Fault],
    hb_ms: u64,
    io_ms: u64,
    hedge_ms: u64,
    stagger_ms: u64,
) -> (Trace, ChaosStats) {
    let (dir, manifest) = mock_manifest(tag);
    let engine = Engine::new(&dir).unwrap();
    let cfg = mock_cfg(parallelism, false);
    let model = manifest.model("mock").unwrap();
    let world = build_world(&cfg, model).unwrap();
    let hello = hello_for(&cfg);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let server_addr = listener.local_addr().unwrap().to_string();
    let exec = MockTransport::new(true);
    let rounds = cfg.rounds;
    let fingerprint = cfg.fingerprint();
    let opts = ServeOpts {
        heartbeat: Duration::from_millis(hb_ms),
        idle_deadline: Duration::ZERO, // workers never give up here
        exec_threads: inflight,
    };
    let ctx = WorkerCtx {
        train: &world.train,
        shards: &world.shards,
        segments: &model.segments,
        kernel: cfg.fp8_kernel,
    };
    thread::scope(|s| {
        for (w, fault) in faults.iter().enumerate() {
            let first_addr = match fault {
                Fault::Direct => server_addr.clone(),
                f => spawn_proxy(s, server_addr.clone(), *f),
            };
            let (server_addr, hello, exec, ctx, opts) =
                (&server_addr, &hello, &exec, &ctx, &opts);
            s.spawn(move || {
                thread::sleep(Duration::from_millis(
                    w as u64 * stagger_ms,
                ));
                let cache = OutcomeCache::new(64);
                let mut target = first_addr;
                for attempt in 0..4u32 {
                    let Ok(mut stream) = net::connect(
                        &target,
                        hello,
                        Duration::from_secs(10),
                    ) else {
                        // proxy already dead: rejoin directly
                        target = server_addr.clone();
                        continue;
                    };
                    match net::serve_conn(
                        &mut stream,
                        exec,
                        ctx,
                        opts,
                        fingerprint,
                        &cache,
                    ) {
                        Ok(()) => return, // orderly shutdown
                        Err(e) => {
                            // dropped link: rejoin as a replacement
                            // worker, cache intact
                            eprintln!(
                                "[chaos worker {w} attempt \
                                 {attempt}] serve ended: {e:#}"
                            );
                            target = server_addr.clone();
                        }
                    }
                }
            });
        }
        let transport = net::accept_workers(
            listener,
            faults.len(),
            &hello,
            SocketCfg {
                heartbeat: Duration::from_millis(hb_ms),
                inflight: Inflight::Fixed(inflight),
                hedge: Duration::from_millis(hedge_ms),
                ..SocketCfg::new(Duration::from_millis(io_ms))
            },
        )
        .expect("server handshake");
        let mut server = Server::with_transport(
            &engine,
            &manifest,
            cfg,
            Box::new(&transport),
        )
        .unwrap();
        let mut losses = Vec::new();
        for t in 0..rounds {
            losses.push(server.round(t).unwrap().to_bits());
        }
        let trace = Trace::capture(&server, losses);
        if hedge_ms > 0 {
            // give the last round's hedge losers time to land, so the
            // duplicate counters below are settled before capture
            let wait = Instant::now() + Duration::from_secs(5);
            while transport.hedges() > 0
                && transport.duplicate_outcomes() == 0
                && Instant::now() < wait
            {
                thread::sleep(Duration::from_millis(20));
            }
        }
        let stats = ChaosStats {
            requeues: transport.requeues(),
            duplicates: transport.duplicate_outcomes(),
            duplicate_bytes: transport.duplicate_outcome_bytes(),
            hedges: transport.hedges(),
            bytes_received: transport.bytes_received(),
            live_at_end: transport.live_workers(),
        };
        drop(server);
        transport.shutdown();
        (trace, stats)
    })
}

#[test]
fn mid_round_disconnect_requeues_and_stays_bit_identical() {
    let base = run_mock(4, false);
    // worker 0's proxy swallows its second job and dies mid-round;
    // the un-acked job must be re-dispatched to a surviving worker
    let (trace, stats) = run_chaos(
        "cut",
        4,
        2,
        &[Fault::CutAtJob(2), Fault::Direct, Fault::Direct],
        500,
        5_000,
    );
    assert_eq!(
        trace, base,
        "mid-round disconnect changed the trajectory"
    );
    assert!(
        stats.requeues >= 1,
        "the swallowed job was never re-dispatched"
    );
    // reported-vs-framed uplink identity under faults: only matched
    // outcomes count, so re-dispatch must not skew the headline
    // communication metric
    assert_eq!(
        stats.bytes_received, trace.comm.up_bytes,
        "re-dispatch skewed the reported uplink bytes"
    );
}

#[test]
fn delayed_frames_complete_bit_identical() {
    let base = run_mock(4, false);
    let (trace, stats) = run_chaos(
        "delay",
        4,
        2,
        &[Fault::Delay(60), Fault::Direct, Fault::Direct],
        150,
        8_000,
    );
    assert_eq!(trace, base, "a slow link changed the trajectory");
    assert_eq!(
        stats.requeues, 0,
        "a merely-slow worker was misclassified as dead"
    );
}

#[test]
fn duplicated_outcomes_are_ignored_and_counted() {
    let base = run_mock(4, false);
    let (trace, stats) = run_chaos(
        "dup",
        4,
        2,
        &[Fault::DuplicateOutcomes, Fault::Direct],
        500,
        5_000,
    );
    assert_eq!(trace, base, "duplicate outcomes changed the trajectory");
    assert!(
        stats.duplicates >= 1,
        "duplicated outcome frames were not detected"
    );
    // the satellite fix: duplicate frames land in their OWN byte
    // counter, and the reported uplink stays identical to the frames
    // that were actually aggregated — duplication must not inflate
    // the paper's headline communication metric
    assert!(
        stats.duplicate_bytes > 0,
        "dropped duplicates were not byte-accounted"
    );
    assert_eq!(
        stats.bytes_received, trace.comm.up_bytes,
        "duplicate outcomes inflated the reported uplink bytes"
    );
}

#[test]
fn hedged_dispatch_races_a_straggler_and_aggregates_once() {
    // worker 0's link delays every frame 400 ms; with a 150 ms hedge
    // timer the server must duplicate the straggling job onto the
    // healthy worker BEFORE any deadline. Both answers eventually
    // arrive (they are bit-identical by the determinism contract);
    // exactly one is aggregated, the loser is counted a duplicate,
    // and the trajectory matches in-process exactly.
    let base = run_mock(4, false);
    let (trace, stats) = run_chaos_hedged(
        "hedge",
        4,
        2,
        &[Fault::Delay(400), Fault::Direct],
        500,
        8_000,
        150,
        0,
    );
    assert_eq!(trace, base, "hedging changed the trajectory");
    assert!(
        stats.hedges >= 1,
        "the straggler was never hedged (hedge timer never fired)"
    );
    assert!(
        stats.duplicates >= 1,
        "the hedge loser's answer was never observed as a duplicate"
    );
    assert_eq!(stats.requeues, 0, "hedging is not failure re-dispatch");
    // matched-exactly-once: however the two answers race, the
    // reported uplink equals the aggregated outcomes alone
    assert_eq!(
        stats.bytes_received, trace.comm.up_bytes,
        "hedge duplicates leaked into the reported uplink bytes"
    );
}

#[test]
fn dead_hedge_route_is_rearmed_once() {
    // The regression: a hedged job whose hedge CONNECTION dies used
    // to fall back to a single route for the rest of the wait — the
    // set-once `hedged` latch never re-fired, leaving the job alone
    // with the very straggler the hedge existed to beat. Now one
    // re-hedge is allowed per dispatch attempt.
    //
    // Deterministic schedule (staggered connects pin the pool order,
    // and the least-loaded tie-break picks the earliest pool entry):
    //
    //   worker 0: Delay(600)  — pooled first, so with parallelism 1
    //                           every primary dispatch lands here
    //                           and straggles past the 150 ms hedge
    //   worker 1: CutAtJob(1) — pooled second, so the FIRST hedge
    //                           lands here; the proxy swallows that
    //                           job and kills the link (a dead hedge
    //                           route with the job un-acked)
    //   worker 2: Direct      — the only place a re-hedge can go
    //
    // The proof is in the counters: without the fix, each of the 16
    // dispatch attempts can fire at most ONE hedge, so
    // hedges <= attempts; the re-hedge pushes it past that bound.
    let base = run_mock(1, false);
    let (trace, stats) = run_chaos_hedged(
        "rehedge",
        1,
        2,
        &[Fault::Delay(600), Fault::CutAtJob(1), Fault::Direct],
        2_000,
        8_000,
        150,
        1_500,
    );
    assert_eq!(
        trace, base,
        "a dying hedge route changed the trajectory"
    );
    assert_eq!(
        stats.requeues, 0,
        "a dead hedge route must not trigger failure re-dispatch \
         while the primary is alive"
    );
    let attempts = 16; // 4 rounds x 4 clients, no requeues
    assert!(
        stats.hedges > attempts,
        "no re-hedge after the hedge route died: {} hedge dispatches \
         across {attempts} attempts (set-once latch is back?)",
        stats.hedges
    );
    assert!(
        stats.duplicates >= 1,
        "the straggler's late answers were never seen as duplicates"
    );
    // re-hedge losers land in duplicate accounting like any hedge
    // loser: the reported uplink still equals aggregated frames only
    assert_eq!(
        stats.bytes_received, trace.comm.up_bytes,
        "re-hedge duplicates leaked into the reported uplink bytes"
    );
}

#[test]
fn multiplexed_window_survives_disconnect() {
    // the acceptance-criteria shape: --net-inflight 4, one worker
    // killed mid-round, byte-identical completion
    let base = run_mock(4, false);
    let (trace, stats) = run_chaos(
        "cutwin",
        4,
        4,
        &[Fault::CutAtJob(1), Fault::Direct],
        500,
        5_000,
    );
    assert_eq!(
        trace, base,
        "inflight-4 + worker kill changed the trajectory"
    );
    assert!(stats.requeues >= 1);
    assert!(stats.live_at_end >= 1);
}

// ---- stalled (heartbeat-less) workers ------------------------------

/// A raw actor that handshakes like a worker, then reads and ignores
/// everything: never answers a job, never acks a probe.
fn spawn_stalled_worker<'s>(
    s: &'s thread::Scope<'s, '_>,
    addr: &'s str,
    hello: &'s Hello,
    hold: Duration,
) {
    s.spawn(move || {
        let Ok(stream) =
            net::connect(addr, hello, Duration::from_secs(10))
        else {
            return;
        };
        let mut stream = stream;
        stream
            .set_read_timeout(Some(Duration::from_millis(50)))
            .unwrap();
        let deadline = Instant::now() + hold;
        let mut fr = frame::FrameReader::new();
        while Instant::now() < deadline {
            // drain whatever arrives, answer nothing
            match fr.poll(&mut stream) {
                Ok(_) => {}
                Err(_) => break,
            }
        }
    });
}

#[test]
fn stalled_worker_is_detected_and_work_requeued() {
    let base = run_mock(4, false);
    let (dir, manifest) = mock_manifest("stall");
    let engine = Engine::new(&dir).unwrap();
    let cfg = mock_cfg(4, false);
    let model = manifest.model("mock").unwrap();
    let world = build_world(&cfg, model).unwrap();
    let hello = hello_for(&cfg);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let exec = MockTransport::new(true);
    let rounds = cfg.rounds;
    let fingerprint = cfg.fingerprint();
    let opts = ServeOpts {
        heartbeat: Duration::from_millis(150),
        idle_deadline: Duration::ZERO,
        exec_threads: 2,
    };
    let ctx = WorkerCtx {
        train: &world.train,
        shards: &world.shards,
        segments: &model.segments,
        kernel: cfg.fp8_kernel,
    };
    let trace = thread::scope(|s| {
        // the stall: holds its socket open, answers nothing, long
        // past the server's idle deadline
        spawn_stalled_worker(s, &addr, &hello, Duration::from_secs(8));
        for _ in 0..2 {
            let (addr, hello, exec, ctx, opts) =
                (&addr, &hello, &exec, &ctx, &opts);
            s.spawn(move || {
                let cache = OutcomeCache::new(64);
                let mut stream = net::connect(
                    addr,
                    hello,
                    Duration::from_secs(10),
                )
                .expect("healthy worker handshake");
                let _ = net::serve_conn(
                    &mut stream,
                    exec,
                    ctx,
                    opts,
                    fingerprint,
                    &cache,
                );
            });
        }
        let transport = net::accept_workers(
            listener,
            3,
            &hello,
            SocketCfg {
                heartbeat: Duration::from_millis(150),
                inflight: Inflight::Fixed(2),
                ..SocketCfg::new(Duration::from_millis(700))
            },
        )
        .expect("server handshake");
        let mut server = Server::with_transport(
            &engine,
            &manifest,
            cfg,
            Box::new(&transport),
        )
        .unwrap();
        let mut losses = Vec::new();
        for t in 0..rounds {
            losses.push(server.round(t).unwrap().to_bits());
        }
        let trace = Trace::capture(&server, losses);
        // heartbeat loss must have evicted the stalled connection
        assert!(
            transport.live_workers() <= 2,
            "stalled worker still counted live"
        );
        drop(server);
        transport.shutdown();
        trace
    });
    assert_eq!(trace, base, "a stalled worker changed the trajectory");
}

#[test]
fn lone_stalled_worker_fails_typed_with_client_named() {
    let (dir, manifest) = mock_manifest("stall1");
    let engine = Engine::new(&dir).unwrap();
    let mut cfg = mock_cfg(1, false);
    cfg.clients = 1;
    cfg.participation = 1;
    let hello = hello_for(&cfg);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let msg = thread::scope(|s| {
        spawn_stalled_worker(s, &addr, &hello, Duration::from_secs(4));
        let transport = net::accept_workers(
            listener,
            1,
            &hello,
            SocketCfg {
                heartbeat: Duration::from_millis(100),
                inflight: Inflight::Fixed(2),
                ..SocketCfg::new(Duration::from_millis(500))
            },
        )
        .expect("handshake");
        let mut server = Server::with_transport(
            &engine,
            &manifest,
            cfg,
            Box::new(&transport),
        )
        .unwrap();
        let err = server.round(0).unwrap_err();
        let msg = format!("{err:?}");
        drop(server);
        transport.shutdown();
        msg
    });
    assert!(msg.contains("client 0"), "missing client id: {msg}");
    assert!(
        msg.contains("heartbeat lost") && msg.contains("timed out"),
        "not a typed heartbeat-loss error: {msg}"
    );
}

#[test]
fn stalled_half_connector_does_not_delay_a_healthy_replacement() {
    // the acceptor head-of-line regression: a connector that opens a
    // socket but never sends its Hello used to pin the acceptor in a
    // blocking handshake for up to io_timeout, stalling every other
    // rejoin behind it. Under the poll loop, half-open handshakes
    // just sit in a table — a healthy replacement arriving AFTER the
    // stall must still join immediately.
    let cfg = mock_cfg(1, false);
    let hello = hello_for(&cfg);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let io_timeout = Duration::from_secs(4);
    thread::scope(|s| {
        // initial worker: handshake, then idle holding the socket
        let (addr_ref, hello_ref) = (&addr, &hello);
        s.spawn(move || {
            let stream = net::connect(
                addr_ref,
                hello_ref,
                Duration::from_secs(10),
            )
            .expect("initial worker handshake");
            thread::sleep(Duration::from_secs(6));
            drop(stream);
        });
        let transport = net::accept_workers(
            listener,
            1,
            &hello,
            SocketCfg {
                heartbeat: Duration::ZERO,
                inflight: Inflight::Fixed(1),
                ..SocketCfg::new(io_timeout)
            },
        )
        .expect("server handshake");
        // the stall: a raw socket that never sends its Hello
        let half_open = TcpStream::connect(&addr).unwrap();
        thread::sleep(Duration::from_millis(200));
        // the healthy replacement, arriving BEHIND the stall
        let started = Instant::now();
        let replacement = net::connect(
            &addr,
            &hello,
            Duration::from_secs(10),
        )
        .expect("healthy replacement handshake");
        let join_latency = started.elapsed();
        assert!(
            join_latency < Duration::from_secs(2),
            "healthy replacement was stalled {join_latency:?} behind \
             a half-open connector (io_timeout {io_timeout:?})"
        );
        // and it really is in the pool
        let deadline = Instant::now() + Duration::from_secs(5);
        while transport.live_workers() < 2 && Instant::now() < deadline
        {
            thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(
            transport.live_workers(),
            2,
            "replacement never joined the pool"
        );
        drop(replacement);
        drop(half_open);
        transport.shutdown();
    });
}

// ---- worker-side partition detection -------------------------------

#[test]
fn worker_detects_a_silent_server_partition() {
    let (_dir, manifest) = mock_manifest("wpart");
    let cfg = mock_cfg(1, false);
    let hello = hello_for(&cfg);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let model = manifest.model("mock").unwrap();
    let world = build_world(&cfg, model).unwrap();
    let exec = MockTransport::new(false);
    let ctx = WorkerCtx {
        train: &world.train,
        shards: &world.shards,
        segments: &model.segments,
        kernel: cfg.fp8_kernel,
    };
    let err = thread::scope(|s| {
        // a "server" that handshakes then goes completely silent
        s.spawn(|| {
            let Ok((mut conn, _)) = listener.accept() else { return };
            let f = frame::read_frame(&mut conn).expect("hello");
            assert_eq!(f.kind, FrameKind::Hello);
            let mut ack = Vec::new();
            codec::encode_hello_ack(hello.fingerprint, hello.auth, &mut ack);
            frame::write_frame(&mut conn, FrameKind::HelloAck, &ack)
                .unwrap();
            // hold the socket open, say nothing
            thread::sleep(Duration::from_millis(1500));
        });
        let mut stream = net::connect(
            &addr,
            &hello,
            Duration::from_secs(5),
        )
        .expect("handshake");
        let cache = OutcomeCache::new(4);
        let opts = ServeOpts {
            heartbeat: Duration::from_millis(80),
            idle_deadline: Duration::from_millis(400),
            exec_threads: 1,
        };
        net::serve_conn(
            &mut stream,
            &exec,
            &ctx,
            &opts,
            cfg.fingerprint(),
            &cache,
        )
        .unwrap_err()
    });
    let msg = format!("{err:?}");
    assert!(
        msg.contains("heartbeat lost") && msg.contains("silent"),
        "worker did not detect the partition: {msg}"
    );
}

// ---- reconnect cache ----------------------------------------------

/// Executor that counts real local-update executions, so the cache
/// test can prove a re-dispatched job was NOT recomputed.
struct CountingExec {
    inner: MockTransport,
    runs: AtomicUsize,
}

impl Transport for CountingExec {
    fn run_client(
        &self,
        job: ClientJob<'_>,
        buffers: &mut WorkBuffers,
    ) -> anyhow::Result<ClientOutcome> {
        self.runs.fetch_add(1, Ordering::SeqCst);
        self.inner.run_client(job, buffers)
    }
}

#[test]
fn reconnect_serves_cached_bit_identical_outcome() {
    let (_dir, manifest) = mock_manifest("rcache");
    let cfg = mock_cfg(1, false);
    let model = manifest.model("mock").unwrap();
    let world = build_world(&cfg, model).unwrap();
    let hello = hello_for(&cfg);
    let fingerprint = cfg.fingerprint();
    // a real broadcast payload for client 0's job
    let w = manifest.load_init(model, "w").unwrap();
    let alpha = manifest.load_init(model, "alpha").unwrap();
    let beta = manifest.load_init(model, "beta").unwrap();
    let mut rng = Pcg32::new(cfg.seed, 0x7E57);
    let down = fp8codec::encode(
        &w,
        &alpha,
        &beta,
        &model.segments,
        cfg.comm,
        &mut rng,
    );
    let job = WireJob {
        round: 0,
        client: 0,
        job_id: 0,
        seed: cfg.seed,
        qat: cfg.qat,
        comm: cfg.comm,
        flip_aug: cfg.flip_aug,
        lr: cfg.lr,
        weight_decay: cfg.weight_decay,
        n_k: world.shards.n_k(0),
        down,
        ef: None,
    };
    let mut job_body = Vec::new();
    codec::encode_job(&job, &mut job_body);

    let exec = CountingExec {
        inner: MockTransport::new(false),
        runs: AtomicUsize::new(0),
    };
    let ctx = WorkerCtx {
        train: &world.train,
        shards: &world.shards,
        segments: &model.segments,
        kernel: cfg.fp8_kernel,
    };
    let cache = OutcomeCache::new(8);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();

    let (out1, out2) = thread::scope(|s| {
        let (hello_ref, exec_ref, ctx_ref, cache_ref) =
            (&hello, &exec, &ctx, &cache);
        let addr_ref = &addr;
        s.spawn(move || {
            let opts = ServeOpts {
                heartbeat: Duration::ZERO,
                idle_deadline: Duration::ZERO,
                exec_threads: 1,
            };
            // serve two consecutive connections with ONE cache: the
            // first is dropped by the "server", the second replays
            // the identical job
            for attempt in 0..2 {
                let mut stream = net::connect(
                    addr_ref,
                    hello_ref,
                    Duration::from_secs(10),
                )
                .expect("worker handshake");
                let r = net::serve_conn(
                    &mut stream,
                    exec_ref,
                    ctx_ref,
                    &opts,
                    fingerprint,
                    cache_ref,
                );
                if attempt == 1 {
                    r.expect("second serve should end cleanly");
                }
            }
        });
        // fake server: two sequential accept/handshake/job dialogs
        let dialog = |shutdown_after: bool| -> Vec<u8> {
            let (mut conn, _) = listener.accept().unwrap();
            conn.set_read_timeout(Some(Duration::from_secs(10)))
                .unwrap();
            let f = frame::read_frame(&mut conn).unwrap();
            assert_eq!(f.kind, FrameKind::Hello);
            let h = codec::decode_hello(&f.body).unwrap();
            assert_eq!(h.fingerprint, fingerprint);
            let mut ack = Vec::new();
            codec::encode_hello_ack(fingerprint, 0, &mut ack);
            frame::write_frame(&mut conn, FrameKind::HelloAck, &ack)
                .unwrap();
            frame::write_frame(&mut conn, FrameKind::Job, &job_body)
                .unwrap();
            let f = frame::read_frame(&mut conn).unwrap();
            assert_eq!(f.kind, FrameKind::Outcome);
            if shutdown_after {
                frame::write_frame(&mut conn, FrameKind::Shutdown, &[])
                    .unwrap();
            } else {
                // abrupt drop: the worker must reconnect
                conn.shutdown(Shutdown::Both).ok();
            }
            f.body
        };
        let out1 = dialog(false);
        let out2 = dialog(true);
        (out1, out2)
    });

    assert_eq!(
        out1, out2,
        "cached outcome bytes differ from the original"
    );
    assert_eq!(
        exec.runs.load(Ordering::SeqCst),
        1,
        "re-dispatched job was recomputed instead of served from cache"
    );
    let (hits, _) = cache.stats();
    assert_eq!(hits, 1, "outcome cache never hit");
    // and the decoded outcome really is the job's answer
    let out = codec::decode_outcome(&out1).unwrap();
    assert_eq!((out.round, out.client, out.job_id), (0, 0, 0));
    assert_eq!(out.n_k, job.n_k);
}

// ---- soak (nightly) ------------------------------------------------

/// 60-second (configurable) kill/rejoin soak: repeated multi-worker
/// loopback experiments with a forced mid-round kill at a rotating
/// position, every iteration checked bit-identical to in-process.
/// Heavy for per-PR CI, so `#[ignore]`d; the nightly workflow runs
/// `cargo test --release --test net_chaos -- --ignored soak_`.
#[test]
#[ignore = "nightly soak — run with --ignored (FEDFP8_SOAK_SECS)"]
fn soak_multi_worker_forced_kills() {
    let secs: u64 = std::env::var("FEDFP8_SOAK_SECS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(60);
    // nightly runs the soak with hedging armed (FEDFP8_SOAK_HEDGE_MS)
    // so the kill/rejoin schedule also races the hedge timer against
    // connection failures; 0 keeps the historical no-hedge soak
    let hedge_ms: u64 = std::env::var("FEDFP8_SOAK_HEDGE_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    let deadline = Instant::now() + Duration::from_secs(secs);
    let base = run_mock(4, false);
    let mut iters = 0u64;
    let mut requeues = 0u64;
    let mut hedges = 0u64;
    while Instant::now() < deadline {
        let cut = (iters as usize % 3) + 1;
        let window = [1usize, 2, 4][iters as usize % 3];
        let (trace, stats) = run_chaos_hedged(
            &format!("soak{iters}"),
            4,
            window,
            &[Fault::CutAtJob(cut), Fault::Direct, Fault::Direct],
            250,
            5_000,
            hedge_ms,
            0,
        );
        assert_eq!(
            trace, base,
            "soak iteration {iters} (cut={cut}, window={window}, \
             hedge={hedge_ms}ms) diverged"
        );
        requeues += stats.requeues;
        hedges += stats.hedges;
        iters += 1;
    }
    println!(
        "soak: {iters} iterations, {requeues} re-dispatches, \
         {hedges} hedges, all bit-identical"
    );
    assert!(iters >= 1, "soak never completed an iteration");
    // sanity: the schedule actually exercised the failover path
    assert!(requeues >= iters, "kills did not force re-dispatches");
}

// ---- aggregator backbone faults ------------------------------------

/// Hello for a mid-tier aggregator connection pinning shard `i/g`.
fn agg_hello(
    cfg: &ExperimentConfig,
    pin: Option<(u32, u32)>,
) -> Hello {
    Hello {
        fingerprint: cfg.fingerprint(),
        dim: common::DIM as u64,
        model: "mock".into(),
        auth: 0,
        role: net::PeerRole::Aggregator,
        shard: pin,
    }
}

#[test]
fn killed_aggregator_shard_redispatches_bit_identical() {
    // --agg tree:2 with two networked aggregators; aggregator 0
    // handshakes, swallows its round-0 shard and dies. The shard
    // geometry is configured (not live), so the survivor executes the
    // dead peer's shard and every round — including the rest of the
    // run on a single aggregator — must stay bit-identical to the
    // in-process tree.
    let base = run_mock_agg(4, false, AggMode::Tree { nodes: 2 });
    let (dir, manifest) = mock_manifest("aggkill");
    let engine = Engine::new(&dir).unwrap();
    let mut cfg = mock_cfg(4, false);
    cfg.agg = AggMode::Tree { nodes: 2 };
    let model = manifest.model("mock").unwrap();
    let agg_cfg = cfg.clone();
    let world = build_world(&agg_cfg, model).unwrap();
    let ctx = net::AggregatorCtx {
        cfg: &agg_cfg,
        train: &world.train,
        shards: &world.shards,
        segments: &model.segments,
        dim: model.dim,
        alpha_dim: model.alpha_dim,
        beta_dim: model.n_act,
    };
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let root_hello = hello_for(&cfg);
    let rounds = cfg.rounds;
    let trace = thread::scope(|s| {
        // aggregator 0: the mid-round kill
        {
            let (addr, agg_cfg) = (&addr, &agg_cfg);
            s.spawn(move || {
                let hello = agg_hello(agg_cfg, Some((0, 2)));
                let mut stream = net::connect(
                    addr,
                    &hello,
                    Duration::from_secs(10),
                )
                .expect("treacherous handshake");
                let f = frame::read_frame(&mut stream)
                    .expect("first shard");
                assert_eq!(f.kind, FrameKind::Shard);
                // die with the shard un-answered
                stream.shutdown(Shutdown::Both).ok();
            });
        }
        // aggregator 1: healthy; inherits the dead peer's shard
        {
            let (addr, ctx, agg_cfg) = (&addr, &ctx, &agg_cfg);
            s.spawn(move || {
                let exec = MockTransport::new(true);
                let hello = agg_hello(agg_cfg, Some((1, 2)));
                let mut stream = net::connect(
                    addr,
                    &hello,
                    Duration::from_secs(10),
                )
                .expect("healthy handshake");
                let opts = ServeOpts {
                    heartbeat: Duration::ZERO,
                    idle_deadline: Duration::ZERO,
                    exec_threads: 1,
                };
                net::serve_upstream(&mut stream, &exec, ctx, &opts)
                    .expect("healthy aggregator serve loop");
            });
        }
        let transport = net::accept_aggregators(
            listener,
            2,
            &root_hello,
            SocketCfg {
                heartbeat: Duration::ZERO,
                ..SocketCfg::new(Duration::from_secs(10))
            },
        )
        .expect("root handshake");
        let mut server = Server::with_transport(
            &engine,
            &manifest,
            cfg,
            Box::new(&transport),
        )
        .unwrap();
        let mut losses = Vec::new();
        for t in 0..rounds {
            losses.push(server.round(t).unwrap().to_bits());
        }
        let trace = Trace::capture(&server, losses);
        assert!(
            transport.requeues() >= 1,
            "the kill never forced a shard re-dispatch"
        );
        drop(server);
        transport.shutdown();
        trace
    });
    assert_eq!(
        trace, base,
        "re-dispatched shard diverged from the in-process tree"
    );
}

#[test]
fn corrupt_partial_frame_fails_typed_naming_the_aggregator() {
    // a lone aggregator answers its shard with a valid ShardDone and
    // a Partial whose envelope lies about the body checksum, then
    // keeps the socket open: the round must fail *fast* with the
    // typed checksum fault, the shard context and the aggregator
    // named — never hang on the held-open link
    let (dir, manifest) = mock_manifest("aggcrc");
    let engine = Engine::new(&dir).unwrap();
    let mut cfg = mock_cfg(1, false);
    cfg.agg = AggMode::Tree { nodes: 1 };
    let agg_cfg = cfg.clone();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let root_hello = hello_for(&cfg);
    let err = thread::scope(|s| {
        {
            let (addr, agg_cfg) = (&addr, &agg_cfg);
            s.spawn(move || {
                use std::io::Write;
                let hello = agg_hello(agg_cfg, Some((0, 1)));
                let mut stream = net::connect(
                    addr,
                    &hello,
                    Duration::from_secs(10),
                )
                .expect("malicious handshake");
                let f = frame::read_frame(&mut stream).expect("shard");
                assert_eq!(f.kind, FrameKind::Shard);
                let shard =
                    codec::decode_shard(&f.body).expect("shard body");
                // a perfectly valid ShardDone first — the fault must
                // be pinned on the Partial, not the protocol order
                let done = codec::WireShardDone {
                    round: shard.round,
                    lo: shard.lo,
                    hi: shard.hi,
                    up_bytes: 0,
                    up_msgs: 0,
                    efs: vec![],
                };
                let mut body = Vec::new();
                codec::encode_shard_done(&done, &mut body);
                frame::write_frame(
                    &mut stream,
                    FrameKind::ShardDone,
                    &body,
                )
                .expect("shard done");
                // ... then a Partial with a corrupted checksum
                let junk = vec![0u8; 28];
                let mut envelope = Vec::new();
                envelope.extend_from_slice(&frame::MAGIC);
                envelope.extend_from_slice(
                    &frame::WIRE_VERSION.to_le_bytes(),
                );
                envelope.push(FrameKind::Partial as u8);
                envelope.push(0);
                envelope.extend_from_slice(
                    &(junk.len() as u32).to_le_bytes(),
                );
                envelope.extend_from_slice(
                    &(frame::crc32(&junk) ^ 1).to_le_bytes(),
                );
                envelope.extend_from_slice(&junk);
                stream.write_all(&envelope).expect("corrupt partial");
                stream.flush().ok();
                // hold the link open: the checksum, not an EOF, is
                // what must kill the connection
                thread::sleep(Duration::from_millis(1500));
            });
        }
        let transport = net::accept_aggregators(
            listener,
            1,
            &root_hello,
            SocketCfg {
                heartbeat: Duration::ZERO,
                ..SocketCfg::new(Duration::from_secs(5))
            },
        )
        .expect("root handshake");
        let mut server = Server::with_transport(
            &engine,
            &manifest,
            cfg,
            Box::new(&transport),
        )
        .unwrap();
        let started = Instant::now();
        let err = server.round(0).unwrap_err();
        assert!(
            started.elapsed() < Duration::from_secs(5),
            "corrupt partial stalled the round for {:?}",
            started.elapsed()
        );
        drop(server);
        transport.shutdown();
        err
    });
    let msg = format!("{err:#}");
    assert!(
        msg.contains("aggregator"),
        "error does not name the aggregator: {msg}"
    );
    assert!(
        msg.contains("checksum"),
        "not the typed checksum fault: {msg}"
    );
    assert!(
        msg.contains("shard"),
        "error lost the shard context: {msg}"
    );
}

// ---- three-tier soak (nightly) --------------------------------------

/// Run the full mock experiment as a THREE-tier deployment — root +
/// two networked aggregators, each fronting two socket workers — with
/// the root -> aggregator-0 link riding the frame proxy, which cuts
/// it at the `cut`-th Shard frame. Aggregator 0 then rejoins the root
/// directly (the replacement-acceptor path) and serves the rest of
/// the run. Returns the bit-exact trace plus the root's re-dispatch
/// count.
fn run_tree_chaos(tag: &str, cut: usize) -> (Trace, u64) {
    let (dir, manifest) = mock_manifest(tag);
    let engine = Engine::new(&dir).unwrap();
    let mut cfg = mock_cfg(4, false);
    cfg.agg = AggMode::Tree { nodes: 2 };
    let model = manifest.model("mock").unwrap();
    let agg_cfg = cfg.clone();
    let world = build_world(&agg_cfg, model).unwrap();
    let ctx = net::AggregatorCtx {
        cfg: &agg_cfg,
        train: &world.train,
        shards: &world.shards,
        segments: &model.segments,
        dim: model.dim,
        alpha_dim: model.alpha_dim,
        beta_dim: model.n_act,
    };
    let worker_ctx = WorkerCtx {
        train: &world.train,
        shards: &world.shards,
        segments: &model.segments,
        kernel: cfg.fp8_kernel,
    };
    let fingerprint = cfg.fingerprint();
    let root_hello = hello_for(&cfg);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let root_addr = listener.local_addr().unwrap().to_string();
    let rounds = cfg.rounds;
    thread::scope(|s| {
        let proxy_addr =
            spawn_proxy(s, root_addr.clone(), Fault::CutAtShard(cut));
        for i in 0..2usize {
            let down_listener =
                TcpListener::bind("127.0.0.1:0").unwrap();
            let down_addr =
                down_listener.local_addr().unwrap().to_string();
            // the aggregator's own two-worker fleet
            for _ in 0..2 {
                let (worker_ctx, agg_cfg) = (&worker_ctx, &agg_cfg);
                let down_addr = down_addr.clone();
                s.spawn(move || {
                    let exec = MockTransport::new(true);
                    let cache = OutcomeCache::new(64);
                    let opts = ServeOpts {
                        heartbeat: Duration::ZERO,
                        idle_deadline: Duration::ZERO,
                        exec_threads: 1,
                    };
                    let mut stream = net::connect(
                        &down_addr,
                        &hello_for(agg_cfg),
                        Duration::from_secs(20),
                    )
                    .expect("worker handshake");
                    net::serve_conn(
                        &mut stream,
                        &exec,
                        worker_ctx,
                        &opts,
                        fingerprint,
                        &cache,
                    )
                    .expect("worker serve loop");
                });
            }
            // the aggregator itself: downstream SocketTransport as
            // its executor, upstream serve loop to the root
            let (ctx, agg_cfg) = (&ctx, &agg_cfg);
            let (root_addr, proxy_addr) =
                (root_addr.clone(), proxy_addr.clone());
            s.spawn(move || {
                let transport = net::accept_workers(
                    down_listener,
                    2,
                    &hello_for(agg_cfg),
                    SocketCfg {
                        heartbeat: Duration::ZERO,
                        ..SocketCfg::new(Duration::from_secs(20))
                    },
                )
                .expect("aggregator worker fleet");
                let opts = ServeOpts {
                    heartbeat: Duration::ZERO,
                    idle_deadline: Duration::ZERO,
                    exec_threads: 1,
                };
                let hello = agg_hello(agg_cfg, Some((i as u32, 2)));
                let first =
                    if i == 0 { &proxy_addr } else { &root_addr };
                let mut stream = net::connect(
                    first,
                    &hello,
                    Duration::from_secs(20),
                )
                .expect("aggregator handshake");
                let mut r = net::serve_upstream(
                    &mut stream,
                    &transport,
                    ctx,
                    &opts,
                );
                // rejoin directly after the proxy cut (bounded)
                let mut attempts = 0;
                while r.is_err() && attempts < 100 {
                    attempts += 1;
                    thread::sleep(Duration::from_millis(50));
                    let Ok(mut stream) = net::connect(
                        &root_addr,
                        &hello,
                        Duration::from_secs(20),
                    ) else {
                        continue;
                    };
                    r = net::serve_upstream(
                        &mut stream,
                        &transport,
                        ctx,
                        &opts,
                    );
                }
                transport.shutdown();
                r.expect("aggregator never finished cleanly");
            });
        }
        let transport = net::accept_aggregators(
            listener,
            2,
            &root_hello,
            SocketCfg {
                heartbeat: Duration::ZERO,
                ..SocketCfg::new(Duration::from_secs(20))
            },
        )
        .expect("root handshake");
        let mut server = Server::with_transport(
            &engine,
            &manifest,
            cfg,
            Box::new(&transport),
        )
        .unwrap();
        let mut losses = Vec::new();
        for t in 0..rounds {
            losses.push(server.round(t).unwrap().to_bits());
        }
        let trace = Trace::capture(&server, losses);
        let requeues = transport.requeues();
        drop(server);
        transport.shutdown();
        (trace, requeues)
    })
}

/// 60-second (configurable) three-tier kill/rejoin soak: root + two
/// networked aggregators + four workers, a forced backbone cut at a
/// rotating Shard frame every iteration, every iteration checked
/// bit-identical to the in-process tree. Heavy for per-PR CI, so
/// `#[ignore]`d; the nightly workflow runs it with `--ignored`.
#[test]
#[ignore = "nightly soak — run with --ignored (FEDFP8_SOAK_SECS)"]
fn soak_networked_tree_kill_rejoin() {
    let secs: u64 = std::env::var("FEDFP8_SOAK_SECS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(60);
    let deadline = Instant::now() + Duration::from_secs(secs);
    let base = run_mock_agg(4, false, AggMode::Tree { nodes: 2 });
    let mut iters = 0u64;
    let mut requeues = 0u64;
    while Instant::now() < deadline {
        // rotate the cut across the first three backbone dispatches
        let cut = (iters as usize % 3) + 1;
        let (trace, rq) =
            run_tree_chaos(&format!("tsoak{iters}"), cut);
        assert_eq!(
            trace, base,
            "tree soak iteration {iters} (cut={cut}) diverged"
        );
        requeues += rq;
        iters += 1;
    }
    println!(
        "tree soak: {iters} iterations, {requeues} shard \
         re-dispatches, all bit-identical"
    );
    assert!(iters >= 1, "tree soak never completed an iteration");
    assert!(requeues >= iters, "cuts did not force re-dispatches");
}
