//! Networked-tree suite: the tree backbone over real sockets.
//!
//! **Equivalence** — a full multi-round experiment whose `--agg
//! tree:G` shards execute on *networked* mid-tier aggregators
//! (loopback `serve_upstream` loops fed by `accept_aggregators` +
//! `ShardDispatch::run_shard`) must be **bit-identical** to the same
//! experiment on the in-process tree AND to the flat stream: final
//! weights, alphas, betas, per-round losses and CommStats, across
//! fan-outs {1, 2, 4} × parallelism {1, 4} × error feedback
//! {off, on}. The aggregators rebuild the round context from their
//! own copy of the config (cohort, lr, weighting, QAT prefix, EF
//! residuals) — exactly the production `--role aggregator` flow — and
//! run the same deterministic mock executor as every other
//! determinism suite.
//!
//! **Accounting** — the backbone identity: the Partial-frame bytes
//! the root's transport physically received must equal the
//! `CommStats.partial_bytes` the trace reports (`record_partial`
//! charges `partial_wire_bytes + PARTIAL_HEADER_BYTES`, and the
//! golden-wire suite pins that constant to the real frame envelope).
//! Client-edge up/down accounting must also be byte-identical to the
//! in-process runs, because the aggregators re-sum it charge for
//! charge from their own uplinks.
//!
//! **Topology** — fewer live aggregators than configured shards
//! (W < G) must still complete bit-exactly: shard geometry comes from
//! the configured fan-out, never the connection count, so unpinned
//! shards ride the least-loaded survivor. Fault schedules (killing an
//! aggregator mid-round, malformed Partial frames) live in
//! `tests/net_chaos.rs`.

mod common;

use std::net::TcpListener;
use std::thread;
use std::time::Duration;

use common::{
    mock_cfg, mock_manifest, run_mock, run_mock_agg, MockTransport,
    Trace,
};
use fedfp8::config::{AggMode, ExperimentConfig};
use fedfp8::coordinator::{build_world, Server};
use fedfp8::net::{
    self, AggregatorCtx, Hello, Inflight, PeerRole, ServeOpts,
    SocketCfg,
};
use fedfp8::runtime::Engine;

fn hello_for(
    cfg: &ExperimentConfig,
    role: PeerRole,
    shard: Option<(u32, u32)>,
) -> Hello {
    Hello {
        fingerprint: cfg.fingerprint(),
        dim: common::DIM as u64,
        model: "mock".into(),
        auth: 0,
        role,
        shard,
    }
}

/// Loopback tuning: long deadlines, probing off on both sides — a
/// clean run carries zero heartbeat traffic to race the shutdown.
fn quiet_cfg() -> (SocketCfg, ServeOpts) {
    (
        SocketCfg {
            inflight: Inflight::Fixed(1),
            heartbeat: Duration::ZERO,
            ..SocketCfg::new(Duration::from_secs(20))
        },
        ServeOpts {
            heartbeat: Duration::ZERO,
            idle_deadline: Duration::ZERO,
            exec_threads: 1,
        },
    )
}

/// Run the full mock experiment with `--agg tree:nodes` where the
/// shards execute on `aggs` in-thread aggregator serve loops over
/// loopback TCP; returns the bit-exact trace. Each aggregator rebuilds
/// its world from its own copy of the config and pins shard `i/nodes`
/// in its Hello (pins beyond `nodes` are simply never preferred).
fn run_tree_socket(
    parallelism: usize,
    nodes: usize,
    aggs: usize,
    error_feedback: bool,
) -> Trace {
    let tag = format!(
        "treenet_p{parallelism}_g{nodes}_a{aggs}_ef{error_feedback}"
    );
    let (dir, manifest) = mock_manifest(&tag);
    let engine = Engine::new(&dir).unwrap();
    let mut cfg = mock_cfg(parallelism, error_feedback);
    cfg.agg = AggMode::Tree { nodes };
    let model = manifest.model("mock").unwrap();
    // the aggregators' own copy of the world — same pure functions,
    // separately evaluated, as a real `--role aggregator` process
    let agg_cfg = cfg.clone();
    let world = build_world(&agg_cfg, model).unwrap();
    let ctx = AggregatorCtx {
        cfg: &agg_cfg,
        train: &world.train,
        shards: &world.shards,
        segments: &model.segments,
        dim: model.dim,
        alpha_dim: model.alpha_dim,
        beta_dim: model.n_act,
    };
    let root_hello = hello_for(&cfg, PeerRole::Worker, None);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let rounds = cfg.rounds;
    let (socket_cfg, opts) = quiet_cfg();
    thread::scope(|s| {
        for i in 0..aggs {
            let (addr, ctx, opts, agg_cfg) =
                (&addr, &ctx, &opts, &agg_cfg);
            s.spawn(move || {
                let exec = MockTransport::new(true);
                let hello = hello_for(
                    agg_cfg,
                    PeerRole::Aggregator,
                    Some((i as u32, nodes as u32)),
                );
                let mut stream = net::connect(
                    addr,
                    &hello,
                    Duration::from_secs(20),
                )
                .expect("aggregator handshake");
                net::serve_upstream(&mut stream, &exec, ctx, opts)
                    .expect("aggregator serve loop");
            });
        }
        let transport = net::accept_aggregators(
            listener,
            aggs,
            &root_hello,
            socket_cfg,
        )
        .expect("root handshake");
        let mut server = Server::with_transport(
            &engine,
            &manifest,
            cfg,
            Box::new(&transport),
        )
        .unwrap();
        let mut losses = Vec::new();
        for t in 0..rounds {
            losses.push(server.round(t).unwrap().to_bits());
        }
        let trace = Trace::capture(&server, losses);
        // the backbone byte identity: reported partial accounting ==
        // the Partial-frame bytes that physically crossed the root's
        // sockets (exactly once per shard in a clean run)
        assert_eq!(
            transport.partial_bytes_received(),
            trace.comm.partial_bytes,
            "partial_bytes accounting != actual backbone frame bytes"
        );
        assert!(
            trace.comm.grand_total_bytes()
                == trace.comm.total_bytes() + trace.comm.partial_bytes,
            "grand total must layer the backbone on the paper metric"
        );
        assert_eq!(transport.requeues(), 0, "clean run re-dispatched");
        assert_eq!(
            transport.duplicate_outcomes(),
            0,
            "clean run saw duplicate shard replies"
        );
        // one poll loop serves the whole backbone, same as workers
        assert_eq!(
            transport.transport_threads(),
            1,
            "transport spawned per-aggregator threads"
        );
        drop(server);
        transport.shutdown();
        trace
    })
}

/// Strip the backbone-only counters so a networked-tree trace can be
/// compared against a *flat* run (flat never ships partials; the
/// paper metric `total_bytes` must still be identical).
fn flatten(mut t: Trace) -> Trace {
    t.comm.partial_bytes = 0;
    t.comm.partial_msgs = 0;
    t
}

#[test]
fn networked_tree_equals_in_process_tree_and_flat() {
    // the acceptance grid: fan-out {1, 2, 4} x parallelism {1, 4} x
    // EF {off, on} — networked tree == in-process tree, bitwise, and
    // (modulo the backbone's own partial_bytes) == flat
    for ef in [false, true] {
        for parallelism in [1usize, 4] {
            let flat = run_mock(parallelism, ef);
            for nodes in [1usize, 2, 4] {
                let agg = AggMode::Tree { nodes };
                let base = run_mock_agg(parallelism, ef, agg);
                let netd =
                    run_tree_socket(parallelism, nodes, nodes, ef);
                assert_eq!(
                    netd, base,
                    "networked tree diverged from in-process tree \
                     at G={nodes} p={parallelism} ef={ef}"
                );
                assert_eq!(
                    flatten(netd),
                    flat,
                    "tree backbone changed the model trajectory at \
                     G={nodes} p={parallelism} ef={ef}"
                );
            }
        }
    }
}

#[test]
fn oversubscribed_aggregator_pool_is_bit_identical() {
    // W < G: four configured shards over two (then one) live
    // aggregator connections — geometry is configured, not live, so
    // the unpinned shards ride the least-loaded survivor and the
    // canonical accumulation is unchanged
    let base = run_mock_agg(4, false, AggMode::Tree { nodes: 4 });
    let two = run_tree_socket(4, 4, 2, false);
    assert_eq!(two, base, "2 aggregators serving 4 shards diverged");
    let one = run_tree_socket(4, 4, 1, false);
    assert_eq!(one, base, "1 aggregator serving 4 shards diverged");
}

#[test]
fn networked_tree_round_trips_error_feedback_residuals() {
    // EF residuals ship inside Shard frames and return inside
    // ShardDone frames; the server's residual store — and therefore
    // every later round — must end bit-identical to in-process,
    // including when shards share one connection
    let base = run_mock_agg(4, true, AggMode::Tree { nodes: 2 });
    let netd = run_tree_socket(4, 2, 2, true);
    assert_eq!(netd, base, "EF diverged over the backbone");
    let shared_conn = run_tree_socket(4, 2, 1, true);
    assert_eq!(shared_conn, base, "EF diverged on a shared link");
}
