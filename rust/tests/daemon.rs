//! Run-scheduler daemon + telemetry feed proofs (`daemon::*`):
//!
//! - the queue executes `<id>.job.json` specs in filename order and
//!   persists every lifecycle transition;
//! - a failed job never blocks the rest of the queue;
//! - a daemon killed mid-job (state file left at `running`, snapshots
//!   on disk) re-runs that job on restart through the snapshot layer,
//!   and the stitched trajectory is **bit-identical** to a run that
//!   was never interrupted;
//! - `Server::run` feeds the `Telemetry` sink one event per round
//!   plus run-boundary events, and the TCP hub serves them as NDJSON
//!   with a working `/status` frame.
//!
//! The crash model matches `tests/durability.rs`: a `kill -9` leaves
//! exactly (a) a state file whose last durable write says `running`
//! and (b) the snapshot generations written at round boundaries —
//! nothing else survives the process.

mod common;

use std::fs;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Duration;

use common::{mock_cfg, mock_manifest, MockTransport, Trace};
use fedfp8::config::ExperimentConfig;
use fedfp8::coordinator::metrics::{
    RoundEvent, RunEvent, RunPhase, Telemetry,
};
use fedfp8::coordinator::Server;
use fedfp8::daemon::{run_queue, JobState, Queue, TelemetryHub};
use fedfp8::runtime::Engine;
use fedfp8::util::json::Json;

/// Fresh (pre-cleaned) queue directory for one test.
fn queue_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "fedfp8_daemon_{}_{tag}",
        std::process::id()
    ));
    let _ = fs::remove_dir_all(&dir);
    dir
}

/// Write a real job spec (a serialized `ExperimentConfig`) into the
/// queue, exercising the config JSON codec end to end.
fn write_job(dir: &Path, id: &str, rounds: usize) {
    let mut cfg = ExperimentConfig::base("mlp_c10")
        .unwrap()
        .with_method("uq")
        .unwrap();
    cfg.rounds = rounds;
    let spec = format!(r#"{{"config": {}}}"#, cfg.to_json());
    fs::write(dir.join(format!("{id}.job.json")), spec).unwrap();
}

#[test]
fn jobs_execute_in_filename_order_and_reach_done() {
    let dir = queue_dir("order");
    let q = Queue::open(&dir).unwrap();
    // written out of order on purpose; filename order is the contract
    for id in ["20-mid", "10-first", "30-last"] {
        write_job(&dir, id, 3);
    }
    let states = Mutex::new(Vec::new());
    let report = run_queue(
        &q,
        1,
        |job, state| {
            states
                .lock()
                .unwrap()
                .push((job.id.clone(), state.as_str()));
        },
        |job| {
            // the spec's config really parsed
            assert_eq!(job.cfg.model, "mlp_c10");
            assert_eq!(job.cfg.rounds, 3);
            Ok(())
        },
    )
    .unwrap();
    assert_eq!(report.started, ["10-first", "20-mid", "30-last"]);
    assert_eq!(report.done, ["10-first", "20-mid", "30-last"]);
    assert!(report.failed.is_empty() && report.skipped.is_empty());
    for id in ["10-first", "20-mid", "30-last"] {
        assert_eq!(
            q.read_state(id).unwrap(),
            Some((JobState::Done, None)),
            "{id} must be durably done"
        );
    }
    // every job went queued -> running -> done, in order
    let seen = states.into_inner().unwrap();
    let for_job = |id: &str| -> Vec<&str> {
        seen.iter()
            .filter(|(j, _)| j == id)
            .map(|(_, s)| *s)
            .collect()
    };
    assert_eq!(for_job("10-first"), ["queued", "running", "done"]);
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn failed_job_does_not_block_the_queue() {
    let dir = queue_dir("fail");
    let q = Queue::open(&dir).unwrap();
    for id in ["a", "b", "c"] {
        write_job(&dir, id, 2);
    }
    let report = run_queue(
        &q,
        1,
        |_, _| {},
        |job| {
            if job.id == "b" {
                anyhow::bail!("injected executor failure");
            }
            Ok(())
        },
    )
    .unwrap();
    assert_eq!(report.done, ["a", "c"]);
    assert_eq!(report.failed.len(), 1);
    assert_eq!(report.failed[0].0, "b");
    let (state, err) = q.read_state("b").unwrap().unwrap();
    assert_eq!(state, JobState::Failed);
    assert!(
        err.unwrap().contains("injected executor failure"),
        "the failure reason must be persisted"
    );
    // a second pass skips everything: done and failed are terminal
    let report = run_queue(
        &q,
        1,
        |_, _| {},
        |_| panic!("nothing should re-run"),
    )
    .unwrap();
    assert!(report.started.is_empty());
    assert_eq!(report.skipped.len(), 3);
    let _ = fs::remove_dir_all(&dir);
}

/// Regression (PR 10): an IO error from `queue.set_state` inside the
/// work loop used to propagate via `?` and abort the whole pass —
/// with `--daemon-slots > 1` it tore down the entire scope — so one
/// job's unwritable state file starved every job behind it. The
/// injection clobbers the job's state path with a directory while the
/// job runs, so the post-run `done` rename fails exactly mid-pass.
#[test]
fn state_persist_io_error_fails_the_job_not_the_pass() {
    let dir = queue_dir("statefail");
    let q = Queue::open(&dir).unwrap();
    write_job(&dir, "10-clobbered", 2);
    write_job(&dir, "20-after", 2);
    let report = run_queue(
        &q,
        1,
        |_, _| {},
        |job| {
            if job.id == "10-clobbered" {
                // simulate the state file going unwritable mid-job: a
                // directory at the state path makes the atomic-rename
                // in set_state fail with a real fs error
                let p = q.state_path(&job.id);
                fs::remove_file(&p).unwrap();
                fs::create_dir(&p).unwrap();
            }
            Ok(())
        },
    )
    .expect("a per-job persist failure must not fail the pass");
    assert_eq!(
        report.started,
        ["10-clobbered", "20-after"],
        "both jobs must get their turn"
    );
    assert_eq!(report.done, ["20-after"]);
    assert_eq!(report.failed.len(), 1, "{:?}", report.failed);
    assert_eq!(report.failed[0].0, "10-clobbered");
    assert!(
        report.failed[0].1.contains("persisting 'done' state"),
        "failure must say what could not be persisted: {}",
        report.failed[0].1
    );
    assert_eq!(
        q.read_state("20-after").unwrap(),
        Some((JobState::Done, None)),
        "the job behind the failure must still reach done"
    );
    let _ = fs::remove_dir_all(&dir);
}

/// Regression (PR 10): the queue used to be scanned exactly once at
/// startup, so a spec dropped into the directory after launch
/// silently never ran until a daemon restart (ROADMAP item 3a). The
/// scheduler now re-scans after each drained pass: a job enqueued
/// *while the first job is running* executes in the same
/// `run_queue` invocation.
#[test]
fn job_enqueued_mid_run_executes_without_restart() {
    let dir = queue_dir("midrun");
    let q = Queue::open(&dir).unwrap();
    write_job(&dir, "10-first", 2);
    let report = run_queue(
        &q,
        1,
        |_, _| {},
        |job| {
            if job.id == "10-first" {
                // a sweep driver drops another spec in mid-run
                write_job(&dir, "20-late", 2);
            }
            Ok(())
        },
    )
    .unwrap();
    assert_eq!(
        report.started,
        ["10-first", "20-late"],
        "the late spec must run in the same invocation"
    );
    assert_eq!(report.done, ["10-first", "20-late"]);
    for id in ["10-first", "20-late"] {
        assert_eq!(
            q.read_state(id).unwrap(),
            Some((JobState::Done, None))
        );
    }
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn two_slots_drain_the_queue() {
    let dir = queue_dir("slots");
    let q = Queue::open(&dir).unwrap();
    for id in ["a", "b", "c", "d"] {
        write_job(&dir, id, 2);
    }
    let report = run_queue(
        &q,
        2,
        |_, _| {},
        |_| {
            std::thread::sleep(Duration::from_millis(10));
            Ok(())
        },
    )
    .unwrap();
    let mut done = report.done.clone();
    done.sort();
    assert_eq!(done, ["a", "b", "c", "d"]);
    for id in ["a", "b", "c", "d"] {
        assert_eq!(
            q.read_state(id).unwrap(),
            Some((JobState::Done, None))
        );
    }
    let _ = fs::remove_dir_all(&dir);
}

/// Acceptance: a daemon killed mid-job restarts and finishes the job
/// **bit-identically**. The kill is simulated exactly as `kill -9`
/// leaves the world: the job's state file says `running` (the
/// `done`/`failed` write never happened) and the snapshot directory
/// holds the generations written at completed round boundaries. The
/// restart pass re-runs the job through snapshot resume, and the
/// stitched trace must equal an uninterrupted run.
#[test]
fn interrupted_job_resumes_bit_identically_on_restart() {
    let cfg = mock_cfg(1, true);
    let rounds = cfg.rounds;
    let cut = 2;

    // uninterrupted baseline (same transport settings as below)
    let base = {
        let (dir, manifest) = mock_manifest("dqbase");
        let engine = Engine::new(&dir).unwrap();
        let transport = MockTransport::new(false);
        let mut server = Server::with_transport(
            &engine,
            &manifest,
            cfg.clone(),
            Box::new(&transport),
        )
        .unwrap();
        let mut losses = Vec::new();
        for t in 0..rounds {
            losses.push(server.round(t).unwrap().to_bits());
        }
        Trace::capture(&server, losses)
    };

    let dir = queue_dir("resume");
    let q = Queue::open(&dir).unwrap();
    write_job(&dir, "job1", rounds);
    let snaps = q.snaps_dir("job1");

    // pass 1, killed after `cut` rounds: snapshots at every boundary,
    // state file durably `running`, then the process "dies"
    q.set_state("job1", JobState::Running, None).unwrap();
    let first = {
        let (mdir, manifest) = mock_manifest("dqcrash");
        let engine = Engine::new(&mdir).unwrap();
        let transport = MockTransport::new(false);
        let mut server = Server::with_transport(
            &engine,
            &manifest,
            cfg.clone(),
            Box::new(&transport),
        )
        .unwrap();
        let mut losses = Vec::new();
        for t in 0..cut {
            losses.push(server.round(t).unwrap().to_bits());
            server.save_snapshot(&snaps, t + 1).unwrap();
        }
        losses
    };
    assert_eq!(
        q.read_state("job1").unwrap(),
        Some((JobState::Running, None)),
        "the crash leaves `running` behind — the restart trigger"
    );

    // pass 2: daemon restart. The scheduler must classify the
    // `running` job as interrupted and re-run it; the runner resumes
    // from the job's snapshot directory like the production runner
    // (the scheduler is runner-generic so the test can use the mock
    // manifest, whose model name no job spec can carry).
    let resumed = Mutex::new(None);
    let report = run_queue(
        &q,
        1,
        |_, _| {},
        |job| {
            assert_eq!(job.id, "job1");
            let (mdir, manifest) = mock_manifest("dqresume");
            let engine = Engine::new(&mdir).unwrap();
            let transport = MockTransport::new(false);
            let mut server = Server::with_transport(
                &engine,
                &manifest,
                cfg.clone(),
                Box::new(&transport),
            )
            .unwrap();
            let start = server.resume_from(&snaps).unwrap();
            assert_eq!(start, cut, "must resume at the cut boundary");
            let mut losses = Vec::new();
            for t in start..rounds {
                losses.push(server.round(t).unwrap().to_bits());
                server.save_snapshot(&snaps, t + 1).unwrap();
            }
            *resumed.lock().unwrap() =
                Some(Trace::capture(&server, losses));
            Ok(())
        },
    )
    .unwrap();
    assert_eq!(report.started, ["job1"]);
    assert_eq!(report.done, ["job1"]);
    assert_eq!(
        q.read_state("job1").unwrap(),
        Some((JobState::Done, None))
    );

    let resumed = resumed.into_inner().unwrap().unwrap();
    let mut losses = first;
    losses.extend_from_slice(&resumed.losses);
    let stitched = Trace { losses, ..resumed };
    assert_eq!(
        stitched, base,
        "restart-resumed job diverged from uninterrupted run"
    );
    let _ = fs::remove_dir_all(&dir);
}

/// Nightly soak: the daemon is "killed" mid-first-job at EVERY round
/// boundary of a 2-job queue and restarted; every restart must (a)
/// classify the first job as interrupted and finish it
/// bit-identically through snapshot resume, and (b) then run the
/// untouched second job to done. The per-boundary sweep is the
/// daemon-level mirror of
/// `durability.rs::kill_resume_soak_every_boundary`; heavy for
/// per-PR CI, so `#[ignore]`d and run by nightly-soak.yml.
#[test]
#[ignore = "nightly soak — run with --ignored (see nightly-soak.yml)"]
fn daemon_kill_restart_soak_every_boundary() {
    let cfg = mock_cfg(1, true);
    let rounds = cfg.rounds;

    // uninterrupted baseline, shared by every cut
    let base = {
        let (dir, manifest) = mock_manifest("dsoakbase");
        let engine = Engine::new(&dir).unwrap();
        let transport = MockTransport::new(false);
        let mut server = Server::with_transport(
            &engine,
            &manifest,
            cfg.clone(),
            Box::new(&transport),
        )
        .unwrap();
        let mut losses = Vec::new();
        for t in 0..rounds {
            losses.push(server.round(t).unwrap().to_bits());
        }
        Trace::capture(&server, losses)
    };

    for cut in 1..rounds {
        let dir = queue_dir(&format!("soak{cut}"));
        let q = Queue::open(&dir).unwrap();
        write_job(&dir, "10-interrupted", rounds);
        write_job(&dir, "20-fresh", rounds);
        let snaps = q.snaps_dir("10-interrupted");

        // the kill -9 world: `running` state + boundary snapshots
        q.set_state("10-interrupted", JobState::Running, None)
            .unwrap();
        let first = {
            let (mdir, manifest) =
                mock_manifest(&format!("dsoakkill{cut}"));
            let engine = Engine::new(&mdir).unwrap();
            let transport = MockTransport::new(false);
            let mut server = Server::with_transport(
                &engine,
                &manifest,
                cfg.clone(),
                Box::new(&transport),
            )
            .unwrap();
            let mut losses = Vec::new();
            for t in 0..cut {
                losses.push(server.round(t).unwrap().to_bits());
                server.save_snapshot(&snaps, t + 1).unwrap();
            }
            losses
        };

        // daemon restart: drain the whole queue
        let resumed = Mutex::new(None);
        let report = run_queue(
            &q,
            1,
            |_, _| {},
            |job| {
                let (mdir, manifest) = mock_manifest(&format!(
                    "dsoak{cut}_{}",
                    job.id
                ));
                let engine = Engine::new(&mdir).unwrap();
                let transport = MockTransport::new(false);
                let mut server = Server::with_transport(
                    &engine,
                    &manifest,
                    cfg.clone(),
                    Box::new(&transport),
                )
                .unwrap();
                if job.id == "10-interrupted" {
                    let start = server.resume_from(&snaps).unwrap();
                    assert_eq!(start, cut, "resume at the boundary");
                    let mut losses = Vec::new();
                    for t in start..rounds {
                        losses.push(
                            server.round(t).unwrap().to_bits(),
                        );
                        server.save_snapshot(&snaps, t + 1).unwrap();
                    }
                    *resumed.lock().unwrap() =
                        Some(Trace::capture(&server, losses));
                } else {
                    for t in 0..rounds {
                        server.round(t).unwrap();
                    }
                }
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(report.started, ["10-interrupted", "20-fresh"]);
        assert_eq!(report.done, ["10-interrupted", "20-fresh"]);

        let resumed = resumed.into_inner().unwrap().unwrap();
        let mut losses = first;
        losses.extend_from_slice(&resumed.losses);
        let stitched = Trace { losses, ..resumed };
        assert_eq!(
            stitched, base,
            "cut={cut}: restart-resumed job diverged"
        );
        let _ = fs::remove_dir_all(&dir);
    }
}

/// In-process sink capturing the event stream of one `Server::run`.
#[derive(Default)]
struct Collect {
    rounds: Mutex<Vec<RoundEvent>>,
    runs: Mutex<Vec<RunEvent>>,
}

impl Telemetry for Collect {
    fn on_round(&self, ev: &RoundEvent) {
        self.rounds.lock().unwrap().push(ev.clone());
    }
    fn on_run(&self, ev: &RunEvent) {
        self.runs.lock().unwrap().push(ev.clone());
    }
}

/// `Server::run` on the mock manifest: Started, one event per
/// completed round, then Failed at the forced final-round evaluate
/// (the mock manifest carries no `evaluate` artifact) — which also
/// proves the Failed path reports the abort reason.
#[test]
fn run_emits_started_rounds_and_failure_to_sink() {
    let cfg = mock_cfg(1, true);
    let rounds = cfg.rounds; // 4: rounds 0..2 complete, 3 fails
    let (dir, manifest) = mock_manifest("sink");
    let engine = Engine::new(&dir).unwrap();
    let transport = MockTransport::new(false);
    let mut server = Server::with_transport(
        &engine,
        &manifest,
        cfg.clone(),
        Box::new(&transport),
    )
    .unwrap();
    let sink = std::sync::Arc::new(Collect::default());
    server.set_telemetry(sink.clone());
    assert!(server.run().is_err(), "mock evaluate must fail");

    let runs = sink.runs.lock().unwrap();
    assert_eq!(runs.len(), 2);
    assert_eq!(runs[0].phase, RunPhase::Started);
    assert_eq!(runs[0].start_round, 0);
    assert_eq!(runs[0].rounds_total, rounds as u64);
    assert_eq!(runs[1].phase, RunPhase::Failed);
    assert!(
        runs[1].error.as_deref().unwrap_or("").contains("evaluate"),
        "abort reason must be carried: {:?}",
        runs[1].error
    );
    let evs = sink.rounds.lock().unwrap();
    assert_eq!(evs.len(), rounds - 1, "one event per completed round");
    for (t, ev) in evs.iter().enumerate() {
        assert_eq!(ev.round, t as u64);
        assert_eq!(ev.rounds_total, rounds as u64);
        assert_eq!(ev.job, cfg.name);
        assert!(
            ev.accuracy.is_nan(),
            "eval_every=1000: no round evaluates"
        );
    }
    // the v2 wall clock is monotone across the run's events
    for pair in evs.windows(2) {
        assert!(pair[0].wall_millis <= pair[1].wall_millis);
    }
}

/// Acceptance: every round arrives at a TCP telemetry client as one
/// valid NDJSON object, and `/status` answers with the summary frame.
#[test]
fn telemetry_socket_streams_rounds_as_ndjson_and_answers_status() {
    let cfg = mock_cfg(1, true);
    let rounds = cfg.rounds;
    let hub = TelemetryHub::bind("127.0.0.1:0").unwrap();
    let stream = TcpStream::connect(hub.local_addr()).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    // the feed has no replay: subscribe before the run starts
    for _ in 0..400 {
        if hub.client_count() >= 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(hub.client_count(), 1, "client never registered");

    let (dir, manifest) = mock_manifest("feed");
    let engine = Engine::new(&dir).unwrap();
    let transport = MockTransport::new(false);
    let mut server = Server::with_transport(
        &engine,
        &manifest,
        cfg.clone(),
        Box::new(&transport),
    )
    .unwrap();
    server.set_telemetry(hub.clone());
    let _ = server.run(); // fails at the final evaluate, by design

    // read until the run-boundary failure event; every line must be
    // a standalone valid JSON object (the NDJSON contract)
    let mut round_events = 0u64;
    let mut saw_started = false;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(!line.is_empty(), "feed closed before the run event");
        let v = Json::parse(line.trim_end()).unwrap();
        match v.get("type").unwrap().as_str().unwrap() {
            "round" => {
                assert_eq!(
                    v.get("round").unwrap().as_usize().unwrap() as u64,
                    round_events,
                    "rounds must arrive in order"
                );
                assert_eq!(
                    v.get("rounds_total").unwrap().as_usize().unwrap(),
                    rounds
                );
                // NaN accuracy serializes as null
                assert!(v.opt("accuracy").is_none());
                round_events += 1;
            }
            "run" => {
                let phase =
                    v.get("phase").unwrap().as_str().unwrap();
                if phase == "started" {
                    saw_started = true;
                    continue;
                }
                assert_eq!(phase, "failed");
                assert!(v
                    .get("error")
                    .unwrap()
                    .as_str()
                    .unwrap()
                    .contains("evaluate"));
                break;
            }
            other => panic!("unexpected event type '{other}'"),
        }
    }
    assert!(saw_started, "run started event must lead the feed");
    assert_eq!(
        round_events,
        (rounds - 1) as u64,
        "every completed round must reach the client"
    );

    // /status reflects the final state of the job
    let mut w = stream.try_clone().unwrap();
    w.write_all(b"/status\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = Json::parse(line.trim_end()).unwrap();
    assert_eq!(v.get("type").unwrap().as_str().unwrap(), "status");
    let job = v.get("jobs").unwrap().get(&cfg.name).unwrap();
    assert_eq!(
        job.get("state").unwrap().as_str().unwrap(),
        "failed"
    );
    assert_eq!(
        job.get("round").unwrap().as_usize().unwrap(),
        rounds - 2,
        "latest completed round"
    );
    hub.shutdown();
}
