//! Integration tests over the full stack: PJRT runtime executing AOT
//! artifacts, driven by the coordinator. Tests that need compiled
//! artifacts skip with a clear message when `make artifacts` has not
//! been run (set FEDFP8_REQUIRE_ARTIFACTS=1 to fail instead), so
//! `cargo test -q` is green out of the box.
//!
//! NOTE: each test builds its own Engine (PJRT CPU client); tests are
//! threaded, so keep per-test work small.

use fedfp8::config::ExperimentConfig;
use fedfp8::coordinator::comm::{
    DOWNLINK_HEADER_BYTES, UPLINK_HEADER_BYTES,
};
use fedfp8::coordinator::Server;
use fedfp8::fp8::format::Fp8Params;
use fedfp8::fp8::rng::Pcg32;
use fedfp8::runtime::{
    artifacts_or_skip, default_dir, engine, Engine, In, Manifest,
};

fn setup() -> Option<(Engine, Manifest)> {
    if !artifacts_or_skip("integration test (AOT artifacts + PJRT)") {
        return None;
    }
    let dir = default_dir();
    Some((Engine::new(&dir).unwrap(), Manifest::load(&dir).unwrap()))
}

#[test]
fn quant_demo_artifact_matches_codec() {
    let Some((eng, man)) = setup() else { return };
    let (file, n) = man.quant_demo.clone().expect("quant_demo exported");
    let mut rng = Pcg32::new(3, 0);
    let x: Vec<f32> =
        (0..n).map(|_| (rng.uniform() - 0.5) * 3.0).collect();
    let alpha = vec![0.9f32; n];
    let u = vec![0.5f32; n];
    let d = [n as i64];
    let out = eng
        .execute(&file, &[In::F32(&x, &d), In::F32(&alpha, &d),
                          In::F32(&u, &d)])
        .unwrap();
    let q = engine::f32_vec(&out[0]).unwrap();
    let p = Fp8Params::new(0.9);
    for i in 0..n {
        let r = p.quantize(x[i], 0.5);
        assert!(
            (q[i] - r).abs() <= r.abs() * 3e-6 + 1e-7,
            "i={i} kernel={} codec={r}",
            q[i]
        );
    }
}

#[test]
fn uq_run_learns_and_counts_bytes() {
    let Some((eng, man)) = setup() else { return };
    let mut cfg = ExperimentConfig::preset("mlp_c10:uq:iid").unwrap();
    cfg.rounds = 8;
    cfg.clients = 10;
    cfg.participation = 4;
    cfg.n_train = 1000;
    cfg.n_test = 256;
    cfg.eval_every = 8;
    let mut server = Server::new(&eng, &man, cfg).unwrap();
    let r = server.run().unwrap();
    assert!(
        r.final_accuracy > 0.3,
        "uq failed to learn: {}",
        r.final_accuracy
    );
    // byte accounting: 8 rounds x 4 clients x (up+down), each
    // direction = packed payload + fixed per-message framing header
    let m = man.model("mlp_c10").unwrap();
    let msg = m.quant_params() as u64
        + 4 * (m.raw_params() + m.alpha_dim + m.n_act) as u64;
    assert_eq!(
        r.total_bytes,
        8 * 4
            * (2 * msg + UPLINK_HEADER_BYTES + DOWNLINK_HEADER_BYTES)
    );
}

#[test]
fn fp32_baseline_costs_about_4x() {
    let Some((eng, man)) = setup() else { return };
    let mut bytes = Vec::new();
    for method in ["fp32", "uq"] {
        let mut cfg = ExperimentConfig::base("mlp_c10")
            .unwrap()
            .with_method(method)
            .unwrap()
            .with_split("iid")
            .unwrap();
        cfg.rounds = 2;
        cfg.clients = 6;
        cfg.participation = 3;
        cfg.n_train = 300;
        cfg.n_test = 256;
        cfg.eval_every = 100; // skip eval
        let mut server = Server::new(&eng, &man, cfg).unwrap();
        let r = server.run().unwrap();
        bytes.push(r.total_bytes as f64);
    }
    let ratio = bytes[0] / bytes[1];
    // mlp is 99.4% quantized -> per-message ratio just below 4x
    assert!(
        (3.5..4.0).contains(&ratio),
        "fp32/uq byte ratio {ratio}"
    );
}

#[test]
fn runs_are_deterministic_per_seed() {
    let Some((eng, man)) = setup() else { return };
    let mut finals = Vec::new();
    for _ in 0..2 {
        let mut cfg = ExperimentConfig::preset("mlp_c10:uq:iid").unwrap();
        cfg.rounds = 3;
        cfg.clients = 6;
        cfg.participation = 3;
        cfg.n_train = 300;
        cfg.n_test = 256;
        cfg.eval_every = 3;
        cfg.seed = 99;
        let mut server = Server::new(&eng, &man, cfg).unwrap();
        let r = server.run().unwrap();
        finals.push((r.final_accuracy, r.total_bytes));
    }
    assert_eq!(finals[0], finals[1]);
}

#[test]
fn server_opt_changes_master_weights() {
    let Some((eng, man)) = setup() else { return };
    let mut states = Vec::new();
    for method in ["uq", "uq+"] {
        let mut cfg = ExperimentConfig::base("mlp_c10")
            .unwrap()
            .with_method(method)
            .unwrap()
            .with_split("iid")
            .unwrap();
        cfg.rounds = 1;
        cfg.clients = 6;
        cfg.participation = 3;
        cfg.n_train = 300;
        cfg.n_test = 256;
        cfg.eval_every = 100;
        cfg.seed = 5;
        let mut server = Server::new(&eng, &man, cfg).unwrap();
        server.round(0).unwrap();
        let (w, alpha, _) = server.state();
        states.push((w.to_vec(), alpha.to_vec()));
    }
    // identical seeds -> identical client work; only ServerOptimize
    // differs, and it must actually move the weights
    assert_ne!(states[0].0, states[1].0, "ServerOptimize was a no-op");
}

#[test]
fn speaker_split_runs_speech_model() {
    let Some((eng, man)) = setup() else { return };
    let mut cfg = ExperimentConfig::preset("matchbox:uq:speaker").unwrap();
    cfg.rounds = 2;
    cfg.n_train = 640;
    cfg.n_test = 256;
    cfg.speakers = 16;
    cfg.participation = 4;
    cfg.eval_every = 2;
    let mut server = Server::new(&eng, &man, cfg).unwrap();
    assert_eq!(server.n_clients(), 16);
    let r = server.run().unwrap();
    assert!(r.final_accuracy.is_finite());
    assert!(r.total_bytes > 0);
}

#[test]
fn biased_comm_arm_runs() {
    let Some((eng, man)) = setup() else { return };
    let mut cfg = ExperimentConfig::preset("mlp_c10:bq:iid").unwrap();
    cfg.rounds = 2;
    cfg.clients = 6;
    cfg.participation = 3;
    cfg.n_train = 300;
    cfg.n_test = 256;
    cfg.eval_every = 2;
    let mut server = Server::new(&eng, &man, cfg).unwrap();
    let r = server.run().unwrap();
    assert!(r.final_accuracy.is_finite());
}

#[test]
fn rand_qat_arm_runs_where_exported() {
    let Some((eng, man)) = setup() else { return };
    let mut cfg =
        ExperimentConfig::preset("lenet_c10:randqat:iid").unwrap();
    cfg.rounds = 1;
    cfg.clients = 6;
    cfg.participation = 3;
    cfg.n_train = 300;
    cfg.n_test = 256;
    cfg.eval_every = 1;
    let mut server = Server::new(&eng, &man, cfg).unwrap();
    let r = server.run().unwrap();
    assert!(r.final_accuracy.is_finite());
}

#[test]
fn eval_of_init_model_is_near_chance() {
    let Some((eng, man)) = setup() else { return };
    let mut cfg = ExperimentConfig::preset("lenet_c100:uq:iid").unwrap();
    cfg.rounds = 1;
    cfg.n_train = 300;
    cfg.n_test = 512;
    cfg.clients = 6;
    cfg.participation = 3;
    let server = Server::new(&eng, &man, cfg).unwrap();
    let (acc, loss) = server.evaluate().unwrap();
    assert!(acc < 0.1, "init acc {acc} on 100 classes");
    // CE of uniform prediction over 100 classes is ln(100) ~ 4.6
    assert!((2.0..8.0).contains(&loss), "init loss {loss}");
}

#[test]
fn error_feedback_reduces_biased_comm_drift() {
    // EF extension: with deterministic (biased) communication, the
    // accumulated residuals must keep the effective transmitted mean
    // close to the true weights — measured as final accuracy not
    // collapsing relative to plain BQ on the same seed/budget.
    let Some((eng, man)) = setup() else { return };
    let mut accs = Vec::new();
    for method in ["bq", "bq_ef"] {
        let mut cfg = ExperimentConfig::base("mlp_c10")
            .unwrap()
            .with_method(method)
            .unwrap()
            .with_split("iid")
            .unwrap();
        cfg.rounds = 6;
        cfg.clients = 8;
        cfg.participation = 4;
        cfg.n_train = 800;
        cfg.n_test = 256;
        cfg.eval_every = 6;
        cfg.seed = 3;
        let mut server = Server::new(&eng, &man, cfg).unwrap();
        let r = server.run().unwrap();
        accs.push(r.final_accuracy);
    }
    assert!(
        accs[1] >= accs[0] - 0.05,
        "EF made biased comm worse: bq={} bq_ef={}",
        accs[0],
        accs[1]
    );
}

#[test]
fn parallel_cohort_is_bit_identical_on_real_engine() {
    // acceptance: the same config at parallelism 1 and 4 must yield
    // bit-identical server weights, metrics and byte counts while a
    // cohort of 4 clients executes concurrently through the shared
    // PJRT engine (engine-free counterpart: tests/parallel_determinism)
    let Some((eng, man)) = setup() else { return };
    let mut outcomes = Vec::new();
    for par in [1usize, 4] {
        let mut cfg = ExperimentConfig::preset("mlp_c10:uq:iid").unwrap();
        cfg.rounds = 3;
        cfg.clients = 8;
        cfg.participation = 4;
        cfg.n_train = 400;
        cfg.n_test = 256;
        cfg.eval_every = 100;
        cfg.seed = 21;
        cfg.parallelism = par;
        let mut server = Server::new(&eng, &man, cfg).unwrap();
        let mut losses = Vec::new();
        for t in 0..3 {
            losses.push(server.round(t).unwrap().to_bits());
        }
        let (w, a, b) = server.state();
        outcomes.push((
            w.to_vec(),
            a.to_vec(),
            b.to_vec(),
            server.comm_stats(),
            losses,
        ));
    }
    assert_eq!(outcomes[0].0, outcomes[1].0, "weights diverged");
    assert_eq!(outcomes[0].1, outcomes[1].1, "alphas diverged");
    assert_eq!(outcomes[0].2, outcomes[1].2, "betas diverged");
    assert_eq!(outcomes[0].3, outcomes[1].3, "comm stats diverged");
    assert_eq!(outcomes[0].4, outcomes[1].4, "train losses diverged");
}

#[test]
fn mixed_precision_fleet_runs() {
    let Some((eng, man)) = setup() else { return };
    let mut cfg = ExperimentConfig::preset("mlp_c10:mixed:iid").unwrap();
    assert!(cfg.fp32_client_frac > 0.0);
    cfg.rounds = 4;
    cfg.clients = 8;
    cfg.participation = 4;
    cfg.n_train = 800;
    cfg.n_test = 256;
    cfg.eval_every = 4;
    let mut server = Server::new(&eng, &man, cfg).unwrap();
    let r = server.run().unwrap();
    assert!(r.final_accuracy > 0.2, "mixed fleet failed to learn");
}
