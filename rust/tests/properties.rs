//! Property-based tests (in-tree harness, `util::proptest`) over the
//! coordinator's core invariants: codec round-trips, grid membership,
//! aggregation weights, partitioner coverage.

use fedfp8::coordinator::aggregate;
use fedfp8::coordinator::comm::Uplink;
use fedfp8::data::partition;
use fedfp8::data::vision::{generate, VisionCfg};
use fedfp8::fp8::codec::{self, Rounding, Segment};
use fedfp8::fp8::format::Fp8Params;
use fedfp8::fp8::rng::Pcg32;
use fedfp8::fp8::simd::KernelKind;
use fedfp8::util::proptest::forall;

fn random_segments(g: &mut fedfp8::util::proptest::Gen) -> (Vec<Segment>, usize, usize) {
    let n_seg = g.usize_in(1, 6);
    let mut segs = Vec::new();
    let mut off = 0usize;
    let mut aidx = 0usize;
    for i in 0..n_seg {
        let size = g.usize_in(1, 200);
        let quant = g.bool() || i == 0; // at least one quantized
        segs.push(Segment {
            name: format!("s{i}"),
            offset: off,
            size,
            quantized: quant,
            alpha_idx: if quant { Some(aidx) } else { None },
        });
        off += size;
        if quant {
            aidx += 1;
        }
    }
    (segs, off, aidx)
}

#[test]
fn prop_roundtrip_idempotent() {
    // decode(encode(x)) lies on the grid: re-encoding deterministically
    // must be lossless for every rounding draw.
    forall("roundtrip-idempotent", 11, 150, |g| {
        let alpha = g.f32_log(0.02, 50.0);
        let p = Fp8Params::new(alpha);
        let xs = g.vec_f32(64, alpha * 0.8);
        for x in xs {
            let u = g.rng.uniform_f64();
            let q = p.decode(p.encode(x, u));
            let q2 = p.decode(p.encode(q, 0.5));
            if q2 != q {
                return Err(format!(
                    "not idempotent: x={x} alpha={alpha} q={q} q2={q2}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_quantize_bounded_by_alpha() {
    forall("bounded-by-alpha", 12, 150, |g| {
        let alpha = g.f32_log(0.02, 50.0);
        let p = Fp8Params::new(alpha);
        for _ in 0..128 {
            let x = (g.rng.uniform() - 0.5) * alpha * 10.0;
            let u = g.rng.uniform_f64();
            let q = p.quantize(x, u);
            if q.abs() > alpha * (1.0 + 1e-6) {
                return Err(format!("|q|={} > alpha={alpha}", q.abs()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_stochastic_brackets_value() {
    // Q_rand(x) is always one of the two neighbouring grid points.
    forall("rand-brackets", 13, 100, |g| {
        let alpha = g.f32_log(0.05, 10.0);
        let p = Fp8Params::new(alpha);
        for _ in 0..64 {
            let x = (g.rng.uniform() - 0.5) * 1.8 * alpha;
            let lo = p.quantize(x, 1.0); // never round up (frac<1 always)
            let hi = p.quantize(x, f64::MIN_POSITIVE); // ~always up
            let u = g.rng.uniform_f64();
            let q = p.quantize(x, u);
            if q != lo && q != hi {
                return Err(format!(
                    "q={q} not in {{{lo},{hi}}} for x={x} alpha={alpha}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_codec_preserves_unquantized_exactly() {
    forall("codec-raw-exact", 14, 80, |g| {
        let (segs, dim, adim) = random_segments(g);
        let w = g.vec_f32(dim, 1.0);
        let alphas: Vec<f32> =
            (0..adim).map(|_| g.f32_log(0.1, 4.0)).collect();
        let mode = if g.bool() {
            Rounding::Deterministic
        } else {
            Rounding::Stochastic
        };
        let p = codec::encode(&w, &alphas, &[], &segs, mode, &mut g.rng);
        let mut out = vec![0.0f32; dim];
        codec::decode(&p, &segs, &mut out);
        for seg in segs.iter().filter(|s| !s.quantized) {
            for i in seg.offset..seg.offset + seg.size {
                if out[i] != w[i] {
                    return Err(format!("raw segment changed at {i}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_codec_error_bounded() {
    // after the wire, every quantized weight is within one bin of the
    // original (for unclipped values)
    forall("codec-error-bounded", 15, 80, |g| {
        let (segs, dim, adim) = random_segments(g);
        let alphas: Vec<f32> =
            (0..adim).map(|_| g.f32_log(0.5, 4.0)).collect();
        let w: Vec<f32> = (0..dim)
            .map(|_| (g.rng.uniform() - 0.5) * 0.9)
            .collect();
        let p =
            codec::encode(&w, &alphas, &[], &segs,
                          Rounding::Stochastic, &mut g.rng);
        let mut out = vec![0.0f32; dim];
        codec::decode(&p, &segs, &mut out);
        for seg in segs.iter().filter(|s| s.quantized) {
            let fp = Fp8Params::new(alphas[seg.alpha_idx.unwrap()]);
            for i in seg.offset..seg.offset + seg.size {
                if w[i].abs() >= fp.alpha {
                    continue;
                }
                let bin = fp.scale((w[i] as f64).abs()) as f32;
                if (out[i] - w[i]).abs() > bin * 1.001 {
                    return Err(format!(
                        "error {} > bin {bin} at {i}",
                        (out[i] - w[i]).abs()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_encode_into_decode_into_match_allocating() {
    // Buffer-reuse APIs must be bit-identical to the allocating ones
    // across every Rounding mode, even when the recycled buffers carry
    // garbage from a previous (differently-shaped) message.
    forall("codec-buffer-reuse", 21, 60, |g| {
        let (segs, dim, adim) = random_segments(g);
        let w = g.vec_f32(dim, 1.5);
        let alphas: Vec<f32> =
            (0..adim).map(|_| g.f32_log(0.1, 4.0)).collect();
        let betas: Vec<f32> =
            (0..g.usize_in(0, 4)).map(|_| g.f32_in(0.5, 4.0)).collect();
        // recycled buffers, polluted by a prior message of a
        // different size
        let mut reused = codec::WirePayload {
            codes: vec![0xAB; g.usize_in(0, 300)],
            raw: g.vec_f32(g.usize_in(0, 50), 9.0),
            alphas: vec![7.0; g.usize_in(0, 3)],
            betas: vec![7.0; g.usize_in(0, 3)],
        };
        let mut reused_out = g.vec_f32(g.usize_in(0, 2 * dim), 9.0);
        for mode in [
            Rounding::Deterministic,
            Rounding::Stochastic,
            Rounding::None,
        ] {
            let seed = g.rng.next_u64();
            let mut r_alloc = Pcg32::new(seed, 17);
            let mut r_reuse = Pcg32::new(seed, 17);
            let fresh = codec::encode(
                &w, &alphas, &betas, &segs, mode, &mut r_alloc,
            );
            codec::encode_into(
                &w, &alphas, &betas, &segs, mode, &mut r_reuse,
                &mut reused,
            );
            if reused.codes != fresh.codes
                || reused.raw != fresh.raw
                || reused.alphas != fresh.alphas
                || reused.betas != fresh.betas
            {
                return Err(format!(
                    "encode_into diverged from encode ({mode:?})"
                ));
            }
            let mut fresh_out = vec![0.0f32; dim];
            codec::decode(&fresh, &segs, &mut fresh_out);
            codec::decode_into(&reused, &segs, &mut reused_out);
            if reused_out != fresh_out {
                return Err(format!(
                    "decode_into diverged from decode ({mode:?})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_suffstats_mse_matches_naive() {
    // the sufficient-statistics Eq. (5) scorer (SegmentStats) must
    // agree with the naive O(G*K*d) rescan — the #[cfg-free] reference
    // oracle segment_quant_mse — to f64 tolerance for every segment
    // shape, client set, weighting and alpha grid
    forall("eq5-suffstats-vs-naive", 31, 60, |g| {
        let size = g.usize_in(1, 300);
        let offset = g.usize_in(0, 40);
        let seg = Segment {
            name: "s".into(),
            offset,
            size,
            quantized: true,
            alpha_idx: Some(0),
        };
        let dim = offset + size;
        let w = g.vec_f32(dim, 1.2);
        let n_cl = g.usize_in(1, 8);
        let clients_data: Vec<Vec<f32>> =
            (0..n_cl).map(|_| g.vec_f32(dim, 1.2)).collect();
        let clients: Vec<&[f32]> =
            clients_data.iter().map(|v| v.as_slice()).collect();
        let kweights: Vec<f32> =
            (0..n_cl).map(|_| g.f32_in(0.0, 1.0)).collect();
        let us: Vec<f64> =
            (0..size).map(|_| g.rng.uniform_f64()).collect();
        let stats = codec::SegmentStats::build(&seg, &clients, &kweights);
        let grid = g.usize_in(1, 12);
        for _ in 0..grid {
            let alpha = g.f32_log(0.05, 20.0);
            let naive = codec::segment_quant_mse(
                &w, &seg, alpha, &clients, &kweights, &us,
            );
            let fast = stats.mse(&w, &seg, alpha, &us);
            // identical math, different f64 summation order: the
            // tolerance covers reassociation, not approximation
            let tol = 1e-9 * (1.0 + naive.abs());
            if (naive - fast).abs() > tol {
                return Err(format!(
                    "alpha={alpha} naive={naive} fast={fast} \
                     (K={n_cl}, d={size})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_batched_encode_bit_identical_to_scalar() {
    // batched-RNG + pooled encode must produce byte-identical payloads
    // to the scalar per-element reference for the same counter-derived
    // wire streams, at parallelism 1 and 4 — including segments larger
    // than one RNG block and large enough to cross the pool threshold
    forall("encode-batched-vs-scalar", 32, 20, |g| {
        let mut segs = Vec::new();
        let mut off = 0usize;
        let mut aidx = 0usize;
        let n_seg = g.usize_in(1, 4);
        for i in 0..n_seg {
            // one in ~3 segments is multi-block / pool-threshold sized
            let size = if g.usize_in(0, 2) == 0 {
                g.usize_in(4000, 40_000)
            } else {
                g.usize_in(1, 300)
            };
            let quant = g.bool() || i == 0;
            segs.push(Segment {
                name: format!("s{i}"),
                offset: off,
                size,
                quantized: quant,
                alpha_idx: if quant { Some(aidx) } else { None },
            });
            off += size;
            if quant {
                aidx += 1;
            }
        }
        let w = g.vec_f32(off, 1.5);
        let alphas: Vec<f32> =
            (0..aidx).map(|_| g.f32_log(0.1, 4.0)).collect();
        for mode in [Rounding::Deterministic, Rounding::Stochastic] {
            let seed = g.rng.next_u64();
            let mut r_ref = Pcg32::new(seed, 3);
            let mut reference = codec::WirePayload::default();
            codec::encode_into_scalar(
                &w, &alphas, &[], &segs, mode, &mut r_ref,
                &mut reference,
            );
            for pool in [1usize, 4] {
                let mut r = Pcg32::new(seed, 3);
                let mut scratch = Vec::new();
                let mut got = codec::WirePayload::default();
                codec::encode_into_pooled(
                    &w, &alphas, &[], &segs, mode, KernelKind::Auto,
                    &mut r, &mut scratch, pool, &mut got,
                );
                if got.codes != reference.codes
                    || got.raw != reference.raw
                {
                    return Err(format!(
                        "batched (pool={pool}, {mode:?}) diverged \
                         from scalar reference"
                    ));
                }
                // the caller RNG advances by exactly one wire-key u64
                // per stochastic message (and not at all for det)
                let mut expect = Pcg32::new(seed, 3);
                if mode == Rounding::Stochastic {
                    expect.next_u64();
                }
                if r.next_u32() != expect.next_u32() {
                    return Err(format!(
                        "caller RNG state diverged (pool={pool})"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_simd_kernel_bit_identical_on_wire_paths() {
    // the SIMD kernel must produce byte-identical wire payloads and
    // in-place quantizations to the scalar kernel for the same wire
    // key, across odd tail lengths (len % lane_width != 0), empty
    // segments, raw segments, and pool sizes 1/2/4 with stochastic
    // rounding
    forall("simd-vs-scalar-wire", 43, 30, |g| {
        let mut segs = Vec::new();
        let mut off = 0usize;
        let mut aidx = 0usize;
        let n_seg = g.usize_in(1, 5);
        for i in 0..n_seg {
            // empty, lane-aligned, odd-tailed and multi-block sizes
            let size = match g.usize_in(0, 5) {
                0 => 0,
                1 => g.usize_in(4000, 20_000) | 1,
                2 => 4 * g.usize_in(1, 64),
                _ => g.usize_in(1, 261),
            };
            let quant = g.bool() || i == 0;
            segs.push(Segment {
                name: format!("s{i}"),
                offset: off,
                size,
                quantized: quant,
                alpha_idx: if quant { Some(aidx) } else { None },
            });
            off += size;
            if quant {
                aidx += 1;
            }
        }
        let w = g.vec_f32(off, 2.5);
        let alphas: Vec<f32> =
            (0..aidx).map(|_| g.f32_log(0.05, 20.0)).collect();
        let seed = g.rng.next_u64();
        for mode in [Rounding::Deterministic, Rounding::Stochastic] {
            // scalar-kernel reference at pool 1
            let mut r = Pcg32::new(seed, 9);
            let mut scratch = Vec::new();
            let mut reference = codec::WirePayload::default();
            codec::encode_into_pooled(
                &w, &alphas, &[], &segs, mode, KernelKind::Scalar,
                &mut r, &mut scratch, 1, &mut reference,
            );
            let mut ref_q = vec![0.0f32; off];
            let mut r = Pcg32::new(seed, 9);
            codec::quantize_vec_pooled(
                &w, &alphas, &segs, mode, KernelKind::Scalar, &mut r,
                &mut scratch, 1, &mut ref_q,
            );
            let ref_q_bits: Vec<u32> =
                ref_q.iter().map(|v| v.to_bits()).collect();
            for kernel in [KernelKind::Simd, KernelKind::Auto] {
                for pool in [1usize, 2, 4] {
                    let mut r = Pcg32::new(seed, 9);
                    let mut got = codec::WirePayload::default();
                    codec::encode_into_pooled(
                        &w, &alphas, &[], &segs, mode, kernel, &mut r,
                        &mut scratch, pool, &mut got,
                    );
                    if got.codes != reference.codes
                        || got.raw != reference.raw
                    {
                        return Err(format!(
                            "encode ({kernel}, pool={pool}, {mode:?}) \
                             diverged from the scalar kernel"
                        ));
                    }
                    let mut q = vec![0.0f32; off];
                    let mut r = Pcg32::new(seed, 9);
                    codec::quantize_vec_pooled(
                        &w, &alphas, &segs, mode, kernel, &mut r,
                        &mut scratch, pool, &mut q,
                    );
                    let q_bits: Vec<u32> =
                        q.iter().map(|v| v.to_bits()).collect();
                    if q_bits != ref_q_bits {
                        return Err(format!(
                            "quantize_vec ({kernel}, pool={pool}, \
                             {mode:?}) diverged from the scalar kernel"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mse_with_kernel_equals_reference_mse() {
    // the kernel-dispatched Eq. (5) scorer must be *bit*-equal to the
    // reference SegmentStats::mse for every kernel: same quantize
    // bits, same accumulation order (not merely within tolerance)
    forall("eq5-mse-kernel-bit-equal", 44, 40, |g| {
        let size = g.usize_in(1, 400);
        let offset = g.usize_in(0, 32);
        let seg = Segment {
            name: "s".into(),
            offset,
            size,
            quantized: true,
            alpha_idx: Some(0),
        };
        let dim = offset + size;
        let w = g.vec_f32(dim, 1.3);
        let n_cl = g.usize_in(1, 5);
        let clients_data: Vec<Vec<f32>> =
            (0..n_cl).map(|_| g.vec_f32(dim, 1.3)).collect();
        let clients: Vec<&[f32]> =
            clients_data.iter().map(|v| v.as_slice()).collect();
        let kweights: Vec<f32> =
            (0..n_cl).map(|_| g.f32_in(0.0, 1.0)).collect();
        let us: Vec<f64> =
            (0..size).map(|_| g.rng.uniform_f64()).collect();
        let stats =
            codec::SegmentStats::build(&seg, &clients, &kweights);
        for _ in 0..4 {
            let alpha = g.f32_log(0.05, 20.0);
            let reference = stats.mse(&w, &seg, alpha, &us);
            for kernel in [
                KernelKind::Scalar,
                KernelKind::Simd,
                KernelKind::Auto,
            ] {
                let got =
                    stats.mse_with(kernel, &w, &seg, alpha, &us);
                if got.to_bits() != reference.to_bits() {
                    return Err(format!(
                        "mse_with({kernel}) = {got} != mse = \
                         {reference} (alpha={alpha}, d={size})"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fedavg_convex_combination() {
    // aggregated weights stay inside the per-coordinate min/max of the
    // client vectors (convexity of weighted averaging)
    forall("fedavg-convex", 16, 60, |g| {
        let seg = vec![Segment {
            name: "w".into(),
            offset: 0,
            size: 32,
            quantized: false, // exact passthrough isolates averaging
            alpha_idx: None,
        }];
        let n_cl = g.usize_in(1, 8);
        let mut ups = Vec::new();
        for c in 0..n_cl {
            let w = g.vec_f32(32, 2.0);
            ups.push(Uplink {
                payload: codec::encode(&w, &[], &[], &seg,
                                       Rounding::None, &mut g.rng),
                client: c,
                n_k: g.usize_in(1, 100) as u64,
                mean_loss: 0.0,
            });
        }
        let agg = aggregate::fedavg(&ups, &seg, 32, 0, 0).unwrap();
        for i in 0..32 {
            let lo = ups
                .iter()
                .map(|u| u.payload.raw[i])
                .fold(f32::MAX, f32::min);
            let hi = ups
                .iter()
                .map(|u| u.payload.raw[i])
                .fold(f32::MIN, f32::max);
            if agg.w[i] < lo - 1e-5 || agg.w[i] > hi + 1e-5 {
                return Err(format!(
                    "avg {} outside [{lo},{hi}] at {i}",
                    agg.w[i]
                ));
            }
        }
        // kweights sum to 1
        let s: f32 = agg.kweights.iter().sum();
        if (s - 1.0).abs() > 1e-5 {
            return Err(format!("kweights sum {s}"));
        }
        Ok(())
    });
}

#[test]
fn prop_partitions_cover_exactly_once() {
    forall("partition-exact-cover", 17, 25, |g| {
        let classes = g.usize_in(2, 10);
        let n = g.usize_in(50, 400);
        let k = g.usize_in(2, 12);
        let cfg = VisionCfg::new(classes);
        let (ds, _) = generate(&cfg, n, 4, g.rng.next_u64());
        let shards = if g.bool() {
            partition::iid(n, k, &mut g.rng)
        } else {
            partition::dirichlet(&ds, k, 0.3, &mut g.rng)
        };
        let mut seen = vec![false; n];
        for s in &shards {
            for &i in s {
                if seen[i] {
                    return Err(format!("duplicate index {i}"));
                }
                seen[i] = true;
            }
        }
        if !seen.iter().all(|&b| b) {
            return Err("missing index".into());
        }
        Ok(())
    });
}

#[test]
fn prop_comm_accounting_matches_payload_sizes() {
    forall("comm-bytes", 18, 60, |g| {
        let (segs, dim, adim) = random_segments(g);
        let w = g.vec_f32(dim, 1.0);
        let alphas: Vec<f32> = (0..adim).map(|_| 1.0).collect();
        let betas = vec![1.0f32; g.usize_in(0, 5)];
        let p = codec::encode(&w, &alphas, &betas, &segs,
                              Rounding::Stochastic, &mut g.rng);
        let n_quant: usize = segs
            .iter()
            .filter(|s| s.quantized)
            .map(|s| s.size)
            .sum();
        let n_raw = dim - n_quant;
        let expect = n_quant as u64
            + 4 * (n_raw + adim + betas.len()) as u64;
        if p.wire_bytes() != expect {
            return Err(format!(
                "bytes {} != expected {expect}",
                p.wire_bytes()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_wire_messages_roundtrip_identity() {
    // net codec: framed encode -> decode is the identity for
    // arbitrary payload shapes — including empty segments (zero-size
    // codes/raw/alphas/betas sections) and zero-client edge cases
    // (client id 0, n_k 0, empty shards, empty EF residuals)
    use fedfp8::config::QatMode;
    use fedfp8::net::{codec as net_codec, frame, WireJob, WireOutcome};

    forall("wire-roundtrip", 31, 150, |g| {
        let payload = codec::WirePayload {
            codes: (0..g.usize_in(0, 300))
                .map(|_| g.rng.next_u32() as u8)
                .collect(),
            raw: g.vec_f32(g.usize_in(0, 40), 2.0),
            alphas: g.vec_f32(g.usize_in(0, 5), 1.0),
            betas: g.vec_f32(g.usize_in(0, 4), 1.0),
        };
        let ef = if g.bool() {
            Some(g.vec_f32(g.usize_in(0, 50), 0.5))
        } else {
            None
        };
        let job = WireJob {
            round: g.usize_in(0, 10_000) as u32,
            client: g.usize_in(0, 500) as u32,
            job_id: g.usize_in(0, 1_000) as u32,
            seed: g.rng.next_u64(),
            qat: [QatMode::Det, QatMode::Rand, QatMode::None]
                [g.rng.below(3)],
            comm: [
                Rounding::Deterministic,
                Rounding::Stochastic,
                Rounding::None,
            ][g.rng.below(3)],
            flip_aug: g.bool(),
            lr: g.f32_in(-2.0, 2.0),
            weight_decay: g.f32_in(0.0, 0.1),
            n_k: g.usize_in(0, 1_000) as u64,
            down: payload.clone(),
            ef: ef.clone(),
        };
        // frame it exactly as the transport would, then read it back
        let mut body = Vec::new();
        net_codec::encode_job(&job, &mut body);
        let mut framed = Vec::new();
        frame::write_frame(&mut framed, frame::FrameKind::Job, &body)
            .map_err(|e| e.to_string())?;
        let f = frame::read_frame(&mut &framed[..])
            .map_err(|e| e.to_string())?;
        let back = net_codec::decode_job(&f.body)
            .map_err(|e| e.to_string())?;
        if back != job {
            return Err("job roundtrip not identity".into());
        }
        let out = WireOutcome {
            round: job.round,
            client: job.client,
            job_id: job.job_id,
            n_k: job.n_k,
            mean_loss: g.f32_in(-5.0, 5.0),
            payload,
            ef,
        };
        net_codec::encode_outcome(&out, &mut body);
        let back = net_codec::decode_outcome(&body)
            .map_err(|e| e.to_string())?;
        if back != out {
            return Err("outcome roundtrip not identity".into());
        }
        Ok(())
    });
}

#[test]
fn prop_v2_interleaved_outcomes_reassemble_in_order() {
    // v2 multiplexing model-check: a window of outcomes tagged with
    // round-scoped job_ids is delivered in a randomized order, with
    // heartbeat/ack frames interleaved and occasional duplicated
    // outcome frames — exactly what a chaotic link hands the server's
    // reader. Routing by job_id into a reorder buffer must (a) ignore
    // the heartbeats, (b) drop duplicates as bit-identical repeats,
    // and (c) reassemble the exact in-order sequence the aggregation
    // stream expects.
    use fedfp8::net::{codec as net_codec, frame, WireOutcome};
    use std::collections::BTreeMap;

    forall("wire-v2-interleavings", 47, 60, |g| {
        let round = g.usize_in(0, 50) as u32;
        let n = g.usize_in(1, 12);
        let outcomes: Vec<WireOutcome> = (0..n)
            .map(|pos| WireOutcome {
                round,
                client: g.usize_in(0, 500) as u32,
                job_id: pos as u32,
                n_k: g.usize_in(0, 100) as u64,
                mean_loss: g.f32_in(-2.0, 2.0),
                payload: codec::WirePayload {
                    codes: (0..g.usize_in(0, 60))
                        .map(|_| g.rng.next_u32() as u8)
                        .collect(),
                    raw: g.vec_f32(g.usize_in(0, 8), 1.0),
                    alphas: g.vec_f32(g.usize_in(0, 3), 1.0),
                    betas: vec![],
                },
                ef: None,
            })
            .collect();
        // a random delivery order (Fisher-Yates on positions)
        let mut order: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = g.rng.below(i + 1);
            order.swap(i, j);
        }
        // write the stream: shuffled outcomes + interleaved
        // heartbeats + some duplicated outcome frames
        let mut stream = Vec::new();
        let mut body = Vec::new();
        for &pos in &order {
            if g.bool() {
                net_codec::encode_heartbeat(
                    g.rng.next_u64(),
                    &mut body,
                );
                let kind = if g.bool() {
                    frame::FrameKind::Heartbeat
                } else {
                    frame::FrameKind::HeartbeatAck
                };
                frame::write_frame(&mut stream, kind, &body)
                    .map_err(|e| e.to_string())?;
            }
            net_codec::encode_outcome(&outcomes[pos], &mut body);
            frame::write_frame(
                &mut stream,
                frame::FrameKind::Outcome,
                &body,
            )
            .map_err(|e| e.to_string())?;
            if g.usize_in(0, 3) == 0 {
                // duplicate delivery of the same frame
                frame::write_frame(
                    &mut stream,
                    frame::FrameKind::Outcome,
                    &body,
                )
                .map_err(|e| e.to_string())?;
            }
        }
        // reader side: route by job_id, ignore heartbeats, detect
        // duplicates, reassemble in job_id (cohort) order
        let mut reorder: BTreeMap<u32, WireOutcome> = BTreeMap::new();
        let mut r = &stream[..];
        loop {
            let f = match frame::read_frame(&mut r) {
                Ok(f) => f,
                Err(e) if e.is_clean_close() => break,
                Err(e) => return Err(e.to_string()),
            };
            match f.kind {
                frame::FrameKind::Heartbeat
                | frame::FrameKind::HeartbeatAck => {
                    net_codec::decode_heartbeat(&f.body)
                        .map_err(|e| e.to_string())?;
                }
                frame::FrameKind::Outcome => {
                    let out = net_codec::decode_outcome(&f.body)
                        .map_err(|e| e.to_string())?;
                    if out.round != round {
                        return Err("round id corrupted".into());
                    }
                    match reorder.get(&out.job_id) {
                        Some(first) => {
                            if *first != out {
                                return Err(format!(
                                    "duplicate of job {} not \
                                     bit-identical",
                                    out.job_id
                                ));
                            }
                        }
                        None => {
                            reorder.insert(out.job_id, out);
                        }
                    }
                }
                k => return Err(format!("unexpected kind {k:?}")),
            }
        }
        // the reorder buffer drains to the exact in-order cohort
        if reorder.len() != n {
            return Err(format!(
                "{} of {n} outcomes reassembled",
                reorder.len()
            ));
        }
        for (pos, original) in outcomes.iter().enumerate() {
            let got = &reorder[&(pos as u32)];
            if got != original {
                return Err(format!(
                    "outcome at cohort position {pos} corrupted by \
                     out-of-order delivery"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_stochastic_unbiased_mean() {
    // statistical unbiasedness across a range of alphas (Lemma 3)
    forall("rand-unbiased", 19, 12, |g| {
        let alpha = g.f32_log(0.2, 8.0);
        let p = Fp8Params::new(alpha);
        let x = (g.rng.uniform() - 0.5) * alpha;
        let n = 6000;
        let mut acc = 0.0f64;
        for _ in 0..n {
            acc += p.quantize(x, g.rng.uniform_f64()) as f64;
        }
        let mean = acc / n as f64;
        let bin = p.scale((x as f64).abs());
        let tol = 5.0 * bin / (n as f64).sqrt() + 1e-7;
        if (mean - x as f64).abs() > tol {
            return Err(format!(
                "bias {} > tol {tol} (x={x}, alpha={alpha})",
                (mean - x as f64).abs()
            ));
        }
        Ok(())
    });
}
