//! Networked-transport suite: loopback equivalence + fault injection.
//!
//! **Equivalence** — a full multi-round experiment driven through
//! `SocketTransport` against worker serve loops on `127.0.0.1` must
//! be *bit-identical* to the same experiment on `InProcessTransport`:
//! final weights, per-segment alphas, betas, per-round losses and
//! CommStats, at parallelism 1 and 4, with an oversubscribed
//! connection pool, and — new in v2 — with a multi-job in-flight
//! window per connection (`--net-inflight`), where outcomes return
//! out of order and are demultiplexed by `job_id`. The workers run
//! the same deterministic mock executor (`tests/common/mod.rs`) on a
//! world they rebuild from their own copy of the config — exactly the
//! production worker flow.
//!
//! **Accounting** — with error feedback off, the bytes the transport
//! physically moved must equal the bytes `CommStats` reported
//! (`reported == actual` is the point of charging real frame
//! overheads in `coordinator/comm.rs`; heartbeat frames are excluded
//! from both sides of that identity by design).
//!
//! **Faults** — a truncated frame, wrong magic, version mismatch, a
//! worker disconnect mid-round and a silent worker must each surface
//! as a typed error naming the client id, never a hang (the reader
//! threads always run an idle deadline). Deeper fault schedules —
//! re-dispatch to surviving workers, duplicated outcomes, delayed
//! frames, reconnect caching — live in `tests/net_chaos.rs`.

mod common;

use std::net::{TcpListener, TcpStream};
use std::thread;
use std::time::Duration;

use common::{mock_cfg, mock_manifest, run_mock, MockTransport, Trace};
use fedfp8::config::ExperimentConfig;
use fedfp8::coordinator::{build_world, Server};
use fedfp8::net::frame::FrameKind;
use fedfp8::net::worker::WorkerCtx;
use fedfp8::net::{
    self, frame, Hello, Inflight, OutcomeCache, ServeOpts, SocketCfg,
};
use fedfp8::runtime::Engine;

fn hello_for(cfg: &ExperimentConfig) -> Hello {
    Hello {
        fingerprint: cfg.fingerprint(),
        dim: common::DIM as u64,
        model: "mock".into(),
        auth: 0,
        role: net::PeerRole::Worker,
        shard: None,
    }
}

/// Loopback tuning: long deadlines (nothing should ever hit them)
/// and probing off on both sides, so a clean run carries zero
/// heartbeat traffic to race the shutdown.
fn quiet_cfg(inflight: Inflight) -> (SocketCfg, ServeOpts) {
    (
        SocketCfg {
            inflight,
            heartbeat: Duration::ZERO,
            ..SocketCfg::new(Duration::from_secs(20))
        },
        ServeOpts {
            heartbeat: Duration::ZERO,
            idle_deadline: Duration::ZERO,
            exec_threads: inflight.exec_threads(),
        },
    )
}

/// Run the full mock experiment through `SocketTransport` against
/// `workers` in-thread serve loops; returns the bit-exact trace.
fn run_socket(
    parallelism: usize,
    workers: usize,
    inflight: Inflight,
    error_feedback: bool,
) -> Trace {
    let tag = format!(
        "net_p{parallelism}_w{workers}_i{inflight}_ef{error_feedback}"
    );
    let (dir, manifest) = mock_manifest(&tag);
    let engine = Engine::new(&dir).unwrap();
    let cfg = mock_cfg(parallelism, error_feedback);
    let model = manifest.model("mock").unwrap();
    let world = build_world(&cfg, model).unwrap();
    let hello = hello_for(&cfg);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let exec = MockTransport::new(true);
    let rounds = cfg.rounds;
    let fingerprint = cfg.fingerprint();
    let (socket_cfg, opts) = quiet_cfg(inflight);
    let ctx = WorkerCtx {
        train: &world.train,
        shards: &world.shards,
        segments: &model.segments,
        kernel: cfg.fp8_kernel,
    };
    thread::scope(|s| {
        for _ in 0..workers {
            let (addr, hello, exec, ctx, opts) =
                (&addr, &hello, &exec, &ctx, &opts);
            s.spawn(move || {
                let cache = OutcomeCache::new(64);
                let mut stream = net::connect(
                    addr,
                    hello,
                    Duration::from_secs(20),
                )
                .expect("worker handshake");
                net::serve_conn(
                    &mut stream,
                    exec,
                    ctx,
                    opts,
                    fingerprint,
                    &cache,
                )
                .expect("worker serve loop");
            });
        }
        let transport = net::accept_workers(
            listener,
            workers,
            &hello,
            socket_cfg,
        )
        .expect("server handshake");
        let mut server = Server::with_transport(
            &engine,
            &manifest,
            cfg,
            Box::new(&transport),
        )
        .unwrap();
        let mut losses = Vec::new();
        for t in 0..rounds {
            losses.push(server.round(t).unwrap().to_bits());
        }
        let trace = Trace::capture(&server, losses);
        if !error_feedback {
            // reported == actual: CommStats byte counts must equal
            // the frame bytes that physically crossed the sockets
            // (EF residual blocks are the documented exclusion, and
            // no job was re-dispatched in a clean run)
            assert_eq!(
                transport.bytes_sent(),
                trace.comm.down_bytes,
                "downlink accounting != actual job-frame bytes"
            );
            assert_eq!(
                transport.bytes_received(),
                trace.comm.up_bytes,
                "uplink accounting != actual outcome-frame bytes"
            );
        }
        assert_eq!(transport.requeues(), 0, "clean run re-dispatched");
        assert_eq!(
            transport.duplicate_outcomes(),
            0,
            "clean run saw duplicate outcomes"
        );
        // the O(1)-threads guarantee: one poll loop serves every
        // worker connection — the transport's thread count must not
        // scale with `workers`
        assert_eq!(
            transport.transport_threads(),
            1,
            "transport spawned per-connection threads"
        );
        drop(server);
        transport.shutdown();
        trace
    })
}

#[test]
fn loopback_equals_in_process_at_parallelism_1_and_4() {
    let base1 = run_mock(1, false);
    let net1 = run_socket(1, 1, Inflight::Fixed(1), false);
    assert_eq!(net1, base1, "socket run diverged at parallelism 1");
    let base4 = run_mock(4, false);
    let net4 = run_socket(4, 4, Inflight::Fixed(1), false);
    assert_eq!(net4, base4, "socket run diverged at parallelism 4");
    // and parallelism itself is invisible either way
    assert_eq!(base1.w, base4.w);
    assert_eq!(net1.w, net4.w);
}

#[test]
fn loopback_is_deterministic_with_oversubscribed_pool() {
    // 4-way cohort fan-out over only 2 worker connections: checkout
    // contention changes scheduling, never results
    let base = run_mock(4, false);
    let net = run_socket(4, 2, Inflight::Fixed(1), false);
    assert_eq!(net, base, "oversubscribed pool changed results");
}

#[test]
fn loopback_is_deterministic_with_multiplexed_window() {
    // the v2 acceptance shape: the whole 4-wide cohort rides ONE
    // connection with --net-inflight 4; outcomes return out of order
    // (the mock sleeps later clients less) and the job_id demux +
    // reorder buffer must still deliver bit-identical results
    let base = run_mock(4, false);
    let net = run_socket(4, 1, Inflight::Fixed(4), false);
    assert_eq!(net, base, "multiplexed window changed results");
    // mixed shape: window 2 over 2 workers
    let net = run_socket(4, 2, Inflight::Fixed(2), false);
    assert_eq!(net, base, "window-2 x 2-workers changed results");
}

#[test]
fn poll_core_is_deterministic_across_window_policies() {
    // the poll-core determinism matrix: inflight {1, 2, adaptive} x
    // parallelism {1, 4} over two connections must all be
    // bit-identical to the in-process run — the adaptive window
    // changes *scheduling* (it grows per-connection from observed
    // latency), never results
    for parallelism in [1usize, 4] {
        let base = run_mock(parallelism, false);
        for inflight in
            [Inflight::Fixed(1), Inflight::Fixed(2), Inflight::Adaptive]
        {
            let net = run_socket(parallelism, 2, inflight, false);
            assert_eq!(
                net, base,
                "p={parallelism} inflight={inflight} diverged \
                 from in-process"
            );
        }
    }
}

#[test]
fn loopback_round_trips_error_feedback_residuals() {
    // EF residuals ride the wire in both directions; the trajectory
    // must still be bit-identical to the in-process run — including
    // through a multiplexed window
    let base = run_mock(4, true);
    let net = run_socket(4, 4, Inflight::Fixed(1), true);
    assert_eq!(net.w, base.w);
    assert_eq!(net.alpha, base.alpha);
    assert_eq!(net.losses, base.losses);
    assert_eq!(net.comm, base.comm);
    let net = run_socket(4, 1, Inflight::Fixed(4), true);
    assert_eq!(net.w, base.w, "EF diverged through the window");
    assert_eq!(net.comm, base.comm);
}

#[test]
fn handshake_rejects_mismatched_config() {
    let cfg = mock_cfg(1, false);
    let mut other = cfg.clone();
    other.seed += 1; // a worker launched with the wrong seed
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server_hello = hello_for(&cfg);
    let worker_hello = hello_for(&other);
    assert_ne!(server_hello.fingerprint, worker_hello.fingerprint);
    thread::scope(|s| {
        s.spawn(|| {
            // the worker's connect() fails too (no ack arrives), but
            // the authoritative, actionable error is the server's
            let _ = net::connect(
                &addr,
                &worker_hello,
                Duration::from_secs(10),
            );
        });
        let err = net::accept_workers(
            listener,
            1,
            &server_hello,
            SocketCfg::new(Duration::from_secs(10)),
        )
        .map(|_| ())
        .unwrap_err();
        let msg = format!("{err:?}");
        assert!(
            msg.contains("fingerprint mismatch"),
            "unexpected handshake error: {msg}"
        );
    });
}

#[test]
fn handshake_rejects_wrong_auth_token() {
    // --net-token: a worker with the wrong (or no) secret must be
    // turned away with the typed error BEFORE any config detail or
    // job flows; a worker with the right secret handshakes fine
    let cfg = mock_cfg(1, false);
    let mut server_hello = hello_for(&cfg);
    server_hello.auth = net::token_digest(Some("right-secret"));
    let reject = |worker_auth: u64| {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let mut worker_hello = hello_for(&cfg);
        worker_hello.auth = worker_auth;
        thread::scope(|s| {
            s.spawn(|| {
                let _ = net::connect(
                    &addr,
                    &worker_hello,
                    Duration::from_secs(10),
                );
            });
            net::accept_workers(
                listener,
                1,
                &server_hello,
                SocketCfg::new(Duration::from_secs(10)),
            )
            .map(|_| ())
            .unwrap_err()
        })
    };
    for bad in [net::token_digest(Some("wrong-secret")), 0] {
        let err = reject(bad);
        assert!(
            matches!(
                err.downcast_ref::<frame::WireError>(),
                Some(frame::WireError::AuthRejected)
            ),
            "expected typed AuthRejected, got: {err:?}"
        );
        assert!(
            format!("{err:#}").contains("--net-token"),
            "error should point at the knob: {err:#}"
        );
    }
    // same secret on both sides: accepted
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    thread::scope(|s| {
        s.spawn(|| {
            let stream = net::connect(
                &addr,
                &server_hello,
                Duration::from_secs(10),
            );
            assert!(stream.is_ok(), "matching token must handshake");
        });
        let transport = net::accept_workers(
            listener,
            1,
            &server_hello,
            SocketCfg::new(Duration::from_secs(10)),
        )
        .expect("matching token must be accepted");
        transport.shutdown();
    });
}

#[test]
fn worker_rejects_unauthenticated_server_ack() {
    // mutual auth: a worker launched with a token refuses to serve a
    // coordinator that did not prove the same secret in its ack
    let cfg = mock_cfg(1, false);
    let mut worker_hello = hello_for(&cfg);
    worker_hello.auth = net::token_digest(Some("right-secret"));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    thread::scope(|s| {
        let server_hello = hello_for(&cfg); // tokenless: auth = 0
        s.spawn(move || {
            // fake coordinator: accept the Hello unconditionally and
            // ack with auth 0 (what a tokenless build would send)
            let (mut stream, _) = listener.accept().unwrap();
            let f = frame::read_frame(&mut stream).unwrap();
            assert_eq!(f.kind, FrameKind::Hello);
            let mut ack = Vec::new();
            net::codec::encode_hello_ack(
                server_hello.fingerprint,
                server_hello.auth,
                &mut ack,
            );
            frame::write_frame(&mut stream, FrameKind::HelloAck, &ack)
                .unwrap();
            // hold the stream open until the worker decides
            let _ = frame::read_frame(&mut stream);
        });
        let err = net::connect(
            &addr,
            &worker_hello,
            Duration::from_secs(10),
        )
        .map(|_| ())
        .unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<frame::WireError>(),
                Some(frame::WireError::AuthRejected)
            ),
            "expected typed AuthRejected, got: {err:?}"
        );
    });
}

// ---- fault injection ------------------------------------------------

/// Drive one round against a single fake worker whose behaviour after
/// the handshake is `misbehave`; returns the server-side round error.
fn round_error_with_fake_worker(
    tag: &str,
    timeout: Duration,
    misbehave: impl FnOnce(&mut TcpStream) + Send,
) -> String {
    let (dir, manifest) = mock_manifest(tag);
    let engine = Engine::new(&dir).unwrap();
    let mut cfg = mock_cfg(1, false);
    // a single client, so the error must name "client 0"
    cfg.clients = 1;
    cfg.participation = 1;
    let hello = hello_for(&cfg);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    thread::scope(|s| {
        let (addr, hello) = (&addr, &hello);
        s.spawn(move || {
            let mut stream = net::connect(
                addr,
                hello,
                Duration::from_secs(10),
            )
            .expect("fake worker handshake");
            // receive the job like a real worker would...
            frame::read_frame(&mut stream).expect("job frame");
            // ...then misbehave
            misbehave(&mut stream);
        });
        let transport = net::accept_workers(
            listener,
            1,
            hello,
            SocketCfg {
                // probing off: these tests exercise the v1-style
                // "silence while a job is pending" deadline
                heartbeat: Duration::ZERO,
                inflight: Inflight::Fixed(1),
                ..SocketCfg::new(timeout)
            },
        )
        .expect("handshake");
        let mut server = Server::with_transport(
            &engine,
            &manifest,
            cfg,
            Box::new(&transport),
        )
        .unwrap();
        let err = server.round(0).unwrap_err();
        let msg = format!("{err:?}");
        transport.shutdown();
        msg
    })
}

#[test]
fn worker_disconnect_mid_round_names_the_client() {
    let msg = round_error_with_fake_worker(
        "disc",
        Duration::from_secs(10),
        |stream| {
            // drop the connection instead of answering
            stream.shutdown(std::net::Shutdown::Both).ok();
        },
    );
    assert!(msg.contains("client 0"), "missing client id: {msg}");
    assert!(msg.contains("closed"), "not a disconnect error: {msg}");
}

#[test]
fn truncated_outcome_frame_names_the_client() {
    let msg = round_error_with_fake_worker(
        "trunc",
        Duration::from_secs(10),
        |stream| {
            // a syntactically valid envelope announcing a 64-byte
            // body, then only 10 bytes and a close
            let mut fake = Vec::new();
            frame::write_frame(
                &mut fake,
                FrameKind::Outcome,
                &[0u8; 64],
            )
            .unwrap();
            use std::io::Write;
            stream
                .write_all(&fake[..frame::FRAME_HEADER_BYTES as usize + 10])
                .unwrap();
            stream.shutdown(std::net::Shutdown::Both).ok();
        },
    );
    assert!(msg.contains("client 0"), "missing client id: {msg}");
    assert!(msg.contains("truncated"), "not a truncation error: {msg}");
}

#[test]
fn wrong_magic_names_the_client() {
    let msg = round_error_with_fake_worker(
        "magic",
        Duration::from_secs(10),
        |stream| {
            use std::io::Write;
            stream.write_all(&[b'N'; 64]).unwrap();
            stream.shutdown(std::net::Shutdown::Both).ok();
        },
    );
    assert!(msg.contains("client 0"), "missing client id: {msg}");
    assert!(msg.contains("magic"), "not a bad-magic error: {msg}");
}

#[test]
fn version_mismatch_names_the_client() {
    // a peer still speaking wire v1 (or any other version) must be a
    // typed version error, not silent corruption
    let msg = round_error_with_fake_worker(
        "ver",
        Duration::from_secs(10),
        |stream| {
            let mut fake = Vec::new();
            frame::write_frame(&mut fake, FrameKind::Outcome, b"x")
                .unwrap();
            fake[4..6].copy_from_slice(&1u16.to_le_bytes());
            use std::io::Write;
            stream.write_all(&fake).unwrap();
            stream.shutdown(std::net::Shutdown::Both).ok();
        },
    );
    assert!(msg.contains("client 0"), "missing client id: {msg}");
    assert!(
        msg.contains("version mismatch") && msg.contains("v1"),
        "not a version error: {msg}"
    );
}

#[test]
fn silent_worker_times_out_instead_of_hanging() {
    let msg = round_error_with_fake_worker(
        "hang",
        Duration::from_millis(400),
        |_stream| {
            // say nothing until the server gives up
            std::thread::sleep(Duration::from_millis(1500));
        },
    );
    assert!(msg.contains("client 0"), "missing client id: {msg}");
    assert!(msg.contains("timed out"), "not a timeout error: {msg}");
}

#[test]
fn corrupted_outcome_checksum_names_the_client() {
    let msg = round_error_with_fake_worker(
        "crc",
        Duration::from_secs(10),
        |stream| {
            let mut fake = Vec::new();
            frame::write_frame(
                &mut fake,
                FrameKind::Outcome,
                &[7u8; 40],
            )
            .unwrap();
            let last = fake.len() - 1;
            fake[last] ^= 0xFF;
            use std::io::Write;
            stream.write_all(&fake).unwrap();
            stream.shutdown(std::net::Shutdown::Both).ok();
        },
    );
    assert!(msg.contains("client 0"), "missing client id: {msg}");
    assert!(msg.contains("checksum"), "not a checksum error: {msg}");
}

#[test]
fn partial_frame_reported_bytes_equal_actual_bytes() {
    // the tree backbone obeys the same reported == actual identity as
    // the client edge: a mid-tier -> root partial frame on the wire
    // is byte-for-byte what CommStats charges for it, and the f64
    // sums survive the trip bit-exactly
    use fedfp8::coordinator::aggregate::TreePartial;
    use fedfp8::coordinator::comm::CommStats;
    use fedfp8::net::codec as wire;

    let p = TreePartial {
        start: 4,
        end: 11,
        width: 3,
        ranges: vec![(4, 4), (8, 2), (10, 1)],
        sums: vec![
            vec![1.5e-300, -0.0, f64::INFINITY],
            vec![0.1, 0.2, 0.3],
            vec![-7.25, 1e300, 5e-324],
        ],
    };
    let mut body = Vec::new();
    wire::encode_partial(9, &p, &mut body);
    let mut framed = Vec::new();
    frame::write_frame(&mut framed, FrameKind::Partial, &body)
        .unwrap();

    let mut comm = CommStats::default();
    comm.record_partial(&p);
    assert_eq!(
        comm.partial_bytes,
        framed.len() as u64,
        "CommStats charge != bytes on the wire"
    );
    assert_eq!(comm.partial_msgs, 1);

    let (round, q) = wire::decode_partial(&body).unwrap();
    assert_eq!(round, 9);
    for (a, b) in p.sums.iter().zip(&q.sums) {
        let bits = |v: &[f64]| -> Vec<u64> {
            v.iter().map(|x| x.to_bits()).collect()
        };
        assert_eq!(bits(a), bits(b), "f64 sums not bit-exact");
    }
    assert_eq!(p, q);
}
