//! Deterministic cohort sampling + virtualized client state, end to
//! end: per-round cohorts are a pure function of `(seed, round)`
//! drawn O(P) from a counter-derived stream, and a million-client
//! population costs O(cohort) resident per-client state — pinned by
//! the [`ClientStateProbe`] struct-count probe.
//!
//! [`ClientStateProbe`]: fedfp8::coordinator::server::ClientStateProbe

mod common;

use std::collections::BTreeSet;

use common::{mock_cfg, mock_manifest, MockTransport, Trace};
use fedfp8::config::AggMode;
use fedfp8::coordinator::transport::streams;
use fedfp8::coordinator::{Server, VIRTUALIZE_AT};
use fedfp8::fp8::rng::Pcg32;
use fedfp8::fp8::Rounding;
use fedfp8::runtime::Engine;

fn cohort_of(seed: u64, round: u64, k: usize, p: usize) -> Vec<usize> {
    Pcg32::derive(seed, round, 0, streams::COHORT)
        .sample_distinct_sparse(k, p)
}

#[test]
fn cohort_is_a_pure_function_of_seed_and_round() {
    let (k, p) = (1_000_000usize, 256usize);
    let a = cohort_of(11, 3, k, p);
    // reproducible: no dependence on prior rounds or shared state
    assert_eq!(a, cohort_of(11, 3, k, p));
    // distinct, in range
    let set: BTreeSet<usize> = a.iter().copied().collect();
    assert_eq!(set.len(), p, "cohort has duplicates");
    assert!(a.iter().all(|&c| c < k));
    // different rounds / seeds draw different cohorts
    assert_ne!(a, cohort_of(11, 4, k, p));
    assert_ne!(a, cohort_of(12, 3, k, p));
    // the sparse sampler IS the dense sampler, draw for draw
    let dense = Pcg32::derive(11, 3, 0, streams::COHORT)
        .sample_distinct(70_000, 256);
    let sparse = cohort_of(11, 3, 70_000, 256);
    assert_eq!(dense, sparse);
}

#[test]
fn cohort_size_is_a_fingerprint_field() {
    // changing --cohort must change the config fingerprint (it moves
    // the trajectory), unlike the topology/parallelism levers
    let base = mock_cfg(1, false);
    let mut bigger = base.clone();
    bigger.participation += 1;
    assert_ne!(base.fingerprint(), bigger.fingerprint());
    let mut tree = base.clone();
    tree.agg = AggMode::Tree { nodes: 4 };
    assert_eq!(base.fingerprint(), tree.fingerprint());
}

/// Run `rounds` mock rounds at population `k`, cohort `p`; returns
/// the server for probing plus the trace.
fn run_million(
    tag: &str,
    k: usize,
    p: usize,
    rounds: usize,
    error_feedback: bool,
    agg: AggMode,
) -> (Trace, fedfp8::coordinator::server::ClientStateProbe) {
    let (dir, manifest) = mock_manifest(tag);
    let engine = Engine::new(&dir).unwrap();
    let transport = MockTransport::new(false);
    let mut cfg = mock_cfg(1, error_feedback);
    cfg.clients = k;
    cfg.participation = p;
    cfg.rounds = rounds;
    cfg.agg = agg;
    let mut server = Server::with_transport(
        &engine,
        &manifest,
        cfg,
        Box::new(&transport),
    )
    .unwrap();
    let mut losses = Vec::new();
    for t in 0..rounds {
        losses.push(server.round(t).unwrap().to_bits());
    }
    let probe = server.client_state_probe();
    (Trace::capture(&server, losses), probe)
}

#[test]
fn million_clients_round_in_o_cohort_memory() {
    // the headline acceptance: K = 10^6, cohort 256, on a 96-sample
    // world — every sampled shard is (almost surely) empty, so this
    // also exercises the degenerate uniform-weighting path
    let (trace, probe) =
        run_million("m1", 1_000_000, 256, 1, false, AggMode::Flat);
    // the struct-count probe: zero resident per-client shard structs
    assert!(probe.virtualized);
    assert_eq!(probe.resident_shard_structs, 0);
    assert_eq!(probe.ef_residuals, 0);
    // the round really ran its 256 clients and produced a finite mean
    assert_eq!(trace.comm.up_msgs, 256);
    assert_eq!(trace.comm.down_msgs, 256);
    let loss = f32::from_bits(trace.losses[0]);
    assert!(loss.is_finite(), "mean loss {loss} not finite");
}

#[test]
fn million_clients_ef_state_grows_with_touched_cohorts_only() {
    let (_, probe) =
        run_million("m_ef", 1_000_000, 64, 2, true, AggMode::Flat);
    assert!(probe.virtualized);
    assert_eq!(probe.resident_shard_structs, 0);
    // EF residuals allocate per *touched* client, never per K
    assert!(
        probe.ef_residuals > 0 && probe.ef_residuals <= 2 * 64,
        "ef_residuals = {}",
        probe.ef_residuals
    );
}

#[test]
fn exactly_zero_ef_residuals_are_evicted_on_write_back() {
    // With FP32 comm the encode/decode pair is the identity, so every
    // EF residual a client writes back is exactly zero. The server
    // must evict those entries rather than hoard one zero vector per
    // touched client — otherwise "memory grows with touched cohorts"
    // quietly becomes "memory grows forever" on long lossless runs.
    let (dir, manifest) = mock_manifest("m_evict");
    let engine = Engine::new(&dir).unwrap();
    let transport = MockTransport::new(false);
    let mut cfg = mock_cfg(1, true);
    cfg.clients = 1_000_000;
    cfg.participation = 64;
    cfg.rounds = 3;
    cfg.comm = Rounding::None;
    assert!(cfg.error_feedback, "EF must stay on for this test");
    let mut server = Server::with_transport(
        &engine,
        &manifest,
        cfg,
        Box::new(&transport),
    )
    .unwrap();
    for t in 0..3 {
        server.round(t).unwrap();
        let probe = server.client_state_probe();
        assert_eq!(
            probe.ef_residuals, 0,
            "round {t}: zero residuals were retained instead of evicted"
        );
    }
}

#[test]
fn million_client_tree_matches_flat() {
    let (flat, _) =
        run_million("m_flat", 1_000_000, 64, 2, false, AggMode::Flat);
    let (tree, probe) = run_million(
        "m_tree",
        1_000_000,
        64,
        2,
        false,
        AggMode::Tree { nodes: 8 },
    );
    assert!(probe.virtualized);
    assert_eq!(flat.w, tree.w);
    assert_eq!(flat.alpha, tree.alpha);
    assert_eq!(flat.beta, tree.beta);
    assert_eq!(flat.losses, tree.losses);
    assert_eq!(tree.comm.partial_msgs, 2 * 8);
}

#[test]
fn dense_worlds_stay_dense_below_the_threshold() {
    let (_, probe) =
        run_million("m_dense", 64, 16, 1, false, AggMode::Flat);
    assert!(!probe.virtualized);
    assert_eq!(probe.resident_shard_structs, 64);
    assert!(64 < VIRTUALIZE_AT);
}

/// Nightly-soak smoke (see .github/workflows/nightly-soak.yml): a
/// longer virtualized run with EF + tree, still O(cohort) resident.
#[test]
#[ignore]
fn million_client_virtualized_soak() {
    let (trace, probe) = run_million(
        "m_soak",
        1_000_000,
        256,
        8,
        true,
        AggMode::Tree { nodes: 16 },
    );
    assert!(probe.virtualized);
    assert_eq!(probe.resident_shard_structs, 0);
    assert!(probe.ef_residuals <= 8 * 256);
    assert_eq!(trace.comm.up_msgs, 8 * 256);
    for bits in &trace.losses {
        assert!(f32::from_bits(*bits).is_finite());
    }
}
