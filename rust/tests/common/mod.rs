//! Shared engine-free test harness: a tiny synthetic "mock" model
//! manifest, a deterministic mock client executor, and a full-run
//! trace, used by both the parallel-determinism suite
//! (`tests/parallel_determinism.rs`) and the networked-transport
//! suite (`tests/net_transport.rs`).
//!
//! The mock transport is a *pure function* of `(seed, round, client,
//! w_start)`, so it produces bit-identical outcomes no matter which
//! thread — or which **process** — runs it; uplink packing goes
//! through the same `finish_uplink` path as the real transport, so
//! wire behaviour is identical no matter where the local update ran.

// each test binary compiles this module independently and uses a
// different subset of it
#![allow(dead_code)]

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};
use fedfp8::config::ExperimentConfig;
use fedfp8::coordinator::client::LocalUpdate;
use fedfp8::coordinator::comm::CommStats;
use fedfp8::coordinator::transport::{
    finish_uplink, ClientJob, ClientOutcome, Transport, WorkBuffers,
};
use fedfp8::coordinator::Server;
use fedfp8::fp8::codec::Segment;
use fedfp8::fp8::rng::Pcg32;
use fedfp8::runtime::{Engine, Manifest, ModelInfo};

pub const DIM: usize = 24;

pub fn write_f32(path: &Path, vals: &[f32]) {
    let bytes: Vec<u8> =
        vals.iter().flat_map(|v| v.to_le_bytes()).collect();
    std::fs::write(path, bytes).unwrap();
}

/// Build an in-memory manifest for a tiny synthetic "mock" model plus
/// its init files on disk — no AOT artifacts involved.
pub fn mock_manifest(tag: &str) -> (PathBuf, Manifest) {
    let dir = std::env::temp_dir()
        .join(format!("fedfp8_mockman_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let w: Vec<f32> = (0..DIM).map(|i| i as f32 * 0.05 - 0.5).collect();
    write_f32(&dir.join("w.bin"), &w);
    write_f32(&dir.join("alpha.bin"), &[1.0]);
    write_f32(&dir.join("beta.bin"), &[2.0]);
    let segments = vec![
        Segment {
            name: "w".into(),
            offset: 0,
            size: 20,
            quantized: true,
            alpha_idx: Some(0),
        },
        Segment {
            name: "bias".into(),
            offset: 20,
            size: 4,
            quantized: false,
            alpha_idx: None,
        },
    ];
    let mut init = BTreeMap::new();
    init.insert("w".to_string(), "w.bin".to_string());
    init.insert("alpha".to_string(), "alpha.bin".to_string());
    init.insert("beta".to_string(), "beta.bin".to_string());
    let info = ModelInfo {
        name: "mock".into(),
        dim: DIM,
        alpha_dim: 1,
        n_act: 1,
        classes: 4,
        kind: "vision".into(),
        input_shape: vec![8, 8, 3],
        u_steps: 2,
        batch: 4,
        eval_batch: 8,
        server_p: 0,
        optimizer: "sgd".into(),
        segments,
        artifacts: BTreeMap::new(),
        init,
    };
    let mut models = BTreeMap::new();
    models.insert("mock".to_string(), info);
    let manifest = Manifest {
        dir: dir.clone(),
        models,
        quant_demo: None,
    };
    (dir, manifest)
}

pub fn mock_cfg(
    parallelism: usize,
    error_feedback: bool,
) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::base("mlp_c10")
        .unwrap()
        .with_method(if error_feedback { "bq_ef" } else { "uq" })
        .unwrap();
    cfg.model = "mock".into();
    cfg.name = format!("mock_par{parallelism}");
    cfg.clients = 6;
    cfg.participation = 4;
    cfg.rounds = 4;
    cfg.n_train = 96;
    cfg.n_test = 32;
    cfg.eval_every = 1000;
    cfg.seed = 11;
    cfg.parallelism = parallelism;
    cfg
}

/// Mock client executor: a deterministic pure-function "local update"
/// plus per-client sleep jitter so later cohort positions finish
/// *earlier* — stressing the reorder buffer. Uplink packing goes
/// through the same `finish_uplink` path as the real transport.
pub struct MockTransport {
    pub jitter: bool,
    /// When `Some(n)`: each client blocks (bounded) until `n` clients
    /// are in flight simultaneously — a deterministic concurrency
    /// detector that cannot false-negative on a slow scheduler.
    pub rendezvous: Option<usize>,
    pub fail_client: Option<usize>,
    pub active: AtomicUsize,
    pub max_active: AtomicUsize,
}

impl MockTransport {
    pub fn new(jitter: bool) -> MockTransport {
        MockTransport {
            jitter,
            rendezvous: None,
            fail_client: None,
            active: AtomicUsize::new(0),
            max_active: AtomicUsize::new(0),
        }
    }
}

impl Transport for MockTransport {
    fn run_client(
        &self,
        job: ClientJob<'_>,
        buffers: &mut WorkBuffers,
    ) -> Result<ClientOutcome> {
        let now = self.active.fetch_add(1, Ordering::SeqCst) + 1;
        self.max_active.fetch_max(now, Ordering::SeqCst);
        if self.jitter {
            // pseudo-random per-client delays so completion order
            // differs from cohort order, stressing the reorder buffer
            std::thread::sleep(Duration::from_millis(
                (job.client as u64 * 31 % 7) * 4,
            ));
        }
        if let Some(target) = self.rendezvous {
            // proceed once `target` clients are in flight at once; a
            // non-concurrent executor times out here and the caller's
            // max_active assert fails instead of the test hanging
            let deadline = Instant::now() + Duration::from_secs(10);
            while self.active.load(Ordering::SeqCst) < target
                && Instant::now() < deadline
            {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        self.active.fetch_sub(1, Ordering::SeqCst);
        if self.fail_client == Some(job.client) {
            bail!("injected failure for client {}", job.client);
        }
        let mut rng = Pcg32::derive(
            job.seed,
            job.round as u64,
            job.client as u64,
            0x4D4F_434B, // "MOCK"
        );
        let w: Vec<f32> = job
            .w_start
            .iter()
            .enumerate()
            .map(|(i, &w)| {
                0.8 * w
                    + 0.05 * rng.uniform()
                    + 0.002 * (job.client as f32 - i as f32 * 0.1)
            })
            .collect();
        let alpha: Vec<f32> = job
            .alpha_start
            .iter()
            .map(|a| a * (1.0 + 0.01 * job.client as f32))
            .collect();
        let upd = LocalUpdate {
            w,
            alpha,
            beta: job.beta_start.to_vec(),
            mean_loss: 1.0 / (job.client + 1) as f32,
        };
        Ok(finish_uplink(job, upd, buffers))
    }
}

/// Bit-exact summary of a full mock run (f32 state as raw bits).
#[derive(Debug, PartialEq, Eq)]
pub struct Trace {
    pub w: Vec<u32>,
    pub alpha: Vec<u32>,
    pub beta: Vec<u32>,
    pub comm: CommStats,
    pub losses: Vec<u32>,
}

impl Trace {
    pub fn capture(server: &Server<'_>, losses: Vec<u32>) -> Trace {
        let (w, a, b) = server.state();
        Trace {
            w: w.iter().map(|v| v.to_bits()).collect(),
            alpha: a.iter().map(|v| v.to_bits()).collect(),
            beta: b.iter().map(|v| v.to_bits()).collect(),
            comm: server.comm_stats(),
            losses,
        }
    }
}

/// Run the full mock experiment in-process and capture its trace.
pub fn run_mock(parallelism: usize, error_feedback: bool) -> Trace {
    run_mock_kernel(
        parallelism,
        error_feedback,
        fedfp8::fp8::simd::KernelKind::Auto,
    )
}

/// [`run_mock`] with an explicit `--fp8-kernel` choice — the knob is
/// a pure wall-clock lever, so every kernel must produce the same
/// bit-exact trace (the metric-fingerprint smoke test).
pub fn run_mock_kernel(
    parallelism: usize,
    error_feedback: bool,
    kernel: fedfp8::fp8::simd::KernelKind,
) -> Trace {
    let tag = format!("det_p{parallelism}_ef{error_feedback}_{kernel}");
    let mut cfg = mock_cfg(parallelism, error_feedback);
    cfg.fp8_kernel = kernel;
    run_mock_cfg(&tag, cfg)
}

/// [`run_mock`] with an explicit aggregation topology — `--agg
/// tree:G` is a pure topology lever, so every fan-out must produce
/// the same model trajectory as the flat stream.
pub fn run_mock_agg(
    parallelism: usize,
    error_feedback: bool,
    agg: fedfp8::config::AggMode,
) -> Trace {
    let tag = format!("agg_p{parallelism}_ef{error_feedback}_{agg}");
    let mut cfg = mock_cfg(parallelism, error_feedback);
    cfg.agg = agg;
    run_mock_cfg(&tag, cfg)
}

/// Run an arbitrary mock-model config to completion and capture its
/// bit-exact trace.
pub fn run_mock_cfg(tag: &str, cfg: ExperimentConfig) -> Trace {
    let (dir, manifest) = mock_manifest(tag);
    let engine = Engine::new(&dir).unwrap();
    let transport = MockTransport::new(true);
    let rounds = cfg.rounds;
    let mut server = Server::with_transport(
        &engine,
        &manifest,
        cfg,
        Box::new(&transport),
    )
    .unwrap();
    let mut losses = Vec::new();
    for t in 0..rounds {
        losses.push(server.round(t).unwrap().to_bits());
    }
    Trace::capture(&server, losses)
}
