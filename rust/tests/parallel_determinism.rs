//! Engine-free tests of the parallel client pipeline: the Transport
//! seam lets a mock client executor drive the *entire* round loop
//! (downlink codec, fan-out, streaming aggregation, error feedback,
//! comm accounting) with no AOT artifacts and no PJRT, so the
//! determinism contract is enforced on every machine:
//!
//!   same config + seed  =>  bit-identical weights, losses and byte
//!   counts for every `parallelism` value, despite out-of-order
//!   client completion.
//!
//! The real-engine twin of these tests (artifact-gated) lives in
//! tests/integration.rs; the cross-process twin (socket transport)
//! lives in tests/net_transport.rs. The shared mock harness is
//! tests/common/mod.rs.

mod common;

use std::sync::atomic::Ordering;

use common::{
    mock_cfg, mock_manifest, run_mock, run_mock_kernel, MockTransport,
};
use fedfp8::coordinator::transport::Transport;
use fedfp8::coordinator::Server;
use fedfp8::fp8::simd::KernelKind;
use fedfp8::runtime::Engine;

#[test]
fn parallelism_is_bit_invisible() {
    let base = run_mock(1, false);
    // sanity: the mock actually moves state round over round
    assert!(base.losses.windows(2).any(|w| w[0] != w[1]));
    assert!(base.comm.up_msgs == 16 && base.comm.down_msgs == 16);
    for par in [2usize, 4, 8] {
        let t = run_mock(par, false);
        assert_eq!(t.w, base.w, "weights diverged at parallelism {par}");
        assert_eq!(t.alpha, base.alpha, "alphas diverged at {par}");
        assert_eq!(t.beta, base.beta, "betas diverged at {par}");
        assert_eq!(t.comm, base.comm, "comm stats diverged at {par}");
        assert_eq!(t.losses, base.losses, "losses diverged at {par}");
    }
}

#[test]
fn parallelism_is_bit_invisible_with_error_feedback() {
    // error feedback adds per-client mutable residuals — the hardest
    // state to keep deterministic under concurrency (taken by the job,
    // written back on in-order delivery)
    let base = run_mock(1, true);
    let t = run_mock(4, true);
    assert_eq!(t.w, base.w);
    assert_eq!(t.alpha, base.alpha);
    assert_eq!(t.comm, base.comm);
    assert_eq!(t.losses, base.losses);
}

#[test]
fn fp8_kernel_knob_changes_no_metric_fingerprints() {
    // the smoke test behind wiring --fp8-kernel into the table1/
    // table2/fig2 drivers: the knob may only move wall-clock, so a
    // full experiment's bit-exact trace (weights, alphas, betas,
    // losses, byte counts) must be identical for every kernel choice,
    // sequential and parallel, with and without error feedback
    let base = run_mock_kernel(2, false, KernelKind::Scalar);
    for kernel in [KernelKind::Simd, KernelKind::Auto] {
        let t = run_mock_kernel(2, false, kernel);
        assert_eq!(
            t, base,
            "metric fingerprint moved under --fp8-kernel {kernel}"
        );
    }
    let base_ef = run_mock_kernel(4, true, KernelKind::Scalar);
    let t = run_mock_kernel(4, true, KernelKind::Simd);
    assert_eq!(
        t, base_ef,
        "EF metric fingerprint moved under --fp8-kernel simd"
    );
}

#[test]
fn cohort_of_four_executes_concurrently() {
    let (dir, manifest) = mock_manifest("conc");
    let engine = Engine::new(&dir).unwrap();
    let mut transport = MockTransport::new(false);
    transport.rendezvous = Some(4);
    let mut cfg = mock_cfg(4, false);
    cfg.clients = 4;
    cfg.participation = 4;
    let mut server = Server::with_transport(
        &engine,
        &manifest,
        cfg,
        Box::new(&transport),
    )
    .unwrap();
    server.round(0).unwrap();
    assert_eq!(
        transport.max_active.load(Ordering::SeqCst),
        4,
        "expected all 4 clients in flight at once"
    );
}

#[test]
fn client_failure_surfaces_from_parallel_round() {
    let (dir, manifest) = mock_manifest("fail");
    let engine = Engine::new(&dir).unwrap();
    let mut transport = MockTransport::new(true);
    transport.fail_client = Some(3);
    let mut cfg = mock_cfg(4, false);
    cfg.clients = 4; // participation 4 of 4: client 3 always sampled
    let mut server = Server::with_transport(
        &engine,
        &manifest,
        cfg,
        Box::new(&transport),
    )
    .unwrap();
    let err = server.round(0).unwrap_err();
    assert!(
        format!("{err:?}").contains("injected failure"),
        "unexpected error: {err:?}"
    );
}

#[test]
fn engine_and_transport_are_thread_shareable() {
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Engine>();
    assert_send_sync::<MockTransport>();
    fn assert_sync_obj(_: &(dyn Transport + '_)) {}
    let t = MockTransport::new(false);
    assert_sync_obj(&t);
}
