//! Engine-free tests of the parallel client pipeline: the Transport
//! seam lets a mock client executor drive the *entire* round loop
//! (downlink codec, fan-out, streaming aggregation, error feedback,
//! comm accounting) with no AOT artifacts and no PJRT, so the
//! determinism contract is enforced on every machine:
//!
//!   same config + seed  =>  bit-identical weights, losses and byte
//!   counts for every `parallelism` value, despite out-of-order
//!   client completion.
//!
//! The real-engine twin of these tests (artifact-gated) lives in
//! tests/integration.rs.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};
use fedfp8::config::ExperimentConfig;
use fedfp8::coordinator::client::LocalUpdate;
use fedfp8::coordinator::comm::CommStats;
use fedfp8::coordinator::transport::{
    finish_uplink, ClientJob, ClientOutcome, Transport, WorkBuffers,
};
use fedfp8::coordinator::Server;
use fedfp8::fp8::codec::Segment;
use fedfp8::fp8::rng::Pcg32;
use fedfp8::runtime::{Engine, Manifest, ModelInfo};

const DIM: usize = 24;

fn write_f32(path: &Path, vals: &[f32]) {
    let bytes: Vec<u8> =
        vals.iter().flat_map(|v| v.to_le_bytes()).collect();
    std::fs::write(path, bytes).unwrap();
}

/// Build an in-memory manifest for a tiny synthetic "mock" model plus
/// its init files on disk — no AOT artifacts involved.
fn mock_manifest(tag: &str) -> (PathBuf, Manifest) {
    let dir = std::env::temp_dir()
        .join(format!("fedfp8_mockman_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let w: Vec<f32> = (0..DIM).map(|i| i as f32 * 0.05 - 0.5).collect();
    write_f32(&dir.join("w.bin"), &w);
    write_f32(&dir.join("alpha.bin"), &[1.0]);
    write_f32(&dir.join("beta.bin"), &[2.0]);
    let segments = vec![
        Segment {
            name: "w".into(),
            offset: 0,
            size: 20,
            quantized: true,
            alpha_idx: Some(0),
        },
        Segment {
            name: "bias".into(),
            offset: 20,
            size: 4,
            quantized: false,
            alpha_idx: None,
        },
    ];
    let mut init = BTreeMap::new();
    init.insert("w".to_string(), "w.bin".to_string());
    init.insert("alpha".to_string(), "alpha.bin".to_string());
    init.insert("beta".to_string(), "beta.bin".to_string());
    let info = ModelInfo {
        name: "mock".into(),
        dim: DIM,
        alpha_dim: 1,
        n_act: 1,
        classes: 4,
        kind: "vision".into(),
        input_shape: vec![8, 8, 3],
        u_steps: 2,
        batch: 4,
        eval_batch: 8,
        server_p: 0,
        optimizer: "sgd".into(),
        segments,
        artifacts: BTreeMap::new(),
        init,
    };
    let mut models = BTreeMap::new();
    models.insert("mock".to_string(), info);
    let manifest = Manifest {
        dir: dir.clone(),
        models,
        quant_demo: None,
    };
    (dir, manifest)
}

fn mock_cfg(parallelism: usize, error_feedback: bool) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::base("mlp_c10")
        .unwrap()
        .with_method(if error_feedback { "bq_ef" } else { "uq" })
        .unwrap();
    cfg.model = "mock".into();
    cfg.name = format!("mock_par{parallelism}");
    cfg.clients = 6;
    cfg.participation = 4;
    cfg.rounds = 4;
    cfg.n_train = 96;
    cfg.n_test = 32;
    cfg.eval_every = 1000;
    cfg.seed = 11;
    cfg.parallelism = parallelism;
    cfg
}

/// Mock client executor: a deterministic pure-function "local update"
/// plus per-client sleep jitter so later cohort positions finish
/// *earlier* — stressing the reorder buffer. Uplink packing goes
/// through the same `finish_uplink` path as the real transport.
struct MockTransport {
    jitter: bool,
    /// When `Some(n)`: each client blocks (bounded) until `n` clients
    /// are in flight simultaneously — a deterministic concurrency
    /// detector that cannot false-negative on a slow scheduler.
    rendezvous: Option<usize>,
    fail_client: Option<usize>,
    active: AtomicUsize,
    max_active: AtomicUsize,
}

impl MockTransport {
    fn new(jitter: bool) -> MockTransport {
        MockTransport {
            jitter,
            rendezvous: None,
            fail_client: None,
            active: AtomicUsize::new(0),
            max_active: AtomicUsize::new(0),
        }
    }
}

impl Transport for MockTransport {
    fn run_client(
        &self,
        job: ClientJob<'_>,
        buffers: &mut WorkBuffers,
    ) -> Result<ClientOutcome> {
        let now = self.active.fetch_add(1, Ordering::SeqCst) + 1;
        self.max_active.fetch_max(now, Ordering::SeqCst);
        if self.jitter {
            // pseudo-random per-client delays so completion order
            // differs from cohort order, stressing the reorder buffer
            std::thread::sleep(Duration::from_millis(
                (job.client as u64 * 31 % 7) * 4,
            ));
        }
        if let Some(target) = self.rendezvous {
            // proceed once `target` clients are in flight at once; a
            // non-concurrent executor times out here and the caller's
            // max_active assert fails instead of the test hanging
            let deadline = Instant::now() + Duration::from_secs(10);
            while self.active.load(Ordering::SeqCst) < target
                && Instant::now() < deadline
            {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        self.active.fetch_sub(1, Ordering::SeqCst);
        if self.fail_client == Some(job.client) {
            bail!("injected failure for client {}", job.client);
        }
        let mut rng = Pcg32::derive(
            job.seed,
            job.round as u64,
            job.client as u64,
            0x4D4F_434B, // "MOCK"
        );
        let w: Vec<f32> = job
            .w_start
            .iter()
            .enumerate()
            .map(|(i, &w)| {
                0.8 * w
                    + 0.05 * rng.uniform()
                    + 0.002 * (job.client as f32 - i as f32 * 0.1)
            })
            .collect();
        let alpha: Vec<f32> = job
            .alpha_start
            .iter()
            .map(|a| a * (1.0 + 0.01 * job.client as f32))
            .collect();
        let upd = LocalUpdate {
            w,
            alpha,
            beta: job.beta_start.to_vec(),
            mean_loss: 1.0 / (job.client + 1) as f32,
        };
        Ok(finish_uplink(job, upd, buffers))
    }
}

struct Trace {
    w: Vec<u32>,
    alpha: Vec<u32>,
    beta: Vec<u32>,
    comm: CommStats,
    losses: Vec<u32>,
}

fn run_mock(parallelism: usize, error_feedback: bool) -> Trace {
    let tag = format!("det_p{parallelism}_ef{error_feedback}");
    let (dir, manifest) = mock_manifest(&tag);
    let engine = Engine::new(&dir).unwrap();
    let transport = MockTransport::new(true);
    let cfg = mock_cfg(parallelism, error_feedback);
    let rounds = cfg.rounds;
    let mut server = Server::with_transport(
        &engine,
        &manifest,
        cfg,
        Box::new(&transport),
    )
    .unwrap();
    let mut losses = Vec::new();
    for t in 0..rounds {
        losses.push(server.round(t).unwrap().to_bits());
    }
    let (w, a, b) = server.state();
    Trace {
        w: w.iter().map(|v| v.to_bits()).collect(),
        alpha: a.iter().map(|v| v.to_bits()).collect(),
        beta: b.iter().map(|v| v.to_bits()).collect(),
        comm: server.comm_stats(),
        losses,
    }
}

#[test]
fn parallelism_is_bit_invisible() {
    let base = run_mock(1, false);
    // sanity: the mock actually moves state round over round
    assert!(base.losses.windows(2).any(|w| w[0] != w[1]));
    assert!(base.comm.up_msgs == 16 && base.comm.down_msgs == 16);
    for par in [2usize, 4, 8] {
        let t = run_mock(par, false);
        assert_eq!(t.w, base.w, "weights diverged at parallelism {par}");
        assert_eq!(t.alpha, base.alpha, "alphas diverged at {par}");
        assert_eq!(t.beta, base.beta, "betas diverged at {par}");
        assert_eq!(t.comm, base.comm, "comm stats diverged at {par}");
        assert_eq!(t.losses, base.losses, "losses diverged at {par}");
    }
}

#[test]
fn parallelism_is_bit_invisible_with_error_feedback() {
    // error feedback adds per-client mutable residuals — the hardest
    // state to keep deterministic under concurrency (taken by the job,
    // written back on in-order delivery)
    let base = run_mock(1, true);
    let t = run_mock(4, true);
    assert_eq!(t.w, base.w);
    assert_eq!(t.alpha, base.alpha);
    assert_eq!(t.comm, base.comm);
    assert_eq!(t.losses, base.losses);
}

#[test]
fn cohort_of_four_executes_concurrently() {
    let (dir, manifest) = mock_manifest("conc");
    let engine = Engine::new(&dir).unwrap();
    let mut transport = MockTransport::new(false);
    transport.rendezvous = Some(4);
    let mut cfg = mock_cfg(4, false);
    cfg.clients = 4;
    cfg.participation = 4;
    let mut server = Server::with_transport(
        &engine,
        &manifest,
        cfg,
        Box::new(&transport),
    )
    .unwrap();
    server.round(0).unwrap();
    assert_eq!(
        transport.max_active.load(Ordering::SeqCst),
        4,
        "expected all 4 clients in flight at once"
    );
}

#[test]
fn client_failure_surfaces_from_parallel_round() {
    let (dir, manifest) = mock_manifest("fail");
    let engine = Engine::new(&dir).unwrap();
    let mut transport = MockTransport::new(true);
    transport.fail_client = Some(3);
    let mut cfg = mock_cfg(4, false);
    cfg.clients = 4; // participation 4 of 4: client 3 always sampled
    let mut server = Server::with_transport(
        &engine,
        &manifest,
        cfg,
        Box::new(&transport),
    )
    .unwrap();
    let err = server.round(0).unwrap_err();
    assert!(
        format!("{err:?}").contains("injected failure"),
        "unexpected error: {err:?}"
    );
}

#[test]
fn engine_and_transport_are_thread_shareable() {
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Engine>();
    assert_send_sync::<MockTransport>();
    fn assert_sync_obj(_: &(dyn Transport + '_)) {}
    let t = MockTransport::new(false);
    assert_sync_obj(&t);
}
