//! Exhaustive differential conformance for the FP8 kernel layer: every
//! kernel (`fp8::simd`) must produce **bit-identical** encode and
//! quantize results to the scalar oracle (`Fp8Params`), for every f32
//! bit pattern, across a grid of alphas and rounding draws — NaN
//! payloads, ±0, ±inf, f32 subnormals, the FP8 subnormal band,
//! saturation and the mantissa-carry boundaries included.
//!
//! Two tiers:
//!
//! * [`stratified_conformance_subset`] — runs in the default
//!   `cargo test` (tier-1) and in an explicit CI step: ~2M
//!   (pattern, alpha, draw) triples covering all 256 f32 exponents ×
//!   both signs × spread + derived mantissas, canonical NaN payloads,
//!   and ±4-ulp neighborhoods of every FP8 grid magnitude per alpha.
//! * [`exhaustive_all_f32_patterns`] — `#[ignore]`d: ALL 2^32 bit
//!   patterns. Chunked via `FEDFP8_EXHAUSTIVE_CHUNKS="i/n"` (run
//!   chunk i of n) or `"all"` (default); nightly CI runs the full
//!   sweep as an 8-way chunk matrix in `--release --features simd`.
//!   Locally: `FEDFP8_EXHAUSTIVE_CHUNKS=0/256 cargo test --release \
//!   --test exhaustive_fp8 -- --ignored` for a quick slice.
//!
//! `tools/fp8_kernel_conformance.c` is the out-of-tree C twin of this
//! harness (same sweep shape, same alphas), used to pre-validate the
//! kernel algorithms over the full 2^32 space.

use std::thread;

use fedfp8::fp8::format::Fp8Params;
use fedfp8::fp8::simd::{
    BranchfreeKernel, Draws, Fp8Kernel, KernelKind, ScalarKernel,
};

/// Sweep alphas: a power of two (exact bias), the canonical 1.0, a
/// "generic" irrational-bias value, and a large one (mirrors the C
/// harness).
const ALPHAS: [f32; 4] = [1.0, 0.0625, 3.7, 117.0];

const BATCH: usize = 1024;

fn splitmix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Pattern-derived pseudo-random rounding draw in [0, 1).
fn derived_u(bits: u64) -> f64 {
    (splitmix(bits) >> 11) as f64 * (1.0 / 9_007_199_254_740_992.0)
}

/// The non-oracle kernels to differentiate: always the portable
/// branch-free kernel, plus whatever `simd`/`auto` resolve to when
/// that differs (the AVX2 kernel under `--features simd` on an AVX2
/// host). Deduped by name; the scalar oracle itself is excluded.
fn kernels_under_test() -> Vec<&'static dyn Fp8Kernel> {
    let mut v: Vec<&'static dyn Fp8Kernel> = vec![&BranchfreeKernel];
    for kind in [KernelKind::Simd, KernelKind::Auto] {
        let k = kind.resolve();
        if k.name() != "scalar"
            && v.iter().all(|e| e.name() != k.name())
        {
            v.push(k);
        }
    }
    v
}

/// Differentially check one batch of patterns against the oracle for
/// every (alpha, draw mode, kernel); returns the triple count.
/// Panics with full context on the first divergence.
fn check_batch(
    params: &[Fp8Params],
    kernels: &[&'static dyn Fp8Kernel],
    xs: &[f32],
    us: &[f64],
) -> u64 {
    let n = xs.len();
    let mut ref_codes = vec![0u8; n];
    let mut ref_quant = vec![0.0f32; n];
    let mut got_codes = vec![0u8; n];
    let mut got_quant = vec![0.0f32; n];
    let mut triples = 0u64;
    for p in params {
        for draws in [Draws::Const(0.5), Draws::Slice(us)] {
            ScalarKernel.encode_slice(p, xs, draws, &mut ref_codes);
            ref_quant.copy_from_slice(xs);
            ScalarKernel.quantize_slice(p, &mut ref_quant, draws);
            for k in kernels {
                k.encode_slice(p, xs, draws, &mut got_codes);
                got_quant.copy_from_slice(xs);
                k.quantize_slice(p, &mut got_quant, draws);
                for i in 0..n {
                    let q_ok = got_quant[i].to_bits()
                        == ref_quant[i].to_bits();
                    if got_codes[i] != ref_codes[i] || !q_ok {
                        let u = match draws {
                            Draws::Const(c) => c,
                            Draws::Slice(s) => s[i],
                        };
                        panic!(
                            "kernel '{}' diverged from the scalar \
                             oracle: x={:#010x} ({}) alpha={} u={u} \
                             encode {:#04x} vs {:#04x}, quantize \
                             {:#010x} vs {:#010x}",
                            k.name(),
                            xs[i].to_bits(),
                            xs[i],
                            p.alpha,
                            got_codes[i],
                            ref_codes[i],
                            got_quant[i].to_bits(),
                            ref_quant[i].to_bits(),
                        );
                    }
                }
            }
            triples += n as u64;
        }
    }
    triples
}

/// Check every pattern in `[lo, hi)` (u64 bounds so `hi` may be
/// 2^32), fanned over the available cores.
fn check_pattern_range(lo: u64, hi: u64) -> u64 {
    let params: Vec<Fp8Params> =
        ALPHAS.iter().map(|&a| Fp8Params::new(a)).collect();
    let kernels = kernels_under_test();
    let workers = thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(16) as u64;
    let span = (hi - lo).div_ceil(workers).div_ceil(BATCH as u64)
        * BATCH as u64;
    thread::scope(|s| {
        let mut handles = Vec::new();
        for w in 0..workers {
            let (params, kernels) = (&params, &kernels);
            let t_lo = lo + w * span;
            let t_hi = (t_lo + span).min(hi);
            handles.push(s.spawn(move || {
                let mut xs = vec![0.0f32; BATCH];
                let mut us = vec![0.0f64; BATCH];
                let mut triples = 0u64;
                let mut base = t_lo;
                while base < t_hi {
                    let n = ((t_hi - base) as usize).min(BATCH);
                    for i in 0..n {
                        let bits = base + i as u64;
                        xs[i] = f32::from_bits(bits as u32);
                        us[i] = derived_u(bits);
                    }
                    triples += check_batch(
                        params,
                        kernels,
                        &xs[..n],
                        &us[..n],
                    );
                    base += n as u64;
                }
                triples
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    })
}

/// Stratified pattern set for one alpha (shared strata + per-alpha
/// grid-boundary neighborhoods), padded to `budget` with
/// deterministic pseudo-random patterns.
fn stratified_patterns(p: &Fp8Params, budget: usize) -> Vec<u32> {
    let mut v: Vec<u32> = Vec::with_capacity(budget);
    // all 256 exponents x both signs x (32 spread + 32 derived)
    // mantissas — covers ±0, ±inf, f32 subnormals and NaN payloads
    // (exponent 255 with nonzero mantissa) structurally
    for exp in 0..=255u32 {
        for sign in [0u32, 0x8000_0000] {
            for m in 0..32u32 {
                v.push(sign | (exp << 23) | (m * 0x3_FFFF));
            }
            for m in 0..32u32 {
                let mant = splitmix((exp * 64 + m) as u64) as u32
                    & 0x007F_FFFF;
                v.push(sign | (exp << 23) | mant);
            }
        }
    }
    // canonical quiet/signalling NaN payloads
    v.extend([0x7FC0_0000, 0xFFC0_0000, 0x7F80_0001, 0x7FFF_FFFF]);
    // ±4-ulp neighborhood of every FP8 grid magnitude for this alpha
    // (subnormal band, mantissa-carry boundaries, and ±alpha
    // saturation all live here)
    for code in 0u8..=0x7F {
        let b = p.decode(code).to_bits();
        for d in -4i64..=4 {
            let nb = b.wrapping_add(d as u32);
            v.push(nb);
            v.push(nb ^ 0x8000_0000);
        }
    }
    let mut i = 0u64;
    while v.len() < budget {
        v.push(splitmix(0xF8F8_0000 + i) as u32);
        i += 1;
    }
    v
}

/// Tier-1 conformance: ~2M (pattern, alpha, draw) triples. Runs in
/// the default `cargo test`; CI additionally invokes this test by
/// name so a filter can never silently skip it.
#[test]
fn stratified_conformance_subset() {
    const BUDGET: usize = 250_000;
    let kernels = kernels_under_test();
    let params: Vec<Fp8Params> =
        ALPHAS.iter().map(|&a| Fp8Params::new(a)).collect();
    let total: u64 = thread::scope(|s| {
        let mut handles = Vec::new();
        for p in &params {
            let kernels = &kernels;
            handles.push(s.spawn(move || {
                let patterns = stratified_patterns(p, BUDGET);
                let one = [*p];
                let mut triples = 0u64;
                let mut xs = vec![0.0f32; BATCH];
                let mut us = vec![0.0f64; BATCH];
                for chunk in patterns.chunks(BATCH) {
                    for (i, &b) in chunk.iter().enumerate() {
                        xs[i] = f32::from_bits(b);
                        us[i] = derived_u(b as u64);
                    }
                    triples += check_batch(
                        &one,
                        kernels,
                        &xs[..chunk.len()],
                        &us[..chunk.len()],
                    );
                }
                triples
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    });
    // ~2M: 4 alphas x 250k patterns x 2 draw modes
    assert!(
        total >= 2_000_000,
        "stratified subset shrank to {total} triples — the ~2M \
         conformance floor is part of the tier-1 contract"
    );
}

/// The full sweep: every f32 bit pattern. `#[ignore]`d by default —
/// run explicitly (nightly CI, or locally in `--release`) with
/// `FEDFP8_EXHAUSTIVE_CHUNKS="i/n"` to cover chunk i of n, or
/// `"all"`.
#[test]
#[ignore = "full 2^32 sweep: run via FEDFP8_EXHAUSTIVE_CHUNKS (nightly CI)"]
fn exhaustive_all_f32_patterns() {
    let spec = std::env::var("FEDFP8_EXHAUSTIVE_CHUNKS")
        .unwrap_or_else(|_| "all".to_string());
    let (lo, hi) = if spec == "all" {
        (0u64, 1u64 << 32)
    } else {
        let (i, n) = spec
            .split_once('/')
            .expect("FEDFP8_EXHAUSTIVE_CHUNKS must be \"i/n\" or \"all\"");
        let i: u64 = i.parse().expect("chunk index");
        let n: u64 = n.parse().expect("chunk count");
        assert!(n > 0 && i < n, "chunk {i}/{n} out of range");
        let span = (1u64 << 32).div_ceil(n);
        (i * span, ((i + 1) * span).min(1u64 << 32))
    };
    let triples = check_pattern_range(lo, hi);
    let expect = (hi - lo) * ALPHAS.len() as u64 * 2;
    assert_eq!(
        triples, expect,
        "sweep [{lo}, {hi}) checked {triples} triples, expected {expect}"
    );
    eprintln!(
        "exhaustive sweep [{lo}, {hi}): {triples} triples bit-identical"
    );
}
