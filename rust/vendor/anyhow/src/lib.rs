//! Offline shim of the `anyhow` 1.x API surface used by `fedfp8`.
//!
//! The build environment has no crates.io access, so this path
//! dependency provides the exact subset the crate relies on —
//! [`Error`], [`Result`], the [`Context`] extension trait (for both
//! `Result` and `Option`), and the `anyhow!` / `bail!` / `ensure!`
//! macros. Error values keep a human-readable cause chain (rendered by
//! `{:?}` like anyhow's "Caused by:" report) but drop downcasting and
//! backtraces, which nothing here uses. Replacing this with the real
//! `anyhow = "1"` is a one-line Cargo.toml change.

use std::fmt;

/// A chain of error messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost first (shim-only helper).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut src = err.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn ensure_and_bail() {
        assert_eq!(fails(true).unwrap(), 7);
        let e = fails(false).unwrap_err();
        assert_eq!(e.to_string(), "flag was false");
    }

    #[test]
    fn context_chains_render() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:") && dbg.contains("inner"));
    }

    #[test]
    fn io_error_converts_with_sources() {
        fn read() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/file")?)
        }
        assert!(read().is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3u32).with_context(|| "unused").unwrap(), 3);
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
