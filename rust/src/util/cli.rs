//! Tiny CLI argument parser (offline substrate; no clap).
//!
//! Supports `--key value`, `--key=value`, `--flag`, and positional
//! arguments; every experiment binary and the main launcher build on
//! this.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut a = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    a.options.insert(rest.to_string(), v);
                } else {
                    a.flags.push(rest.to_string());
                }
            } else {
                a.positional.push(tok);
            }
        }
        a
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// An option that must be present in context `why` (e.g. a flag
    /// implied by the chosen subcommand/role).
    pub fn required(&self, name: &str, why: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| anyhow!("{why} requires --{name}"))
    }

    pub fn parse_or<T: std::str::FromStr>(
        &self,
        name: &str,
        default: T,
    ) -> Result<T> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("invalid value for --{name}: {v}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_mixed() {
        // NB: a bare `--flag` followed by a non-dashed token binds as
        // an option (`--flag value`) — put positionals first or use
        // `--flag=true`, like clap's greedy value binding.
        let a = args("run pos1 --rounds 30 --model=lenet_c10 --verbose");
        assert_eq!(a.positional, vec!["run", "pos1"]);
        assert_eq!(a.get("rounds"), Some("30"));
        assert_eq!(a.get("model"), Some("lenet_c10"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn typed_defaults() {
        let a = args("--rounds 25");
        assert_eq!(a.parse_or("rounds", 10usize).unwrap(), 25);
        assert_eq!(a.parse_or("seed", 7u64).unwrap(), 7);
        assert!(args("--rounds x").parse_or("rounds", 1usize).is_err());
    }

    #[test]
    fn required_reports_context() {
        let a = args("--listen 127.0.0.1:7878");
        assert_eq!(
            a.required("listen", "--role server").unwrap(),
            "127.0.0.1:7878"
        );
        let e = a.required("connect", "--role worker").unwrap_err();
        assert!(e.to_string().contains("--connect"), "{e}");
    }

    #[test]
    fn flag_before_flag() {
        let a = args("--a --b v");
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("v"));
    }

    #[test]
    fn shard_specs_bind_as_values() {
        // `--shard 1/4` specs and `host:port` addresses contain no
        // leading dashes, so they must bind as the preceding option's
        // value — the aggregator role's flags depend on this
        let a = args(
            "run --role aggregator --shard 1/4 \
             --connect 127.0.0.1:7878 --listen 127.0.0.1:7879",
        );
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get("shard"), Some("1/4"));
        assert_eq!(a.get("connect"), Some("127.0.0.1:7878"));
        assert_eq!(a.get("listen"), Some("127.0.0.1:7879"));
    }
}
