//! Offline substrates: JSON, CLI, bench harness, property testing.
//!
//! This environment has no network access to crates.io; everything a
//! production launcher would normally pull in (serde_json, clap,
//! criterion, proptest) is implemented here from scratch — see
//! DESIGN.md §Substitutions.

pub mod bench;
pub mod cli;
pub mod json;
pub mod proptest;
