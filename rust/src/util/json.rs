//! Minimal JSON parser/serializer (offline substrate; no serde_json).
//!
//! Supports the full JSON grammar needed by `manifest.json` and
//! `golden_fp8.json`: objects, arrays, strings (with escapes), numbers,
//! booleans, null. Numbers are stored as f64 (plenty for segment
//! offsets < 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => {
                m.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
            }
            _ => bail!("not an object (key '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key).filter(|v| !matches!(v, Json::Null)),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?
            .iter()
            .map(|v| Ok(v.as_f64()? as f32))
            .collect()
    }

    pub fn f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    // ---- serializer -------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']', got '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("bad \\u"))?,
                            );
                        }
                        _ => bail!("bad escape"),
                    }
                }
                c => {
                    // byte-wise UTF-8 passthrough
                    let start = self.i - 1;
                    let len = match c {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(
                        &self.b[start..self.i],
                    )?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "x\"y", "c": true,
                      "d": null, "e": {}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 42, "s": "hi", "a": [1,2]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), 42);
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "hi");
        assert_eq!(v.get("a").unwrap().f32_vec().unwrap(), vec![1.0, 2.0]);
        assert!(v.get("zzz").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ☕");
    }

    #[test]
    fn deep_nesting() {
        let v = Json::parse("[[[[[1]]]]]").unwrap();
        let mut cur = &v;
        for _ in 0..5 {
            cur = &cur.as_arr().unwrap()[0];
        }
        assert_eq!(cur.as_f64().unwrap(), 1.0);
    }
}
