//! Seeded property-testing helper (offline substrate; no proptest crate).
//!
//! `forall` drives a closure over many generated cases from a
//! deterministic RNG; on failure it reports the failing case index and
//! seed so the case replays exactly. No shrinking — cases are kept
//! small instead.

use crate::fp8::rng::Pcg32;

pub struct Gen {
    pub rng: Pcg32,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.uniform() * (hi - lo)
    }

    /// Log-uniform positive float (good for alpha / scale parameters).
    pub fn f32_log(&mut self, lo: f32, hi: f32) -> f32 {
        (self.f32_in(lo.ln(), hi.ln())).exp()
    }

    pub fn vec_f32(&mut self, n: usize, scale: f32) -> Vec<f32> {
        let mut cache = None;
        (0..n).map(|_| self.rng.normal(&mut cache) * scale).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }
}

/// Run `cases` property checks; the closure returns `Err(msg)` on
/// violation. Panics with seed + case number for replay.
pub fn forall<F>(name: &str, seed: u64, cases: usize, mut f: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let mut g = Gen {
            rng: Pcg32::new(seed, case as u64),
        };
        if let Err(msg) = f(&mut g) {
            panic!(
                "property '{name}' failed at case {case} \
                 (replay: seed={seed}, stream={case}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial() {
        forall("trivial", 1, 50, |g| {
            let v = g.f32_in(0.0, 1.0);
            if (0.0..=1.0).contains(&v) {
                Ok(())
            } else {
                Err(format!("{v}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn forall_reports_failure() {
        forall("fails", 1, 10, |g| {
            let n = g.usize_in(0, 5);
            if n < 5 {
                Ok(())
            } else {
                Err("hit 5".into())
            }
        });
    }

    #[test]
    fn log_uniform_in_range() {
        forall("log-range", 2, 100, |g| {
            let v = g.f32_log(0.01, 100.0);
            if (0.0099..=101.0).contains(&v) {
                Ok(())
            } else {
                Err(format!("{v}"))
            }
        });
    }
}
