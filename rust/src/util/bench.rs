//! Micro-benchmark harness (offline substrate; no criterion).
//!
//! Measures wall time with warmup + repeated timed batches, reporting
//! median / p10 / p90 per-iteration latency and derived throughput.
//! Used by `rust/benches/*` (registered with `harness = false`).
//! [`BenchJson`] serializes a bench run (config, per-kernel results,
//! derived speedups) into the repo's `BENCH_*.json` perf trajectory —
//! the schema is documented in ARCHITECTURE.md §Kernel hot paths.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "{:<44} {:>12} {:>12} {:>12}",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.p10_ns),
            fmt_ns(self.p90_ns)
        );
    }

    /// items/second at the median latency.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.median_ns * 1e-9)
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

pub fn header() {
    println!(
        "{:<44} {:>12} {:>12} {:>12}",
        "benchmark", "median", "p10", "p90"
    );
    println!("{}", "-".repeat(84));
}

/// Run `f` repeatedly for ~`budget_ms` after a short warmup; one sample
/// per call. Suitable for ops in the microsecond-to-second range.
pub fn bench<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> BenchResult {
    // warmup
    let warm_until = Instant::now() + std::time::Duration::from_millis(
        (budget_ms / 5).max(10),
    );
    while Instant::now() < warm_until {
        f();
    }
    let mut samples: Vec<f64> = Vec::new();
    let run_until =
        Instant::now() + std::time::Duration::from_millis(budget_ms);
    while Instant::now() < run_until || samples.len() < 5 {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
        if samples.len() >= 100_000 {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    let r = BenchResult {
        name: name.to_string(),
        iters: samples.len() as u64,
        median_ns: q(0.5),
        p10_ns: q(0.1),
        p90_ns: q(0.9),
    };
    r.report();
    r
}

/// Collects one bench binary's run into a `BENCH_*.json` document —
/// the machine-readable perf trajectory the ROADMAP's "measurably
/// faster" mandate is checked against.
#[derive(Debug, Default, Clone)]
pub struct BenchJson {
    bench: String,
    provenance: String,
    config: Vec<(String, String)>,
    /// (result, items/iter for throughput derivation — None = latency
    /// only).
    results: Vec<(BenchResult, Option<f64>)>,
    speedups: Vec<(String, f64)>,
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".into()
    }
}

impl BenchJson {
    pub fn new(bench: &str, provenance: &str) -> BenchJson {
        BenchJson {
            bench: bench.to_string(),
            provenance: provenance.to_string(),
            ..BenchJson::default()
        }
    }

    pub fn config(&mut self, key: &str, value: impl std::fmt::Display) {
        self.config.push((key.to_string(), value.to_string()));
    }

    pub fn push(&mut self, r: &BenchResult, items_per_iter: Option<f64>) {
        self.results.push((r.clone(), items_per_iter));
    }

    /// Record a derived before/after ratio (>1 = the "after" is faster).
    pub fn speedup(&mut self, name: &str, ratio: f64) {
        self.speedups.push((name.to_string(), ratio));
    }

    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"bench\": \"{}\",\n", esc(&self.bench)));
        s.push_str(&format!(
            "  \"provenance\": \"{}\",\n",
            esc(&self.provenance)
        ));
        s.push_str("  \"config\": {");
        for (i, (k, v)) in self.config.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("\n    \"{}\": \"{}\"", esc(k), esc(v)));
        }
        s.push_str("\n  },\n  \"results\": [");
        for (i, (r, items)) in self.results.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "\n    {{\"name\": \"{}\", \"iters\": {}, \
                 \"median_ns\": {}, \"p10_ns\": {}, \"p90_ns\": {}",
                esc(&r.name),
                r.iters,
                num(r.median_ns),
                num(r.p10_ns),
                num(r.p90_ns)
            ));
            if let Some(it) = items {
                s.push_str(&format!(
                    ", \"throughput_per_s\": {}",
                    num(r.throughput(*it))
                ));
            }
            s.push('}');
        }
        s.push_str("\n  ],\n  \"speedups\": {");
        for (i, (k, v)) in self.speedups.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("\n    \"{}\": {}", esc(k), num(*v)));
        }
        s.push_str("\n  }\n}\n");
        s
    }

    pub fn write(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produces_sane_stats() {
        let r = bench("noop-spin", 30, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.iters >= 5);
        assert!(r.p10_ns <= r.median_ns && r.median_ns <= r.p90_ns);
    }

    #[test]
    fn formats() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert!(fmt_ns(2_500.0).contains("µs"));
        assert!(fmt_ns(2_500_000.0).contains("ms"));
    }

    #[test]
    fn bench_json_is_parseable() {
        let mut j = BenchJson::new("unit", "test \"quoted\"");
        j.config("dim", 100);
        j.push(
            &BenchResult {
                name: "a/b".into(),
                iters: 7,
                median_ns: 1000.0,
                p10_ns: 900.0,
                p90_ns: 1100.0,
            },
            Some(100.0),
        );
        j.speedup("x_over_y", 5.25);
        let parsed = crate::util::json::Json::parse(&j.to_json())
            .expect("emitted JSON parses");
        assert_eq!(
            parsed.get("bench").unwrap().as_str().unwrap(),
            "unit"
        );
        let results = parsed.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(
            results[0].get("iters").unwrap().as_usize().unwrap(),
            7
        );
        let sp = parsed.get("speedups").unwrap();
        assert!(
            (sp.get("x_over_y").unwrap().as_f64().unwrap() - 5.25)
                .abs()
                < 1e-12
        );
    }
}
