//! Micro-benchmark harness (offline substrate; no criterion).
//!
//! Measures wall time with warmup + repeated timed batches, reporting
//! median / p10 / p90 per-iteration latency and derived throughput.
//! Used by `rust/benches/*` (registered with `harness = false`).

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "{:<44} {:>12} {:>12} {:>12}",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.p10_ns),
            fmt_ns(self.p90_ns)
        );
    }

    /// items/second at the median latency.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.median_ns * 1e-9)
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

pub fn header() {
    println!(
        "{:<44} {:>12} {:>12} {:>12}",
        "benchmark", "median", "p10", "p90"
    );
    println!("{}", "-".repeat(84));
}

/// Run `f` repeatedly for ~`budget_ms` after a short warmup; one sample
/// per call. Suitable for ops in the microsecond-to-second range.
pub fn bench<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> BenchResult {
    // warmup
    let warm_until = Instant::now() + std::time::Duration::from_millis(
        (budget_ms / 5).max(10),
    );
    while Instant::now() < warm_until {
        f();
    }
    let mut samples: Vec<f64> = Vec::new();
    let run_until =
        Instant::now() + std::time::Duration::from_millis(budget_ms);
    while Instant::now() < run_until || samples.len() < 5 {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
        if samples.len() >= 100_000 {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    let r = BenchResult {
        name: name.to_string(),
        iters: samples.len() as u64,
        median_ns: q(0.5),
        p10_ns: q(0.1),
        p90_ns: q(0.9),
    };
    r.report();
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produces_sane_stats() {
        let r = bench("noop-spin", 30, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.iters >= 5);
        assert!(r.p10_ns <= r.median_ns && r.median_ns <= r.p90_ns);
    }

    #[test]
    fn formats() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert!(fmt_ns(2_500.0).contains("µs"));
        assert!(fmt_ns(2_500_000.0).contains("ms"));
    }
}
