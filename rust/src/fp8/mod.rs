//! FP8 number format, wire codec, kernel layer and deterministic RNG
//! substrate.

pub mod codec;
pub mod format;
pub mod rng;
pub mod simd;

pub use codec::{
    DecodeLutCache, Rounding, Segment, SegmentStats, WirePayload,
};
pub use format::Fp8Params;
pub use rng::{Pcg32, SplitMix64};
pub use simd::{Draws, Fp8Kernel, KernelKind};
