//! Deterministic RNG substrate (no external `rand` crate offline).
//!
//! `SplitMix64` seeds, `Pcg32` generates. Every stochastic decision in
//! the coordinator (client sampling, data synthesis, stochastic
//! rounding draws) flows through these so whole experiments replay
//! bit-identically from a single seed.

/// SplitMix64 — used for seeding / key derivation.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR 64/32) — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

/// SplitMix64 finalizer — mixes one word into a running hash. Used by
/// [`Pcg32::derive`] to turn structured coordinates into seed material.
#[inline]
fn mix(h: u64, v: u64) -> u64 {
    let mut z = (h ^ v).wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ stream.rotate_left(17));
        let mut rng = Self {
            state: sm.next_u64(),
            inc: sm.next_u64() | 1,
        };
        rng.next_u32();
        rng
    }

    /// Derive an independent stream (for per-client / per-round RNGs).
    pub fn fork(&mut self, tag: u64) -> Pcg32 {
        Pcg32::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15), tag)
    }

    /// Counter-derived stream for `(round, client, domain)` under one
    /// experiment seed — the determinism substrate of the parallel
    /// client pipeline. Unlike [`Pcg32::fork`], this is a *pure
    /// function* of its coordinates: no shared generator state is
    /// consumed, so any number of worker threads can derive their
    /// streams in any order (or concurrently) and produce bit-identical
    /// draws. `domain` separates uses that share coordinates (data
    /// sampling vs. uplink quantization vs. downlink encoding).
    pub fn derive(seed: u64, round: u64, client: u64, domain: u64) -> Pcg32 {
        let h = mix(mix(mix(seed, domain), round), client);
        Pcg32::new(h, domain ^ client.rotate_left(32) ^ round)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f32 in [0, 1) with 24 bits of entropy.
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / 16_777_216.0)
    }

    /// Uniform f64 in [0, 1) with 53 bits of entropy. Used by the FP8
    /// codec so Rust-side stochastic rounding matches the f64 oracle.
    #[inline]
    pub fn uniform_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / 9_007_199_254_740_992.0)
    }

    /// Fill `out` with uniform f64 draws in [0, 1) — the batched form
    /// of [`Pcg32::uniform_f64`] used by the codec hot path. Draw `i`
    /// of the fill is bit-identical to the `i`-th scalar call on the
    /// same state, so batching never changes a stream (enforced by the
    /// codec's scalar-vs-batched property test).
    #[inline]
    pub fn fill_uniform_f64(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.uniform_f64();
        }
    }

    /// Standard normal via Box-Muller (pairs cached).
    pub fn normal(&mut self, cache: &mut Option<f32>) -> f32 {
        if let Some(v) = cache.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f32::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f32::consts::PI * u2).sin_cos();
            *cache = Some(r * s);
            return r * c;
        }
    }

    /// `n` uniform integers in [0, bound) (Lemire-style rejection-free
    /// modulo is fine here; bias < 2^-32 * bound is irrelevant).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        (self.next_u64() % bound as u64) as usize
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// [`Pcg32::sample_distinct`] in O(k) memory: the same partial
    /// Fisher-Yates over a *virtual* identity array, tracking only the
    /// displaced entries in a map. The `below` draw sequence is
    /// identical, so the returned cohort is bit-for-bit the one the
    /// dense sampler would produce — at any population size — which is
    /// what lets a K=10^6 client population be cohort-sampled without
    /// materializing a million-entry index vector.
    pub fn sample_distinct_sparse(
        &mut self,
        n: usize,
        k: usize,
    ) -> Vec<usize> {
        assert!(k <= n);
        let mut displaced: std::collections::HashMap<usize, usize> =
            std::collections::HashMap::with_capacity(2 * k);
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = i + self.below(n - i);
            let vj = displaced.get(&j).copied().unwrap_or(j);
            let vi = displaced.get(&i).copied().unwrap_or(i);
            // the dense sampler swaps idx[i] <-> idx[j]; slot i is
            // never drawn again, so only j's displacement must persist
            displaced.insert(j, vi);
            out.push(vj);
        }
        out
    }

    /// Gamma(shape, 1) via Marsaglia-Tsang (shape >= 0); used for
    /// Dirichlet partitioning.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            let u = self.uniform_f64().max(1e-300);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let mut cache = None;
            let x = self.normal(&mut cache) as f64;
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.uniform_f64().max(1e-300);
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v;
            }
        }
    }

    /// Dirichlet(concentration * ones(k)).
    pub fn dirichlet(&mut self, concentration: f64, k: usize) -> Vec<f64> {
        let g: Vec<f64> = (0..k).map(|_| self.gamma(concentration)).collect();
        let s: f64 = g.iter().sum::<f64>().max(1e-12);
        g.into_iter().map(|v| v / s).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::new(1, 2);
        let mut b = Pcg32::new(1, 2);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Pcg32::new(3, 0);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_half() {
        let mut r = Pcg32::new(4, 0);
        let m: f64 = (0..100_000).map(|_| r.uniform() as f64).sum::<f64>()
            / 100_000.0;
        assert!((m - 0.5).abs() < 0.01, "{m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(5, 0);
        let mut cache = None;
        let xs: Vec<f64> =
            (0..100_000).map(|_| r.normal(&mut cache) as f64).collect();
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / xs.len() as f64;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.03, "var {v}");
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Pcg32::new(6, 0);
        let s = r.sample_distinct(100, 10);
        let mut u = s.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 10);
        assert!(s.iter().all(|&i| i < 100));
    }

    #[test]
    fn sparse_sampler_matches_dense_bitwise() {
        // the virtualization contract: identical draw sequence =>
        // identical cohorts, for every (n, k) shape incl. k == n
        for (n, k) in [
            (1usize, 0usize),
            (1, 1),
            (7, 7),
            (100, 10),
            (100, 100),
            (4096, 64),
            (65_537, 256),
        ] {
            let dense = Pcg32::new(6, 0xC0).sample_distinct(n, k);
            let sparse =
                Pcg32::new(6, 0xC0).sample_distinct_sparse(n, k);
            assert_eq!(sparse, dense, "diverged at n={n} k={k}");
        }
    }

    #[test]
    fn sparse_sampler_is_distinct_and_in_range() {
        let mut r = Pcg32::new(9, 1);
        let s = r.sample_distinct_sparse(1_000_000, 256);
        let mut u = s.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 256);
        assert!(s.iter().all(|&i| i < 1_000_000));
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Pcg32::new(7, 0);
        for conc in [0.1, 0.3, 1.0, 10.0] {
            let d = r.dirichlet(conc, 10);
            let s: f64 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn dirichlet_low_concentration_is_skewed() {
        let mut r = Pcg32::new(8, 0);
        // With conc=0.1 most mass concentrates on few categories.
        let mut max_sum = 0.0;
        for _ in 0..50 {
            let d = r.dirichlet(0.1, 10);
            max_sum += d.iter().cloned().fold(0.0, f64::max);
        }
        assert!(max_sum / 50.0 > 0.5);
    }

    #[test]
    fn derive_is_pure_and_deterministic() {
        let mut a = Pcg32::derive(7, 3, 11, 0xDA7A);
        let mut b = Pcg32::derive(7, 3, 11, 0xDA7A);
        for _ in 0..64 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn derive_coordinates_decorrelate() {
        // any single-coordinate change must yield a different stream
        let base = (7u64, 3u64, 11u64, 0xDA7Au64);
        let variants = [
            (8, 3, 11, 0xDA7A),
            (7, 4, 11, 0xDA7A),
            (7, 3, 12, 0xDA7A),
            (7, 3, 11, 0xC0DE),
        ];
        let mut r0 = Pcg32::derive(base.0, base.1, base.2, base.3);
        let ref_draws: Vec<u32> = (0..32).map(|_| r0.next_u32()).collect();
        for (s, t, c, d) in variants {
            let mut r = Pcg32::derive(s, t, c, d);
            let same = ref_draws
                .iter()
                .filter(|&&v| v == r.next_u32())
                .count();
            assert!(same < 2, "stream collision for ({s},{t},{c},{d:#x})");
        }
    }

    #[test]
    fn fill_matches_scalar_draws() {
        let mut a = Pcg32::new(21, 3);
        let mut b = Pcg32::new(21, 3);
        let mut buf = [0.0f64; 97];
        a.fill_uniform_f64(&mut buf);
        for (i, &v) in buf.iter().enumerate() {
            assert_eq!(v.to_bits(), b.uniform_f64().to_bits(), "draw {i}");
        }
        // generators end in the same state
        assert_eq!(a.next_u32(), b.next_u32());
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Pcg32::new(9, 0);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 2);
    }
}
