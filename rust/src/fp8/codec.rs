//! Wire codec — the *physical* 8-bit payloads of FP8FedAvg-UQ.
//!
//! Unlike simulation-style FL codebases that merely *count* hypothetical
//! bytes, the coordinator really packs every quantized tensor into
//! `1 byte/param` codes (+ a 4-byte alpha side channel per tensor) and
//! unpacks them on the other side, so the communication accounting in
//! EXPERIMENTS.md is physical. Unquantized segments (biases, norm
//! parameters — <2% of params, paper §4) travel as raw little-endian
//! f32.
//!
//! Decode is a 256-entry LUT per tensor (one `Fp8Params::decode_table`
//! per alpha), making the downlink/uplink decode path branch-free.

use super::format::Fp8Params;
use super::rng::Pcg32;

/// One named parameter segment of the flat weight vector (mirrors the
/// manifest's segment table produced by `python/compile/aot.py`).
#[derive(Clone, Debug)]
pub struct Segment {
    pub name: String,
    pub offset: usize,
    pub size: usize,
    pub quantized: bool,
    pub alpha_idx: Option<usize>,
}

/// Rounding mode for communication quantization.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rounding {
    /// Q_det — biased round-half-up (the BQ ablation arm).
    Deterministic,
    /// Q_rand — unbiased stochastic rounding (the paper's UQ).
    Stochastic,
    /// No quantization: raw f32 (the FP32 FedAvg baseline).
    None,
}

/// A packed model update as it would travel over the network.
#[derive(Clone, Debug, Default)]
pub struct WirePayload {
    /// 8-bit codes for quantized segments, concatenated in segment order.
    pub codes: Vec<u8>,
    /// Raw f32 values for unquantized segments, in segment order.
    pub raw: Vec<f32>,
    /// Per-tensor clipping values (alpha side channel).
    pub alphas: Vec<f32>,
    /// Activation clipping values (beta side channel).
    pub betas: Vec<f32>,
}

impl WirePayload {
    /// Bytes on the wire: 1 per code, 4 per raw f32 / alpha / beta.
    pub fn wire_bytes(&self) -> u64 {
        self.codes.len() as u64
            + 4 * (self.raw.len() + self.alphas.len() + self.betas.len())
                as u64
    }
}

/// Encode a flat weight vector into a wire payload.
///
/// `u_draw` supplies the stochastic-rounding randomness; deterministic
/// mode uses u = 0.5 everywhere. With `Rounding::None` the full vector
/// is shipped as f32 (codes empty).
pub fn encode(
    w: &[f32],
    alphas: &[f32],
    betas: &[f32],
    segments: &[Segment],
    mode: Rounding,
    rng: &mut Pcg32,
) -> WirePayload {
    let mut out = WirePayload::default();
    encode_into(w, alphas, betas, segments, mode, rng, &mut out);
    out
}

/// Buffer-reusing variant of [`encode`]: packs into `out`, recycling
/// its allocations. Bit-identical to the allocating path for the same
/// RNG stream (property-tested). Reuse happens wherever the caller
/// retains the payload: the server's downlink buffer is encoded into
/// once per round for the life of a run. Uplink payloads still
/// allocate per message — they are shipped (moved into the `Uplink`)
/// rather than retained; the uplink path instead reuses the
/// per-worker EF/decode scratch in `WorkBuffers`.
pub fn encode_into(
    w: &[f32],
    alphas: &[f32],
    betas: &[f32],
    segments: &[Segment],
    mode: Rounding,
    rng: &mut Pcg32,
    out: &mut WirePayload,
) {
    out.codes.clear();
    out.raw.clear();
    out.alphas.clear();
    out.alphas.extend_from_slice(alphas);
    out.betas.clear();
    out.betas.extend_from_slice(betas);
    if mode == Rounding::None {
        out.raw.extend_from_slice(w);
        return;
    }
    out.codes.reserve(w.len());
    for seg in segments {
        let vals = &w[seg.offset..seg.offset + seg.size];
        match seg.alpha_idx {
            Some(ai) if seg.quantized => {
                let p = Fp8Params::new(alphas[ai]);
                match mode {
                    Rounding::Deterministic => {
                        for &x in vals {
                            out.codes.push(p.encode(x, 0.5));
                        }
                    }
                    Rounding::Stochastic => {
                        for &x in vals {
                            out.codes.push(p.encode(x, rng.uniform_f64()));
                        }
                    }
                    Rounding::None => unreachable!(),
                }
            }
            _ => out.raw.extend_from_slice(vals),
        }
    }
}

/// Buffer-reusing variant of [`decode`]: resizes `out` to the model
/// dimension implied by the segment table and decodes into it, so a
/// recycled (even garbage-filled or wrongly-sized) buffer yields the
/// same result as a fresh allocation.
pub fn decode_into(
    payload: &WirePayload,
    segments: &[Segment],
    out: &mut Vec<f32>,
) {
    let dim = segments
        .iter()
        .map(|s| s.offset + s.size)
        .max()
        .unwrap_or(payload.raw.len());
    out.clear();
    out.resize(dim, 0.0);
    decode(payload, segments, out);
}

/// Decode a wire payload back into a flat weight vector.
pub fn decode(payload: &WirePayload, segments: &[Segment], out: &mut [f32]) {
    if payload.codes.is_empty() && !payload.raw.is_empty() {
        // FP32 passthrough
        out.copy_from_slice(&payload.raw);
        return;
    }
    let mut ci = 0usize;
    let mut ri = 0usize;
    for seg in segments {
        let dst = &mut out[seg.offset..seg.offset + seg.size];
        match seg.alpha_idx {
            Some(ai) if seg.quantized => {
                let table =
                    Fp8Params::new(payload.alphas[ai]).decode_table();
                for d in dst.iter_mut() {
                    *d = table[payload.codes[ci] as usize];
                    ci += 1;
                }
            }
            _ => {
                dst.copy_from_slice(&payload.raw[ri..ri + seg.size]);
                ri += seg.size;
            }
        }
    }
}

/// Quantize a full weight vector in place on the FP8 grid *without*
/// packing (ServerOptimize Eq. (5) inner loop: grid-search over alpha
/// candidates only needs the dequantized values).
pub fn quantize_vec(
    w: &[f32],
    alphas: &[f32],
    segments: &[Segment],
    mode: Rounding,
    rng: &mut Pcg32,
    out: &mut [f32],
) {
    out.copy_from_slice(w);
    if mode == Rounding::None {
        return;
    }
    for seg in segments {
        if let (true, Some(ai)) = (seg.quantized, seg.alpha_idx) {
            let p = Fp8Params::new(alphas[ai]);
            let dst = &mut out[seg.offset..seg.offset + seg.size];
            match mode {
                Rounding::Deterministic => {
                    for d in dst.iter_mut() {
                        *d = p.quantize(*d, 0.5);
                    }
                }
                Rounding::Stochastic => {
                    for d in dst.iter_mut() {
                        *d = p.quantize(*d, rng.uniform_f64());
                    }
                }
                Rounding::None => unreachable!(),
            }
        }
    }
}

/// Weighted MSE between Q(w; alpha) and a set of client vectors —
/// the ServerOptimize Eq. (5) objective, evaluated for one alpha
/// candidate on one segment.
pub fn segment_quant_mse(
    w: &[f32],
    seg: &Segment,
    alpha: f32,
    clients: &[&[f32]],
    kweights: &[f32],
    us: &[f64],
) -> f64 {
    let p = Fp8Params::new(alpha);
    let mut total = 0.0f64;
    let base = seg.offset;
    for i in 0..seg.size {
        let q = p.quantize(w[base + i], us[i]) as f64;
        for (c, &kw) in clients.iter().zip(kweights) {
            let d = q - c[base + i] as f64;
            total += kw as f64 * d * d;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    fn segs() -> Vec<Segment> {
        vec![
            Segment {
                name: "w1".into(),
                offset: 0,
                size: 100,
                quantized: true,
                alpha_idx: Some(0),
            },
            Segment {
                name: "b1".into(),
                offset: 100,
                size: 10,
                quantized: false,
                alpha_idx: None,
            },
            Segment {
                name: "w2".into(),
                offset: 110,
                size: 50,
                quantized: true,
                alpha_idx: Some(1),
            },
        ]
    }

    fn test_vec(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut rng = Pcg32::new(seed, 0);
        (0..n).map(|_| (rng.uniform() - 0.5) * scale).collect()
    }

    #[test]
    fn roundtrip_preserves_unquantized() {
        let w = test_vec(160, 1, 2.0);
        let alphas = vec![1.0, 0.5];
        let mut rng = Pcg32::new(2, 0);
        let p = encode(&w, &alphas, &[], &segs(), Rounding::Deterministic,
                       &mut rng);
        let mut out = vec![0.0; 160];
        decode(&p, &segs(), &mut out);
        assert_eq!(&out[100..110], &w[100..110]); // bias exact
    }

    #[test]
    fn roundtrip_equals_quantize_vec() {
        let w = test_vec(160, 3, 2.0);
        let alphas = vec![0.9, 1.7];
        let mut r1 = Pcg32::new(7, 1);
        let mut r2 = Pcg32::new(7, 1);
        let p = encode(&w, &alphas, &[], &segs(), Rounding::Stochastic,
                       &mut r1);
        let mut via_wire = vec![0.0; 160];
        decode(&p, &segs(), &mut via_wire);
        let mut direct = vec![0.0; 160];
        quantize_vec(&w, &alphas, &segs(), Rounding::Stochastic, &mut r2,
                     &mut direct);
        assert_eq!(via_wire, direct);
    }

    #[test]
    fn fp32_mode_is_exact() {
        let w = test_vec(160, 4, 3.0);
        let mut rng = Pcg32::new(5, 0);
        let p = encode(&w, &[1.0, 1.0], &[], &segs(), Rounding::None,
                       &mut rng);
        let mut out = vec![0.0; 160];
        decode(&p, &segs(), &mut out);
        assert_eq!(out, w);
        assert_eq!(p.wire_bytes(), 160 * 4 + 2 * 4);
    }

    #[test]
    fn wire_bytes_accounting() {
        let w = test_vec(160, 6, 1.0);
        let mut rng = Pcg32::new(6, 0);
        let p = encode(&w, &[1.0, 1.0], &[4.0; 3], &segs(),
                       Rounding::Deterministic, &mut rng);
        // 150 quantized codes + 10 raw f32 + 2 alphas + 3 betas
        assert_eq!(p.wire_bytes(), 150 + 40 + 8 + 12);
    }

    #[test]
    fn stochastic_unbiased_statistically() {
        let seg = vec![Segment {
            name: "w".into(),
            offset: 0,
            size: 64,
            quantized: true,
            alpha_idx: Some(0),
        }];
        let w = test_vec(64, 8, 0.6);
        let mut rng = Pcg32::new(9, 0);
        let mut acc = vec![0.0f64; 64];
        let n = 4000;
        let mut out = vec![0.0; 64];
        for _ in 0..n {
            quantize_vec(&w, &[1.0], &seg, Rounding::Stochastic, &mut rng,
                         &mut out);
            for (a, &v) in acc.iter_mut().zip(&out) {
                *a += v as f64;
            }
        }
        let p = Fp8Params::new(1.0);
        for (i, a) in acc.iter().enumerate() {
            let mean = a / n as f64;
            let bin = p.scale((w[i] as f64).abs());
            let tol = 4.0 * bin / (n as f64).sqrt() + 1e-7;
            assert!(
                (mean - w[i] as f64).abs() < tol,
                "i={i} mean={mean} x={} tol={tol}",
                w[i]
            );
        }
    }

    #[test]
    fn deterministic_encode_is_reproducible() {
        let w = test_vec(160, 10, 1.5);
        let mut r1 = Pcg32::new(1, 0);
        let mut r2 = Pcg32::new(99, 7); // rng must not matter for det
        let a = encode(&w, &[1.0, 1.0], &[], &segs(),
                       Rounding::Deterministic, &mut r1);
        let b = encode(&w, &[1.0, 1.0], &[], &segs(),
                       Rounding::Deterministic, &mut r2);
        assert_eq!(a.codes, b.codes);
    }
}
