//! Wire codec — the *physical* 8-bit payloads of FP8FedAvg-UQ.
//!
//! Unlike simulation-style FL codebases that merely *count* hypothetical
//! bytes, the coordinator really packs every quantized tensor into
//! `1 byte/param` codes (+ a 4-byte alpha side channel per tensor) and
//! unpacks them on the other side, so the communication accounting in
//! EXPERIMENTS.md is physical. Unquantized segments (biases, norm
//! parameters — <2% of params, paper §4) travel as raw little-endian
//! f32.
//!
//! ## Hot-path structure (see ARCHITECTURE.md §Kernel hot paths)
//!
//! * **Batched stochastic rounding.** A stochastic message consumes
//!   exactly one `u64` from the caller's RNG (the *wire key*); the
//!   per-element rounding draws come from counter-derived streams
//!   `Pcg32::derive(key, segment, block, WIRE_DOMAIN)`, one stream per
//!   [`RNG_BLOCK`]-element block, filled in bulk into a reusable
//!   scratch buffer ([`Pcg32::fill_uniform_f64`]). Because each block's
//!   draws are a pure function of `(key, segment, block)`, any
//!   partitioning of blocks across worker threads produces the same
//!   bytes — the codec twin of the parallel-round determinism contract.
//! * **Cached decode LUTs.** Decode is a 256-entry LUT per (tensor,
//!   alpha); [`DecodeLutCache`] memoizes tables across segments,
//!   messages and rounds instead of rebuilding them (256 `exp2` calls)
//!   inside every `decode`.
//! * **Pool fan-out.** `encode_into_pooled` / `decode_pooled` /
//!   `quantize_vec_pooled` spread block tasks across up to `pool`
//!   scoped threads for large tensors; results are bit-identical for
//!   every pool size.
//! * **Sufficient statistics for Eq. (5).** [`SegmentStats`] turns the
//!   ServerOptimize alpha grid search from O(G·K·d) into O(d·(K+G));
//!   [`segment_quant_mse`] is kept as the naive reference oracle.
//! * **Kernel dispatch.** Every quantize/encode inner loop — scalar,
//!   batched, pooled, and the Eq. (5) scorer — runs through one
//!   [`Fp8Kernel`] implementation selected by a [`KernelKind`]
//!   (`--fp8-kernel scalar|simd|auto`). Kernels are bit-identical by
//!   contract (`fp8::simd`), so the knob is pure wall-clock;
//!   [`encode_into_scalar`] stays pinned to the scalar oracle as the
//!   differential reference.

use std::sync::Arc;
use std::thread;

use super::format::Fp8Params;
use super::rng::Pcg32;
use super::simd::{Draws, Fp8Kernel, KernelKind};

/// Elements per counter-derived rounding stream. Fixed: it is part of
/// the wire determinism contract (changing it changes every stochastic
/// payload), and it bounds the RNG scratch buffer.
pub const RNG_BLOCK: usize = 4096;

/// Stream-domain tag for wire rounding draws (distinct from the
/// coordinator's round/client domains in `coordinator::transport`).
const WIRE_DOMAIN: u64 = 0xF8B1_0C5E;

/// Below this many quantized elements a message is encoded (or
/// quantized in place) on the calling thread even when a pool is
/// available. Encode costs ~15-20 ns/element (f64 div dominates), so
/// the threshold sits where the work comfortably exceeds thread
/// spawn cost.
const PAR_MIN_ELEMS: usize = 1 << 15;

/// Decode is ~1 ns/element (pure LUT loads), so fan-out only pays for
/// much larger payloads than encode — below this the pool is ignored
/// (measured: spawning for a 100k-element decode is a net loss).
const DEC_PAR_MIN_ELEMS: usize = 1 << 20;

/// Elements per decode task (decode is table lookups only, so tasks
/// can be coarser than [`RNG_BLOCK`]).
const DEC_BLOCK: usize = 1 << 16;

/// One named parameter segment of the flat weight vector (mirrors the
/// manifest's segment table produced by `python/compile/aot.py`).
#[derive(Clone, Debug)]
pub struct Segment {
    pub name: String,
    pub offset: usize,
    pub size: usize,
    pub quantized: bool,
    pub alpha_idx: Option<usize>,
}

impl Segment {
    #[inline]
    fn wire_quantized(&self) -> bool {
        self.quantized && self.alpha_idx.is_some()
    }
}

/// Rounding mode for communication quantization.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rounding {
    /// Q_det — biased round-half-up (the BQ ablation arm).
    Deterministic,
    /// Q_rand — unbiased stochastic rounding (the paper's UQ).
    Stochastic,
    /// No quantization: raw f32 (the FP32 FedAvg baseline).
    None,
}

/// A packed model update as it would travel over the network (and,
/// through `net::codec`, really does).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WirePayload {
    /// 8-bit codes for quantized segments, concatenated in segment order.
    pub codes: Vec<u8>,
    /// Raw f32 values for unquantized segments, in segment order.
    pub raw: Vec<f32>,
    /// Per-tensor clipping values (alpha side channel).
    pub alphas: Vec<f32>,
    /// Activation clipping values (beta side channel).
    pub betas: Vec<f32>,
}

impl WirePayload {
    /// Bytes on the wire: 1 per code, 4 per raw f32 / alpha / beta.
    pub fn wire_bytes(&self) -> u64 {
        self.codes.len() as u64
            + 4 * (self.raw.len() + self.alphas.len() + self.betas.len())
                as u64
    }
}

/// Small MRU cache of 256-entry decode tables keyed by alpha bits.
///
/// One table per (tensor, alpha) is enough for a whole round: the
/// downlink broadcast, every client's hard-reset decode and the
/// error-feedback decodes all share the round's alphas, and uplink
/// alphas repeat across rounds as training converges. Tables are
/// `Arc`-shared so parallel decode workers can hold them without
/// copies. Capacity-bounded (MRU eviction), so a long run with
/// drifting alphas cannot grow it without bound.
#[derive(Default)]
pub struct DecodeLutCache {
    /// MRU-ordered (alpha bits, table) pairs; front = most recent.
    entries: Vec<(u32, Arc<[f32; 256]>)>,
}

/// Cache capacity: comfortably above alpha_dim for every model variant
/// (tens of tensors) while keeping the linear MRU scan trivial.
const LUT_CACHE_CAP: usize = 64;

impl DecodeLutCache {
    /// Table for `alpha`, building (and memoizing) it on first use.
    pub fn get(&mut self, alpha: f32) -> Arc<[f32; 256]> {
        let key = alpha.to_bits();
        if let Some(i) =
            self.entries.iter().position(|(k, _)| *k == key)
        {
            if i != 0 {
                let hit = self.entries.remove(i);
                self.entries.insert(0, hit);
            }
            return self.entries[0].1.clone();
        }
        let table = Arc::new(Fp8Params::new(alpha).decode_table());
        self.entries.insert(0, (key, table.clone()));
        self.entries.truncate(LUT_CACHE_CAP);
        table
    }

    /// Number of cached tables (diagnostics / tests).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Encode a flat weight vector into a wire payload.
///
/// `rng` supplies the stochastic-rounding *wire key* (exactly one u64
/// is consumed per stochastic message — see the module docs for the
/// per-block stream derivation); deterministic mode uses u = 0.5
/// everywhere and consumes nothing. With `Rounding::None` the full
/// vector is shipped as f32 (codes empty).
pub fn encode(
    w: &[f32],
    alphas: &[f32],
    betas: &[f32],
    segments: &[Segment],
    mode: Rounding,
    rng: &mut Pcg32,
) -> WirePayload {
    let mut out = WirePayload::default();
    encode_into(w, alphas, betas, segments, mode, rng, &mut out);
    out
}

/// Buffer-reusing variant of [`encode`]: packs into `out`, recycling
/// its allocations. Bit-identical to the allocating path for the same
/// RNG stream (property-tested). Hot callers that also want to recycle
/// the RNG scratch buffer and fan out across a pool use
/// [`encode_into_pooled`] directly.
pub fn encode_into(
    w: &[f32],
    alphas: &[f32],
    betas: &[f32],
    segments: &[Segment],
    mode: Rounding,
    rng: &mut Pcg32,
    out: &mut WirePayload,
) {
    let mut scratch = Vec::new();
    encode_into_pooled(
        w, alphas, betas, segments, mode, KernelKind::Auto, rng,
        &mut scratch, 1, out,
    );
}

/// One block of one quantized segment: the unit of encode work and of
/// RNG stream derivation.
struct EncodeBlock<'a> {
    params: Fp8Params,
    src: &'a [f32],
    dst: &'a mut [u8],
    /// (segment index, block index) — the stream coordinates.
    si: u64,
    block: u64,
}

#[inline]
fn encode_block(
    t: &mut EncodeBlock<'_>,
    mode: Rounding,
    key: u64,
    scratch: &mut [f64],
    kernel: &dyn Fp8Kernel,
) {
    match mode {
        Rounding::Deterministic => {
            kernel.encode_slice(
                &t.params, t.src, Draws::Const(0.5), t.dst,
            );
        }
        Rounding::Stochastic => {
            let us = &mut scratch[..t.src.len()];
            let mut srng = Pcg32::derive(key, t.si, t.block, WIRE_DOMAIN);
            srng.fill_uniform_f64(us);
            kernel.encode_slice(&t.params, t.src, Draws::Slice(us), t.dst);
        }
        Rounding::None => unreachable!(),
    }
}

/// The core encoder: batched rounding draws, chunked inner loops, and
/// optional pool fan-out.
///
/// `scratch` is the reusable rounding-draw buffer (lives in the
/// caller's `WorkBuffers` on the uplink path, in the `Server` on the
/// downlink path); it is grown to at most [`RNG_BLOCK`] f64s. `pool`
/// is the worker-thread budget for this message and `kernel` picks
/// the quantize/encode inner loop; output bytes are identical for
/// every value of both (per-block counter-derived streams +
/// bit-identical kernels), so they are purely wall-clock knobs —
/// enforced by the scalar-vs-batched property suite at pool 1/2/4
/// and the kernel conformance harness.
pub fn encode_into_pooled(
    w: &[f32],
    alphas: &[f32],
    betas: &[f32],
    segments: &[Segment],
    mode: Rounding,
    kernel: KernelKind,
    rng: &mut Pcg32,
    scratch: &mut Vec<f64>,
    pool: usize,
    out: &mut WirePayload,
) {
    let kernel = kernel.resolve();
    out.codes.clear();
    out.raw.clear();
    out.alphas.clear();
    out.alphas.extend_from_slice(alphas);
    out.betas.clear();
    out.betas.extend_from_slice(betas);
    if mode == Rounding::None {
        out.raw.extend_from_slice(w);
        return;
    }
    // one wire key per stochastic message; every rounding draw below
    // is a pure function of (key, segment, block)
    let key = match mode {
        Rounding::Stochastic => rng.next_u64(),
        _ => 0,
    };
    let total_q: usize = segments
        .iter()
        .filter(|s| s.wire_quantized())
        .map(|s| s.size)
        .sum();
    out.codes.resize(total_q, 0);
    // raw segments copy inline; quantized segments become block tasks
    // over disjoint sub-slices of the codes buffer
    let mut tasks: Vec<EncodeBlock<'_>> = Vec::new();
    let mut codes: &mut [u8] = out.codes.as_mut_slice();
    for (si, seg) in segments.iter().enumerate() {
        let vals = &w[seg.offset..seg.offset + seg.size];
        if seg.wire_quantized() {
            let params =
                Fp8Params::new(alphas[seg.alpha_idx.unwrap()]);
            let (dst_seg, rest) =
                std::mem::take(&mut codes).split_at_mut(seg.size);
            codes = rest;
            for (block, (src, dst)) in vals
                .chunks(RNG_BLOCK)
                .zip(dst_seg.chunks_mut(RNG_BLOCK))
                .enumerate()
            {
                tasks.push(EncodeBlock {
                    params,
                    src,
                    dst,
                    si: si as u64,
                    block: block as u64,
                });
            }
        } else {
            out.raw.extend_from_slice(vals);
        }
    }
    if mode == Rounding::Stochastic && scratch.len() < RNG_BLOCK {
        scratch.resize(RNG_BLOCK, 0.0);
    }
    let workers = pool.min(tasks.len()).max(1);
    if workers == 1 || total_q < PAR_MIN_ELEMS {
        for t in tasks.iter_mut() {
            encode_block(t, mode, key, scratch, kernel);
        }
        return;
    }
    scatter_tasks(
        &mut tasks,
        workers,
        || worker_scratch(mode),
        |t, local| encode_block(t, mode, key, local, kernel),
    );
}

/// Per-worker RNG scratch: only stochastic rounding reads it, so the
/// deterministic arms skip the 32 KB allocation.
fn worker_scratch(mode: Rounding) -> Vec<f64> {
    if mode == Rounding::Stochastic {
        vec![0.0f64; RNG_BLOCK]
    } else {
        Vec::new()
    }
}

/// Shared fan-out skeleton for the pooled kernel paths: split `tasks`
/// into contiguous chunks, one scoped worker per chunk, each with its
/// own scratch from `scratch_init`. Chunking is static (block counts
/// far exceed worker counts) and the task partition never affects
/// output bytes — every task is independent.
fn scatter_tasks<T: Send>(
    tasks: &mut [T],
    workers: usize,
    scratch_init: impl Fn() -> Vec<f64> + Sync,
    run: impl Fn(&mut T, &mut Vec<f64>) + Sync,
) {
    let per = tasks.len().div_ceil(workers);
    let run = &run;
    let scratch_init = &scratch_init;
    thread::scope(|s| {
        for chunk in tasks.chunks_mut(per) {
            s.spawn(move || {
                let mut local = scratch_init();
                for t in chunk.iter_mut() {
                    run(t, &mut local);
                }
            });
        }
    });
}

/// Map-into-slots twin of the fan-out skeleton: score each read-only
/// task into its result slot, chunked across `workers` scoped
/// threads. Slot order equals task order, so reductions downstream
/// are deterministic for every worker count. Used by the
/// ServerOptimize Eq. (5) candidate search and the kernel bench.
pub fn scatter_zip<T: Sync, R: Send>(
    tasks: &[T],
    results: &mut [R],
    workers: usize,
    run: impl Fn(&T) -> R + Sync,
) {
    if tasks.is_empty() {
        return;
    }
    let per = tasks.len().div_ceil(workers.max(1));
    let run = &run;
    thread::scope(|s| {
        for (tchunk, rchunk) in
            tasks.chunks(per).zip(results.chunks_mut(per))
        {
            s.spawn(move || {
                for (t, slot) in tchunk.iter().zip(rchunk.iter_mut()) {
                    *slot = run(t);
                }
            });
        }
    });
}

/// Reference scalar encoder: same wire contract as
/// [`encode_into_pooled`] (per-block counter-derived streams) but with
/// per-element RNG calls, push-based output and no batching or pool.
/// This is the oracle the batched path is property-tested against and
/// the "before" arm of `benches/fp8_kernels.rs`.
pub fn encode_into_scalar(
    w: &[f32],
    alphas: &[f32],
    betas: &[f32],
    segments: &[Segment],
    mode: Rounding,
    rng: &mut Pcg32,
    out: &mut WirePayload,
) {
    out.codes.clear();
    out.raw.clear();
    out.alphas.clear();
    out.alphas.extend_from_slice(alphas);
    out.betas.clear();
    out.betas.extend_from_slice(betas);
    if mode == Rounding::None {
        out.raw.extend_from_slice(w);
        return;
    }
    let key = match mode {
        Rounding::Stochastic => rng.next_u64(),
        _ => 0,
    };
    for (si, seg) in segments.iter().enumerate() {
        let vals = &w[seg.offset..seg.offset + seg.size];
        if seg.wire_quantized() {
            let p = Fp8Params::new(alphas[seg.alpha_idx.unwrap()]);
            match mode {
                Rounding::Deterministic => {
                    for &x in vals {
                        out.codes.push(p.encode(x, 0.5));
                    }
                }
                Rounding::Stochastic => {
                    for (block, blk) in
                        vals.chunks(RNG_BLOCK).enumerate()
                    {
                        let mut srng = Pcg32::derive(
                            key,
                            si as u64,
                            block as u64,
                            WIRE_DOMAIN,
                        );
                        for &x in blk {
                            out.codes
                                .push(p.encode(x, srng.uniform_f64()));
                        }
                    }
                }
                Rounding::None => unreachable!(),
            }
        } else {
            out.raw.extend_from_slice(vals);
        }
    }
}

/// Buffer-reusing variant of [`decode`]: resizes `out` to the model
/// dimension implied by the segment table and decodes into it, so a
/// recycled (even garbage-filled or wrongly-sized) buffer yields the
/// same result as a fresh allocation.
pub fn decode_into(
    payload: &WirePayload,
    segments: &[Segment],
    out: &mut Vec<f32>,
) {
    let mut cache = DecodeLutCache::default();
    decode_into_pooled(payload, segments, &mut cache, 1, out);
}

/// [`decode_into`] with a caller-held LUT cache and pool fan-out.
pub fn decode_into_pooled(
    payload: &WirePayload,
    segments: &[Segment],
    cache: &mut DecodeLutCache,
    pool: usize,
    out: &mut Vec<f32>,
) {
    let dim = segments
        .iter()
        .map(|s| s.offset + s.size)
        .max()
        .unwrap_or(payload.raw.len());
    out.clear();
    out.resize(dim, 0.0);
    decode_pooled(payload, segments, cache, pool, out);
}

/// Decode a wire payload back into a flat weight vector.
pub fn decode(payload: &WirePayload, segments: &[Segment], out: &mut [f32]) {
    let mut cache = DecodeLutCache::default();
    decode_pooled(payload, segments, &mut cache, 1, out);
}

/// True when segments are offset-ascending and non-overlapping — the
/// layout every manifest produces, and the precondition for splitting
/// `out` into disjoint per-segment slices for the parallel path.
fn ascending_disjoint(segments: &[Segment]) -> bool {
    segments
        .windows(2)
        .all(|w| w[0].offset + w[0].size <= w[1].offset)
}

/// One block of decode work: pure table lookups on disjoint slices.
struct DecodeBlock<'a> {
    table: Arc<[f32; 256]>,
    src: &'a [u8],
    dst: &'a mut [f32],
}

/// The core decoder: LUT-cached, branch-free inner loops, optional
/// pool fan-out for large payloads. Bit-identical for every `pool`.
pub fn decode_pooled(
    payload: &WirePayload,
    segments: &[Segment],
    cache: &mut DecodeLutCache,
    pool: usize,
    out: &mut [f32],
) {
    if payload.codes.is_empty() && !payload.raw.is_empty() {
        // FP32 passthrough
        out.copy_from_slice(&payload.raw);
        return;
    }
    let total_q: usize = segments
        .iter()
        .filter(|s| s.wire_quantized())
        .map(|s| s.size)
        .sum();
    if pool > 1
        && total_q >= DEC_PAR_MIN_ELEMS
        && ascending_disjoint(segments)
    {
        decode_parallel(payload, segments, cache, pool, out);
        return;
    }
    let mut ci = 0usize;
    let mut ri = 0usize;
    for seg in segments {
        let dst = &mut out[seg.offset..seg.offset + seg.size];
        if seg.wire_quantized() {
            let table = cache.get(payload.alphas[seg.alpha_idx.unwrap()]);
            let codes = &payload.codes[ci..ci + seg.size];
            ci += seg.size;
            for (d, &c) in dst.iter_mut().zip(codes.iter()) {
                *d = table[c as usize];
            }
        } else {
            dst.copy_from_slice(&payload.raw[ri..ri + seg.size]);
            ri += seg.size;
        }
    }
}

fn decode_parallel(
    payload: &WirePayload,
    segments: &[Segment],
    cache: &mut DecodeLutCache,
    pool: usize,
    out: &mut [f32],
) {
    let mut tasks: Vec<DecodeBlock<'_>> = Vec::new();
    let mut rest: &mut [f32] = out;
    let mut consumed = 0usize;
    let mut ci = 0usize;
    let mut ri = 0usize;
    for seg in segments {
        let skip = seg.offset - consumed;
        let (_gap, r) = std::mem::take(&mut rest).split_at_mut(skip);
        let (dst_seg, r) = r.split_at_mut(seg.size);
        rest = r;
        consumed = seg.offset + seg.size;
        if seg.wire_quantized() {
            let table = cache.get(payload.alphas[seg.alpha_idx.unwrap()]);
            let codes = &payload.codes[ci..ci + seg.size];
            ci += seg.size;
            for (src, dst) in codes
                .chunks(DEC_BLOCK)
                .zip(dst_seg.chunks_mut(DEC_BLOCK))
            {
                tasks.push(DecodeBlock {
                    table: table.clone(),
                    src,
                    dst,
                });
            }
        } else {
            // raw copies are memcpy-speed; keep them on this thread
            dst_seg.copy_from_slice(&payload.raw[ri..ri + seg.size]);
            ri += seg.size;
        }
    }
    let workers = pool.min(tasks.len()).max(1);
    if workers == 1 {
        for t in tasks.iter_mut() {
            for (d, &c) in t.dst.iter_mut().zip(t.src.iter()) {
                *d = t.table[c as usize];
            }
        }
        return;
    }
    scatter_tasks(&mut tasks, workers, Vec::new, |t, _| {
        for (d, &c) in t.dst.iter_mut().zip(t.src.iter()) {
            *d = t.table[c as usize];
        }
    });
}

/// Quantize a full weight vector in place on the FP8 grid *without*
/// packing (grid-membership checks, ablation tooling). Same wire RNG
/// contract as [`encode`], so `decode(encode(w)) == quantize_vec(w)`
/// for identically-seeded RNGs.
pub fn quantize_vec(
    w: &[f32],
    alphas: &[f32],
    segments: &[Segment],
    mode: Rounding,
    rng: &mut Pcg32,
    out: &mut [f32],
) {
    let mut scratch = Vec::new();
    quantize_vec_pooled(
        w, alphas, segments, mode, KernelKind::Auto, rng, &mut scratch,
        1, out,
    );
}

/// One block of in-place quantization work.
struct QuantBlock<'a> {
    params: Fp8Params,
    dst: &'a mut [f32],
    si: u64,
    block: u64,
}

#[inline]
fn quantize_block(
    t: &mut QuantBlock<'_>,
    mode: Rounding,
    key: u64,
    scratch: &mut [f64],
    kernel: &dyn Fp8Kernel,
) {
    match mode {
        Rounding::Deterministic => {
            kernel.quantize_slice(&t.params, t.dst, Draws::Const(0.5));
        }
        Rounding::Stochastic => {
            let us = &mut scratch[..t.dst.len()];
            let mut srng = Pcg32::derive(key, t.si, t.block, WIRE_DOMAIN);
            srng.fill_uniform_f64(us);
            kernel.quantize_slice(&t.params, t.dst, Draws::Slice(us));
        }
        Rounding::None => unreachable!(),
    }
}

/// [`quantize_vec`] with a reusable RNG scratch buffer and pool
/// fan-out — the batched/pooled twin of [`encode_into_pooled`].
pub fn quantize_vec_pooled(
    w: &[f32],
    alphas: &[f32],
    segments: &[Segment],
    mode: Rounding,
    kernel: KernelKind,
    rng: &mut Pcg32,
    scratch: &mut Vec<f64>,
    pool: usize,
    out: &mut [f32],
) {
    let kernel = kernel.resolve();
    out.copy_from_slice(w);
    if mode == Rounding::None {
        return;
    }
    let key = match mode {
        Rounding::Stochastic => rng.next_u64(),
        _ => 0,
    };
    let mut tasks: Vec<QuantBlock<'_>> = Vec::new();
    let mut total_q = 0usize;
    // split `out` into disjoint per-segment slices when the layout
    // allows; otherwise quantize sequentially by direct indexing
    if ascending_disjoint(segments) {
        let mut rest: &mut [f32] = out;
        let mut consumed = 0usize;
        for (si, seg) in segments.iter().enumerate() {
            let skip = seg.offset - consumed;
            let (_gap, r) = std::mem::take(&mut rest).split_at_mut(skip);
            let (dst_seg, r) = r.split_at_mut(seg.size);
            rest = r;
            consumed = seg.offset + seg.size;
            if !seg.wire_quantized() {
                continue;
            }
            total_q += seg.size;
            let params = Fp8Params::new(alphas[seg.alpha_idx.unwrap()]);
            for (block, dst) in
                dst_seg.chunks_mut(RNG_BLOCK).enumerate()
            {
                tasks.push(QuantBlock {
                    params,
                    dst,
                    si: si as u64,
                    block: block as u64,
                });
            }
        }
    } else {
        if mode == Rounding::Stochastic && scratch.len() < RNG_BLOCK {
            scratch.resize(RNG_BLOCK, 0.0);
        }
        for (si, seg) in segments.iter().enumerate() {
            if !seg.wire_quantized() {
                continue;
            }
            let params = Fp8Params::new(alphas[seg.alpha_idx.unwrap()]);
            let dst_seg = &mut out[seg.offset..seg.offset + seg.size];
            for (block, dst) in
                dst_seg.chunks_mut(RNG_BLOCK).enumerate()
            {
                let mut t = QuantBlock {
                    params,
                    dst,
                    si: si as u64,
                    block: block as u64,
                };
                quantize_block(&mut t, mode, key, scratch, kernel);
            }
        }
        return;
    }
    if mode == Rounding::Stochastic && scratch.len() < RNG_BLOCK {
        scratch.resize(RNG_BLOCK, 0.0);
    }
    let workers = pool.min(tasks.len()).max(1);
    if workers == 1 || total_q < PAR_MIN_ELEMS {
        for t in tasks.iter_mut() {
            quantize_block(t, mode, key, scratch, kernel);
        }
        return;
    }
    scatter_tasks(
        &mut tasks,
        workers,
        || worker_scratch(mode),
        |t, local| quantize_block(t, mode, key, local, kernel),
    );
}

/// Weighted MSE between Q(w; alpha) and a set of client vectors —
/// the ServerOptimize Eq. (5) objective, evaluated for one alpha
/// candidate on one segment.
///
/// This is the naive O(K·d)-per-candidate **reference** implementation;
/// it is the oracle for the [`SegmentStats`] property suite and the
/// "before" arm of `benches/fp8_kernels.rs`. The hot path
/// (`coordinator::server_opt`) uses [`SegmentStats`], which amortizes
/// the client scan across the whole candidate grid.
pub fn segment_quant_mse(
    w: &[f32],
    seg: &Segment,
    alpha: f32,
    clients: &[&[f32]],
    kweights: &[f32],
    us: &[f64],
) -> f64 {
    let p = Fp8Params::new(alpha);
    let mut total = 0.0f64;
    let base = seg.offset;
    for i in 0..seg.size {
        let q = p.quantize(w[base + i], us[i]) as f64;
        for (c, &kw) in clients.iter().zip(kweights) {
            let d = q - c[base + i] as f64;
            total += kw as f64 * d * d;
        }
    }
    total
}

/// Per-element sufficient statistics of the Eq. (5) objective over one
/// segment.
///
/// With `W = Σ_k kw_k`, `S_i = Σ_k kw_k·c_{k,i}` and
/// `T_i = Σ_k kw_k·c_{k,i}²` precomputed once per segment (O(K·d)),
/// each alpha candidate costs `Σ_i q_i²·W − 2·q_i·S_i + T_i` — O(d)
/// instead of O(K·d) — so a G-point grid search drops from O(G·K·d)
/// to O(d·(K+G)). Equal to [`segment_quant_mse`] up to f64 summation
/// order (property-tested to tolerance).
pub struct SegmentStats {
    /// W — total FedAvg weight of the cohort.
    pub wsum: f64,
    s: Vec<f64>,
    t: Vec<f64>,
}

impl SegmentStats {
    /// Scan the K client vectors once for this segment.
    pub fn build(
        seg: &Segment,
        clients: &[&[f32]],
        kweights: &[f32],
    ) -> SegmentStats {
        let mut s = vec![0.0f64; seg.size];
        let mut t = vec![0.0f64; seg.size];
        let mut wsum = 0.0f64;
        for (c, &kw) in clients.iter().zip(kweights) {
            let kw = kw as f64;
            wsum += kw;
            let cseg = &c[seg.offset..seg.offset + seg.size];
            for ((si, ti), &cv) in
                s.iter_mut().zip(t.iter_mut()).zip(cseg.iter())
            {
                let cv = cv as f64;
                *si += kw * cv;
                *ti += kw * cv * cv;
            }
        }
        SegmentStats { wsum, s, t }
    }

    /// Score one alpha candidate in O(d) using the precomputed stats.
    /// `us` are the common random numbers shared by all candidates of
    /// this segment (same contract as [`segment_quant_mse`]).
    ///
    /// Four independent accumulators break the serial dependency on
    /// the f64 sum (per-element math is unchanged; the reassociated
    /// total is covered by the property-test tolerance), and
    /// chunks_exact keeps bounds checks out of the inner loop.
    pub fn mse(
        &self,
        w: &[f32],
        seg: &Segment,
        alpha: f32,
        us: &[f64],
    ) -> f64 {
        let p = Fp8Params::new(alpha);
        let wseg = &w[seg.offset..seg.offset + seg.size];
        let n = wseg.len();
        let n4 = n - n % 4;
        let mut acc = [0.0f64; 4];
        for (((wc, uc), sc), tc) in wseg
            .chunks_exact(4)
            .zip(us.chunks_exact(4))
            .zip(self.s.chunks_exact(4))
            .zip(self.t.chunks_exact(4))
        {
            let q0 = p.quantize(wc[0], uc[0]) as f64;
            let q1 = p.quantize(wc[1], uc[1]) as f64;
            let q2 = p.quantize(wc[2], uc[2]) as f64;
            let q3 = p.quantize(wc[3], uc[3]) as f64;
            acc[0] += q0 * q0 * self.wsum - 2.0 * q0 * sc[0] + tc[0];
            acc[1] += q1 * q1 * self.wsum - 2.0 * q1 * sc[1] + tc[1];
            acc[2] += q2 * q2 * self.wsum - 2.0 * q2 * sc[2] + tc[2];
            acc[3] += q3 * q3 * self.wsum - 2.0 * q3 * sc[3] + tc[3];
        }
        let mut tail = 0.0f64;
        for i in n4..n {
            let q = p.quantize(wseg[i], us[i]) as f64;
            tail += q * q * self.wsum - 2.0 * q * self.s[i] + self.t[i];
        }
        (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
    }

    /// [`SegmentStats::mse`] with the quantize inner loop dispatched
    /// through a [`KernelKind`] — the form `server_opt` actually
    /// scores candidates with.
    ///
    /// Bit-identical to [`SegmentStats::mse`] for every kernel: the
    /// per-element quantize results are identical by the kernel
    /// contract, and the accumulation order is preserved exactly —
    /// blocks are multiples of four, element `i` still feeds
    /// accumulator `i % 4` in ascending order, and the `n % 4` tail
    /// uses the same separate accumulator. Exact equality (not
    /// tolerance) is property-tested.
    pub fn mse_with(
        &self,
        kernel: KernelKind,
        w: &[f32],
        seg: &Segment,
        alpha: f32,
        us: &[f64],
    ) -> f64 {
        // quantize granularity: a multiple of 4 (keeps the 4-lane
        // accumulator mapping aligned), small enough for stack + L1
        const QBLOCK: usize = 128;
        let kernel = kernel.resolve();
        let p = Fp8Params::new(alpha);
        let wseg = &w[seg.offset..seg.offset + seg.size];
        let n = wseg.len();
        let n4 = n - n % 4;
        let mut qbuf = [0.0f32; QBLOCK];
        let mut acc = [0.0f64; 4];
        let mut base = 0usize;
        while base < n4 {
            let blk = QBLOCK.min(n4 - base);
            let q = &mut qbuf[..blk];
            q.copy_from_slice(&wseg[base..base + blk]);
            kernel.quantize_slice(
                &p,
                q,
                Draws::Slice(&us[base..base + blk]),
            );
            for (ci, ch) in q.chunks_exact(4).enumerate() {
                let i = base + 4 * ci;
                let q0 = ch[0] as f64;
                let q1 = ch[1] as f64;
                let q2 = ch[2] as f64;
                let q3 = ch[3] as f64;
                acc[0] +=
                    q0 * q0 * self.wsum - 2.0 * q0 * self.s[i] + self.t[i];
                acc[1] += q1 * q1 * self.wsum - 2.0 * q1 * self.s[i + 1]
                    + self.t[i + 1];
                acc[2] += q2 * q2 * self.wsum - 2.0 * q2 * self.s[i + 2]
                    + self.t[i + 2];
                acc[3] += q3 * q3 * self.wsum - 2.0 * q3 * self.s[i + 3]
                    + self.t[i + 3];
            }
            base += blk;
        }
        let mut tail = 0.0f64;
        for i in n4..n {
            let q = p.quantize(wseg[i], us[i]) as f64;
            tail += q * q * self.wsum - 2.0 * q * self.s[i] + self.t[i];
        }
        (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn segs() -> Vec<Segment> {
        vec![
            Segment {
                name: "w1".into(),
                offset: 0,
                size: 100,
                quantized: true,
                alpha_idx: Some(0),
            },
            Segment {
                name: "b1".into(),
                offset: 100,
                size: 10,
                quantized: false,
                alpha_idx: None,
            },
            Segment {
                name: "w2".into(),
                offset: 110,
                size: 50,
                quantized: true,
                alpha_idx: Some(1),
            },
        ]
    }

    fn test_vec(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut rng = Pcg32::new(seed, 0);
        (0..n).map(|_| (rng.uniform() - 0.5) * scale).collect()
    }

    #[test]
    fn roundtrip_preserves_unquantized() {
        let w = test_vec(160, 1, 2.0);
        let alphas = vec![1.0, 0.5];
        let mut rng = Pcg32::new(2, 0);
        let p = encode(&w, &alphas, &[], &segs(), Rounding::Deterministic,
                       &mut rng);
        let mut out = vec![0.0; 160];
        decode(&p, &segs(), &mut out);
        assert_eq!(&out[100..110], &w[100..110]); // bias exact
    }

    #[test]
    fn roundtrip_equals_quantize_vec() {
        let w = test_vec(160, 3, 2.0);
        let alphas = vec![0.9, 1.7];
        let mut r1 = Pcg32::new(7, 1);
        let mut r2 = Pcg32::new(7, 1);
        let p = encode(&w, &alphas, &[], &segs(), Rounding::Stochastic,
                       &mut r1);
        let mut via_wire = vec![0.0; 160];
        decode(&p, &segs(), &mut via_wire);
        let mut direct = vec![0.0; 160];
        quantize_vec(&w, &alphas, &segs(), Rounding::Stochastic, &mut r2,
                     &mut direct);
        assert_eq!(via_wire, direct);
    }

    #[test]
    fn fp32_mode_is_exact() {
        let w = test_vec(160, 4, 3.0);
        let mut rng = Pcg32::new(5, 0);
        let p = encode(&w, &[1.0, 1.0], &[], &segs(), Rounding::None,
                       &mut rng);
        let mut out = vec![0.0; 160];
        decode(&p, &segs(), &mut out);
        assert_eq!(out, w);
        assert_eq!(p.wire_bytes(), 160 * 4 + 2 * 4);
    }

    #[test]
    fn wire_bytes_accounting() {
        let w = test_vec(160, 6, 1.0);
        let mut rng = Pcg32::new(6, 0);
        let p = encode(&w, &[1.0, 1.0], &[4.0; 3], &segs(),
                       Rounding::Deterministic, &mut rng);
        // 150 quantized codes + 10 raw f32 + 2 alphas + 3 betas
        assert_eq!(p.wire_bytes(), 150 + 40 + 8 + 12);
    }

    #[test]
    fn stochastic_unbiased_statistically() {
        let seg = vec![Segment {
            name: "w".into(),
            offset: 0,
            size: 64,
            quantized: true,
            alpha_idx: Some(0),
        }];
        let w = test_vec(64, 8, 0.6);
        let mut rng = Pcg32::new(9, 0);
        let mut acc = vec![0.0f64; 64];
        let n = 4000;
        let mut out = vec![0.0; 64];
        for _ in 0..n {
            quantize_vec(&w, &[1.0], &seg, Rounding::Stochastic, &mut rng,
                         &mut out);
            for (a, &v) in acc.iter_mut().zip(&out) {
                *a += v as f64;
            }
        }
        let p = Fp8Params::new(1.0);
        for (i, a) in acc.iter().enumerate() {
            let mean = a / n as f64;
            let bin = p.scale((w[i] as f64).abs());
            let tol = 4.0 * bin / (n as f64).sqrt() + 1e-7;
            assert!(
                (mean - w[i] as f64).abs() < tol,
                "i={i} mean={mean} x={} tol={tol}",
                w[i]
            );
        }
    }

    #[test]
    fn deterministic_encode_is_reproducible() {
        let w = test_vec(160, 10, 1.5);
        let mut r1 = Pcg32::new(1, 0);
        let mut r2 = Pcg32::new(99, 7); // rng must not matter for det
        let a = encode(&w, &[1.0, 1.0], &[], &segs(),
                       Rounding::Deterministic, &mut r1);
        let b = encode(&w, &[1.0, 1.0], &[], &segs(),
                       Rounding::Deterministic, &mut r2);
        assert_eq!(a.codes, b.codes);
    }

    #[test]
    fn stochastic_message_consumes_one_key_draw() {
        // the whole point of the wire-key scheme: the caller's RNG
        // advances by exactly one u64 per stochastic message, no
        // matter how large the tensor is
        let w_small = test_vec(160, 12, 1.0);
        let mut r1 = Pcg32::new(42, 0);
        let mut r2 = Pcg32::new(42, 0);
        let _ = encode(&w_small, &[1.0, 1.0], &[], &segs(),
                       Rounding::Stochastic, &mut r1);
        r2.next_u64();
        assert_eq!(r1.next_u32(), r2.next_u32());
    }

    #[test]
    fn scalar_reference_matches_batched_all_pools() {
        // large enough to cross PAR_MIN_ELEMS so pool > 1 really
        // exercises the scoped-thread fan-out (plus a ragged tail)
        let big = 9 * RNG_BLOCK + 137;
        let seg = vec![
            Segment {
                name: "big".into(),
                offset: 0,
                size: big,
                quantized: true,
                alpha_idx: Some(0),
            },
            Segment {
                name: "raw".into(),
                offset: big,
                size: 33,
                quantized: false,
                alpha_idx: None,
            },
        ];
        let dim = big + 33;
        let w = test_vec(dim, 13, 2.4);
        for mode in [Rounding::Deterministic, Rounding::Stochastic] {
            let mut r_ref = Pcg32::new(5, 5);
            let mut reference = WirePayload::default();
            encode_into_scalar(&w, &[1.1], &[], &seg, mode, &mut r_ref,
                               &mut reference);
            for pool in [1usize, 2, 4] {
                for kernel in [
                    KernelKind::Scalar,
                    KernelKind::Simd,
                    KernelKind::Auto,
                ] {
                    let mut r = Pcg32::new(5, 5);
                    let mut scratch = Vec::new();
                    let mut got = WirePayload::default();
                    encode_into_pooled(&w, &[1.1], &[], &seg, mode,
                                       kernel, &mut r, &mut scratch,
                                       pool, &mut got);
                    assert_eq!(got.codes, reference.codes,
                               "pool={pool} kernel={kernel} {mode:?}");
                    assert_eq!(got.raw, reference.raw);
                }
            }
        }
    }

    #[test]
    fn pooled_decode_matches_sequential() {
        // big enough to cross DEC_PAR_MIN_ELEMS so pool > 1 really
        // takes the decode_parallel path
        let big = DEC_PAR_MIN_ELEMS + 999;
        let seg = vec![
            Segment {
                name: "big".into(),
                offset: 0,
                size: big,
                quantized: true,
                alpha_idx: Some(0),
            },
            Segment {
                name: "raw".into(),
                offset: big,
                size: 21,
                quantized: false,
                alpha_idx: None,
            },
        ];
        let dim = big + 21;
        let w = test_vec(dim, 17, 1.8);
        let mut rng = Pcg32::new(3, 3);
        let p = encode(&w, &[0.9], &[], &seg, Rounding::Stochastic,
                       &mut rng);
        let mut seq = vec![0.0f32; dim];
        decode(&p, &seg, &mut seq);
        for pool in [2usize, 4] {
            let mut cache = DecodeLutCache::default();
            let mut par = vec![0.0f32; dim];
            decode_pooled(&p, &seg, &mut cache, pool, &mut par);
            assert_eq!(par, seq, "pool={pool}");
        }
    }

    #[test]
    fn lut_cache_hits_and_evicts() {
        let mut cache = DecodeLutCache::default();
        let a = cache.get(1.25);
        let b = cache.get(1.25);
        assert!(Arc::ptr_eq(&a, &b), "same alpha must hit");
        assert_eq!(cache.len(), 1);
        assert_eq!(a[0x7F], Fp8Params::new(1.25).decode(0x7F));
        for i in 0..(LUT_CACHE_CAP + 10) {
            cache.get(2.0 + i as f32 * 0.01);
        }
        assert_eq!(cache.len(), LUT_CACHE_CAP, "capacity bound");
    }

    #[test]
    fn mse_with_is_bit_identical_to_mse() {
        // not just "close": same quantize bits + same accumulation
        // order means mse_with must equal mse exactly, per kernel
        let seg = &segs()[2]; // offset 110, size 50 (n % 4 != 0 tail)
        let w = test_vec(160, 33, 1.4);
        let c1 = test_vec(160, 34, 1.4);
        let clients: Vec<&[f32]> = vec![&c1];
        let kw = [1.0f32];
        let us: Vec<f64> =
            (0..seg.size).map(|i| (i as f64 * 0.37) % 1.0).collect();
        let stats = SegmentStats::build(seg, &clients, &kw);
        for alpha in [0.4f32, 1.7, 12.0] {
            let reference = stats.mse(&w, seg, alpha, &us);
            for kernel in [
                KernelKind::Scalar,
                KernelKind::Simd,
                KernelKind::Auto,
            ] {
                let got = stats.mse_with(kernel, &w, seg, alpha, &us);
                assert_eq!(
                    got.to_bits(),
                    reference.to_bits(),
                    "kernel={kernel} alpha={alpha}"
                );
            }
        }
    }

    #[test]
    fn suffstats_match_naive_small() {
        let seg = &segs()[0];
        let w = test_vec(160, 23, 1.6);
        let c1 = test_vec(160, 24, 1.6);
        let c2 = test_vec(160, 25, 1.6);
        let clients: Vec<&[f32]> = vec![&c1, &c2];
        let kw = [0.6f32, 0.4];
        let us: Vec<f64> = (0..seg.size).map(|i| i as f64 / 100.0).collect();
        let stats = SegmentStats::build(seg, &clients, &kw);
        for alpha in [0.4f32, 0.9, 1.7] {
            let naive =
                segment_quant_mse(&w, seg, alpha, &clients, &kw, &us);
            let fast = stats.mse(&w, seg, alpha, &us);
            assert!(
                (naive - fast).abs() <= 1e-9 * (1.0 + naive.abs()),
                "alpha={alpha}: naive={naive} fast={fast}"
            );
        }
    }
}
