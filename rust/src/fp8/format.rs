//! The flexible-bias FP8 number format (1 sign, e=4 exponent, m=3
//! mantissa bits) — bit-level twin of `python/compile/kernels/ref.py`.
//!
//! The per-tensor clipping value `alpha` fixes a *real-valued* exponent
//! bias
//!
//! ```text
//!     b = 2^e - log2(alpha) + log2(2 - 2^-m) - 1
//! ```
//!
//! so the top code (E=15, M=7) decodes exactly to `alpha` (Kuzmin et
//! al.). All internal math is f64 — identical to the `quantize_np`
//! oracle that generates the golden vectors — and dequantized values
//! are cast to f32 at the end.

pub const M_BITS: u32 = 3;
pub const E_BITS: u32 = 4;
pub const E_MAX: i64 = (1 << E_BITS) - 1; // 15
pub const M_MAX: u32 = (1 << M_BITS) - 1; // 7
/// log2(2 - 2^-m)
pub const LOG2_TOP: f64 = 0.9068905956085185; // ln(1.875)/ln(2)

/// Per-tensor format parameters derived from alpha, precomputed once
/// per tensor per round (hot path works only with these).
#[derive(Clone, Copy, Debug)]
pub struct Fp8Params {
    pub alpha: f32,
    /// real-valued exponent bias b
    pub bias: f64,
    /// 2^b (scales |x| into code space)
    pub exp2_bias: f64,
    /// subnormal scale 2^(1-b-m)
    pub sub_scale: f64,
    /// per-exponent scale LUT: scales[c] = 2^(c-b-m) for c in 0..=15
    /// (§Perf: replaces a per-element exp2 in the encode hot loop;
    /// for c > 15 the value clips to ±alpha regardless of scale, so
    /// scales[15] is a safe stand-in)
    scales: [f64; 16],
}

impl Fp8Params {
    pub fn new(alpha: f32) -> Self {
        let a = alpha as f64;
        debug_assert!(a > 0.0, "alpha must be positive");
        let bias = (1u64 << E_BITS) as f64 - a.log2() + LOG2_TOP - 1.0;
        let mut scales = [0.0f64; 16];
        for (c, s) in scales.iter_mut().enumerate() {
            *s = (c as f64 - bias - M_BITS as f64).exp2();
        }
        Self {
            alpha,
            bias,
            exp2_bias: bias.exp2(),
            sub_scale: (1.0 - bias - M_BITS as f64).exp2(),
            scales,
        }
    }

    /// The per-exponent scale LUT (scales[c] = 2^(c-b-m), c in
    /// 0..=15). Read by the kernel layer (`fp8::simd`): every kernel
    /// must divide by exactly these doubles — not recomputed or
    /// reciprocal-multiplied variants — to stay bit-identical to
    /// [`Fp8Params::quantize`] / [`Fp8Params::encode`].
    #[inline]
    pub fn scales(&self) -> &[f64; 16] {
        &self.scales
    }

    /// floor(log2|x| + b) without calling log2 per element: exact
    /// binary exponent of u = |x| * 2^b via bit inspection.
    #[inline]
    pub fn code_exponent(&self, absx: f64) -> i64 {
        let u = absx * self.exp2_bias;
        // IEEE754 f64: exponent field gives floor(log2 u) exactly for
        // normal u (and u is astronomically far from subnormal here).
        let bits = u.to_bits();
        ((bits >> 52) & 0x7FF) as i64 - 1023
    }

    /// Quantization scale for |x| (paper Eq. 2) — LUT fast path.
    #[inline]
    pub fn scale(&self, absx: f64) -> f64 {
        let c = self.code_exponent(absx);
        if c > 1 {
            self.scales[(c.min(15)) as usize]
        } else {
            self.sub_scale
        }
    }

    /// exp2-per-element variant kept for the §Perf before/after bench.
    #[inline]
    pub fn scale_exp2(&self, absx: f64) -> f64 {
        let c = self.code_exponent(absx);
        if c > 1 {
            (c as f64 - self.bias - M_BITS as f64).exp2()
        } else {
            self.sub_scale
        }
    }

    /// Quantize one value to the grid, returning the dequantized f32.
    /// `u` in [0,1): 0.5 = deterministic round-half-up, random =
    /// unbiased stochastic rounding. NaN maps to 0 (matching
    /// [`Fp8Params::encode`], so wire and direct paths stay in
    /// lockstep on every input); infinities clip to ±alpha.
    #[inline]
    pub fn quantize(&self, x: f32, u: f64) -> f32 {
        if x == 0.0 {
            return 0.0;
        }
        if x.is_nan() {
            return 0.0;
        }
        let x64 = x as f64;
        let s = self.scale(x64.abs());
        let z = x64 / s;
        let f = z.floor();
        let up = if z - f >= u { 1.0 } else { 0.0 };
        let q = (f + up) * s;
        let a = self.alpha as f64;
        (q.clamp(-a, a)) as f32
    }

    /// Encode one value to its 8-bit code. NaN encodes to 0 (there is
    /// no NaN code on the flexible-bias grid, and ±alpha — the old
    /// saturating behaviour — would inject the largest representable
    /// magnitude from a poisoned input); infinities clip to ±alpha.
    #[inline]
    pub fn encode(&self, x: f32, u: f64) -> u8 {
        if x == 0.0 || x.is_nan() {
            return 0;
        }
        if !x.is_finite() {
            // saturate infinities to the top code (decodes ±alpha)
            return ((x < 0.0) as u8) << 7 | 0x7F;
        }
        let neg = x < 0.0;
        let absx = (x as f64).abs();
        // Rounding happens on the SIGNED z = x/s (matching quantize and
        // the Python oracle): for negative x, "round toward +inf with
        // probability frac(z)" is "round DOWN in magnitude when
        // 1 - frac(|z|) >= u".
        let round_up_mag = |z_abs: f64, f: f64| -> bool {
            if neg {
                1.0 - (z_abs - f) < u
            } else {
                z_abs - f >= u
            }
        };
        let mut c = self.code_exponent(absx);
        let n = if c > 1 {
            if c > E_MAX {
                return (neg as u8) << 7 | 0x7F; // clips to +-alpha
            }
            let s = self.scales[c as usize];
            let z = absx / s;
            let f = z.floor();
            let mut n = f as i64 + (round_up_mag(z, f) as i64);
            // mantissa overflow carries into the exponent
            if n >= (1 << (M_BITS + 1)) {
                c += 1;
                n = 1 << M_BITS;
            }
            // defensive: boundary jitter from the f64 exponent extract
            if n < (1 << M_BITS) {
                c -= 1;
                n = (1 << (M_BITS + 1)) - 1;
            }
            if c > E_MAX {
                return (neg as u8) << 7 | 0x7F; // clip to +-alpha
            }
            return (neg as u8) << 7
                | ((c as u8) << M_BITS)
                | (n as u8 & M_MAX as u8);
        } else {
            let z = absx / self.sub_scale;
            let f = z.floor();
            (f as i64 + (round_up_mag(z, f) as i64))
                .min((1 << (M_BITS + 1)) as i64)
        };
        // subnormal band: n in [0, 16]; n>=8 lands in E=1, n==16 in E=2
        let (e, m) = (n >> M_BITS, n & M_MAX as i64);
        (neg as u8) << 7 | ((e as u8) << M_BITS) | m as u8
    }

    /// Decode one 8-bit code to its f32 value.
    #[inline]
    pub fn decode(&self, code: u8) -> f32 {
        let neg = code & 0x80 != 0;
        let e = ((code >> M_BITS) & 0x0F) as i64;
        let m = (code & M_MAX as u8) as f64;
        let v = if e == 0 {
            self.sub_scale * m
        } else {
            (e as f64 - self.bias).exp2() * (1.0 + m / (1u64 << M_BITS) as f64)
        };
        let v = v as f32;
        if neg {
            -v
        } else {
            v
        }
    }

    /// 256-entry decode lookup table (hot-path decode is a byte index).
    pub fn decode_table(&self) -> [f32; 256] {
        let mut t = [0.0f32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            *slot = self.decode(i as u8);
        }
        t
    }

    /// Largest grid spacing (the scale bound S of Assumption 3):
    /// alpha * 2^-m / (2 - 2^-m).
    pub fn max_scale(&self) -> f64 {
        self.alpha as f64 * (0.5f64.powi(M_BITS as i32))
            / (2.0 - 0.5f64.powi(M_BITS as i32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_code_decodes_to_alpha() {
        for alpha in [0.01f32, 0.5, 1.0, 3.7, 128.0] {
            let p = Fp8Params::new(alpha);
            let v = p.decode(0x7F);
            assert!(
                (v - alpha).abs() <= alpha * 1e-6,
                "alpha={alpha} v={v}"
            );
        }
    }

    #[test]
    fn zero_code_is_zero() {
        let p = Fp8Params::new(1.0);
        assert_eq!(p.decode(0x00), 0.0);
        assert_eq!(p.decode(0x80), -0.0);
        assert_eq!(p.encode(0.0, 0.5), 0);
    }

    #[test]
    fn code_exponent_matches_log2() {
        let p = Fp8Params::new(2.31);
        for x in [1e-6f64, 0.013, 0.5, 1.0, 1.99, 2.3] {
            let direct = (x.log2() + p.bias).floor() as i64;
            assert_eq!(p.code_exponent(x), direct, "x={x}");
        }
    }

    #[test]
    fn encode_decode_equals_quantize() {
        let mut rng = crate::fp8::rng::Pcg32::new(11, 0);
        for alpha in [0.3f32, 1.0, 5.5] {
            let p = Fp8Params::new(alpha);
            for _ in 0..5000 {
                let x = (rng.uniform() - 0.5) * 4.0 * alpha;
                let u = rng.uniform_f64();
                let via_code = p.decode(p.encode(x, u));
                let direct = p.quantize(x, u);
                assert_eq!(via_code, direct, "x={x} alpha={alpha} u={u}");
            }
        }
    }

    #[test]
    fn quantize_is_idempotent() {
        let p = Fp8Params::new(1.7);
        let mut rng = crate::fp8::rng::Pcg32::new(12, 0);
        for _ in 0..2000 {
            let x = (rng.uniform() - 0.5) * 5.0;
            let q = p.quantize(x, 0.5);
            assert_eq!(p.quantize(q, 0.5), q, "x={x}");
        }
    }

    #[test]
    fn nan_encodes_to_zero_and_inf_clips() {
        // regression: NaN used to take the non-finite branch and
        // encode to ±0x7F (i.e. decode to ±alpha)
        for alpha in [0.3f32, 1.0, 7.5] {
            let p = Fp8Params::new(alpha);
            for u in [0.0f64, 0.3, 0.5, 0.999] {
                assert_eq!(p.encode(f32::NAN, u), 0, "alpha={alpha}");
                assert_eq!(p.encode(-f32::NAN, u), 0, "alpha={alpha}");
                assert_eq!(p.quantize(f32::NAN, u), 0.0);
                assert_eq!(p.decode(p.encode(f32::NAN, u)), 0.0);
                // infinities still saturate to ±alpha
                assert_eq!(p.encode(f32::INFINITY, u), 0x7F);
                assert_eq!(p.encode(f32::NEG_INFINITY, u), 0xFF);
                assert_eq!(p.quantize(f32::INFINITY, u), alpha);
                assert_eq!(p.quantize(f32::NEG_INFINITY, u), -alpha);
                // wire path and direct path agree on every edge input
                for x in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
                    assert_eq!(p.decode(p.encode(x, u)), p.quantize(x, u));
                }
            }
        }
    }

    #[test]
    fn clips_to_alpha() {
        let p = Fp8Params::new(1.5);
        assert_eq!(p.quantize(10.0, 0.5), 1.5);
        assert_eq!(p.quantize(-1e30, 0.5), -1.5);
        assert_eq!(p.decode(p.encode(99.0, 0.1)), 1.5);
    }

    #[test]
    fn decode_table_matches_decode() {
        let p = Fp8Params::new(0.77);
        let t = p.decode_table();
        for c in 0..=255u8 {
            assert_eq!(t[c as usize], p.decode(c));
        }
    }

    #[test]
    fn max_scale_is_top_bin() {
        let p = Fp8Params::new(4.0);
        // top bin: alpha - second-largest value
        let second = p.decode(0x7E);
        // f32 decode rounding allows ~1e-6 absolute slack at alpha=4
        assert!(((p.alpha - second) as f64 - p.max_scale()).abs() < 1e-5);
    }

    #[test]
    fn error_below_one_bin() {
        let p = Fp8Params::new(1.0);
        let mut rng = crate::fp8::rng::Pcg32::new(13, 0);
        for _ in 0..5000 {
            let x = (rng.uniform() - 0.5) * 1.9;
            let u = rng.uniform_f64();
            let q = p.quantize(x, u);
            let s = p.scale((x as f64).abs());
            assert!(
                ((q - x) as f64).abs() <= s * (1.0 + 1e-9),
                "x={x} q={q} s={s}"
            );
        }
    }
}
