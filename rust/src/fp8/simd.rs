//! FP8 kernel layer: one trait, three interchangeable bit-identical
//! implementations of the quantize/encode inner loops.
//!
//! The value mapping of the flexible-bias FP8 format lives in
//! [`Fp8Params`] (`format.rs`) — that scalar, branchy code is the
//! **oracle**. This module adds lane-batched kernels behind the
//! [`Fp8Kernel`] trait so the codec hot paths (`codec::encode_into_*`,
//! `codec::quantize_vec_*`, `SegmentStats::mse_with`) can swap
//! implementations without touching wire semantics:
//!
//! * [`ScalarKernel`] — calls the oracle per element. The reference.
//! * [`BranchfreeKernel`] — straight-line select-based twin of the
//!   oracle ([`quantize_bf`] / [`encode_bf`]): the portable fallback
//!   and the op-for-op template the explicit SIMD lanes follow.
//! * `Avx2Kernel` (behind the `simd` cargo feature, `x86_64` only,
//!   runtime-detected) — 4-wide `core::arch` lanes: vectorized
//!   exponent extraction, per-exponent scale lookup, `vdivpd` +
//!   `vroundpd` grid math.
//!
//! ## Exactness contract
//!
//! Every kernel must produce **byte-identical** output to the oracle
//! for *all* 2^32 f32 bit patterns, every alpha and every rounding
//! draw — NaN→0, saturation at ±alpha, the subnormal band and the
//! mantissa-carry boundaries included. This is possible because every
//! operation in the hot path is an exactly-rounded IEEE-754 op
//! (multiply, divide, floor, compare) over identical inputs: the
//! kernels divide by the *same* `scales[]` doubles the oracle uses
//! (never reciprocal-multiplied), and lane selects mirror the
//! oracle's branches one for one. The contract is enforced three
//! ways: a stratified differential sweep in tier-1
//! (`tests/exhaustive_fp8.rs`), the full 2^32 sweep in nightly CI
//! (`FEDFP8_EXHAUSTIVE_CHUNKS`), and property suites over the wire
//! paths (`tests/properties.rs`). `tools/fp8_kernel_conformance.c` is
//! the C twin used to pre-validate the algorithms exhaustively.
//!
//! Because all kernels are bit-identical, [`KernelKind`] is a pure
//! wall-clock knob — like `--parallelism`, it is excluded from the
//! config fingerprint and never changes a trajectory.

use super::format::Fp8Params;

/// Rounding draws for one slice: one shared constant (deterministic
/// round-half-up) or one `u` per element (stochastic, from the
/// counter-derived wire streams).
#[derive(Clone, Copy)]
pub enum Draws<'a> {
    Const(f64),
    Slice(&'a [f64]),
}

impl Draws<'_> {
    /// Draw for element `i` of the slice.
    #[inline]
    fn at(&self, i: usize) -> f64 {
        match self {
            Draws::Const(u) => *u,
            Draws::Slice(us) => us[i],
        }
    }
}

/// A quantize/encode inner-loop implementation. Implementations must
/// be bit-identical to the scalar oracle (see the module docs) and
/// `Sync` (one kernel instance serves every worker thread).
pub trait Fp8Kernel: Sync {
    fn name(&self) -> &'static str;

    /// Encode `src` to 8-bit codes in `dst` (`dst.len() == src.len()`;
    /// `Draws::Slice` must cover `src.len()` elements — kernels panic
    /// on a short slice, never read out of bounds).
    fn encode_slice(
        &self,
        p: &Fp8Params,
        src: &[f32],
        us: Draws<'_>,
        dst: &mut [u8],
    );

    /// Quantize `data` in place onto the FP8 grid.
    fn quantize_slice(&self, p: &Fp8Params, data: &mut [f32], us: Draws<'_>);
}

/// Branch-free twin of [`Fp8Params::quantize`]: the same IEEE op
/// sequence with the oracle's branches turned into selects, so a
/// compiler (or the explicit AVX2 lanes, which follow this function
/// op for op) can evaluate all paths and blend. Bit-identical to the
/// oracle for every input — enforced by `tests/exhaustive_fp8.rs`.
#[inline]
pub fn quantize_bf(p: &Fp8Params, x: f32, u: f64) -> f32 {
    let x64 = x as f64;
    let absx = x64.abs();
    let bits = (absx * p.exp2_bias).to_bits();
    let c = ((bits >> 52) & 0x7FF) as i64 - 1023;
    let is_sub = c <= 1;
    // clamped index keeps the lookup in-bounds for every lane; lanes
    // with c <= 1 select sub_scale and c > 15 saturates after the
    // divide, exactly like the oracle's early returns
    let s = if is_sub {
        p.sub_scale
    } else {
        p.scales()[c.clamp(0, 15) as usize]
    };
    let z = x64 / s;
    let f = z.floor();
    let up = if z - f >= u { 1.0 } else { 0.0 };
    let a = p.alpha as f64;
    // clamp is the oracle's exact op; NaN q (x NaN/±0 lanes) passes
    // through and is overridden by the final select
    let q = ((f + up) * s).clamp(-a, a);
    if x == 0.0 || x.is_nan() {
        0.0
    } else {
        q as f32
    }
}

/// Branch-free twin of [`Fp8Params::encode`] (see [`quantize_bf`]).
///
/// The oracle's early returns become final selects: saturation is
/// `c_adj > 15` (for any original `c > 15` the clamped-scale divide
/// leaves `z >= 16`, so the mantissa carry always pushes `c_adj` past
/// 15), infinities land in the saturation select, and NaN/±0 are
/// overridden to code 0 at the end.
#[inline]
pub fn encode_bf(p: &Fp8Params, x: f32, u: f64) -> u8 {
    let x64 = x as f64;
    let absx = x64.abs();
    let bits = (absx * p.exp2_bias).to_bits();
    let c = ((bits >> 52) & 0x7FF) as i64 - 1023;
    let is_sub = c <= 1;
    let s = if is_sub {
        p.sub_scale
    } else {
        p.scales()[c.clamp(0, 15) as usize]
    };
    let z = absx / s;
    let f = z.floor();
    let frac = z - f;
    let neg = x64 < 0.0;
    let up = if neg { 1.0 - frac < u } else { frac >= u };
    // clamp before the int conversion: saturated lanes can carry huge
    // or NaN f (f64::min maps NaN to 17); unsaturated lanes never
    // exceed 16, so the clamp is a no-op wherever the result is used
    let n = f.min(17.0) as i64 + up as i64;
    let c_adj = c + (n > 15) as i64 - (n < 8) as i64;
    let n_adj = if n > 15 {
        8
    } else if n < 8 {
        15
    } else {
        n
    };
    let sat = c_adj > 15;
    let code_norm = if sat {
        0x7F
    } else {
        ((c_adj as u8) << 3) | (n_adj as u8 & 7)
    };
    let code_sub = n.min(16) as u8;
    let mag = if is_sub { code_sub } else { code_norm };
    let code = ((neg as u8) << 7) | mag;
    if x == 0.0 || x.is_nan() {
        0
    } else {
        code
    }
}

/// Per-element oracle calls — the reference arm of every differential
/// test and the "before" arm of the kernel bench.
pub struct ScalarKernel;

impl Fp8Kernel for ScalarKernel {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn encode_slice(
        &self,
        p: &Fp8Params,
        src: &[f32],
        us: Draws<'_>,
        dst: &mut [u8],
    ) {
        for (i, (d, &x)) in dst.iter_mut().zip(src.iter()).enumerate() {
            *d = p.encode(x, us.at(i));
        }
    }

    fn quantize_slice(
        &self,
        p: &Fp8Params,
        data: &mut [f32],
        us: Draws<'_>,
    ) {
        for (i, d) in data.iter_mut().enumerate() {
            *d = p.quantize(*d, us.at(i));
        }
    }
}

/// Portable branch-free kernel: [`encode_bf`] / [`quantize_bf`] per
/// element. The fallback when the `simd` feature is off or the CPU
/// lacks AVX2, and the semantic template for the explicit lanes.
pub struct BranchfreeKernel;

impl Fp8Kernel for BranchfreeKernel {
    fn name(&self) -> &'static str {
        "branchfree"
    }

    fn encode_slice(
        &self,
        p: &Fp8Params,
        src: &[f32],
        us: Draws<'_>,
        dst: &mut [u8],
    ) {
        for (i, (d, &x)) in dst.iter_mut().zip(src.iter()).enumerate() {
            *d = encode_bf(p, x, us.at(i));
        }
    }

    fn quantize_slice(
        &self,
        p: &Fp8Params,
        data: &mut [f32],
        us: Draws<'_>,
    ) {
        for (i, d) in data.iter_mut().enumerate() {
            *d = quantize_bf(p, *d, us.at(i));
        }
    }
}

/// Explicit AVX2 lanes — 4 f64 grid divisions per `vdivpd`. Gated on
/// the `simd` feature at compile time and `is_x86_feature_detected!`
/// at dispatch time; [`KernelKind::resolve`] is the only constructor
/// path, so the unsafe `target_feature` calls below only ever run on
/// CPUs that advertise AVX2.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    use std::arch::x86_64::*;

    use super::{encode_bf, quantize_bf, Draws, Fp8Kernel};
    use crate::fp8::format::Fp8Params;

    pub fn available() -> bool {
        std::arch::is_x86_feature_detected!("avx2")
    }

    pub struct Avx2Kernel;

    /// The lane loops read `Draws::Slice` with unchecked vector
    /// loads, so the safe trait boundary must enforce the length
    /// contract the scalar kernels enforce implicitly (their `us[i]`
    /// indexing panics) — otherwise a short slice would be UB, not a
    /// panic.
    fn check_draws(us: Draws<'_>, n: usize) {
        if let Draws::Slice(s) = us {
            assert!(
                s.len() >= n,
                "Draws::Slice covers {} elements, data has {n}",
                s.len()
            );
        }
    }

    impl Fp8Kernel for Avx2Kernel {
        fn name(&self) -> &'static str {
            "avx2"
        }

        fn encode_slice(
            &self,
            p: &Fp8Params,
            src: &[f32],
            us: Draws<'_>,
            dst: &mut [u8],
        ) {
            check_draws(us, src.len());
            // SAFETY: KernelKind::resolve only returns this kernel
            // after available() confirmed AVX2 support, and
            // check_draws guarantees the slice loads stay in bounds.
            unsafe { encode_slice_avx2(p, src, us, dst) }
        }

        fn quantize_slice(
            &self,
            p: &Fp8Params,
            data: &mut [f32],
            us: Draws<'_>,
        ) {
            check_draws(us, data.len());
            // SAFETY: as above — dispatch is detection-gated and the
            // draw slice is length-checked.
            unsafe { quantize_slice_avx2(p, data, us) }
        }
    }

    /// Low dwords of the four 64-bit lanes — narrows exponents and
    /// compare masks (whose dword halves are equal) to i32x4.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn narrow64(v: __m256i) -> __m128i {
        _mm256_castsi256_si128(_mm256_permutevar8x32_epi32(
            v,
            _mm256_setr_epi32(0, 2, 4, 6, 0, 0, 0, 0),
        ))
    }

    /// Per-exponent scale lookup via four indexed loads — measurably
    /// faster than `vgatherdpd` on older/virtualized parts and
    /// bit-identical: the loads read the exact `scales[]` doubles the
    /// oracle divides by.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn scale_lookup(scales: &[f64; 16], idx: __m128i) -> __m256d {
        // SAFETY: idx lanes are clamped to [0, 15] by the caller.
        _mm256_setr_pd(
            *scales.get_unchecked(_mm_extract_epi32::<0>(idx) as usize),
            *scales.get_unchecked(_mm_extract_epi32::<1>(idx) as usize),
            *scales.get_unchecked(_mm_extract_epi32::<2>(idx) as usize),
            *scales.get_unchecked(_mm_extract_epi32::<3>(idx) as usize),
        )
    }

    /// Shared lane prologue: widen 4 f32, extract the binary exponent
    /// of |x|·2^b, and select the grid scale — the vector form of
    /// `code_exponent` + `scale`. Returns (x, c32, is_sub32, s).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn lanes_prologue(
        p: &Fp8Params,
        ptr: *const f32,
    ) -> (__m256d, __m128i, __m128i, __m256d) {
        let x = _mm256_cvtps_pd(_mm_loadu_ps(ptr));
        let absx = _mm256_andnot_pd(_mm256_set1_pd(-0.0), x);
        let ub = _mm256_mul_pd(absx, _mm256_set1_pd(p.exp2_bias));
        let ebits = _mm256_and_si256(
            _mm256_srli_epi64::<52>(_mm256_castpd_si256(ub)),
            _mm256_set1_epi64x(0x7FF),
        );
        let c32 = _mm_sub_epi32(narrow64(ebits), _mm_set1_epi32(1023));
        let is_sub32 = _mm_cmpgt_epi32(_mm_set1_epi32(2), c32);
        let idx = _mm_min_epi32(
            _mm_max_epi32(c32, _mm_setzero_si128()),
            _mm_set1_epi32(15),
        );
        let s = _mm256_blendv_pd(
            scale_lookup(p.scales(), idx),
            _mm256_set1_pd(p.sub_scale),
            _mm256_castsi256_pd(_mm256_cvtepi32_epi64(is_sub32)),
        );
        (x, c32, is_sub32, s)
    }

    /// NaN-or-±0 lanes (the oracle's "encode/quantize to zero" early
    /// returns), as a 64-bit mask.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn kill_mask(x: __m256d) -> __m256i {
        _mm256_castpd_si256(_mm256_or_pd(
            _mm256_cmp_pd::<_CMP_EQ_OQ>(x, _mm256_setzero_pd()),
            _mm256_cmp_pd::<_CMP_UNORD_Q>(x, x),
        ))
    }

    #[target_feature(enable = "avx2")]
    unsafe fn quantize_slice_avx2(
        p: &Fp8Params,
        data: &mut [f32],
        us: Draws<'_>,
    ) {
        let n = data.len();
        let n4 = n & !3usize;
        let a = _mm256_set1_pd(p.alpha as f64);
        let neg_a = _mm256_sub_pd(_mm256_setzero_pd(), a);
        let mut i = 0usize;
        while i < n4 {
            let u = match us {
                Draws::Const(c) => _mm256_set1_pd(c),
                Draws::Slice(s) => _mm256_loadu_pd(s.as_ptr().add(i)),
            };
            let (x, _c32, _is_sub, s) =
                lanes_prologue(p, data.as_ptr().add(i));
            // signed z, exactly like the oracle's quantize
            let z = _mm256_div_pd(x, s);
            let f = _mm256_floor_pd(z);
            let up = _mm256_and_pd(
                _mm256_cmp_pd::<_CMP_GE_OQ>(_mm256_sub_pd(z, f), u),
                _mm256_set1_pd(1.0),
            );
            let q = _mm256_mul_pd(_mm256_add_pd(f, up), s);
            let q = _mm256_min_pd(_mm256_max_pd(q, neg_a), a);
            let qf = _mm256_cvtpd_ps(q);
            let kill =
                _mm_castsi128_ps(narrow64(kill_mask(x)));
            _mm_storeu_ps(
                data.as_mut_ptr().add(i),
                _mm_andnot_ps(kill, qf),
            );
            i += 4;
        }
        for j in n4..n {
            data[j] = quantize_bf(p, data[j], us.at(j));
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn encode_slice_avx2(
        p: &Fp8Params,
        src: &[f32],
        us: Draws<'_>,
        dst: &mut [u8],
    ) {
        let n = src.len();
        let n4 = n & !3usize;
        let mut i = 0usize;
        while i < n4 {
            let u = match us {
                Draws::Const(c) => _mm256_set1_pd(c),
                Draws::Slice(s) => _mm256_loadu_pd(s.as_ptr().add(i)),
            };
            let (x, c32, is_sub32, s) =
                lanes_prologue(p, src.as_ptr().add(i));
            let absx = _mm256_andnot_pd(_mm256_set1_pd(-0.0), x);
            // magnitude z, sign-asymmetric rounding — the oracle's
            // round_up_mag closure, lane-blended on the sign mask
            let z = _mm256_div_pd(absx, s);
            let f = _mm256_floor_pd(z);
            let frac = _mm256_sub_pd(z, f);
            let neg_pd =
                _mm256_cmp_pd::<_CMP_LT_OQ>(x, _mm256_setzero_pd());
            let up_pos = _mm256_cmp_pd::<_CMP_GE_OQ>(frac, u);
            let up_neg = _mm256_cmp_pd::<_CMP_LT_OQ>(
                _mm256_sub_pd(_mm256_set1_pd(1.0), frac),
                u,
            );
            let up_pd = _mm256_blendv_pd(up_pos, up_neg, neg_pd);
            // clamp huge/NaN f before the i32 conversion (min maps
            // NaN lanes to 17; see encode_bf)
            let fi = _mm256_cvttpd_epi32(_mm256_min_pd(
                f,
                _mm256_set1_pd(17.0),
            ));
            // up mask lanes are 0/-1: subtracting adds the increment
            let n32 =
                _mm_sub_epi32(fi, narrow64(_mm256_castpd_si256(up_pd)));
            let carry = _mm_cmpgt_epi32(n32, _mm_set1_epi32(15));
            let jitter = _mm_cmpgt_epi32(_mm_set1_epi32(8), n32);
            let c_adj =
                _mm_add_epi32(_mm_sub_epi32(c32, carry), jitter);
            let n_adj =
                _mm_blendv_epi8(n32, _mm_set1_epi32(8), carry);
            let n_adj =
                _mm_blendv_epi8(n_adj, _mm_set1_epi32(15), jitter);
            let sat = _mm_cmpgt_epi32(c_adj, _mm_set1_epi32(15));
            let code_norm = _mm_or_si128(
                _mm_slli_epi32::<3>(c_adj),
                _mm_and_si128(n_adj, _mm_set1_epi32(7)),
            );
            let code_norm = _mm_blendv_epi8(
                code_norm,
                _mm_set1_epi32(0x7F),
                sat,
            );
            let code_sub = _mm_min_epi32(n32, _mm_set1_epi32(16));
            let mag = _mm_blendv_epi8(code_norm, code_sub, is_sub32);
            let neg32 = narrow64(_mm256_castpd_si256(neg_pd));
            let code = _mm_or_si128(
                mag,
                _mm_and_si128(neg32, _mm_set1_epi32(0x80)),
            );
            let code =
                _mm_andnot_si128(narrow64(kill_mask(x)), code);
            // pack the four dword codes into four bytes
            let packed = _mm_shuffle_epi8(
                code,
                _mm_setr_epi8(
                    0, 4, 8, 12, -1, -1, -1, -1, -1, -1, -1, -1, -1,
                    -1, -1, -1,
                ),
            );
            let out4 = (_mm_cvtsi128_si32(packed) as u32).to_le_bytes();
            dst[i..i + 4].copy_from_slice(&out4);
            i += 4;
        }
        for j in n4..n {
            dst[j] = encode_bf(p, src[j], us.at(j));
        }
    }
}

/// Kernel selection — the value of the `--fp8-kernel` knob. A pure
/// wall-clock choice: every kernel is bit-identical (the conformance
/// harness makes that a tested invariant), so this is deliberately
/// excluded from `ExperimentConfig::fingerprint`, like
/// `--parallelism`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelKind {
    /// Pin the per-element oracle path.
    Scalar,
    /// Pin the vectorized path: explicit AVX2 lanes when compiled
    /// with `--features simd` on an AVX2 host, the portable
    /// branch-free kernel otherwise.
    Simd,
    /// Best available: AVX2 lanes when compiled + detected, else the
    /// scalar oracle (the branchy scalar beats the portable
    /// branch-free code on current compilers — see
    /// `BENCH_fp8_kernels.json`).
    #[default]
    Auto,
}

impl KernelKind {
    /// Resolve to a concrete kernel (detection-gated for AVX2).
    pub fn resolve(self) -> &'static dyn Fp8Kernel {
        match self {
            KernelKind::Scalar => &ScalarKernel,
            KernelKind::Simd => {
                #[cfg(all(feature = "simd", target_arch = "x86_64"))]
                if avx2::available() {
                    return &avx2::Avx2Kernel;
                }
                &BranchfreeKernel
            }
            KernelKind::Auto => {
                #[cfg(all(feature = "simd", target_arch = "x86_64"))]
                if avx2::available() {
                    return &avx2::Avx2Kernel;
                }
                &ScalarKernel
            }
        }
    }
}

impl std::str::FromStr for KernelKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "scalar" => Ok(KernelKind::Scalar),
            "simd" => Ok(KernelKind::Simd),
            "auto" => Ok(KernelKind::Auto),
            other => Err(format!(
                "unknown fp8 kernel '{other}' (scalar|simd|auto)"
            )),
        }
    }
}

impl std::fmt::Display for KernelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Simd => "simd",
            KernelKind::Auto => "auto",
        };
        f.write_str(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::rng::Pcg32;

    fn edge_inputs(alpha: f32) -> Vec<f32> {
        let mut xs = vec![
            0.0,
            -0.0,
            f32::NAN,
            -f32::NAN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::MIN_POSITIVE,
            -f32::MIN_POSITIVE,
            f32::from_bits(1),           // smallest subnormal
            f32::from_bits(0x8000_0001),
            f32::MAX,
            f32::MIN,
            alpha,
            -alpha,
            alpha * 0.999_999,
            alpha * 1.000_001,
            alpha * 2.0,
        ];
        // dense neighborhood around every decodable grid magnitude
        let p = Fp8Params::new(alpha);
        for code in 0u8..=0x7F {
            let v = p.decode(code);
            let b = v.to_bits();
            for d in -2i32..=2 {
                let vb = f32::from_bits(b.wrapping_add(d as u32));
                xs.push(vb);
                xs.push(-vb);
            }
        }
        xs
    }

    #[test]
    fn branchfree_matches_oracle_on_edges_and_random() {
        for alpha in [0.0625f32, 1.0, 3.7, 117.0] {
            let p = Fp8Params::new(alpha);
            let mut rng = Pcg32::new(41, 7);
            let mut xs = edge_inputs(alpha);
            for _ in 0..4000 {
                xs.push(f32::from_bits(rng.next_u32()));
            }
            for &x in &xs {
                for u in [0.0, 0.25, 0.5, 0.999_999, rng.uniform_f64()]
                {
                    assert_eq!(
                        encode_bf(&p, x, u),
                        p.encode(x, u),
                        "encode x={x} ({:#010x}) alpha={alpha} u={u}",
                        x.to_bits()
                    );
                    assert_eq!(
                        quantize_bf(&p, x, u).to_bits(),
                        p.quantize(x, u).to_bits(),
                        "quantize x={x} ({:#010x}) alpha={alpha} u={u}",
                        x.to_bits()
                    );
                }
            }
        }
    }

    #[test]
    fn all_kernels_agree_on_slices() {
        // every resolvable kernel, odd tail lengths, const + slice
        // draws — the slice-level twin of the scalar equivalence test
        let kernels = [
            KernelKind::Scalar.resolve(),
            KernelKind::Simd.resolve(),
            KernelKind::Auto.resolve(),
        ];
        let mut rng = Pcg32::new(42, 0);
        for alpha in [0.3f32, 1.0, 9.5] {
            let p = Fp8Params::new(alpha);
            for n in [0usize, 1, 3, 4, 5, 63, 64, 65, 1021] {
                let src: Vec<f32> = (0..n)
                    .map(|_| (rng.uniform() - 0.5) * 3.0 * alpha)
                    .collect();
                let us: Vec<f64> =
                    (0..n).map(|_| rng.uniform_f64()).collect();
                for draws in
                    [Draws::Const(0.5), Draws::Slice(&us)]
                {
                    let mut ref_codes = vec![0u8; n];
                    ScalarKernel.encode_slice(
                        &p, &src, draws, &mut ref_codes,
                    );
                    let mut ref_q = src.clone();
                    ScalarKernel.quantize_slice(&p, &mut ref_q, draws);
                    for k in &kernels {
                        let mut codes = vec![0u8; n];
                        k.encode_slice(&p, &src, draws, &mut codes);
                        assert_eq!(
                            codes,
                            ref_codes,
                            "{} encode n={n} alpha={alpha}",
                            k.name()
                        );
                        let mut q = src.clone();
                        k.quantize_slice(&p, &mut q, draws);
                        let qb: Vec<u32> =
                            q.iter().map(|v| v.to_bits()).collect();
                        let rb: Vec<u32> =
                            ref_q.iter().map(|v| v.to_bits()).collect();
                        assert_eq!(
                            qb,
                            rb,
                            "{} quantize n={n} alpha={alpha}",
                            k.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn kind_parses_and_resolves() {
        assert_eq!("scalar".parse(), Ok(KernelKind::Scalar));
        assert_eq!("simd".parse(), Ok(KernelKind::Simd));
        assert_eq!("auto".parse(), Ok(KernelKind::Auto));
        assert!("avx512".parse::<KernelKind>().is_err());
        assert_eq!(KernelKind::Scalar.resolve().name(), "scalar");
        // Simd resolves to the portable fallback without the feature
        // (or without AVX2); either way it must resolve
        let simd = KernelKind::Simd.resolve().name();
        assert!(simd == "branchfree" || simd == "avx2", "{simd}");
        assert_eq!(KernelKind::default(), KernelKind::Auto);
        assert_eq!(KernelKind::Auto.to_string(), "auto");
    }
}
