//! `fedfp8` — launcher for FP8FedAvg-UQ experiments.
//!
//! ```text
//! fedfp8 run --preset lenet_c10:uq+:iid [--rounds N] [--seed S]
//!            [--parallelism T]  # concurrent client workers per round
//!            [--fp8-kernel scalar|simd|auto]  # codec inner loops
//!            [--cohort P | --cohort-frac F]  # per-round cohort size
//!            [--agg flat|tree:G]  # aggregation topology (G mid-tier
//!            # nodes; bit-identical to flat by construction)
//!            [--snapshot-dir D [--snapshot-every N] [--resume]]
//!            # durable round state: atomic crc-framed snapshots
//!            # every N rounds; --resume continues from the newest
//!            # valid generation, bit-identical to an uninterrupted
//!            # run (config fingerprint enforced)
//! fedfp8 run --preset ... --role server --listen 127.0.0.1:7878 \
//!            --workers 2        # drive remote workers over TCP
//!            [--net-inflight 4|adaptive] # in-flight window per
//!            # connection (adaptive: grown from observed latency)
//!            [--heartbeat-ms T]   # liveness probe interval (0=off;
//!            # default min(1000, timeout/4))
//!            [--net-hedge-ms T]   # duplicate a straggler's job onto
//!            # a second worker after T ms unanswered (0=off)
//!            [--net-token SECRET] # handshake auth (both sides must
//!            # carry the same secret; REQUIRED beyond localhost)
//! fedfp8 run --preset ... --role worker --connect 127.0.0.1:7878
//!            # serve client jobs for a --role server coordinator;
//!            # must be launched with the identical preset/overrides
//!            # (enforced by the config-fingerprint handshake).
//!            # Reconnects with its outcome cache intact after drops.
//! fedfp8 run --preset ... --agg tree:G --role server --listen ADDR
//!            # networked tree root: accepts G --role aggregator
//!            # connections and dispatches whole cohort shards;
//!            # bit-identical to in-process tree:G and to flat
//! fedfp8 run --preset ... --agg tree:G --role aggregator \
//!            --connect ROOT --listen ADDR [--workers N] [--shard i/G]
//!            # mid-tier tree node: serves its cohort shard on N
//!            # downstream workers, folds their uplinks and forwards
//!            # one Partial frame per round upstream. --shard pins
//!            # the preferred shard index (the root falls back to
//!            # any live aggregator on a death — still bit-identical)
//! fedfp8 run --role daemon --queue-dir D [--daemon-slots N]
//!            # run-scheduler daemon: execute every <id>.job.json in
//!            # D (filename order; N jobs at a time), persisting
//!            # per-job state atomically. A daemon killed mid-job
//!            # resumes it bit-identically on the next launch via
//!            # the snapshot layer.
//!            [--telemetry-listen ADDR]  # NDJSON event feed (also
//!            # valid on plain/server runs); clients get one JSON
//!            # object per round/run event, and "/status\n" answers
//!            # with a job-summary frame
//! fedfp8 table1 [--rounds N] [--seeds 3] [--models lenet_c10,...]
//! fedfp8 table2 [--rounds N] [--seeds 3]
//! fedfp8 fig2   [--rounds N] [--model lenet_c10]
//! fedfp8 info                      # artifact + platform inventory
//! fedfp8 presets                   # list experiment presets
//! ```
//!
//! Results land in `artifacts/results/*.csv` plus stdout tables.

use std::net::TcpListener;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use fedfp8::config::{
    telemetry_listen_from_args, AggMode, DaemonCfg, ExperimentConfig,
    NetCfg, NetRole, SnapshotCfg,
};
use fedfp8::coordinator::transport::InProcessTransport;
use fedfp8::coordinator::{build_world, RunResult, Server, World};
use fedfp8::daemon::{run_queue, Queue, TelemetryHub};
use fedfp8::net::{self, Hello, PeerRole};
use fedfp8::runtime::{default_dir, Engine, Manifest};
use fedfp8::util::cli::Args;

use fedfp8::bench_tables;

fn apply_overrides(
    mut cfg: ExperimentConfig,
    args: &Args,
) -> Result<ExperimentConfig> {
    cfg.rounds = args.parse_or("rounds", cfg.rounds)?;
    cfg.clients = args.parse_or("clients", cfg.clients)?;
    cfg.participation =
        args.parse_or("participation", cfg.participation)?;
    cfg.parallelism = args.parse_or("parallelism", cfg.parallelism)?;
    cfg.fp8_kernel = args.parse_or("fp8-kernel", cfg.fp8_kernel)?;
    cfg.seed = args.parse_or("seed", cfg.seed)?;
    cfg.lr = args.parse_or("lr", cfg.lr)?;
    cfg.weight_decay = args.parse_or("wd", cfg.weight_decay)?;
    cfg.eval_every = args.parse_or("eval-every", cfg.eval_every)?;
    cfg.n_train = args.parse_or("n-train", cfg.n_train)?;
    cfg.n_test = args.parse_or("n-test", cfg.n_test)?;
    // --cohort / --cohort-frac / --agg, then whole-config validation
    cfg.apply_scale_flags(args)?;
    Ok(cfg)
}

/// Print the run result + engine stats and write the accuracy curve.
fn report_run(
    engine: &Engine,
    result: &RunResult,
) -> Result<()> {
    let dir = default_dir();
    let csv = dir.join("results").join(format!("{}.csv", result.name));
    result.to_csv(&csv)?;
    println!(
        "final accuracy {:.4}  best {:.4}  total comm {:.2} MiB  \
         wall {:.1}s\ncurve -> {}",
        result.final_accuracy,
        result.best_accuracy(),
        result.total_bytes as f64 / (1 << 20) as f64,
        result.wall_secs,
        csv.display()
    );
    let st = engine.stats();
    println!(
        "engine: {} compilations ({:.1}s), {} executions ({:.1}s exec, \
         {:.1}s marshal)",
        st.compilations,
        st.compile_ns as f64 * 1e-9,
        st.executions,
        st.execute_ns as f64 * 1e-9,
        st.marshal_ns as f64 * 1e-9,
    );
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    // --role daemon first: it takes no preset (jobs carry their own
    // configs) and NetCfg rejects roles it doesn't know
    if let Some(d) = DaemonCfg::from_args(args)? {
        return cmd_daemon(args, d);
    }
    let preset = args
        .get("preset")
        .unwrap_or("lenet_c10:uq:iid")
        .to_string();
    let cfg = apply_overrides(ExperimentConfig::preset(&preset)?, args)?;
    let net = NetCfg::from_args(args)?;
    let snap = SnapshotCfg::from_args(args, net.as_ref())?;
    let telemetry = telemetry_listen_from_args(args, net.as_ref())?;
    match net {
        None => run_local(&preset, cfg, snap, telemetry),
        Some(n) if n.role == NetRole::Server => {
            run_net_server(&preset, cfg, n, snap, telemetry)
        }
        Some(n) if n.role == NetRole::Aggregator => {
            run_net_aggregator(cfg, n)
        }
        Some(n) => run_net_worker(cfg, n),
    }
}

/// Bind the NDJSON feed when `--telemetry-listen` was given.
fn bind_telemetry(
    addr: Option<String>,
) -> Result<Option<std::sync::Arc<TelemetryHub>>> {
    let Some(addr) = addr else {
        return Ok(None);
    };
    let hub = TelemetryHub::bind(&addr)?;
    println!("[telemetry] listening on {}", hub.local_addr());
    Ok(Some(hub))
}

/// `--role daemon`: execute every job spec in `--queue-dir`,
/// `--daemon-slots` at a time. Each job gets its own `Engine` (slots
/// may run concurrently), snapshots under `<id>.snaps/`, and is
/// always armed with resume — so a daemon killed mid-job continues
/// that job bit-identically on the next launch.
fn cmd_daemon(args: &Args, d: DaemonCfg) -> Result<()> {
    let telemetry = telemetry_listen_from_args(args, None)?;
    let hub = bind_telemetry(telemetry)?;
    let queue = Queue::open(&d.queue_dir)?;
    println!(
        "[daemon] queue={} slots={}",
        queue.dir().display(),
        d.slots
    );
    let report = run_queue(
        &queue,
        d.slots,
        |job, state| {
            if let Some(h) = &hub {
                h.job_state(&job.id, state);
            }
            println!("[daemon] {} -> {}", job.id, state.as_str());
        },
        |job| {
            let dir = default_dir();
            let engine = Engine::new(&dir)?;
            let manifest = Manifest::load(&dir)?;
            let mut server =
                Server::new(&engine, &manifest, job.cfg.clone())?;
            server.set_verbose(true);
            if let Some(h) = &hub {
                server.set_telemetry(h.clone());
            }
            let snaps = queue.snaps_dir(&job.id);
            server.set_snapshot(snaps.clone(), job.snapshot_every);
            server.resume_from(&snaps).with_context(|| {
                format!("resuming job '{}'", job.id)
            })?;
            let result = server.run()?;
            report_run(&engine, &result)
        },
    )?;
    println!(
        "[daemon] done={} failed={} skipped={}",
        report.done.len(),
        report.failed.len(),
        report.skipped.len()
    );
    if let Some(h) = &hub {
        h.shutdown();
    }
    if !report.failed.is_empty() {
        bail!(
            "{} job(s) failed: {}",
            report.failed.len(),
            report
                .failed
                .iter()
                .map(|(id, _)| id.as_str())
                .collect::<Vec<_>>()
                .join(", ")
        );
    }
    Ok(())
}

/// Arm the durability layer on a built server: install the write
/// cadence and, under `--resume`, load the newest valid generation
/// (bit-identical continuation; a fingerprint mismatch aborts here).
fn arm_snapshots(server: &mut Server<'_>, snap: &SnapshotCfg) -> Result<()> {
    let Some(dir) = snap.dir.clone() else {
        return Ok(());
    };
    server.set_snapshot(dir.clone(), snap.every);
    if snap.resume {
        let start = server
            .resume_from(&dir)
            .with_context(|| format!("--resume from {}", dir.display()))?;
        if start == 0 {
            println!(
                "[resume] no snapshot in {} yet; starting at round 0",
                dir.display()
            );
        }
    }
    Ok(())
}

fn run_local(
    preset: &str,
    cfg: ExperimentConfig,
    snap: SnapshotCfg,
    telemetry: Option<String>,
) -> Result<()> {
    let dir = default_dir();
    let engine = Engine::new(&dir)?;
    let manifest = Manifest::load(&dir)?;
    println!(
        "platform={}  preset={preset}  rounds={}  K={}  P={}  \
         agg={}  parallelism={}  fp8-kernel={} ({})",
        engine.platform(),
        cfg.rounds,
        cfg.clients,
        cfg.participation,
        cfg.agg,
        cfg.parallelism,
        cfg.fp8_kernel,
        cfg.fp8_kernel.resolve().name(),
    );
    let hub = bind_telemetry(telemetry)?;
    let mut server = Server::new(&engine, &manifest, cfg)?;
    server.set_verbose(true);
    if let Some(h) = &hub {
        server.set_telemetry(h.clone());
    }
    arm_snapshots(&mut server, &snap)?;
    let result = server.run()?;
    report_run(&engine, &result)
}

/// `--role server`: accept the handshaken downstream pool —
/// `--workers` worker connections under `--agg flat`, or G `--role
/// aggregator` connections under `--agg tree:G` (the networked tree:
/// the root dispatches whole cohort shards and absorbs their Partial
/// frames; bit-identical to the in-process tree and to flat) — then
/// drive the ordinary round loop through a `SocketTransport`.
fn run_net_server(
    preset: &str,
    cfg: ExperimentConfig,
    net: NetCfg,
    snap: SnapshotCfg,
    telemetry: Option<String>,
) -> Result<()> {
    let dir = default_dir();
    let engine = Engine::new(&dir)?;
    let manifest = Manifest::load(&dir)?;
    let model = manifest.model(&cfg.model)?;
    let hello = Hello {
        fingerprint: cfg.fingerprint(),
        dim: model.dim as u64,
        model: cfg.model.clone(),
        auth: net::token_digest(net.token.as_deref()),
        role: PeerRole::Worker,
        shard: None,
    };
    let listener = TcpListener::bind(&net.addr)
        .with_context(|| format!("binding {}", net.addr))?;
    // the downstream pool's shape follows the aggregation topology:
    // a tree root fronts G mid-tier aggregators, a flat root fronts
    // --workers workers
    let (peers, noun) = match cfg.agg {
        AggMode::Tree { nodes } => (nodes, "aggregators"),
        AggMode::Flat => (net.workers, "workers"),
    };
    println!(
        "platform={}  preset={preset}  rounds={}  K={}  P={}  \
         role=server listen={}  agg={}  {noun}={peers}  inflight={}  \
         heartbeat={}ms  hedge={}ms  fingerprint={:#018x}",
        engine.platform(),
        cfg.rounds,
        cfg.clients,
        cfg.participation,
        listener.local_addr()?,
        cfg.agg,
        net.inflight,
        net.heartbeat_ms,
        net.hedge_ms,
        hello.fingerprint,
    );
    let sock_cfg = net::SocketCfg {
        io_timeout: Duration::from_millis(net.timeout_ms),
        heartbeat: Duration::from_millis(net.heartbeat_ms),
        inflight: net.inflight,
        hedge: Duration::from_millis(net.hedge_ms),
        aimd_spike: net.aimd_spike,
        aimd_cap: net.aimd_cap,
    };
    let transport = match cfg.agg {
        AggMode::Tree { .. } => {
            net::accept_aggregators(listener, peers, &hello, sock_cfg)?
        }
        AggMode::Flat => {
            net::accept_workers(listener, peers, &hello, sock_cfg)?
        }
    };
    println!("[server] {peers} {noun} handshaken; starting");
    let hub = bind_telemetry(telemetry)?;
    let mut server =
        Server::with_transport(&engine, &manifest, cfg, Box::new(&transport))?;
    server.set_verbose(true);
    if let Some(h) = &hub {
        server.set_telemetry(h.clone());
    }
    arm_snapshots(&mut server, &snap)?;
    let result = server.run();
    drop(server);
    transport.shutdown();
    report_run(&engine, &result?)
}

/// Reconnect attempts after a dropped connection before a worker
/// gives up (the outcome cache survives every retry, so re-dispatched
/// jobs on the fresh connection answer bit-identically from cache).
const WORKER_RECONNECT_ATTEMPTS: u32 = 5;

/// `--role worker`: rebuild the world from the local config copy,
/// handshake, and serve jobs on the in-process executor until the
/// server shuts the connection down. A dropped connection is retried
/// with backoff; the outcome cache persists across reconnects.
fn run_net_worker(cfg: ExperimentConfig, net: NetCfg) -> Result<()> {
    let dir = default_dir();
    let engine = Engine::new(&dir)?;
    let manifest = Manifest::load(&dir)?;
    let model = manifest.model(&cfg.model)?;
    let hello = Hello {
        fingerprint: cfg.fingerprint(),
        dim: model.dim as u64,
        model: cfg.model.clone(),
        auth: net::token_digest(net.token.as_deref()),
        role: PeerRole::Worker,
        shard: None,
    };
    let World { train, shards, .. } = build_world(&cfg, model)?;
    let ctx = net::WorkerCtx {
        train: &train,
        shards: &shards,
        segments: &model.segments,
        kernel: cfg.fp8_kernel,
    };
    let executor = InProcessTransport {
        engine: &engine,
        model,
    };
    let opts = net::ServeOpts {
        heartbeat: Duration::from_millis(net.heartbeat_ms),
        idle_deadline: if net.heartbeat_ms == 0 {
            Duration::ZERO // v1 behaviour: wait for work forever
        } else {
            Duration::from_millis(net.timeout_ms)
        },
        exec_threads: net.inflight.exec_threads(),
    };
    // sized for a whole round's share of re-dispatchable outcomes
    let cache = net::OutcomeCache::new(256);
    println!(
        "[worker] platform={}  model={}  K={}  exec-threads={}  \
         fingerprint={:#018x}  connecting to {}",
        engine.platform(),
        cfg.model,
        shards.n_clients(),
        opts.exec_threads,
        hello.fingerprint,
        net.addr,
    );
    // the budget covers the process lifetime and deliberately does
    // NOT reset on a successful connect: a deterministic serve
    // failure (executor error, diverged world) must not turn into an
    // unbounded reconnect/fail cycle just because TCP still works
    let mut attempt = 0u32;
    loop {
        match net::connect(
            &net.addr,
            &hello,
            Duration::from_millis(net.timeout_ms),
        ) {
            Ok(mut stream) => {
                println!("[worker] handshake ok; serving");
                match net::serve_conn(
                    &mut stream,
                    &executor,
                    &ctx,
                    &opts,
                    hello.fingerprint,
                    &cache,
                ) {
                    Ok(()) => {
                        println!(
                            "[worker] server closed the connection; \
                             exiting"
                        );
                        return Ok(());
                    }
                    Err(e) => eprintln!(
                        "[worker] connection lost: {e:#}; reconnecting \
                         (outcome cache: {} entries)",
                        cache.len()
                    ),
                }
            }
            Err(e) => eprintln!("[worker] connect failed: {e:#}"),
        }
        attempt += 1;
        if attempt > WORKER_RECONNECT_ATTEMPTS {
            bail!(
                "giving up after {WORKER_RECONNECT_ATTEMPTS} \
                 reconnect attempts"
            );
        }
        std::thread::sleep(Duration::from_millis(
            300 * u64::from(attempt),
        ));
    }
}

/// `--role aggregator`: mid-tier node of the networked tree. Accepts
/// `--workers` downstream worker connections (this process is a
/// server to its own workers), connects upstream to the `tree:G`
/// root announcing the aggregator role (and the `--shard i/G` pin,
/// if any), then serves whole cohort shards: each `FrameKind::Shard`
/// executes through the downstream `SocketTransport` and answers
/// with a ShardDone + Partial pair. A dropped upstream link is
/// retried with backoff; re-dispatched shards recompute
/// bit-identically from counter-derived streams.
fn run_net_aggregator(cfg: ExperimentConfig, net: NetCfg) -> Result<()> {
    let dir = default_dir();
    let engine = Engine::new(&dir)?;
    let manifest = Manifest::load(&dir)?;
    let model = manifest.model(&cfg.model)?;
    let up_hello = Hello {
        fingerprint: cfg.fingerprint(),
        dim: model.dim as u64,
        model: cfg.model.clone(),
        auth: net::token_digest(net.token.as_deref()),
        role: PeerRole::Aggregator,
        shard: net.shard,
    };
    // downstream, this process plays the server role
    let down_hello = Hello {
        role: PeerRole::Worker,
        shard: None,
        ..up_hello.clone()
    };
    let listen = net
        .listen
        .as_deref()
        .expect("--role aggregator requires --listen");
    let listener = TcpListener::bind(listen)
        .with_context(|| format!("binding {listen}"))?;
    println!(
        "[aggregator] platform={}  model={}  workers={}  shard={}  \
         fingerprint={:#018x}  listen={}  upstream={}",
        engine.platform(),
        cfg.model,
        net.workers,
        net.shard
            .map(|(i, g)| format!("{i}/{g}"))
            .unwrap_or_else(|| "auto".into()),
        up_hello.fingerprint,
        listener.local_addr()?,
        net.addr,
    );
    let transport = net::accept_workers(
        listener,
        net.workers,
        &down_hello,
        net::SocketCfg {
            io_timeout: Duration::from_millis(net.timeout_ms),
            heartbeat: Duration::from_millis(net.heartbeat_ms),
            inflight: net.inflight,
            hedge: Duration::from_millis(net.hedge_ms),
            aimd_spike: net.aimd_spike,
            aimd_cap: net.aimd_cap,
        },
    )?;
    println!(
        "[aggregator] {} workers handshaken; connecting upstream",
        net.workers
    );
    let World { train, shards, .. } = build_world(&cfg, model)?;
    let ctx = net::AggregatorCtx {
        cfg: &cfg,
        train: &train,
        shards: &shards,
        segments: &model.segments,
        dim: model.dim,
        alpha_dim: model.alpha_dim,
        beta_dim: model.n_act,
    };
    let opts = net::ServeOpts {
        heartbeat: Duration::from_millis(net.heartbeat_ms),
        idle_deadline: if net.heartbeat_ms == 0 {
            Duration::ZERO
        } else {
            Duration::from_millis(net.timeout_ms)
        },
        exec_threads: 1,
    };
    // same lifetime-scoped budget as the worker reconnect loop
    let mut attempt = 0u32;
    let result = loop {
        match net::connect(
            &net.addr,
            &up_hello,
            Duration::from_millis(net.timeout_ms),
        ) {
            Ok(mut stream) => {
                println!("[aggregator] upstream handshake ok; serving");
                match net::serve_upstream(
                    &mut stream,
                    &transport,
                    &ctx,
                    &opts,
                ) {
                    Ok(()) => {
                        println!(
                            "[aggregator] root closed the connection; \
                             exiting"
                        );
                        break Ok(());
                    }
                    Err(e) => eprintln!(
                        "[aggregator] upstream lost: {e:#}; \
                         reconnecting"
                    ),
                }
            }
            Err(e) => eprintln!("[aggregator] connect failed: {e:#}"),
        }
        attempt += 1;
        if attempt > WORKER_RECONNECT_ATTEMPTS {
            break Err(anyhow::anyhow!(
                "giving up after {WORKER_RECONNECT_ATTEMPTS} \
                 reconnect attempts"
            ));
        }
        std::thread::sleep(Duration::from_millis(
            300 * u64::from(attempt),
        ));
    };
    transport.shutdown();
    result
}

fn cmd_info() -> Result<()> {
    let dir = default_dir();
    let manifest = Manifest::load(&dir)?;
    let engine = Engine::new(&dir)?;
    println!("platform: {}", engine.platform());
    println!("artifacts: {}", dir.display());
    println!(
        "{:<14} {:>8} {:>6} {:>6} {:>8} {:>7} {:>9}",
        "model", "params", "alphas", "betas", "quant%", "U*B", "artifacts"
    );
    for (name, m) in &manifest.models {
        println!(
            "{:<14} {:>8} {:>6} {:>6} {:>7.1}% {:>7} {:>9}",
            name,
            m.dim,
            m.alpha_dim,
            m.n_act,
            100.0 * m.quant_params() as f64 / m.dim as f64,
            format!("{}x{}", m.u_steps, m.batch),
            m.artifacts.len()
        );
    }
    Ok(())
}

fn cmd_presets() {
    println!("preset notation: model:method[:split]");
    println!("models : mlp_c10 lenet_c10 lenet_c100 resnet8_c10 \
              resnet8_c100 matchbox kwt");
    println!("methods: fp32 uq uq+ bq randqat nocq_det nocq_rand bq_ef mixed");
    println!("splits : iid dir03 speaker");
    println!();
    println!("paper Table 1 rows, e.g.:");
    for m in ["lenet_c10", "lenet_c100", "resnet8_c10", "resnet8_c100"] {
        for s in ["iid", "dir03"] {
            println!("  {m}:{{fp32|uq|uq+}}:{s}");
        }
    }
    for m in ["matchbox", "kwt"] {
        for s in ["iid", "speaker"] {
            println!("  {m}:{{fp32|uq|uq+}}:{s}");
        }
    }
    println!();
    println!("multi-process rounds (same preset on every process):");
    println!("  fedfp8 run --preset P --role server --listen ADDR \
              --workers N");
    println!("  fedfp8 run --preset P --role worker --connect ADDR");
    println!();
    println!("networked tree (root + G mid-tier aggregators):");
    println!("  fedfp8 run --preset P --agg tree:G --role server \
              --listen ROOT");
    println!("  fedfp8 run --preset P --agg tree:G --role aggregator \
              --connect ROOT --listen ADDR --workers N [--shard i/G]");
    println!("  fedfp8 run --preset P --agg tree:G --role worker \
              --connect ADDR");
}

fn main() -> Result<()> {
    let args = Args::parse_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&args),
        Some("table1") => bench_tables::table1::run(&args),
        Some("table2") => bench_tables::table2::run(&args),
        Some("fig2") => bench_tables::fig2::run(&args),
        Some("info") => cmd_info(),
        Some("presets") => {
            cmd_presets();
            Ok(())
        }
        Some(other) => bail!(
            "unknown command '{other}' \
             (run|table1|table2|fig2|info|presets)"
        ),
        None => {
            cmd_presets();
            Ok(())
        }
    }
}
