//! Durable round-state snapshots: crash-safe persistence for the
//! coordinator with provably bit-identical resume.
//!
//! The paper's method keeps the full-precision master state on the
//! server — the FP32 model plus the error-feedback residuals — and
//! that is exactly what must survive a `kill -9`: FP8 exists only on
//! the wire, so persisting the FP32 master (not its FP8 projection)
//! follows the master-weights discipline of mixed-precision training.
//! Everything else a round needs is *derivable*: cohorts, rounding
//! draws and data splits all come from counter-derived streams
//! (`Pcg32::derive(seed, round, client, domain)`), so a snapshot of
//! (model, residuals, round counter, comm totals) is sufficient for
//! the resumed trajectory to be bit-identical to an uninterrupted
//! run at any `--parallelism`, over any transport.
//!
//! Format (all little-endian, mirrored in
//! `tools/gen_wire_fixture.py` and pinned by
//! `tests/golden_snapshot.rs`):
//!
//! ```text
//! header (16 bytes):
//!   magic      4  "FP8S"
//!   version    u16   SNAPSHOT_VERSION
//!   reserved   u16   0
//!   body_len   u32
//!   crc32      u32   IEEE crc32 of body (matches zlib.crc32)
//! body:
//!   fingerprint  u64   ExperimentConfig::fingerprint()
//!   next_round   u64   first round the resumed loop will run
//!   dim          u32   |w|
//!   alpha_dim    u32   |alpha|
//!   beta_dim     u32   |beta|
//!   w            dim x f32 (raw LE bits)
//!   alpha        alpha_dim x f32
//!   beta         beta_dim x f32
//!   ef_server    u32 len + len x f32
//!   ef_clients   u32 count, then per entry:
//!                  client u64, len u32, len x f32
//!   comm         6 x u64 (up_bytes, down_bytes, up_msgs,
//!                 down_msgs, partial_bytes, partial_msgs)
//!   wall_millis  u64   cumulative wall-clock of all completed
//!                      rounds (v2; keeps resumed bytes-vs-time
//!                      curves continuous, like the comm totals)
//! ```
//!
//! Durability discipline: [`write_atomic`] writes a temp file in the
//! target directory, fsyncs it, renames it into place and fsyncs the
//! directory — a crash leaves either the old generation set or the
//! new one, never a half-visible file. The last
//! [`KEEP_GENERATIONS`] generations are retained, so a torn or
//! corrupted newest file (detected by crc) lets [`load_resume`] fall
//! back one generation with a typed [`SnapshotError`] trail naming
//! every bad file. A config-fingerprint mismatch is a *hard* reject
//! (never a fallback): silently resuming another config's state
//! would diverge without any error.

use std::collections::BTreeMap;
use std::fmt;
use std::fs::{self, File};
use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::net::frame::crc32;

use super::comm::CommStats;

/// Snapshot file magic — "FP8S" (S for state; the wire uses "FP8W").
pub const SNAPSHOT_MAGIC: [u8; 4] = *b"FP8S";

/// Bump on any layout change; readers hard-reject other versions.
/// v2 appended `wall_millis` to the body (cumulative wall clock, so
/// resumed runs report continuous time next to cumulative bytes).
pub const SNAPSHOT_VERSION: u16 = 2;

/// Fixed header size: magic + version + reserved + body_len + crc32.
pub const SNAPSHOT_HEADER_BYTES: usize = 16;

/// Snapshot generations kept on disk. Two is the minimum that makes
/// a torn newest write recoverable: the previous generation is still
/// intact (it was never rewritten, only renamed over after the new
/// file was durable).
pub const KEEP_GENERATIONS: usize = 2;

/// Everything the coordinator must persist to resume bit-identically;
/// see the module docs for what is deliberately *not* here (anything
/// derivable from the config via counter-derived streams).
#[derive(Clone, Debug, PartialEq)]
pub struct SnapshotState {
    /// `ExperimentConfig::fingerprint()` of the writing run — the
    /// resume gate.
    pub fingerprint: u64,
    /// First round the resumed loop will execute (rounds `0 ..
    /// next_round` are complete in this state).
    pub next_round: u64,
    /// FP32 master model.
    pub w: Vec<f32>,
    pub alpha: Vec<f32>,
    pub beta: Vec<f32>,
    /// Server-side downlink EF residual (empty when EF is off).
    pub ef_server: Vec<f32>,
    /// Per-client uplink EF residuals (sparse: touched clients only;
    /// exactly-zero vectors are evicted before they get here).
    pub ef_clients: BTreeMap<u64, Vec<f32>>,
    /// Communication totals so resumed byte curves continue, not
    /// restart.
    pub comm: CommStats,
    /// Cumulative wall-clock milliseconds spent across all completed
    /// rounds, including prior resumed segments — the time twin of
    /// the cumulative `comm` totals, so a resumed run's
    /// bytes-vs-time curve continues instead of restarting at the
    /// resume boundary.
    pub wall_millis: u64,
}

/// Typed snapshot failures. Every variant names the offending file,
/// so a fallback (or a hard reject) is always attributable.
#[derive(Debug)]
pub enum SnapshotError {
    /// Filesystem-level failure reading or writing `path`.
    Io { path: PathBuf, source: std::io::Error },
    /// File is not a fedfp8 snapshot at all.
    BadMagic { path: PathBuf, got: [u8; 4] },
    /// Snapshot written by an incompatible format version.
    VersionMismatch { path: PathBuf, got: u16, want: u16 },
    /// File ends before the declared header/body does — the torn- or
    /// partial-write signature.
    Truncated { path: PathBuf, context: &'static str },
    /// Body bytes do not match the header checksum — bit rot or a
    /// torn overwrite.
    ChecksumMismatch { path: PathBuf, got: u32, want: u32 },
    /// Checksum passed but a field is structurally invalid (writer
    /// bug or handcrafted file).
    Malformed { path: PathBuf, what: String },
    /// Snapshot belongs to a different experiment config. Hard
    /// reject — resuming it would silently diverge. Names both
    /// fingerprints so the operator can see *which* side is stale.
    FingerprintMismatch {
        path: PathBuf,
        snapshot: u64,
        config: u64,
    },
    /// Snapshot files exist but every generation failed to load;
    /// `tried` records each candidate and why it was rejected.
    NoValidSnapshot { dir: PathBuf, tried: Vec<String> },
}

impl fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SnapshotError::Io { path, source } => write!(
                f,
                "snapshot i/o error on {}: {source}",
                path.display()
            ),
            SnapshotError::BadMagic { path, got } => write!(
                f,
                "{}: bad snapshot magic {got:02x?} (expected \
                 \"FP8S\")",
                path.display()
            ),
            SnapshotError::VersionMismatch { path, got, want } => {
                write!(
                    f,
                    "{}: snapshot format v{got}, this build reads \
                     v{want}",
                    path.display()
                )
            }
            SnapshotError::Truncated { path, context } => write!(
                f,
                "{}: truncated snapshot (file ends mid-{context})",
                path.display()
            ),
            SnapshotError::ChecksumMismatch { path, got, want } => {
                write!(
                    f,
                    "{}: snapshot checksum mismatch (body crc32 \
                     {got:#010x}, header says {want:#010x}) — torn \
                     or corrupted write",
                    path.display()
                )
            }
            SnapshotError::Malformed { path, what } => write!(
                f,
                "{}: malformed snapshot body: {what}",
                path.display()
            ),
            SnapshotError::FingerprintMismatch {
                path,
                snapshot,
                config,
            } => write!(
                f,
                "{}: snapshot was written by config fingerprint \
                 {snapshot:#018x} but this run's config fingerprints \
                 to {config:#018x} — refusing to resume across \
                 configs (same preset + overrides required)",
                path.display()
            ),
            SnapshotError::NoValidSnapshot { dir, tried } => write!(
                f,
                "no valid snapshot generation in {}: {}",
                dir.display(),
                tried.join("; ")
            ),
        }
    }
}

impl std::error::Error for SnapshotError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SnapshotError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

// ---- little-endian writers (snapshot-local; the net codec's are
// private to that module) ---------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, vs: &[f32]) {
    out.reserve(vs.len() * 4);
    for &v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Serialize to the framed byte form (header + crc'd body).
pub fn encode(s: &SnapshotState) -> Vec<u8> {
    let mut body = Vec::with_capacity(
        64 + 4 * (s.w.len() + s.alpha.len() + s.beta.len())
            + 4 * s.ef_server.len()
            + s.ef_clients
                .values()
                .map(|v| 12 + 4 * v.len())
                .sum::<usize>(),
    );
    put_u64(&mut body, s.fingerprint);
    put_u64(&mut body, s.next_round);
    put_u32(&mut body, s.w.len() as u32);
    put_u32(&mut body, s.alpha.len() as u32);
    put_u32(&mut body, s.beta.len() as u32);
    put_f32s(&mut body, &s.w);
    put_f32s(&mut body, &s.alpha);
    put_f32s(&mut body, &s.beta);
    put_u32(&mut body, s.ef_server.len() as u32);
    put_f32s(&mut body, &s.ef_server);
    put_u32(&mut body, s.ef_clients.len() as u32);
    for (&client, res) in &s.ef_clients {
        put_u64(&mut body, client);
        put_u32(&mut body, res.len() as u32);
        put_f32s(&mut body, res);
    }
    put_u64(&mut body, s.comm.up_bytes);
    put_u64(&mut body, s.comm.down_bytes);
    put_u64(&mut body, s.comm.up_msgs);
    put_u64(&mut body, s.comm.down_msgs);
    put_u64(&mut body, s.comm.partial_bytes);
    put_u64(&mut body, s.comm.partial_msgs);
    put_u64(&mut body, s.wall_millis);

    let mut out =
        Vec::with_capacity(SNAPSHOT_HEADER_BYTES + body.len());
    out.extend_from_slice(&SNAPSHOT_MAGIC);
    out.extend_from_slice(&SNAPSHOT_VERSION.to_le_bytes());
    out.extend_from_slice(&0u16.to_le_bytes());
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(&body).to_le_bytes());
    out.extend_from_slice(&body);
    out
}

/// Bounds-checked cursor over a crc-verified body; overruns are
/// [`SnapshotError::Malformed`] (the checksum already passed, so a
/// short field means a broken writer, not a torn file).
struct Rd<'a> {
    buf: &'a [u8],
    pos: usize,
    path: &'a Path,
}

impl<'a> Rd<'a> {
    fn bytes(
        &mut self,
        n: usize,
        what: &str,
    ) -> Result<&'a [u8], SnapshotError> {
        if self.buf.len() - self.pos < n {
            return Err(SnapshotError::Malformed {
                path: self.path.to_path_buf(),
                what: format!(
                    "{what}: need {n} bytes, only {} left",
                    self.buf.len() - self.pos
                ),
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self, what: &str) -> Result<u32, SnapshotError> {
        let b = self.bytes(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &str) -> Result<u64, SnapshotError> {
        let b = self.bytes(8, what)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn f32s(
        &mut self,
        n: usize,
        what: &str,
    ) -> Result<Vec<f32>, SnapshotError> {
        let b = self.bytes(n * 4, what)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn finish(self) -> Result<(), SnapshotError> {
        if self.pos != self.buf.len() {
            return Err(SnapshotError::Malformed {
                path: self.path.to_path_buf(),
                what: format!(
                    "{} trailing bytes after wall_millis",
                    self.buf.len() - self.pos
                ),
            });
        }
        Ok(())
    }
}

/// Parse framed snapshot bytes; `path` only names the source in
/// errors. Every corruption class maps to a distinct typed variant
/// (see [`SnapshotError`]), which is what lets [`load_resume`]
/// distinguish "fall back a generation" from "hard reject".
pub fn decode(
    bytes: &[u8],
    path: &Path,
) -> Result<SnapshotState, SnapshotError> {
    let p = || path.to_path_buf();
    if bytes.len() < SNAPSHOT_HEADER_BYTES {
        return Err(SnapshotError::Truncated {
            path: p(),
            context: "header",
        });
    }
    if bytes[0..4] != SNAPSHOT_MAGIC {
        return Err(SnapshotError::BadMagic {
            path: p(),
            got: [bytes[0], bytes[1], bytes[2], bytes[3]],
        });
    }
    let version = u16::from_le_bytes([bytes[4], bytes[5]]);
    if version != SNAPSHOT_VERSION {
        return Err(SnapshotError::VersionMismatch {
            path: p(),
            got: version,
            want: SNAPSHOT_VERSION,
        });
    }
    let body_len =
        u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]])
            as usize;
    let want_crc = u32::from_le_bytes([
        bytes[12], bytes[13], bytes[14], bytes[15],
    ]);
    let rest = &bytes[SNAPSHOT_HEADER_BYTES..];
    if rest.len() < body_len {
        return Err(SnapshotError::Truncated {
            path: p(),
            context: "body",
        });
    }
    if rest.len() > body_len {
        return Err(SnapshotError::Malformed {
            path: p(),
            what: format!(
                "{} trailing bytes after the declared body",
                rest.len() - body_len
            ),
        });
    }
    let got_crc = crc32(rest);
    if got_crc != want_crc {
        return Err(SnapshotError::ChecksumMismatch {
            path: p(),
            got: got_crc,
            want: want_crc,
        });
    }
    let mut r = Rd { buf: rest, pos: 0, path };
    let fingerprint = r.u64("fingerprint")?;
    let next_round = r.u64("next_round")?;
    let dim = r.u32("dim")? as usize;
    let alpha_dim = r.u32("alpha_dim")? as usize;
    let beta_dim = r.u32("beta_dim")? as usize;
    let w = r.f32s(dim, "w")?;
    let alpha = r.f32s(alpha_dim, "alpha")?;
    let beta = r.f32s(beta_dim, "beta")?;
    let ef_len = r.u32("ef_server length")? as usize;
    let ef_server = r.f32s(ef_len, "ef_server")?;
    let n_ef = r.u32("ef_clients count")? as usize;
    let mut ef_clients = BTreeMap::new();
    for _ in 0..n_ef {
        let client = r.u64("ef client id")?;
        let len = r.u32("ef residual length")? as usize;
        let res = r.f32s(len, "ef residual")?;
        if ef_clients.insert(client, res).is_some() {
            return Err(SnapshotError::Malformed {
                path: p(),
                what: format!("duplicate ef client id {client}"),
            });
        }
    }
    let comm = CommStats {
        up_bytes: r.u64("comm.up_bytes")?,
        down_bytes: r.u64("comm.down_bytes")?,
        up_msgs: r.u64("comm.up_msgs")?,
        down_msgs: r.u64("comm.down_msgs")?,
        partial_bytes: r.u64("comm.partial_bytes")?,
        partial_msgs: r.u64("comm.partial_msgs")?,
    };
    let wall_millis = r.u64("wall_millis")?;
    r.finish()?;
    Ok(SnapshotState {
        fingerprint,
        next_round,
        w,
        alpha,
        beta,
        ef_server,
        ef_clients,
        comm,
        wall_millis,
    })
}

fn io_err(path: &Path, source: std::io::Error) -> SnapshotError {
    SnapshotError::Io {
        path: path.to_path_buf(),
        source,
    }
}

/// On-disk name for a generation: `snap-<next_round:08>.fp8s`, so a
/// lexicographic sort is a round sort for any run under 10^8 rounds.
fn generation_name(next_round: u64) -> String {
    format!("snap-{next_round:08}.fp8s")
}

/// Parse a directory entry name back to its round, if it is one of
/// ours (temp files and foreign files are skipped, not errors).
fn parse_generation(name: &str) -> Option<u64> {
    let digits = name
        .strip_prefix("snap-")?
        .strip_suffix(".fp8s")?;
    digits.parse::<u64>().ok()
}

/// True for the temp-file names [`write_atomic`] creates
/// (`.tmp-snap-<round:08>.fp8s`). A crash between `File::create` and
/// the commit rename strands one of these; nothing ever reads them,
/// so they are safe to delete whenever no write is in progress.
fn is_stale_tmp(name: &str) -> bool {
    name.strip_prefix(".tmp-")
        .and_then(parse_generation)
        .is_some()
}

/// Best-effort removal of orphaned temp files left by a crash
/// mid-[`write_atomic`]. Only our own `.tmp-snap-*.fp8s` names are
/// touched — committed generations (and foreign files) never match
/// [`is_stale_tmp`] — and removal failures are ignored: a surviving
/// orphan costs disk space, not correctness.
fn prune_stale_tmps(dir: &Path) {
    let Ok(rd) = fs::read_dir(dir) else { return };
    for entry in rd.flatten() {
        let name = entry.file_name();
        if name.to_str().is_some_and(is_stale_tmp) {
            let _ = fs::remove_file(entry.path());
        }
    }
}

/// Snapshot generations in `dir`, newest (highest round) first.
pub fn list_generations(
    dir: &Path,
) -> Result<Vec<(u64, PathBuf)>, SnapshotError> {
    let rd = fs::read_dir(dir).map_err(|e| io_err(dir, e))?;
    let mut out = Vec::new();
    for entry in rd {
        let entry = entry.map_err(|e| io_err(dir, e))?;
        let name = entry.file_name();
        if let Some(round) =
            name.to_str().and_then(parse_generation)
        {
            out.push((round, entry.path()));
        }
    }
    out.sort_by(|a, b| b.0.cmp(&a.0));
    Ok(out)
}

/// Durably write one generation: temp file in the same directory,
/// fsync, rename into place, fsync the directory, then prune old
/// generations down to [`KEEP_GENERATIONS`]. A crash at any point
/// leaves a loadable generation set — the rename is the commit
/// point, and the previous generation is never touched before it.
pub fn write_atomic(
    dir: &Path,
    s: &SnapshotState,
) -> Result<PathBuf, SnapshotError> {
    fs::create_dir_all(dir).map_err(|e| io_err(dir, e))?;
    let name = generation_name(s.next_round);
    let final_path = dir.join(&name);
    let tmp_path = dir.join(format!(".tmp-{name}"));
    let bytes = encode(s);
    {
        let mut f = File::create(&tmp_path)
            .map_err(|e| io_err(&tmp_path, e))?;
        f.write_all(&bytes).map_err(|e| io_err(&tmp_path, e))?;
        f.sync_all().map_err(|e| io_err(&tmp_path, e))?;
    }
    fs::rename(&tmp_path, &final_path)
        .map_err(|e| io_err(&final_path, e))?;
    // Directory fsync makes the rename itself durable. Best-effort:
    // not every filesystem lets you open a directory for sync, and a
    // lost *rename* (vs a torn file) only costs one generation.
    if let Ok(d) = File::open(dir) {
        d.sync_all().ok();
    }
    for (_, old) in
        list_generations(dir)?.into_iter().skip(KEEP_GENERATIONS)
    {
        fs::remove_file(&old).map_err(|e| io_err(&old, e))?;
    }
    // Our temp file was consumed by the rename above, so anything
    // still matching the temp pattern is an orphan from a crashed
    // earlier write — clean it up now that this generation is
    // committed.
    prune_stale_tmps(dir);
    Ok(final_path)
}

/// Find the newest loadable generation in `dir` and gate it on the
/// config fingerprint.
///
/// * `Ok(None)`: no snapshot files at all (missing or empty dir) —
///   a cold start, so `--resume` can be passed from the first launch
///   of a kill/resume loop.
/// * Corrupt/torn generations (bad magic, version, crc, truncation,
///   malformed body, unreadable file) fall back to the next-newest,
///   accumulating the per-file reason.
/// * A *fingerprint* mismatch on a structurally valid snapshot is a
///   hard reject — that file is the operator pointing two different
///   experiments at one state directory, and "fall back" would hide
///   it.
/// * All generations bad: [`SnapshotError::NoValidSnapshot`] naming
///   every file tried.
pub fn load_resume(
    dir: &Path,
    fingerprint: u64,
) -> Result<Option<(SnapshotState, PathBuf)>, SnapshotError> {
    if !dir.exists() {
        return Ok(None);
    }
    // A crash mid-write_atomic can strand a `.tmp-snap-*` orphan
    // (the exact state a resume starts from); sweep them before
    // walking generations so the directory never accumulates them.
    prune_stale_tmps(dir);
    let generations = list_generations(dir)?;
    if generations.is_empty() {
        return Ok(None);
    }
    let mut tried = Vec::new();
    for (_, path) in &generations {
        let bytes = match fs::read(path) {
            Ok(b) => b,
            Err(e) => {
                tried.push(format!("{}: {e}", path.display()));
                continue;
            }
        };
        match decode(&bytes, path) {
            Ok(s) => {
                if s.fingerprint != fingerprint {
                    return Err(SnapshotError::FingerprintMismatch {
                        path: path.clone(),
                        snapshot: s.fingerprint,
                        config: fingerprint,
                    });
                }
                return Ok(Some((s, path.clone())));
            }
            Err(e) => tried.push(e.to_string()),
        }
    }
    Err(SnapshotError::NoValidSnapshot {
        dir: dir.to_path_buf(),
        tried,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state() -> SnapshotState {
        let mut ef_clients = BTreeMap::new();
        ef_clients.insert(3u64, vec![0.5f32, -0.25]);
        ef_clients.insert(11u64, vec![1.5f32, 2.5]);
        SnapshotState {
            fingerprint: 0xDEAD_BEEF_0123_4567,
            next_round: 42,
            w: vec![1.0, -2.0, 0.5],
            alpha: vec![3.0],
            beta: vec![0.125, 8.0],
            ef_server: vec![0.0625, -0.0625, 0.0],
            ef_clients,
            comm: CommStats {
                up_bytes: 111,
                down_bytes: 222,
                up_msgs: 3,
                down_msgs: 4,
                partial_bytes: 55,
                partial_msgs: 6,
            },
            wall_millis: 987_654,
        }
    }

    #[test]
    fn roundtrips() {
        let s = state();
        let bytes = encode(&s);
        assert_eq!(&bytes[0..4], b"FP8S");
        let back = decode(&bytes, Path::new("t")).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn corruption_classes_are_typed() {
        let good = encode(&state());
        // truncated header
        assert!(matches!(
            decode(&good[..10], Path::new("t")),
            Err(SnapshotError::Truncated { context: "header", .. })
        ));
        // truncated body (torn write)
        assert!(matches!(
            decode(&good[..good.len() - 5], Path::new("t")),
            Err(SnapshotError::Truncated { context: "body", .. })
        ));
        // flipped body byte
        let mut flip = good.clone();
        *flip.last_mut().unwrap() ^= 0x40;
        assert!(matches!(
            decode(&flip, Path::new("t")),
            Err(SnapshotError::ChecksumMismatch { .. })
        ));
        // wrong magic
        let mut magic = good.clone();
        magic[0] = b'X';
        assert!(matches!(
            decode(&magic, Path::new("t")),
            Err(SnapshotError::BadMagic { .. })
        ));
        // future version
        let mut ver = good.clone();
        ver[4] = 9;
        assert!(matches!(
            decode(&ver, Path::new("t")),
            Err(SnapshotError::VersionMismatch { got: 9, .. })
        ));
        // trailing garbage
        let mut long = good.clone();
        long.push(0);
        assert!(matches!(
            decode(&long, Path::new("t")),
            Err(SnapshotError::Malformed { .. })
        ));
    }

    #[test]
    fn atomic_write_retains_two_generations() {
        let dir = std::env::temp_dir().join(format!(
            "fedfp8_snap_unit_{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        let mut s = state();
        for round in [1u64, 2, 3] {
            s.next_round = round;
            write_atomic(&dir, &s).unwrap();
        }
        let gens = list_generations(&dir).unwrap();
        assert_eq!(
            gens.iter().map(|g| g.0).collect::<Vec<_>>(),
            vec![3, 2]
        );
        let (loaded, path) =
            load_resume(&dir, s.fingerprint).unwrap().unwrap();
        assert_eq!(loaded.next_round, 3);
        assert!(path.ends_with("snap-00000003.fp8s"));
        // empty / missing dir is a cold start, not an error
        let _ = fs::remove_dir_all(&dir);
        assert!(load_resume(&dir, 1).unwrap().is_none());
    }

    #[test]
    fn stale_tmp_files_are_pruned_but_generations_survive() {
        let dir = std::env::temp_dir().join(format!(
            "fedfp8_snap_tmp_unit_{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        let mut s = state();
        for round in [7u64, 8] {
            s.next_round = round;
            write_atomic(&dir, &s).unwrap();
        }
        // plant a crashed write's orphan plus a foreign dotfile that
        // must NOT be swept
        let orphan = dir.join(".tmp-snap-00000009.fp8s");
        fs::write(&orphan, b"torn").unwrap();
        let foreign = dir.join(".tmp-notes.txt");
        fs::write(&foreign, b"keep me").unwrap();

        // load_resume sweeps the orphan and still resumes newest
        let (loaded, _) =
            load_resume(&dir, s.fingerprint).unwrap().unwrap();
        assert_eq!(loaded.next_round, 8);
        assert!(!orphan.exists(), "orphan tmp survived load_resume");
        assert!(foreign.exists(), "foreign dotfile was swept");

        // write_atomic also sweeps orphans after committing
        fs::write(&orphan, b"torn again").unwrap();
        s.next_round = 9;
        write_atomic(&dir, &s).unwrap();
        assert!(!orphan.exists(), "orphan tmp survived write_atomic");
        let gens = list_generations(&dir).unwrap();
        assert_eq!(
            gens.iter().map(|g| g.0).collect::<Vec<_>>(),
            vec![9, 8]
        );
        let _ = fs::remove_dir_all(&dir);
    }
}
