//! Client-execution transport: the seam between the round loop and
//! *where clients actually run*.
//!
//! The server describes one client's work order as a [`ClientJob`]
//! (downlink state + shard + hyperparameters + an owned error-feedback
//! residual) and hands it to a [`Transport`]. The in-process
//! implementation ([`InProcessTransport`]) simulates the device on the
//! shared thread-safe [`Engine`]; a future networked backend would
//! serialize the job's downlink and ship it to a real fleet — the
//! trait is deliberately message-shaped (owned outcome, no callbacks
//! into server state) so that seam stays narrow.
//!
//! [`run_cohort`] fans a round's cohort out over a scoped worker pool
//! (`parallelism` threads) and streams outcomes to a sink **in cohort
//! order** regardless of completion order: a reorder buffer holds
//! early finishers until their turn. Combined with the counter-derived
//! per-client RNG streams ([`Pcg32::derive`]), this makes a round's
//! result bit-identical for every `parallelism` value — enforced by
//! `tests/parallel_determinism.rs`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Mutex};
use std::thread;

use anyhow::{ensure, Context, Result};

use crate::config::QatMode;
use crate::data::{self, Dataset};
use crate::fp8::codec::{self, Rounding, Segment, WirePayload};
use crate::fp8::rng::Pcg32;
use crate::fp8::simd::KernelKind;
use crate::runtime::{Engine, ModelInfo};

use super::client::{ClientRunner, LocalUpdate};
use super::comm::Uplink;

/// RNG domain tags for [`Pcg32::derive`] — one per independent use of
/// randomness inside a round, so streams sharing `(round, client)`
/// coordinates never overlap.
pub mod streams {
    /// Client-local batch sampling / augmentation draws.
    pub const DATA: u64 = 0xDA7A;
    /// Client-side uplink wire quantization (stochastic rounding).
    pub const UPLINK: u64 = 0x0B1A;
    /// Server-side downlink wire quantization.
    pub const DOWNLINK: u64 = 0xD014;
    /// ServerOptimize stochastic draws (Eq. 4 GD + Eq. 5 grid).
    pub const SERVER_OPT: u64 = 0x50B7;
    /// Per-round cohort draw (the P-of-K participant sample). Derived
    /// per round — `Pcg32::derive(seed, round, 0, COHORT)` — so round
    /// t's cohort is a pure function of (seed, t), independent of how
    /// many rounds ran before it.
    pub const COHORT: u64 = 0x5A3F;
}

/// Work order for one client in one round. Borrows the round-shared
/// broadcast state (`w_start`/clips are the decoded downlink — every
/// participant hard-resets to the same grid values) and owns the
/// client-private error-feedback residual, which travels back in the
/// [`ClientOutcome`].
pub struct ClientJob<'r> {
    pub round: usize,
    pub client: usize,
    /// Round-scoped dispatch tag: the client's cohort position. Pure
    /// metadata for in-process execution; the networked transport uses
    /// it as the wire multiplexing key (one connection carries N
    /// in-flight jobs, demultiplexed by `(round, client, job_id)`) and
    /// the worker's reconnect cache is keyed on it. Deterministic —
    /// a re-dispatched job presents the identical tag.
    pub job_id: u32,
    /// Experiment seed — all client randomness is derived from
    /// `(seed, round, client)`, never from shared generator state.
    pub seed: u64,
    pub qat: QatMode,
    pub lr: f32,
    pub weight_decay: f32,
    pub flip_aug: bool,
    /// Communication quantizer for the uplink.
    pub comm: Rounding,
    pub w_start: &'r [f32],
    pub alpha_start: &'r [f32],
    pub beta_start: &'r [f32],
    pub train: &'r Dataset,
    pub shard: &'r [usize],
    pub segments: &'r [Segment],
    /// n_k — local dataset size (FedAvg weighting).
    pub n_k: u64,
    /// Error-feedback residual (cloned from the server's store, the
    /// updated copy travels back and replaces it on delivery — a
    /// failed round therefore never loses undelivered residuals);
    /// `None` when EF is disabled.
    pub ef: Option<Vec<f32>>,
    /// The *encoded* downlink broadcast (`w_start` is its decode).
    /// In-process execution reads the decoded fields above; a
    /// networked transport ships these packed bytes instead, so the
    /// downlink frame carries FP8 codes — never re-inflated f32 —
    /// and the remote decode reproduces `w_start` bit-exactly.
    pub down: &'r WirePayload,
}

/// What one client sends back: the encoded uplink plus the updated
/// error-feedback residual.
pub struct ClientOutcome {
    pub uplink: Uplink,
    pub ef: Option<Vec<f32>>,
}

/// Per-worker scratch reused across every message that worker
/// processes — allocated once per worker, not once per message: the
/// EF fold-in source, the decode buffer, the batched stochastic-
/// rounding draw buffer ([`Pcg32::fill_uniform_f64`] target), and the
/// worker's decode-table cache.
#[derive(Default)]
pub struct WorkBuffers {
    pub up_src: Vec<f32>,
    pub dec: Vec<f32>,
    /// RNG scratch for the codec's batched rounding draws.
    pub us: Vec<f64>,
    /// Per-worker decode-LUT cache (codes → f32 tables per alpha).
    pub lut: codec::DecodeLutCache,
    /// Quantize/encode kernel for this worker's uplink packing
    /// (`--fp8-kernel`; bit-identical for every value, so purely a
    /// wall-clock knob). `Default` is [`KernelKind::Auto`].
    pub kernel: KernelKind,
    /// Transport-side scratch: the job serialization buffer a
    /// networked transport reuses across dispatches — one
    /// payload-sized allocation per cohort worker for the life of
    /// the run, not one per message. Unused by in-process transports.
    pub wire: Vec<u8>,
}

impl WorkBuffers {
    /// Fresh buffers pinned to `kernel` (the cohort pool and the
    /// networked worker build their per-thread buffers through this).
    pub fn with_kernel(kernel: KernelKind) -> WorkBuffers {
        WorkBuffers {
            kernel,
            ..WorkBuffers::default()
        }
    }
}

/// Work order for one contiguous cohort shard, dispatched whole to a
/// networked mid-tier aggregator (`--role aggregator`). Everything a
/// deterministic peer cannot derive from its own config copy rides
/// here: the *encoded* downlink broadcast and the server-held EF
/// residuals of the shard's clients. The cohort itself is a pure
/// function of `(seed, round)`, so only the position range travels.
pub struct ShardSpec<'r> {
    pub round: u32,
    /// Cohort position range `[lo, hi)` this shard owns.
    pub lo: u64,
    pub hi: u64,
    /// Shard index within the configured `tree:G` fan-out.
    pub index: u32,
    /// Configured fan-out G (shard geometry is derived from this,
    /// never from the live connection count — re-dispatch after a
    /// death must not change the tree shape).
    pub nodes: u32,
    /// The encoded downlink broadcast (shared by every shard).
    pub down: &'r WirePayload,
    /// `(client id, residual)` for the shard's participants that have
    /// a stored EF residual; empty when EF is off.
    pub efs: Vec<(u32, &'r [f32])>,
}

/// What a mid-tier aggregator answers a [`ShardSpec`] with: the folded
/// [`TreePartial`] plus the client-edge uplink accounting and returned
/// EF residuals the root needs to keep `CommStats` and the EF store
/// bit-identical to an in-process tree.
///
/// [`TreePartial`]: super::aggregate::TreePartial
pub struct ShardReply {
    pub partial: super::aggregate::TreePartial,
    /// Sum of the shard's client uplink wire bytes (payload bytes +
    /// `UPLINK_HEADER_BYTES` each), as `CommStats::record_up` charges.
    pub up_bytes: u64,
    pub up_msgs: u64,
    /// Updated `(client id, residual)` pairs, ascending by client id.
    pub efs: Vec<(u32, Vec<f32>)>,
}

/// Shard-level dispatch: the seam [`run_tree_net`] drives when the
/// transport fronts a pool of networked aggregators instead of
/// workers. Implementations must be `Sync` — shards run concurrently.
///
/// [`run_tree_net`]: super::tree::run_tree_net
pub trait ShardDispatch: Sync {
    fn run_shard(&self, spec: &ShardSpec<'_>) -> Result<ShardReply>;
}

/// Where a client's local round executes. Implementations must be
/// `Sync`: one transport instance serves the whole worker pool.
pub trait Transport: Sync {
    fn run_client(
        &self,
        job: ClientJob<'_>,
        buffers: &mut WorkBuffers,
    ) -> Result<ClientOutcome>;

    /// Non-`None` when this transport fronts mid-tier aggregators
    /// and rounds should fan out whole shards ([`ShardSpec`]) instead
    /// of individual client jobs. The default — every in-process and
    /// plain worker-pool transport — dispatches per client.
    fn shard_dispatcher(&self) -> Option<&dyn ShardDispatch> {
        None
    }
}

/// Transports pass through references, so callers can keep ownership
/// (e.g. to inspect a mock after the run) and hand the server `&T`.
impl<T: Transport + ?Sized> Transport for &T {
    fn run_client(
        &self,
        job: ClientJob<'_>,
        buffers: &mut WorkBuffers,
    ) -> Result<ClientOutcome> {
        (**self).run_client(job, buffers)
    }

    fn shard_dispatcher(&self) -> Option<&dyn ShardDispatch> {
        (**self).shard_dispatcher()
    }
}

/// Deterministic seed handed to the AOT local-update artifact
/// (dropout/stochastic-QAT draws inside the graph).
pub fn artifact_seed(round: usize, client: usize) -> i32 {
    ((round as i32) << 12) | (client as i32 & 0xFFF)
}

/// Shared "client modem": fold in error feedback, quantize + pack the
/// uplink with the client's counter-derived RNG stream, and update the
/// residual. Both the in-process transport and test mocks route
/// through this, so wire behaviour is identical no matter where the
/// local update itself ran.
pub fn finish_uplink(
    job: ClientJob<'_>,
    upd: LocalUpdate,
    buffers: &mut WorkBuffers,
) -> ClientOutcome {
    let mut rng_q = Pcg32::derive(
        job.seed,
        job.round as u64,
        job.client as u64,
        streams::UPLINK,
    );
    let WorkBuffers { up_src, dec, us, lut, kernel, wire: _ } = buffers;
    let src: &[f32] = match &job.ef {
        Some(e) => {
            up_src.clear();
            up_src.extend(
                upd.w.iter().zip(e.iter()).map(|(w, e)| w + e),
            );
            up_src
        }
        None => &upd.w,
    };
    // pool = 1: each client message already runs on its own cohort
    // worker; nesting a second fan-out here would oversubscribe
    let mut payload = WirePayload::default();
    codec::encode_into_pooled(
        src,
        &upd.alpha,
        &upd.beta,
        job.segments,
        job.comm,
        *kernel,
        &mut rng_q,
        us,
        1,
        &mut payload,
    );
    let ef = job.ef.map(|mut e| {
        codec::decode_into_pooled(&payload, job.segments, lut, 1, dec);
        for ((e, s), d) in e.iter_mut().zip(src).zip(dec.iter()) {
            *e = s - d;
        }
        e
    });
    ClientOutcome {
        uplink: Uplink {
            payload,
            client: job.client,
            n_k: job.n_k,
            mean_loss: upd.mean_loss,
        },
        ef,
    }
}

/// In-process client executor: the paper's simulation setup, where the
/// coordinator runs every sampled client on the shared PJRT engine.
pub struct InProcessTransport<'a> {
    pub engine: &'a Engine,
    pub model: &'a ModelInfo,
}

impl Transport for InProcessTransport<'_> {
    fn run_client(
        &self,
        job: ClientJob<'_>,
        buffers: &mut WorkBuffers,
    ) -> Result<ClientOutcome> {
        let m = self.model;
        let mut rng_data = Pcg32::derive(
            job.seed,
            job.round as u64,
            job.client as u64,
            streams::DATA,
        );
        let (xs, ys) = data::make_batches(
            job.train,
            job.shard,
            m.u_steps,
            m.batch,
            &mut rng_data,
            job.flip_aug,
        );
        let runner = ClientRunner {
            engine: self.engine,
            model: m,
        };
        let upd = runner
            .local_update(
                job.qat,
                job.w_start,
                job.alpha_start,
                job.beta_start,
                &xs,
                &ys,
                job.lr,
                job.weight_decay,
                artifact_seed(job.round, job.client),
            )
            .with_context(|| {
                format!("client {} round {}", job.client, job.round)
            })?;
        Ok(finish_uplink(job, upd, buffers))
    }
}

/// Execute a cohort of jobs on `transport` with up to `parallelism`
/// worker threads, delivering outcomes to `sink` strictly in cohort
/// order (position 0, 1, 2, ...) as soon as each becomes deliverable.
/// `kernel` pins each worker's uplink quantize/encode kernel
/// (bit-identical for every choice — a wall-clock knob, like
/// `parallelism` itself).
///
/// The in-order delivery is what makes streaming aggregation
/// bit-identical across thread counts: FP32 accumulation is not
/// associative, so the accumulate order must not depend on completion
/// order. Early finishers park in a reorder buffer (packed payloads,
/// not decoded tensors) until their predecessors arrive.
pub fn run_cohort<F>(
    transport: &dyn Transport,
    jobs: Vec<ClientJob<'_>>,
    parallelism: usize,
    kernel: KernelKind,
    mut sink: F,
) -> Result<()>
where
    F: FnMut(usize, ClientOutcome) -> Result<()>,
{
    let n = jobs.len();
    if n == 0 {
        return Ok(());
    }
    let workers = parallelism.max(1).min(n);
    if workers == 1 {
        // sequential fast path: no threads, no channel
        let mut buffers = WorkBuffers::with_kernel(kernel);
        for (pos, job) in jobs.into_iter().enumerate() {
            let out = transport.run_client(job, &mut buffers)?;
            sink(pos, out)?;
        }
        return Ok(());
    }

    let queue = Mutex::new(jobs.into_iter().enumerate());
    let cancel = AtomicBool::new(false);
    let (tx, rx) = mpsc::channel::<(usize, Result<ClientOutcome>)>();
    thread::scope(|s| -> Result<()> {
        for _ in 0..workers {
            let tx = tx.clone();
            let queue = &queue;
            let cancel = &cancel;
            s.spawn(move || {
                let mut buffers = WorkBuffers::with_kernel(kernel);
                while !cancel.load(Ordering::Relaxed) {
                    let next =
                        queue.lock().ok().and_then(|mut q| q.next());
                    let Some((pos, job)) = next else { break };
                    let res = transport.run_client(job, &mut buffers);
                    if tx.send((pos, res)).is_err() {
                        break;
                    }
                }
            });
        }
        drop(tx);

        let mut pending: BTreeMap<usize, ClientOutcome> = BTreeMap::new();
        let mut next_pos = 0usize;
        let mut first_err: Option<anyhow::Error> = None;
        for _ in 0..n {
            let Ok((pos, res)) = rx.recv() else { break };
            match res {
                Ok(out) => {
                    pending.insert(pos, out);
                }
                Err(e) => {
                    cancel.store(true, Ordering::Relaxed);
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
            if first_err.is_none() {
                while let Some(out) = pending.remove(&next_pos) {
                    if let Err(e) = sink(next_pos, out) {
                        // stop workers from draining the rest of the
                        // queue while scope joins them
                        cancel.store(true, Ordering::Relaxed);
                        return Err(e);
                    }
                    next_pos += 1;
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        ensure!(
            next_pos == n,
            "cohort incomplete: {next_pos}/{n} clients delivered"
        );
        Ok(())
    })
}
