//! ServerOptimize (the "+" of FP8FedAvg-UQ+): replace plain federated
//! averaging with explicit minimization of the quantized-MSE objective.
//!
//! Alternating minimization, exactly as §2 of the paper:
//!   1. Eq. (4) — `gd_steps` gradient-descent steps on the weights
//!      `min_w sum_k (n_k/m_t) ||Q_rand(w; abar) - what_k||^2` with
//!      alpha fixed to the weighted average. Gradients (STE through
//!      Q_rand) are computed by the AOT `server_opt_det` artifact; the
//!      stochastic-rounding draw `u` comes from the coordinator RNG.
//!   2. Eq. (5) — per-tensor grid search for alpha over `grid_points`
//!      values spanning [min_k alpha_k, max_k alpha_k]. Common random
//!      numbers across candidates keep the comparison tight.
//!
//! ## Eq. (5) hot path
//!
//! Scoring a candidate used to rescan all K client vectors
//! (O(G·K·d) per segment for a G-point grid). The search now
//! precomputes per-element sufficient statistics once per segment
//! ([`codec::SegmentStats`]: `W = Σ_k kw_k`, `S_i = Σ_k kw_k·c_{k,i}`,
//! `T_i = Σ_k kw_k·c²_{k,i}`), so each candidate costs
//! `Σ_i q_i²·W − 2·q_i·S_i + T_i` — O(d·(K+G)) total — and fans the
//! candidate scoring across up to `parallelism` scoped threads.
//! Candidate order, RNG draw order and the strict-improvement
//! tie-break are preserved, so the search is deterministic for every
//! thread count.

use anyhow::{ensure, Result};

use crate::config::ServerOptCfg;
use crate::fp8::codec::{scatter_zip, Segment, SegmentStats};
use crate::fp8::rng::Pcg32;
use crate::fp8::simd::KernelKind;
use crate::runtime::{engine, Engine, In, ModelInfo};

use super::aggregate::Aggregate;

/// One segment's prepared grid search: candidate range, common random
/// numbers, and the client sufficient statistics.
struct SegSearch<'m> {
    seg: &'m Segment,
    ai: usize,
    lo: f32,
    hi: f32,
    us: Vec<f64>,
    stats: SegmentStats,
}

/// Total candidate-scoring work (elements × candidates) below which
/// the search stays on the calling thread. Scoring costs ~15 ns per
/// element-candidate, so the threshold (~4 ms of work) comfortably
/// amortizes thread spawn.
const PAR_MIN_WORK: usize = 1 << 18;

/// Run ServerOptimize in place on the aggregate. Returns the final
/// Eq. (4) objective value (for logging / tests). `parallelism` is
/// the worker budget for the Eq. (5) candidate scoring and `kernel`
/// picks the quantize inner loop of the candidate scorer
/// (`SegmentStats::mse_with`); results are identical for every value
/// of both.
pub fn optimize(
    eng: &Engine,
    model: &ModelInfo,
    cfg: &ServerOptCfg,
    agg: &mut Aggregate,
    rng: &mut Pcg32,
    parallelism: usize,
    kernel: KernelKind,
) -> Result<f32> {
    let p = model.server_p;
    ensure!(
        agg.client_ws.len() <= p,
        "round had {} uplinks but artifact is baked for P={p}",
        agg.client_ws.len()
    );
    // ---- Eq. (4): GD on w with alpha fixed --------------------------
    // pad client set to P with zero-weight duplicates (kw=0 rows do not
    // contribute to the objective or gradient)
    let dim = model.dim;
    let mut clients_flat = Vec::with_capacity(p * dim);
    let mut kweights = Vec::with_capacity(p);
    for (cw, &kw) in agg.client_ws.iter().zip(&agg.kweights) {
        clients_flat.extend_from_slice(cw);
        kweights.push(kw);
    }
    while kweights.len() < p {
        clients_flat.extend_from_slice(&agg.client_ws[0]);
        kweights.push(0.0);
    }
    let file = model.artifact("server_opt", "det")?;
    let mut mse = f32::NAN;
    let mut u = vec![0.0f32; dim];
    for _ in 0..cfg.gd_steps {
        for v in u.iter_mut() {
            *v = rng.uniform();
        }
        let out = eng.execute(
            file,
            &[
                In::F32(&agg.w, &[dim as i64]),
                In::F32(&agg.alpha, &[model.alpha_dim as i64]),
                In::F32(&clients_flat, &[p as i64, dim as i64]),
                In::F32(&kweights, &[p as i64]),
                In::F32(&u, &[dim as i64]),
                In::ScalarF32(cfg.gd_lr),
            ],
        )?;
        ensure!(out.len() == 2, "server_opt returns (w', mse)");
        agg.w = engine::f32_vec(&out[0])?;
        mse = engine::f32_scalar(&out[1])?;
    }

    // ---- Eq. (5): per-tensor alpha grid search ----------------------
    // Phase 1 (sequential): candidate ranges, common random numbers
    // (drawn in segment order — the draw order is part of the
    // determinism contract) and the per-segment sufficient statistics.
    let client_refs: Vec<&[f32]> =
        agg.client_ws.iter().map(|v| v.as_slice()).collect();
    let mut searches: Vec<SegSearch<'_>> = Vec::new();
    for seg in model.segments.iter().filter(|s| s.quantized) {
        let ai = seg.alpha_idx.unwrap();
        // candidate range from the clients' transmitted alphas
        let (mut lo, mut hi) = (f32::MAX, f32::MIN);
        for up_alpha in agg.client_alphas.iter() {
            lo = lo.min(up_alpha[ai]);
            hi = hi.max(up_alpha[ai]);
        }
        if !(lo.is_finite() && hi.is_finite()) || lo <= 0.0 {
            continue;
        }
        // common random numbers for all candidates of this segment
        let us: Vec<f64> =
            (0..seg.size).map(|_| rng.uniform_f64()).collect();
        let stats =
            SegmentStats::build(seg, &client_refs, &agg.kweights);
        searches.push(SegSearch { seg, ai, lo, hi, us, stats });
    }

    // Phase 2: score every (segment, candidate) pair — O(d) each via
    // the sufficient statistics — optionally across the pool.
    let n = cfg.grid_points.max(1);
    let mut tasks: Vec<(usize, f32)> = Vec::new();
    for (si, sr) in searches.iter().enumerate() {
        for gi in 0..n {
            let cand = if n == 1 {
                sr.lo
            } else {
                sr.lo + (sr.hi - sr.lo) * gi as f32 / (n - 1) as f32
            };
            if cand <= 0.0 {
                continue;
            }
            tasks.push((si, cand));
        }
    }
    let mut mses = vec![0.0f64; tasks.len()];
    let work: usize = tasks
        .iter()
        .map(|&(si, _)| searches[si].seg.size)
        .sum();
    let workers = parallelism.min(tasks.len()).max(1);
    let score = |&(si, cand): &(usize, f32)| -> f64 {
        let sr = &searches[si];
        sr.stats.mse_with(kernel, &agg.w, sr.seg, cand, &sr.us)
    };
    if workers == 1 || work < PAR_MIN_WORK {
        for (slot, task) in mses.iter_mut().zip(tasks.iter()) {
            *slot = score(task);
        }
    } else {
        scatter_zip(&tasks, &mut mses, workers, score);
    }

    // Phase 3 (sequential reduce, task order = candidate order):
    // strict improvement keeps the earliest minimizer, matching the
    // sequential search exactly.
    let mut best: Vec<(f32, f64)> = searches
        .iter()
        .map(|sr| (agg.alpha[sr.ai], f64::MAX))
        .collect();
    for (&(si, cand), &m) in tasks.iter().zip(mses.iter()) {
        if m < best[si].1 {
            best[si] = (cand, m);
        }
    }
    for (sr, &(cand, _)) in searches.iter().zip(best.iter()) {
        agg.alpha[sr.ai] = cand;
    }
    Ok(mse)
}
