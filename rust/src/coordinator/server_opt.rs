//! ServerOptimize (the "+" of FP8FedAvg-UQ+): replace plain federated
//! averaging with explicit minimization of the quantized-MSE objective.
//!
//! Alternating minimization, exactly as §2 of the paper:
//!   1. Eq. (4) — `gd_steps` gradient-descent steps on the weights
//!      `min_w sum_k (n_k/m_t) ||Q_rand(w; abar) - what_k||^2` with
//!      alpha fixed to the weighted average. Gradients (STE through
//!      Q_rand) are computed by the AOT `server_opt_det` artifact; the
//!      stochastic-rounding draw `u` comes from the coordinator RNG.
//!   2. Eq. (5) — per-tensor grid search for alpha over `grid_points`
//!      values spanning [min_k alpha_k, max_k alpha_k], scoring each
//!      candidate with the wire codec (no HLO dispatch needed). Common
//!      random numbers across candidates keep the comparison tight.

use anyhow::{ensure, Result};

use crate::config::ServerOptCfg;
use crate::fp8::codec;
use crate::fp8::rng::Pcg32;
use crate::runtime::{engine, Engine, In, ModelInfo};

use super::aggregate::Aggregate;

/// Run ServerOptimize in place on the aggregate. Returns the final
/// Eq. (4) objective value (for logging / tests).
pub fn optimize(
    eng: &Engine,
    model: &ModelInfo,
    cfg: &ServerOptCfg,
    agg: &mut Aggregate,
    rng: &mut Pcg32,
) -> Result<f32> {
    let p = model.server_p;
    ensure!(
        agg.client_ws.len() <= p,
        "round had {} uplinks but artifact is baked for P={p}",
        agg.client_ws.len()
    );
    // ---- Eq. (4): GD on w with alpha fixed --------------------------
    // pad client set to P with zero-weight duplicates (kw=0 rows do not
    // contribute to the objective or gradient)
    let dim = model.dim;
    let mut clients_flat = Vec::with_capacity(p * dim);
    let mut kweights = Vec::with_capacity(p);
    for (cw, &kw) in agg.client_ws.iter().zip(&agg.kweights) {
        clients_flat.extend_from_slice(cw);
        kweights.push(kw);
    }
    while kweights.len() < p {
        clients_flat.extend_from_slice(&agg.client_ws[0]);
        kweights.push(0.0);
    }
    let file = model.artifact("server_opt", "det")?;
    let mut mse = f32::NAN;
    let mut u = vec![0.0f32; dim];
    for _ in 0..cfg.gd_steps {
        for v in u.iter_mut() {
            *v = rng.uniform();
        }
        let out = eng.execute(
            file,
            &[
                In::F32(&agg.w, &[dim as i64]),
                In::F32(&agg.alpha, &[model.alpha_dim as i64]),
                In::F32(&clients_flat, &[p as i64, dim as i64]),
                In::F32(&kweights, &[p as i64]),
                In::F32(&u, &[dim as i64]),
                In::ScalarF32(cfg.gd_lr),
            ],
        )?;
        ensure!(out.len() == 2, "server_opt returns (w', mse)");
        agg.w = engine::f32_vec(&out[0])?;
        mse = engine::f32_scalar(&out[1])?;
    }

    // ---- Eq. (5): per-tensor alpha grid search ----------------------
    let client_refs: Vec<&[f32]> =
        agg.client_ws.iter().map(|v| v.as_slice()).collect();
    for seg in model.segments.iter().filter(|s| s.quantized) {
        let ai = seg.alpha_idx.unwrap();
        // candidate range from the clients' transmitted alphas
        let (mut lo, mut hi) = (f32::MAX, f32::MIN);
        for up_alpha in agg.client_alphas.iter() {
            lo = lo.min(up_alpha[ai]);
            hi = hi.max(up_alpha[ai]);
        }
        if !(lo.is_finite() && hi.is_finite()) || lo <= 0.0 {
            continue;
        }
        // common random numbers for all candidates of this segment
        let us: Vec<f64> =
            (0..seg.size).map(|_| rng.uniform_f64()).collect();
        let mut best = (agg.alpha[ai], f64::MAX);
        let n = cfg.grid_points.max(1);
        for gi in 0..n {
            let cand = if n == 1 {
                lo
            } else {
                lo + (hi - lo) * gi as f32 / (n - 1) as f32
            };
            if cand <= 0.0 {
                continue;
            }
            let mse = codec::segment_quant_mse(
                &agg.w,
                seg,
                cand,
                &client_refs,
                &agg.kweights,
                &us,
            );
            if mse < best.1 {
                best = (cand, mse);
            }
        }
        agg.alpha[ai] = best.0;
    }
    Ok(mse)
}
