//! Wire messages + communication accounting.
//!
//! Both link directions carry real packed payloads ([`crate::fp8::codec`]);
//! the byte counters here feed the paper's communication-gain metric
//! (Table 1) and the Figure-2 accuracy-vs-bytes curves.

use crate::fp8::codec::WirePayload;
use crate::net::codec::{
    partial_wire_bytes, JOB_FRAME_OVERHEAD_BYTES,
    OUTCOME_FRAME_OVERHEAD_BYTES, PARTIAL_FRAME_OVERHEAD_BYTES,
};

use super::aggregate::TreePartial;

/// Per-message framing charged on the downlink in addition to the
/// packed payload: every non-payload byte of a v2 Job frame — the
/// frame envelope (magic, version, kind, length, crc32), the scalar
/// job metadata (round/client ids, the v2 multiplexing job_id, seed,
/// quantizer switches, lr, weight decay, n_k) and the payload section
/// table. This is exactly
/// what `net::codec::encode_job` puts around the packed tensors, so
/// the reported byte counts equal the bytes a `SocketTransport`
/// really moves (asserted by `tests/net_transport.rs`; the optional
/// error-feedback residual blocks — simulation-only state migration —
/// are the one documented exclusion). Without framing the Table-1
/// communication gains would be optimistic — every real transport
/// sends an envelope around the tensor bytes.
pub const DOWNLINK_HEADER_BYTES: u64 = JOB_FRAME_OVERHEAD_BYTES;

/// Per-message framing charged on the uplink: every non-payload byte
/// of a v2 Outcome frame (envelope + round/client/job ids, n_k,
/// mean_loss + payload section table). Same exactness contract as
/// [`DOWNLINK_HEADER_BYTES`]. Heartbeat/HeartbeatAck frames are
/// deliberately *not* charged: they are transport liveness overhead,
/// not part of the paper's communication cost (and their volume is a
/// wall-clock tuning artifact, not a function of the trajectory).
pub const UPLINK_HEADER_BYTES: u64 = OUTCOME_FRAME_OVERHEAD_BYTES;

/// Per-message framing charged on a mid-tier -> root partial frame
/// (tree aggregation): every non-sum byte of a Partial frame — the
/// envelope plus the round/range/width/count metadata. Same exactness
/// contract as the job/outcome constants: a partial frame is exactly
/// `net::codec::partial_wire_bytes(p) + PARTIAL_HEADER_BYTES` on the
/// wire (asserted by `tests/net_transport.rs`).
pub const PARTIAL_HEADER_BYTES: u64 = PARTIAL_FRAME_OVERHEAD_BYTES;

/// Downlink: server -> client (global model + clip side channels).
#[derive(Clone, Debug)]
pub struct Downlink {
    pub payload: WirePayload,
    pub round: usize,
}

/// Uplink: client -> server (updated local model + clips + weighting).
#[derive(Clone, Debug)]
pub struct Uplink {
    pub payload: WirePayload,
    pub client: usize,
    /// n_k — local dataset size (FedAvg weighting).
    pub n_k: u64,
    pub mean_loss: f32,
}

/// Running totals of bytes that crossed each link.
///
/// Client-edge traffic (up/down) is the paper's communication metric
/// and is independent of the aggregation topology — a tree moves the
/// same uplinks, just through mid-tier nodes. Backbone traffic
/// (mid-tier -> root partials) is tracked separately: it exists only
/// under `--agg tree:G` and is server-infrastructure cost, not client
/// communication.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CommStats {
    pub up_bytes: u64,
    pub down_bytes: u64,
    pub up_msgs: u64,
    pub down_msgs: u64,
    /// Aggregation-backbone bytes (partial frames), tree mode only.
    pub partial_bytes: u64,
    pub partial_msgs: u64,
}

impl CommStats {
    pub fn record_up(&mut self, p: &WirePayload) {
        self.up_bytes += p.wire_bytes() + UPLINK_HEADER_BYTES;
        self.up_msgs += 1;
    }

    pub fn record_down(&mut self, p: &WirePayload) {
        self.down_bytes += p.wire_bytes() + DOWNLINK_HEADER_BYTES;
        self.down_msgs += 1;
    }

    pub fn record_partial(&mut self, p: &TreePartial) {
        self.partial_bytes += partial_wire_bytes(p) + PARTIAL_HEADER_BYTES;
        self.partial_msgs += 1;
    }

    /// Client-edge bytes — the paper's communication-gain metric.
    pub fn total_bytes(&self) -> u64 {
        self.up_bytes + self.down_bytes
    }

    /// Everything that moved, including the aggregation backbone.
    pub fn grand_total_bytes(&self) -> u64 {
        self.total_bytes() + self.partial_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting_accumulates() {
        let p = WirePayload {
            codes: vec![0u8; 100],
            raw: vec![0.0; 10],
            alphas: vec![1.0; 2],
            betas: vec![1.0; 3],
        };
        let mut s = CommStats::default();
        s.record_up(&p);
        s.record_down(&p);
        s.record_down(&p);
        // payload = 100 codes + 4 B * (10 raw + 2 alphas + 3 betas)
        let payload = 100 + 4 * 15;
        assert_eq!(s.up_bytes, payload + UPLINK_HEADER_BYTES);
        assert_eq!(s.down_bytes, 2 * (payload + DOWNLINK_HEADER_BYTES));
        // independently computed against the v2 frame layout:
        // 1 up (57 B overhead) + 2 down (72 B overhead each)
        assert_eq!(s.total_bytes(), 3 * payload + 57 + 2 * 72);
        assert_eq!((s.up_msgs, s.down_msgs), (1, 2));
    }

    #[test]
    fn framing_charges_fixed_header_per_message() {
        let empty = WirePayload::default();
        let mut s = CommStats::default();
        s.record_up(&empty);
        s.record_down(&empty);
        assert_eq!(s.up_bytes, UPLINK_HEADER_BYTES);
        assert_eq!(s.down_bytes, DOWNLINK_HEADER_BYTES);
    }

    #[test]
    fn partials_are_backbone_not_client_edge() {
        let p = TreePartial {
            start: 0,
            end: 4,
            width: 5,
            ranges: vec![(0, 4)],
            sums: vec![vec![0.0; 5]],
        };
        let mut s = CommStats::default();
        s.record_partial(&p);
        // 1 fragment of (16 B range header + 5 * 8 B sums) + 44 B
        // frame overhead (16 B envelope + 28 B partial meta)
        assert_eq!(s.partial_bytes, 16 + 40 + 44);
        assert_eq!(s.partial_msgs, 1);
        // client-edge metric unaffected; grand total includes it
        assert_eq!(s.total_bytes(), 0);
        assert_eq!(s.grand_total_bytes(), 100);
    }
}
