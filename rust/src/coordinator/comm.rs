//! Wire messages + communication accounting.
//!
//! Both link directions carry real packed payloads ([`crate::fp8::codec`]);
//! the byte counters here feed the paper's communication-gain metric
//! (Table 1) and the Figure-2 accuracy-vs-bytes curves.

use crate::fp8::codec::WirePayload;

/// Downlink: server -> client (global model + clip side channels).
#[derive(Clone, Debug)]
pub struct Downlink {
    pub payload: WirePayload,
    pub round: usize,
}

/// Uplink: client -> server (updated local model + clips + weighting).
#[derive(Clone, Debug)]
pub struct Uplink {
    pub payload: WirePayload,
    pub client: usize,
    /// n_k — local dataset size (FedAvg weighting).
    pub n_k: u64,
    pub mean_loss: f32,
}

/// Running totals of bytes that crossed each link.
#[derive(Clone, Copy, Debug, Default)]
pub struct CommStats {
    pub up_bytes: u64,
    pub down_bytes: u64,
    pub up_msgs: u64,
    pub down_msgs: u64,
}

impl CommStats {
    pub fn record_up(&mut self, p: &WirePayload) {
        self.up_bytes += p.wire_bytes();
        self.up_msgs += 1;
    }

    pub fn record_down(&mut self, p: &WirePayload) {
        self.down_bytes += p.wire_bytes();
        self.down_msgs += 1;
    }

    pub fn total_bytes(&self) -> u64 {
        self.up_bytes + self.down_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting_accumulates() {
        let p = WirePayload {
            codes: vec![0u8; 100],
            raw: vec![0.0; 10],
            alphas: vec![1.0; 2],
            betas: vec![1.0; 3],
        };
        let mut s = CommStats::default();
        s.record_up(&p);
        s.record_down(&p);
        s.record_down(&p);
        assert_eq!(s.up_bytes, 100 + 4 * 15);
        assert_eq!(s.down_bytes, 2 * (100 + 4 * 15));
        assert_eq!(s.total_bytes(), 3 * (100 + 4 * 15));
        assert_eq!((s.up_msgs, s.down_msgs), (1, 2));
    }
}
