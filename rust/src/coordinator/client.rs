//! Client-side executor: runs one client's full local round (`U` QAT
//! steps) by dispatching the AOT `local_update_*` artifact.
//!
//! A real deployment would run this on-device; here the in-process
//! [`super::transport::Transport`] simulates every client on the
//! shared thread-safe PJRT engine, potentially many at once (the
//! runner holds only shared references, so one instance per worker is
//! free). The *state contract* matches the paper exactly: the client
//! hard-resets its master weights to the dequantized downlink (already
//! on the FP8 grid), trains `U` steps of quantization-aware training,
//! and ships its new master weights through the stochastic wire codec.

use anyhow::{ensure, Context, Result};

use crate::config::QatMode;
use crate::runtime::{engine, Engine, In, ModelInfo};

/// Outcome of one client's local round.
pub struct LocalUpdate {
    pub w: Vec<f32>,
    pub alpha: Vec<f32>,
    pub beta: Vec<f32>,
    pub mean_loss: f32,
}

pub struct ClientRunner<'a> {
    pub engine: &'a Engine,
    pub model: &'a ModelInfo,
}

impl<'a> ClientRunner<'a> {
    /// Execute `local_update_<mode>` for one client.
    #[allow(clippy::too_many_arguments)]
    pub fn local_update(
        &self,
        mode: QatMode,
        w: &[f32],
        alpha: &[f32],
        beta: &[f32],
        xs: &[f32],
        ys: &[i32],
        lr: f32,
        wd: f32,
        seed: i32,
    ) -> Result<LocalUpdate> {
        let m = self.model;
        ensure!(w.len() == m.dim, "w dim mismatch");
        ensure!(alpha.len() == m.alpha_dim, "alpha dim mismatch");
        ensure!(beta.len() == m.n_act, "beta dim mismatch");
        ensure!(ys.len() == m.u_steps * m.batch, "label count mismatch");
        ensure!(
            xs.len() == ys.len() * m.feat_len(),
            "feature count mismatch"
        );
        let mut xdims: Vec<i64> =
            vec![m.u_steps as i64, m.batch as i64];
        xdims.extend(m.input_shape.iter().map(|&d| d as i64));
        let ydims = [m.u_steps as i64, m.batch as i64];
        let file = m.artifact("local_update", mode.artifact_suffix())?;
        let out = self
            .engine
            .execute(
                file,
                &[
                    In::F32(w, &[m.dim as i64]),
                    In::F32(alpha, &[m.alpha_dim as i64]),
                    In::F32(beta, &[m.n_act as i64]),
                    In::F32(xs, &xdims),
                    In::I32(ys, &ydims),
                    In::ScalarF32(lr),
                    In::ScalarF32(wd),
                    In::ScalarI32(seed),
                ],
            )
            .with_context(|| format!("local_update on {}", m.name))?;
        ensure!(out.len() == 4, "expected 4 outputs, got {}", out.len());
        Ok(LocalUpdate {
            w: engine::f32_vec(&out[0])?,
            alpha: engine::f32_vec(&out[1])?,
            beta: engine::f32_vec(&out[2])?,
            mean_loss: engine::f32_scalar(&out[3])?,
        })
    }

    /// Execute `evaluate_<mode>` on one test batch; returns
    /// (nll_sum, correct_count).
    pub fn evaluate(
        &self,
        mode: QatMode,
        w: &[f32],
        alpha: &[f32],
        beta: &[f32],
        x: &[f32],
        y: &[i32],
    ) -> Result<(f32, i32)> {
        let m = self.model;
        ensure!(y.len() == m.eval_batch, "eval batch mismatch");
        let mut xdims: Vec<i64> = vec![m.eval_batch as i64];
        xdims.extend(m.input_shape.iter().map(|&d| d as i64));
        // rand-QAT runs evaluate deterministically; aot exports eval
        // only for det/none, so map rand -> det.
        let suffix = match mode {
            QatMode::None => "none",
            _ => "det",
        };
        let file = m.artifact("evaluate", suffix)?;
        let out = self.engine.execute(
            file,
            &[
                In::F32(w, &[m.dim as i64]),
                In::F32(alpha, &[m.alpha_dim as i64]),
                In::F32(beta, &[m.n_act as i64]),
                In::F32(x, &xdims),
                In::I32(y, &[m.eval_batch as i64]),
            ],
        )?;
        ensure!(out.len() == 2, "expected 2 outputs");
        Ok((engine::f32_scalar(&out[0])?, engine::i32_scalar(&out[1])?))
    }
}
