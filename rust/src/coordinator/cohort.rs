//! Virtualized client state: a million-client population at O(cohort)
//! memory.
//!
//! The flat representation materializes one `Vec<usize>` shard per
//! client, which is fine for thousands of clients and fatal for the
//! "millions of users" scale target (K per-client structs just to
//! sample P << K of them per round). [`ClientShards`] keeps the same
//! observable contract — `shard(k)` / `n_k(k)` / `n_clients()` — but
//! for the i.i.d. split stores only the O(n_train) shuffled sample
//! order and materializes a client's shard on demand:
//!
//!   shard(k) = order[k], order[k + K], order[k + 2K], ...
//!
//! which is exactly the round-robin scatter `partition::iid` performs
//! (`shards[i % k].push(idx[i])`), so the virtual and dense paths are
//! index-for-index identical (pinned by tests here and in
//! tests/cohort_virtual.rs). The RNG consumption is identical too —
//! one full Fisher-Yates shuffle via [`partition::iid_order`] — so
//! crossing the [`VIRTUALIZE_AT`] threshold never moves a trajectory.
//!
//! Dirichlet and speaker splits are inherently dense (their shard
//! shapes depend on per-example labels/groups), so they stay
//! materialized; populations that large should use the i.i.d. split.

use std::borrow::Cow;

use crate::data::partition;
use crate::fp8::rng::Pcg32;

/// Client-population threshold at which `build_world` switches the
/// i.i.d. split to the virtual representation.
pub const VIRTUALIZE_AT: usize = 65_536;

/// Per-client training shards, dense or virtualized.
pub enum ClientShards {
    /// One materialized index vector per client (small populations,
    /// or the inherently dense Dirichlet/speaker splits).
    Dense(Vec<Vec<usize>>),
    /// i.i.d. split over a huge population: only the shuffled sample
    /// order is stored; any client's shard is the strided
    /// sub-sequence starting at its index.
    VirtualIid { order: Vec<usize>, clients: usize },
}

impl ClientShards {
    pub fn dense(shards: Vec<Vec<usize>>) -> ClientShards {
        ClientShards::Dense(shards)
    }

    /// Virtualized i.i.d. split over `clients` clients; consumes
    /// `rng` identically to `partition::iid(n, clients, rng)`.
    pub fn virtual_iid(
        n: usize,
        clients: usize,
        rng: &mut Pcg32,
    ) -> ClientShards {
        assert!(clients > 0, "zero clients");
        ClientShards::VirtualIid {
            order: partition::iid_order(n, rng),
            clients,
        }
    }

    pub fn n_clients(&self) -> usize {
        match self {
            ClientShards::Dense(s) => s.len(),
            ClientShards::VirtualIid { clients, .. } => *clients,
        }
    }

    /// Client `k`'s sample count, without materializing the shard.
    pub fn n_k(&self, client: usize) -> u64 {
        match self {
            ClientShards::Dense(s) => s[client].len() as u64,
            ClientShards::VirtualIid { order, clients } => {
                assert!(client < *clients, "client {client} out of range");
                let n = order.len();
                if client < n {
                    // |{ i < n : i mod K == client }|
                    ((n - client - 1) / clients + 1) as u64
                } else {
                    0
                }
            }
        }
    }

    /// Client `k`'s shard: borrowed when dense, materialized on
    /// demand (O(n_k)) when virtual.
    pub fn shard(&self, client: usize) -> Cow<'_, [usize]> {
        match self {
            ClientShards::Dense(s) => Cow::Borrowed(&s[client][..]),
            ClientShards::VirtualIid { order, clients } => {
                assert!(client < *clients, "client {client} out of range");
                Cow::Owned(
                    order
                        .iter()
                        .skip(client)
                        .step_by(*clients)
                        .copied()
                        .collect(),
                )
            }
        }
    }

    /// True when per-client structs are materialized on demand rather
    /// than held resident.
    pub fn is_virtual(&self) -> bool {
        matches!(self, ClientShards::VirtualIid { .. })
    }

    /// Number of per-client index vectors resident in memory right
    /// now — the struct-count probe behind the O(cohort) memory
    /// contract (0 when virtualized; asserted in
    /// tests/cohort_virtual.rs).
    pub fn resident_structs(&self) -> usize {
        match self {
            ClientShards::Dense(s) => s.len(),
            ClientShards::VirtualIid { .. } => 0,
        }
    }

    /// Total samples across all clients (each index appears in
    /// exactly one shard).
    pub fn total_samples(&self) -> usize {
        match self {
            ClientShards::Dense(s) => s.iter().map(Vec::len).sum(),
            ClientShards::VirtualIid { order, .. } => order.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pair(n: usize, k: usize) -> (Vec<Vec<usize>>, ClientShards) {
        let dense =
            partition::iid(n, k, &mut Pcg32::new(7, 0x9A27_1710));
        let virt = ClientShards::virtual_iid(
            n,
            k,
            &mut Pcg32::new(7, 0x9A27_1710),
        );
        (dense, virt)
    }

    #[test]
    fn virtual_matches_dense_partition() {
        for (n, k) in [(96usize, 6usize), (100, 7), (5, 9), (0, 3)] {
            let (dense, virt) = pair(n, k);
            assert_eq!(virt.n_clients(), k);
            for (c, shard) in dense.iter().enumerate() {
                assert_eq!(
                    virt.shard(c).as_ref(),
                    &shard[..],
                    "shard {c} diverged at n={n} k={k}"
                );
                assert_eq!(virt.n_k(c), shard.len() as u64);
            }
            assert_eq!(virt.total_samples(), n);
        }
    }

    #[test]
    fn virtual_holds_no_per_client_structs() {
        let (dense, virt) = pair(96, 6);
        assert_eq!(virt.resident_structs(), 0);
        assert!(virt.is_virtual());
        let d = ClientShards::dense(dense);
        assert_eq!(d.resident_structs(), 6);
        assert!(!d.is_virtual());
    }

    #[test]
    fn million_clients_cost_o_cohort() {
        // K = 10^6 clients over 96 samples: shards are almost all
        // empty, n_k is exact, and nothing K-sized is allocated
        let virt = ClientShards::virtual_iid(
            96,
            1_000_000,
            &mut Pcg32::new(3, 1),
        );
        assert_eq!(virt.n_clients(), 1_000_000);
        assert_eq!(virt.resident_structs(), 0);
        let total: u64 =
            (0..200).map(|c| virt.n_k(c * 4999)).sum();
        assert!(total <= 96);
        assert_eq!(virt.n_k(95), 1);
        assert_eq!(virt.n_k(96), 0);
        assert_eq!(virt.shard(999_999).len(), 0);
        assert_eq!(virt.shard(95).len(), 1);
    }
}
