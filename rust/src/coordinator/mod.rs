//! Layer-3 coordination: the paper's federated-learning protocol.

pub mod aggregate;
pub mod client;
pub mod cohort;
pub mod comm;
pub mod metrics;
pub mod server;
pub mod server_opt;
pub mod snapshot;
pub mod transport;
pub mod tree;

pub use cohort::{ClientShards, VIRTUALIZE_AT};
pub use metrics::{comm_gain, mean_std, RoundRecord, RunResult};
pub use server::{build_world, ClientStateProbe, Server, World};
pub use snapshot::{SnapshotError, SnapshotState, SNAPSHOT_VERSION};
pub use transport::{
    ClientJob, ClientOutcome, InProcessTransport, Transport, WorkBuffers,
};
