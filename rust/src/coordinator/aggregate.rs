//! Server-side aggregation: federated averaging over decoded uplinks.
//!
//! `w_{t+1} = sum_{k in P_t} (n_k / m_t) dequant(uplink_k)` — the
//! uplinks are already on each client's FP8 grid (Q_rand applied by the
//! client codec), so averaging the dequantized values in FP32 is
//! exactly Algorithm 1's aggregation step. Alphas and betas are
//! averaged unquantized (they travel as f32 side channels).

use anyhow::{ensure, Result};

use crate::fp8::codec::{self, Segment};

use super::comm::Uplink;

/// Result of one aggregation: FP32 master model + averaged clips.
pub struct Aggregate {
    pub w: Vec<f32>,
    pub alpha: Vec<f32>,
    pub beta: Vec<f32>,
    /// Per-client dequantized weight vectors (kept for ServerOptimize).
    pub client_ws: Vec<Vec<f32>>,
    /// Per-client alpha side channels (Eq. (5) search range).
    pub client_alphas: Vec<Vec<f32>>,
    /// Per-client FedAvg weights n_k/m_t.
    pub kweights: Vec<f32>,
    pub mean_loss: f32,
}

pub fn fedavg(
    uplinks: &[Uplink],
    segments: &[Segment],
    dim: usize,
    alpha_dim: usize,
    beta_dim: usize,
) -> Result<Aggregate> {
    ensure!(!uplinks.is_empty(), "no uplinks to aggregate");
    let m_t: u64 = uplinks.iter().map(|u| u.n_k).sum();
    ensure!(m_t > 0, "zero total samples");
    let mut w = vec![0.0f32; dim];
    let mut alpha = vec![0.0f32; alpha_dim];
    let mut beta = vec![0.0f32; beta_dim];
    let mut client_ws = Vec::with_capacity(uplinks.len());
    let mut client_alphas = Vec::with_capacity(uplinks.len());
    let mut kweights = Vec::with_capacity(uplinks.len());
    let mut mean_loss = 0.0f32;
    let mut buf = vec![0.0f32; dim];
    for up in uplinks {
        let kw = up.n_k as f32 / m_t as f32;
        codec::decode(&up.payload, segments, &mut buf);
        for (acc, &v) in w.iter_mut().zip(&buf) {
            *acc += kw * v;
        }
        for (acc, &v) in alpha.iter_mut().zip(&up.payload.alphas) {
            *acc += kw * v;
        }
        for (acc, &v) in beta.iter_mut().zip(&up.payload.betas) {
            *acc += kw * v;
        }
        mean_loss += kw * up.mean_loss;
        client_ws.push(buf.clone());
        client_alphas.push(up.payload.alphas.clone());
        kweights.push(kw);
    }
    Ok(Aggregate {
        w,
        alpha,
        beta,
        client_ws,
        client_alphas,
        kweights,
        mean_loss,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::codec::{encode, Rounding};
    use crate::fp8::rng::Pcg32;

    fn segs() -> Vec<Segment> {
        vec![Segment {
            name: "w".into(),
            offset: 0,
            size: 8,
            quantized: true,
            alpha_idx: Some(0),
        }]
    }

    fn uplink(vals: &[f32], alpha: f32, n_k: u64) -> Uplink {
        let mut rng = Pcg32::new(1, 0);
        Uplink {
            payload: encode(vals, &[alpha], &[2.0], &segs(),
                            Rounding::Deterministic, &mut rng),
            client: 0,
            n_k,
            mean_loss: 1.0,
        }
    }

    #[test]
    fn equal_weights_average() {
        // values already exactly on the grid for alpha=1 -> lossless
        let a = uplink(&[0.5; 8], 1.0, 10);
        let b = uplink(&[1.0; 8], 1.0, 10);
        let agg = fedavg(&[a, b], &segs(), 8, 1, 1).unwrap();
        assert!(agg.w.iter().all(|&v| (v - 0.75).abs() < 1e-6));
        assert_eq!(agg.kweights, vec![0.5, 0.5]);
    }

    #[test]
    fn nk_weighting() {
        let a = uplink(&[0.0; 8], 1.0, 30);
        let b = uplink(&[1.0; 8], 1.0, 10);
        let agg = fedavg(&[a, b], &segs(), 8, 1, 1).unwrap();
        assert!(agg.w.iter().all(|&v| (v - 0.25).abs() < 1e-6));
        // alpha averaged with same weights
        assert!((agg.alpha[0] - 1.0).abs() < 1e-6);
        assert!((agg.beta[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn rejects_empty() {
        assert!(fedavg(&[], &segs(), 8, 1, 1).is_err());
    }

    #[test]
    fn keeps_client_vectors_for_server_opt() {
        let a = uplink(&[0.5; 8], 1.0, 1);
        let agg = fedavg(&[a], &segs(), 8, 1, 1).unwrap();
        assert_eq!(agg.client_ws.len(), 1);
        assert_eq!(agg.client_ws[0], agg.w);
    }
}
