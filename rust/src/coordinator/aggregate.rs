//! Server-side aggregation: federated averaging over decoded uplinks.
//!
//! `w_{t+1} = sum_{k in P_t} (n_k / m_t) dequant(uplink_k)` — the
//! uplinks are already on each client's FP8 grid (Q_rand applied by the
//! client codec), so averaging the dequantized values is exactly
//! Algorithm 1's aggregation step. Alphas and betas are averaged
//! unquantized (they travel as f32 side channels).
//!
//! [`FedAvgStream`] is the streaming form used by the parallel round
//! loop: uplinks are folded into the weighted sums one at a time as
//! the cohort delivers them (decode + accumulate + drop), so the
//! server never buffers the whole cohort's decoded tensors. Per-client
//! vectors are retained only when ServerOptimize needs them.
//!
//! ## Canonical pairwise accumulation (the tree-vs-flat contract)
//!
//! Sums accumulate in f64 through a *canonical pairwise reduction*
//! over cohort positions: each uplink's weighted contribution is a
//! leaf at its cohort position, and two adjacent fragments
//! `[s, s+l) + [s+l, s+2l)` merge only when `l0 == l1` and
//! `s % 2l == 0` — the segment decomposition of a perfect binary tree
//! over positions. The f64 addition tree for any position range is
//! therefore a pure function of the range, independent of how the
//! cohort is sharded across aggregator nodes, so a mid-tier
//! aggregator covering positions `[s, e)` produces *exactly* the
//! fragments the flat stream holds internally for those positions — a
//! depth-D tree of [`FedAvgStream`]s (compose via
//! [`FedAvgStream::into_partial`] / [`FedAvgStream::absorb`]) is
//! bit-identical to the flat stream (pinned by
//! tests/tree_determinism.rs). Pending state is O(log P) fragments;
//! the final f64 → f32 rounding happens once, in
//! [`FedAvgStream::finish`].
//!
//! Determinism note: positions are assigned in push order, so callers
//! must push uplinks in cohort order — `transport::run_cohort`
//! guarantees that ordering regardless of thread count.

use anyhow::{ensure, Result};

use crate::fp8::codec::{self, DecodeLutCache, Segment};

use super::comm::Uplink;

/// Result of one aggregation: FP32 master model + averaged clips.
pub struct Aggregate {
    pub w: Vec<f32>,
    pub alpha: Vec<f32>,
    pub beta: Vec<f32>,
    /// Per-client dequantized weight vectors (kept for ServerOptimize;
    /// empty when the stream was built with `keep_clients = false`).
    pub client_ws: Vec<Vec<f32>>,
    /// Per-client alpha side channels (Eq. (5) search range).
    pub client_alphas: Vec<Vec<f32>>,
    /// Per-client FedAvg weights n_k/m_t.
    pub kweights: Vec<f32>,
    pub mean_loss: f32,
}

/// How cohort members are weighted in the round mean.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Weighting {
    /// The paper weighting: `n_k / m_t`.
    BySamples { m_t: u64 },
    /// Degenerate cohort — every sampled shard is empty (`m_t == 0`),
    /// which a K >> n_train virtualized population makes routine.
    /// Uniform `1/P` weights keep the round a well-defined mean.
    Uniform { cohort: u64 },
}

impl Weighting {
    /// Pick the weighting for a cohort with total sample count `m_t`.
    pub fn for_cohort(m_t: u64, cohort: usize) -> Weighting {
        if m_t > 0 {
            Weighting::BySamples { m_t }
        } else {
            Weighting::Uniform { cohort: cohort as u64 }
        }
    }

    /// The FedAvg coefficient for a member holding `n_k` samples.
    pub fn kw(&self, n_k: u64) -> f64 {
        match *self {
            Weighting::BySamples { m_t } => n_k as f64 / m_t as f64,
            Weighting::Uniform { cohort } => 1.0 / cohort as f64,
        }
    }
}

/// One aggregator's frozen partial: the canonical pending fragments
/// over its contiguous cohort position range `[start, end)`. The f64
/// sums travel bit-exactly (the wire codec ships raw bit patterns,
/// `net::codec::{encode,decode}_partial`), so absorbing a forwarded
/// partial replays exactly the f64 adds the flat stream would have
/// performed on those positions.
#[derive(Clone, Debug, PartialEq)]
pub struct TreePartial {
    pub start: u64,
    pub end: u64,
    /// Leaf vector width = dim + alpha_dim + beta_dim + 1 (loss last).
    pub width: u32,
    /// Canonical fragments in ascending position order: `(start, len)`
    /// paired 1:1 with `sums` (one f64 vector of `width` each). At
    /// most O(log P) of them — the dyadic decomposition of
    /// `[start, end)`.
    pub ranges: Vec<(u64, u64)>,
    pub sums: Vec<Vec<f64>>,
}

impl TreePartial {
    /// Leaves (uplinks) covered by this partial.
    pub fn leaves(&self) -> u64 {
        self.end - self.start
    }
}

/// Canonical pairwise f64 accumulator over global cohort positions
/// (see the module doc for the alignment rule and why it makes tree
/// aggregation bit-identical to flat).
struct PairwiseAcc {
    width: usize,
    next_pos: u64,
    /// Pending fragments, ascending and contiguous: `(start, len)`.
    ranges: Vec<(u64, u64)>,
    sums: Vec<Vec<f64>>,
    /// Retired fragment buffers, reused for new leaves (the pairwise
    /// reduction retires one buffer per merge, so a million-leaf round
    /// allocates O(log P) vectors, not O(P)).
    spare: Vec<Vec<f64>>,
}

impl PairwiseAcc {
    fn start_at(width: usize, start: u64) -> PairwiseAcc {
        PairwiseAcc {
            width,
            next_pos: start,
            ranges: Vec::new(),
            sums: Vec::new(),
            spare: Vec::new(),
        }
    }

    /// Merge tail fragments while the alignment rule allows:
    /// equal lengths and the left fragment starts on a `2l` boundary.
    fn settle(&mut self) {
        while self.ranges.len() >= 2 {
            let (s1, l1) = self.ranges[self.ranges.len() - 1];
            let (s0, l0) = self.ranges[self.ranges.len() - 2];
            if l0 != l1 || s0 % (2 * l0) != 0 {
                break;
            }
            debug_assert_eq!(s0 + l0, s1, "fragments not contiguous");
            let top = self.sums.pop().unwrap();
            let into = self.sums.last_mut().unwrap();
            for (a, b) in into.iter_mut().zip(&top) {
                *a += *b;
            }
            self.spare.push(top);
            self.ranges.pop();
            let last = self.ranges.len() - 1;
            self.ranges[last] = (s0, 2 * l0);
        }
    }

    /// A leaf buffer to fill (recycled from a retired fragment when
    /// possible), already sized to `width`.
    fn leaf_buf(&mut self) -> Vec<f64> {
        match self.spare.pop() {
            Some(mut v) => {
                v.clear();
                v.resize(self.width, 0.0);
                v
            }
            None => vec![0.0; self.width],
        }
    }

    fn push_leaf(&mut self, leaf: Vec<f64>) {
        debug_assert_eq!(leaf.len(), self.width);
        self.ranges.push((self.next_pos, 1));
        self.sums.push(leaf);
        self.next_pos += 1;
        self.settle();
    }

    /// Append a fragment produced by a downstream accumulator over the
    /// positions immediately following ours.
    fn append_range(
        &mut self,
        start: u64,
        len: u64,
        sum: Vec<f64>,
    ) -> Result<()> {
        ensure!(
            start == self.next_pos,
            "partial fragment starts at {start}, expected {}",
            self.next_pos
        );
        ensure!(len >= 1, "empty partial fragment at {start}");
        ensure!(
            sum.len() == self.width,
            "partial fragment width {} != stream width {}",
            sum.len(),
            self.width
        );
        self.ranges.push((start, len));
        self.sums.push(sum);
        self.next_pos = start + len;
        self.settle();
        Ok(())
    }

    /// Fold the pending fragments right-to-left into the final sum.
    /// Flat and tree runs arrive here with the identical pending set
    /// (the dyadic decomposition of the full range), so the fold
    /// order is shared too.
    fn finish(mut self) -> Vec<f64> {
        while self.sums.len() > 1 {
            let top = self.sums.pop().unwrap();
            let into = self.sums.last_mut().unwrap();
            for (a, b) in into.iter_mut().zip(&top) {
                *a += *b;
            }
        }
        self.sums.pop().unwrap_or_else(|| vec![0.0; self.width])
    }
}

/// Streaming weighted accumulator for one round's uplinks.
///
/// `m_t` (the cohort's total sample count) is known before any client
/// finishes — the server samples the cohort and knows every `n_k` — so
/// each uplink can be folded in with its final weight `n_k / m_t` the
/// moment it arrives. In a tree, a mid-tier stream covers the cohort
/// positions `[start, start + shard_len)` and is frozen into a
/// [`TreePartial`] for forwarding; the upstream stream [`absorb`]s
/// partials in cohort order, interchangeably with direct [`push`]es.
///
/// [`absorb`]: FedAvgStream::absorb
/// [`push`]: FedAvgStream::push
pub struct FedAvgStream<'s> {
    segments: &'s [Segment],
    weighting: Weighting,
    dim: usize,
    alpha_dim: usize,
    beta_dim: usize,
    start: u64,
    acc: PairwiseAcc,
    /// Uplinks folded in, directly or via absorbed partials.
    leaves: u64,
    keep_clients: bool,
    client_ws: Vec<Vec<f32>>,
    client_alphas: Vec<Vec<f32>>,
    kweights: Vec<f32>,
    /// Reused decode buffer — one allocation per round, not per uplink.
    buf: Vec<f32>,
    /// Decode-table cache shared by every uplink this stream folds in
    /// (clients whose alphas agree — common early in training and
    /// whenever ServerOptimize pins them — decode off the same LUT).
    lut: DecodeLutCache,
}

impl<'s> FedAvgStream<'s> {
    /// Root stream with the paper's by-samples weighting (errors on
    /// `m_t == 0`; use [`Weighting::for_cohort`] +
    /// [`FedAvgStream::with_weighting`] when the cohort may be
    /// degenerate).
    pub fn new(
        segments: &'s [Segment],
        dim: usize,
        alpha_dim: usize,
        beta_dim: usize,
        m_t: u64,
        keep_clients: bool,
    ) -> Result<FedAvgStream<'s>> {
        ensure!(m_t > 0, "zero total samples");
        Self::with_weighting(
            segments,
            dim,
            alpha_dim,
            beta_dim,
            Weighting::BySamples { m_t },
            keep_clients,
            0,
        )
    }

    /// General constructor: explicit weighting and starting cohort
    /// position (`start > 0` makes a mid-tier stream over a later
    /// shard of the cohort).
    pub fn with_weighting(
        segments: &'s [Segment],
        dim: usize,
        alpha_dim: usize,
        beta_dim: usize,
        weighting: Weighting,
        keep_clients: bool,
        start: u64,
    ) -> Result<FedAvgStream<'s>> {
        match weighting {
            Weighting::BySamples { m_t } => {
                ensure!(m_t > 0, "zero total samples")
            }
            Weighting::Uniform { cohort } => {
                ensure!(cohort > 0, "zero cohort")
            }
        }
        let width = dim + alpha_dim + beta_dim + 1;
        Ok(FedAvgStream {
            segments,
            weighting,
            dim,
            alpha_dim,
            beta_dim,
            start,
            acc: PairwiseAcc::start_at(width, start),
            leaves: 0,
            keep_clients,
            client_ws: Vec::new(),
            client_alphas: Vec::new(),
            kweights: Vec::new(),
            buf: vec![0.0f32; dim],
            lut: DecodeLutCache::default(),
        })
    }

    /// Fold one uplink into the running weighted sums at the next
    /// cohort position.
    pub fn push(&mut self, up: &Uplink) {
        let kw = self.weighting.kw(up.n_k);
        codec::decode_pooled(
            &up.payload,
            self.segments,
            &mut self.lut,
            1,
            &mut self.buf,
        );
        let (d, ad, bd) = (self.dim, self.alpha_dim, self.beta_dim);
        let mut leaf = self.acc.leaf_buf();
        for (o, &v) in leaf[..d].iter_mut().zip(self.buf.iter()) {
            *o = kw * v as f64;
        }
        for (o, &v) in
            leaf[d..d + ad].iter_mut().zip(&up.payload.alphas)
        {
            *o = kw * v as f64;
        }
        for (o, &v) in
            leaf[d + ad..d + ad + bd].iter_mut().zip(&up.payload.betas)
        {
            *o = kw * v as f64;
        }
        leaf[d + ad + bd] = kw * up.mean_loss as f64;
        self.acc.push_leaf(leaf);
        self.leaves += 1;
        if self.keep_clients {
            self.client_ws.push(self.buf.clone());
            self.client_alphas.push(up.payload.alphas.clone());
        }
        self.kweights.push(kw as f32);
    }

    /// Fold a downstream aggregator's partial in at the current cohort
    /// frontier: its fragments append contiguously and merge on the
    /// same alignment rule as direct pushes, so the resulting f64
    /// state is bit-identical to having pushed those uplinks here.
    pub fn absorb(&mut self, p: &TreePartial) -> Result<()> {
        ensure!(
            p.width as usize == self.acc.width,
            "partial width {} != stream width {}",
            p.width,
            self.acc.width
        );
        ensure!(
            p.ranges.len() == p.sums.len(),
            "partial has {} ranges but {} sums",
            p.ranges.len(),
            p.sums.len()
        );
        ensure!(
            p.start == self.acc.next_pos,
            "partial covers [{}, {}) but stream frontier is {}",
            p.start,
            p.end,
            self.acc.next_pos
        );
        for (&(s, l), sum) in p.ranges.iter().zip(&p.sums) {
            self.acc.append_range(s, l, sum.clone())?;
        }
        ensure!(
            self.acc.next_pos == p.end,
            "partial fragments do not tile [{}, {})",
            p.start,
            p.end
        );
        self.leaves += p.leaves();
        Ok(())
    }

    /// Freeze a mid-tier stream into the weighted partial it forwards
    /// upstream. Per-client retention is a root-only (ServerOptimize)
    /// feature, and ServerOptimize is flat-only — rejected here and at
    /// config validation.
    pub fn into_partial(self) -> Result<TreePartial> {
        ensure!(
            !self.keep_clients,
            "per-client retention cannot cross a tree link"
        );
        Ok(TreePartial {
            start: self.start,
            end: self.acc.next_pos,
            width: self.acc.width as u32,
            ranges: self.acc.ranges,
            sums: self.acc.sums,
        })
    }

    pub fn finish(self) -> Result<Aggregate> {
        ensure!(self.leaves > 0, "no uplinks to aggregate");
        let (d, ad, bd) = (self.dim, self.alpha_dim, self.beta_dim);
        let total = self.acc.finish();
        Ok(Aggregate {
            w: total[..d].iter().map(|&v| v as f32).collect(),
            alpha: total[d..d + ad].iter().map(|&v| v as f32).collect(),
            beta: total[d + ad..d + ad + bd]
                .iter()
                .map(|&v| v as f32)
                .collect(),
            client_ws: self.client_ws,
            client_alphas: self.client_alphas,
            kweights: self.kweights,
            mean_loss: total[d + ad + bd] as f32,
        })
    }
}

/// Batch federated averaging over a buffered cohort — a thin wrapper
/// around [`FedAvgStream`] (always retains per-client vectors).
pub fn fedavg(
    uplinks: &[Uplink],
    segments: &[Segment],
    dim: usize,
    alpha_dim: usize,
    beta_dim: usize,
) -> Result<Aggregate> {
    ensure!(!uplinks.is_empty(), "no uplinks to aggregate");
    let m_t: u64 = uplinks.iter().map(|u| u.n_k).sum();
    let mut stream =
        FedAvgStream::new(segments, dim, alpha_dim, beta_dim, m_t, true)?;
    for up in uplinks {
        stream.push(up);
    }
    stream.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::codec::{encode, Rounding};
    use crate::fp8::rng::Pcg32;

    fn segs() -> Vec<Segment> {
        vec![Segment {
            name: "w".into(),
            offset: 0,
            size: 8,
            quantized: true,
            alpha_idx: Some(0),
        }]
    }

    fn uplink(vals: &[f32], alpha: f32, n_k: u64) -> Uplink {
        let mut rng = Pcg32::new(1, 0);
        Uplink {
            payload: encode(vals, &[alpha], &[2.0], &segs(),
                            Rounding::Deterministic, &mut rng),
            client: 0,
            n_k,
            mean_loss: 1.0,
        }
    }

    #[test]
    fn equal_weights_average() {
        // values already exactly on the grid for alpha=1 -> lossless
        let a = uplink(&[0.5; 8], 1.0, 10);
        let b = uplink(&[1.0; 8], 1.0, 10);
        let agg = fedavg(&[a, b], &segs(), 8, 1, 1).unwrap();
        assert!(agg.w.iter().all(|&v| (v - 0.75).abs() < 1e-6));
        assert_eq!(agg.kweights, vec![0.5, 0.5]);
    }

    #[test]
    fn nk_weighting() {
        let a = uplink(&[0.0; 8], 1.0, 30);
        let b = uplink(&[1.0; 8], 1.0, 10);
        let agg = fedavg(&[a, b], &segs(), 8, 1, 1).unwrap();
        assert!(agg.w.iter().all(|&v| (v - 0.25).abs() < 1e-6));
        // alpha averaged with same weights
        assert!((agg.alpha[0] - 1.0).abs() < 1e-6);
        assert!((agg.beta[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn rejects_empty() {
        assert!(fedavg(&[], &segs(), 8, 1, 1).is_err());
    }

    #[test]
    fn stream_matches_batch_bitwise() {
        let ups = [
            uplink(&[0.5; 8], 1.0, 30),
            uplink(&[1.0; 8], 0.7, 10),
            uplink(&[0.25; 8], 1.3, 5),
        ];
        let m_t = ups.iter().map(|u| u.n_k).sum();
        let segs = segs();
        let batch = fedavg(&ups, &segs, 8, 1, 1).unwrap();
        let mut s =
            FedAvgStream::new(&segs, 8, 1, 1, m_t, false).unwrap();
        for up in &ups {
            s.push(up);
        }
        let streamed = s.finish().unwrap();
        assert_eq!(streamed.w, batch.w);
        assert_eq!(streamed.alpha, batch.alpha);
        assert_eq!(streamed.beta, batch.beta);
        assert_eq!(streamed.kweights, batch.kweights);
        assert_eq!(streamed.mean_loss, batch.mean_loss);
        // memory contract: nothing retained unless asked
        assert!(streamed.client_ws.is_empty());
        assert!(!batch.client_ws.is_empty());
    }

    #[test]
    fn stream_rejects_empty_cohort() {
        let segs = segs();
        assert!(FedAvgStream::new(&segs, 8, 1, 1, 0, false).is_err());
        let s = FedAvgStream::new(&segs, 8, 1, 1, 10, false).unwrap();
        assert!(s.finish().is_err());
    }

    #[test]
    fn keeps_client_vectors_for_server_opt() {
        let a = uplink(&[0.5; 8], 1.0, 1);
        let agg = fedavg(&[a], &segs(), 8, 1, 1).unwrap();
        assert_eq!(agg.client_ws.len(), 1);
        assert_eq!(agg.client_ws[0], agg.w);
    }

    fn cohort(n: usize) -> Vec<Uplink> {
        (0..n)
            .map(|c| {
                uplink(
                    &[0.1 * c as f32 - 0.3; 8],
                    0.8 + 0.07 * c as f32,
                    (c as u64 * 13 + 1) % 40 + 1,
                )
            })
            .collect()
    }

    fn flat(ups: &[Uplink], segs: &[Segment], w: Weighting) -> Aggregate {
        let mut s =
            FedAvgStream::with_weighting(segs, 8, 1, 1, w, false, 0)
                .unwrap();
        for up in ups {
            s.push(up);
        }
        s.finish().unwrap()
    }

    #[test]
    fn partials_compose_bitwise_at_any_split() {
        // the tree contract at the aggregate layer: shard the cohort
        // at every possible boundary pair, forward partials, and the
        // root must match the flat stream bit-for-bit
        let segs = segs();
        let ups = cohort(7);
        let m_t: u64 = ups.iter().map(|u| u.n_k).sum();
        let w = Weighting::BySamples { m_t };
        let base = flat(&ups, &segs, w);
        for cut1 in 0..=ups.len() {
            for cut2 in cut1..=ups.len() {
                let mut root = FedAvgStream::with_weighting(
                    &segs, 8, 1, 1, w, false, 0,
                )
                .unwrap();
                for (lo, hi) in
                    [(0, cut1), (cut1, cut2), (cut2, ups.len())]
                {
                    if lo == hi {
                        continue;
                    }
                    let mut mid = FedAvgStream::with_weighting(
                        &segs, 8, 1, 1, w, false, lo as u64,
                    )
                    .unwrap();
                    for up in &ups[lo..hi] {
                        mid.push(up);
                    }
                    root.absorb(&mid.into_partial().unwrap()).unwrap();
                }
                let agg = root.finish().unwrap();
                let bits = |v: &[f32]| -> Vec<u32> {
                    v.iter().map(|x| x.to_bits()).collect()
                };
                assert_eq!(
                    bits(&agg.w),
                    bits(&base.w),
                    "diverged at cuts ({cut1}, {cut2})"
                );
                assert_eq!(bits(&agg.alpha), bits(&base.alpha));
                assert_eq!(bits(&agg.beta), bits(&base.beta));
                assert_eq!(
                    agg.mean_loss.to_bits(),
                    base.mean_loss.to_bits()
                );
            }
        }
    }

    #[test]
    fn partials_compose_across_depths() {
        // depth-3: grandchildren -> mid-tier -> root is still
        // bit-identical to flat (absorb composes)
        let segs = segs();
        let ups = cohort(6);
        let m_t: u64 = ups.iter().map(|u| u.n_k).sum();
        let w = Weighting::BySamples { m_t };
        let base = flat(&ups, &segs, w);
        let mut root =
            FedAvgStream::with_weighting(&segs, 8, 1, 1, w, false, 0)
                .unwrap();
        for (lo, hi) in [(0usize, 3usize), (3, 6)] {
            let mut mid = FedAvgStream::with_weighting(
                &segs, 8, 1, 1, w, false, lo as u64,
            )
            .unwrap();
            for (glo, ghi) in [(lo, lo + 1), (lo + 1, hi)] {
                let mut leafagg = FedAvgStream::with_weighting(
                    &segs, 8, 1, 1, w, false, glo as u64,
                )
                .unwrap();
                for up in &ups[glo..ghi] {
                    leafagg.push(up);
                }
                mid.absorb(&leafagg.into_partial().unwrap()).unwrap();
            }
            root.absorb(&mid.into_partial().unwrap()).unwrap();
        }
        let agg = root.finish().unwrap();
        assert_eq!(
            agg.w.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            base.w.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(agg.mean_loss.to_bits(), base.mean_loss.to_bits());
    }

    #[test]
    fn absorb_rejects_gaps_and_width_mismatch() {
        let segs = segs();
        let m_t = 10;
        let w = Weighting::BySamples { m_t };
        let mut mid =
            FedAvgStream::with_weighting(&segs, 8, 1, 1, w, false, 2)
                .unwrap();
        mid.push(&uplink(&[0.5; 8], 1.0, 10));
        let p = mid.into_partial().unwrap();
        // root frontier is 0, partial starts at 2 -> gap
        let mut root =
            FedAvgStream::with_weighting(&segs, 8, 1, 1, w, false, 0)
                .unwrap();
        assert!(root.absorb(&p).is_err());
        // width mismatch
        let mut bad = p.clone();
        bad.start = 0;
        bad.width += 1;
        assert!(root.absorb(&bad).is_err());
    }

    #[test]
    fn uniform_weighting_for_degenerate_cohort() {
        // all-empty shards (m_t = 0): uniform 1/P weights make the
        // round the plain mean of the uplinks
        let segs = segs();
        let ups =
            [uplink(&[0.5; 8], 1.0, 0), uplink(&[1.0; 8], 1.0, 0)];
        let w = Weighting::for_cohort(0, ups.len());
        assert_eq!(w, Weighting::Uniform { cohort: 2 });
        let agg = flat(&ups, &segs, w);
        assert!(agg.w.iter().all(|&v| (v - 0.75).abs() < 1e-6));
        assert_eq!(agg.kweights, vec![0.5, 0.5]);
        // and a non-degenerate cohort keeps the paper weighting
        assert_eq!(
            Weighting::for_cohort(40, 2),
            Weighting::BySamples { m_t: 40 }
        );
    }

    #[test]
    fn into_partial_rejects_client_retention() {
        let segs = segs();
        let s = FedAvgStream::new(&segs, 8, 1, 1, 10, true).unwrap();
        assert!(s.into_partial().is_err());
    }
}
