//! Server-side aggregation: federated averaging over decoded uplinks.
//!
//! `w_{t+1} = sum_{k in P_t} (n_k / m_t) dequant(uplink_k)` — the
//! uplinks are already on each client's FP8 grid (Q_rand applied by the
//! client codec), so averaging the dequantized values in FP32 is
//! exactly Algorithm 1's aggregation step. Alphas and betas are
//! averaged unquantized (they travel as f32 side channels).
//!
//! [`FedAvgStream`] is the streaming form used by the parallel round
//! loop: uplinks are folded into the weighted sums one at a time as
//! the cohort delivers them (decode + accumulate + drop), so the
//! server never buffers the whole cohort's decoded tensors. Per-client
//! vectors are retained only when ServerOptimize needs them.
//! Determinism note: FP32 accumulation is order-sensitive, so callers
//! must push uplinks in cohort order — `transport::run_cohort`
//! guarantees that ordering regardless of thread count.

use anyhow::{ensure, Result};

use crate::fp8::codec::{self, DecodeLutCache, Segment};

use super::comm::Uplink;

/// Result of one aggregation: FP32 master model + averaged clips.
pub struct Aggregate {
    pub w: Vec<f32>,
    pub alpha: Vec<f32>,
    pub beta: Vec<f32>,
    /// Per-client dequantized weight vectors (kept for ServerOptimize;
    /// empty when the stream was built with `keep_clients = false`).
    pub client_ws: Vec<Vec<f32>>,
    /// Per-client alpha side channels (Eq. (5) search range).
    pub client_alphas: Vec<Vec<f32>>,
    /// Per-client FedAvg weights n_k/m_t.
    pub kweights: Vec<f32>,
    pub mean_loss: f32,
}

/// Streaming weighted accumulator for one round's uplinks.
///
/// `m_t` (the cohort's total sample count) is known before any client
/// finishes — the server samples the cohort and knows every `n_k` — so
/// each uplink can be folded in with its final weight `n_k / m_t` the
/// moment it arrives.
pub struct FedAvgStream<'s> {
    segments: &'s [Segment],
    m_t: u64,
    w: Vec<f32>,
    alpha: Vec<f32>,
    beta: Vec<f32>,
    mean_loss: f32,
    n_seen: usize,
    keep_clients: bool,
    client_ws: Vec<Vec<f32>>,
    client_alphas: Vec<Vec<f32>>,
    kweights: Vec<f32>,
    /// Reused decode buffer — one allocation per round, not per uplink.
    buf: Vec<f32>,
    /// Decode-table cache shared by every uplink this stream folds in
    /// (clients whose alphas agree — common early in training and
    /// whenever ServerOptimize pins them — decode off the same LUT).
    lut: DecodeLutCache,
}

impl<'s> FedAvgStream<'s> {
    pub fn new(
        segments: &'s [Segment],
        dim: usize,
        alpha_dim: usize,
        beta_dim: usize,
        m_t: u64,
        keep_clients: bool,
    ) -> Result<FedAvgStream<'s>> {
        ensure!(m_t > 0, "zero total samples");
        Ok(FedAvgStream {
            segments,
            m_t,
            w: vec![0.0f32; dim],
            alpha: vec![0.0f32; alpha_dim],
            beta: vec![0.0f32; beta_dim],
            mean_loss: 0.0,
            n_seen: 0,
            keep_clients,
            client_ws: Vec::new(),
            client_alphas: Vec::new(),
            kweights: Vec::new(),
            buf: vec![0.0f32; dim],
            lut: DecodeLutCache::default(),
        })
    }

    /// Fold one uplink into the running weighted sums.
    pub fn push(&mut self, up: &Uplink) {
        let kw = up.n_k as f32 / self.m_t as f32;
        codec::decode_pooled(
            &up.payload,
            self.segments,
            &mut self.lut,
            1,
            &mut self.buf,
        );
        for (acc, &v) in self.w.iter_mut().zip(&self.buf) {
            *acc += kw * v;
        }
        for (acc, &v) in self.alpha.iter_mut().zip(&up.payload.alphas) {
            *acc += kw * v;
        }
        for (acc, &v) in self.beta.iter_mut().zip(&up.payload.betas) {
            *acc += kw * v;
        }
        self.mean_loss += kw * up.mean_loss;
        self.n_seen += 1;
        if self.keep_clients {
            self.client_ws.push(self.buf.clone());
            self.client_alphas.push(up.payload.alphas.clone());
        }
        self.kweights.push(kw);
    }

    pub fn finish(self) -> Result<Aggregate> {
        ensure!(self.n_seen > 0, "no uplinks to aggregate");
        Ok(Aggregate {
            w: self.w,
            alpha: self.alpha,
            beta: self.beta,
            client_ws: self.client_ws,
            client_alphas: self.client_alphas,
            kweights: self.kweights,
            mean_loss: self.mean_loss,
        })
    }
}

/// Batch federated averaging over a buffered cohort — a thin wrapper
/// around [`FedAvgStream`] (always retains per-client vectors).
pub fn fedavg(
    uplinks: &[Uplink],
    segments: &[Segment],
    dim: usize,
    alpha_dim: usize,
    beta_dim: usize,
) -> Result<Aggregate> {
    ensure!(!uplinks.is_empty(), "no uplinks to aggregate");
    let m_t: u64 = uplinks.iter().map(|u| u.n_k).sum();
    let mut stream =
        FedAvgStream::new(segments, dim, alpha_dim, beta_dim, m_t, true)?;
    for up in uplinks {
        stream.push(up);
    }
    stream.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::codec::{encode, Rounding};
    use crate::fp8::rng::Pcg32;

    fn segs() -> Vec<Segment> {
        vec![Segment {
            name: "w".into(),
            offset: 0,
            size: 8,
            quantized: true,
            alpha_idx: Some(0),
        }]
    }

    fn uplink(vals: &[f32], alpha: f32, n_k: u64) -> Uplink {
        let mut rng = Pcg32::new(1, 0);
        Uplink {
            payload: encode(vals, &[alpha], &[2.0], &segs(),
                            Rounding::Deterministic, &mut rng),
            client: 0,
            n_k,
            mean_loss: 1.0,
        }
    }

    #[test]
    fn equal_weights_average() {
        // values already exactly on the grid for alpha=1 -> lossless
        let a = uplink(&[0.5; 8], 1.0, 10);
        let b = uplink(&[1.0; 8], 1.0, 10);
        let agg = fedavg(&[a, b], &segs(), 8, 1, 1).unwrap();
        assert!(agg.w.iter().all(|&v| (v - 0.75).abs() < 1e-6));
        assert_eq!(agg.kweights, vec![0.5, 0.5]);
    }

    #[test]
    fn nk_weighting() {
        let a = uplink(&[0.0; 8], 1.0, 30);
        let b = uplink(&[1.0; 8], 1.0, 10);
        let agg = fedavg(&[a, b], &segs(), 8, 1, 1).unwrap();
        assert!(agg.w.iter().all(|&v| (v - 0.25).abs() < 1e-6));
        // alpha averaged with same weights
        assert!((agg.alpha[0] - 1.0).abs() < 1e-6);
        assert!((agg.beta[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn rejects_empty() {
        assert!(fedavg(&[], &segs(), 8, 1, 1).is_err());
    }

    #[test]
    fn stream_matches_batch_bitwise() {
        let ups = [
            uplink(&[0.5; 8], 1.0, 30),
            uplink(&[1.0; 8], 0.7, 10),
            uplink(&[0.25; 8], 1.3, 5),
        ];
        let m_t = ups.iter().map(|u| u.n_k).sum();
        let segs = segs();
        let batch = fedavg(&ups, &segs, 8, 1, 1).unwrap();
        let mut s =
            FedAvgStream::new(&segs, 8, 1, 1, m_t, false).unwrap();
        for up in &ups {
            s.push(up);
        }
        let streamed = s.finish().unwrap();
        assert_eq!(streamed.w, batch.w);
        assert_eq!(streamed.alpha, batch.alpha);
        assert_eq!(streamed.beta, batch.beta);
        assert_eq!(streamed.kweights, batch.kweights);
        assert_eq!(streamed.mean_loss, batch.mean_loss);
        // memory contract: nothing retained unless asked
        assert!(streamed.client_ws.is_empty());
        assert!(!batch.client_ws.is_empty());
    }

    #[test]
    fn stream_rejects_empty_cohort() {
        let segs = segs();
        assert!(FedAvgStream::new(&segs, 8, 1, 1, 0, false).is_err());
        let s = FedAvgStream::new(&segs, 8, 1, 1, 10, false).unwrap();
        assert!(s.finish().is_err());
    }

    #[test]
    fn keeps_client_vectors_for_server_opt() {
        let a = uplink(&[0.5; 8], 1.0, 1);
        let agg = fedavg(&[a], &segs(), 8, 1, 1).unwrap();
        assert_eq!(agg.client_ws.len(), 1);
        assert_eq!(agg.client_ws[0], agg.w);
    }
}
