//! The federated server — Algorithm 1 (FP8FedAvg-UQ / -UQ+) round loop.
//!
//! Per round t:
//!   1. sample P_t ⊂ [K] clients
//!   2. downlink: Q_rand(w_t) packed by the wire codec, broadcast
//!      (every client hard-resets its master weights to the decoded
//!      grid values — the "hard reset" of §2)
//!   3. each client: U local steps of FP8-QAT via the AOT artifact —
//!      dispatched through the [`Transport`] seam and executed by up
//!      to `cfg.parallelism` workers concurrently (the cohort is
//!      embarrassingly parallel)
//!   4. uplink: Q_rand(w_{t+1}^k) + alpha/beta side channels
//!   5. FedAvg aggregation in FP32 (unbiased: Lemma 3/6), streamed —
//!      each uplink is decoded and folded into the weighted sums as it
//!      is delivered, in cohort order so results are bit-identical for
//!      every thread count
//!   6. optional ServerOptimize (Eq. 4 + Eq. 5)
//!   7. periodic centralized evaluation of the quantized server model
//!
//! The server master model stays FP32 throughout; FP8 exists only on
//! the wire and inside the QAT graphs — exactly the paper's split.
//!
//! Determinism contract: every stochastic decision inside a round is
//! drawn from a counter-derived stream `Pcg32::derive(seed, round,
//! client, domain)` — never from shared mutable generator state — so
//! the trajectory is a pure function of the config, independent of
//! `parallelism` and of worker completion order.

use std::borrow::Cow;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use crate::config::{AggMode, ExperimentConfig, QatMode, SplitCfg};
use crate::data::{partition, speech, vision, Dataset};
use crate::fp8::codec::{self, DecodeLutCache, WirePayload};
use crate::fp8::rng::Pcg32;
use crate::runtime::{Engine, Manifest, ModelInfo};

use super::aggregate::{self, Weighting};
use super::client::ClientRunner;
use super::cohort::{ClientShards, VIRTUALIZE_AT};
use super::comm::CommStats;
use super::metrics::{
    RoundEvent, RoundRecord, RunEvent, RunPhase, RunResult, Telemetry,
};
use super::server_opt;
use super::snapshot::{self, SnapshotState};
use super::transport::{
    self, streams, ClientJob, InProcessTransport, Transport,
};
use super::tree;

/// The experiment substrate shared by every participant role: the
/// synthetic datasets and the per-client shards. A **pure function of
/// (config, model)** — every random draw comes from streams derived
/// from `cfg.seed` — so the coordinator and networked worker
/// processes each rebuild an identical world from their own copy of
/// the config instead of shipping datasets over the wire
/// (`ExperimentConfig::fingerprint` + the net handshake guard the
/// "same config" precondition).
pub struct World {
    pub train: Dataset,
    pub test: Dataset,
    pub shards: ClientShards,
}

/// Deterministically generate the data + partition for `cfg`.
pub fn build_world(
    cfg: &ExperimentConfig,
    model: &ModelInfo,
) -> Result<World> {
    // experiment-setup stream (partitioning); deliberately NOT
    // 0xDA7A, which is transport::streams::DATA — distinct
    // randomness domains must never share a tag
    let mut rng_data = Pcg32::new(cfg.seed, 0x9A27_1710);
    let (train, test) = match model.kind.as_str() {
        "vision" => {
            let vcfg = vision::VisionCfg::new(model.classes);
            vision::generate(&vcfg, cfg.n_train, cfg.n_test, cfg.seed)
        }
        "speech" => {
            let scfg = speech::SpeechCfg::new(model.classes, cfg.speakers);
            speech::generate(&scfg, cfg.n_train, cfg.n_test, cfg.seed)
        }
        k => bail!("unknown data kind '{k}'"),
    };
    ensure!(
        train.feat_shape == model.input_shape,
        "data/model shape mismatch: {:?} vs {:?}",
        train.feat_shape,
        model.input_shape
    );
    let shards = match cfg.split {
        // the i.i.d. split virtualizes above the population
        // threshold: same shuffle, same shards, O(n_train) memory
        // instead of O(clients) resident structs
        SplitCfg::Iid if cfg.clients >= VIRTUALIZE_AT => {
            ClientShards::virtual_iid(
                train.len(),
                cfg.clients,
                &mut rng_data,
            )
        }
        SplitCfg::Iid => ClientShards::dense(partition::iid(
            train.len(),
            cfg.clients,
            &mut rng_data,
        )),
        SplitCfg::Dirichlet(c) => ClientShards::dense(
            partition::dirichlet(&train, cfg.clients, c, &mut rng_data),
        ),
        SplitCfg::Speaker => {
            let s = partition::by_group(&train);
            ensure!(
                s.len() >= cfg.participation,
                "only {} speakers for P={}",
                s.len(),
                cfg.participation
            );
            ClientShards::dense(s)
        }
    };
    Ok(World {
        train,
        test,
        shards,
    })
}

pub struct Server<'a> {
    pub cfg: ExperimentConfig,
    engine: &'a Engine,
    model: &'a ModelInfo,
    /// Where clients execute: in-process PJRT by default; injectable
    /// for tests and future networked backends.
    transport: Box<dyn Transport + 'a>,
    train: Dataset,
    test: Dataset,
    shards: ClientShards,
    // FP32 master state
    w: Vec<f32>,
    alpha: Vec<f32>,
    beta: Vec<f32>,
    comm: CommStats,
    /// Reused downlink payload buffer (`encode_into_pooled` target):
    /// one allocation for the life of the run, not one per round.
    down_buf: WirePayload,
    /// Reused RNG scratch for the codec's batched rounding draws.
    enc_scratch: Vec<f64>,
    /// Decode-table cache for the broadcast hard-reset decode (alphas
    /// drift slowly round-over-round, so tables mostly hit).
    down_lut: DecodeLutCache,
    verbose: bool,
    /// Error-feedback memories (extension, cfg.error_feedback):
    /// server-side downlink residual + lazily allocated per-client
    /// uplink residuals. EF keeps the quantization error at the
    /// compressing node and adds it back before the next compression,
    /// which restores convergence under *biased* compressors
    /// (Richtárik et al., the fix the paper's Remark 3 points to).
    /// The per-client map is sparse — only clients that have actually
    /// participated hold a residual — so a huge virtualized
    /// population costs O(clients touched), not O(K).
    ef_server: Vec<f32>,
    ef_clients: BTreeMap<usize, Vec<f32>>,
    /// Durability knobs (`--snapshot-dir` / `--snapshot-every`):
    /// when set, [`Server::run`] writes an atomic state snapshot
    /// every `snap_every` completed rounds (and after the final one).
    snap_dir: Option<PathBuf>,
    snap_every: usize,
    /// First round `run` will execute — 0 unless a snapshot was
    /// restored ([`Server::resume_from`]).
    start_round: usize,
    /// Cumulative wall-clock millis of all completed rounds,
    /// including prior resumed segments (restored from snapshot v2,
    /// advanced by [`Server::run`]) — so resumed runs report
    /// continuous time next to their cumulative byte totals.
    wall_millis: u64,
    /// Structured event sink ([`Telemetry`]); `None` (the default)
    /// costs the round loop nothing.
    telemetry: Option<std::sync::Arc<dyn Telemetry>>,
}

/// Write back a client's error-feedback residual, evicting
/// exactly-zero vectors: the round loop treats a missing entry as
/// zeros, so an all-zero residual is pure memory cost (common under
/// `--comm none`, where encode/decode is the identity and every
/// residual collapses to zero). Eviction keeps the `BTreeMap`
/// bounded by the set of clients with *live* residuals instead of
/// every client ever touched — ROADMAP's long-run growth fix — and
/// keeps snapshots canonical (no redundant zero vectors on disk).
fn store_ef(
    map: &mut BTreeMap<usize, Vec<f32>>,
    client: usize,
    e: Vec<f32>,
) {
    if e.iter().all(|&v| v == 0.0) {
        map.remove(&client);
    } else {
        map.insert(client, e);
    }
}

/// Snapshot of the server's per-client state residency — the
/// struct-count probe behind the virtualized O(cohort) memory
/// contract (asserted by tests/cohort_virtual.rs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ClientStateProbe {
    /// Per-client shard index vectors held resident (0 when the
    /// population is virtualized).
    pub resident_shard_structs: usize,
    /// Error-feedback residuals allocated so far — grows with the
    /// set of clients that have participated, never with K.
    pub ef_residuals: usize,
    /// True when shards materialize on demand from the sample order.
    pub virtualized: bool,
}

impl<'a> Server<'a> {
    pub fn new(
        engine: &'a Engine,
        manifest: &'a Manifest,
        cfg: ExperimentConfig,
    ) -> Result<Server<'a>> {
        let model = manifest.model(&cfg.model)?;
        let transport = Box::new(InProcessTransport { engine, model });
        Self::with_transport(engine, manifest, cfg, transport)
    }

    /// Build a server with an explicit client-execution transport —
    /// the injection point for mock transports (engine-free tests) and
    /// future networked backends.
    pub fn with_transport(
        engine: &'a Engine,
        manifest: &'a Manifest,
        cfg: ExperimentConfig,
        transport: Box<dyn Transport + 'a>,
    ) -> Result<Server<'a>> {
        let model = manifest.model(&cfg.model)?;
        cfg.validate()?;
        if cfg.server_opt.is_some() {
            ensure!(
                cfg.participation <= model.server_p,
                "ServerOptimize artifact baked for P={}, cfg has {}",
                model.server_p,
                cfg.participation
            );
        }
        // ---- data + split (shared with networked workers) -----------
        let World {
            train,
            test,
            shards,
        } = build_world(&cfg, model)?;
        // ---- init ---------------------------------------------------
        let w = manifest.load_init(model, "w")?;
        let alpha = manifest.load_init(model, "alpha")?;
        let beta = manifest.load_init(model, "beta")?;
        let ef_server = vec![0.0f32; if cfg.error_feedback { model.dim }
                             else { 0 }];
        Ok(Server {
            engine,
            model,
            transport,
            train,
            test,
            shards,
            w,
            alpha,
            beta,
            comm: CommStats::default(),
            down_buf: WirePayload::default(),
            enc_scratch: Vec::new(),
            down_lut: DecodeLutCache::default(),
            cfg,
            verbose: false,
            ef_server,
            ef_clients: BTreeMap::new(),
            snap_dir: None,
            snap_every: 1,
            start_round: 0,
            wall_millis: 0,
            telemetry: None,
        })
    }

    pub fn set_verbose(&mut self, v: bool) {
        self.verbose = v;
    }

    /// Effective client count (speaker split may differ from cfg).
    pub fn n_clients(&self) -> usize {
        self.shards.n_clients()
    }

    pub fn comm_stats(&self) -> CommStats {
        self.comm
    }

    /// How much per-client state the server holds right now.
    pub fn client_state_probe(&self) -> ClientStateProbe {
        ClientStateProbe {
            resident_shard_structs: self.shards.resident_structs(),
            ef_residuals: self.ef_clients.len(),
            virtualized: self.shards.is_virtual(),
        }
    }

    pub fn state(&self) -> (&[f32], &[f32], &[f32]) {
        (&self.w, &self.alpha, &self.beta)
    }

    /// Enable periodic durable snapshots: one atomic write into
    /// `dir` every `every` completed rounds (plus one after the
    /// final round, so a finished run always leaves its end state).
    pub fn set_snapshot(&mut self, dir: PathBuf, every: usize) {
        self.snap_dir = Some(dir);
        self.snap_every = every.max(1);
    }

    /// Install a structured-event sink ([`Telemetry`]); the daemon's
    /// NDJSON feed rides this. Purely observational — events are
    /// derived from the trajectory and can never move it.
    pub fn set_telemetry(
        &mut self,
        sink: std::sync::Arc<dyn Telemetry>,
    ) {
        self.telemetry = Some(sink);
    }

    /// Cumulative wall-clock millis of all completed rounds,
    /// including resumed prior segments (the snapshot-v2 counter).
    pub fn wall_millis(&self) -> u64 {
        self.wall_millis
    }

    /// The durable round state as of "rounds `0..next_round` are
    /// complete" — everything [`SnapshotState`] documents as
    /// non-derivable.
    pub fn snapshot_state(&self, next_round: usize) -> SnapshotState {
        SnapshotState {
            fingerprint: self.cfg.fingerprint(),
            next_round: next_round as u64,
            w: self.w.clone(),
            alpha: self.alpha.clone(),
            beta: self.beta.clone(),
            ef_server: self.ef_server.clone(),
            ef_clients: self
                .ef_clients
                .iter()
                .map(|(&k, v)| (k as u64, v.clone()))
                .collect(),
            comm: self.comm,
            wall_millis: self.wall_millis,
        }
    }

    /// Atomically persist the current state into `dir` (see
    /// [`snapshot::write_atomic`] for the torn-write discipline).
    pub fn save_snapshot(
        &self,
        dir: &Path,
        next_round: usize,
    ) -> Result<PathBuf, snapshot::SnapshotError> {
        snapshot::write_atomic(dir, &self.snapshot_state(next_round))
    }

    /// Install a decoded snapshot as the live state. The caller (or
    /// [`Server::resume_from`]) has already gated the config
    /// fingerprint; this validates the shape against the model.
    pub fn restore_snapshot(&mut self, s: &SnapshotState) -> Result<()> {
        let m = self.model;
        ensure!(
            s.w.len() == m.dim,
            "snapshot w has {} params, model '{}' has {}",
            s.w.len(),
            self.cfg.model,
            m.dim
        );
        ensure!(
            s.alpha.len() == self.alpha.len()
                && s.beta.len() == self.beta.len(),
            "snapshot alpha/beta dims {}x{} do not match model \
             {}x{}",
            s.alpha.len(),
            s.beta.len(),
            self.alpha.len(),
            self.beta.len()
        );
        ensure!(
            s.ef_server.len() == self.ef_server.len(),
            "snapshot ef_server has {} entries, this config expects \
             {} (error_feedback mismatch should have been caught by \
             the fingerprint gate)",
            s.ef_server.len(),
            self.ef_server.len()
        );
        self.w = s.w.clone();
        self.alpha = s.alpha.clone();
        self.beta = s.beta.clone();
        self.ef_server = s.ef_server.clone();
        self.ef_clients = s
            .ef_clients
            .iter()
            .map(|(&k, v)| (k as usize, v.clone()))
            .collect();
        self.comm = s.comm;
        self.wall_millis = s.wall_millis;
        self.start_round = s.next_round as usize;
        Ok(())
    }

    /// `--resume`: load the newest valid snapshot generation from
    /// `dir` (falling back across torn/corrupt files, hard-rejecting
    /// a foreign config fingerprint) and continue from it. Returns
    /// the first round the loop will run — 0 on a cold start (no
    /// snapshot files yet), which makes `--resume` safe to pass on
    /// the very first launch of a kill/resume cycle.
    pub fn resume_from(&mut self, dir: &Path) -> Result<usize> {
        match snapshot::load_resume(dir, self.cfg.fingerprint())? {
            Some((s, path)) => {
                self.restore_snapshot(&s)?;
                if self.verbose {
                    eprintln!(
                        "[{}] resumed at round {} from {}",
                        self.cfg.name,
                        self.start_round,
                        path.display()
                    );
                }
                Ok(self.start_round)
            }
            None => Ok(0),
        }
    }

    /// Emit a run-boundary event to the installed sink, if any.
    fn emit_run(
        &self,
        phase: RunPhase,
        final_accuracy: f64,
        wall_secs: f64,
        error: Option<String>,
    ) {
        if let Some(sink) = &self.telemetry {
            sink.on_run(&RunEvent {
                job: self.cfg.name.clone(),
                phase,
                start_round: self.start_round as u64,
                rounds_total: self.cfg.rounds as u64,
                final_accuracy,
                total_bytes: self.comm.total_bytes(),
                wall_secs,
                error,
            });
        }
    }

    /// Run the full experiment; returns the per-round record series
    /// (starting at the resumed round, if any).
    ///
    /// `wall_secs` (and the snapshot's `wall_millis`) are cumulative
    /// across resumes: the clock restarts per process, but the
    /// restored base from snapshot v2 is added back, so
    /// bytes-vs-time comparisons stay continuous exactly like the
    /// cumulative `cum_bytes` column (the pre-v2 counter restarted
    /// at every resume boundary).
    pub fn run(&mut self) -> Result<RunResult> {
        let t0 = Instant::now();
        let wall_base = self.wall_millis;
        self.emit_run(
            RunPhase::Started,
            f64::NAN,
            wall_base as f64 / 1e3,
            None,
        );
        let res = self.run_rounds(t0, wall_base);
        let wall_secs =
            wall_base as f64 / 1e3 + t0.elapsed().as_secs_f64();
        match &res {
            Ok(r) => self.emit_run(
                RunPhase::Finished,
                r.final_accuracy,
                wall_secs,
                None,
            ),
            Err(e) => self.emit_run(
                RunPhase::Failed,
                f64::NAN,
                wall_secs,
                Some(format!("{e:#}")),
            ),
        }
        res
    }

    fn run_rounds(
        &mut self,
        t0: Instant,
        wall_base: u64,
    ) -> Result<RunResult> {
        let mut records = Vec::with_capacity(
            self.cfg.rounds.saturating_sub(self.start_round),
        );
        let mut last_acc = f64::NAN;
        for t in self.start_round..self.cfg.rounds {
            let rt = Instant::now();
            let train_loss = self.round(t)?;
            let evaluate = (t + 1) % self.cfg.eval_every == 0
                || t + 1 == self.cfg.rounds;
            let (acc, tl) = if evaluate {
                let (a, l) = self.evaluate()?;
                last_acc = a;
                (a, l)
            } else {
                (f64::NAN, f64::NAN)
            };
            let rec = RoundRecord {
                round: t,
                accuracy: acc,
                test_loss: tl,
                train_loss: train_loss as f64,
                cum_bytes: self.comm.total_bytes(),
                round_ms: rt.elapsed().as_secs_f64() * 1e3,
            };
            if self.verbose && evaluate {
                eprintln!(
                    "[{}] round {t:>4}  acc {:.4}  train-loss {:.4}  \
                     comm {:.2} MiB",
                    self.cfg.name,
                    acc,
                    train_loss,
                    rec.cum_bytes as f64 / (1 << 20) as f64
                );
            }
            records.push(rec);
            // advance the cumulative wall clock BEFORE the snapshot
            // below persists it: state will say "rounds 0..=t are
            // complete and cost this much wall time so far"
            self.wall_millis =
                wall_base + t0.elapsed().as_millis() as u64;
            if let Some(sink) = &self.telemetry {
                sink.on_round(&RoundEvent {
                    job: self.cfg.name.clone(),
                    round: t as u64,
                    rounds_total: self.cfg.rounds as u64,
                    accuracy: rec.accuracy,
                    test_loss: rec.test_loss,
                    train_loss: rec.train_loss,
                    cum_bytes: rec.cum_bytes,
                    round_ms: rec.round_ms,
                    wall_millis: self.wall_millis,
                });
            }
            // snapshot at the round boundary: state now says "rounds
            // 0..=t are complete", so a resume re-enters at t + 1
            if let Some(dir) = self.snap_dir.clone() {
                if (t + 1) % self.snap_every == 0
                    || t + 1 == self.cfg.rounds
                {
                    self.save_snapshot(&dir, t + 1)?;
                }
            }
        }
        Ok(RunResult {
            name: self.cfg.name.clone(),
            final_accuracy: last_acc,
            total_bytes: self.comm.total_bytes(),
            wall_secs: wall_base as f64 / 1e3
                + t0.elapsed().as_secs_f64(),
            records,
        })
    }

    /// One communication round; returns the mean client training loss.
    pub fn round(&mut self, t: usize) -> Result<f32> {
        let m = self.model;
        let cfg = &self.cfg;
        // 1. sample the round's cohort from a counter-derived stream:
        // a pure function of (seed, round), so any round's cohort can
        // be reproduced without replaying the rounds before it. The
        // sparse Fisher-Yates sampler draws the same ids as the dense
        // one at O(P) memory — a million-client population costs
        // nothing here.
        let participants =
            Pcg32::derive(cfg.seed, t as u64, 0, streams::COHORT)
                .sample_distinct_sparse(
                    self.shards.n_clients(),
                    cfg.participation,
                );
        // 2. downlink: quantize once, broadcast to P clients (with the
        // optional error-feedback residual folded in pre-compression)
        let mut rng_down =
            Pcg32::derive(cfg.seed, t as u64, 0, streams::DOWNLINK);
        let down_src: Vec<f32> = if cfg.error_feedback {
            self.w
                .iter()
                .zip(&self.ef_server)
                .map(|(w, e)| w + e)
                .collect()
        } else {
            self.w.clone()
        };
        codec::encode_into_pooled(
            &down_src,
            &self.alpha,
            &self.beta,
            &m.segments,
            cfg.comm,
            cfg.fp8_kernel,
            &mut rng_down,
            &mut self.enc_scratch,
            cfg.parallelism,
            &mut self.down_buf,
        );
        for _ in &participants {
            self.comm.record_down(&self.down_buf);
        }
        // hard reset: every participant starts from the decoded grid
        let mut w_start = vec![0.0f32; m.dim];
        codec::decode_pooled(
            &self.down_buf,
            &m.segments,
            &mut self.down_lut,
            cfg.parallelism,
            &mut w_start,
        );
        if cfg.error_feedback {
            for ((e, src), dec) in self
                .ef_server
                .iter_mut()
                .zip(&down_src)
                .zip(&w_start)
            {
                *e = src - dec;
            }
        }
        // the broadcast side channels double as every job's
        // alpha/beta_start — borrowed, not cloned (the worker side
        // reads the same vectors out of the wire payload)
        let down_buf = &self.down_buf;

        // 3-4. local updates + uplinks, fanned out over the transport.
        // m_t is known before dispatch (n_k is O(1) even when the
        // population is virtualized), so aggregation can stream with
        // final weights. Only the cohort's shards are materialized —
        // O(P) per-client structs regardless of K.
        let lr = cfg.schedule.lr_at(cfg.lr, t, cfg.rounds);
        let m_t: u64 = participants
            .iter()
            .map(|&k| self.shards.n_k(k))
            .sum();
        // degenerate cohorts (every sampled client empty — routine
        // when K far exceeds n_train) fall back to uniform weights
        let weighting = Weighting::for_cohort(m_t, participants.len());
        let cohort_shards: Vec<Cow<'_, [usize]>> = participants
            .iter()
            .map(|&k| self.shards.shard(k))
            .collect();
        let n_clients = self.shards.n_clients();
        let mut jobs = Vec::with_capacity(participants.len());
        for (pos, &k) in participants.iter().enumerate() {
            // heterogeneous fleets: a fixed prefix of the client id
            // space trains in FP32 (no on-device FP8 support)
            let qat = if (k as f32)
                < cfg.fp32_client_frac * n_clients as f32
            {
                QatMode::None
            } else {
                cfg.qat
            };
            // clone (not take) the residual: if the round fails
            // mid-cohort, every undelivered client keeps its prior
            // residual (under parallelism that can include cohort
            // positions before the failing one — only the delivered
            // in-order prefix is recorded, so callers should abandon
            // a failed round rather than continue)
            let ef = if cfg.error_feedback {
                Some(self.ef_clients.get(&k).cloned()
                    .unwrap_or_else(|| vec![0.0f32; m.dim]))
            } else {
                None
            };
            jobs.push(ClientJob {
                round: t,
                client: k,
                // the dispatch tag is the cohort position — stable
                // across re-dispatch, unique within the round
                job_id: pos as u32,
                seed: cfg.seed,
                qat,
                lr,
                weight_decay: cfg.weight_decay,
                flip_aug: cfg.flip_aug,
                comm: cfg.comm,
                w_start: &w_start,
                alpha_start: &down_buf.alphas,
                beta_start: &down_buf.betas,
                train: &self.train,
                shard: cohort_shards[pos].as_ref(),
                segments: &m.segments,
                n_k: cohort_shards[pos].len() as u64,
                ef,
                down: down_buf,
            });
        }

        // 5. streaming aggregation — uplinks are folded in as the
        // cohort delivers them (cohort order, so the f64 sums are
        // independent of thread count); per-client tensors are kept
        // only when ServerOptimize will need them. Under `--agg
        // tree:G` the same uplinks flow through G mid-tier streams
        // whose partials the root absorbs — bit-identical to flat by
        // the pairwise accumulator's canonical-form invariant.
        let mut agg = match cfg.agg {
            AggMode::Flat => {
                let mut stream = aggregate::FedAvgStream::with_weighting(
                    &m.segments,
                    m.dim,
                    m.alpha_dim,
                    m.n_act,
                    weighting,
                    cfg.server_opt.is_some(),
                    0,
                )?;
                let comm = &mut self.comm;
                let ef_clients = &mut self.ef_clients;
                transport::run_cohort(
                    self.transport.as_ref(),
                    jobs,
                    cfg.parallelism,
                    cfg.fp8_kernel,
                    |pos, out| {
                        comm.record_up(&out.uplink.payload);
                        if let Some(e) = out.ef {
                            store_ef(ef_clients, participants[pos], e);
                        }
                        stream.push(&out.uplink);
                        Ok(())
                    },
                )?;
                stream.finish()?
            }
            AggMode::Tree { nodes } => {
                let ef_clients = &mut self.ef_clients;
                // a transport fronting networked mid-tier aggregators
                // dispatches whole shards; everything else runs the
                // shards in-process. Same shard geometry, same
                // canonical accumulation — bit-identical either way.
                match self.transport.shard_dispatcher() {
                    Some(dispatch) => tree::run_tree_net(
                        dispatch,
                        jobs,
                        nodes,
                        t as u32,
                        &m.segments,
                        m.dim,
                        m.alpha_dim,
                        m.n_act,
                        weighting,
                        &mut self.comm,
                        |client, e| {
                            store_ef(ef_clients, client as usize, e);
                            Ok(())
                        },
                    )?,
                    None => tree::run_tree(
                        self.transport.as_ref(),
                        jobs,
                        cfg.parallelism,
                        cfg.fp8_kernel,
                        nodes,
                        t as u32,
                        &m.segments,
                        m.dim,
                        m.alpha_dim,
                        m.n_act,
                        weighting,
                        &mut self.comm,
                        |pos, out| {
                            if let Some(e) = out.ef.take() {
                                store_ef(ef_clients, participants[pos], e);
                            }
                            Ok(())
                        },
                    )?,
                }
            }
        };

        // 6. ServerOptimize (UQ+)
        if let Some(so) = &cfg.server_opt {
            let mut rng_so =
                Pcg32::derive(cfg.seed, t as u64, 0, streams::SERVER_OPT);
            server_opt::optimize(
                self.engine,
                m,
                so,
                &mut agg,
                &mut rng_so,
                cfg.parallelism,
                cfg.fp8_kernel,
            )?;
        }
        self.w = agg.w;
        self.alpha = agg.alpha;
        self.beta = agg.beta;
        Ok(agg.mean_loss)
    }

    /// Centralized evaluation over the test set (full eval batches).
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        let m = self.model;
        let runner = ClientRunner {
            engine: self.engine,
            model: m,
        };
        let fl = m.feat_len();
        let nb = self.test.len() / m.eval_batch;
        ensure!(nb > 0, "test set smaller than eval batch");
        let mut correct = 0i64;
        let mut nll = 0.0f64;
        let mut n = 0usize;
        for b in 0..nb {
            let lo = b * m.eval_batch;
            let hi = lo + m.eval_batch;
            let x = &self.test.x[lo * fl..hi * fl];
            let y = &self.test.y[lo..hi];
            let (loss_sum, corr) = runner.evaluate(
                self.cfg.qat,
                &self.w,
                &self.alpha,
                &self.beta,
                x,
                y,
            )?;
            correct += corr as i64;
            nll += loss_sum as f64;
            n += m.eval_batch;
        }
        Ok((correct as f64 / n as f64, nll / n as f64))
    }
}
