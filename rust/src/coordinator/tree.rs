//! Two-tier (depth-D composable) aggregation: mid-tier nodes fold a
//! contiguous shard of the cohort through the ordinary
//! [`FedAvgStream`] and forward one weighted [`TreePartial`] upstream
//! through the real wire codec; the root absorbs partials in cohort
//! order. Because the stream's pairwise accumulator is canonical over
//! global cohort positions (see `coordinator::aggregate`), the result
//! is bit-identical to the flat stream for every fan-out and every
//! `--parallelism` — pinned by tests/tree_determinism.rs.
//!
//! A mid-tier node is just a server whose upstream is another
//! server's client: it reuses the [`Transport`] seam to execute its
//! shard and the [`FedAvgStream`] it already runs flat; the only new
//! machinery is the partial frame ([`net::codec::encode_partial`])
//! and [`FedAvgStream::absorb`]. Depth > 2 is the same composition
//! applied recursively (a partial of partials — exercised by the
//! aggregate-layer tests).
//!
//! [`net::codec::encode_partial`]: crate::net::codec::encode_partial

use std::thread;

use anyhow::{ensure, Context, Result};

use crate::fp8::codec::Segment;
use crate::fp8::simd::KernelKind;
use crate::net::codec as wire;
use crate::net::frame::FRAME_HEADER_BYTES;

use super::aggregate::{Aggregate, FedAvgStream, TreePartial, Weighting};
use super::comm::{CommStats, PARTIAL_HEADER_BYTES};
use super::transport::{
    run_cohort, ClientJob, ClientOutcome, ShardDispatch, ShardSpec,
    Transport,
};

/// Contiguous near-equal split of the cohort positions `[0, p)` into
/// `min(nodes, p)` shards (the first `p % nodes` shards get one extra
/// position). Empty when `p == 0`.
pub fn shard_bounds(p: usize, nodes: usize) -> Vec<(usize, usize)> {
    if p == 0 {
        return Vec::new();
    }
    let g = nodes.max(1).min(p);
    let (base, extra) = (p / g, p % g);
    let mut out = Vec::with_capacity(g);
    let mut s = 0usize;
    for i in 0..g {
        let l = base + usize::from(i < extra);
        out.push((s, s + l));
        s += l;
    }
    debug_assert_eq!(s, p);
    out
}

/// Ship one mid-tier partial upstream through the real wire codec:
/// encode, account the frame, decode. The root therefore absorbs
/// exactly the bytes a networked mid-tier would have sent — and the
/// accounting charge equals the true frame size (the
/// reported-vs-actual identity, also asserted end-to-end in
/// tests/net_transport.rs).
pub fn forward_partial(
    round: u32,
    partial: &TreePartial,
    comm: &mut CommStats,
) -> Result<TreePartial> {
    let mut body = Vec::new();
    wire::encode_partial(round, partial, &mut body);
    comm.record_partial(partial);
    debug_assert_eq!(
        FRAME_HEADER_BYTES + body.len() as u64,
        wire::partial_wire_bytes(partial) + PARTIAL_HEADER_BYTES
    );
    let (echo, decoded) = wire::decode_partial(&body)?;
    ensure!(
        echo == round,
        "partial round {echo} does not match round {round}"
    );
    Ok(decoded)
}

/// Run one round's cohort through a depth-2 aggregation tree with
/// `nodes` mid-tier aggregators and return the root aggregate.
///
/// `sink` sees every outcome in global cohort order (exactly like the
/// flat path's sink) and may take client-private state (the EF
/// residual) out of it; uplink traffic is charged to `comm` here —
/// before the sink runs, matching the flat path's record-then-push
/// order — and uplink decoding and weighting stay inside the streams.
/// Per-client retention (ServerOptimize) cannot cross a tree link,
/// which config validation enforces before a round starts.
#[allow(clippy::too_many_arguments)]
pub fn run_tree<F>(
    transport: &dyn Transport,
    jobs: Vec<ClientJob<'_>>,
    parallelism: usize,
    kernel: KernelKind,
    nodes: usize,
    round: u32,
    segments: &[Segment],
    dim: usize,
    alpha_dim: usize,
    beta_dim: usize,
    weighting: Weighting,
    comm: &mut CommStats,
    mut sink: F,
) -> Result<Aggregate>
where
    F: FnMut(usize, &mut ClientOutcome) -> Result<()>,
{
    ensure!(nodes > 0, "tree with zero aggregator nodes");
    // the root never sees uplinks directly, so per-member weights are
    // reconstructed from the dispatch order afterwards
    let n_ks: Vec<u64> = jobs.iter().map(|j| j.n_k).collect();
    let mut root = FedAvgStream::with_weighting(
        segments, dim, alpha_dim, beta_dim, weighting, false, 0,
    )?;
    let mut jobs = jobs.into_iter();
    for (lo, hi) in shard_bounds(n_ks.len(), nodes) {
        let shard: Vec<ClientJob<'_>> =
            jobs.by_ref().take(hi - lo).collect();
        let mut mid = FedAvgStream::with_weighting(
            segments,
            dim,
            alpha_dim,
            beta_dim,
            weighting,
            false,
            lo as u64,
        )?;
        run_cohort(
            transport,
            shard,
            parallelism,
            kernel,
            |rel, mut out| {
                comm.record_up(&out.uplink.payload);
                sink(lo + rel, &mut out)?;
                mid.push(&out.uplink);
                Ok(())
            },
        )?;
        let partial = forward_partial(
            round,
            &mid.into_partial()?,
            comm,
        )?;
        root.absorb(&partial)?;
    }
    let mut agg = root.finish()?;
    agg.kweights =
        n_ks.iter().map(|&n| weighting.kw(n) as f32).collect();
    Ok(agg)
}

/// Run one round through *networked* mid-tier aggregators: fan whole
/// shards out over `dispatch` (one [`ShardSpec`] per shard, executed
/// concurrently), absorb the returned partials in shard order, and
/// rebuild the flat path's accounting from the replies.
///
/// Shard geometry comes from the **configured** fan-out `nodes`, never
/// from the live connection count: a dead aggregator's shard is
/// re-dispatched to a survivor by the transport, so the tree shape —
/// and therefore the canonical accumulation — is identical under any
/// completable fault schedule.
///
/// `ef_sink` receives every returned `(client id, residual)` pair, in
/// ascending client order within each shard and shard order across
/// shards — the same client set the in-process sink would have taken
/// out of the outcomes, so the server's EF store ends bit-identical.
#[allow(clippy::too_many_arguments)]
pub fn run_tree_net<F>(
    dispatch: &dyn ShardDispatch,
    jobs: Vec<ClientJob<'_>>,
    nodes: usize,
    round: u32,
    segments: &[Segment],
    dim: usize,
    alpha_dim: usize,
    beta_dim: usize,
    weighting: Weighting,
    comm: &mut CommStats,
    mut ef_sink: F,
) -> Result<Aggregate>
where
    F: FnMut(u32, Vec<f32>) -> Result<()>,
{
    ensure!(nodes > 0, "tree with zero aggregator nodes");
    let n_ks: Vec<u64> = jobs.iter().map(|j| j.n_k).collect();
    let mut root = FedAvgStream::with_weighting(
        segments, dim, alpha_dim, beta_dim, weighting, false, 0,
    )?;
    let bounds = shard_bounds(jobs.len(), nodes);
    let replies: Vec<_> = thread::scope(|s| {
        let handles: Vec<_> = bounds
            .iter()
            .enumerate()
            .map(|(i, &(lo, hi))| {
                let shard = &jobs[lo..hi];
                s.spawn(move || {
                    let spec = ShardSpec {
                        round,
                        lo: lo as u64,
                        hi: hi as u64,
                        index: i as u32,
                        nodes: nodes as u32,
                        // every job carries the same broadcast
                        down: shard[0].down,
                        efs: shard
                            .iter()
                            .filter_map(|j| {
                                let e = j.ef.as_deref()?;
                                Some((j.client as u32, e))
                            })
                            .collect(),
                    };
                    dispatch.run_shard(&spec)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("shard dispatcher panicked"))
            .collect()
    });
    for ((lo, hi), reply) in bounds.into_iter().zip(replies) {
        let reply = reply
            .with_context(|| format!("shard [{lo}, {hi})"))?;
        ensure!(
            reply.partial.start == lo as u64
                && reply.partial.end == hi as u64,
            "aggregator answered for cohort range [{}, {}), \
             expected [{lo}, {hi})",
            reply.partial.start,
            reply.partial.end,
        );
        // client-edge accounting, exactly as the in-process shard
        // would have charged it outcome by outcome
        comm.up_bytes += reply.up_bytes;
        comm.up_msgs += reply.up_msgs;
        comm.record_partial(&reply.partial);
        for (client, ef) in reply.efs {
            ef_sink(client, ef)?;
        }
        root.absorb(&reply.partial)?;
    }
    let mut agg = root.finish()?;
    agg.kweights =
        n_ks.iter().map(|&n| weighting.kw(n) as f32).collect();
    Ok(agg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_bounds_tile_the_cohort() {
        for (p, nodes) in
            [(7usize, 2usize), (7, 3), (7, 7), (7, 20), (4, 1), (1, 5)]
        {
            let b = shard_bounds(p, nodes);
            assert_eq!(b.len(), nodes.min(p));
            assert_eq!(b[0].0, 0);
            assert_eq!(b[b.len() - 1].1, p);
            for w in b.windows(2) {
                assert_eq!(w[0].1, w[1].0, "gap in {b:?}");
            }
            // near-equal: lengths differ by at most one
            let lens: Vec<usize> =
                b.iter().map(|&(s, e)| e - s).collect();
            let (lo, hi) = (
                lens.iter().min().unwrap(),
                lens.iter().max().unwrap(),
            );
            assert!(hi - lo <= 1, "uneven shards {lens:?}");
        }
        assert!(shard_bounds(0, 3).is_empty());
    }

    #[test]
    fn forward_partial_accounts_and_roundtrips() {
        let p = TreePartial {
            start: 2,
            end: 4,
            width: 2,
            ranges: vec![(2, 2)],
            sums: vec![vec![0.5, -1.5]],
        };
        let mut comm = CommStats::default();
        let q = forward_partial(3, &p, &mut comm).unwrap();
        assert_eq!(q, p);
        assert_eq!(comm.partial_msgs, 1);
        assert_eq!(
            comm.partial_bytes,
            wire::partial_wire_bytes(&p) + PARTIAL_HEADER_BYTES
        );
    }
}
