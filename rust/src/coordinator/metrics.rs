//! Run metrics: per-round records, CSV export, and the paper's
//! communication-gain metric.
//!
//! Table 1 reports "final accuracy / communication gain vs FP32",
//! where the gain is computed *per method* as the ratio of cumulative
//! communicated bytes needed to first reach acc* — acc* being the
//! best accuracy reached by BOTH the FP32 baseline and the method
//! (§4 "Results"). Figure 2 plots accuracy against cumulative bytes;
//! `to_csv` emits exactly that series.

use std::io::Write;
use std::path::Path;

use anyhow::Result;

#[derive(Clone, Copy, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// Centralized test accuracy (NaN when not evaluated this round).
    pub accuracy: f64,
    pub test_loss: f64,
    pub train_loss: f64,
    /// Cumulative bytes (uplink + downlink) after this round.
    pub cum_bytes: u64,
    /// Wall time of the round in milliseconds.
    pub round_ms: f64,
}

#[derive(Clone, Debug)]
pub struct RunResult {
    pub name: String,
    pub records: Vec<RoundRecord>,
    pub final_accuracy: f64,
    pub total_bytes: u64,
    pub wall_secs: f64,
}

impl RunResult {
    pub fn best_accuracy(&self) -> f64 {
        self.records
            .iter()
            .filter(|r| !r.accuracy.is_nan())
            .map(|r| r.accuracy)
            .fold(f64::NAN, f64::max)
    }

    /// Cumulative bytes when accuracy first reached `target`.
    pub fn bytes_to_accuracy(&self, target: f64) -> Option<u64> {
        self.records
            .iter()
            .find(|r| !r.accuracy.is_nan() && r.accuracy >= target)
            .map(|r| r.cum_bytes)
    }

    /// Accuracy-vs-bytes series (Figure 2 axis pair).
    pub fn curve(&self) -> Vec<(u64, f64)> {
        self.records
            .iter()
            .filter(|r| !r.accuracy.is_nan())
            .map(|r| (r.cum_bytes, r.accuracy))
            .collect()
    }

    pub fn to_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(
            f,
            "round,accuracy,test_loss,train_loss,cum_bytes,round_ms"
        )?;
        for r in &self.records {
            writeln!(
                f,
                "{},{},{},{},{},{}",
                r.round,
                r.accuracy,
                r.test_loss,
                r.train_loss,
                r.cum_bytes,
                r.round_ms
            )?;
        }
        Ok(())
    }
}

/// Communication gain of `method` over `fp32` at the shared-best
/// accuracy (paper Table 1 definition). Returns (acc_star, gain).
pub fn comm_gain(fp32: &RunResult, method: &RunResult) -> (f64, f64) {
    let acc_star = fp32.best_accuracy().min(method.best_accuracy());
    if acc_star.is_nan() {
        return (f64::NAN, f64::NAN);
    }
    match (
        fp32.bytes_to_accuracy(acc_star),
        method.bytes_to_accuracy(acc_star),
    ) {
        (Some(b32), Some(bm)) if bm > 0 => {
            (acc_star, b32 as f64 / bm as f64)
        }
        _ => (acc_star, f64::NAN),
    }
}

/// Mean and sample standard deviation over seeds (table cells report
/// "mean ± std / gain" across 3 seeds).
pub fn mean_std(vals: &[f64]) -> (f64, f64) {
    let n = vals.len() as f64;
    if vals.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    let m = vals.iter().sum::<f64>() / n;
    if vals.len() < 2 {
        return (m, 0.0);
    }
    let v = vals.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
        / (n - 1.0);
    (m, v.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(name: &str, accs: &[f64], bytes_per_round: u64) -> RunResult {
        let records: Vec<RoundRecord> = accs
            .iter()
            .enumerate()
            .map(|(i, &a)| RoundRecord {
                round: i,
                accuracy: a,
                test_loss: 0.0,
                train_loss: 0.0,
                cum_bytes: bytes_per_round * (i as u64 + 1),
                round_ms: 1.0,
            })
            .collect();
        RunResult {
            name: name.into(),
            final_accuracy: *accs.last().unwrap(),
            total_bytes: bytes_per_round * accs.len() as u64,
            wall_secs: 0.0,
            records,
        }
    }

    #[test]
    fn gain_is_byte_ratio_at_shared_acc() {
        // fp32 reaches 0.8 at round 3 (4 * 400 bytes); method reaches
        // 0.8 at round 3 too but rounds cost 100 bytes -> gain 4x
        let f = run("fp32", &[0.2, 0.5, 0.7, 0.8, 0.81], 400);
        let m = run("uq", &[0.2, 0.5, 0.7, 0.8, 0.82], 100);
        let (acc, gain) = comm_gain(&f, &m);
        assert!((acc - 0.81).abs() < 1e-9);
        // acc* = min(0.81, 0.82) = 0.81: fp32 hits it at round 4
        // (2000 B), method at round 4 (500 B) -> 4x
        assert!((gain - 4.0).abs() < 1e-9);
    }

    #[test]
    fn gain_counts_fewer_rounds_too() {
        // method converges faster AND cheaper
        let f = run("fp32", &[0.3, 0.5, 0.6, 0.7], 400);
        let m = run("uq", &[0.7, 0.7, 0.7, 0.7], 100);
        let (_, gain) = comm_gain(&f, &m);
        // fp32 needs 4 rounds (1600 B), method 1 round (100 B)
        assert!((gain - 16.0).abs() < 1e-9);
    }

    #[test]
    fn bytes_to_accuracy_none_when_unreached() {
        let f = run("x", &[0.1, 0.2], 10);
        assert!(f.bytes_to_accuracy(0.5).is_none());
    }

    #[test]
    fn mean_std_basics() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - 1.0).abs() < 1e-12);
        let (m1, s1) = mean_std(&[5.0]);
        assert_eq!((m1, s1), (5.0, 0.0));
    }

    #[test]
    fn csv_writes(){
        let r = run("t", &[0.5], 10);
        let p = std::env::temp_dir().join("fedfp8_metrics_test.csv");
        r.to_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.starts_with("round,accuracy"));
        assert!(s.lines().count() == 2);
        let _ = std::fs::remove_file(p);
    }
}
