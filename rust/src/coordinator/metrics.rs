//! Run metrics: per-round records, CSV export, the paper's
//! communication-gain metric, and the structured telemetry events
//! the run-scheduler daemon streams as NDJSON.
//!
//! Table 1 reports "final accuracy / communication gain vs FP32",
//! where the gain is computed *per method* as the ratio of cumulative
//! communicated bytes needed to first reach acc* — acc* being the
//! best accuracy reached by BOTH the FP32 baseline and the method
//! (§4 "Results"). Figure 2 plots accuracy against cumulative bytes;
//! `to_csv` emits exactly that series.
//!
//! The [`Telemetry`] sink trait is the observation seam of
//! `Server::run`: every round emits a [`RoundEvent`] (the structured
//! twin of [`RoundRecord`]) and the run boundaries emit
//! [`RunEvent`]s. The default sink is a no-op, so a plain run pays
//! nothing and nothing here can move a config fingerprint — events
//! are derived *from* the trajectory, never an input to it.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use anyhow::Result;

use crate::util::json::Json;

#[derive(Clone, Copy, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// Centralized test accuracy (NaN when not evaluated this round).
    pub accuracy: f64,
    pub test_loss: f64,
    pub train_loss: f64,
    /// Cumulative bytes (uplink + downlink) after this round.
    pub cum_bytes: u64,
    /// Wall time of the round in milliseconds.
    pub round_ms: f64,
}

#[derive(Clone, Debug)]
pub struct RunResult {
    pub name: String,
    pub records: Vec<RoundRecord>,
    pub final_accuracy: f64,
    pub total_bytes: u64,
    pub wall_secs: f64,
}

impl RunResult {
    pub fn best_accuracy(&self) -> f64 {
        self.records
            .iter()
            .filter(|r| !r.accuracy.is_nan())
            .map(|r| r.accuracy)
            .fold(f64::NAN, f64::max)
    }

    /// Cumulative bytes when accuracy first reached `target`.
    pub fn bytes_to_accuracy(&self, target: f64) -> Option<u64> {
        self.records
            .iter()
            .find(|r| !r.accuracy.is_nan() && r.accuracy >= target)
            .map(|r| r.cum_bytes)
    }

    /// Accuracy-vs-bytes series (Figure 2 axis pair).
    pub fn curve(&self) -> Vec<(u64, f64)> {
        self.records
            .iter()
            .filter(|r| !r.accuracy.is_nan())
            .map(|r| (r.cum_bytes, r.accuracy))
            .collect()
    }

    pub fn to_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(
            f,
            "round,accuracy,test_loss,train_loss,cum_bytes,round_ms"
        )?;
        for r in &self.records {
            writeln!(
                f,
                "{},{},{},{},{},{}",
                r.round,
                r.accuracy,
                r.test_loss,
                r.train_loss,
                r.cum_bytes,
                r.round_ms
            )?;
        }
        Ok(())
    }
}

/// Communication gain of `method` over `fp32` at the shared-best
/// accuracy (paper Table 1 definition). Returns (acc_star, gain).
pub fn comm_gain(fp32: &RunResult, method: &RunResult) -> (f64, f64) {
    let acc_star = fp32.best_accuracy().min(method.best_accuracy());
    if acc_star.is_nan() {
        return (f64::NAN, f64::NAN);
    }
    match (
        fp32.bytes_to_accuracy(acc_star),
        method.bytes_to_accuracy(acc_star),
    ) {
        (Some(b32), Some(bm)) if bm > 0 => {
            (acc_star, b32 as f64 / bm as f64)
        }
        _ => (acc_star, f64::NAN),
    }
}

// ---- structured telemetry (the daemon's NDJSON feed) -----------------

/// JSON number with the NaN/infinity hole closed: JSON has no NaN
/// literal, so an unevaluated accuracy serializes as `null` (the
/// same contract `RoundRecord` expresses with NaN in memory).
fn num_or_null(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
    )
}

/// One round of one run, as a structured event — the telemetry twin
/// of [`RoundRecord`], plus the identity (`job`) and cumulative
/// wall-clock context a feed consumer needs to plot
/// accuracy-vs-bytes-vs-time across resumes.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundEvent {
    /// Run name (`ExperimentConfig::name`; the daemon's job id maps
    /// onto it in the `/status` frame).
    pub job: String,
    pub round: u64,
    pub rounds_total: u64,
    /// NaN when this round did not evaluate (serialized as `null`).
    pub accuracy: f64,
    pub test_loss: f64,
    pub train_loss: f64,
    pub cum_bytes: u64,
    pub round_ms: f64,
    /// Cumulative wall-clock millis including resumed segments — the
    /// snapshot-v2 counter, so the feed's time axis is continuous
    /// across a crash/resume.
    pub wall_millis: u64,
}

impl RoundEvent {
    /// One NDJSON object (no trailing newline; the feed adds it).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("type", Json::Str("round".into())),
            ("job", Json::Str(self.job.clone())),
            ("round", Json::Num(self.round as f64)),
            ("rounds_total", Json::Num(self.rounds_total as f64)),
            ("accuracy", num_or_null(self.accuracy)),
            ("test_loss", num_or_null(self.test_loss)),
            ("train_loss", num_or_null(self.train_loss)),
            ("cum_bytes", Json::Num(self.cum_bytes as f64)),
            ("round_ms", num_or_null(self.round_ms)),
            ("wall_millis", Json::Num(self.wall_millis as f64)),
        ])
    }
}

/// Run-boundary transitions on the feed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunPhase {
    /// The round loop is about to enter its first (possibly resumed)
    /// round.
    Started,
    /// The loop completed every round.
    Finished,
    /// The loop aborted with an error (carried in
    /// [`RunEvent::error`]).
    Failed,
}

impl RunPhase {
    pub fn as_str(&self) -> &'static str {
        match self {
            RunPhase::Started => "started",
            RunPhase::Finished => "finished",
            RunPhase::Failed => "failed",
        }
    }
}

/// A run-boundary event: emitted once when `Server::run` enters the
/// loop and once when it leaves (finished or failed).
#[derive(Clone, Debug, PartialEq)]
pub struct RunEvent {
    pub job: String,
    pub phase: RunPhase,
    /// First round the loop executes — nonzero exactly when resuming.
    pub start_round: u64,
    pub rounds_total: u64,
    /// NaN (→ `null`) until a round has evaluated.
    pub final_accuracy: f64,
    pub total_bytes: u64,
    /// Cumulative across resumes, like the comm totals.
    pub wall_secs: f64,
    /// The abort reason when `phase == Failed`.
    pub error: Option<String>,
}

impl RunEvent {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("type", Json::Str("run".into())),
            ("job", Json::Str(self.job.clone())),
            ("phase", Json::Str(self.phase.as_str().into())),
            ("start_round", Json::Num(self.start_round as f64)),
            ("rounds_total", Json::Num(self.rounds_total as f64)),
            ("final_accuracy", num_or_null(self.final_accuracy)),
            ("total_bytes", Json::Num(self.total_bytes as f64)),
            ("wall_secs", num_or_null(self.wall_secs)),
            (
                "error",
                match &self.error {
                    Some(e) => Json::Str(e.clone()),
                    None => Json::Null,
                },
            ),
        ])
    }
}

/// Observation seam of `Server::run`: implementors receive every
/// round/run event of the trajectory. Contract:
///
/// * **Read-only.** A sink observes; it must never feed anything
///   back into the round loop (events cannot move the trajectory or
///   the config fingerprint).
/// * **Cheap and non-blocking.** Called on the round loop's thread
///   between rounds; do buffered writes or hand off to a channel —
///   never block on a slow consumer.
/// * **Infallible.** Telemetry loss must not fail a run; swallow
///   (and count, if you care) your own I/O errors.
///
/// Both methods default to no-ops so the trait doubles as its own
/// null object ([`NoTelemetry`]).
pub trait Telemetry: Send + Sync {
    fn on_round(&self, _ev: &RoundEvent) {}
    fn on_run(&self, _ev: &RunEvent) {}
}

/// The default sink: drops everything (a plain `fedfp8 run` carries
/// no telemetry cost beyond two `Option` checks per round).
pub struct NoTelemetry;

impl Telemetry for NoTelemetry {}

/// Mean and sample standard deviation over seeds (table cells report
/// "mean ± std / gain" across 3 seeds).
pub fn mean_std(vals: &[f64]) -> (f64, f64) {
    let n = vals.len() as f64;
    if vals.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    let m = vals.iter().sum::<f64>() / n;
    if vals.len() < 2 {
        return (m, 0.0);
    }
    let v = vals.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
        / (n - 1.0);
    (m, v.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(name: &str, accs: &[f64], bytes_per_round: u64) -> RunResult {
        let records: Vec<RoundRecord> = accs
            .iter()
            .enumerate()
            .map(|(i, &a)| RoundRecord {
                round: i,
                accuracy: a,
                test_loss: 0.0,
                train_loss: 0.0,
                cum_bytes: bytes_per_round * (i as u64 + 1),
                round_ms: 1.0,
            })
            .collect();
        RunResult {
            name: name.into(),
            final_accuracy: *accs.last().unwrap(),
            total_bytes: bytes_per_round * accs.len() as u64,
            wall_secs: 0.0,
            records,
        }
    }

    #[test]
    fn gain_is_byte_ratio_at_shared_acc() {
        // fp32 reaches 0.8 at round 3 (4 * 400 bytes); method reaches
        // 0.8 at round 3 too but rounds cost 100 bytes -> gain 4x
        let f = run("fp32", &[0.2, 0.5, 0.7, 0.8, 0.81], 400);
        let m = run("uq", &[0.2, 0.5, 0.7, 0.8, 0.82], 100);
        let (acc, gain) = comm_gain(&f, &m);
        assert!((acc - 0.81).abs() < 1e-9);
        // acc* = min(0.81, 0.82) = 0.81: fp32 hits it at round 4
        // (2000 B), method at round 4 (500 B) -> 4x
        assert!((gain - 4.0).abs() < 1e-9);
    }

    #[test]
    fn gain_counts_fewer_rounds_too() {
        // method converges faster AND cheaper
        let f = run("fp32", &[0.3, 0.5, 0.6, 0.7], 400);
        let m = run("uq", &[0.7, 0.7, 0.7, 0.7], 100);
        let (_, gain) = comm_gain(&f, &m);
        // fp32 needs 4 rounds (1600 B), method 1 round (100 B)
        assert!((gain - 16.0).abs() < 1e-9);
    }

    #[test]
    fn bytes_to_accuracy_none_when_unreached() {
        let f = run("x", &[0.1, 0.2], 10);
        assert!(f.bytes_to_accuracy(0.5).is_none());
    }

    #[test]
    fn mean_std_basics() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - 1.0).abs() < 1e-12);
        let (m1, s1) = mean_std(&[5.0]);
        assert_eq!((m1, s1), (5.0, 0.0));
    }

    #[test]
    fn gain_is_nan_nan_when_a_run_never_evaluated() {
        // one run whose records are ALL unevaluated (accuracy NaN,
        // e.g. eval_every > rounds): best_accuracy is NaN, so acc*
        // is NaN and the contract is a (NaN, NaN) pair — never a
        // panic, a zero, or a one-sided number
        let f = run("fp32", &[0.2, 0.5, 0.7], 400);
        let never = run(
            "uq",
            &[f64::NAN, f64::NAN, f64::NAN],
            100,
        );
        let (acc, gain) = comm_gain(&f, &never);
        assert!(acc.is_nan() && gain.is_nan());
        // symmetric: the baseline never evaluating is the same hole
        let (acc, gain) = comm_gain(&never, &f);
        assert!(acc.is_nan() && gain.is_nan());
        // and both-NaN too
        let (acc, gain) = comm_gain(&never, &never);
        assert!(acc.is_nan() && gain.is_nan());
    }

    #[test]
    fn csv_writes() {
        // unique per-test path: the old fixed name
        // (fedfp8_metrics_test.csv) raced concurrent cargo test
        // invocations sharing one temp dir
        let r = run("t", &[0.5], 10);
        let p = std::env::temp_dir().join(format!(
            "fedfp8_metrics_test_{}_{:p}.csv",
            std::process::id(),
            &r as *const _
        ));
        r.to_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.starts_with("round,accuracy"));
        assert!(s.lines().count() == 2);
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn events_serialize_to_valid_json_with_null_nans() {
        use crate::util::json::Json;

        let ev = RoundEvent {
            job: "lenet_c10_uq_iid".into(),
            round: 3,
            rounds_total: 8,
            accuracy: f64::NAN, // not evaluated this round
            test_loss: f64::NAN,
            train_loss: 0.25,
            cum_bytes: 4096,
            round_ms: 12.5,
            wall_millis: 77,
        };
        let line = ev.to_json().to_string();
        let back = Json::parse(&line).expect("round event is JSON");
        assert_eq!(back.get("type").unwrap().as_str().unwrap(), "round");
        assert_eq!(back.get("round").unwrap().as_usize().unwrap(), 3);
        // NaN serializes as null (JSON has no NaN literal); `opt`
        // filters nulls, so an absent-or-null read is uniform
        assert!(back.opt("accuracy").is_none());
        assert_eq!(
            back.get("cum_bytes").unwrap().as_usize().unwrap(),
            4096
        );
        assert_eq!(
            back.get("wall_millis").unwrap().as_usize().unwrap(),
            77
        );

        let ev = RunEvent {
            job: "j".into(),
            phase: RunPhase::Failed,
            start_round: 2,
            rounds_total: 8,
            final_accuracy: 0.5,
            total_bytes: 10,
            wall_secs: 1.25,
            error: Some("worker died".into()),
        };
        let back = Json::parse(&ev.to_json().to_string()).unwrap();
        assert_eq!(back.get("phase").unwrap().as_str().unwrap(), "failed");
        assert_eq!(
            back.get("error").unwrap().as_str().unwrap(),
            "worker died"
        );
        let ok = RunEvent { error: None, phase: RunPhase::Finished, ..ev };
        let back = Json::parse(&ok.to_json().to_string()).unwrap();
        assert!(back.opt("error").is_none());
    }
}
