//! NDJSON telemetry feed over a local TCP socket.
//!
//! [`TelemetryHub`] is a [`Telemetry`] sink that broadcasts every
//! round/run event as one JSON object per line to all connected
//! clients, and answers the literal request line `/status` with a
//! summary frame (job lifecycle map + latest round metrics). One
//! background thread owns the listener and the read side of every
//! client, multiplexed through the transport's [`Poller`]; event
//! writes happen on the emitting thread (the round loop), so the
//! feed adds no polling latency to event delivery.
//!
//! Contract notes:
//! - The feed is observational: a client connecting mid-run starts
//!   receiving from the next event; `/status` is the catch-up.
//! - A client that stops reading is dropped once its socket buffer
//!   fills (a `WouldBlock`/error on write) — a stalled consumer must
//!   never stall the round loop.
//! - Events serialize through `util::json`, so non-finite metrics
//!   (unevaluated rounds' NaN accuracy) arrive as `null`, matching
//!   the comm_gain NaN contract.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use super::queue::JobState;
use crate::coordinator::metrics::{
    RoundEvent, RunEvent, RunPhase, Telemetry,
};
use crate::net::Poller;
use crate::util::json::Json;

/// Latest known facts about one job, for the `/status` frame.
#[derive(Clone, Debug)]
struct JobEntry {
    state: &'static str,
    round: Option<u64>,
    rounds_total: u64,
    accuracy: f64,
}

struct Inner {
    /// Write side of every connected client, keyed by poll token.
    clients: Mutex<Vec<(u64, TcpStream)>>,
    jobs: Mutex<BTreeMap<String, JobEntry>>,
    closed: AtomicBool,
}

impl Inner {
    /// Send one NDJSON line to every client; drop the ones that fail
    /// (closed, or stalled past their socket buffer).
    fn broadcast(&self, line: &str) {
        let mut clients = self.clients.lock().unwrap();
        clients.retain_mut(|(_, stream)| {
            stream
                .write_all(line.as_bytes())
                .and_then(|_| stream.write_all(b"\n"))
                .is_ok()
        });
    }

    fn update_job(
        &self,
        job: &str,
        state: Option<&'static str>,
        round: Option<u64>,
        rounds_total: u64,
        accuracy: f64,
    ) {
        let mut jobs = self.jobs.lock().unwrap();
        let e = jobs.entry(job.to_string()).or_insert(JobEntry {
            state: "running",
            round: None,
            rounds_total,
            accuracy: f64::NAN,
        });
        if let Some(s) = state {
            e.state = s;
        }
        if round.is_some() {
            e.round = round;
        }
        if rounds_total > 0 {
            e.rounds_total = rounds_total;
        }
        if !accuracy.is_nan() {
            e.accuracy = accuracy;
        }
    }

    /// The `/status` summary frame (one line, like every event).
    fn status_json(&self) -> Json {
        let jobs = self.jobs.lock().unwrap();
        let mut m = BTreeMap::new();
        for (id, e) in jobs.iter() {
            let mut j = BTreeMap::new();
            j.insert(
                "state".to_string(),
                Json::Str(e.state.to_string()),
            );
            j.insert(
                "round".to_string(),
                match e.round {
                    Some(r) => Json::Num(r as f64),
                    None => Json::Null,
                },
            );
            j.insert(
                "rounds_total".to_string(),
                Json::Num(e.rounds_total as f64),
            );
            j.insert(
                "accuracy".to_string(),
                if e.accuracy.is_nan() {
                    Json::Null
                } else {
                    Json::Num(e.accuracy)
                },
            );
            m.insert(id.clone(), Json::Obj(j));
        }
        let mut top = BTreeMap::new();
        top.insert(
            "type".to_string(),
            Json::Str("status".to_string()),
        );
        top.insert("jobs".to_string(), Json::Obj(m));
        Json::Obj(top)
    }
}

/// The telemetry feed server. Construct with [`TelemetryHub::bind`],
/// hand the `Arc` to `Server::set_telemetry` (and the scheduler's
/// `on_state` callback), and [`shutdown`](Self::shutdown) when done.
pub struct TelemetryHub {
    inner: Arc<Inner>,
    addr: SocketAddr,
    thread: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl TelemetryHub {
    /// Bind `addr` (e.g. `127.0.0.1:0`) and start the acceptor
    /// thread.
    pub fn bind(addr: &str) -> Result<Arc<TelemetryHub>> {
        let listener = TcpListener::bind(addr).with_context(|| {
            format!("binding telemetry listener {addr}")
        })?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let inner = Arc::new(Inner {
            clients: Mutex::new(Vec::new()),
            jobs: Mutex::new(BTreeMap::new()),
            closed: AtomicBool::new(false),
        });
        let thread_inner = inner.clone();
        let thread = std::thread::Builder::new()
            .name("telemetry-hub".to_string())
            .spawn(move || serve(listener, thread_inner))
            .context("spawning telemetry thread")?;
        Ok(Arc::new(TelemetryHub {
            inner,
            addr: local,
            thread: Mutex::new(Some(thread)),
        }))
    }

    /// The bound address (resolves `:0` to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Connected feed clients right now (emitters can use this to
    /// wait for a subscriber before a short-lived run).
    pub fn client_count(&self) -> usize {
        self.inner.clients.lock().unwrap().len()
    }

    /// Record a scheduler lifecycle transition for the `/status`
    /// frame.
    pub fn job_state(&self, job: &str, state: JobState) {
        self.inner
            .update_job(job, Some(state.as_str()), None, 0, f64::NAN);
    }

    /// Stop the acceptor thread and close every client.
    pub fn shutdown(&self) {
        self.inner.closed.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.lock().unwrap().take() {
            let _ = t.join();
        }
        self.inner.clients.lock().unwrap().clear();
    }
}

impl Drop for TelemetryHub {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl Telemetry for TelemetryHub {
    fn on_round(&self, ev: &RoundEvent) {
        self.inner.update_job(
            &ev.job,
            Some("running"),
            Some(ev.round),
            ev.rounds_total,
            ev.accuracy,
        );
        self.inner.broadcast(&ev.to_json().to_string());
    }

    fn on_run(&self, ev: &RunEvent) {
        let state = match ev.phase {
            RunPhase::Started => "running",
            RunPhase::Finished => "done",
            RunPhase::Failed => "failed",
        };
        self.inner.update_job(
            &ev.job,
            Some(state),
            None,
            ev.rounds_total,
            ev.final_accuracy,
        );
        self.inner.broadcast(&ev.to_json().to_string());
    }
}

/// Acceptor/reader loop: owns the listener and the read side of
/// every client. Reuses the transport's readiness layer
/// ([`Poller`]), so on Linux this is one epoll set, and elsewhere
/// the portable scan fallback — either way a single thread.
fn serve(listener: TcpListener, inner: Arc<Inner>) {
    const LISTENER_TOKEN: u64 = 0;
    let mut poller = match Poller::new() {
        Ok(p) => p,
        Err(e) => {
            eprintln!("[telemetry] poller init failed: {e}");
            return;
        }
    };
    if let Err(e) =
        poller.register_listener(&listener, LISTENER_TOKEN)
    {
        eprintln!("[telemetry] listener register failed: {e}");
        return;
    }
    // read halves: token -> (stream, partial request line)
    let mut readers: Vec<(u64, TcpStream, Vec<u8>)> = Vec::new();
    let mut next_token = 1u64;
    let mut ready = Vec::new();
    while !inner.closed.load(Ordering::SeqCst) {
        if poller
            .wait(Duration::from_millis(50), &mut ready)
            .is_err()
        {
            std::thread::sleep(Duration::from_millis(50));
            continue;
        }
        for &token in &ready {
            if token == LISTENER_TOKEN {
                while let Ok((stream, _)) = listener.accept() {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let Ok(write_half) = stream.try_clone() else {
                        continue;
                    };
                    let token = next_token;
                    next_token += 1;
                    if poller
                        .register_stream(&stream, token)
                        .is_err()
                    {
                        continue;
                    }
                    inner
                        .clients
                        .lock()
                        .unwrap()
                        .push((token, write_half));
                    readers.push((token, stream, Vec::new()));
                }
                continue;
            }
            let Some(idx) =
                readers.iter().position(|(t, _, _)| *t == token)
            else {
                continue; // stale token
            };
            let mut gone = false;
            let mut buf = [0u8; 1024];
            loop {
                match readers[idx].1.read(&mut buf) {
                    Ok(0) => {
                        gone = true;
                        break;
                    }
                    Ok(n) => {
                        readers[idx].2.extend_from_slice(&buf[..n])
                    }
                    Err(e)
                        if e.kind()
                            == std::io::ErrorKind::WouldBlock =>
                    {
                        break;
                    }
                    Err(_) => {
                        gone = true;
                        break;
                    }
                }
            }
            // answer every complete `/status` request line
            while let Some(pos) =
                readers[idx].2.iter().position(|&b| b == b'\n')
            {
                let line: Vec<u8> =
                    readers[idx].2.drain(..=pos).collect();
                let line = String::from_utf8_lossy(&line);
                if line.trim() == "/status" {
                    let frame =
                        inner.status_json().to_string() + "\n";
                    if readers[idx]
                        .1
                        .write_all(frame.as_bytes())
                        .is_err()
                    {
                        gone = true;
                    }
                }
            }
            if gone {
                let (token, stream, _) = readers.remove(idx);
                let _ = poller.deregister_stream(&stream, token);
                inner
                    .clients
                    .lock()
                    .unwrap()
                    .retain(|(t, _)| *t != token);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufRead;

    fn read_line(
        reader: &mut std::io::BufReader<TcpStream>,
    ) -> String {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        line
    }

    #[test]
    fn broadcasts_events_and_answers_status() {
        let hub = TelemetryHub::bind("127.0.0.1:0").unwrap();
        let stream =
            TcpStream::connect(hub.local_addr()).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        let mut reader =
            std::io::BufReader::new(stream.try_clone().unwrap());
        // wait until the acceptor registered us
        for _ in 0..200 {
            if !hub.inner.clients.lock().unwrap().is_empty() {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        let ev = RoundEvent {
            job: "j1".to_string(),
            round: 2,
            rounds_total: 4,
            accuracy: f64::NAN,
            test_loss: f64::NAN,
            train_loss: 0.5,
            cum_bytes: 1000,
            round_ms: 1.5,
            wall_millis: 77,
        };
        hub.on_round(&ev);
        let line = read_line(&mut reader);
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("type").unwrap().as_str().unwrap(), "round");
        assert_eq!(v.get("round").unwrap().as_usize().unwrap(), 2);
        // NaN accuracy arrives as null (opt filters Null)
        assert!(v.opt("accuracy").is_none());
        // /status reflects the round and the scheduler state
        hub.job_state("j2", JobState::Queued);
        let mut w = stream.try_clone().unwrap();
        w.write_all(b"/status\n").unwrap();
        let line = read_line(&mut reader);
        let v = Json::parse(&line).unwrap();
        assert_eq!(
            v.get("type").unwrap().as_str().unwrap(),
            "status"
        );
        let jobs = v.get("jobs").unwrap();
        assert_eq!(
            jobs.get("j1")
                .unwrap()
                .get("round")
                .unwrap()
                .as_usize()
                .unwrap(),
            2
        );
        assert_eq!(
            jobs.get("j2")
                .unwrap()
                .get("state")
                .unwrap()
                .as_str()
                .unwrap(),
            "queued"
        );
        hub.shutdown();
    }
}
