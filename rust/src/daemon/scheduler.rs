//! Job execution loop of the run-scheduler daemon.
//!
//! [`run_queue`] drains the queue in passes: scan, execute every
//! runnable job — sequentially by default, or `slots`-wide over
//! scoped worker threads — then **re-scan**. Specs dropped into the
//! queue directory while a pass was running are picked up by the next
//! pass, so `fedfp8 daemon` drains a growing sweep without a restart;
//! the loop exits once a re-scan discovers nothing new. The scheduler
//! is generic over the actual runner so tests can inject a mock (and
//! the production runner in `main.rs` can build a full
//! `Engine`/`Server` per job without this module depending on the
//! runtime layer).
//!
//! Restart contract (the crash-recovery half of the tentpole): a job
//! whose persisted state is `running` was interrupted — the previous
//! daemon died mid-job — and is re-run. The production runner always
//! arms snapshots with resume, so the re-run continues bit-identically
//! from the last durable round boundary instead of starting over.
//! `done`/`failed` jobs are skipped; removing a job's state file
//! re-queues it.
//!
//! Failure isolation: *nothing about one job can fail the pass*. A
//! runner error is that job's `failed` entry; so is an IO error from
//! persisting the job's own state transition (`queue.set_state`) —
//! the disk may be full or the state path clobbered, but the other
//! jobs in the queue still deserve their turn.

use std::collections::HashSet;
use std::sync::Mutex;

use anyhow::Result;

use super::queue::{Job, JobState, Queue};

/// What one [`run_queue`] invocation did, in terms of job ids —
/// accumulated across every drain pass (including jobs that arrived
/// mid-run and were picked up by a re-scan).
#[derive(Clone, Debug, Default)]
pub struct Report {
    /// Ids in the order execution *started* (with `slots == 1` this
    /// is exactly the filename order within each pass).
    pub started: Vec<String>,
    pub done: Vec<String>,
    /// `(id, error)` for jobs whose runner returned an error — or
    /// whose state could not be persisted. A failed job never fails
    /// the pass — the rest of the queue still runs; the caller
    /// decides what a non-empty list means.
    pub failed: Vec<(String, String)>,
    /// Jobs already `done`/`failed` from a previous pass.
    pub skipped: Vec<String>,
}

/// Drain `queue` through `runner`, `slots` jobs at a time, re-scanning
/// after each pass until no new runnable specs appear. `on_state`
/// observes every lifecycle transition (the telemetry hub's `/status`
/// map rides this); it must be cheap and must not fail.
pub fn run_queue<F, S>(
    queue: &Queue,
    slots: usize,
    on_state: S,
    runner: F,
) -> Result<Report>
where
    F: Fn(&Job) -> Result<()> + Send + Sync,
    S: Fn(&Job, JobState) + Send + Sync,
{
    let mut report = Report::default();
    // ids this invocation has already claimed (run, failed, or
    // skipped) — a re-scan only surfaces jobs we have not seen
    let mut seen: HashSet<String> = HashSet::new();
    loop {
        let mut runnable = Vec::new();
        for job in queue.scan()? {
            if seen.contains(&job.id) {
                continue;
            }
            seen.insert(job.id.clone());
            match queue.read_state(&job.id)? {
                Some((JobState::Done, _)) => {
                    on_state(&job, JobState::Done);
                    report.skipped.push(job.id);
                }
                Some((JobState::Failed, _)) => {
                    on_state(&job, JobState::Failed);
                    report.skipped.push(job.id);
                }
                // no state file, explicit `queued`, or `running` (= a
                // previous daemon was killed mid-job; the runner's
                // snapshot resume continues it bit-identically)
                _ => runnable.push(job),
            }
        }
        if runnable.is_empty() {
            // a full scan surfaced nothing new: the queue is drained
            break;
        }
        run_pass(queue, slots, &on_state, &runner, &runnable, &mut report);
    }
    Ok(report)
}

/// Execute one pass over `runnable`, appending into `report`.
fn run_pass<F, S>(
    queue: &Queue,
    slots: usize,
    on_state: &S,
    runner: &F,
    runnable: &[Job],
    report: &mut Report,
) where
    F: Fn(&Job) -> Result<()> + Send + Sync,
    S: Fn(&Job, JobState) + Send + Sync,
{
    // persist the full backlog as `queued` before starting anything,
    // so `/status` (and a post-crash inspection) sees every job the
    // pass owns — except interrupted ones, which stay `running` on
    // disk until their slot picks them up. A persist failure here is
    // observational only (the job still runs): noted, not fatal.
    for job in runnable {
        match queue.read_state(&job.id) {
            Ok(None) => {
                let _ = queue.set_state(&job.id, JobState::Queued, None);
            }
            Ok(Some(_)) | Err(_) => {}
        }
        on_state(job, JobState::Queued);
    }

    let next = Mutex::new(0usize);
    let started = Mutex::new(Vec::new());
    let done = Mutex::new(Vec::new());
    let failed = Mutex::new(Vec::<(String, String)>::new());
    let work = || {
        loop {
            let i = {
                let mut n = next.lock().unwrap();
                if *n >= runnable.len() {
                    break;
                }
                let i = *n;
                *n += 1;
                i
            };
            let job = &runnable[i];
            started.lock().unwrap().push(job.id.clone());
            // state-persist IO errors are demoted to this job's
            // `failed` entry — "a failed job never fails the pass"
            // holds even when the failure is the state file itself
            if let Err(e) =
                queue.set_state(&job.id, JobState::Running, None)
            {
                let msg =
                    format!("persisting 'running' state: {e:#}");
                on_state(job, JobState::Failed);
                failed.lock().unwrap().push((job.id.clone(), msg));
                continue;
            }
            on_state(job, JobState::Running);
            match runner(job) {
                Ok(()) => match queue.set_state(
                    &job.id,
                    JobState::Done,
                    None,
                ) {
                    Ok(()) => {
                        on_state(job, JobState::Done);
                        done.lock().unwrap().push(job.id.clone());
                    }
                    Err(e) => {
                        // the job itself succeeded, but without a
                        // durable `done` a restart would re-run it —
                        // surface that as a failure, not silence
                        let msg = format!(
                            "job succeeded but persisting 'done' \
                             state failed: {e:#}"
                        );
                        on_state(job, JobState::Failed);
                        failed
                            .lock()
                            .unwrap()
                            .push((job.id.clone(), msg));
                    }
                },
                Err(e) => {
                    let mut msg = format!("{e:#}");
                    if let Err(pe) = queue.set_state(
                        &job.id,
                        JobState::Failed,
                        Some(&msg),
                    ) {
                        msg = format!(
                            "{msg}; additionally, persisting \
                             'failed' state failed: {pe:#}"
                        );
                    }
                    on_state(job, JobState::Failed);
                    failed
                        .lock()
                        .unwrap()
                        .push((job.id.clone(), msg));
                }
            }
        }
    };
    let slots = slots.max(1).min(runnable.len().max(1));
    if slots == 1 {
        work();
    } else {
        std::thread::scope(|s| {
            let handles: Vec<_> =
                (0..slots).map(|_| s.spawn(&work)).collect();
            for h in handles {
                h.join().expect("scheduler slot panicked");
            }
        });
    }
    report.started.append(&mut started.into_inner().unwrap());
    report.done.append(&mut done.into_inner().unwrap());
    report.failed.append(&mut failed.into_inner().unwrap());
}
