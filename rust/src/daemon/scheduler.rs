//! Job execution loop of the run-scheduler daemon.
//!
//! [`run_queue`] scans the queue once, then executes every runnable
//! job — sequentially by default, or `slots`-wide over scoped worker
//! threads. The scheduler is generic over the actual runner so tests
//! can inject a mock (and the production runner in `main.rs` can
//! build a full `Engine`/`Server` per job without this module
//! depending on the runtime layer).
//!
//! Restart contract (the crash-recovery half of the tentpole): a job
//! whose persisted state is `running` was interrupted — the previous
//! daemon died mid-job — and is re-run. The production runner always
//! arms snapshots with resume, so the re-run continues bit-identically
//! from the last durable round boundary instead of starting over.
//! `done`/`failed` jobs are skipped; removing a job's state file
//! re-queues it.

use std::sync::Mutex;

use anyhow::Result;

use super::queue::{Job, JobState, Queue};

/// What one [`run_queue`] pass did, in terms of job ids.
#[derive(Clone, Debug, Default)]
pub struct Report {
    /// Ids in the order execution *started* (with `slots == 1` this
    /// is exactly the filename order).
    pub started: Vec<String>,
    pub done: Vec<String>,
    /// `(id, error)` for jobs whose runner returned an error. A
    /// failed job never fails the pass — the rest of the queue still
    /// runs; the caller decides what a non-empty list means.
    pub failed: Vec<(String, String)>,
    /// Jobs already `done`/`failed` from a previous pass.
    pub skipped: Vec<String>,
}

/// Scan `queue` and execute every runnable job through `runner`,
/// `slots` at a time. `on_state` observes every lifecycle transition
/// (the telemetry hub's `/status` map rides this); it must be cheap
/// and must not fail.
pub fn run_queue<F, S>(
    queue: &Queue,
    slots: usize,
    on_state: S,
    runner: F,
) -> Result<Report>
where
    F: Fn(&Job) -> Result<()> + Send + Sync,
    S: Fn(&Job, JobState) + Send + Sync,
{
    let mut runnable = Vec::new();
    let mut report = Report::default();
    for job in queue.scan()? {
        match queue.read_state(&job.id)? {
            Some((JobState::Done, _)) => {
                on_state(&job, JobState::Done);
                report.skipped.push(job.id);
            }
            Some((JobState::Failed, _)) => {
                on_state(&job, JobState::Failed);
                report.skipped.push(job.id);
            }
            // no state file, explicit `queued`, or `running` (= a
            // previous daemon was killed mid-job; the runner's
            // snapshot resume continues it bit-identically)
            _ => runnable.push(job),
        }
    }
    // persist the full backlog as `queued` before starting anything,
    // so `/status` (and a post-crash inspection) sees every job the
    // pass owns — except interrupted ones, which stay `running` on
    // disk until their slot picks them up
    for job in &runnable {
        if queue.read_state(&job.id)?.is_none() {
            queue.set_state(&job.id, JobState::Queued, None)?;
        }
        on_state(job, JobState::Queued);
    }

    let next = Mutex::new(0usize);
    let started = Mutex::new(Vec::new());
    let done = Mutex::new(Vec::new());
    let failed = Mutex::new(Vec::new());
    let work = || -> Result<()> {
        loop {
            let i = {
                let mut n = next.lock().unwrap();
                if *n >= runnable.len() {
                    break;
                }
                let i = *n;
                *n += 1;
                i
            };
            let job = &runnable[i];
            started.lock().unwrap().push(job.id.clone());
            queue.set_state(&job.id, JobState::Running, None)?;
            on_state(job, JobState::Running);
            match runner(job) {
                Ok(()) => {
                    queue.set_state(&job.id, JobState::Done, None)?;
                    on_state(job, JobState::Done);
                    done.lock().unwrap().push(job.id.clone());
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    queue.set_state(
                        &job.id,
                        JobState::Failed,
                        Some(&msg),
                    )?;
                    on_state(job, JobState::Failed);
                    failed
                        .lock()
                        .unwrap()
                        .push((job.id.clone(), msg));
                }
            }
        }
        Ok(())
    };
    let slots = slots.max(1).min(runnable.len().max(1));
    if slots == 1 {
        work()?;
    } else {
        std::thread::scope(|s| -> Result<()> {
            let handles: Vec<_> =
                (0..slots).map(|_| s.spawn(&work)).collect();
            for h in handles {
                h.join().expect("scheduler slot panicked")?;
            }
            Ok(())
        })?;
    }
    report.started = started.into_inner().unwrap();
    report.done = done.into_inner().unwrap();
    report.failed = failed.into_inner().unwrap();
    Ok(report)
}
