//! Directory-backed job queue for the run-scheduler daemon.
//!
//! A queue is a plain directory. Each job is one `<id>.job.json` file
//! holding a `config` object ([`ExperimentConfig::from_json`]) plus
//! optional operational knobs; the scheduler executes jobs in
//! filename order, so operators control ordering the way they control
//! logrotate: by naming (`00-warmup.job.json`, `10-main.job.json`).
//!
//! Per-job lifecycle state lives next to the spec as
//! `<id>.state.json`, written with the snapshot layer's tmp+rename
//! idiom so a crash can never leave a torn state file: after `kill
//! -9` the file still reads as the last state that was fully durable
//! (`running` for the interrupted job), which is exactly what the
//! restart path keys on. Snapshots for job `<id>` live under
//! `<id>.snaps/`, so `--resume` semantics come from the existing
//! durability layer unchanged.

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::config::ExperimentConfig;
use crate::util::json::Json;

/// Job specs are `<id>.job.json`; everything else in the directory
/// (state files, snapshot subdirs, stray notes) is not a job.
pub const JOB_SUFFIX: &str = ".job.json";

/// Lifecycle of one queued job. Only the scheduler writes
/// transitions; the states on disk are the crash-recovery contract:
/// a process killed mid-job leaves `Running` behind, and the next
/// daemon launch re-runs exactly those jobs through snapshot resume.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
}

impl JobState {
    pub fn as_str(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
        }
    }

    pub fn parse(s: &str) -> Option<JobState> {
        Some(match s {
            "queued" => JobState::Queued,
            "running" => JobState::Running,
            "done" => JobState::Done,
            "failed" => JobState::Failed,
            _ => return None,
        })
    }
}

/// One parsed job spec.
#[derive(Clone, Debug)]
pub struct Job {
    /// File stem (`foo` for `foo.job.json`) — the queue-unique id,
    /// and the `/status` key. The run itself is labelled by
    /// `cfg.name` on the telemetry feed.
    pub id: String,
    pub path: PathBuf,
    pub cfg: ExperimentConfig,
    /// Snapshot cadence for this job (rounds per generation;
    /// default 1 = every round boundary is durable/resumable).
    pub snapshot_every: usize,
}

/// Handle on a queue directory.
pub struct Queue {
    dir: PathBuf,
}

impl Queue {
    /// Open (creating if needed) a queue directory.
    pub fn open(dir: &Path) -> Result<Queue> {
        fs::create_dir_all(dir).with_context(|| {
            format!("creating queue dir {}", dir.display())
        })?;
        Ok(Queue {
            dir: dir.to_path_buf(),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// All job specs, sorted by filename (the execution order
    /// contract). A malformed spec is an error, not a skip: silently
    /// dropping a typo'd job would look like the daemon "lost" it.
    pub fn scan(&self) -> Result<Vec<Job>> {
        let mut paths = Vec::new();
        for entry in fs::read_dir(&self.dir).with_context(|| {
            format!("reading queue dir {}", self.dir.display())
        })? {
            let path = entry?.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str())
            else {
                continue;
            };
            if name.ends_with(JOB_SUFFIX) {
                paths.push(path);
            }
        }
        paths.sort();
        paths.iter().map(|p| self.load(p)).collect()
    }

    /// Parse one `<id>.job.json` spec.
    pub fn load(&self, path: &Path) -> Result<Job> {
        let name = path
            .file_name()
            .and_then(|n| n.to_str())
            .unwrap_or_default();
        let Some(id) = name.strip_suffix(JOB_SUFFIX) else {
            bail!(
                "job spec {} must be named <id>{JOB_SUFFIX}",
                path.display()
            );
        };
        ensure!(
            !id.is_empty(),
            "job spec {} has an empty id",
            path.display()
        );
        let text = fs::read_to_string(path).with_context(|| {
            format!("reading job spec {}", path.display())
        })?;
        let v = Json::parse(&text).with_context(|| {
            format!("parsing job spec {}", path.display())
        })?;
        let cfg = ExperimentConfig::from_json(
            v.get("config").with_context(|| {
                format!("job spec {}: missing 'config'", path.display())
            })?,
        )
        .with_context(|| {
            format!("job spec {}: 'config'", path.display())
        })?;
        let snapshot_every = match v.opt("snapshot_every") {
            Some(n) => n.as_usize().with_context(|| {
                format!(
                    "job spec {}: 'snapshot_every'",
                    path.display()
                )
            })?,
            None => 1,
        };
        ensure!(
            snapshot_every >= 1,
            "job spec {}: 'snapshot_every' must be at least 1",
            path.display()
        );
        Ok(Job {
            id: id.to_string(),
            path: path.to_path_buf(),
            cfg,
            snapshot_every,
        })
    }

    pub fn state_path(&self, id: &str) -> PathBuf {
        self.dir.join(format!("{id}.state.json"))
    }

    /// Snapshot directory for job `id` — handed to the existing
    /// durability layer (`Server::set_snapshot` / `resume_from`).
    pub fn snaps_dir(&self, id: &str) -> PathBuf {
        self.dir.join(format!("{id}.snaps"))
    }

    /// Read a job's persisted state; `None` means never started
    /// (equivalent to [`JobState::Queued`]).
    pub fn read_state(
        &self,
        id: &str,
    ) -> Result<Option<(JobState, Option<String>)>> {
        let path = self.state_path(id);
        let text = match fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(None);
            }
            Err(e) => {
                return Err(e).with_context(|| {
                    format!("reading job state {}", path.display())
                });
            }
        };
        let v = Json::parse(&text).with_context(|| {
            format!("parsing job state {}", path.display())
        })?;
        let state_str = v.get("state")?.as_str()?;
        let Some(state) = JobState::parse(state_str) else {
            bail!(
                "job state {}: unknown state '{state_str}'",
                path.display()
            );
        };
        let error = v
            .opt("error")
            .map(|e| e.as_str().map(String::from))
            .transpose()?;
        Ok(Some((state, error)))
    }

    /// Persist a job-state transition with the snapshot layer's
    /// tmp+rename idiom: write `.tmp-<id>.state.json`, fsync, rename
    /// over the final name. A crash at any instruction leaves either
    /// the previous state file or the new one — never a torn mix.
    pub fn set_state(
        &self,
        id: &str,
        state: JobState,
        error: Option<&str>,
    ) -> Result<()> {
        let mut m = std::collections::BTreeMap::new();
        m.insert("job".to_string(), Json::Str(id.to_string()));
        m.insert(
            "state".to_string(),
            Json::Str(state.as_str().to_string()),
        );
        m.insert(
            "error".to_string(),
            match error {
                Some(e) => Json::Str(e.to_string()),
                None => Json::Null,
            },
        );
        let body = Json::Obj(m).to_string() + "\n";
        let path = self.state_path(id);
        let tmp = self.dir.join(format!(".tmp-{id}.state.json"));
        let mut f = fs::File::create(&tmp).with_context(|| {
            format!("creating {}", tmp.display())
        })?;
        f.write_all(body.as_bytes())?;
        f.sync_all()?;
        drop(f);
        fs::rename(&tmp, &path).with_context(|| {
            format!("renaming {} -> {}", tmp.display(), path.display())
        })?;
        // directory entry durability (same best-effort as snapshots:
        // some filesystems reject dir fsync — the rename alone already
        // guarantees atomicity, just not power-fail ordering)
        if let Ok(d) = fs::File::open(&self.dir) {
            let _ = d.sync_all();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "fedfp8-queue-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn spec(model: &str) -> String {
        format!(r#"{{"config": {{"model": "{model}"}}}}"#)
    }

    #[test]
    fn scan_orders_by_filename_and_ignores_non_jobs() {
        let dir = tmpdir("scan");
        let q = Queue::open(&dir).unwrap();
        for name in ["20-b.job.json", "10-a.job.json", "30-c.job.json"]
        {
            fs::write(dir.join(name), spec("mlp_c10")).unwrap();
        }
        // non-jobs must not parse as jobs
        fs::write(dir.join("notes.txt"), "hi").unwrap();
        fs::write(dir.join("10-a.state.json"), "{}").unwrap();
        let jobs = q.scan().unwrap();
        let ids: Vec<&str> =
            jobs.iter().map(|j| j.id.as_str()).collect();
        assert_eq!(ids, ["10-a", "20-b", "30-c"]);
        assert_eq!(jobs[0].cfg.model, "mlp_c10");
        assert_eq!(jobs[0].snapshot_every, 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn state_roundtrips_atomically() {
        let dir = tmpdir("state");
        let q = Queue::open(&dir).unwrap();
        assert!(q.read_state("j").unwrap().is_none());
        q.set_state("j", JobState::Running, None).unwrap();
        assert_eq!(
            q.read_state("j").unwrap(),
            Some((JobState::Running, None))
        );
        q.set_state("j", JobState::Failed, Some("boom")).unwrap();
        assert_eq!(
            q.read_state("j").unwrap(),
            Some((JobState::Failed, Some("boom".to_string())))
        );
        // no tmp residue after a completed transition
        assert!(!dir.join(".tmp-j.state.json").exists());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn malformed_spec_is_an_error_not_a_skip() {
        let dir = tmpdir("bad");
        let q = Queue::open(&dir).unwrap();
        fs::write(dir.join("x.job.json"), "{nope").unwrap();
        assert!(q.scan().is_err());
        fs::write(dir.join("x.job.json"), r#"{"config": {}}"#)
            .unwrap();
        assert!(q.scan().is_err(), "config without model must fail");
        let _ = fs::remove_dir_all(&dir);
    }
}
