//! Run-scheduler daemon + structured telemetry feed.
//!
//! `fedfp8 run --role daemon --queue-dir D [--daemon-slots N]
//! [--telemetry-listen ADDR]` turns the launcher into a small batch
//! scheduler: job specs (`<id>.job.json`, a serialized
//! [`ExperimentConfig`](crate::config::ExperimentConfig) plus
//! operational knobs) are executed in filename order, per-job state
//! is persisted atomically, and an interrupted daemon resumes killed
//! jobs bit-identically through the existing snapshot layer.
//!
//! Three parts, deliberately decoupled:
//! - [`queue`]: the on-disk contract (specs, states, snapshots).
//! - [`scheduler`]: the execution loop, generic over the runner.
//! - [`telemetry`]: the NDJSON event feed + `/status` socket.
//!
//! See ARCHITECTURE.md §Run scheduler & telemetry feed.

pub mod queue;
pub mod scheduler;
pub mod telemetry;

pub use queue::{Job, JobState, Queue, JOB_SUFFIX};
pub use scheduler::{run_queue, Report};
pub use telemetry::TelemetryHub;
