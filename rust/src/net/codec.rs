//! Message bodies for the v2 wire protocol: the serialized forms of
//! a client work order ([`WireJob`]) and its result ([`WireOutcome`]),
//! plus the connection handshake ([`Hello`]) and the liveness probes
//! (Heartbeat/HeartbeatAck nonces).
//!
//! ## v2: multiplexing ids
//!
//! Every Job and Outcome body opens with `(round, client, job_id)` —
//! `job_id` is the round-scoped dispatch tag (the client's cohort
//! position) that lets a single worker connection carry N in-flight
//! jobs: the server demultiplexes out-of-order Outcome frames back to
//! their waiting dispatchers by this key, and the worker's reconnect
//! cache is keyed on it (`(fingerprint, round, client, job_id,
//! body_crc)`), so a re-dispatched job after a drop returns the cached
//! bit-identical bytes instead of recomputing.
//!
//! ## What travels, what doesn't
//!
//! The networked job carries exactly what the paper's protocol puts on
//! the downlink — the *encoded* FP8 broadcast (`WirePayload`: codes +
//! alpha/beta side channels) — plus the scalar hyperparameters of the
//! round and the message ids. Everything data-shaped is deliberately
//! **not** on the wire: the synthetic datasets, the client shards and
//! the segment table are pure functions of the experiment config and
//! manifest, so a worker rebuilds the identical world locally
//! (`coordinator::server::build_world`) and the handshake fingerprint
//! (`ExperimentConfig::fingerprint`) guarantees both sides derived it
//! from the same config. The worker decodes the broadcast itself —
//! decode is a pure LUT function of the payload bytes, so its
//! `w_start` is bit-identical to the server's, which is what makes a
//! networked round bit-identical to `InProcessTransport`.
//!
//! The optional error-feedback residual blocks are a *simulation-only
//! state migration* (a real device keeps its residual locally); they
//! ride the frame when `error_feedback` is on but are excluded from
//! the `CommStats` identity below.
//!
//! ## Accounting identity
//!
//! With EF off, the non-payload part of each frame is a constant:
//!
//! ```text
//! job frame bytes     = payload.wire_bytes() + JOB_FRAME_OVERHEAD_BYTES
//! outcome frame bytes = payload.wire_bytes() + OUTCOME_FRAME_OVERHEAD_BYTES
//! ```
//!
//! `coordinator::comm` charges exactly these overheads per message, so
//! the byte counts behind the paper's communication-gain tables equal
//! the bytes a `SocketTransport` really moves (asserted by the
//! loopback suite in `tests/net_transport.rs`).
//!
//! Byte-level layout: see the module docs of [`super::frame`] and the
//! independent Python mirror `tools/gen_wire_fixture.py`.

use crate::config::QatMode;
use crate::coordinator::aggregate::TreePartial;
use crate::coordinator::transport::ClientJob;
use crate::fp8::codec::{Rounding, WirePayload};

use super::frame::{WireError, FRAME_HEADER_BYTES};

/// Fixed scalar metadata preceding a job's payload block (v2: the
/// 4-byte `job_id` sits between the client id and the seed).
pub const JOB_META_BYTES: u64 = 40;
/// Fixed scalar metadata preceding an outcome's payload block (v2:
/// includes the echoed 4-byte `job_id`).
pub const OUTCOME_META_BYTES: u64 = 25;
/// The payload section table (codes/raw/alphas/betas lengths).
pub const PAYLOAD_TABLE_BYTES: u64 = 16;

/// Every non-payload byte of a job frame (envelope + meta + section
/// table) — the downlink framing charge in `coordinator::comm`.
pub const JOB_FRAME_OVERHEAD_BYTES: u64 =
    FRAME_HEADER_BYTES + JOB_META_BYTES + PAYLOAD_TABLE_BYTES;

/// Every non-payload byte of an outcome frame — the uplink framing
/// charge in `coordinator::comm`.
pub const OUTCOME_FRAME_OVERHEAD_BYTES: u64 =
    FRAME_HEADER_BYTES + OUTCOME_META_BYTES + PAYLOAD_TABLE_BYTES;

/// Serialized form of one client's work order — the owned mirror of
/// [`ClientJob`] minus everything a worker derives locally (dataset,
/// shard, segment table, decoded weights).
#[derive(Clone, Debug, PartialEq)]
pub struct WireJob {
    pub round: u32,
    pub client: u32,
    /// Round-scoped dispatch tag (cohort position): the multiplexing
    /// key echoed by the matching [`WireOutcome`]. Stable across
    /// re-dispatch attempts, so a worker's outcome cache can serve a
    /// repeated job bit-identically.
    pub job_id: u32,
    pub seed: u64,
    pub qat: QatMode,
    pub comm: Rounding,
    pub flip_aug: bool,
    pub lr: f32,
    pub weight_decay: f32,
    pub n_k: u64,
    /// The *encoded* downlink broadcast; the worker decodes it to
    /// reconstruct `w_start`/`alpha_start`/`beta_start` bit-exactly.
    pub down: WirePayload,
    pub ef: Option<Vec<f32>>,
}

/// Serialized form of one client's result.
#[derive(Clone, Debug, PartialEq)]
pub struct WireOutcome {
    pub round: u32,
    pub client: u32,
    /// Echo of the job's dispatch tag — the demultiplexing key.
    pub job_id: u32,
    pub n_k: u64,
    pub mean_loss: f32,
    pub payload: WirePayload,
    pub ef: Option<Vec<f32>>,
}

/// What kind of downstream peer a connection's [`Hello`] announces.
///
/// A server pool is homogeneous: it either executes client jobs
/// (worker peers) or cohort shards (aggregator peers); mixing the two
/// in one pool is a handshake-time error in `net::socket`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PeerRole {
    /// Executes [`FrameKind::Job`] work orders.
    ///
    /// [`FrameKind::Job`]: super::frame::FrameKind::Job
    #[default]
    Worker,
    /// Mid-tier tree node: executes [`FrameKind::Shard`] work orders
    /// and answers with ShardDone + Partial.
    ///
    /// [`FrameKind::Shard`]: super::frame::FrameKind::Shard
    Aggregator,
}

/// Connection handshake: proves both processes derived their world
/// from the same experiment config and model before any job flows.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Hello {
    /// `ExperimentConfig::fingerprint()` of the launching config.
    pub fingerprint: u64,
    /// Model dimension (cheap extra guard beyond the fingerprint).
    pub dim: u64,
    /// Manifest model-variant name.
    pub model: String,
    /// `--net-token` digest ([`token_digest`]); 0 when no token is
    /// configured. Both handshake directions carry it and compare in
    /// constant time ([`digest_eq`]) — mismatch is a typed
    /// [`WireError::AuthRejected`] before any job flows.
    pub auth: u64,
    /// What this peer executes. Absent on the wire (pre-aggregator
    /// builds) decodes as [`PeerRole::Worker`], the only role that
    /// existed then.
    pub role: PeerRole,
    /// `--shard i/G` pin of an aggregator peer: `(i, G)` with
    /// `i < G`. `None` lets the root assign shards in connection
    /// order. Always `None` for workers.
    pub shard: Option<(u32, u32)>,
}

/// FNV-1a 64 digest of the shared handshake secret; `None` (no
/// `--net-token`) maps to 0. The digest fences off misconfigured and
/// foreign peers — the threat model is accidental cross-talk between
/// deployments, not a hostile network (that is what the ROADMAP's
/// TLS item is for), so the repo's standard FNV hash is the right
/// weight.
pub fn token_digest(token: Option<&str>) -> u64 {
    let Some(t) = token else { return 0 };
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in t.as_bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Constant-time digest comparison: folds the xor-difference instead
/// of short-circuiting, so a byte-guessing peer learns nothing from
/// response timing.
pub fn digest_eq(a: u64, b: u64) -> bool {
    let mut d = a ^ b;
    d |= d >> 32;
    d |= d >> 16;
    d |= d >> 8;
    d |= d >> 4;
    d |= d >> 2;
    d |= d >> 1;
    (d & 1) == 0
}

// ---- little-endian writers -----------------------------------------

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, vs: &[f32]) {
    out.reserve(vs.len() * 4);
    for &v in vs {
        put_f32(out, v);
    }
}

// ---- little-endian reader ------------------------------------------

/// Bounds-checked cursor over a frame body; every failure is a typed
/// [`WireError::Malformed`] naming the field being read.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn bytes(
        &mut self,
        n: usize,
        what: &str,
    ) -> Result<&'a [u8], WireError> {
        if self.buf.len() - self.pos < n {
            return Err(WireError::Malformed {
                what: format!(
                    "{what}: need {n} bytes, only {} left",
                    self.buf.len() - self.pos
                ),
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8, WireError> {
        Ok(self.bytes(1, what)?[0])
    }

    fn u16(&mut self, what: &str) -> Result<u16, WireError> {
        let b = self.bytes(2, what)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self, what: &str) -> Result<u32, WireError> {
        let b = self.bytes(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &str) -> Result<u64, WireError> {
        let b = self.bytes(8, what)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn f32(&mut self, what: &str) -> Result<f32, WireError> {
        Ok(f32::from_bits(self.u32(what)?))
    }

    fn f32s(
        &mut self,
        n: usize,
        what: &str,
    ) -> Result<Vec<f32>, WireError> {
        let b = self.bytes(n * 4, what)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn finish(self) -> Result<(), WireError> {
        if self.pos != self.buf.len() {
            return Err(WireError::Malformed {
                what: format!(
                    "{} trailing bytes after message",
                    self.buf.len() - self.pos
                ),
            });
        }
        Ok(())
    }
}

// ---- enum tags -----------------------------------------------------

fn qat_to_u8(q: QatMode) -> u8 {
    match q {
        QatMode::Det => 0,
        QatMode::Rand => 1,
        QatMode::None => 2,
    }
}

fn qat_from_u8(v: u8) -> Result<QatMode, WireError> {
    Ok(match v {
        0 => QatMode::Det,
        1 => QatMode::Rand,
        2 => QatMode::None,
        _ => {
            return Err(WireError::Malformed {
                what: format!("invalid qat mode byte {v}"),
            })
        }
    })
}

fn rounding_to_u8(r: Rounding) -> u8 {
    match r {
        Rounding::Deterministic => 0,
        Rounding::Stochastic => 1,
        Rounding::None => 2,
    }
}

fn rounding_from_u8(v: u8) -> Result<Rounding, WireError> {
    Ok(match v {
        0 => Rounding::Deterministic,
        1 => Rounding::Stochastic,
        2 => Rounding::None,
        _ => {
            return Err(WireError::Malformed {
                what: format!("invalid rounding mode byte {v}"),
            })
        }
    })
}

// ---- payload block -------------------------------------------------

fn put_payload(out: &mut Vec<u8>, p: &WirePayload) {
    put_u32(out, p.codes.len() as u32);
    put_u32(out, p.raw.len() as u32);
    put_u32(out, p.alphas.len() as u32);
    put_u32(out, p.betas.len() as u32);
    out.extend_from_slice(&p.codes);
    put_f32s(out, &p.raw);
    put_f32s(out, &p.alphas);
    put_f32s(out, &p.betas);
}

fn get_payload(r: &mut Reader<'_>) -> Result<WirePayload, WireError> {
    let n_codes = r.u32("codes length")? as usize;
    let n_raw = r.u32("raw length")? as usize;
    let n_alphas = r.u32("alphas length")? as usize;
    let n_betas = r.u32("betas length")? as usize;
    Ok(WirePayload {
        codes: r.bytes(n_codes, "codes")?.to_vec(),
        raw: r.f32s(n_raw, "raw values")?,
        alphas: r.f32s(n_alphas, "alphas")?,
        betas: r.f32s(n_betas, "betas")?,
    })
}

fn put_ef(out: &mut Vec<u8>, ef: Option<&[f32]>) {
    if let Some(e) = ef {
        put_u32(out, e.len() as u32);
        put_f32s(out, e);
    }
}

fn get_ef(
    r: &mut Reader<'_>,
    has_ef: u8,
) -> Result<Option<Vec<f32>>, WireError> {
    match has_ef {
        0 => Ok(None),
        1 => {
            let n = r.u32("ef length")? as usize;
            Ok(Some(r.f32s(n, "ef residual")?))
        }
        v => Err(WireError::Malformed {
            what: format!("invalid ef flag byte {v}"),
        }),
    }
}

// ---- job -----------------------------------------------------------

/// Encode a job body straight from the borrowed [`ClientJob`] — no
/// intermediate owned copy of the (large) downlink payload.
pub fn encode_job_from(job: &ClientJob<'_>, out: &mut Vec<u8>) {
    encode_job_parts(
        job.round as u32,
        job.client as u32,
        job.job_id,
        job.seed,
        job.qat,
        job.comm,
        job.flip_aug,
        job.lr,
        job.weight_decay,
        job.n_k,
        job.down,
        job.ef.as_deref(),
        out,
    );
}

/// Encode a job body from an owned [`WireJob`] (tests, tools).
pub fn encode_job(j: &WireJob, out: &mut Vec<u8>) {
    encode_job_parts(
        j.round,
        j.client,
        j.job_id,
        j.seed,
        j.qat,
        j.comm,
        j.flip_aug,
        j.lr,
        j.weight_decay,
        j.n_k,
        &j.down,
        j.ef.as_deref(),
        out,
    );
}

#[allow(clippy::too_many_arguments)]
fn encode_job_parts(
    round: u32,
    client: u32,
    job_id: u32,
    seed: u64,
    qat: QatMode,
    comm: Rounding,
    flip_aug: bool,
    lr: f32,
    weight_decay: f32,
    n_k: u64,
    down: &WirePayload,
    ef: Option<&[f32]>,
    out: &mut Vec<u8>,
) {
    out.clear();
    put_u32(out, round);
    put_u32(out, client);
    put_u32(out, job_id);
    put_u64(out, seed);
    out.push(qat_to_u8(qat));
    out.push(rounding_to_u8(comm));
    out.push(flip_aug as u8);
    out.push(ef.is_some() as u8);
    put_f32(out, lr);
    put_f32(out, weight_decay);
    put_u64(out, n_k);
    debug_assert_eq!(out.len() as u64, JOB_META_BYTES);
    put_payload(out, down);
    put_ef(out, ef);
}

/// Decode a job body. Rejects trailing bytes.
pub fn decode_job(body: &[u8]) -> Result<WireJob, WireError> {
    let mut r = Reader::new(body);
    let round = r.u32("round")?;
    let client = r.u32("client")?;
    let job_id = r.u32("job_id")?;
    let seed = r.u64("seed")?;
    let qat = qat_from_u8(r.u8("qat mode")?)?;
    let comm = rounding_from_u8(r.u8("comm mode")?)?;
    let flip_aug = r.u8("flip_aug flag")? != 0;
    let has_ef = r.u8("ef flag")?;
    let lr = r.f32("lr")?;
    let weight_decay = r.f32("weight_decay")?;
    let n_k = r.u64("n_k")?;
    let down = get_payload(&mut r)?;
    let ef = get_ef(&mut r, has_ef)?;
    r.finish()?;
    Ok(WireJob {
        round,
        client,
        job_id,
        seed,
        qat,
        comm,
        flip_aug,
        lr,
        weight_decay,
        n_k,
        down,
        ef,
    })
}

// ---- outcome -------------------------------------------------------

/// Encode an outcome body.
pub fn encode_outcome(o: &WireOutcome, out: &mut Vec<u8>) {
    out.clear();
    put_u32(out, o.round);
    put_u32(out, o.client);
    put_u32(out, o.job_id);
    put_u64(out, o.n_k);
    put_f32(out, o.mean_loss);
    out.push(o.ef.is_some() as u8);
    debug_assert_eq!(out.len() as u64, OUTCOME_META_BYTES);
    put_payload(out, &o.payload);
    put_ef(out, o.ef.as_deref());
}

/// Decode an outcome body. Rejects trailing bytes.
pub fn decode_outcome(body: &[u8]) -> Result<WireOutcome, WireError> {
    let mut r = Reader::new(body);
    let round = r.u32("round")?;
    let client = r.u32("client")?;
    let job_id = r.u32("job_id")?;
    let n_k = r.u64("n_k")?;
    let mean_loss = r.f32("mean_loss")?;
    let has_ef = r.u8("ef flag")?;
    let payload = get_payload(&mut r)?;
    let ef = get_ef(&mut r, has_ef)?;
    r.finish()?;
    Ok(WireOutcome {
        round,
        client,
        job_id,
        n_k,
        mean_loss,
        payload,
        ef,
    })
}

// ---- handshake -----------------------------------------------------

/// Encode a [`Hello`] body.
pub fn encode_hello(h: &Hello, out: &mut Vec<u8>) {
    out.clear();
    put_u64(out, h.fingerprint);
    put_u64(out, h.dim);
    put_u16(out, h.model.len() as u16);
    out.extend_from_slice(h.model.as_bytes());
    put_u64(out, h.auth);
    // role + shard pin trail the auth digest with the same
    // optional-on-read rule; G = 0 encodes "no pin"
    out.push(match h.role {
        PeerRole::Worker => 0,
        PeerRole::Aggregator => 1,
    });
    let (i, g) = h.shard.unwrap_or((0, 0));
    put_u32(out, i);
    put_u32(out, g);
}

/// Decode a [`Hello`] body. The trailing auth digest is optional on
/// read (absent decodes as 0 = "no token"), so a tokenless build one
/// PR older still handshakes against a tokenless launch of this one
/// — and is rejected, not confused, the moment a token is set. The
/// role + shard trailer that follows is optional the same way
/// (absent decodes as a worker, the only role that existed then).
pub fn decode_hello(body: &[u8]) -> Result<Hello, WireError> {
    let mut r = Reader::new(body);
    let fingerprint = r.u64("fingerprint")?;
    let dim = r.u64("dim")?;
    let n = r.u16("model name length")? as usize;
    let model = String::from_utf8(r.bytes(n, "model name")?.to_vec())
        .map_err(|_| WireError::Malformed {
            what: "model name is not utf-8".into(),
        })?;
    let auth = if r.remaining() > 0 {
        r.u64("auth digest")?
    } else {
        0
    };
    let (role, shard) = if r.remaining() > 0 {
        let role = match r.u8("peer role")? {
            0 => PeerRole::Worker,
            1 => PeerRole::Aggregator,
            v => {
                return Err(WireError::Malformed {
                    what: format!("invalid peer role byte {v}"),
                })
            }
        };
        let i = r.u32("shard index")?;
        let g = r.u32("shard count")?;
        let shard = if g == 0 {
            None
        } else {
            if i >= g {
                return Err(WireError::Malformed {
                    what: format!("shard pin {i}/{g} out of range"),
                });
            }
            Some((i, g))
        };
        (role, shard)
    } else {
        (PeerRole::Worker, None)
    };
    r.finish()?;
    Ok(Hello {
        fingerprint,
        dim,
        model,
        auth,
        role,
        shard,
    })
}

/// Encode a HelloAck body (the echoed fingerprint + the server's own
/// auth digest, so auth is mutual — a worker will not serve a
/// foreign coordinator either).
pub fn encode_hello_ack(fingerprint: u64, auth: u64, out: &mut Vec<u8>) {
    out.clear();
    put_u64(out, fingerprint);
    put_u64(out, auth);
}

/// Decode a HelloAck body into (fingerprint, auth digest); the auth
/// field is optional on read with the same compatibility rule as
/// [`decode_hello`].
pub fn decode_hello_ack(body: &[u8]) -> Result<(u64, u64), WireError> {
    let mut r = Reader::new(body);
    let fp = r.u64("ack fingerprint")?;
    let auth = if r.remaining() > 0 {
        r.u64("ack auth digest")?
    } else {
        0
    };
    r.finish()?;
    Ok((fp, auth))
}

// ---- heartbeat -----------------------------------------------------

/// Encode a Heartbeat / HeartbeatAck body (the 8-byte nonce; the ack
/// echoes the probe's nonce verbatim).
pub fn encode_heartbeat(nonce: u64, out: &mut Vec<u8>) {
    out.clear();
    put_u64(out, nonce);
}

/// Decode a Heartbeat / HeartbeatAck body.
pub fn decode_heartbeat(body: &[u8]) -> Result<u64, WireError> {
    let mut r = Reader::new(body);
    let nonce = r.u64("heartbeat nonce")?;
    r.finish()?;
    Ok(nonce)
}

// ---- tree-aggregation partial --------------------------------------

/// Fixed scalar metadata of a Partial body: round u32 + start u64 +
/// end u64 + width u32 + fragment count u32.
pub const PARTIAL_META_BYTES: u64 = 28;
/// Per-fragment header: fragment start u64 + fragment len u64.
pub const PARTIAL_RANGE_HEADER_BYTES: u64 = 16;
/// Every non-sum byte of a partial frame per message (envelope +
/// meta) — the backbone framing charge in `coordinator::comm`.
pub const PARTIAL_FRAME_OVERHEAD_BYTES: u64 =
    FRAME_HEADER_BYTES + PARTIAL_META_BYTES;

/// Per-fragment wire cost: range header + `width` raw f64 sums.
pub fn partial_fragment_bytes(width: u64) -> u64 {
    PARTIAL_RANGE_HEADER_BYTES + 8 * width
}

/// The payload-proportional bytes of an encoded partial (everything
/// except [`PARTIAL_FRAME_OVERHEAD_BYTES`]); a full partial frame is
/// exactly `partial_wire_bytes(p) + PARTIAL_FRAME_OVERHEAD_BYTES` —
/// the reported-vs-actual identity asserted in
/// tests/net_transport.rs.
pub fn partial_wire_bytes(p: &TreePartial) -> u64 {
    p.ranges.len() as u64 * partial_fragment_bytes(p.width as u64)
}

/// Encode a [`TreePartial`] body ([`FrameKind::Partial`]). The f64
/// sums travel as raw little-endian bit patterns, so a decoded
/// partial is bit-identical to the sender's accumulator state — the
/// property the tree-vs-flat contract rests on.
///
/// [`FrameKind::Partial`]: super::frame::FrameKind::Partial
pub fn encode_partial(round: u32, p: &TreePartial, out: &mut Vec<u8>) {
    out.clear();
    put_u32(out, round);
    put_u64(out, p.start);
    put_u64(out, p.end);
    put_u32(out, p.width);
    put_u32(out, p.ranges.len() as u32);
    debug_assert_eq!(out.len() as u64, PARTIAL_META_BYTES);
    for (&(s, l), sum) in p.ranges.iter().zip(&p.sums) {
        put_u64(out, s);
        put_u64(out, l);
        out.reserve(sum.len() * 8);
        for &v in sum {
            put_u64(out, v.to_bits());
        }
    }
}

/// Decode a Partial body. Rejects trailing bytes; structural
/// validation (contiguity, tiling) happens in
/// `FedAvgStream::absorb`.
pub fn decode_partial(
    body: &[u8],
) -> Result<(u32, TreePartial), WireError> {
    let mut r = Reader::new(body);
    let round = r.u32("round")?;
    let start = r.u64("partial start")?;
    let end = r.u64("partial end")?;
    let width = r.u32("partial width")? as usize;
    let n = r.u32("fragment count")? as usize;
    // cap pre-reservation by what the body could possibly hold, so a
    // corrupt count cannot trigger a giant allocation before the
    // bounds-checked reads fail
    let cap = n.min(body.len() / PARTIAL_RANGE_HEADER_BYTES as usize);
    let mut ranges = Vec::with_capacity(cap);
    let mut sums = Vec::with_capacity(cap);
    for _ in 0..n {
        let s = r.u64("fragment start")?;
        let l = r.u64("fragment len")?;
        let mut sum = Vec::with_capacity(width.min(body.len() / 8));
        for _ in 0..width {
            sum.push(f64::from_bits(r.u64("fragment sum")?));
        }
        ranges.push((s, l));
        sums.push(sum);
    }
    r.finish()?;
    Ok((
        round,
        TreePartial {
            start,
            end,
            width: width as u32,
            ranges,
            sums,
        },
    ))
}

// ---- tree shard dispatch (root <-> networked aggregator) -----------

/// Fixed scalar metadata of a Shard body: round u32 + shard index u32
/// + configured fan-out u32 + cohort lo u64 + cohort hi u64.
pub const SHARD_META_BYTES: u64 = 28;
/// Fixed scalar metadata of a ShardDone body: round u32 + lo u64 +
/// hi u64 + up_bytes u64 + up_msgs u64 + ef count u32.
pub const SHARD_DONE_META_BYTES: u64 = 40;

/// One round's work order for a networked mid-tier aggregator
/// ([`FrameKind::Shard`]): execute cohort positions `[lo, hi)` of the
/// round's cohort (which the aggregator derives locally — the cohort
/// draw is a pure function of the config) against the broadcast
/// `down`, and answer with a ShardDone + Partial pair.
///
/// `index`/`nodes` name the shard's place in the configured `tree:G`
/// topology so the aggregator can sanity-check a pin mismatch;
/// `efs` carries the EF residuals of exactly the shard's clients
/// (simulation-only state migration, like the per-job `ef` field).
///
/// [`FrameKind::Shard`]: super::frame::FrameKind::Shard
#[derive(Clone, Debug, PartialEq)]
pub struct WireShard {
    pub round: u32,
    pub index: u32,
    pub nodes: u32,
    pub lo: u64,
    pub hi: u64,
    pub down: WirePayload,
    /// `(client id, residual)` pairs, ascending by client id.
    pub efs: Vec<(u32, Vec<f32>)>,
}

/// A networked aggregator's per-shard completion report
/// ([`FrameKind::ShardDone`]), sent immediately *before* the shard's
/// Partial frame: downstream uplink accounting (so the root's
/// client-edge `CommStats` stays identical to an in-process tree) and
/// the returned EF residuals. The Partial itself is the completion
/// signal — a ShardDone without its Partial is an unfinished shard.
///
/// [`FrameKind::ShardDone`]: super::frame::FrameKind::ShardDone
#[derive(Clone, Debug, PartialEq)]
pub struct WireShardDone {
    pub round: u32,
    pub lo: u64,
    pub hi: u64,
    /// Client-edge uplink bytes the shard's outcomes were charged
    /// (`payload.wire_bytes() + UPLINK_HEADER_BYTES` per member).
    pub up_bytes: u64,
    pub up_msgs: u64,
    /// `(client id, residual)` pairs, ascending by client id.
    pub efs: Vec<(u32, Vec<f32>)>,
}

fn put_ef_map(out: &mut Vec<u8>, efs: &[(u32, &[f32])]) {
    put_u32(out, efs.len() as u32);
    for &(client, e) in efs {
        put_u32(out, client);
        put_u32(out, e.len() as u32);
        put_f32s(out, e);
    }
}

fn get_ef_map(
    r: &mut Reader<'_>,
) -> Result<Vec<(u32, Vec<f32>)>, WireError> {
    let n = r.u32("ef map count")? as usize;
    // bounds like decode_partial: cap pre-reservation by what the
    // body could possibly hold
    let mut efs = Vec::with_capacity(n.min(r.remaining() / 8));
    for _ in 0..n {
        let client = r.u32("ef map client")?;
        let len = r.u32("ef map length")? as usize;
        efs.push((client, r.f32s(len, "ef map residual")?));
    }
    Ok(efs)
}

/// Encode a Shard body straight from borrowed parts (the dispatch
/// path holds the payload and residuals by reference).
#[allow(clippy::too_many_arguments)]
pub fn encode_shard_parts(
    round: u32,
    index: u32,
    nodes: u32,
    lo: u64,
    hi: u64,
    down: &WirePayload,
    efs: &[(u32, &[f32])],
    out: &mut Vec<u8>,
) {
    out.clear();
    put_u32(out, round);
    put_u32(out, index);
    put_u32(out, nodes);
    put_u64(out, lo);
    put_u64(out, hi);
    debug_assert_eq!(out.len() as u64, SHARD_META_BYTES);
    put_payload(out, down);
    put_ef_map(out, efs);
}

/// Encode a Shard body from an owned [`WireShard`] (tests, tools).
pub fn encode_shard(s: &WireShard, out: &mut Vec<u8>) {
    let efs: Vec<(u32, &[f32])> =
        s.efs.iter().map(|(c, e)| (*c, e.as_slice())).collect();
    encode_shard_parts(
        s.round, s.index, s.nodes, s.lo, s.hi, &s.down, &efs, out,
    );
}

/// Decode a Shard body. Rejects trailing bytes and inverted bounds.
pub fn decode_shard(body: &[u8]) -> Result<WireShard, WireError> {
    let mut r = Reader::new(body);
    let round = r.u32("shard round")?;
    let index = r.u32("shard index")?;
    let nodes = r.u32("shard nodes")?;
    let lo = r.u64("shard lo")?;
    let hi = r.u64("shard hi")?;
    if lo >= hi || index >= nodes {
        return Err(WireError::Malformed {
            what: format!(
                "shard {index}/{nodes} bounds [{lo}, {hi}) invalid"
            ),
        });
    }
    let down = get_payload(&mut r)?;
    let efs = get_ef_map(&mut r)?;
    r.finish()?;
    Ok(WireShard {
        round,
        index,
        nodes,
        lo,
        hi,
        down,
        efs,
    })
}

/// Encode a ShardDone body.
pub fn encode_shard_done(d: &WireShardDone, out: &mut Vec<u8>) {
    out.clear();
    put_u32(out, d.round);
    put_u64(out, d.lo);
    put_u64(out, d.hi);
    put_u64(out, d.up_bytes);
    put_u64(out, d.up_msgs);
    let efs: Vec<(u32, &[f32])> =
        d.efs.iter().map(|(c, e)| (*c, e.as_slice())).collect();
    put_ef_map(out, &efs);
    debug_assert!(out.len() as u64 >= SHARD_DONE_META_BYTES);
}

/// Decode a ShardDone body. Rejects trailing bytes.
pub fn decode_shard_done(
    body: &[u8],
) -> Result<WireShardDone, WireError> {
    let mut r = Reader::new(body);
    let round = r.u32("shard-done round")?;
    let lo = r.u64("shard-done lo")?;
    let hi = r.u64("shard-done hi")?;
    let up_bytes = r.u64("shard-done up_bytes")?;
    let up_msgs = r.u64("shard-done up_msgs")?;
    let efs = get_ef_map(&mut r)?;
    r.finish()?;
    Ok(WireShardDone {
        round,
        lo,
        hi,
        up_bytes,
        up_msgs,
        efs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_payload() -> WirePayload {
        WirePayload {
            codes: vec![1, 2, 3, 250],
            raw: vec![0.5, -1.5],
            alphas: vec![1.0],
            betas: vec![2.0, 4.0],
        }
    }

    fn sample_job(ef: Option<Vec<f32>>) -> WireJob {
        WireJob {
            round: 7,
            client: 11,
            job_id: 3,
            seed: 0xDEAD_BEEF,
            qat: QatMode::Det,
            comm: Rounding::Stochastic,
            flip_aug: true,
            lr: 0.05,
            weight_decay: 1e-3,
            n_k: 64,
            down: sample_payload(),
            ef,
        }
    }

    #[test]
    fn job_roundtrips() {
        for ef in [None, Some(vec![0.25f32, -0.125, 3.5])] {
            let j = sample_job(ef);
            let mut body = Vec::new();
            encode_job(&j, &mut body);
            assert_eq!(decode_job(&body).unwrap(), j);
        }
    }

    #[test]
    fn outcome_roundtrips() {
        for ef in [None, Some(vec![])] {
            let o = WireOutcome {
                round: 3,
                client: 0,
                job_id: 0,
                n_k: 0,
                mean_loss: f32::MIN_POSITIVE,
                payload: sample_payload(),
                ef,
            };
            let mut body = Vec::new();
            encode_outcome(&o, &mut body);
            assert_eq!(decode_outcome(&body).unwrap(), o);
        }
    }

    #[test]
    fn hello_roundtrips() {
        let h = Hello {
            fingerprint: 0x1234_5678_9ABC_DEF0,
            dim: 4096,
            model: "lenet_c10".into(),
            auth: token_digest(Some("hunter2")),
            role: PeerRole::Worker,
            shard: None,
        };
        let mut body = Vec::new();
        encode_hello(&h, &mut body);
        assert_eq!(decode_hello(&body).unwrap(), h);
        encode_hello_ack(h.fingerprint, h.auth, &mut body);
        assert_eq!(
            decode_hello_ack(&body).unwrap(),
            (h.fingerprint, h.auth)
        );
        // an aggregator announces itself and may pin a shard
        let a = Hello {
            role: PeerRole::Aggregator,
            shard: Some((1, 4)),
            ..h.clone()
        };
        encode_hello(&a, &mut body);
        assert_eq!(decode_hello(&body).unwrap(), a);
        // a pin outside its group is rejected, not clamped
        let mut bad = Vec::new();
        encode_hello(&a, &mut bad);
        let n = bad.len();
        bad[n - 8..n - 4].copy_from_slice(&7u32.to_le_bytes());
        assert!(decode_hello(&bad).is_err());
        // pre-role peers omit the trailing role + pin (9 bytes):
        // decodes as an unpinned worker, not as an error
        encode_hello(&h, &mut body);
        body.truncate(body.len() - 9);
        let d = decode_hello(&body).unwrap();
        assert_eq!(d.auth, h.auth);
        assert_eq!(d.role, PeerRole::Worker);
        assert_eq!(d.shard, None);
        // pre-token peers also omit the digest (17 bytes total):
        // auth decodes as 0, not as an error
        encode_hello(&h, &mut body);
        body.truncate(body.len() - 17);
        let d = decode_hello(&body).unwrap();
        assert_eq!(d.auth, 0);
        assert_eq!(d.role, PeerRole::Worker);
        encode_hello_ack(h.fingerprint, h.auth, &mut body);
        body.truncate(8);
        assert_eq!(
            decode_hello_ack(&body).unwrap(),
            (h.fingerprint, 0)
        );
    }

    #[test]
    fn token_digest_and_ct_compare() {
        assert_eq!(token_digest(None), 0);
        // FNV-1a of the empty string is the offset basis — distinct
        // from "no token configured"
        assert_eq!(token_digest(Some("")), 0xcbf2_9ce4_8422_2325);
        let a = token_digest(Some("hunter2"));
        let b = token_digest(Some("hunter3"));
        assert_ne!(a, 0);
        assert_ne!(a, b);
        assert_eq!(token_digest(Some("hunter2")), a);
        assert!(digest_eq(a, a) && digest_eq(0, 0));
        assert!(!digest_eq(a, b) && !digest_eq(a, 0));
        // every single-bit difference must be caught by the fold
        for bit in 0..64 {
            assert!(!digest_eq(a, a ^ (1u64 << bit)), "bit {bit}");
        }
    }

    #[test]
    fn heartbeat_nonce_roundtrips() {
        let mut body = Vec::new();
        for nonce in [0u64, 1, u64::MAX, 0xBEA7_BEA7] {
            encode_heartbeat(nonce, &mut body);
            assert_eq!(body.len(), 8);
            assert_eq!(decode_heartbeat(&body).unwrap(), nonce);
        }
        assert!(decode_heartbeat(&[0u8; 7]).is_err());
        assert!(decode_heartbeat(&[0u8; 9]).is_err());
    }

    #[test]
    fn frame_overhead_identity() {
        // the accounting contract: frame bytes = payload wire bytes +
        // a constant, for both directions (EF off)
        let j = sample_job(None);
        let mut body = Vec::new();
        encode_job(&j, &mut body);
        assert_eq!(
            FRAME_HEADER_BYTES + body.len() as u64,
            j.down.wire_bytes() + JOB_FRAME_OVERHEAD_BYTES
        );
        let o = WireOutcome {
            round: 1,
            client: 2,
            job_id: 9,
            n_k: 3,
            mean_loss: 0.5,
            payload: sample_payload(),
            ef: None,
        };
        encode_outcome(&o, &mut body);
        assert_eq!(
            FRAME_HEADER_BYTES + body.len() as u64,
            o.payload.wire_bytes() + OUTCOME_FRAME_OVERHEAD_BYTES
        );
    }

    #[test]
    fn truncated_body_is_malformed() {
        let j = sample_job(None);
        let mut body = Vec::new();
        encode_job(&j, &mut body);
        let err = decode_job(&body[..body.len() - 1]).unwrap_err();
        assert!(matches!(err, WireError::Malformed { .. }), "{err}");
    }

    #[test]
    fn trailing_bytes_rejected() {
        let j = sample_job(None);
        let mut body = Vec::new();
        encode_job(&j, &mut body);
        body.push(0);
        let err = decode_job(&body).unwrap_err();
        assert!(err.to_string().contains("trailing"), "{err}");
    }

    #[test]
    fn bad_enum_bytes_rejected() {
        let j = sample_job(None);
        let mut body = Vec::new();
        encode_job(&j, &mut body);
        body[20] = 9; // qat byte (after round/client/job_id/seed)
        assert!(decode_job(&body).is_err());
        encode_job(&j, &mut body);
        body[21] = 9; // comm byte
        assert!(decode_job(&body).is_err());
        encode_job(&j, &mut body);
        body[23] = 2; // ef flag byte
        assert!(decode_job(&body).is_err());
    }

    #[test]
    fn empty_messages_roundtrip() {
        // zero-size everything: the empty-segment / zero-client edges
        let j = WireJob {
            round: 0,
            client: 0,
            job_id: 0,
            seed: 0,
            qat: QatMode::None,
            comm: Rounding::None,
            flip_aug: false,
            lr: 0.0,
            weight_decay: 0.0,
            n_k: 0,
            down: WirePayload::default(),
            ef: Some(vec![]),
        };
        let mut body = Vec::new();
        encode_job(&j, &mut body);
        assert_eq!(decode_job(&body).unwrap(), j);
    }

    fn sample_partial() -> TreePartial {
        TreePartial {
            start: 4,
            end: 11,
            width: 3,
            ranges: vec![(4, 4), (8, 2), (10, 1)],
            sums: vec![
                vec![1.5, -0.25, f64::NAN],
                vec![0.1 + 0.2, f64::INFINITY, -0.0],
                vec![1e-310, 7.0, 42.0],
            ],
        }
    }

    #[test]
    fn partial_roundtrips_bit_exactly() {
        // NaN / inf / subnormal / -0.0 all survive: sums travel as
        // raw bit patterns, not values
        let p = sample_partial();
        let mut body = Vec::new();
        encode_partial(9, &p, &mut body);
        let (round, q) = decode_partial(&body).unwrap();
        assert_eq!(round, 9);
        assert_eq!((q.start, q.end, q.width), (p.start, p.end, p.width));
        assert_eq!(q.ranges, p.ranges);
        for (a, b) in q.sums.iter().zip(&p.sums) {
            let bits =
                |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(a), bits(b));
        }
    }

    #[test]
    fn partial_overhead_identity() {
        // the backbone accounting contract, mirroring the job/outcome
        // constants: frame bytes = fragment wire bytes + a constant
        let p = sample_partial();
        let mut body = Vec::new();
        encode_partial(0, &p, &mut body);
        assert_eq!(
            FRAME_HEADER_BYTES + body.len() as u64,
            partial_wire_bytes(&p) + PARTIAL_FRAME_OVERHEAD_BYTES
        );
    }

    #[test]
    fn partial_truncation_and_trailing_are_malformed() {
        let p = sample_partial();
        let mut body = Vec::new();
        encode_partial(0, &p, &mut body);
        let err = decode_partial(&body[..body.len() - 3]).unwrap_err();
        assert!(matches!(err, WireError::Malformed { .. }), "{err}");
        body.push(0);
        let err = decode_partial(&body).unwrap_err();
        assert!(matches!(err, WireError::Malformed { .. }), "{err}");
    }

    #[test]
    fn partial_corrupt_count_fails_without_huge_alloc() {
        let p = sample_partial();
        let mut body = Vec::new();
        encode_partial(0, &p, &mut body);
        // fragment count lives at meta offset 24..28: forge u32::MAX
        body[24..28].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = decode_partial(&body).unwrap_err();
        assert!(matches!(err, WireError::Malformed { .. }), "{err}");
    }

    fn sample_shard() -> WireShard {
        WireShard {
            round: 3,
            index: 1,
            nodes: 4,
            lo: 6,
            hi: 11,
            down: sample_payload(),
            efs: vec![
                (7, vec![0.5, -1.25, f32::MIN_POSITIVE]),
                (9, vec![]),
                (10, vec![2.0; 5]),
            ],
        }
    }

    #[test]
    fn shard_roundtrips() {
        let s = sample_shard();
        let mut body = Vec::new();
        encode_shard(&s, &mut body);
        assert_eq!(decode_shard(&body).unwrap(), s);
        // the borrowed-parts encoder produces the identical body
        let efs: Vec<(u32, &[f32])> =
            s.efs.iter().map(|(c, e)| (*c, e.as_slice())).collect();
        let mut parts = Vec::new();
        encode_shard_parts(
            s.round, s.index, s.nodes, s.lo, s.hi, &s.down, &efs,
            &mut parts,
        );
        assert_eq!(parts, body);
        // no residuals in flight is a plain empty map
        let bare = WireShard {
            efs: Vec::new(),
            ..sample_shard()
        };
        encode_shard(&bare, &mut body);
        assert_eq!(decode_shard(&body).unwrap(), bare);
    }

    #[test]
    fn shard_rejects_bad_bounds_truncation_and_trailing() {
        let mut body = Vec::new();
        for (index, nodes, lo, hi) in
            [(1, 4, 6, 6), (1, 4, 8, 6), (4, 4, 6, 11), (0, 0, 6, 11)]
        {
            let s = WireShard {
                index,
                nodes,
                lo,
                hi,
                ..sample_shard()
            };
            encode_shard(&s, &mut body);
            let err = decode_shard(&body).unwrap_err();
            assert!(
                matches!(err, WireError::Malformed { .. }),
                "{index}/{nodes} [{lo},{hi}): {err}"
            );
        }
        encode_shard(&sample_shard(), &mut body);
        assert!(decode_shard(&body[..body.len() - 2]).is_err());
        body.push(0);
        assert!(decode_shard(&body).is_err());
    }

    #[test]
    fn shard_done_roundtrips_and_rejects_damage() {
        let d = WireShardDone {
            round: 3,
            lo: 6,
            hi: 11,
            up_bytes: 12_345,
            up_msgs: 5,
            efs: vec![(7, vec![1.0, -2.0]), (10, vec![0.0; 4])],
        };
        let mut body = Vec::new();
        encode_shard_done(&d, &mut body);
        assert!(body.len() as u64 > SHARD_DONE_META_BYTES);
        assert_eq!(decode_shard_done(&body).unwrap(), d);
        assert!(decode_shard_done(&body[..body.len() - 1]).is_err());
        body.push(0);
        assert!(decode_shard_done(&body).is_err());
        // a forged EF count cannot trigger a giant allocation: the
        // reservation is capped by the body length
        encode_shard_done(&d, &mut body);
        body[36..40].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = decode_shard_done(&body).unwrap_err();
        assert!(matches!(err, WireError::Malformed { .. }), "{err}");
    }
}
