//! Wire frames — the length-prefixed, checksummed envelope every
//! fedfp8 network message travels in.
//!
//! Layout (all little-endian; independently mirrored by
//! `tools/gen_wire_fixture.py`, pinned by `tests/golden_wire.rs`):
//!
//! ```text
//! 0   magic     4 B  = b"FP8W"
//! 4   version   u16  = WIRE_VERSION
//! 6   kind      u8   (Hello/HelloAck/Job/Outcome/Shutdown/
//!                     Heartbeat/HeartbeatAck)
//! 7   flags     u8   = 0 (reserved)
//! 8   body_len  u32
//! 12  crc32     u32  (IEEE CRC-32 of the body)
//! 16  body ...
//! ```
//!
//! The envelope is deliberately *per-frame*, not per-connection:
//! every message re-asserts magic + version + checksum, so a
//! desynchronized or corrupted stream fails on the very next frame
//! with a typed [`WireError`] instead of feeding garbage lengths into
//! the codec. Body size is capped ([`MAX_BODY_BYTES`]) so a corrupt
//! length field cannot trigger a multi-gigabyte allocation.
//!
//! Error taxonomy: every failure mode a peer can induce — wrong
//! magic, version skew, truncation, checksum mismatch, read timeout,
//! clean close — is a distinct [`WireError`] variant, so callers (and
//! the fault-injection suite in `tests/net_transport.rs`) can tell
//! "remote speaks a different protocol" from "remote died mid-frame"
//! from "remote is gone".

use std::fmt;
use std::io::{ErrorKind, Read, Write};
use std::sync::OnceLock;
use std::time::{Duration, Instant};

/// Frame magic: identifies a fedfp8 wire peer.
pub const MAGIC: [u8; 4] = *b"FP8W";

/// Wire protocol version. Bump on ANY change to the frame envelope or
/// to a message body layout in `net::codec`, and regenerate the golden
/// fixture (`tools/gen_wire_fixture.py`).
///
/// v2 (this build): every Job/Outcome body carries a round-scoped
/// `job_id` so one connection multiplexes N in-flight jobs, and the
/// Heartbeat/HeartbeatAck frames exist. v1 frames decode to a typed
/// [`WireError::VersionMismatch`] (pinned by `tests/golden_wire.rs`
/// against the retained `wire_v1.bin` fixture).
///
/// [`FrameKind::Partial`] (tree aggregation) was added *within* v2:
/// a new kind alters no existing layout, so v2 peers that predate it
/// interoperate fully on the client edge and reject partial frames
/// with a typed [`WireError::UnknownKind`] instead of misparsing.
pub const WIRE_VERSION: u16 = 2;

/// Envelope size preceding every body.
pub const FRAME_HEADER_BYTES: u64 = 16;

/// Upper bound on a frame body — far above any model this repo ships
/// (a 100M-param FP8 payload is ~100 MB) but small enough that a
/// corrupted length field cannot OOM the process.
pub const MAX_BODY_BYTES: u32 = 1 << 30;

/// What a frame carries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// Worker -> server: config fingerprint + model identity.
    Hello = 1,
    /// Server -> worker: handshake accepted.
    HelloAck = 2,
    /// Server -> worker: one client's work order.
    Job = 3,
    /// Worker -> server: one client's result.
    Outcome = 4,
    /// Server -> worker: drain and exit cleanly.
    Shutdown = 5,
    /// Liveness probe (either direction): "are you still there?".
    /// Body: an opaque u64 nonce, echoed back by the ack.
    Heartbeat = 6,
    /// Reply to a [`FrameKind::Heartbeat`], echoing its nonce.
    HeartbeatAck = 7,
    /// Mid-tier aggregator -> upstream: one weighted FedAvg partial
    /// over a contiguous cohort shard (tree aggregation; body layout
    /// in `net::codec::encode_partial`).
    Partial = 8,
    /// Root -> mid-tier aggregator: one round's shard work order
    /// (shard bounds + downlink payload + EF residuals; body layout
    /// in `net::codec::encode_shard`).
    Shard = 9,
    /// Mid-tier aggregator -> root: shard execution stats + returned
    /// EF residuals, sent immediately before the shard's
    /// [`FrameKind::Partial`] (body layout in
    /// `net::codec::encode_shard_done`).
    ShardDone = 10,
}

impl FrameKind {
    fn from_u8(v: u8) -> Result<FrameKind, WireError> {
        Ok(match v {
            1 => FrameKind::Hello,
            2 => FrameKind::HelloAck,
            3 => FrameKind::Job,
            4 => FrameKind::Outcome,
            5 => FrameKind::Shutdown,
            6 => FrameKind::Heartbeat,
            7 => FrameKind::HeartbeatAck,
            8 => FrameKind::Partial,
            9 => FrameKind::Shard,
            10 => FrameKind::ShardDone,
            got => return Err(WireError::UnknownKind { got }),
        })
    }
}

/// A received frame: kind + raw body (decoded by `net::codec`).
#[derive(Clone, Debug)]
pub struct Frame {
    pub kind: FrameKind,
    pub body: Vec<u8>,
}

impl Frame {
    /// Total bytes this frame occupied on the wire.
    pub fn total_bytes(&self) -> u64 {
        FRAME_HEADER_BYTES + self.body.len() as u64
    }
}

/// Typed failure modes of the wire layer.
#[derive(Debug)]
pub enum WireError {
    /// Peer is not speaking the fedfp8 protocol at all.
    BadMagic { got: [u8; 4] },
    /// Peer speaks the protocol at an incompatible version.
    VersionMismatch { got: u16, want: u16 },
    /// Envelope carried an unassigned frame-kind byte.
    UnknownKind { got: u8 },
    /// Connection closed in the middle of a frame.
    Truncated { context: &'static str },
    /// Body bytes do not match the envelope checksum.
    ChecksumMismatch { got: u32, want: u32 },
    /// A body larger than [`MAX_BODY_BYTES`] (declared by a received
    /// envelope, or about to be sent).
    Oversize { len: u64 },
    /// Read (or write) deadline expired — the peer went silent.
    Timeout,
    /// The heartbeat state machine declared the peer dead: no frame
    /// (not even a heartbeat ack) arrived within the idle deadline.
    /// Distinct from [`WireError::Timeout`] (a single blocked read):
    /// this is "the connection looked idle for so long, across probe
    /// attempts, that the peer must be partitioned or wedged".
    HeartbeatLost { idle_ms: u64, deadline_ms: u64 },
    /// Connection closed cleanly *between* frames (EOF at a frame
    /// boundary). An orderly shutdown for a serve loop; an error (the
    /// peer is gone) for a caller awaiting a response.
    CleanClose,
    /// Handshake token digests differ (`--net-token`): the peer is
    /// live and speaks the protocol, but is not part of this
    /// deployment. Raised by either side of the Hello/HelloAck
    /// exchange before any job or state flows.
    AuthRejected,
    /// Body parsed structurally but a field was invalid
    /// (codec layer: bad enum byte, short body, trailing bytes...).
    Malformed { what: String },
    /// Any other transport-level I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::BadMagic { got } => write!(
                f,
                "bad frame magic {got:02x?} (expected \"FP8W\") — peer \
                 is not a fedfp8 wire endpoint"
            ),
            WireError::VersionMismatch { got, want } => write!(
                f,
                "wire version mismatch: peer sent v{got}, this build \
                 speaks v{want}"
            ),
            WireError::UnknownKind { got } => {
                write!(f, "unknown frame kind {got}")
            }
            WireError::Truncated { context } => write!(
                f,
                "truncated frame: connection closed mid-{context}"
            ),
            WireError::ChecksumMismatch { got, want } => write!(
                f,
                "frame checksum mismatch (body crc32 {got:#010x}, \
                 envelope says {want:#010x}) — corrupted stream"
            ),
            WireError::Oversize { len } => write!(
                f,
                "frame body of {len} bytes exceeds the \
                 {MAX_BODY_BYTES}-byte limit"
            ),
            WireError::Timeout => {
                write!(f, "timed out waiting for the peer")
            }
            WireError::HeartbeatLost { idle_ms, deadline_ms } => write!(
                f,
                "heartbeat lost: timed out waiting for the peer (no \
                 frames for {idle_ms} ms, idle deadline {deadline_ms} \
                 ms) — silent partition or wedged process"
            ),
            WireError::CleanClose => {
                write!(f, "connection closed by the peer")
            }
            WireError::AuthRejected => write!(
                f,
                "handshake auth rejected: --net-token digest \
                 mismatch (launch both sides with the identical \
                 secret, or neither)"
            ),
            WireError::Malformed { what } => {
                write!(f, "malformed message body: {what}")
            }
            WireError::Io(e) => write!(f, "wire i/o error: {e}"),
        }
    }
}

impl std::error::Error for WireError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            WireError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl WireError {
    /// True when the peer simply closed the connection at a frame
    /// boundary — the orderly end of a serve loop.
    pub fn is_clean_close(&self) -> bool {
        matches!(self, WireError::CleanClose)
    }
}

fn map_io(e: std::io::Error) -> WireError {
    match e.kind() {
        // read/write deadline on a socket with SO_RCVTIMEO/SNDTIMEO:
        // unix reports WouldBlock, windows TimedOut
        ErrorKind::WouldBlock | ErrorKind::TimedOut => WireError::Timeout,
        _ => WireError::Io(e),
    }
}

/// IEEE CRC-32 (reflected, poly 0xEDB88320) — matches `zlib.crc32`
/// in the Python fixture mirror.
pub fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 {
                    0xEDB8_8320 ^ (c >> 1)
                } else {
                    c >> 1
                };
            }
            *e = c;
        }
        t
    });
    let mut c = !0u32;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// Build the 16-byte envelope for `body`, rejecting oversize bodies —
/// the single construction point both write paths share.
fn encode_header(
    kind: FrameKind,
    body: &[u8],
) -> Result<[u8; FRAME_HEADER_BYTES as usize], WireError> {
    // symmetric with the read side: never put an un-receivable (or,
    // past u32, length-wrapping) frame on the wire
    if body.len() as u64 > MAX_BODY_BYTES as u64 {
        return Err(WireError::Oversize {
            len: body.len() as u64,
        });
    }
    let mut hdr = [0u8; FRAME_HEADER_BYTES as usize];
    hdr[0..4].copy_from_slice(&MAGIC);
    hdr[4..6].copy_from_slice(&WIRE_VERSION.to_le_bytes());
    hdr[6] = kind as u8;
    hdr[7] = 0;
    hdr[8..12].copy_from_slice(&(body.len() as u32).to_le_bytes());
    hdr[12..16].copy_from_slice(&crc32(body).to_le_bytes());
    Ok(hdr)
}

/// Write one frame; returns the total bytes put on the wire
/// (envelope + body) so transports can account exactly.
pub fn write_frame(
    w: &mut impl Write,
    kind: FrameKind,
    body: &[u8],
) -> Result<u64, WireError> {
    let hdr = encode_header(kind, body)?;
    w.write_all(&hdr).map_err(map_io)?;
    w.write_all(body).map_err(map_io)?;
    w.flush().map_err(map_io)?;
    Ok(FRAME_HEADER_BYTES + body.len() as u64)
}

/// [`write_frame`] for **non-blocking** writers: `WouldBlock` is
/// retried with a short backoff until `deadline`, partial writes
/// resume where they left off.
///
/// On deadline the typed [`WireError::Timeout`] surfaces with the
/// frame possibly half-written — the caller MUST treat that as fatal
/// for the connection (a mid-frame abandon desynchronizes the
/// stream), exactly like any other write error.
pub fn write_frame_nb(
    w: &mut impl Write,
    kind: FrameKind,
    body: &[u8],
    deadline: Instant,
) -> Result<u64, WireError> {
    let hdr = encode_header(kind, body)?;
    write_all_nb(w, &hdr, deadline)?;
    write_all_nb(w, body, deadline)?;
    match w.flush() {
        Ok(()) => {}
        // a TCP stream's flush is a no-op; tolerate WouldBlock from
        // exotic writers rather than failing a fully-written frame
        Err(e) if e.kind() == ErrorKind::WouldBlock => {}
        Err(e) => return Err(map_io(e)),
    }
    Ok(FRAME_HEADER_BYTES + body.len() as u64)
}

/// Push `buf` through a non-blocking writer, advancing over partial
/// writes, until done or `deadline`.
fn write_all_nb(
    w: &mut impl Write,
    buf: &[u8],
    deadline: Instant,
) -> Result<(), WireError> {
    let mut sent = 0usize;
    while sent < buf.len() {
        match w.write(&buf[sent..]) {
            Ok(0) => {
                return Err(WireError::Io(std::io::Error::new(
                    ErrorKind::WriteZero,
                    "peer socket accepted zero bytes",
                )));
            }
            Ok(n) => sent += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut =>
            {
                if Instant::now() >= deadline {
                    return Err(WireError::Timeout);
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(e) => return Err(map_io(e)),
        }
    }
    Ok(())
}

/// Fill `buf` completely; `at_boundary` selects the EOF flavour
/// (CleanClose for byte 0 of the envelope, Truncated otherwise).
fn read_full(
    r: &mut impl Read,
    buf: &mut [u8],
    at_boundary: bool,
    context: &'static str,
) -> Result<(), WireError> {
    let mut filled = 0usize;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                return Err(if at_boundary && filled == 0 {
                    WireError::CleanClose
                } else {
                    WireError::Truncated { context }
                });
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(map_io(e)),
        }
    }
    Ok(())
}

/// Read one complete frame, validating magic, version, kind, size
/// bound and checksum. Never blocks past the stream's read timeout.
pub fn read_frame(r: &mut impl Read) -> Result<Frame, WireError> {
    let mut hdr = [0u8; FRAME_HEADER_BYTES as usize];
    read_full(r, &mut hdr, true, "frame header")?;
    if hdr[0..4] != MAGIC {
        return Err(WireError::BadMagic {
            got: [hdr[0], hdr[1], hdr[2], hdr[3]],
        });
    }
    let version = u16::from_le_bytes([hdr[4], hdr[5]]);
    if version != WIRE_VERSION {
        return Err(WireError::VersionMismatch {
            got: version,
            want: WIRE_VERSION,
        });
    }
    let kind = FrameKind::from_u8(hdr[6])?;
    let len = u32::from_le_bytes([hdr[8], hdr[9], hdr[10], hdr[11]]);
    if len > MAX_BODY_BYTES {
        return Err(WireError::Oversize { len: len as u64 });
    }
    let want = u32::from_le_bytes([hdr[12], hdr[13], hdr[14], hdr[15]]);
    let mut body = vec![0u8; len as usize];
    read_full(r, &mut body, false, "frame body")?;
    let got = crc32(&body);
    if got != want {
        return Err(WireError::ChecksumMismatch { got, want });
    }
    Ok(Frame { kind, body })
}

/// Resumable frame reader for streams with a short read timeout.
///
/// [`read_frame`] treats a read timeout as fatal, which is right for a
/// one-shot blocking exchange but wrong for the v2 long-lived reader
/// loops: they wake on a short tick to run the heartbeat state machine,
/// and a tick that fires in the *middle* of a frame (header half-read,
/// body trickling in) must not throw away the bytes already consumed —
/// that would desynchronize the stream. `FrameReader` keeps the
/// partial frame across [`FrameReader::poll`] calls:
///
/// * `Ok(Some(frame))` — a complete, validated frame;
/// * `Ok(None)` — the read deadline fired; call again later (the
///   partial state, if any, is retained);
/// * `Err(_)` — the same typed failures as [`read_frame`].
///
/// Liveness is the *caller's* job: [`FrameReader::bytes_consumed`] is a
/// monotone counter of stream bytes absorbed, so the caller can tell
/// "idle tick" from "slow but alive peer" and apply its own idle
/// deadline.
#[derive(Debug, Default)]
pub struct FrameReader {
    hdr: [u8; FRAME_HEADER_BYTES as usize],
    hdr_have: usize,
    /// `Some` once the header has been validated; holds the kind and
    /// the expected body checksum while the body streams in.
    in_body: Option<(FrameKind, u32)>,
    body: Vec<u8>,
    body_have: usize,
    consumed: u64,
}

impl FrameReader {
    pub fn new() -> FrameReader {
        FrameReader::default()
    }

    /// Total stream bytes absorbed so far (monotone; includes partial
    /// frames) — the caller's liveness signal.
    pub fn bytes_consumed(&self) -> u64 {
        self.consumed
    }

    /// True when a frame is partially read (a timeout now means "slow
    /// peer", not "idle connection").
    pub fn mid_frame(&self) -> bool {
        self.hdr_have > 0 || self.in_body.is_some()
    }

    /// Fill `buf[*have..]` from `r`. Returns false when the read
    /// deadline fired (partial progress retained).
    fn fill(
        &mut self,
        r: &mut impl Read,
        at_boundary: bool,
        context: &'static str,
    ) -> Result<bool, WireError> {
        // split-borrow helper: operate on header or body via indices
        loop {
            let (done, dst_is_hdr) = match self.in_body {
                None => (self.hdr_have >= self.hdr.len(), true),
                Some(_) => (self.body_have >= self.body.len(), false),
            };
            if done {
                return Ok(true);
            }
            let res = if dst_is_hdr {
                r.read(&mut self.hdr[self.hdr_have..])
            } else {
                r.read(&mut self.body[self.body_have..])
            };
            match res {
                Ok(0) => {
                    return Err(if at_boundary
                        && dst_is_hdr
                        && self.hdr_have == 0
                    {
                        WireError::CleanClose
                    } else {
                        WireError::Truncated { context }
                    });
                }
                Ok(n) => {
                    if dst_is_hdr {
                        self.hdr_have += n;
                    } else {
                        self.body_have += n;
                    }
                    self.consumed += n as u64;
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e)
                    if matches!(
                        e.kind(),
                        ErrorKind::WouldBlock | ErrorKind::TimedOut
                    ) =>
                {
                    return Ok(false);
                }
                Err(e) => return Err(WireError::Io(e)),
            }
        }
    }

    /// Advance the in-progress frame as far as the stream allows.
    pub fn poll(
        &mut self,
        r: &mut impl Read,
    ) -> Result<Option<Frame>, WireError> {
        if self.in_body.is_none() {
            if !self.fill(r, true, "frame header")? {
                return Ok(None);
            }
            // full header: validate exactly like `read_frame`
            let hdr = &self.hdr;
            if hdr[0..4] != MAGIC {
                return Err(WireError::BadMagic {
                    got: [hdr[0], hdr[1], hdr[2], hdr[3]],
                });
            }
            let version = u16::from_le_bytes([hdr[4], hdr[5]]);
            if version != WIRE_VERSION {
                return Err(WireError::VersionMismatch {
                    got: version,
                    want: WIRE_VERSION,
                });
            }
            let kind = FrameKind::from_u8(hdr[6])?;
            let len =
                u32::from_le_bytes([hdr[8], hdr[9], hdr[10], hdr[11]]);
            if len > MAX_BODY_BYTES {
                return Err(WireError::Oversize { len: len as u64 });
            }
            let want =
                u32::from_le_bytes([hdr[12], hdr[13], hdr[14], hdr[15]]);
            self.body.clear();
            self.body.resize(len as usize, 0);
            self.body_have = 0;
            self.in_body = Some((kind, want));
        }
        if !self.fill(r, false, "frame body")? {
            return Ok(None);
        }
        let (kind, want) = self.in_body.take().unwrap();
        self.hdr_have = 0;
        let body = std::mem::take(&mut self.body);
        let got = crc32(&body);
        if got != want {
            return Err(WireError::ChecksumMismatch { got, want });
        }
        Ok(Some(Frame { kind, body }))
    }
}

/// What a reader loop's idle tick should do next, per [`Liveness`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TickAction {
    /// Nothing due yet.
    Idle,
    /// The probe interval elapsed with no traffic: send a Heartbeat.
    Probe,
    /// The idle deadline expired: declare the peer dead.
    Dead { idle_ms: u64, deadline_ms: u64 },
}

/// The probe/deadline liveness state machine both long-lived reader
/// loops (server side in `net::socket`, worker side in `net::worker`)
/// run on their idle ticks — one implementation, so the two sides
/// cannot diverge.
///
/// Rules:
/// * any stream progress (reported via [`Liveness::on_progress`],
///   even a partial frame) refreshes the peer's liveness;
/// * after `heartbeat` of silence, probe (at most once per interval);
/// * after `deadline` of silence — when the caller says the deadline
///   applies — the peer is dead ([`TickAction::Dead`], which callers
///   turn into the typed [`WireError::HeartbeatLost`]).
///
/// A zero `heartbeat` disables probing; a zero `deadline` disables
/// the death verdict.
#[derive(Debug)]
pub struct Liveness {
    heartbeat: Duration,
    deadline: Duration,
    last_rx: Instant,
    last_probe: Instant,
    seen: u64,
}

impl Liveness {
    /// Default probe interval for a given idle/death `deadline`:
    /// `min(1 s, deadline / 4)` — a peer is always probed (and has
    /// time to ack) well before the deadline can fire, for *any*
    /// deadline, instead of the old fixed 1 s default that made every
    /// deadline ≤ 1 s an invariant violation at startup. A zero
    /// deadline yields a zero interval (probing disabled).
    pub fn default_heartbeat(deadline: Duration) -> Duration {
        (deadline / 4).min(Duration::from_millis(1000))
    }

    pub fn new(heartbeat: Duration, deadline: Duration) -> Liveness {
        Liveness {
            heartbeat,
            deadline,
            last_rx: Instant::now(),
            last_probe: Instant::now(),
            seen: 0,
        }
    }

    /// The socket read timeout that keeps this machine responsive:
    /// the smallest non-zero interval, capped at 250 ms so shutdown
    /// and join latency stay bounded.
    pub fn tick(&self) -> Duration {
        [self.heartbeat, self.deadline, Duration::from_millis(250)]
            .into_iter()
            .filter(|d| !d.is_zero())
            .min()
            .unwrap_or(Duration::from_millis(250))
    }

    /// Report the reader's monotone consumed-byte counter
    /// ([`FrameReader::bytes_consumed`]); any growth counts as proof
    /// of life.
    pub fn on_progress(&mut self, consumed: u64) {
        if consumed != self.seen {
            self.seen = consumed;
            self.last_rx = Instant::now();
        }
    }

    /// Decide the idle-tick action. `deadline_applies` lets callers
    /// scope the death verdict (e.g. the server kills a silent idle
    /// connection only when probing is on — without probes a silent
    /// idle peer is indistinguishable from a healthy one).
    pub fn on_idle(&mut self, deadline_applies: bool) -> TickAction {
        let idle = self.last_rx.elapsed();
        if deadline_applies
            && !self.deadline.is_zero()
            && idle >= self.deadline
        {
            return TickAction::Dead {
                idle_ms: idle.as_millis() as u64,
                deadline_ms: self.deadline.as_millis() as u64,
            };
        }
        if !self.heartbeat.is_zero()
            && idle >= self.heartbeat
            && self.last_probe.elapsed() >= self.heartbeat
        {
            self.last_probe = Instant::now();
            return TickAction::Probe;
        }
        TickAction::Idle
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vector() {
        // the canonical IEEE CRC-32 check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip() {
        let mut buf = Vec::new();
        let n = write_frame(&mut buf, FrameKind::Job, b"hello body")
            .unwrap();
        assert_eq!(n, buf.len() as u64);
        assert_eq!(n, FRAME_HEADER_BYTES + 10);
        let f = read_frame(&mut &buf[..]).unwrap();
        assert_eq!(f.kind, FrameKind::Job);
        assert_eq!(f.body, b"hello body");
        assert_eq!(f.total_bytes(), n);
    }

    #[test]
    fn two_frames_stream() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Hello, b"a").unwrap();
        write_frame(&mut buf, FrameKind::Shutdown, b"").unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap().kind, FrameKind::Hello);
        assert_eq!(read_frame(&mut r).unwrap().kind, FrameKind::Shutdown);
        // and then a clean close
        let err = read_frame(&mut r).unwrap_err();
        assert!(err.is_clean_close(), "{err}");
    }

    #[test]
    fn truncation_is_typed() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Outcome, b"0123456789").unwrap();
        // mid-body cut
        let cut = &buf[..buf.len() - 3];
        let err = read_frame(&mut &cut[..]).unwrap_err();
        assert!(
            matches!(err, WireError::Truncated { .. }),
            "{err}"
        );
        assert!(err.to_string().contains("truncated"));
        // mid-header cut is truncation too, not a clean close
        let cut = &buf[..7];
        let err = read_frame(&mut &cut[..]).unwrap_err();
        assert!(matches!(err, WireError::Truncated { .. }), "{err}");
    }

    #[test]
    fn bad_magic_is_typed() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Job, b"x").unwrap();
        buf[0] = b'N';
        let err = read_frame(&mut &buf[..]).unwrap_err();
        assert!(matches!(err, WireError::BadMagic { .. }), "{err}");
        assert!(err.to_string().contains("magic"));
    }

    #[test]
    fn version_mismatch_is_typed() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Job, b"x").unwrap();
        buf[4..6].copy_from_slice(&99u16.to_le_bytes());
        let err = read_frame(&mut &buf[..]).unwrap_err();
        match err {
            WireError::VersionMismatch { got, want } => {
                assert_eq!((got, want), (99, WIRE_VERSION));
            }
            e => panic!("unexpected: {e}"),
        }
    }

    #[test]
    fn checksum_mismatch_is_typed() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Job, b"payload").unwrap();
        let last = buf.len() - 1;
        buf[last] ^= 0x40; // corrupt one body byte
        let err = read_frame(&mut &buf[..]).unwrap_err();
        assert!(
            matches!(err, WireError::ChecksumMismatch { .. }),
            "{err}"
        );
        assert!(err.to_string().contains("checksum"));
    }

    #[test]
    fn oversize_is_typed() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Job, b"x").unwrap();
        buf[8..12]
            .copy_from_slice(&(MAX_BODY_BYTES + 1).to_le_bytes());
        let err = read_frame(&mut &buf[..]).unwrap_err();
        assert!(matches!(err, WireError::Oversize { .. }), "{err}");
    }

    #[test]
    fn unknown_kind_is_typed() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Job, b"x").unwrap();
        buf[6] = 77;
        let err = read_frame(&mut &buf[..]).unwrap_err();
        assert!(matches!(err, WireError::UnknownKind { got: 77 }), "{err}");
    }

    #[test]
    fn heartbeat_kinds_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Heartbeat, &7u64.to_le_bytes())
            .unwrap();
        write_frame(&mut buf, FrameKind::HeartbeatAck, &7u64.to_le_bytes())
            .unwrap();
        let mut r = &buf[..];
        assert_eq!(
            read_frame(&mut r).unwrap().kind,
            FrameKind::Heartbeat
        );
        assert_eq!(
            read_frame(&mut r).unwrap().kind,
            FrameKind::HeartbeatAck
        );
    }

    /// Reader that yields `chunks` one at a time, interleaving a
    /// WouldBlock "timeout" before each — the worst-case trickle a
    /// short read deadline can produce.
    struct Trickle {
        chunks: Vec<Vec<u8>>,
        next: usize,
        blocked: bool,
    }

    impl Read for Trickle {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            if !self.blocked {
                self.blocked = true;
                return Err(std::io::Error::from(ErrorKind::WouldBlock));
            }
            self.blocked = false;
            match self.chunks.get(self.next) {
                None => Ok(0),
                Some(c) => {
                    let n = c.len().min(buf.len());
                    buf[..n].copy_from_slice(&c[..n]);
                    if n == c.len() {
                        self.next += 1;
                    } else {
                        self.chunks[self.next].drain(..n);
                    }
                    Ok(n)
                }
            }
        }
    }

    #[test]
    fn frame_reader_survives_mid_frame_timeouts() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Outcome, b"multiplexed body")
            .unwrap();
        write_frame(&mut buf, FrameKind::Heartbeat, &1u64.to_le_bytes())
            .unwrap();
        // deliver the stream in 3-byte fragments, a timeout before each
        let mut src = Trickle {
            chunks: buf.chunks(3).map(|c| c.to_vec()).collect(),
            next: 0,
            blocked: false,
        };
        let mut fr = FrameReader::new();
        let mut frames = Vec::new();
        let mut ticks = 0usize;
        while frames.len() < 2 {
            match fr.poll(&mut src).unwrap() {
                Some(f) => frames.push(f),
                None => ticks += 1,
            }
            assert!(ticks < 10_000, "reader made no progress");
        }
        assert!(ticks > 0, "trickle source never timed out");
        assert_eq!(frames[0].kind, FrameKind::Outcome);
        assert_eq!(frames[0].body, b"multiplexed body");
        assert_eq!(frames[1].kind, FrameKind::Heartbeat);
        assert_eq!(
            fr.bytes_consumed(),
            buf.len() as u64,
            "consumed-byte counter must equal the stream length"
        );
        assert!(!fr.mid_frame());
        // and the stream end is a clean close at a boundary
        let err = loop {
            match fr.poll(&mut src) {
                Ok(Some(f)) => panic!("unexpected frame {:?}", f.kind),
                Ok(None) => continue,
                Err(e) => break e,
            }
        };
        assert!(err.is_clean_close(), "{err}");
    }

    #[test]
    fn liveness_state_machine_probes_then_dies() {
        let hb = Duration::from_millis(20);
        let dl = Duration::from_millis(60);
        let mut l = Liveness::new(hb, dl);
        assert_eq!(l.tick(), hb);
        assert_eq!(l.on_idle(true), TickAction::Idle);
        std::thread::sleep(hb + Duration::from_millis(5));
        // probe due, and only once per interval
        assert_eq!(l.on_idle(true), TickAction::Probe);
        assert_eq!(l.on_idle(true), TickAction::Idle);
        // progress refreshes liveness
        l.on_progress(10);
        assert_eq!(l.on_idle(true), TickAction::Idle);
        std::thread::sleep(dl + Duration::from_millis(10));
        match l.on_idle(true) {
            TickAction::Dead { idle_ms, deadline_ms } => {
                assert!(idle_ms >= deadline_ms);
                assert_eq!(deadline_ms, 60);
            }
            a => panic!("expected Dead, got {a:?}"),
        }
        // ...but not when the caller says the deadline doesn't apply
        assert!(!matches!(l.on_idle(false), TickAction::Dead { .. }));
    }

    #[test]
    fn liveness_zero_knobs_disable_probe_and_death() {
        let mut l = Liveness::new(Duration::ZERO, Duration::ZERO);
        assert_eq!(l.tick(), Duration::from_millis(250));
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(l.on_idle(true), TickAction::Idle);
    }

    /// A writer that WouldBlocks between every accepted byte — the
    /// worst-case non-blocking socket.
    struct Choppy {
        out: Vec<u8>,
        ready: bool,
    }

    impl Write for Choppy {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            if self.ready {
                self.ready = false;
                self.out.push(buf[0]);
                Ok(1)
            } else {
                self.ready = true;
                Err(ErrorKind::WouldBlock.into())
            }
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn write_frame_nb_resumes_across_would_block() {
        let mut blocking = Vec::new();
        let n = write_frame(&mut blocking, FrameKind::Job, b"nb body")
            .unwrap();
        let mut choppy = Choppy { out: Vec::new(), ready: false };
        let deadline = Instant::now() + Duration::from_secs(5);
        let m =
            write_frame_nb(&mut choppy, FrameKind::Job, b"nb body", deadline)
                .unwrap();
        assert_eq!(n, m);
        // byte-identical to the blocking writer: partial writes never
        // corrupt or reorder the envelope
        assert_eq!(choppy.out, blocking);
    }

    /// A writer that never accepts anything.
    struct Wedged;

    impl Write for Wedged {
        fn write(&mut self, _buf: &[u8]) -> std::io::Result<usize> {
            Err(ErrorKind::WouldBlock.into())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn write_frame_nb_times_out_on_a_wedged_writer() {
        let deadline = Instant::now() + Duration::from_millis(20);
        let err =
            write_frame_nb(&mut Wedged, FrameKind::Job, b"x", deadline)
                .unwrap_err();
        assert!(matches!(err, WireError::Timeout), "{err}");
    }

    #[test]
    fn default_heartbeat_derivation() {
        // quarter of the deadline, capped at 1 s, zero stays zero
        let hb = Liveness::default_heartbeat;
        assert_eq!(hb(Duration::from_millis(800)), Duration::from_millis(200));
        assert_eq!(hb(Duration::from_millis(1000)), Duration::from_millis(250));
        assert_eq!(hb(Duration::from_secs(30)), Duration::from_millis(1000));
        assert_eq!(hb(Duration::ZERO), Duration::ZERO);
        // the probe-before-deadline invariant holds for every
        // non-zero deadline
        for ms in [1u64, 2, 3, 999, 1000, 1001, 4000, 120_000] {
            let d = Duration::from_millis(ms);
            assert!(hb(d) < d, "derived heartbeat not below deadline {ms}ms");
        }
    }

    #[test]
    fn frame_reader_types_mid_frame_truncation() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Job, b"0123456789").unwrap();
        buf.truncate(buf.len() - 4);
        let mut src = Trickle {
            chunks: buf.chunks(5).map(|c| c.to_vec()).collect(),
            next: 0,
            blocked: false,
        };
        let mut fr = FrameReader::new();
        let err = loop {
            match fr.poll(&mut src) {
                Ok(Some(_)) => panic!("frame should be truncated"),
                Ok(None) => continue,
                Err(e) => break e,
            }
        };
        assert!(matches!(err, WireError::Truncated { .. }), "{err}");
    }
}
