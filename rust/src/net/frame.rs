//! Wire frames — the length-prefixed, checksummed envelope every
//! fedfp8 network message travels in.
//!
//! Layout (all little-endian; independently mirrored by
//! `tools/gen_wire_fixture.py`, pinned by `tests/golden_wire.rs`):
//!
//! ```text
//! 0   magic     4 B  = b"FP8W"
//! 4   version   u16  = WIRE_VERSION
//! 6   kind      u8   (Hello/HelloAck/Job/Outcome/Shutdown)
//! 7   flags     u8   = 0 (reserved)
//! 8   body_len  u32
//! 12  crc32     u32  (IEEE CRC-32 of the body)
//! 16  body ...
//! ```
//!
//! The envelope is deliberately *per-frame*, not per-connection:
//! every message re-asserts magic + version + checksum, so a
//! desynchronized or corrupted stream fails on the very next frame
//! with a typed [`WireError`] instead of feeding garbage lengths into
//! the codec. Body size is capped ([`MAX_BODY_BYTES`]) so a corrupt
//! length field cannot trigger a multi-gigabyte allocation.
//!
//! Error taxonomy: every failure mode a peer can induce — wrong
//! magic, version skew, truncation, checksum mismatch, read timeout,
//! clean close — is a distinct [`WireError`] variant, so callers (and
//! the fault-injection suite in `tests/net_transport.rs`) can tell
//! "remote speaks a different protocol" from "remote died mid-frame"
//! from "remote is gone".

use std::fmt;
use std::io::{ErrorKind, Read, Write};
use std::sync::OnceLock;

/// Frame magic: identifies a fedfp8 wire peer.
pub const MAGIC: [u8; 4] = *b"FP8W";

/// Wire protocol version. Bump on ANY change to the frame envelope or
/// to a message body layout in `net::codec`, and regenerate the golden
/// fixture (`tools/gen_wire_fixture.py`).
pub const WIRE_VERSION: u16 = 1;

/// Envelope size preceding every body.
pub const FRAME_HEADER_BYTES: u64 = 16;

/// Upper bound on a frame body — far above any model this repo ships
/// (a 100M-param FP8 payload is ~100 MB) but small enough that a
/// corrupted length field cannot OOM the process.
pub const MAX_BODY_BYTES: u32 = 1 << 30;

/// What a frame carries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// Worker -> server: config fingerprint + model identity.
    Hello = 1,
    /// Server -> worker: handshake accepted.
    HelloAck = 2,
    /// Server -> worker: one client's work order.
    Job = 3,
    /// Worker -> server: one client's result.
    Outcome = 4,
    /// Server -> worker: drain and exit cleanly.
    Shutdown = 5,
}

impl FrameKind {
    fn from_u8(v: u8) -> Result<FrameKind, WireError> {
        Ok(match v {
            1 => FrameKind::Hello,
            2 => FrameKind::HelloAck,
            3 => FrameKind::Job,
            4 => FrameKind::Outcome,
            5 => FrameKind::Shutdown,
            got => return Err(WireError::UnknownKind { got }),
        })
    }
}

/// A received frame: kind + raw body (decoded by `net::codec`).
#[derive(Clone, Debug)]
pub struct Frame {
    pub kind: FrameKind,
    pub body: Vec<u8>,
}

impl Frame {
    /// Total bytes this frame occupied on the wire.
    pub fn total_bytes(&self) -> u64 {
        FRAME_HEADER_BYTES + self.body.len() as u64
    }
}

/// Typed failure modes of the wire layer.
#[derive(Debug)]
pub enum WireError {
    /// Peer is not speaking the fedfp8 protocol at all.
    BadMagic { got: [u8; 4] },
    /// Peer speaks the protocol at an incompatible version.
    VersionMismatch { got: u16, want: u16 },
    /// Envelope carried an unassigned frame-kind byte.
    UnknownKind { got: u8 },
    /// Connection closed in the middle of a frame.
    Truncated { context: &'static str },
    /// Body bytes do not match the envelope checksum.
    ChecksumMismatch { got: u32, want: u32 },
    /// A body larger than [`MAX_BODY_BYTES`] (declared by a received
    /// envelope, or about to be sent).
    Oversize { len: u64 },
    /// Read (or write) deadline expired — the peer went silent.
    Timeout,
    /// Connection closed cleanly *between* frames (EOF at a frame
    /// boundary). An orderly shutdown for a serve loop; an error (the
    /// peer is gone) for a caller awaiting a response.
    CleanClose,
    /// Body parsed structurally but a field was invalid
    /// (codec layer: bad enum byte, short body, trailing bytes...).
    Malformed { what: String },
    /// Any other transport-level I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::BadMagic { got } => write!(
                f,
                "bad frame magic {got:02x?} (expected \"FP8W\") — peer \
                 is not a fedfp8 wire endpoint"
            ),
            WireError::VersionMismatch { got, want } => write!(
                f,
                "wire version mismatch: peer sent v{got}, this build \
                 speaks v{want}"
            ),
            WireError::UnknownKind { got } => {
                write!(f, "unknown frame kind {got}")
            }
            WireError::Truncated { context } => write!(
                f,
                "truncated frame: connection closed mid-{context}"
            ),
            WireError::ChecksumMismatch { got, want } => write!(
                f,
                "frame checksum mismatch (body crc32 {got:#010x}, \
                 envelope says {want:#010x}) — corrupted stream"
            ),
            WireError::Oversize { len } => write!(
                f,
                "frame body of {len} bytes exceeds the \
                 {MAX_BODY_BYTES}-byte limit"
            ),
            WireError::Timeout => {
                write!(f, "timed out waiting for the peer")
            }
            WireError::CleanClose => {
                write!(f, "connection closed by the peer")
            }
            WireError::Malformed { what } => {
                write!(f, "malformed message body: {what}")
            }
            WireError::Io(e) => write!(f, "wire i/o error: {e}"),
        }
    }
}

impl std::error::Error for WireError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            WireError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl WireError {
    /// True when the peer simply closed the connection at a frame
    /// boundary — the orderly end of a serve loop.
    pub fn is_clean_close(&self) -> bool {
        matches!(self, WireError::CleanClose)
    }
}

fn map_io(e: std::io::Error) -> WireError {
    match e.kind() {
        // read/write deadline on a socket with SO_RCVTIMEO/SNDTIMEO:
        // unix reports WouldBlock, windows TimedOut
        ErrorKind::WouldBlock | ErrorKind::TimedOut => WireError::Timeout,
        _ => WireError::Io(e),
    }
}

/// IEEE CRC-32 (reflected, poly 0xEDB88320) — matches `zlib.crc32`
/// in the Python fixture mirror.
pub fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 {
                    0xEDB8_8320 ^ (c >> 1)
                } else {
                    c >> 1
                };
            }
            *e = c;
        }
        t
    });
    let mut c = !0u32;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// Write one frame; returns the total bytes put on the wire
/// (envelope + body) so transports can account exactly.
pub fn write_frame(
    w: &mut impl Write,
    kind: FrameKind,
    body: &[u8],
) -> Result<u64, WireError> {
    // symmetric with the read side: never put an un-receivable (or,
    // past u32, length-wrapping) frame on the wire
    if body.len() as u64 > MAX_BODY_BYTES as u64 {
        return Err(WireError::Oversize {
            len: body.len() as u64,
        });
    }
    let mut hdr = [0u8; FRAME_HEADER_BYTES as usize];
    hdr[0..4].copy_from_slice(&MAGIC);
    hdr[4..6].copy_from_slice(&WIRE_VERSION.to_le_bytes());
    hdr[6] = kind as u8;
    hdr[7] = 0;
    hdr[8..12].copy_from_slice(&(body.len() as u32).to_le_bytes());
    hdr[12..16].copy_from_slice(&crc32(body).to_le_bytes());
    w.write_all(&hdr).map_err(map_io)?;
    w.write_all(body).map_err(map_io)?;
    w.flush().map_err(map_io)?;
    Ok(FRAME_HEADER_BYTES + body.len() as u64)
}

/// Fill `buf` completely; `at_boundary` selects the EOF flavour
/// (CleanClose for byte 0 of the envelope, Truncated otherwise).
fn read_full(
    r: &mut impl Read,
    buf: &mut [u8],
    at_boundary: bool,
    context: &'static str,
) -> Result<(), WireError> {
    let mut filled = 0usize;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                return Err(if at_boundary && filled == 0 {
                    WireError::CleanClose
                } else {
                    WireError::Truncated { context }
                });
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(map_io(e)),
        }
    }
    Ok(())
}

/// Read one complete frame, validating magic, version, kind, size
/// bound and checksum. Never blocks past the stream's read timeout.
pub fn read_frame(r: &mut impl Read) -> Result<Frame, WireError> {
    let mut hdr = [0u8; FRAME_HEADER_BYTES as usize];
    read_full(r, &mut hdr, true, "frame header")?;
    if hdr[0..4] != MAGIC {
        return Err(WireError::BadMagic {
            got: [hdr[0], hdr[1], hdr[2], hdr[3]],
        });
    }
    let version = u16::from_le_bytes([hdr[4], hdr[5]]);
    if version != WIRE_VERSION {
        return Err(WireError::VersionMismatch {
            got: version,
            want: WIRE_VERSION,
        });
    }
    let kind = FrameKind::from_u8(hdr[6])?;
    let len = u32::from_le_bytes([hdr[8], hdr[9], hdr[10], hdr[11]]);
    if len > MAX_BODY_BYTES {
        return Err(WireError::Oversize { len: len as u64 });
    }
    let want = u32::from_le_bytes([hdr[12], hdr[13], hdr[14], hdr[15]]);
    let mut body = vec![0u8; len as usize];
    read_full(r, &mut body, false, "frame body")?;
    let got = crc32(&body);
    if got != want {
        return Err(WireError::ChecksumMismatch { got, want });
    }
    Ok(Frame { kind, body })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vector() {
        // the canonical IEEE CRC-32 check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip() {
        let mut buf = Vec::new();
        let n = write_frame(&mut buf, FrameKind::Job, b"hello body")
            .unwrap();
        assert_eq!(n, buf.len() as u64);
        assert_eq!(n, FRAME_HEADER_BYTES + 10);
        let f = read_frame(&mut &buf[..]).unwrap();
        assert_eq!(f.kind, FrameKind::Job);
        assert_eq!(f.body, b"hello body");
        assert_eq!(f.total_bytes(), n);
    }

    #[test]
    fn two_frames_stream() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Hello, b"a").unwrap();
        write_frame(&mut buf, FrameKind::Shutdown, b"").unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap().kind, FrameKind::Hello);
        assert_eq!(read_frame(&mut r).unwrap().kind, FrameKind::Shutdown);
        // and then a clean close
        let err = read_frame(&mut r).unwrap_err();
        assert!(err.is_clean_close(), "{err}");
    }

    #[test]
    fn truncation_is_typed() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Outcome, b"0123456789").unwrap();
        // mid-body cut
        let cut = &buf[..buf.len() - 3];
        let err = read_frame(&mut &cut[..]).unwrap_err();
        assert!(
            matches!(err, WireError::Truncated { .. }),
            "{err}"
        );
        assert!(err.to_string().contains("truncated"));
        // mid-header cut is truncation too, not a clean close
        let cut = &buf[..7];
        let err = read_frame(&mut &cut[..]).unwrap_err();
        assert!(matches!(err, WireError::Truncated { .. }), "{err}");
    }

    #[test]
    fn bad_magic_is_typed() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Job, b"x").unwrap();
        buf[0] = b'N';
        let err = read_frame(&mut &buf[..]).unwrap_err();
        assert!(matches!(err, WireError::BadMagic { .. }), "{err}");
        assert!(err.to_string().contains("magic"));
    }

    #[test]
    fn version_mismatch_is_typed() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Job, b"x").unwrap();
        buf[4..6].copy_from_slice(&99u16.to_le_bytes());
        let err = read_frame(&mut &buf[..]).unwrap_err();
        match err {
            WireError::VersionMismatch { got, want } => {
                assert_eq!((got, want), (99, WIRE_VERSION));
            }
            e => panic!("unexpected: {e}"),
        }
    }

    #[test]
    fn checksum_mismatch_is_typed() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Job, b"payload").unwrap();
        let last = buf.len() - 1;
        buf[last] ^= 0x40; // corrupt one body byte
        let err = read_frame(&mut &buf[..]).unwrap_err();
        assert!(
            matches!(err, WireError::ChecksumMismatch { .. }),
            "{err}"
        );
        assert!(err.to_string().contains("checksum"));
    }

    #[test]
    fn oversize_is_typed() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Job, b"x").unwrap();
        buf[8..12]
            .copy_from_slice(&(MAX_BODY_BYTES + 1).to_le_bytes());
        let err = read_frame(&mut &buf[..]).unwrap_err();
        assert!(matches!(err, WireError::Oversize { .. }), "{err}");
    }

    #[test]
    fn unknown_kind_is_typed() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Job, b"x").unwrap();
        buf[6] = 77;
        let err = read_frame(&mut &buf[..]).unwrap_err();
        assert!(matches!(err, WireError::UnknownKind { got: 77 }), "{err}");
    }
}
