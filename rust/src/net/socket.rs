//! `SocketTransport` — the [`Transport`] implementation that runs a
//! round's clients on remote worker processes over TCP, v2:
//! multiplexed in-flight jobs, heartbeat liveness, and straggler
//! re-dispatch.
//!
//! ## Sliding window & demultiplexing
//!
//! One connection per worker, up to [`SocketCfg::inflight`] jobs in
//! flight on each. `run_cohort`'s threads call
//! [`SocketTransport::run_client`] concurrently; each call acquires a
//! *slot* on the least-loaded live connection, registers the job under
//! its `(round, client, job_id)` key, writes the Job frame, and parks
//! on a private channel. A per-connection **reader thread** decodes
//! Outcome frames — in whatever order the worker finishes them — and
//! routes each to its waiting dispatcher. Out-of-order completion is
//! invisible to the round loop: `run_cohort`'s reorder buffer still
//! feeds the streaming aggregation in cohort order, so results stay
//! bit-identical to the in-process transport.
//!
//! ## Heartbeats
//!
//! Reader threads wake on a short tick. When a connection has been
//! silent past [`SocketCfg::heartbeat`] the reader probes the worker
//! (Heartbeat frame; workers answer immediately even while computing,
//! because their reader services the socket during execution). If
//! *nothing* arrives for [`SocketCfg::io_timeout`] the connection is
//! declared dead with the typed
//! [`WireError::HeartbeatLost`] — a silent partition can stall a
//! round for at most the idle deadline, never hang it.
//!
//! ## Straggler re-dispatch
//!
//! When a connection dies (read/write error, frame corruption, or
//! heartbeat loss), every job in flight on it is failed over: the
//! waiting dispatchers receive the typed [`ConnDied`] and re-dispatch
//! to a surviving connection (the determinism contract makes
//! re-execution bit-identical; workers that already computed the job
//! answer from their outcome cache). Only when no live connections
//! remain — or the re-dispatch budget is exhausted — does the error
//! surface, naming the client, round and worker.
//!
//! A background acceptor keeps the listener open for *replacement*
//! workers: a relaunched (or reconnecting) worker handshakes exactly
//! like an initial one and joins the pool mid-run.
//!
//! Duplicate Outcome frames (network-level duplication, or a slow
//! worker answering after its job was re-dispatched) are ignored and
//! counted — delivery is effectively at-least-once, and every copy is
//! bit-identical by the determinism contract.
//!
//! [`WireError::HeartbeatLost`]: super::frame::WireError::HeartbeatLost

use std::collections::HashMap;
use std::fmt;
use std::io::ErrorKind;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

use anyhow::{anyhow, ensure, Context, Result};

use crate::coordinator::comm::Uplink;
use crate::coordinator::transport::{
    ClientJob, ClientOutcome, Transport, WorkBuffers,
};

use super::codec::{self, Hello, WireOutcome};
use super::frame::{
    self, FrameKind, FrameReader, Liveness, TickAction, WireError,
};

/// Server-side transport tuning.
#[derive(Clone, Copy, Debug)]
pub struct SocketCfg {
    /// Per-read/write socket deadline AND the silence deadline after
    /// which a non-responsive connection is declared dead.
    pub io_timeout: Duration,
    /// Probe interval: a connection silent this long gets a Heartbeat.
    /// `Duration::ZERO` disables probing (silence then only kills a
    /// connection while jobs are pending on it).
    pub heartbeat: Duration,
    /// Sliding window: max in-flight jobs per worker connection.
    pub inflight: usize,
}

impl SocketCfg {
    /// v1-flavoured defaults around a single `--net-timeout-ms` value.
    pub fn new(io_timeout: Duration) -> SocketCfg {
        SocketCfg {
            io_timeout,
            heartbeat: Duration::from_millis(1000),
            inflight: 4,
        }
    }
}

/// How many times one job is re-dispatched after connection failures
/// before the error surfaces (each attempt lands on a *different*
/// connection — the dead one leaves the pool first).
const MAX_DISPATCH_ATTEMPTS: usize = 4;

/// Typed "the connection died" failure, fanned out to every job that
/// was in flight on it. The underlying [`WireError`] is shared, so
/// the chaos suite can assert the exact fault class for every victim.
#[derive(Clone, Debug)]
pub struct ConnDied {
    pub peer: String,
    pub error: Arc<WireError>,
}

impl fmt::Display for ConnDied {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "worker {} connection failed: {}",
            self.peer, self.error
        )
    }
}

impl std::error::Error for ConnDied {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        Some(self.error.as_ref())
    }
}

type PendingKey = (u32, u32, u32); // (round, client, job_id)
type PendingTx = mpsc::Sender<Result<WireOutcome, ConnDied>>;

/// One live worker connection.
struct Conn {
    id: u64,
    peer: String,
    /// Write half (cloned stream); all frame writes serialize here.
    writer: Mutex<TcpStream>,
    /// In-flight jobs awaiting their Outcome frames.
    pending: Mutex<HashMap<PendingKey, PendingTx>>,
    in_flight: AtomicUsize,
    alive: AtomicBool,
}

struct Shared {
    cfg: SocketCfg,
    hello: Hello,
    /// Live connections (a dead one is removed before its pending
    /// jobs are failed over).
    conns: Mutex<Vec<Arc<Conn>>>,
    /// Signalled when a slot frees, a connection joins, or one dies.
    slots: Condvar,
    next_conn_id: AtomicU64,
    next_nonce: AtomicU64,
    closed: AtomicBool,
    /// Job-frame bytes written (the downlink frame bytes; re-dispatch
    /// duplicates are counted — under faults, actual >= reported).
    bytes_sent: AtomicU64,
    /// Outcome-frame bytes read.
    bytes_received: AtomicU64,
    /// Outcome frames that matched no pending job (duplicates /
    /// answers that arrived after a re-dispatch) — ignored by design.
    duplicate_outcomes: AtomicU64,
    /// Heartbeat probes sent (liveness traffic, excluded from the
    /// CommStats byte identity).
    heartbeats_sent: AtomicU64,
    /// Jobs re-dispatched to a surviving worker after a failure.
    requeues: AtomicU64,
    /// Reader/acceptor handles, joined on shutdown.
    threads: Mutex<Vec<JoinHandle<()>>>,
}

/// TCP-backed client-execution transport (server side).
pub struct SocketTransport {
    shared: Arc<Shared>,
}

/// Handshake one inbound worker stream in place: validate its Hello
/// against ours, ack it, and install the socket deadlines.
fn handshake(
    stream: &mut TcpStream,
    peer: &str,
    hello: &Hello,
    io_timeout: Duration,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(io_timeout))
        .context("setting worker read timeout")?;
    stream
        .set_write_timeout(Some(io_timeout))
        .context("setting worker write timeout")?;
    let f = frame::read_frame(stream)
        .with_context(|| format!("handshake with worker {peer}"))?;
    ensure!(
        f.kind == FrameKind::Hello,
        "worker {peer} opened with a {:?} frame, expected Hello",
        f.kind
    );
    let h = codec::decode_hello(&f.body)
        .with_context(|| format!("handshake with worker {peer}"))?;
    // auth gates everything else: an unauthenticated peer learns
    // nothing about our config beyond "the digest didn't match"
    if !codec::digest_eq(h.auth, hello.auth) {
        return Err(WireError::AuthRejected).with_context(|| {
            format!("handshake with worker {peer}")
        });
    }
    ensure!(
        h.fingerprint == hello.fingerprint,
        "config fingerprint mismatch with worker {peer}: server \
         {:#018x}, worker {:#018x} — launch every worker with the \
         identical preset and overrides",
        hello.fingerprint,
        h.fingerprint
    );
    ensure!(
        h.model == hello.model,
        "model mismatch with worker {peer}: server runs '{}', \
         worker runs '{}'",
        hello.model,
        h.model
    );
    ensure!(
        h.dim == hello.dim,
        "model dim mismatch with worker {peer}: server {}, worker {}",
        hello.dim,
        h.dim
    );
    let mut ack = Vec::new();
    codec::encode_hello_ack(hello.fingerprint, hello.auth, &mut ack);
    frame::write_frame(stream, FrameKind::HelloAck, &ack)
        .with_context(|| format!("acking worker {peer}"))?;
    Ok(())
}

/// Accept `n` initial worker connections from `listener`, handshake
/// each against `hello` (config fingerprint + model identity), and
/// build the transport. The listener then stays open on a background
/// acceptor so replacement workers can join mid-run. Initial
/// handshake failures are hard errors (a mislaunched fleet must not
/// start); replacement handshake failures are logged and dropped.
pub fn accept_workers(
    listener: TcpListener,
    n: usize,
    hello: &Hello,
    cfg: SocketCfg,
) -> Result<SocketTransport> {
    ensure!(n >= 1, "need at least one worker connection");
    ensure!(
        !cfg.io_timeout.is_zero(),
        "worker io timeout must be non-zero"
    );
    ensure!(cfg.inflight >= 1, "per-connection window must be >= 1");
    // probe-before-deadline invariant: with probing on, a peer must
    // be probed (and able to ack) before the idle deadline can fire —
    // otherwise long computations would be killed unprobed
    ensure!(
        cfg.heartbeat.is_zero() || cfg.heartbeat < cfg.io_timeout,
        "heartbeat interval ({:?}) must be shorter than the io \
         timeout ({:?}), or zero to disable probing",
        cfg.heartbeat,
        cfg.io_timeout
    );
    let mut initial = Vec::with_capacity(n);
    for _ in 0..n {
        let (mut stream, peer) = listener
            .accept()
            .context("accepting a worker connection")?;
        let peer = peer.to_string();
        handshake(&mut stream, &peer, hello, cfg.io_timeout)?;
        initial.push((stream, peer));
    }
    let shared = Arc::new(Shared {
        cfg,
        hello: hello.clone(),
        conns: Mutex::new(Vec::new()),
        slots: Condvar::new(),
        next_conn_id: AtomicU64::new(0),
        next_nonce: AtomicU64::new(0),
        closed: AtomicBool::new(false),
        bytes_sent: AtomicU64::new(0),
        bytes_received: AtomicU64::new(0),
        duplicate_outcomes: AtomicU64::new(0),
        heartbeats_sent: AtomicU64::new(0),
        requeues: AtomicU64::new(0),
        threads: Mutex::new(Vec::new()),
    });
    for (stream, peer) in initial {
        add_conn(&shared, stream, peer)?;
    }
    spawn_acceptor(&shared, listener)?;
    Ok(SocketTransport { shared })
}

/// Register a handshaken stream: clone it into reader/writer halves
/// and start its reader thread.
fn add_conn(
    shared: &Arc<Shared>,
    stream: TcpStream,
    peer: String,
) -> Result<()> {
    let reader_stream = stream
        .try_clone()
        .context("cloning a worker connection for its reader")?;
    let conn = Arc::new(Conn {
        id: shared.next_conn_id.fetch_add(1, Ordering::Relaxed),
        peer,
        writer: Mutex::new(stream),
        pending: Mutex::new(HashMap::new()),
        in_flight: AtomicUsize::new(0),
        alive: AtomicBool::new(true),
    });
    {
        let mut conns = shared.conns.lock().unwrap();
        // a replacement racing shutdown() must not be registered into
        // the already-drained pool (it would never get a Shutdown
        // frame and its reader would never be joined)
        ensure!(
            !shared.closed.load(Ordering::SeqCst),
            "transport is shut down"
        );
        conns.push(conn.clone());
    }
    shared.slots.notify_all();
    let sh = shared.clone();
    let h = thread::Builder::new()
        .name(format!("fedfp8-net-reader-{}", conn.id))
        .spawn(move || reader_loop(&sh, &conn, reader_stream))
        .context("spawning a connection reader thread")?;
    shared.threads.lock().unwrap().push(h);
    Ok(())
}

/// Background acceptor: handshake replacement workers for the life of
/// the transport (non-blocking accept + short poll, so shutdown is
/// prompt).
fn spawn_acceptor(
    shared: &Arc<Shared>,
    listener: TcpListener,
) -> Result<()> {
    listener
        .set_nonblocking(true)
        .context("switching the listener to non-blocking accepts")?;
    let sh = shared.clone();
    let h = thread::Builder::new()
        .name("fedfp8-net-acceptor".into())
        .spawn(move || {
            while !sh.closed.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((mut stream, peer)) => {
                        let peer = peer.to_string();
                        // handshake with deadlines; blocking I/O again
                        if stream.set_nonblocking(false).is_err() {
                            continue;
                        }
                        match handshake(
                            &mut stream,
                            &peer,
                            &sh.hello,
                            sh.cfg.io_timeout,
                        ) {
                            Ok(()) => {
                                eprintln!(
                                    "[server] replacement worker \
                                     {peer} joined"
                                );
                                let _ = add_conn(&sh, stream, peer);
                            }
                            Err(e) => eprintln!(
                                "[server] rejected replacement worker \
                                 {peer}: {e:#}"
                            ),
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {
                        thread::sleep(Duration::from_millis(25));
                    }
                    Err(_) => {
                        thread::sleep(Duration::from_millis(100));
                    }
                }
            }
        })
        .context("spawning the replacement acceptor thread")?;
    shared.threads.lock().unwrap().push(h);
    Ok(())
}

/// Declare a connection dead: remove it from the pool, fail over its
/// in-flight jobs, and close the socket. Idempotent.
fn kill_conn(shared: &Shared, conn: &Arc<Conn>, error: WireError) {
    if !conn.alive.swap(false, Ordering::SeqCst) {
        return;
    }
    {
        let mut conns = shared.conns.lock().unwrap();
        conns.retain(|c| c.id != conn.id);
    }
    let died = ConnDied {
        peer: conn.peer.clone(),
        error: Arc::new(error),
    };
    let victims: Vec<PendingTx> = {
        let mut pending = conn.pending.lock().unwrap();
        pending.drain().map(|(_, tx)| tx).collect()
    };
    for tx in victims {
        let _ = tx.send(Err(died.clone()));
    }
    conn.in_flight.store(0, Ordering::SeqCst);
    let _ = conn.writer.lock().unwrap().shutdown(Shutdown::Both);
    shared.slots.notify_all();
}

/// Per-connection reader: demultiplex Outcome frames to their waiting
/// dispatchers, answer worker heartbeats, probe on silence, and kill
/// the connection past the idle deadline.
fn reader_loop(shared: &Shared, conn: &Arc<Conn>, mut stream: TcpStream) {
    let hb = shared.cfg.heartbeat;
    let mut live = Liveness::new(hb, shared.cfg.io_timeout);
    if stream.set_read_timeout(Some(live.tick())).is_err() {
        kill_conn(
            shared,
            conn,
            WireError::Io(std::io::Error::other(
                "failed to set the reader tick",
            )),
        );
        return;
    }
    let mut fr = FrameReader::new();
    let mut hb_body = Vec::new();
    while conn.alive.load(Ordering::SeqCst)
        && !shared.closed.load(Ordering::SeqCst)
    {
        let polled = match fr.poll(&mut stream) {
            Ok(p) => p,
            Err(e) => {
                kill_conn(shared, conn, e);
                return;
            }
        };
        live.on_progress(fr.bytes_consumed());
        let Some(f) = polled else {
            // idle deadline: always while jobs are pending; only with
            // probing on for idle connections (a silent idle peer is
            // indistinguishable from a partitioned one without probes)
            let has_pending = !conn.pending.lock().unwrap().is_empty();
            match live.on_idle(has_pending || !hb.is_zero()) {
                TickAction::Dead { idle_ms, deadline_ms } => {
                    kill_conn(
                        shared,
                        conn,
                        WireError::HeartbeatLost {
                            idle_ms,
                            deadline_ms,
                        },
                    );
                    return;
                }
                TickAction::Probe => {
                    let nonce = shared
                        .next_nonce
                        .fetch_add(1, Ordering::Relaxed);
                    codec::encode_heartbeat(nonce, &mut hb_body);
                    let res = {
                        let mut w = conn.writer.lock().unwrap();
                        frame::write_frame(
                            &mut *w,
                            FrameKind::Heartbeat,
                            &hb_body,
                        )
                    };
                    match res {
                        Ok(_) => {
                            shared
                                .heartbeats_sent
                                .fetch_add(1, Ordering::Relaxed);
                        }
                        Err(e) => {
                            kill_conn(shared, conn, e);
                            return;
                        }
                    }
                }
                TickAction::Idle => {}
            }
            continue;
        };
        match f.kind {
            FrameKind::Outcome => {
                shared
                    .bytes_received
                    .fetch_add(f.total_bytes(), Ordering::Relaxed);
                let out = match codec::decode_outcome(&f.body) {
                    Ok(o) => o,
                    Err(e) => {
                        kill_conn(shared, conn, e);
                        return;
                    }
                };
                let key: PendingKey =
                    (out.round, out.client, out.job_id);
                let tx = conn.pending.lock().unwrap().remove(&key);
                match tx {
                    Some(tx) => {
                        // free the slot under the pool lock so slot
                        // waiters can't miss the wakeup
                        {
                            let _pool = shared.conns.lock().unwrap();
                            conn.in_flight
                                .fetch_sub(1, Ordering::SeqCst);
                        }
                        shared.slots.notify_all();
                        let _ = tx.send(Ok(out));
                    }
                    None => {
                        // duplicated frame, or the answer to a job
                        // that was already re-dispatched: bit-identical
                        // by the determinism contract, safe to drop
                        shared
                            .duplicate_outcomes
                            .fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            FrameKind::Heartbeat => {
                let nonce = match codec::decode_heartbeat(&f.body) {
                    Ok(n) => n,
                    Err(e) => {
                        kill_conn(shared, conn, e);
                        return;
                    }
                };
                codec::encode_heartbeat(nonce, &mut hb_body);
                let res = {
                    let mut w = conn.writer.lock().unwrap();
                    frame::write_frame(
                        &mut *w,
                        FrameKind::HeartbeatAck,
                        &hb_body,
                    )
                };
                if let Err(e) = res {
                    kill_conn(shared, conn, e);
                    return;
                }
            }
            FrameKind::HeartbeatAck => {
                // liveness already refreshed via bytes_consumed
                if let Err(e) = codec::decode_heartbeat(&f.body) {
                    kill_conn(shared, conn, e);
                    return;
                }
            }
            k => {
                kill_conn(
                    shared,
                    conn,
                    WireError::Malformed {
                        what: format!(
                            "unexpected {k:?} frame from a worker"
                        ),
                    },
                );
                return;
            }
        }
    }
    // transport shut down (or the conn was killed elsewhere): make
    // sure nobody is left waiting on this connection
    kill_conn(shared, conn, WireError::CleanClose);
}

impl Shared {
    /// Acquire a dispatch slot: the least-loaded live connection with
    /// a free window position. Blocks while the pool is saturated;
    /// fails fast when no live connections remain.
    fn acquire(&self) -> Result<Arc<Conn>> {
        let mut conns = self.conns.lock().unwrap();
        loop {
            ensure!(
                !self.closed.load(Ordering::SeqCst),
                "transport is shut down"
            );
            ensure!(
                !conns.is_empty(),
                "no live worker connections left (all were discarded \
                 after errors)"
            );
            let best = conns
                .iter()
                .filter(|c| {
                    c.in_flight.load(Ordering::SeqCst)
                        < self.cfg.inflight
                })
                .min_by_key(|c| c.in_flight.load(Ordering::SeqCst))
                .cloned();
            if let Some(c) = best {
                c.in_flight.fetch_add(1, Ordering::SeqCst);
                return Ok(c);
            }
            conns = self.slots.wait(conns).unwrap();
        }
    }
}

impl SocketTransport {
    /// Total Job-frame bytes sent to workers so far (re-dispatched
    /// frames included).
    pub fn bytes_sent(&self) -> u64 {
        self.shared.bytes_sent.load(Ordering::Relaxed)
    }

    /// Total Outcome-frame bytes received from workers so far.
    pub fn bytes_received(&self) -> u64 {
        self.shared.bytes_received.load(Ordering::Relaxed)
    }

    /// Live worker connections (diagnostics / tests).
    pub fn live_workers(&self) -> usize {
        self.shared.conns.lock().unwrap().len()
    }

    /// Outcome frames ignored because no job was waiting for them.
    pub fn duplicate_outcomes(&self) -> u64 {
        self.shared.duplicate_outcomes.load(Ordering::Relaxed)
    }

    /// Heartbeat probes this side has sent.
    pub fn heartbeats_sent(&self) -> u64 {
        self.shared.heartbeats_sent.load(Ordering::Relaxed)
    }

    /// Jobs re-dispatched to a surviving worker after a connection
    /// failure.
    pub fn requeues(&self) -> u64 {
        self.shared.requeues.load(Ordering::Relaxed)
    }

    /// Politely close every connection (Shutdown frame + socket
    /// close) so workers exit their serve loops, then stop the
    /// acceptor and reader threads. Idempotent; also runs on Drop.
    pub fn shutdown(&self) {
        let shared = &self.shared;
        if shared.closed.swap(true, Ordering::SeqCst) {
            return;
        }
        let conns: Vec<Arc<Conn>> = {
            let mut pool = shared.conns.lock().unwrap();
            pool.drain(..).collect()
        };
        for conn in conns {
            {
                let mut w = conn.writer.lock().unwrap();
                let _ =
                    frame::write_frame(&mut *w, FrameKind::Shutdown, &[]);
                let _ = w.shutdown(Shutdown::Both);
            }
            conn.alive.store(false, Ordering::SeqCst);
            // any pending jobs at shutdown (there should be none: the
            // round loop completes before shutdown) fail over cleanly
            let victims: Vec<PendingTx> = conn
                .pending
                .lock()
                .unwrap()
                .drain()
                .map(|(_, tx)| tx)
                .collect();
            let died = ConnDied {
                peer: conn.peer.clone(),
                error: Arc::new(WireError::CleanClose),
            };
            for tx in victims {
                let _ = tx.send(Err(died.clone()));
            }
        }
        shared.slots.notify_all();
        // join until the list drains: the acceptor may push one last
        // reader handle while we join (a replacement racing shutdown
        // — add_conn refuses to register it, but its spawn may have
        // landed in the list already)
        loop {
            let threads: Vec<JoinHandle<()>> = {
                let mut t = shared.threads.lock().unwrap();
                t.drain(..).collect()
            };
            if threads.is_empty() {
                break;
            }
            for h in threads {
                let _ = h.join();
            }
        }
    }
}

impl Drop for SocketTransport {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl Transport for SocketTransport {
    fn run_client(
        &self,
        job: ClientJob<'_>,
        buffers: &mut WorkBuffers,
    ) -> Result<ClientOutcome> {
        let shared = &self.shared;
        let (client, round) = (job.client, job.round);
        let key: PendingKey =
            (round as u32, client as u32, job.job_id);
        // reuse the cohort worker's wire scratch: one payload-sized
        // allocation per dispatcher thread for the life of the run,
        // not one per message (encode_job_from clears it first)
        let body = &mut buffers.wire;
        codec::encode_job_from(&job, body);
        let mut last_err: Option<anyhow::Error> = None;
        for attempt in 0..MAX_DISPATCH_ATTEMPTS {
            let conn = match shared.acquire() {
                Ok(c) => c,
                Err(e) => {
                    // no live workers: surface the fault that got us
                    // here (the pool-empty message alone hides it)
                    let e = match last_err.take() {
                        Some(prior) => prior.context(e.to_string()),
                        None => e,
                    };
                    return Err(e.context(format!(
                        "client {client} round {round}: dispatch failed"
                    )));
                }
            };
            if attempt > 0 {
                shared.requeues.fetch_add(1, Ordering::Relaxed);
            }
            let (tx, rx) = mpsc::channel();
            conn.pending.lock().unwrap().insert(key, tx);
            let write_res = {
                let mut w = conn.writer.lock().unwrap();
                frame::write_frame(&mut *w, FrameKind::Job, body)
            };
            match write_res {
                Ok(n) => {
                    shared.bytes_sent.fetch_add(n, Ordering::Relaxed);
                }
                Err(e) => {
                    // kill_conn drains pending (including ours), so
                    // rx below resolves immediately
                    kill_conn(shared, &conn, e);
                }
            }
            // race guard: if the connection died *around* our insert
            // (kill_conn may already have drained pending before the
            // entry landed), reclaim the entry ourselves so rx can't
            // wait on a sender nobody will ever drain — dropping our
            // tx turns the recv below into an immediate disconnect.
            if !conn.alive.load(Ordering::SeqCst) {
                conn.pending.lock().unwrap().remove(&key);
            }
            // wait for the outcome, re-checking connection health on
            // every io_timeout tick. Legitimate long computations are
            // unbounded by design — the worker's reader acks probes
            // while executing — but if the connection dies without
            // our entry being drained (a reader failure mode this
            // guards against), we reclaim it instead of parking
            // forever.
            let received = loop {
                match rx.recv_timeout(shared.cfg.io_timeout) {
                    Ok(r) => break Some(r),
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        if conn.alive.load(Ordering::SeqCst) {
                            continue;
                        }
                        conn.pending.lock().unwrap().remove(&key);
                        break None;
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        break None;
                    }
                }
            };
            match received {
                Some(Ok(out)) => {
                    ensure!(
                        out.client as usize == client
                            && out.round as usize == round,
                        "worker answered for client {} round {}, \
                         expected client {client} round {round}",
                        out.client,
                        out.round,
                    );
                    ensure!(
                        out.n_k == job.n_k,
                        "worker reported n_k {} for client {client}, \
                         server expected {} — worlds out of sync \
                         despite matching fingerprints?",
                        out.n_k,
                        job.n_k
                    );
                    return Ok(ClientOutcome {
                        uplink: Uplink {
                            payload: out.payload,
                            client,
                            n_k: out.n_k,
                            mean_loss: out.mean_loss,
                        },
                        ef: out.ef,
                    });
                }
                Some(Err(died)) => {
                    let peer = died.peer.clone();
                    last_err =
                        Some(anyhow::Error::from(died).context(format!(
                            "client {client} round {round} via worker \
                             {peer}"
                        )));
                }
                None => {
                    last_err = Some(anyhow!(
                        "client {client} round {round} via worker {}: \
                         connection reader exited without a result",
                        conn.peer
                    ));
                }
            }
        }
        Err(last_err
            .unwrap_or_else(|| anyhow!("dispatch failed"))
            .context(format!(
                "client {client} round {round}: re-dispatch budget \
                 ({MAX_DISPATCH_ATTEMPTS} attempts) exhausted"
            )))
    }
}
