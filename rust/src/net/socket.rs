//! `SocketTransport` — the [`Transport`] implementation that runs a
//! round's clients on remote worker processes over TCP.
//!
//! One pooled connection per worker, **one in-flight job per
//! connection**: `run_cohort`'s scoped threads each check a connection
//! out of the pool, exchange exactly one Job/Outcome frame pair with
//! blocking I/O, and return it. If the cohort fan-out is wider than
//! the pool, surplus threads block on a condvar until a connection
//! frees up — results are bit-identical either way (determinism comes
//! from counter-derived RNG streams and in-order aggregation, never
//! from scheduling).
//!
//! Every pooled stream carries a **read/write timeout**, so a silent
//! or wedged worker surfaces as a typed `WireError::Timeout` naming
//! the client — a round can fail, but it can never hang. A connection
//! that errors in any way is discarded (never returned to the pool):
//! the stream state after a failed exchange is unknowable, and the
//! next round must not inherit it. When every connection is gone the
//! next checkout fails fast instead of waiting forever.

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use anyhow::{ensure, Context, Result};

use crate::coordinator::comm::Uplink;
use crate::coordinator::transport::{
    ClientJob, ClientOutcome, Transport, WorkBuffers,
};

use super::codec::{self, Hello};
use super::frame::{self, FrameKind};

/// One pooled worker connection.
struct Conn {
    stream: TcpStream,
    /// Peer address, for error messages ("which worker failed?").
    peer: String,
    /// Reused job-serialization buffer: one payload-sized allocation
    /// per connection for the life of the run, not one per message.
    buf: Vec<u8>,
}

struct Pool {
    idle: Vec<Conn>,
    /// Live connections (idle + checked out). Reaches 0 only when
    /// every worker has been discarded after an error.
    live: usize,
}

/// TCP-backed client-execution transport (server side).
pub struct SocketTransport {
    pool: Mutex<Pool>,
    available: Condvar,
    /// Job-frame bytes written (exactly the downlink frame bytes).
    bytes_sent: AtomicU64,
    /// Outcome-frame bytes read (exactly the uplink frame bytes).
    bytes_received: AtomicU64,
}

/// Accept `n` worker connections from `listener`, handshake each one
/// against `hello` (config fingerprint + model identity), and build
/// the transport. Every accepted stream gets `timeout` as its
/// read/write deadline — the "never hang" guarantee.
pub fn accept_workers(
    listener: &TcpListener,
    n: usize,
    hello: &Hello,
    timeout: Duration,
) -> Result<SocketTransport> {
    ensure!(n >= 1, "need at least one worker connection");
    ensure!(!timeout.is_zero(), "worker read timeout must be non-zero");
    let mut idle = Vec::with_capacity(n);
    let mut ack = Vec::new();
    for _ in 0..n {
        let (mut stream, peer) = listener
            .accept()
            .context("accepting a worker connection")?;
        let peer = peer.to_string();
        stream.set_nodelay(true).ok();
        stream
            .set_read_timeout(Some(timeout))
            .context("setting worker read timeout")?;
        stream
            .set_write_timeout(Some(timeout))
            .context("setting worker write timeout")?;
        let f = frame::read_frame(&mut stream)
            .with_context(|| format!("handshake with worker {peer}"))?;
        ensure!(
            f.kind == FrameKind::Hello,
            "worker {peer} opened with a {:?} frame, expected Hello",
            f.kind
        );
        let h = codec::decode_hello(&f.body)
            .with_context(|| format!("handshake with worker {peer}"))?;
        ensure!(
            h.fingerprint == hello.fingerprint,
            "config fingerprint mismatch with worker {peer}: server \
             {:#018x}, worker {:#018x} — launch every worker with the \
             identical preset and overrides",
            hello.fingerprint,
            h.fingerprint
        );
        ensure!(
            h.model == hello.model,
            "model mismatch with worker {peer}: server runs '{}', \
             worker runs '{}'",
            hello.model,
            h.model
        );
        ensure!(
            h.dim == hello.dim,
            "model dim mismatch with worker {peer}: server {}, worker {}",
            hello.dim,
            h.dim
        );
        codec::encode_hello_ack(hello.fingerprint, &mut ack);
        frame::write_frame(&mut stream, FrameKind::HelloAck, &ack)
            .with_context(|| format!("acking worker {peer}"))?;
        idle.push(Conn {
            stream,
            peer,
            buf: Vec::new(),
        });
    }
    Ok(SocketTransport {
        pool: Mutex::new(Pool { idle, live: n }),
        available: Condvar::new(),
        bytes_sent: AtomicU64::new(0),
        bytes_received: AtomicU64::new(0),
    })
}

impl SocketTransport {
    /// Total Job-frame bytes sent to workers so far.
    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent.load(Ordering::Relaxed)
    }

    /// Total Outcome-frame bytes received from workers so far.
    pub fn bytes_received(&self) -> u64 {
        self.bytes_received.load(Ordering::Relaxed)
    }

    /// Live worker connections (diagnostics / tests).
    pub fn live_workers(&self) -> usize {
        self.pool.lock().unwrap().live
    }

    fn checkout(&self) -> Result<Conn> {
        let mut pool = self.pool.lock().unwrap();
        loop {
            if let Some(c) = pool.idle.pop() {
                return Ok(c);
            }
            ensure!(
                pool.live > 0,
                "no live worker connections left (all were discarded \
                 after errors)"
            );
            pool = self.available.wait(pool).unwrap();
        }
    }

    fn checkin(&self, conn: Conn) {
        self.pool.lock().unwrap().idle.push(conn);
        self.available.notify_one();
    }

    fn discard(&self, conn: Conn) {
        drop(conn); // closes the stream
        self.pool.lock().unwrap().live -= 1;
        // wake every waiter: they must re-check `live`
        self.available.notify_all();
    }

    /// One blocking job/outcome exchange on one connection.
    fn exchange(
        &self,
        conn: &mut Conn,
        job: &ClientJob<'_>,
    ) -> Result<ClientOutcome> {
        codec::encode_job_from(job, &mut conn.buf);
        let sent = frame::write_frame(
            &mut conn.stream,
            FrameKind::Job,
            &conn.buf,
        )
        .context("sending job frame")?;
        self.bytes_sent.fetch_add(sent, Ordering::Relaxed);
        let f = frame::read_frame(&mut conn.stream)
            .context("awaiting outcome frame")?;
        self.bytes_received
            .fetch_add(f.total_bytes(), Ordering::Relaxed);
        ensure!(
            f.kind == FrameKind::Outcome,
            "worker sent a {:?} frame where an Outcome was expected",
            f.kind
        );
        let out =
            codec::decode_outcome(&f.body).context("decoding outcome")?;
        ensure!(
            out.client as usize == job.client
                && out.round as usize == job.round,
            "worker answered for client {} round {}, expected client \
             {} round {}",
            out.client,
            out.round,
            job.client,
            job.round
        );
        ensure!(
            out.n_k == job.n_k,
            "worker reported n_k {} for client {}, server expected {} \
             — worlds out of sync despite matching fingerprints?",
            out.n_k,
            job.client,
            job.n_k
        );
        Ok(ClientOutcome {
            uplink: Uplink {
                payload: out.payload,
                client: job.client,
                n_k: out.n_k,
                mean_loss: out.mean_loss,
            },
            ef: out.ef,
        })
    }

    /// Politely close every idle connection (Shutdown frame + drop) so
    /// workers exit their serve loops cleanly. Best-effort: a worker
    /// that is already gone is simply dropped.
    pub fn shutdown(&self) {
        let drained: Vec<Conn> = {
            let mut pool = self.pool.lock().unwrap();
            let drained: Vec<Conn> = pool.idle.drain(..).collect();
            pool.live -= drained.len();
            drained
        };
        for mut conn in drained {
            let _ = frame::write_frame(
                &mut conn.stream,
                FrameKind::Shutdown,
                &[],
            );
        }
        self.available.notify_all();
    }
}

impl Transport for SocketTransport {
    fn run_client(
        &self,
        job: ClientJob<'_>,
        _buffers: &mut WorkBuffers,
    ) -> Result<ClientOutcome> {
        let (client, round) = (job.client, job.round);
        let mut conn = self.checkout().with_context(|| {
            format!("dispatching client {client} round {round}")
        })?;
        match self.exchange(&mut conn, &job) {
            Ok(out) => {
                self.checkin(conn);
                Ok(out)
            }
            Err(e) => {
                let peer = conn.peer.clone();
                self.discard(conn);
                Err(e.context(format!(
                    "client {client} round {round} via worker {peer}"
                )))
            }
        }
    }
}
